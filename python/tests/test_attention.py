"""L1 correctness: Pallas attention kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/blocks/dtypes; assert_allclose against ref.py is
the CORE correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode_attention, flash_attention_prefill, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


TOL = dict(rtol=2e-5, atol=2e-5)


class TestPrefillAttention:
    def test_matches_ref_basic(self):
        q, k, v = (_rand(i, (2, 4, 32, 16)) for i in range(3))
        out = flash_attention_prefill(q, k, v, block_q=16, block_k=16)
        np.testing.assert_allclose(out, ref.attention_prefill(q, k, v), **TOL)

    def test_single_block(self):
        q, k, v = (_rand(i, (1, 2, 8, 8)) for i in range(3))
        out = flash_attention_prefill(q, k, v, block_q=8, block_k=8)
        np.testing.assert_allclose(out, ref.attention_prefill(q, k, v), **TOL)

    def test_block_larger_than_seq_is_clamped(self):
        q, k, v = (_rand(i, (1, 2, 16, 8)) for i in range(3))
        out = flash_attention_prefill(q, k, v, block_q=128, block_k=128)
        np.testing.assert_allclose(out, ref.attention_prefill(q, k, v), **TOL)

    def test_rejects_non_dividing_block(self):
        q, k, v = (_rand(i, (1, 2, 24, 8)) for i in range(3))
        with pytest.raises(ValueError):
            flash_attention_prefill(q, k, v, block_q=16, block_k=16)

    def test_causality(self):
        """Perturbing a future key must not change earlier outputs."""
        q, k, v = (_rand(i, (1, 1, 16, 8)) for i in range(3))
        out1 = flash_attention_prefill(q, k, v, block_q=8, block_k=8)
        k2 = k.at[:, :, -1, :].add(100.0)
        v2 = v.at[:, :, -1, :].add(100.0)
        out2 = flash_attention_prefill(q, k2, v2, block_q=8, block_k=8)
        np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1], **TOL)

    def test_first_row_attends_only_to_itself(self):
        q, k, v = (_rand(i, (1, 1, 8, 4)) for i in range(3))
        out = flash_attention_prefill(q, k, v, block_q=8, block_k=8)
        np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], **TOL)

    @settings(deadline=None, max_examples=20)
    @given(
        batch=st.integers(1, 3),
        heads=st.integers(1, 4),
        log_seq=st.integers(2, 6),
        head_dim=st.sampled_from([4, 8, 16, 32]),
        log_block=st.integers(1, 5),
    )
    def test_hypothesis_shapes(self, batch, heads, log_seq, head_dim, log_block):
        seq, block = 2**log_seq, 2**log_block
        if seq % min(block, seq):
            return
        q, k, v = (_rand(i + 7, (batch, heads, seq, head_dim)) for i in range(3))
        out = flash_attention_prefill(q, k, v, block_q=block, block_k=block)
        np.testing.assert_allclose(out, ref.attention_prefill(q, k, v), **TOL)

    def test_large_magnitude_stability(self):
        """Online softmax must not overflow with large logits."""
        q = _rand(0, (1, 1, 16, 8)) * 30
        k = _rand(1, (1, 1, 16, 8)) * 30
        v = _rand(2, (1, 1, 16, 8))
        out = flash_attention_prefill(q, k, v, block_q=8, block_k=8)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(out, ref.attention_prefill(q, k, v), **TOL)


class TestDecodeAttention:
    def test_matches_ref_basic(self):
        b, h, m, d = 2, 4, 64, 16
        q = _rand(0, (b, h, 1, d))
        kc, vc = _rand(1, (b, h, m, d)), _rand(2, (b, h, m, d))
        out = decode_attention(q, kc, vc, jnp.int32(10), block_k=16)
        np.testing.assert_allclose(
            out, ref.attention_decode(q, kc, vc, jnp.int32(10)), **TOL
        )

    @pytest.mark.parametrize("pos", [0, 1, 15, 31, 63])
    def test_positions(self, pos):
        b, h, m, d = 1, 2, 64, 8
        q = _rand(3, (b, h, 1, d))
        kc, vc = _rand(4, (b, h, m, d)), _rand(5, (b, h, m, d))
        out = decode_attention(q, kc, vc, jnp.int32(pos), block_k=16)
        np.testing.assert_allclose(
            out, ref.attention_decode(q, kc, vc, jnp.int32(pos)), **TOL
        )

    def test_pos_zero_returns_first_value(self):
        b, h, m, d = 1, 1, 32, 8
        q = _rand(6, (b, h, 1, d))
        kc, vc = _rand(7, (b, h, m, d)), _rand(8, (b, h, m, d))
        out = decode_attention(q, kc, vc, jnp.int32(0), block_k=8)
        np.testing.assert_allclose(out[0, 0, 0], vc[0, 0, 0], **TOL)

    def test_masked_cache_is_ignored(self):
        """Garbage beyond pos must not leak into the output."""
        b, h, m, d = 1, 2, 32, 8
        q = _rand(9, (b, h, 1, d))
        kc, vc = _rand(10, (b, h, m, d)), _rand(11, (b, h, m, d))
        pos = jnp.int32(7)
        out1 = decode_attention(q, kc, vc, pos, block_k=8)
        kc2 = kc.at[:, :, 8:, :].set(1e6)
        vc2 = vc.at[:, :, 8:, :].set(-1e6)
        out2 = decode_attention(q, kc2, vc2, pos, block_k=8)
        np.testing.assert_allclose(out1, out2, **TOL)

    @settings(deadline=None, max_examples=20)
    @given(
        batch=st.integers(1, 3),
        heads=st.integers(1, 4),
        log_max=st.integers(3, 7),
        head_dim=st.sampled_from([4, 8, 16]),
        pos_frac=st.floats(0, 1),
    )
    def test_hypothesis_shapes(self, batch, heads, log_max, head_dim, pos_frac):
        m = 2**log_max
        pos = jnp.int32(int(pos_frac * (m - 1)))
        q = _rand(12, (batch, heads, 1, head_dim))
        kc, vc = _rand(13, (batch, heads, m, head_dim)), _rand(
            14, (batch, heads, m, head_dim)
        )
        out = decode_attention(q, kc, vc, pos, block_k=8)
        np.testing.assert_allclose(out, ref.attention_decode(q, kc, vc, pos), **TOL)

    def test_decode_equals_prefill_last_row(self):
        """Decode over a cache == last row of prefill over the same seq."""
        b, h, s, d = 1, 2, 16, 8
        q = _rand(15, (b, h, s, d))
        k = _rand(16, (b, h, s, d))
        v = _rand(17, (b, h, s, d))
        pre = ref.attention_prefill(q, k, v)
        out = decode_attention(
            q[:, :, -1:, :], k, v, jnp.int32(s - 1), block_k=8
        )
        np.testing.assert_allclose(out[:, :, 0], pre[:, :, -1], **TOL)
