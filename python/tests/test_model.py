"""L2 correctness: shard composition, KV-cache consistency, GQA, generation.

The key invariant for EdgeShard: running the model as independent shards
(what the rust coordinator does across devices) must be numerically
identical to a monolithic forward pass, and the decode path (KV cache) must
agree with re-running prefill over the extended sequence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.fixture(scope="module", params=[M.TINY_GQA])
def cfg(request):
    return request.param


@pytest.fixture(scope="module")
def weights(cfg):
    return M.init_weights(cfg, seed=0)


def _tokens(cfg, batch, length, seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (batch, length), 0, cfg.vocab_size
    ).astype(jnp.int32)


def monolithic_forward(cfg, weights, tokens):
    """Straight-line reference forward (no shards, no pallas, no cache)."""
    h = weights["tok_emb"][tokens]
    s = tokens.shape[1]
    positions = jnp.arange(s)
    for i in range(cfg.n_layers):
        w = {p: weights[f"layers.{i}.{p}"] for p in M.ModelConfig.LAYER_PARAM_ORDER}
        x = ref.rms_norm(h, w["attn_norm"], cfg.norm_eps)
        b = x.shape[0]
        hd = cfg.head_dim
        q = (x @ w["wq"]).reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        k = (x @ w["wk"]).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        v = (x @ w["wv"]).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        q = ref.rope(q, positions, cfg.rope_theta)
        k = ref.rope(k, positions, cfg.rope_theta)
        reps = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, reps, axis=1)
        v = jnp.repeat(v, reps, axis=1)
        attn = ref.attention_prefill(q, k, v)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, -1)
        h = h + attn @ w["wo"]
        x = ref.rms_norm(h, w["ffn_norm"], cfg.norm_eps)
        mlp = ref.swiglu_mlp(
            x.reshape(b * s, cfg.d_model), w["w_gate"], w["w_up"], w["w_down"]
        ).reshape(b, s, cfg.d_model)
        h = h + mlp
    x = ref.rms_norm(h[:, -1, :], weights["final_norm"], cfg.norm_eps)
    return x @ weights["lm_head"]


class TestShardComposition:
    def test_prefill_matches_monolithic(self, cfg, weights):
        toks = _tokens(cfg, 2, cfg.prefill_len)
        logits, _ = M.full_prefill(cfg, weights, toks)
        expect = monolithic_forward(cfg, weights, toks)
        np.testing.assert_allclose(logits, expect, **TOL)

    def test_decode_matches_prefill_extension(self, cfg, weights):
        """Prefill(n) + decode steps == prefill(n + k) at every step."""
        n, k = cfg.prefill_len, 3
        toks = _tokens(cfg, 1, n + k, seed=3)
        logits, caches = M.full_prefill(cfg, weights, toks[:, :n])
        for step in range(k):
            pos = n + step
            expect = monolithic_forward(cfg, weights, toks[:, : pos + 1])
            logits, caches = M.full_decode_step(
                cfg, weights, toks[:, pos : pos + 1], caches, jnp.int32(pos)
            )
            np.testing.assert_allclose(logits, expect, **TOL)

    def test_prefill_cache_contents(self, cfg, weights):
        """Cache positions >= prompt length must be zero after prefill."""
        toks = _tokens(cfg, 1, cfg.prefill_len)
        _, caches = M.full_prefill(cfg, weights, toks)
        for kc, vc in caches:
            assert kc.shape == (1, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim)
            np.testing.assert_array_equal(kc[:, :, cfg.prefill_len :], 0.0)
            np.testing.assert_array_equal(vc[:, :, cfg.prefill_len :], 0.0)
            assert np.abs(np.asarray(kc[:, :, : cfg.prefill_len])).sum() > 0

    def test_batch_consistency(self, cfg, weights):
        """Each batch row must be independent (batched == per-row)."""
        toks = _tokens(cfg, 3, cfg.prefill_len, seed=5)
        logits, _ = M.full_prefill(cfg, weights, toks)
        for b in range(3):
            solo, _ = M.full_prefill(cfg, weights, toks[b : b + 1])
            np.testing.assert_allclose(logits[b : b + 1], solo, **TOL)


class TestGenerate:
    def test_deterministic(self, cfg, weights):
        toks = _tokens(cfg, 2, cfg.prefill_len, seed=7)
        g1 = M.generate(cfg, weights, toks, 4)
        g2 = M.generate(cfg, weights, toks, 4)
        np.testing.assert_array_equal(g1, g2)

    def test_output_range(self, cfg, weights):
        toks = _tokens(cfg, 1, cfg.prefill_len, seed=8)
        g = M.generate(cfg, weights, toks, 5)
        assert g.shape == (1, 5)
        assert ((g >= 0) & (g < cfg.vocab_size)).all()

    def test_greedy_matches_manual_loop(self, cfg, weights):
        toks = _tokens(cfg, 1, cfg.prefill_len, seed=9)
        g = M.generate(cfg, weights, toks, 3)
        logits, caches = M.full_prefill(cfg, weights, toks)
        t0 = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        np.testing.assert_array_equal(g[:, 0:1], t0)
        logits, caches = M.full_decode_step(
            cfg, weights, t0, caches, jnp.int32(cfg.prefill_len)
        )
        t1 = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        np.testing.assert_array_equal(g[:, 1:2], t1)


class TestWeights:
    def test_deterministic_init(self, cfg):
        w1 = M.init_weights(cfg, seed=0)
        w2 = M.init_weights(cfg, seed=0)
        for k in w1:
            np.testing.assert_array_equal(w1[k], w2[k])

    def test_seed_changes_weights(self, cfg):
        w1 = M.init_weights(cfg, seed=0)
        w2 = M.init_weights(cfg, seed=1)
        assert not np.allclose(w1["lm_head"], w2["lm_head"])

    def test_all_params_present(self, cfg):
        w = M.init_weights(cfg)
        assert "tok_emb" in w and "final_norm" in w and "lm_head" in w
        for i in range(cfg.n_layers):
            for p in M.ModelConfig.LAYER_PARAM_ORDER:
                assert f"layers.{i}.{p}" in w

    def test_shapes_match_config(self, cfg):
        w = M.init_weights(cfg)
        shapes = cfg.layer_param_shapes()
        for p, s in shapes.items():
            assert w[f"layers.0.{p}"].shape == s
        assert w["tok_emb"].shape == (cfg.vocab_size, cfg.d_model)
        assert w["lm_head"].shape == (cfg.d_model, cfg.vocab_size)


class TestRefPrimitives:
    def test_rms_norm_unit_variance(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 5
        out = ref.rms_norm(x, jnp.ones(64))
        rms = jnp.sqrt(jnp.mean(out**2, -1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 8, 16))
        out = ref.rope(x, jnp.arange(8))
        np.testing.assert_allclose(
            jnp.linalg.norm(out, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
        )

    def test_rope_position_zero_is_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 8))
        out = ref.rope(x, jnp.array([0]))
        np.testing.assert_allclose(out, x, atol=1e-6)

    def test_rope_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n (per pair-plane)."""
        q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, 16))
        def dot(m, n):
            qr = ref.rope(q, jnp.array([m]))
            kr = ref.rope(k, jnp.array([n]))
            return float(jnp.sum(qr * kr))
        np.testing.assert_allclose(dot(5, 3), dot(10, 8), rtol=1e-4)
        np.testing.assert_allclose(dot(2, 2), dot(9, 9), rtol=1e-4)
