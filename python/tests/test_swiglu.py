"""L1 correctness: fused SwiGLU Pallas kernel vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import swiglu_mlp, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


TOL = dict(rtol=1e-4, atol=1e-4)


def _mats(d_model, d_ff, scale=0.1):
    return (
        _rand(1, (d_model, d_ff), scale),
        _rand(2, (d_model, d_ff), scale),
        _rand(3, (d_ff, d_model), scale),
    )


class TestSwiglu:
    def test_matches_ref_basic(self):
        x = _rand(0, (8, 32))
        wg, wu, wd = _mats(32, 64)
        out = swiglu_mlp(x, wg, wu, wd, block_f=16)
        np.testing.assert_allclose(out, ref.swiglu_mlp(x, wg, wu, wd), **TOL)

    def test_single_token(self):
        x = _rand(4, (1, 16))
        wg, wu, wd = _mats(16, 32)
        out = swiglu_mlp(x, wg, wu, wd, block_f=8)
        np.testing.assert_allclose(out, ref.swiglu_mlp(x, wg, wu, wd), **TOL)

    def test_block_equals_dff(self):
        x = _rand(5, (4, 16))
        wg, wu, wd = _mats(16, 32)
        out = swiglu_mlp(x, wg, wu, wd, block_f=32)
        np.testing.assert_allclose(out, ref.swiglu_mlp(x, wg, wu, wd), **TOL)

    def test_block_clamped_to_dff(self):
        x = _rand(6, (4, 16))
        wg, wu, wd = _mats(16, 32)
        out = swiglu_mlp(x, wg, wu, wd, block_f=512)
        np.testing.assert_allclose(out, ref.swiglu_mlp(x, wg, wu, wd), **TOL)

    def test_rejects_non_dividing_block(self):
        x = _rand(7, (4, 16))
        wg, wu, wd = _mats(16, 48)
        with pytest.raises(ValueError):
            swiglu_mlp(x, wg, wu, wd, block_f=32)

    def test_zero_input_gives_zero(self):
        x = jnp.zeros((4, 16))
        wg, wu, wd = _mats(16, 32)
        out = swiglu_mlp(x, wg, wu, wd, block_f=8)
        np.testing.assert_allclose(out, jnp.zeros((4, 16)), atol=1e-7)

    def test_block_invariance(self):
        """Result must not depend on the tiling choice."""
        x = _rand(8, (8, 32))
        wg, wu, wd = _mats(32, 64)
        outs = [swiglu_mlp(x, wg, wu, wd, block_f=bf) for bf in (8, 16, 32, 64)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], **TOL)

    @settings(deadline=None, max_examples=20)
    @given(
        tokens=st.integers(1, 16),
        log_d=st.integers(2, 6),
        log_f=st.integers(3, 7),
        log_block=st.integers(2, 6),
    )
    def test_hypothesis_shapes(self, tokens, log_d, log_f, log_block):
        d, f, bf = 2**log_d, 2**log_f, 2**log_block
        x = _rand(9, (tokens, d))
        wg, wu, wd = _mats(d, f)
        if f % min(bf, f):
            return
        out = swiglu_mlp(x, wg, wu, wd, block_f=bf)
        np.testing.assert_allclose(out, ref.swiglu_mlp(x, wg, wu, wd), **TOL)
