"""AOT path: manifest integrity, weight export layout, HLO text validity.

These tests guard the python->rust interchange contract: the rust runtime
(rust/src/runtime/) trusts manifest.json's signatures and weights.bin's
layout byte-for-byte.
"""

import io
import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_config_matches_tiny(self):
        m = _manifest()
        cfg = m["config"]
        assert cfg["d_model"] == M.TINY.d_model
        assert cfg["n_layers"] == M.TINY.n_layers
        assert cfg["max_seq"] == M.TINY.max_seq
        assert cfg["prefill_len"] == M.TINY.prefill_len
        assert cfg["layer_param_order"] == list(M.ModelConfig.LAYER_PARAM_ORDER)

    def test_all_variants_present(self):
        m = _manifest()
        names = {a["name"] for a in m["artifacts"]}
        for b in m["batch_sizes"]:
            for fn in ("embed", "layer", "head"):
                for ph in ("prefill", "decode"):
                    assert f"{fn}_{ph}_b{b}" in names

    def test_artifact_files_exist(self):
        m = _manifest()
        for a in m["artifacts"]:
            path = os.path.join(ART, a["file"])
            assert os.path.exists(path), a["file"]
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head

    def test_weight_table_is_contiguous(self):
        m = _manifest()
        offset = 0
        for w in m["weights"]:
            assert w["offset_bytes"] == offset
            offset += int(np.prod(w["shape"])) * 4
        assert offset == m["weights_total_bytes"]
        assert os.path.getsize(os.path.join(ART, m["weights_file"])) == offset

    def test_weight_order_matches_model(self):
        m = _manifest()
        names = [w["name"] for w in m["weights"]]
        expect = ["tok_emb"]
        for i in range(M.TINY.n_layers):
            expect += [f"layers.{i}.{p}" for p in M.ModelConfig.LAYER_PARAM_ORDER]
        expect += ["final_norm", "lm_head"]
        assert names == expect

    def test_weights_bin_matches_init(self):
        """weights.bin must equal init_weights(TINY, seed=0) byte-for-byte."""
        m = _manifest()
        weights = M.init_weights(M.TINY, seed=0)
        with open(os.path.join(ART, m["weights_file"]), "rb") as f:
            blob = f.read()
        for w in m["weights"][:3] + m["weights"][-2:]:
            n = int(np.prod(w["shape"]))
            got = np.frombuffer(
                blob, dtype="<f4", count=n, offset=w["offset_bytes"]
            ).reshape(w["shape"])
            np.testing.assert_array_equal(got, np.asarray(weights[w["name"]]))

    def test_layer_signatures(self):
        """The rust runtime relies on exact input ordering for layer shards."""
        m = _manifest()
        cfg = M.TINY
        art = {a["name"]: a for a in m["artifacts"]}
        for b in m["batch_sizes"]:
            a = art[f"layer_decode_b{b}"]
            ins = a["inputs"]
            assert len(ins) == 9 + 4  # 9 weights + h, k_cache, v_cache, pos
            assert ins[9]["shape"] == [b, 1, cfg.d_model]
            assert ins[10]["shape"] == [
                b,
                cfg.n_kv_heads,
                cfg.max_seq,
                cfg.head_dim,
            ]
            assert ins[12]["shape"] == []
            assert ins[12]["dtype"] == "int32"
            outs = a["outputs"]
            assert [o["shape"] for o in outs] == [
                [b, 1, cfg.d_model],
                [b, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim],
                [b, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim],
            ]

    def test_head_signature(self):
        m = _manifest()
        cfg = M.TINY
        art = {a["name"]: a for a in m["artifacts"]}
        a = art["head_prefill_b1"]
        assert a["outputs"][0]["shape"] == [1, cfg.vocab_size]


class TestLowering:
    def test_hlo_text_roundtrip(self, tmp_path):
        """Lower one variant fresh and sanity-check the HLO text."""
        cfg = M.TINY_GQA
        found = False
        for name, fn, specs in aot.shard_variants(cfg):
            if name != "embed_decode_b1":
                continue
            found = True
            lowered = jax.jit(fn).lower(*specs)
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule")
            assert "ENTRY" in text
        assert found

    def test_variant_count(self):
        names = [n for n, _, _ in aot.shard_variants(M.TINY)]
        assert len(names) == 6 * len(aot.BATCH_SIZES)
        assert len(set(names)) == len(names)

    def test_export_weights_layout(self, tmp_path):
        cfg = M.TINY_GQA
        table, total = aot.export_weights(cfg, str(tmp_path), seed=0)
        blob = open(os.path.join(tmp_path, "weights.bin"), "rb").read()
        assert len(blob) == total
        weights = M.init_weights(cfg, seed=0)
        for w in table:
            n = int(np.prod(w["shape"]))
            got = np.frombuffer(
                blob, dtype="<f4", count=n, offset=w["offset_bytes"]
            ).reshape(w["shape"])
            np.testing.assert_array_equal(got, np.asarray(weights[w["name"]]))
