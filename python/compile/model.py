"""Layer-2: Llama-style decoder shards in JAX (build-time only).

EdgeShard partitions an LLM *layer-wise* across devices, so the unit of AOT
compilation is the **shard function**, not the whole model:

* ``embed_prefill`` / ``embed_decode``  — token embedding lookup
* ``layer_prefill`` / ``layer_decode``  — one decoder block (RMSNorm ->
  RoPE QKV -> Pallas attention -> residual -> RMSNorm -> Pallas SwiGLU ->
  residual), KV cache explicit in/out
* ``head_prefill`` / ``head_decode``    — final RMSNorm + LM head logits

All decoder layers share shapes, so ONE compiled ``layer_*`` executable
serves every layer: the rust coordinator feeds each call that layer's weight
buffers.  This is what makes arbitrary layer->device partitions possible
without recompilation.

Weights are runtime *inputs* (exported to ``artifacts/weights.bin`` by
``aot.py``), never baked into HLO constants.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import decode_attention, flash_attention_prefill, swiglu_mlp
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture of the executable model."""

    name: str = "tinyllama-4l"
    vocab_size: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 256
    max_seq: int = 128
    prefill_len: int = 32
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def layer_param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        d, hd = self.d_model, self.head_dim
        return {
            "attn_norm": (d,),
            "wq": (d, self.n_heads * hd),
            "wk": (d, self.n_kv_heads * hd),
            "wv": (d, self.n_kv_heads * hd),
            "wo": (self.n_heads * hd, d),
            "ffn_norm": (d,),
            "w_gate": (d, self.d_ff),
            "w_up": (d, self.d_ff),
            "w_down": (self.d_ff, d),
        }

    # Canonical ordering of the per-layer weight arguments for the shard fns
    # and for the flat weights.bin export.  rust/src/runtime/weights.rs
    # mirrors this order.
    LAYER_PARAM_ORDER = (
        "attn_norm",
        "wq",
        "wk",
        "wv",
        "wo",
        "ffn_norm",
        "w_gate",
        "w_up",
        "w_down",
    )


TINY = ModelConfig()
# A second config exercised by tests to catch shape assumptions (GQA: fewer
# KV heads than Q heads).
TINY_GQA = ModelConfig(
    name="tinyllama-gqa",
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    max_seq=64,
    prefill_len=16,
)


def init_weights(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic random-init weights, keyed like the manifest entries."""
    key = jax.random.PRNGKey(seed)
    out: Dict[str, jax.Array] = {}

    def nxt():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    scale = 0.02
    out["tok_emb"] = jax.random.normal(
        nxt(), (cfg.vocab_size, cfg.d_model), jnp.float32
    ) * scale
    for i in range(cfg.n_layers):
        # Draw in canonical order so the export layout is deterministic.
        for pname in ModelConfig.LAYER_PARAM_ORDER:
            shape = cfg.layer_param_shapes()[pname]
            if pname.endswith("norm"):
                out[f"layers.{i}.{pname}"] = jnp.ones(shape, jnp.float32)
            else:
                out[f"layers.{i}.{pname}"] = (
                    jax.random.normal(nxt(), shape, jnp.float32) * scale
                )
    out["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    out["lm_head"] = (
        jax.random.normal(nxt(), (cfg.d_model, cfg.vocab_size), jnp.float32) * scale
    )
    return out


# --------------------------------------------------------------------------
# Shard functions.  Layer weights are passed positionally in
# ModelConfig.LAYER_PARAM_ORDER so the HLO parameter order is stable.
# --------------------------------------------------------------------------


def embed_shard(cfg: ModelConfig, tok_emb: jax.Array, tokens: jax.Array) -> jax.Array:
    """tokens [B, S] int32 -> hidden [B, S, D]."""
    return tok_emb[tokens]


def _qkv(cfg: ModelConfig, h, wq, wk, wv, positions):
    """Project + reshape + RoPE.  h: [B, S, D] -> q [B,H,S,hd], k/v [B,KV,S,hd]."""
    b, s, _ = h.shape
    hd = cfg.head_dim
    q = (h @ wq).reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = (h @ wk).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (h @ wv).reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    q = ref.rope(q, positions, cfg.rope_theta)
    k = ref.rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """[B, KV, S, hd] -> [B, H, S, hd] by repeating each KV head."""
    reps = cfg.n_heads // cfg.n_kv_heads
    if reps == 1:
        return x
    return jnp.repeat(x, reps, axis=1)


def layer_prefill_shard(
    cfg: ModelConfig,
    attn_norm,
    wq,
    wk,
    wv,
    wo,
    ffn_norm,
    w_gate,
    w_up,
    w_down,
    h: jax.Array,
    *,
    interpret: bool = True,
):
    """One decoder block over the whole prompt.

    h: [B, S, D] -> (h': [B, S, D], k_cache, v_cache: [B, KV, max_seq, hd])
    The returned caches are zero-padded to max_seq with positions 0..S-1
    filled, ready for the decode phase.
    """
    b, s, _ = h.shape
    positions = jnp.arange(s)
    x = ref.rms_norm(h, attn_norm, cfg.norm_eps)
    q, k, v = _qkv(cfg, x, wq, wk, wv, positions)
    attn = flash_attention_prefill(
        q, _repeat_kv(cfg, k), _repeat_kv(cfg, v), interpret=interpret
    )
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, -1)
    h = h + attn @ wo
    x = ref.rms_norm(h, ffn_norm, cfg.norm_eps)
    mlp = swiglu_mlp(
        x.reshape(b * s, cfg.d_model), w_gate, w_up, w_down, interpret=interpret
    ).reshape(b, s, cfg.d_model)
    h = h + mlp

    pad = cfg.max_seq - s
    k_cache = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    v_cache = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return h, k_cache, v_cache


def layer_decode_shard(
    cfg: ModelConfig,
    attn_norm,
    wq,
    wk,
    wv,
    wo,
    ffn_norm,
    w_gate,
    w_up,
    w_down,
    h: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    interpret: bool = True,
):
    """One decoder block for a single new token at absolute position ``pos``.

    h: [B, 1, D]; caches [B, KV, max_seq, hd] -> (h', k_cache', v_cache').
    """
    b = h.shape[0]
    positions = jnp.reshape(pos, (1,))
    x = ref.rms_norm(h, attn_norm, cfg.norm_eps)
    q, k, v = _qkv(cfg, x, wq, wk, wv, positions)
    # Write this token's K/V into the cache at `pos`.
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, 0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, 0, pos, 0))
    attn = decode_attention(
        q,
        _repeat_kv(cfg, k_cache),
        _repeat_kv(cfg, v_cache),
        pos,
        interpret=interpret,
    )
    attn = attn.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    h = h + attn @ wo
    x = ref.rms_norm(h, ffn_norm, cfg.norm_eps)
    mlp = swiglu_mlp(
        x.reshape(b, cfg.d_model), w_gate, w_up, w_down, interpret=interpret
    ).reshape(b, 1, cfg.d_model)
    h = h + mlp
    return h, k_cache, v_cache


def head_shard(cfg: ModelConfig, final_norm, lm_head, h: jax.Array) -> jax.Array:
    """hidden [B, S, D] -> logits [B, vocab] for the LAST position."""
    x = ref.rms_norm(h[:, -1, :], final_norm, cfg.norm_eps)
    return x @ lm_head


# --------------------------------------------------------------------------
# Whole-model composition (used by tests and by aot.py's self-check; the
# rust coordinator performs the same composition across devices).
# --------------------------------------------------------------------------


def full_prefill(
    cfg: ModelConfig,
    weights: Dict[str, jax.Array],
    tokens: jax.Array,
    *,
    interpret: bool = True,
):
    """Compose shards over the prompt.  Returns (logits, caches per layer)."""
    h = embed_shard(cfg, weights["tok_emb"], tokens)
    caches: List[Tuple[jax.Array, jax.Array]] = []
    for i in range(cfg.n_layers):
        args = [weights[f"layers.{i}.{p}"] for p in ModelConfig.LAYER_PARAM_ORDER]
        h, kc, vc = layer_prefill_shard(cfg, *args, h, interpret=interpret)
        caches.append((kc, vc))
    logits = head_shard(cfg, weights["final_norm"], weights["lm_head"], h)
    return logits, caches


def full_decode_step(
    cfg: ModelConfig,
    weights: Dict[str, jax.Array],
    token: jax.Array,
    caches,
    pos: jax.Array,
    *,
    interpret: bool = True,
):
    """One autoregressive step.  token: [B, 1] int32."""
    h = embed_shard(cfg, weights["tok_emb"], token)
    new_caches = []
    for i in range(cfg.n_layers):
        args = [weights[f"layers.{i}.{p}"] for p in ModelConfig.LAYER_PARAM_ORDER]
        kc, vc = caches[i]
        h, kc, vc = layer_decode_shard(cfg, *args, h, kc, vc, pos, interpret=interpret)
        new_caches.append((kc, vc))
    logits = head_shard(cfg, weights["final_norm"], weights["lm_head"], h)
    return logits, new_caches


def generate(
    cfg: ModelConfig,
    weights: Dict[str, jax.Array],
    tokens: jax.Array,
    n_new: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Greedy generation oracle (python reference for the rust engine)."""
    logits, caches = full_prefill(cfg, weights, tokens, interpret=interpret)
    out = []
    pos = tokens.shape[1]
    cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out.append(cur)
    for _ in range(n_new - 1):
        logits, caches = full_decode_step(
            cfg, weights, cur, caches, jnp.int32(pos), interpret=interpret
        )
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(cur)
        pos += 1
    return jnp.concatenate(out, axis=1)
