"""AOT compile path: lower every shard variant to HLO **text** + export weights.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs (consumed by rust/src/runtime/):

* ``artifacts/<name>.hlo.txt``  — one per (shard fn, phase, batch) variant
* ``artifacts/weights.bin``     — flat little-endian f32, canonical order
* ``artifacts/manifest.json``   — model config + weight table + artifact
  input/output signatures

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate links) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

BATCH_SIZES = (1, 8)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(specs) -> List[dict]:
    return [{"dtype": str(s.dtype), "shape": list(s.shape)} for s in specs]


def shard_variants(cfg: M.ModelConfig):
    """Yield (name, fn, arg_specs, output_signature) for every AOT variant."""
    d, hd, kv = cfg.d_model, cfg.head_dim, cfg.n_kv_heads
    s_pre, max_seq, vocab = cfg.prefill_len, cfg.max_seq, cfg.vocab_size
    layer_w = [
        _spec(cfg.layer_param_shapes()[p]) for p in M.ModelConfig.LAYER_PARAM_ORDER
    ]
    emb_w = _spec((vocab, d))
    head_w = [_spec((d,)), _spec((d, vocab))]
    cache = _spec((0, kv, max_seq, hd))  # batch filled per-variant

    for b in BATCH_SIZES:
        cache_b = _spec((b, kv, max_seq, hd))
        variants = {
            f"embed_prefill_b{b}": (
                lambda emb, toks: (M.embed_shard(cfg, emb, toks),),
                [emb_w, _spec((b, s_pre), jnp.int32)],
            ),
            f"embed_decode_b{b}": (
                lambda emb, toks: (M.embed_shard(cfg, emb, toks),),
                [emb_w, _spec((b, 1), jnp.int32)],
            ),
            f"layer_prefill_b{b}": (
                lambda *a: M.layer_prefill_shard(cfg, *a),
                layer_w + [_spec((b, s_pre, d))],
            ),
            f"layer_decode_b{b}": (
                lambda *a: M.layer_decode_shard(cfg, *a),
                layer_w
                + [_spec((b, 1, d)), cache_b, cache_b, _spec((), jnp.int32)],
            ),
            f"head_prefill_b{b}": (
                lambda fn_, lm, h: (M.head_shard(cfg, fn_, lm, h),),
                head_w + [_spec((b, s_pre, d))],
            ),
            f"head_decode_b{b}": (
                lambda fn_, lm, h: (M.head_shard(cfg, fn_, lm, h),),
                head_w + [_spec((b, 1, d))],
            ),
        }
        for name, (fn, specs) in variants.items():
            yield name, fn, specs


def export_weights(cfg: M.ModelConfig, out_dir: str, seed: int = 0):
    """Write weights.bin + return the manifest weight table."""
    weights = M.init_weights(cfg, seed)
    order = ["tok_emb"]
    for i in range(cfg.n_layers):
        order += [f"layers.{i}.{p}" for p in M.ModelConfig.LAYER_PARAM_ORDER]
    order += ["final_norm", "lm_head"]

    table = []
    offset = 0
    path = os.path.join(out_dir, "weights.bin")
    with open(path, "wb") as f:
        for name in order:
            arr = np.asarray(weights[name], dtype="<f4")
            f.write(arr.tobytes())
            table.append(
                {"name": name, "offset_bytes": offset, "shape": list(arr.shape)}
            )
            offset += arr.nbytes
    return table, offset


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file marker path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    cfg = M.TINY
    artifacts = []
    for name, fn, specs in shard_variants(cfg):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_specs = [
            {"dtype": str(o.dtype), "shape": list(o.shape)}
            for o in jax.eval_shape(fn, *specs)
        ]
        artifacts.append(
            {"name": name, "file": fname, "inputs": _sig(specs), "outputs": out_specs}
        )
        print(f"lowered {name}: {len(text)} chars")

    table, total = export_weights(cfg, out_dir, args.seed)

    manifest = {
        "config": {
            "name": cfg.name,
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "prefill_len": cfg.prefill_len,
            "layer_param_order": list(M.ModelConfig.LAYER_PARAM_ORDER),
        },
        "batch_sizes": list(BATCH_SIZES),
        "weights_file": "weights.bin",
        "weights_total_bytes": total,
        "weights": table,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if args.out is not None:
        # legacy Makefile stamp: the first artifact doubles as the stamp file
        with open(args.out, "w") as f:
            f.write("see manifest.json\n")
    print(f"wrote {len(artifacts)} artifacts + weights ({total} bytes) to {out_dir}")


if __name__ == "__main__":
    main()
