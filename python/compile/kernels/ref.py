"""Pure-jnp reference oracle for every Layer-1 kernel.

These are the semantics the Pallas kernels must reproduce; pytest asserts
allclose between the two.  Kept dependency-free (no pallas import) so they
also serve as readable documentation of the math.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_prefill(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal softmax attention. q,k,v: [batch, heads, seq, head_dim]."""
    seq = q.shape[2]
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def attention_decode(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array
) -> jax.Array:
    """Single-token attention over a length-masked cache.

    q: [batch, heads, 1, head_dim]; caches: [batch, heads, max_seq, head_dim];
    pos: scalar — positions > pos are masked out.
    """
    max_seq = k_cache.shape[2]
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    )
    s = s * scale
    idx = jnp.arange(max_seq)
    s = jnp.where(idx[None, None, None, :] <= pos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v_cache.astype(jnp.float32)).astype(
        q.dtype
    )


def swiglu_mlp(
    x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array
) -> jax.Array:
    """SwiGLU feed-forward: silu(x@Wg) * (x@Wu) @ Wd.  x: [tokens, d_model]."""
    xf = x.astype(jnp.float32)
    g = xf @ w_gate.astype(jnp.float32)
    u = xf @ w_up.astype(jnp.float32)
    h = jax.nn.silu(g) * u
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding.

    x: [batch, heads, seq, head_dim]; positions: [seq] absolute positions.
    Rotates pairs (x[..., :d/2], x[..., d/2:]).
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [seq, half]
    cos = jnp.cos(angles)[None, None, :, :]
    sin = jnp.sin(angles)[None, None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
