"""Fused SwiGLU MLP Pallas kernel (Layer 1).

Computes ``silu(x @ w_gate) * (x @ w_up) @ w_down`` with a single pass over
the hidden dimension: the gate/up products are materialised one
``block_f``-wide tile of the FFN dimension at a time (VMEM-resident), the
silu*up product is formed in registers, and the partial contribution through
``w_down`` is accumulated — the ``[tokens, d_ff]`` intermediate never hits
HBM.  This is the TPU restatement of the fused-MLP epilogue that CUDA
kernels do with threadblock tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, *, block_f: int):
    tokens, d_model = x_ref.shape
    d_ff = wg_ref.shape[1]

    x = x_ref[...].astype(jnp.float32)

    def body(fi, acc):
        sl = (slice(None), pl.dslice(fi * block_f, block_f))
        wg = pl.load(wg_ref, sl).astype(jnp.float32)  # (d_model, block_f)
        wu = pl.load(wu_ref, sl).astype(jnp.float32)
        wd = pl.load(
            wd_ref, (pl.dslice(fi * block_f, block_f), slice(None))
        ).astype(jnp.float32)  # (block_f, d_model)
        g = x @ wg
        u = x @ wu
        h = g * jax.nn.sigmoid(g) * u  # silu(g) * u, (tokens, block_f)
        return acc + h @ wd

    acc0 = jnp.zeros((tokens, d_model), jnp.float32)
    acc = jax.lax.fori_loop(0, d_ff // block_f, body, acc0)
    o_ref[...] = acc.astype(o_ref.dtype)


def swiglu_mlp(
    x: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    block_f: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused SwiGLU feed-forward.

    Args:
      x: ``[tokens, d_model]`` flattened activations.
      w_gate, w_up: ``[d_model, d_ff]``.
      w_down: ``[d_ff, d_model]``.
    Returns:
      ``[tokens, d_model]``.
    """
    tokens, d_model = x.shape
    d_ff = w_gate.shape[1]
    block_f = min(block_f, d_ff)
    if d_ff % block_f:
        raise ValueError(f"d_ff={d_ff} must divide block_f={block_f}")

    kernel = functools.partial(_swiglu_kernel, block_f=block_f)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((tokens, d_model), lambda i: (0, 0)),
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff, d_model), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tokens, d_model), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((tokens, d_model), x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
