"""Layer-1 Pallas kernels for the EdgeShard shard compute.

All kernels run under ``interpret=True`` so the lowered HLO executes on any
PJRT backend (the rust coordinator uses the CPU plugin).  Real-TPU lowering
would emit Mosaic custom-calls; see DESIGN.md #Hardware-Adaptation for the
VMEM/MXU tiling rationale.
"""

from .attention import flash_attention_prefill, decode_attention
from .swiglu import swiglu_mlp
from . import ref

__all__ = [
    "flash_attention_prefill",
    "decode_attention",
    "swiglu_mlp",
    "ref",
]
