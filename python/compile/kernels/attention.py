"""Pallas attention kernels (Layer 1).

Two kernels cover the two phases of autoregressive LLM inference:

* :func:`flash_attention_prefill` — blocked causal self-attention over the
  whole prompt, flash-attention style online softmax.  The CUDA original
  tiles Q/K/V into shared memory per threadblock; on TPU the same insight
  becomes a VMEM-resident (block_q, head_dim) accumulator streamed against
  (block_k, head_dim) K/V tiles, with the HBM->VMEM schedule expressed by
  ``pl.BlockSpec`` index maps instead of a CUDA grid.

* :func:`decode_attention` — single-token attention against the KV cache
  with a runtime length mask, one (batch, head) program per grid cell.

Both are lowered with ``interpret=True`` (see package docstring).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Mask value used instead of -inf so that fully-masked rows produce zeros
# (exp(-1e30 - max) == 0) rather than NaNs.
_NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One (batch*head, q-block) program: stream K/V blocks, online softmax."""
    block_q, head_dim = q_ref.shape
    seq_len = k_ref.shape[0]
    q_index = pl.program_id(1)

    q = q_ref[...].astype(jnp.float32) * scale

    def body(start_k, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (pl.dslice(start_k * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(start_k * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # (block_q, block_k)

        # Causal mask: query row (absolute) >= key col (absolute).
        row = q_index * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        col = start_k * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        s = jnp.where(row >= col, s, _NEG_INF)

        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        correction = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * correction + jnp.sum(p, axis=-1)
        acc = acc * correction[:, None] + p @ v.astype(jnp.float32)
        return acc, m_cur, l_cur

    # Only stream K blocks at-or-below the diagonal of this Q block.
    num_k = (q_index + 1) * block_q // block_k
    acc0 = jnp.zeros((block_q, head_dim), jnp.float32)
    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, num_k, body, (acc0, m0, l0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_prefill(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Causal flash attention for the prefill phase.

    Args:
      q, k, v: ``[batch, heads, seq, head_dim]`` (multi-query already
        expanded — the L2 model repeats KV heads before calling in).
    Returns:
      ``[batch, heads, seq, head_dim]`` attention output.
    """
    batch, heads, seq, head_dim = q.shape
    block_q = min(block_q, seq)
    block_k = min(block_k, seq)
    if seq % block_q or seq % block_k:
        raise ValueError(f"seq={seq} must divide block sizes {block_q},{block_k}")
    scale = 1.0 / math.sqrt(head_dim)

    kernel = functools.partial(_prefill_kernel, block_k=block_k, scale=scale)
    bh = batch * heads
    qf = q.reshape(bh, seq, head_dim)
    kf = k.reshape(bh, seq, head_dim)
    vf = v.reshape(bh, seq, head_dim)

    out = pl.pallas_call(
        kernel,
        grid=(bh, seq // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, head_dim), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, seq, head_dim), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, seq, head_dim), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, head_dim), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, head_dim), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(batch, heads, seq, head_dim)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One (batch*head,) program: q is a single row, K/V are the full cache."""
    max_seq, head_dim = k_ref.shape
    pos = pos_ref[0]  # number of valid cache entries - 1 == current position

    q = q_ref[...].astype(jnp.float32) * scale  # (1, head_dim)

    def body(start_k, carry):
        acc, m_prev, l_prev = carry
        kb = pl.load(k_ref, (pl.dslice(start_k * block_k, block_k), slice(None)))
        vb = pl.load(v_ref, (pl.dslice(start_k * block_k, block_k), slice(None)))
        s = q @ kb.astype(jnp.float32).T  # (1, block_k)
        col = start_k * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(col <= pos, s, _NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        correction = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * correction + jnp.sum(p, axis=-1)
        acc = acc * correction[:, None] + p @ vb.astype(jnp.float32)
        return acc, m_cur, l_cur

    num_k = max_seq // block_k
    acc0 = jnp.zeros((1, head_dim), jnp.float32)
    m0 = jnp.full((1,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((1,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, num_k, body, (acc0, m0, l0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Single-step attention against the KV cache.

    Args:
      q: ``[batch, heads, 1, head_dim]`` current-token queries.
      k_cache, v_cache: ``[batch, heads, max_seq, head_dim]`` with the
        current token's K/V already written at index ``pos``.
      pos: scalar int32 — the current absolute position (mask is ``<= pos``).
    Returns:
      ``[batch, heads, 1, head_dim]``.
    """
    batch, heads, one, head_dim = q.shape
    assert one == 1
    max_seq = k_cache.shape[2]
    block_k = min(block_k, max_seq)
    if max_seq % block_k:
        raise ValueError(f"max_seq={max_seq} must divide block_k={block_k}")
    scale = 1.0 / math.sqrt(head_dim)

    bh = batch * heads
    qf = q.reshape(bh, 1, head_dim)
    kf = k_cache.reshape(bh, max_seq, head_dim)
    vf = v_cache.reshape(bh, max_seq, head_dim)
    pos_arr = jnp.broadcast_to(pos.astype(jnp.int32), (1,))

    kernel = functools.partial(_decode_kernel, block_k=block_k, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (0,)),
            pl.BlockSpec((None, 1, head_dim), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, max_seq, head_dim), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, max_seq, head_dim), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, 1, head_dim), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, head_dim), q.dtype),
        interpret=interpret,
    )(pos_arr, qf, kf, vf)
    return out.reshape(batch, heads, 1, head_dim)
