//! Pipeline-execution simulator: EdgeShard-Bubbles vs EdgeShard-No-bubbles
//! (paper §IV-B "Pipeline Execution Optimization", Fig. 5).
//!
//! LLM pipelines differ from GPipe-style one-shot pipelines because of the
//! autoregressive loop: micro-batch `b` cannot start generation iteration
//! `t+1` until its token from iteration `t` has travelled back to the
//! source node.  The **Bubbles** strategy additionally imposes the
//! iteration barrier of classic pipelined inference — no micro-batch may
//! enter iteration `t+1` until *every* micro-batch finished iteration `t` —
//! which is exactly the idle time Fig. 5(a) shows.  **No-bubbles** drops
//! the barrier: a micro-batch re-enters the pipeline the moment its own
//! dependency is satisfied (Fig. 5(b)).
//!
//! The simulator is event-free: start times are computed with a dependency
//! recurrence over `(micro-batch, iteration, stage)`, with per-device FIFO
//! occupancy in `(iteration, micro-batch)` order — the dispatch order of
//! the paper's figures.  [`Strategy::NoBubbleGreedy`] is an ablation that
//! relaxes FIFO to earliest-ready-first.

use crate::cluster::Cluster;
use crate::planner::Plan;
use crate::profiler::ProfiledTraces;

/// Pipeline execution strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Iteration barrier across micro-batches (Fig. 5a).
    Bubble,
    /// Immediate re-entry per micro-batch, FIFO device order (Fig. 5b).
    NoBubble,
    /// No-bubble with earliest-ready-first device order (ablation).
    NoBubbleGreedy,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Bubble => "EdgeShard-Bubbles",
            Strategy::NoBubble => "EdgeShard-No-bubbles",
            Strategy::NoBubbleGreedy => "EdgeShard-No-bubbles(greedy)",
        }
    }
}

/// One scheduled task on a device timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Slot {
    pub stage: usize,
    pub micro: usize,
    /// 0 = prefill; ≥1 = autoregressive iteration.
    pub iter: usize,
    pub start_ms: f64,
    pub end_ms: f64,
}

/// Full simulated schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub strategy: Strategy,
    /// Per stage, in execution order.
    pub slots: Vec<Vec<Slot>>,
    pub makespan_ms: f64,
    /// Tokens produced (micro-batches × batch-per-micro × iterations).
    pub tokens: u64,
    pub throughput_tps: f64,
    /// Mean busy fraction across devices over the makespan.
    pub utilization: f64,
}

/// Inputs for one pipeline simulation.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    /// Per-stage prefill time (whole prompt, one micro-batch).
    pub prefill_ms: Vec<f64>,
    /// Per-stage decode time (one iteration, one micro-batch).
    pub decode_ms: Vec<f64>,
    /// Comm time stage s-1 → s for prefill activations (index 0 unused).
    pub comm_prefill_ms: Vec<f64>,
    /// Comm time stage s-1 → s for decode activations.
    pub comm_decode_ms: Vec<f64>,
    /// Token loopback time (last stage → source).
    pub loopback_ms: f64,
    /// Number of micro-batches in flight.
    pub n_micro: usize,
    /// Autoregressive iterations (tokens generated per sequence).
    pub n_iters: usize,
    /// Sequences per micro-batch (for token accounting).
    pub batch_per_micro: usize,
}

impl PipelineSpec {
    /// Build from a plan + traces (the production path).
    pub fn from_plan(
        plan: &Plan,
        traces: &ProfiledTraces,
        cluster: &Cluster,
        n_micro: usize,
    ) -> Self {
        let s = plan.n_stages();
        let mut prefill = Vec::with_capacity(s);
        let mut decode = Vec::with_capacity(s);
        let mut comm_p = vec![0.0; s];
        let mut comm_d = vec![0.0; s];
        for (i, st) in plan.stages.iter().enumerate() {
            prefill.push(traces.range_prefill_ms(st.start, st.end, st.device));
            decode.push(traces.range_decode_ms(st.start, st.end, st.device));
            if i > 0 {
                let prev = plan.stages[i - 1].device;
                comm_p[i] =
                    cluster.comm_ms(prev, st.device, traces.act_bytes_prefill[st.start - 1]);
                comm_d[i] =
                    cluster.comm_ms(prev, st.device, traces.act_bytes_decode[st.start - 1]);
            }
        }
        let last = plan.stages.last().unwrap().device;
        let loopback = cluster.comm_ms(
            last,
            cluster.source,
            traces.act_bytes_decode[traces.n_layers - 1],
        );
        PipelineSpec {
            prefill_ms: prefill,
            decode_ms: decode,
            comm_prefill_ms: comm_p,
            comm_decode_ms: comm_d,
            loopback_ms: loopback,
            n_micro: n_micro.max(1),
            n_iters: traces.workload.iterations(),
            batch_per_micro: traces.workload.batch,
        }
    }

    fn comp(&self, stage: usize, iter: usize) -> f64 {
        if iter == 0 {
            self.prefill_ms[stage]
        } else {
            self.decode_ms[stage]
        }
    }

    fn comm(&self, stage: usize, iter: usize) -> f64 {
        if stage == 0 {
            0.0
        } else if iter == 0 {
            self.comm_prefill_ms[stage]
        } else {
            self.comm_decode_ms[stage]
        }
    }
}

/// Simulate one strategy over the spec.
pub fn simulate(spec: &PipelineSpec, strategy: Strategy) -> Schedule {
    match strategy {
        Strategy::NoBubbleGreedy => simulate_greedy(spec, strategy),
        _ => simulate_fifo(spec, strategy),
    }
}

/// FIFO dispatch in (iteration, micro) order per device; optional
/// iteration barrier for [`Strategy::Bubble`].
fn simulate_fifo(spec: &PipelineSpec, strategy: Strategy) -> Schedule {
    let s_count = spec.prefill_ms.len();
    let (n_micro, n_iters) = (spec.n_micro, spec.n_iters);
    // finish[b][t][s]
    let mut finish = vec![vec![vec![0.0f64; s_count]; n_iters]; n_micro];
    let mut dev_free = vec![0.0f64; s_count];
    let mut slots: Vec<Vec<Slot>> = vec![Vec::new(); s_count];
    let mut iter_done = 0.0f64; // barrier: when the previous iteration fully completed

    for t in 0..n_iters {
        let mut this_iter_done = 0.0f64;
        for b in 0..n_micro {
            for s in 0..s_count {
                // dependency: previous stage of same (b, t), or for stage 0
                // the token loopback from (b, t-1)'s last stage
                let dep = if s > 0 {
                    finish[b][t][s - 1] + spec.comm(s, t)
                } else if t > 0 {
                    finish[b][t - 1][s_count - 1] + spec.loopback_ms
                } else {
                    0.0
                };
                let barrier = if strategy == Strategy::Bubble && s == 0 && t > 0 {
                    iter_done + spec.loopback_ms
                } else {
                    0.0
                };
                let start = dep.max(barrier).max(dev_free[s]);
                let end = start + spec.comp(s, t);
                finish[b][t][s] = end;
                dev_free[s] = end;
                slots[s].push(Slot {
                    stage: s,
                    micro: b,
                    iter: t,
                    start_ms: start,
                    end_ms: end,
                });
            }
            this_iter_done = this_iter_done.max(finish[b][t][s_count - 1]);
        }
        iter_done = this_iter_done;
    }

    finalize(spec, strategy, slots)
}

/// Earliest-ready-first per device (work-conserving ablation).
fn simulate_greedy(spec: &PipelineSpec, strategy: Strategy) -> Schedule {
    let s_count = spec.prefill_ms.len();
    let (n_micro, n_iters) = (spec.n_micro, spec.n_iters);
    // ready time of (b,t,s); f64::INFINITY = dependency unmet
    let mut ready = vec![vec![vec![f64::INFINITY; s_count]; n_iters]; n_micro];
    let mut done = vec![vec![vec![false; s_count]; n_iters]; n_micro];
    let mut dev_free = vec![0.0f64; s_count];
    let mut slots: Vec<Vec<Slot>> = vec![Vec::new(); s_count];
    for b in 0..n_micro {
        ready[b][0][0] = 0.0;
    }
    let total = n_micro * n_iters * s_count;
    for _ in 0..total {
        // pick the globally earliest-startable task
        let mut best: Option<(f64, usize, usize, usize)> = None;
        for b in 0..n_micro {
            for t in 0..n_iters {
                for s in 0..s_count {
                    if done[b][t][s] || !ready[b][t][s].is_finite() {
                        continue;
                    }
                    let start = ready[b][t][s].max(dev_free[s]);
                    if best.map_or(true, |(bs, ..)| {
                        start < bs
                    }) {
                        best = Some((start, b, t, s));
                    }
                }
            }
        }
        let (start, b, t, s) = best.expect("schedulable task must exist");
        let end = start + spec.comp(s, t);
        done[b][t][s] = true;
        dev_free[s] = end;
        slots[s].push(Slot {
            stage: s,
            micro: b,
            iter: t,
            start_ms: start,
            end_ms: end,
        });
        // release successors
        if s + 1 < s_count {
            ready[b][t][s + 1] = end + spec.comm(s + 1, t);
        } else if t + 1 < n_iters {
            ready[b][t + 1][0] = end + spec.loopback_ms;
        }
    }
    finalize(spec, strategy, slots)
}

fn finalize(spec: &PipelineSpec, strategy: Strategy, slots: Vec<Vec<Slot>>) -> Schedule {
    let makespan = slots
        .iter()
        .flat_map(|v| v.iter().map(|s| s.end_ms))
        .fold(0.0f64, f64::max);
    let tokens = (spec.n_micro * spec.n_iters * spec.batch_per_micro) as u64;
    let busy: f64 = slots
        .iter()
        .map(|v| v.iter().map(|s| s.end_ms - s.start_ms).sum::<f64>())
        .sum();
    let util = if makespan > 0.0 {
        busy / (makespan * slots.len() as f64)
    } else {
        0.0
    };
    Schedule {
        strategy,
        slots,
        makespan_ms: makespan,
        tokens,
        throughput_tps: if makespan > 0.0 {
            tokens as f64 / (makespan / 1e3)
        } else {
            0.0
        },
        utilization: util,
    }
}

/// Render an ASCII Gantt chart (one row per stage/device).
pub fn gantt(schedule: &Schedule, width: usize) -> String {
    let span = schedule.makespan_ms.max(1e-9);
    let mut out = String::new();
    for (s, row) in schedule.slots.iter().enumerate() {
        let mut line = vec![' '; width];
        for slot in row {
            let a = ((slot.start_ms / span) * width as f64) as usize;
            let b = (((slot.end_ms / span) * width as f64) as usize).min(width);
            let ch = if slot.iter == 0 {
                'P'
            } else {
                char::from_digit(((slot.iter - 1) % 10) as u32, 10).unwrap()
            };
            for c in line.iter_mut().take(b).skip(a.min(width)) {
                *c = ch;
            }
        }
        out.push_str(&format!("stage{:<2}|{}|\n", s, line.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{} makespan={:.1}ms tokens={} throughput={:.2}tok/s util={:.0}%\n",
        schedule.strategy.name(),
        schedule.makespan_ms,
        schedule.tokens,
        schedule.throughput_tps,
        schedule.utilization * 100.0
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ideal 4-stage, equal-time pipeline like Fig. 5.
    fn fig5_spec() -> PipelineSpec {
        PipelineSpec {
            prefill_ms: vec![10.0; 4],
            decode_ms: vec![10.0; 4],
            comm_prefill_ms: vec![0.0; 4],
            comm_decode_ms: vec![0.0; 4],
            loopback_ms: 0.0,
            n_micro: 4,
            n_iters: 5,
            batch_per_micro: 1,
        }
    }

    #[test]
    fn no_bubble_beats_bubble_fig5() {
        let spec = fig5_spec();
        let b = simulate(&spec, Strategy::Bubble);
        let nb = simulate(&spec, Strategy::NoBubble);
        assert!(
            nb.makespan_ms < b.makespan_ms,
            "no-bubble {} vs bubble {}",
            nb.makespan_ms,
            b.makespan_ms
        );
        assert!(nb.throughput_tps > b.throughput_tps);
    }

    #[test]
    fn ideal_no_bubble_is_fully_packed() {
        // With equal stage times and no comm, no-bubble keeps every device
        // busy once warmed up: makespan = (pipeline fill) + work.
        let spec = fig5_spec();
        let nb = simulate(&spec, Strategy::NoBubble);
        // stage0 processes 4 micro × 5 iters × 10 ms = 200 ms of work,
        // pipeline drain adds 3 stages × 10 ms.
        assert!((nb.makespan_ms - 230.0).abs() < 1e-6, "{}", nb.makespan_ms);
        let b = simulate(&spec, Strategy::Bubble);
        assert!(b.makespan_ms >= 230.0 + 30.0, "{}", b.makespan_ms);
    }

    #[test]
    fn tokens_accounting() {
        let spec = PipelineSpec {
            batch_per_micro: 8,
            ..fig5_spec()
        };
        let s = simulate(&spec, Strategy::NoBubble);
        assert_eq!(s.tokens, 4 * 5 * 8);
    }

    #[test]
    fn single_stage_no_pipeline_equal_strategies() {
        // One device: bubble vs no-bubble identical (§V.E: Cloud-Edge-Opt
        // local execution has "no pipeline execution").
        let spec = PipelineSpec {
            prefill_ms: vec![20.0],
            decode_ms: vec![5.0],
            comm_prefill_ms: vec![0.0],
            comm_decode_ms: vec![0.0],
            loopback_ms: 0.0,
            n_micro: 3,
            n_iters: 4,
            batch_per_micro: 1,
        };
        let b = simulate(&spec, Strategy::Bubble);
        let nb = simulate(&spec, Strategy::NoBubble);
        assert!((b.makespan_ms - nb.makespan_ms).abs() < 1e-9);
    }

    #[test]
    fn dependencies_respected() {
        let spec = fig5_spec();
        for strat in [Strategy::Bubble, Strategy::NoBubble, Strategy::NoBubbleGreedy] {
            let sch = simulate(&spec, strat);
            // collect finish times
            let mut fin = std::collections::HashMap::new();
            for row in &sch.slots {
                for s in row {
                    fin.insert((s.micro, s.iter, s.stage), (s.start_ms, s.end_ms));
                }
            }
            for (&(b, t, s), &(start, _)) in &fin {
                if s > 0 {
                    let (_, prev_end) = fin[&(b, t, s - 1)];
                    assert!(start >= prev_end - 1e-9, "{strat:?} ({b},{t},{s})");
                }
                if s == 0 && t > 0 {
                    let (_, prev_end) = fin[&(b, t - 1, spec.prefill_ms.len() - 1)];
                    assert!(start >= prev_end - 1e-9, "{strat:?} loopback ({b},{t})");
                }
            }
        }
    }

    #[test]
    fn device_never_overlaps() {
        let spec = fig5_spec();
        for strat in [Strategy::Bubble, Strategy::NoBubble, Strategy::NoBubbleGreedy] {
            let sch = simulate(&spec, strat);
            for row in &sch.slots {
                let mut sorted = row.clone();
                sorted.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
                for w in sorted.windows(2) {
                    assert!(
                        w[1].start_ms >= w[0].end_ms - 1e-9,
                        "{strat:?}: overlap {w:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn greedy_close_to_fifo() {
        // Earliest-ready-first is work-conserving but list scheduling has
        // no optimality guarantee (Graham anomalies) — require it stays
        // within the 2x list-scheduling bound and usually close.
        let spec = PipelineSpec {
            prefill_ms: vec![30.0, 10.0, 20.0],
            decode_ms: vec![12.0, 4.0, 8.0],
            comm_prefill_ms: vec![0.0, 3.0, 3.0],
            comm_decode_ms: vec![0.0, 1.0, 1.0],
            loopback_ms: 2.0,
            n_micro: 4,
            n_iters: 6,
            batch_per_micro: 1,
        };
        let fifo = simulate(&spec, Strategy::NoBubble);
        let greedy = simulate(&spec, Strategy::NoBubbleGreedy);
        assert!(
            greedy.makespan_ms <= fifo.makespan_ms * 1.25,
            "greedy={} fifo={}",
            greedy.makespan_ms,
            fifo.makespan_ms
        );
    }

    #[test]
    fn comm_delays_push_starts() {
        let mut spec = fig5_spec();
        spec.comm_decode_ms = vec![0.0, 50.0, 0.0, 0.0];
        spec.comm_prefill_ms = vec![0.0, 50.0, 0.0, 0.0];
        let sch = simulate(&spec, Strategy::NoBubble);
        // stage1's first slot must start ≥ stage0 prefill end + 50
        let s1 = &sch.slots[1][0];
        assert!(s1.start_ms >= 60.0 - 1e-9, "{}", s1.start_ms);
    }

    #[test]
    fn utilization_bounded() {
        let sch = simulate(&fig5_spec(), Strategy::NoBubble);
        assert!(sch.utilization > 0.5 && sch.utilization <= 1.0);
    }

    #[test]
    fn gantt_renders() {
        let sch = simulate(&fig5_spec(), Strategy::NoBubble);
        let g = gantt(&sch, 60);
        assert!(g.contains("stage0"));
        assert!(g.contains('P'));
        assert!(g.contains("throughput"));
    }

    #[test]
    fn more_micro_batches_increase_throughput_until_saturation() {
        let mut last = 0.0;
        for n_micro in [1, 2, 4] {
            let spec = PipelineSpec {
                n_micro,
                ..fig5_spec()
            };
            let sch = simulate(&spec, Strategy::NoBubble);
            assert!(
                sch.throughput_tps >= last - 1e-9,
                "n_micro={n_micro}: {} < {last}",
                sch.throughput_tps
            );
            last = sch.throughput_tps;
        }
    }
}
