//! Llama2 family descriptors (the paper's benchmark models) and the
//! executable tiny model loaded from `artifacts/manifest.json`.

use super::{LayerDesc, LayerKind, ModelDesc, Precision};

/// Architecture hyper-parameters of a Llama-family model.
#[derive(Debug, Clone, Copy)]
pub struct LlamaParams {
    pub d_model: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub n_kv_heads: u64,
    pub d_ff: u64,
    pub vocab: u64,
}

impl LlamaParams {
    pub fn head_dim(&self) -> u64 {
        self.d_model / self.n_heads
    }

    /// Parameters in one decoder block: attention (q,k,v,o) + SwiGLU MLP
    /// (gate, up, down) + two RMSNorm vectors.
    pub fn decoder_params(&self) -> u64 {
        let d = self.d_model;
        let kv_dim = self.n_kv_heads * self.head_dim();
        let attn = d * d /*q*/ + d * kv_dim /*k*/ + d * kv_dim /*v*/ + d * d /*o*/;
        let mlp = 3 * d * self.d_ff;
        attn + mlp + 2 * d
    }
}

/// Build a layered descriptor from Llama hyper-parameters.
///
/// FLOPs/token per layer ≈ 2 × matmul params (multiply + accumulate);
/// attention score FLOPs are sequence-dependent and small next to the
/// projections at the paper's context (≤128 tokens), matching its
/// profiling which averages prefill/decode per-token cost.
pub fn llama_desc(name: &str, p: LlamaParams, max_seq: usize) -> ModelDesc {
    let mut layers = Vec::with_capacity(p.n_layers as usize + 2);
    let emb_params = p.vocab * p.d_model;
    layers.push(LayerDesc {
        kind: LayerKind::Embedding,
        params: emb_params,
        // lookup, negligible FLOPs, but nonzero to keep costs positive
        flops_per_token: p.d_model as f64,
        activation_elems: p.d_model,
        kv_elems_per_token: 0,
    });
    let dec_params = p.decoder_params();
    for _ in 0..p.n_layers {
        layers.push(LayerDesc {
            kind: LayerKind::Decoder,
            params: dec_params,
            flops_per_token: 2.0 * dec_params as f64,
            activation_elems: p.d_model,
            kv_elems_per_token: 2 * p.n_kv_heads * p.head_dim(),
        });
    }
    let head_params = p.vocab * p.d_model + p.d_model;
    layers.push(LayerDesc {
        kind: LayerKind::Head,
        params: head_params,
        flops_per_token: 2.0 * head_params as f64,
        // After the head only the sampled token id crosses the wire (the
        // autoregressive loopback to the source node) — 1 element.
        activation_elems: 1,
        kv_elems_per_token: 0,
    });
    ModelDesc {
        name: name.to_string(),
        layers,
        weight_precision: Precision::Fp32,
        activation_precision: Precision::Fp32,
        max_seq,
    }
}

/// Llama2-7B: 32 layers, d=4096, 32 heads (MHA), ff=11008, vocab=32000.
pub fn llama2_7b() -> ModelDesc {
    llama_desc(
        "Llama2-7B",
        LlamaParams {
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 32,
            d_ff: 11008,
            vocab: 32000,
        },
        128,
    )
}

/// Llama2-13B: 40 layers, d=5120, 40 heads (MHA), ff=13824.
pub fn llama2_13b() -> ModelDesc {
    llama_desc(
        "Llama2-13B",
        LlamaParams {
            d_model: 5120,
            n_layers: 40,
            n_heads: 40,
            n_kv_heads: 40,
            d_ff: 13824,
            vocab: 32000,
        },
        128,
    )
}

/// Llama2-70B: 80 layers, d=8192, 64 heads, 8 KV heads (GQA), ff=28672.
pub fn llama2_70b() -> ModelDesc {
    llama_desc(
        "Llama2-70B",
        LlamaParams {
            d_model: 8192,
            n_layers: 80,
            n_heads: 64,
            n_kv_heads: 8,
            d_ff: 28672,
            vocab: 32000,
        },
        128,
    )
}

/// Descriptor for the AOT-compiled tiny model, derived from the manifest
/// written by `python/compile/aot.py` so the analytic and executable views
/// can never drift apart.
pub fn tiny_from_manifest(manifest: &crate::runtime::Manifest) -> ModelDesc {
    let c = &manifest.config;
    llama_desc(
        &c.name,
        LlamaParams {
            d_model: c.d_model as u64,
            n_layers: c.n_layers as u64,
            n_heads: c.n_heads as u64,
            n_kv_heads: c.n_kv_heads as u64,
            d_ff: c.d_ff as u64,
            vocab: c.vocab_size as u64,
        },
        c.max_seq,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_dim_7b() {
        let p = LlamaParams {
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 32,
            d_ff: 11008,
            vocab: 32000,
        };
        assert_eq!(p.head_dim(), 128);
        // attention 4*d^2 + mlp 3*d*ff + norms
        assert_eq!(
            p.decoder_params(),
            4 * 4096 * 4096 + 3 * 4096 * 11008 + 2 * 4096
        );
    }

    #[test]
    fn gqa_reduces_decoder_params() {
        let mha = LlamaParams {
            d_model: 8192,
            n_layers: 80,
            n_heads: 64,
            n_kv_heads: 64,
            d_ff: 28672,
            vocab: 32000,
        };
        let gqa = LlamaParams { n_kv_heads: 8, ..mha };
        assert!(gqa.decoder_params() < mha.decoder_params());
    }

    #[test]
    fn names() {
        assert_eq!(llama2_7b().name, "Llama2-7B");
        assert_eq!(llama2_70b().layers.len(), 82);
    }
}
