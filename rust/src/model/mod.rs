//! LLM descriptors: the layered view of a model that the profiler, the
//! planners and the pipeline simulator operate on.
//!
//! EdgeShard partitions a model **layer-wise**: `[embedding, decoder_0,
//! …, decoder_{L-1}, head]`.  Each layer carries its parameter bytes,
//! per-token FLOPs, activation output size and per-token KV-cache bytes —
//! exactly the traces the paper's offline profiling stage collects.
//!
//! Analytic descriptors exist for Llama2-7B/13B/70B (the paper's
//! benchmarks) plus the executable `tiny` model compiled by
//! `python/compile/aot.py`.

mod llama;

pub use llama::{llama2_13b, llama2_70b, llama2_7b, llama_desc, tiny_from_manifest, LlamaParams};

/// Numeric precision of the deployed weights (Table I of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    Fp16,
    Int8,
    Int4,
}

impl Precision {
    /// Bytes per parameter.
    pub fn bytes_per_param(self) -> f64 {
        match self {
            Precision::Fp32 => 4.0,
            Precision::Fp16 => 2.0,
            Precision::Int8 => 1.0,
            Precision::Int4 => 0.5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16 => "fp16",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        }
    }
}

/// Role of a layer in the stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Token embedding lookup (must sit on the source node — privacy).
    Embedding,
    /// One transformer decoder block.
    Decoder,
    /// Final norm + LM head.
    Head,
}

/// One partitionable layer.
#[derive(Debug, Clone)]
pub struct LayerDesc {
    pub kind: LayerKind,
    /// Parameter count (not bytes — precision applied by [`ModelDesc`]).
    pub params: u64,
    /// FLOPs to process ONE token through this layer (decode step).
    pub flops_per_token: f64,
    /// Output activation size per token, in elements (multiplied by
    /// activation precision for wire bytes).
    pub activation_elems: u64,
    /// KV-cache elements appended per token (2 × kv_heads × head_dim for a
    /// decoder layer, 0 otherwise).
    pub kv_elems_per_token: u64,
}

/// A layered model description.
#[derive(Debug, Clone)]
pub struct ModelDesc {
    pub name: String,
    pub layers: Vec<LayerDesc>,
    pub weight_precision: Precision,
    /// Activations travel at this precision between devices.
    pub activation_precision: Precision,
    /// Upper bound on sequence length (prompt + generation) — sizes the
    /// KV cache reservation.
    pub max_seq: usize,
}

impl ModelDesc {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total parameter count.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params).sum()
    }

    /// Weight bytes of one layer at the deployed precision.
    pub fn layer_weight_bytes(&self, i: usize) -> u64 {
        (self.layers[i].params as f64 * self.weight_precision.bytes_per_param()) as u64
    }

    /// Weight bytes of a contiguous layer range `[lo, hi)`.
    pub fn range_weight_bytes(&self, lo: usize, hi: usize) -> u64 {
        (lo..hi).map(|i| self.layer_weight_bytes(i)).sum()
    }

    /// Total model weight bytes.
    pub fn total_weight_bytes(&self) -> u64 {
        self.range_weight_bytes(0, self.n_layers())
    }

    /// Wire size of layer `i`'s output activations for `tokens` tokens.
    pub fn activation_bytes(&self, i: usize, tokens: usize) -> u64 {
        (self.layers[i].activation_elems as f64
            * tokens as f64
            * self.activation_precision.bytes_per_param()) as u64
    }

    /// KV-cache bytes one sequence consumes over `max_seq` positions for a
    /// contiguous layer range (what a device must reserve per batch slot).
    pub fn range_kv_bytes_per_seq(&self, lo: usize, hi: usize) -> u64 {
        let per_tok: u64 = (lo..hi).map(|i| self.layers[i].kv_elems_per_token).sum();
        (per_tok as f64
            * self.max_seq as f64
            * self.activation_precision.bytes_per_param()) as u64
    }

    /// Memory a device needs to host layers `[lo, hi)` with `batch`
    /// concurrent sequences: weights + KV reservation + one activation
    /// workspace.
    pub fn range_memory_bytes(&self, lo: usize, hi: usize, batch: usize) -> u64 {
        let weights = self.range_weight_bytes(lo, hi);
        let kv = self.range_kv_bytes_per_seq(lo, hi) * batch as u64;
        let workspace = if hi > lo {
            self.activation_bytes(hi - 1, self.max_seq) * batch as u64
        } else {
            0
        };
        weights + kv + workspace
    }

    /// FLOPs for one decode token through layers `[lo, hi)`.
    pub fn range_flops_per_token(&self, lo: usize, hi: usize) -> f64 {
        (lo..hi).map(|i| self.layers[i].flops_per_token).sum()
    }

    /// Clone at a different weight precision (Table I sweeps this).
    pub fn with_precision(&self, p: Precision) -> ModelDesc {
        let mut m = self.clone();
        m.weight_precision = p;
        m.name = format!("{}-{}", self.name, p.name());
        m
    }

    /// Indices of decoder layers (excludes embedding/head).
    pub fn decoder_range(&self) -> std::ops::Range<usize> {
        let lo = self
            .layers
            .iter()
            .position(|l| l.kind == LayerKind::Decoder)
            .unwrap_or(0);
        let hi = self
            .layers
            .iter()
            .rposition(|l| l.kind == LayerKind::Decoder)
            .map(|i| i + 1)
            .unwrap_or(self.n_layers());
        lo..hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_bytes() {
        assert_eq!(Precision::Fp32.bytes_per_param(), 4.0);
        assert_eq!(Precision::Int4.bytes_per_param(), 0.5);
    }

    #[test]
    fn llama7b_param_count_close_to_7b() {
        let m = llama2_7b();
        let p = m.total_params() as f64;
        assert!((6.5e9..7.5e9).contains(&p), "params={p}");
    }

    #[test]
    fn llama13b_param_count() {
        let p = llama2_13b().total_params() as f64;
        assert!((12.5e9..13.5e9).contains(&p), "params={p}");
    }

    #[test]
    fn llama70b_param_count() {
        let p = llama2_70b().total_params() as f64;
        assert!((65e9..72e9).contains(&p), "params={p}");
    }

    #[test]
    fn table1_memory_footprints() {
        // Table I: 7B -> 28GB fp32, 7GB int8, 3.5GB int4 (±15%).
        let m = llama2_7b();
        let gb = |b: u64| b as f64 / 1e9;
        let fp32 = gb(m.total_weight_bytes());
        assert!((24.0..30.0).contains(&fp32), "fp32={fp32}GB");
        let int8 = gb(m.with_precision(Precision::Int8).total_weight_bytes());
        assert!((6.0..7.5).contains(&int8), "int8={int8}GB");
        let int4 = gb(m.with_precision(Precision::Int4).total_weight_bytes());
        assert!((3.0..3.8).contains(&int4), "int4={int4}GB");
    }

    #[test]
    fn layer_structure() {
        let m = llama2_7b();
        assert_eq!(m.n_layers(), 34); // embed + 32 decoders + head
        assert_eq!(m.layers[0].kind, LayerKind::Embedding);
        assert_eq!(m.layers[33].kind, LayerKind::Head);
        assert_eq!(m.decoder_range(), 1..33);
    }

    #[test]
    fn range_weight_bytes_adds_up() {
        let m = llama2_7b();
        let total: u64 = (0..m.n_layers()).map(|i| m.layer_weight_bytes(i)).sum();
        assert_eq!(m.total_weight_bytes(), total);
        assert_eq!(
            m.range_weight_bytes(0, 10) + m.range_weight_bytes(10, m.n_layers()),
            total
        );
    }

    #[test]
    fn flops_approx_2x_params_for_decoders() {
        // Matmul-dominated decode: FLOPs/token ≈ 2 × params.
        let m = llama2_7b();
        for i in m.decoder_range() {
            let l = &m.layers[i];
            let ratio = l.flops_per_token / (2.0 * l.params as f64);
            assert!((0.9..1.2).contains(&ratio), "layer {i} ratio={ratio}");
        }
    }

    #[test]
    fn kv_bytes_7b() {
        // Llama2-7B fp32 KV: 2 * 32 heads * 128 dim * 4B = 32KB per token
        // per layer.
        let m = llama2_7b();
        let i = m.decoder_range().start;
        let per_tok = (m.layers[i].kv_elems_per_token as f64
            * m.activation_precision.bytes_per_param()) as u64;
        assert_eq!(per_tok, 32 * 1024);
    }

    #[test]
    fn gqa_70b_kv_smaller_than_mha_scaling() {
        // 70B uses GQA (8 kv heads), so per-layer KV is smaller than d_model
        // scaling would suggest.
        let m7 = llama2_7b();
        let m70 = llama2_70b();
        let kv7 = m7.layers[1].kv_elems_per_token;
        let kv70 = m70.layers[1].kv_elems_per_token;
        assert!(kv70 < kv7, "kv70={kv70} kv7={kv7}");
    }

    #[test]
    fn memory_includes_kv_and_grows_with_batch() {
        let m = llama2_7b();
        let b1 = m.range_memory_bytes(0, m.n_layers(), 1);
        let b8 = m.range_memory_bytes(0, m.n_layers(), 8);
        assert!(b8 > b1);
        assert!(b1 > m.total_weight_bytes());
    }

    #[test]
    fn with_precision_renames_and_shrinks() {
        let m = llama2_7b();
        let q = m.with_precision(Precision::Int8);
        assert!(q.name.contains("int8"));
        assert_eq!(q.total_weight_bytes() * 4, m.total_weight_bytes());
    }
}
