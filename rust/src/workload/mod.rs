//! Workload generation: a synthetic WikiText-like corpus (the paper samples
//! 32-token prompts from WikiText-2 and generates 96 tokens) and request
//! traces with Poisson arrivals for the serving experiments.

pub mod corpus;

pub use corpus::Corpus;

use crate::util::Rng;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival offset from trace start, milliseconds.
    pub arrival_ms: f64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Deterministic request-trace generator.
#[derive(Debug, Clone)]
pub struct TraceGen {
    pub prompt_len: usize,
    pub gen_len: usize,
    pub vocab_size: i32,
    /// Mean inter-arrival gap (ms); 0 ⇒ all requests arrive at t=0
    /// (closed-loop batch experiments).
    pub mean_interarrival_ms: f64,
    pub seed: u64,
}

impl TraceGen {
    /// The paper's workload shape: 32 prompt tokens, 96 generated.
    pub fn paper_default(vocab_size: i32, seed: u64) -> Self {
        TraceGen {
            prompt_len: 32,
            gen_len: 96,
            vocab_size,
            mean_interarrival_ms: 0.0,
            seed,
        }
    }

    /// Generate `n` requests.  Prompts are sampled from the synthetic
    /// corpus so token streams look text-like rather than uniform.
    pub fn generate(&self, n: usize) -> Vec<Request> {
        let corpus = Corpus::new(self.seed);
        let mut rng = Rng::new(self.seed ^ 0x9E3779B97F4A7C15);
        let mut t = 0.0;
        (0..n as u64)
            .map(|id| {
                let prompt = corpus.sample_tokens(self.prompt_len, self.vocab_size, id);
                let arrival = t;
                if self.mean_interarrival_ms > 0.0 {
                    t += rng.exponential(self.mean_interarrival_ms);
                }
                Request {
                    id,
                    arrival_ms: arrival,
                    prompt,
                    max_new_tokens: self.gen_len,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let g = TraceGen::paper_default(256, 1);
        assert_eq!(g.generate(5), g.generate(5));
    }

    #[test]
    fn trace_shapes() {
        let g = TraceGen::paper_default(256, 2);
        let reqs = g.generate(10);
        assert_eq!(reqs.len(), 10);
        for r in &reqs {
            assert_eq!(r.prompt.len(), 32);
            assert_eq!(r.max_new_tokens, 96);
            assert!(r.prompt.iter().all(|&t| (0..256).contains(&t)));
            assert_eq!(r.arrival_ms, 0.0); // closed-loop default
        }
    }

    #[test]
    fn poisson_arrivals_increase() {
        let g = TraceGen {
            mean_interarrival_ms: 50.0,
            ..TraceGen::paper_default(256, 3)
        };
        let reqs = g.generate(20);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        assert!(reqs.last().unwrap().arrival_ms > 0.0);
    }

    #[test]
    fn different_requests_different_prompts() {
        let g = TraceGen::paper_default(256, 4);
        let reqs = g.generate(2);
        assert_ne!(reqs[0].prompt, reqs[1].prompt);
    }
}
