//! Workload generation: a synthetic WikiText-like corpus (the paper samples
//! 32-token prompts from WikiText-2 and generates 96 tokens) and request
//! traces with Poisson arrivals for the serving experiments.

//! `RaggedTraceGen` adds the mixed-`max_new_tokens` burst mix the
//! continuous-batching bench runs on.

pub mod corpus;

pub use corpus::Corpus;

use crate::util::Rng;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival offset from trace start, milliseconds.
    pub arrival_ms: f64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Deterministic request-trace generator.
#[derive(Debug, Clone)]
pub struct TraceGen {
    pub prompt_len: usize,
    pub gen_len: usize,
    pub vocab_size: i32,
    /// Mean inter-arrival gap (ms); 0 ⇒ all requests arrive at t=0
    /// (closed-loop batch experiments).
    pub mean_interarrival_ms: f64,
    pub seed: u64,
}

impl TraceGen {
    /// The paper's workload shape: 32 prompt tokens, 96 generated.
    pub fn paper_default(vocab_size: i32, seed: u64) -> Self {
        TraceGen {
            prompt_len: 32,
            gen_len: 96,
            vocab_size,
            mean_interarrival_ms: 0.0,
            seed,
        }
    }

    /// Generate `n` requests.  Prompts are sampled from the synthetic
    /// corpus so token streams look text-like rather than uniform.
    pub fn generate(&self, n: usize) -> Vec<Request> {
        let corpus = Corpus::new(self.seed);
        let mut rng = Rng::new(self.seed ^ 0x9E3779B97F4A7C15);
        let mut t = 0.0;
        (0..n as u64)
            .map(|id| {
                let prompt = corpus.sample_tokens(self.prompt_len, self.vocab_size, id);
                let arrival = t;
                if self.mean_interarrival_ms > 0.0 {
                    t += rng.exponential(self.mean_interarrival_ms);
                }
                Request {
                    id,
                    arrival_ms: arrival,
                    prompt,
                    max_new_tokens: self.gen_len,
                }
            })
            .collect()
    }
}

/// Ragged serving mix: `max_new_tokens` is drawn per *burst* — short
/// stretches of consecutive requests sharing one generation length, the
/// arrival shape real serving queues exhibit and the one static group
/// packing handles worst (bursts shorter than the compiled batch turn
/// into padded groups; mixed lengths hold pipeline slots hostage).  This
/// is the workload the continuous-batching scheduler is benched on.
#[derive(Debug, Clone)]
pub struct RaggedTraceGen {
    pub prompt_len: usize,
    pub vocab_size: i32,
    /// Generation lengths a burst may draw (e.g. `[8, 48]`).
    pub gen_lens: Vec<usize>,
    /// Burst length is uniform in `1..=2*mean_burst-1` (mean `mean_burst`).
    pub mean_burst: usize,
    /// Mean inter-arrival gap (ms); 0 ⇒ closed loop.
    pub mean_interarrival_ms: f64,
    pub seed: u64,
}

impl RaggedTraceGen {
    pub fn new(prompt_len: usize, vocab_size: i32, gen_lens: Vec<usize>, seed: u64) -> Self {
        assert!(!gen_lens.is_empty(), "need at least one generation length");
        RaggedTraceGen {
            prompt_len,
            vocab_size,
            gen_lens,
            mean_burst: 3,
            mean_interarrival_ms: 0.0,
            seed,
        }
    }

    /// Generate `n` requests in same-length bursts.
    pub fn generate(&self, n: usize) -> Vec<Request> {
        let corpus = Corpus::new(self.seed);
        let mut rng = Rng::new(self.seed ^ 0xA24B_AED4_963E_E407);
        let mut t = 0.0;
        let mut burst_left = 0usize;
        let mut gen_len = self.gen_lens[0];
        (0..n as u64)
            .map(|id| {
                if burst_left == 0 {
                    let span = (2 * self.mean_burst as u64).saturating_sub(1).max(1);
                    burst_left = 1 + rng.next_below(span) as usize;
                    gen_len = self.gen_lens
                        [rng.next_below(self.gen_lens.len() as u64) as usize];
                }
                burst_left -= 1;
                let prompt = corpus.sample_tokens(self.prompt_len, self.vocab_size, id);
                let arrival = t;
                if self.mean_interarrival_ms > 0.0 {
                    t += rng.exponential(self.mean_interarrival_ms);
                }
                Request {
                    id,
                    arrival_ms: arrival,
                    prompt,
                    max_new_tokens: gen_len,
                }
            })
            .collect()
    }
}

/// Offered load of a trace: total requested tokens over the arrival
/// span (the open-loop x-axis).  A closed-loop trace (every arrival at
/// t = 0) reads as its total tokens over 1 ms — effectively "all at
/// once".
pub fn offered_tokens_per_s(trace: &[Request]) -> f64 {
    let total: usize = trace.iter().map(|r| r.max_new_tokens).sum();
    let span_ms = trace
        .iter()
        .map(|r| r.arrival_ms)
        .fold(0.0f64, f64::max)
        .max(1.0);
    total as f64 / (span_ms / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offered_load_spans_arrivals() {
        let g = TraceGen {
            mean_interarrival_ms: 10.0,
            ..TraceGen::paper_default(256, 9)
        };
        let trace = g.generate(10);
        let offered = offered_tokens_per_s(&trace);
        let span_s = trace.iter().map(|r| r.arrival_ms).fold(0.0f64, f64::max) / 1e3;
        assert!((offered - 10.0 * 96.0 / span_s).abs() < 1e-6);
        // closed loop: span floors at 1 ms
        let c = TraceGen::paper_default(256, 9).generate(3);
        assert_eq!(offered_tokens_per_s(&c), 3.0 * 96.0 * 1000.0);
    }

    #[test]
    fn ragged_trace_is_deterministic_and_bursty() {
        let g = RaggedTraceGen::new(16, 64, vec![4, 32], 7);
        let a = g.generate(40);
        assert_eq!(a, g.generate(40));
        assert_eq!(a.len(), 40);
        // both lengths appear, and at least one same-length burst of ≥ 2
        assert!(a.iter().any(|r| r.max_new_tokens == 4));
        assert!(a.iter().any(|r| r.max_new_tokens == 32));
        assert!(a
            .windows(2)
            .any(|w| w[0].max_new_tokens == w[1].max_new_tokens));
        // …and the mix actually switches (it is ragged, not uniform)
        assert!(a
            .windows(2)
            .any(|w| w[0].max_new_tokens != w[1].max_new_tokens));
        for r in &a {
            assert_eq!(r.prompt.len(), 16);
            assert!(r.prompt.iter().all(|&t| (0..64).contains(&t)));
        }
    }

    #[test]
    fn trace_is_deterministic() {
        let g = TraceGen::paper_default(256, 1);
        assert_eq!(g.generate(5), g.generate(5));
    }

    #[test]
    fn trace_shapes() {
        let g = TraceGen::paper_default(256, 2);
        let reqs = g.generate(10);
        assert_eq!(reqs.len(), 10);
        for r in &reqs {
            assert_eq!(r.prompt.len(), 32);
            assert_eq!(r.max_new_tokens, 96);
            assert!(r.prompt.iter().all(|&t| (0..256).contains(&t)));
            assert_eq!(r.arrival_ms, 0.0); // closed-loop default
        }
    }

    #[test]
    fn poisson_arrivals_increase() {
        let g = TraceGen {
            mean_interarrival_ms: 50.0,
            ..TraceGen::paper_default(256, 3)
        };
        let reqs = g.generate(20);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        assert!(reqs.last().unwrap().arrival_ms > 0.0);
    }

    #[test]
    fn different_requests_different_prompts() {
        let g = TraceGen::paper_default(256, 4);
        let reqs = g.generate(2);
        assert_ne!(reqs[0].prompt, reqs[1].prompt);
    }
}
