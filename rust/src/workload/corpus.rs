//! Synthetic text corpus — stands in for WikiText-2 (see DESIGN.md
//! Substitutions).  Generates deterministic English-like sentences via a
//! tiny template grammar, then byte-tokenizes them to match the executable
//! model's 256-entry vocabulary.  Latency/throughput are content-agnostic;
//! only the token-stream *shape* matters.

use crate::util::Rng;

const SUBJECTS: &[&str] = &[
    "the river", "a senator", "the museum", "an engineer", "the treaty",
    "the orchestra", "a glacier", "the village", "the archive", "a comet",
];
const VERBS: &[&str] = &[
    "crossed", "described", "rebuilt", "measured", "inspired",
    "preserved", "followed", "composed", "surveyed", "recorded",
];
const OBJECTS: &[&str] = &[
    "the northern valley", "an early manuscript", "the coastal railway",
    "a series of experiments", "the annual festival", "the stone bridge",
    "a collection of maps", "the quiet harbor", "the old observatory",
    "a chain of islands",
];
const CONNECTIVES: &[&str] = &[" while ", " because ", " and later ", " although ", " before "];

/// Deterministic sentence generator + byte tokenizer.
#[derive(Debug, Clone)]
pub struct Corpus {
    seed: u64,
}

impl Corpus {
    pub fn new(seed: u64) -> Self {
        Corpus { seed }
    }

    /// The `idx`-th document: a few clauses of template text.
    pub fn document(&self, idx: u64) -> String {
        let mut rng = Rng::new(self.seed.wrapping_add(idx.wrapping_mul(0x0FD4_7DED)));
        let mut s = String::new();
        let clauses = 2 + rng.next_below(3);
        for c in 0..clauses {
            if c > 0 {
                s.push_str(CONNECTIVES[rng.next_below(CONNECTIVES.len() as u64) as usize]);
            }
            s.push_str(SUBJECTS[rng.next_below(SUBJECTS.len() as u64) as usize]);
            s.push(' ');
            s.push_str(VERBS[rng.next_below(VERBS.len() as u64) as usize]);
            s.push(' ');
            s.push_str(OBJECTS[rng.next_below(OBJECTS.len() as u64) as usize]);
        }
        s.push('.');
        s
    }

    /// Byte-tokenize `document(idx)` into exactly `len` tokens in
    /// `[0, vocab)`, cycling the text if it is shorter.
    pub fn sample_tokens(&self, len: usize, vocab: i32, idx: u64) -> Vec<i32> {
        let doc = self.document(idx);
        let bytes = doc.as_bytes();
        (0..len)
            .map(|i| (bytes[i % bytes.len()] as i32) % vocab)
            .collect()
    }

    /// Decode byte tokens back into (lossy) text — used by the demo server.
    pub fn detokenize(tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| {
                let b = (t.clamp(0, 255)) as u8;
                if b.is_ascii_graphic() || b == b' ' {
                    b as char
                } else {
                    '·'
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documents_deterministic() {
        let c = Corpus::new(5);
        assert_eq!(c.document(3), c.document(3));
        assert_ne!(c.document(3), c.document(4));
    }

    #[test]
    fn tokens_in_range_and_exact_length() {
        let c = Corpus::new(1);
        let t = c.sample_tokens(100, 256, 0);
        assert_eq!(t.len(), 100);
        assert!(t.iter().all(|&x| (0..256).contains(&x)));
    }

    #[test]
    fn short_doc_cycles() {
        let c = Corpus::new(2);
        let t = c.sample_tokens(500, 256, 1);
        assert_eq!(t.len(), 500);
    }

    #[test]
    fn detokenize_roundtrip_printable() {
        let s = "the river crossed";
        let toks: Vec<i32> = s.bytes().map(|b| b as i32).collect();
        assert_eq!(Corpus::detokenize(&toks), s);
    }

    #[test]
    fn text_looks_like_text() {
        let c = Corpus::new(7);
        let d = c.document(0);
        assert!(d.len() > 20);
        assert!(d.ends_with('.'));
        assert!(d.contains(' '));
    }
}
