//! Serving metrics: latency histograms, throughput meters, and experiment
//! result tables.

use std::time::Instant;

/// Latency histogram with exact percentiles (stores samples; fine at the
/// request rates these experiments run).
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples_ms: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_ms.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples_ms
                .sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact percentile (nearest-rank). `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let n = self.samples_ms.len();
        let rank = ((q / 100.0) * (n as f64 - 1.0)).round() as usize;
        self.samples_ms[rank.min(n - 1)]
    }

    pub fn min(&mut self) -> f64 {
        self.percentile(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.percentile(100.0)
    }

    pub fn summary(&mut self) -> String {
        format!(
            "n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.max(),
        )
    }
}

/// One stage-compute timing observation flowing from a stage actor to the
/// adaptive monitor: milliseconds of shard execution (compute-scale
/// applied) for one pipeline message.  Link time is observed separately
/// as [`crate::netsim::TransferObs`]; together they are everything the
/// online estimators in [`crate::adaptive::monitor`] see.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeObs {
    pub device: usize,
    pub stage: usize,
    /// `true` for a decode iteration, `false` for prefill.
    pub decode: bool,
    pub ms: f64,
}

/// Counts events over a wall-clock window.  The window opens at the
/// *first* [`ThroughputMeter::add`], not at construction, so setup time
/// between building the meter and the first event never dilutes
/// [`ThroughputMeter::per_second`].
#[derive(Debug, Default)]
pub struct ThroughputMeter {
    start: Option<Instant>,
    count: u64,
}

impl ThroughputMeter {
    pub fn new() -> Self {
        ThroughputMeter::default()
    }

    pub fn add(&mut self, n: u64) {
        self.start.get_or_insert_with(Instant::now);
        self.count += n;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Seconds since the first event (0 before any event).
    pub fn elapsed_s(&self) -> f64 {
        self.start
            .map(|s| s.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }

    pub fn per_second(&self) -> f64 {
        let s = self.elapsed_s();
        if s <= 0.0 {
            0.0
        } else {
            self.count as f64 / s
        }
    }
}

/// One (method × model) cell of a paper-style result table.
#[derive(Debug, Clone, PartialEq)]
pub enum Cell {
    /// latency ms/token, throughput tokens/s
    Ok { latency_ms: f64, throughput: f64 },
    /// the configuration cannot host the model
    Oom,
}

impl Cell {
    pub fn latency_str(&self) -> String {
        match self {
            Cell::Ok { latency_ms, .. } => format!("{latency_ms:.2}"),
            Cell::Oom => "OOM".into(),
        }
    }

    pub fn throughput_str(&self) -> String {
        match self {
            Cell::Ok { throughput, .. } => format!("{throughput:.2}"),
            Cell::Oom => "OOM".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile(95.0) - 95.0).abs() <= 1.0);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 100.0);
    }

    #[test]
    fn histogram_empty_safe() {
        let mut h = Histogram::new();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_unsorted_input() {
        let mut h = Histogram::new();
        for v in [5.0, 1.0, 3.0, 2.0, 4.0] {
            h.record(v);
        }
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 5.0);
    }

    #[test]
    fn throughput_counts() {
        let mut t = ThroughputMeter::new();
        t.add(10);
        t.add(5);
        assert_eq!(t.count(), 15);
        assert!(t.per_second() > 0.0);
    }

    /// Regression: the window must start at the first `add`, not at
    /// construction — otherwise setup time silently deflates the rate.
    #[test]
    fn throughput_window_starts_on_first_add() {
        let mut t = ThroughputMeter::new();
        assert_eq!(t.elapsed_s(), 0.0);
        assert_eq!(t.per_second(), 0.0);
        // construction-to-first-event gap must not count
        std::thread::sleep(std::time::Duration::from_millis(60));
        t.add(100);
        let elapsed = t.elapsed_s();
        assert!(
            elapsed < 0.050,
            "window included setup time: elapsed {elapsed}s"
        );
        // 100 events over well under 50 ms is > 2000/s; the old
        // construction-anchored window would report < 1700/s here
        assert!(t.per_second() > 2000.0, "rate {}", t.per_second());
    }

    #[test]
    fn cell_render() {
        let c = Cell::Ok {
            latency_ms: 75.879,
            throughput: 52.446,
        };
        assert_eq!(c.latency_str(), "75.88");
        assert_eq!(c.throughput_str(), "52.45");
        assert_eq!(Cell::Oom.latency_str(), "OOM");
    }
}
