//! Network simulator — the stand-in for the paper's Linux-TC-shaped LAN.
//!
//! Two layers of fidelity:
//!
//! * [`LinkSpec::transfer_ms`] — closed-form transfer time, used by the
//!   planners and the pipeline simulator (identical math to
//!   [`crate::cluster::Cluster::comm_ms`]).
//! * [`shaped_channel`] — a real channel whose deliveries are delayed by
//!   transfer time + propagation latency, serialized like a physical link
//!   (one frame at a time; a dedicated pacer thread plays the role of the
//!   NIC).  The collaborative engines in [`crate::coordinator`] move real
//!   activation tensors through these, so the end-to-end demo experiences
//!   the same queueing the paper's testbed does.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread;
use std::time::Duration;

/// Static description of one directed link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    pub bandwidth_mbps: f64,
    pub latency_ms: f64,
}

impl LinkSpec {
    pub fn new(bandwidth_mbps: f64, latency_ms: f64) -> Self {
        LinkSpec {
            bandwidth_mbps,
            latency_ms,
        }
    }

    /// Pure serialization delay for `bytes` (no propagation latency).
    pub fn transfer_ms(&self, bytes: u64) -> f64 {
        if !self.bandwidth_mbps.is_finite() {
            return 0.0;
        }
        bytes as f64 * 8.0 / (self.bandwidth_mbps * 1e6) * 1e3
    }

    /// One-shot delivery time: serialization + propagation.
    pub fn delivery_ms(&self, bytes: u64) -> f64 {
        self.transfer_ms(bytes) + self.latency_ms
    }
}

/// A message with an explicit wire size.
struct Frame<T> {
    payload: T,
    bytes: u64,
}

/// Sender half of a shaped channel.
pub struct ShapedSender<T> {
    tx: Sender<Frame<T>>,
}

impl<T> Clone for ShapedSender<T> {
    fn clone(&self) -> Self {
        ShapedSender {
            tx: self.tx.clone(),
        }
    }
}

impl<T: Send + 'static> ShapedSender<T> {
    /// Enqueue a frame; it will arrive after the link finishes serializing
    /// everything ahead of it plus this frame, plus propagation latency.
    pub fn send(&self, payload: T, bytes: u64) -> anyhow::Result<()> {
        self.tx
            .send(Frame { payload, bytes })
            .map_err(|_| anyhow::anyhow!("shaped link closed"))
    }
}

/// Create a shaped, serialized link.
///
/// `time_scale` compresses simulated time (0.01 ⇒ delays run at 1% of
/// real time) so integration tests finish quickly while preserving
/// ordering and relative timing.  The pacer thread exits when both ends
/// hang up.
pub fn shaped_channel<T: Send + 'static>(
    spec: LinkSpec,
    time_scale: f64,
) -> (ShapedSender<T>, Receiver<T>) {
    let (in_tx, in_rx) = mpsc::channel::<Frame<T>>();
    let (out_tx, out_rx) = mpsc::channel::<T>();
    thread::spawn(move || {
        // Track the latency-stage so propagation overlaps the next frame's
        // serialization: deliver_at(frame) = serialize_done + latency.
        while let Ok(frame) = in_rx.recv() {
            let transfer = spec.transfer_ms(frame.bytes) * time_scale;
            if transfer > 0.0 {
                thread::sleep(Duration::from_secs_f64(transfer / 1e3));
            }
            let lat = spec.latency_ms * time_scale;
            if lat > 0.0 {
                let out = out_tx.clone();
                thread::spawn(move || {
                    thread::sleep(Duration::from_secs_f64(lat / 1e3));
                    let _ = out.send(frame.payload);
                });
            } else if out_tx.send(frame.payload).is_err() {
                break;
            }
        }
    });
    (ShapedSender { tx: in_tx }, out_rx)
}

/// Full-mesh link specs for a cluster: `specs[a][b]` describes traffic a→b.
pub fn cluster_link_specs(cluster: &crate::cluster::Cluster) -> Vec<Vec<LinkSpec>> {
    let m = cluster.len();
    (0..m)
        .map(|a| {
            (0..m)
                .map(|b| LinkSpec::new(cluster.bandwidth_mbps[a][b], cluster.latency_ms[a][b]))
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn transfer_math() {
        let l = LinkSpec::new(8.0, 2.0);
        assert!((l.transfer_ms(1_000_000) - 1000.0).abs() < 1e-9);
        assert!((l.delivery_ms(0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn infinite_bandwidth_is_free() {
        let l = LinkSpec::new(f64::INFINITY, 0.0);
        assert_eq!(l.transfer_ms(u64::MAX / 16), 0.0);
    }

    #[test]
    fn shaped_channel_delivers_in_order() {
        let (tx, rx) = shaped_channel(LinkSpec::new(1000.0, 0.0), 0.01);
        for i in 0..5 {
            tx.send(i, 1000).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn shaped_channel_delays_by_bandwidth() {
        // 1 MB at 8 Mbps = 1000 ms; at scale 0.05 → 50 ms.
        let (tx, rx) = shaped_channel(LinkSpec::new(8.0, 0.0), 0.05);
        let start = Instant::now();
        tx.send("x", 1_000_000).unwrap();
        rx.recv().unwrap();
        let ms = start.elapsed().as_secs_f64() * 1e3;
        assert!((35.0..500.0).contains(&ms), "elapsed={ms}ms");
    }

    #[test]
    fn link_serializes_back_to_back_frames() {
        let (tx, rx) = shaped_channel(LinkSpec::new(8.0, 0.0), 0.05);
        let start = Instant::now();
        tx.send(1, 500_000).unwrap();
        tx.send(2, 500_000).unwrap();
        rx.recv().unwrap();
        let t1 = start.elapsed().as_secs_f64() * 1e3;
        rx.recv().unwrap();
        let t2 = start.elapsed().as_secs_f64() * 1e3;
        // Second frame must wait for the first (~25 ms each at this scale).
        assert!(t2 > t1 + 10.0, "t1={t1} t2={t2}");
    }

    #[test]
    fn zero_scale_is_instant() {
        let (tx, rx) = shaped_channel(LinkSpec::new(0.001, 100.0), 0.0);
        tx.send(7, 1 << 40).unwrap();
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn latency_overlaps_serialization() {
        // Two tiny frames over a high-latency fast link: both arrive about
        // one latency after send, not two latencies.
        let (tx, rx) = shaped_channel(LinkSpec::new(1e6, 1000.0), 0.05);
        let start = Instant::now();
        tx.send(1, 10).unwrap();
        tx.send(2, 10).unwrap();
        rx.recv().unwrap();
        rx.recv().unwrap();
        let ms = start.elapsed().as_secs_f64() * 1e3;
        assert!(ms < 140.0, "elapsed={ms}ms (latencies must overlap)");
    }

    #[test]
    fn cluster_specs_mirror_cluster() {
        let c = crate::cluster::presets::paper_testbed(1.0, 0);
        let specs = cluster_link_specs(&c);
        assert_eq!(specs[0][14].bandwidth_mbps, 1.0);
        assert_eq!(specs[3][4].bandwidth_mbps, c.bandwidth_mbps[3][4]);
    }
}
