//! Network simulator — the stand-in for the paper's Linux-TC-shaped LAN.
//!
//! Two layers of fidelity:
//!
//! * [`LinkSpec::transfer_ms`] — closed-form transfer time, used by the
//!   planners and the pipeline simulator (identical math to
//!   [`crate::cluster::Cluster::comm_ms`]).
//! * [`shaped_channel`] — a real channel whose deliveries are delayed by
//!   transfer time + propagation latency, serialized like a physical link
//!   (one frame at a time; a dedicated pacer thread plays the role of the
//!   NIC).  The collaborative engines in [`crate::coordinator`] move real
//!   activation tensors through these, so the end-to-end demo experiences
//!   the same queueing the paper's testbed does.
//!
//! For the adaptive runtime ([`crate::adaptive`]) links are **live**:
//! [`shaped_channel_live`] reads its [`LiveLink`] spec in small slices
//! while serializing, so a bandwidth change applied mid-frame (by
//! [`crate::adaptive::dynamics`]) immediately stretches or shrinks the
//! remaining transfer.  Live channels can also report a [`TransferObs`]
//! per delivered frame — the raw signal the online
//! [`crate::adaptive::monitor`] estimates link state from, without ever
//! reading the ground-truth spec.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Static description of one directed link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    pub bandwidth_mbps: f64,
    pub latency_ms: f64,
}

impl LinkSpec {
    pub fn new(bandwidth_mbps: f64, latency_ms: f64) -> Self {
        LinkSpec {
            bandwidth_mbps,
            latency_ms,
        }
    }

    /// Whether the link can move bytes at all (positive finite rate or
    /// the infinite same-device "link").
    pub fn is_up(&self) -> bool {
        self.bandwidth_mbps == f64::INFINITY
            || (self.bandwidth_mbps > 0.0 && self.bandwidth_mbps.is_finite())
    }

    /// Pure serialization delay for `bytes` (no propagation latency).
    ///
    /// Infinite bandwidth is free; zero, negative or NaN bandwidth means
    /// the link is **down** and yields `INFINITY` (so planners route
    /// around it) rather than the NaN the naive division would produce.
    pub fn transfer_ms(&self, bytes: u64) -> f64 {
        let bw = self.bandwidth_mbps;
        if bw == f64::INFINITY {
            return 0.0;
        }
        if !bw.is_finite() || bw <= 0.0 {
            return f64::INFINITY;
        }
        bytes as f64 * 8.0 / (bw * 1e6) * 1e3
    }

    /// One-shot delivery time: serialization + propagation.
    pub fn delivery_ms(&self, bytes: u64) -> f64 {
        self.transfer_ms(bytes) + self.latency_ms
    }
}

/// A link spec that can be re-shaped while traffic is in flight — the
/// Linux-TC analogue for the adaptive runtime.  Cloning shares the spec.
#[derive(Debug, Clone)]
pub struct LiveLink {
    spec: Arc<Mutex<LinkSpec>>,
}

impl LiveLink {
    pub fn new(spec: LinkSpec) -> Self {
        LiveLink {
            spec: Arc::new(Mutex::new(spec)),
        }
    }

    pub fn get(&self) -> LinkSpec {
        *self.spec.lock().expect("link spec lock poisoned")
    }

    pub fn set(&self, spec: LinkSpec) {
        *self.spec.lock().expect("link spec lock poisoned") = spec;
    }

    pub fn set_bandwidth(&self, mbps: f64) {
        self.spec.lock().expect("link spec lock poisoned").bandwidth_mbps = mbps;
    }

    pub fn set_latency(&self, ms: f64) {
        self.spec.lock().expect("link spec lock poisoned").latency_ms = ms;
    }
}

/// A live link annotated with the device pair it connects, so dynamics
/// drivers can look up the right schedule.
#[derive(Debug, Clone)]
pub struct RoutedLink {
    pub from: usize,
    pub to: usize,
    pub link: LiveLink,
}

/// One delivered frame as observed at the receiving end of a shaped link:
/// wire bytes and simulated milliseconds from send to delivery (queueing +
/// serialization + propagation).  This is a *measurement*, not the spec —
/// under congestion it reads slower than the nominal rate, exactly like a
/// real transfer timing would.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferObs {
    pub from: usize,
    pub to: usize,
    pub bytes: u64,
    pub sim_ms: f64,
}

/// A message with an explicit wire size.
struct Frame<T> {
    payload: T,
    bytes: u64,
    enqueued: Instant,
}

/// Sender half of a shaped channel.
pub struct ShapedSender<T> {
    tx: Sender<Frame<T>>,
}

impl<T> Clone for ShapedSender<T> {
    fn clone(&self) -> Self {
        ShapedSender {
            tx: self.tx.clone(),
        }
    }
}

impl<T: Send + 'static> ShapedSender<T> {
    /// Enqueue a frame; it will arrive after the link finishes serializing
    /// everything ahead of it plus this frame, plus propagation latency.
    pub fn send(&self, payload: T, bytes: u64) -> anyhow::Result<()> {
        self.tx
            .send(Frame {
                payload,
                bytes,
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow::anyhow!("shaped link closed"))
    }
}

/// How often the pacer re-reads a live spec while serializing (real ms).
/// Small enough that a mid-frame bandwidth change takes effect promptly;
/// large enough that tiny frames cost one syscall-scale sleep.
const PACER_SLICE_REAL_MS: f64 = 2.0;

/// Create a shaped, serialized link with a fixed spec.
///
/// `time_scale` compresses simulated time (0.01 ⇒ delays run at 1% of
/// real time) so integration tests finish quickly while preserving
/// ordering and relative timing.  The pacer thread exits when both ends
/// hang up.
pub fn shaped_channel<T: Send + 'static>(
    spec: LinkSpec,
    time_scale: f64,
) -> (ShapedSender<T>, Receiver<T>) {
    shaped_channel_live(LiveLink::new(spec), time_scale, (0, 0), Vec::new())
}

/// Create a shaped link whose spec is read live from `link` — bandwidth
/// changes apply to the *remaining* bits of any frame being serialized.
///
/// `route` tags observations with the (from, to) device pair; every
/// delivered frame reports a [`TransferObs`] to each sender in `obs`
/// (fan-out: the adaptive monitor and the tracer can both listen).
pub fn shaped_channel_live<T: Send + 'static>(
    link: LiveLink,
    time_scale: f64,
    route: (usize, usize),
    obs: Vec<Sender<TransferObs>>,
) -> (ShapedSender<T>, Receiver<T>) {
    let (in_tx, in_rx) = mpsc::channel::<Frame<T>>();
    let (out_tx, out_rx) = mpsc::channel::<T>();
    let (deliver_tx, deliver_rx) = mpsc::channel::<(Instant, T)>();
    // Delivery thread: frames queue FIFO with a due time (serialize_done +
    // latency), so propagation overlaps the next frame's serialization
    // while per-link ordering is preserved — the coordinator's control
    // protocol (Free before Export before Shutdown) depends on links
    // never reordering frames.
    thread::spawn(move || {
        while let Ok((due, payload)) = deliver_rx.recv() {
            let wait = due.saturating_duration_since(Instant::now());
            if !wait.is_zero() {
                thread::sleep(wait);
            }
            if out_tx.send(payload).is_err() {
                break;
            }
        }
    });
    thread::spawn(move || {
        while let Ok(frame) = in_rx.recv() {
            let mut spec = link.get();
            let mut remaining_bits = frame.bytes as f64 * 8.0;
            while remaining_bits > 0.0 {
                spec = link.get();
                let bw = spec.bandwidth_mbps;
                if bw == f64::INFINITY || time_scale <= 0.0 {
                    break;
                }
                if !bw.is_finite() || bw <= 0.0 {
                    // Link down: hold the frame and poll for recovery.
                    thread::sleep(Duration::from_secs_f64(PACER_SLICE_REAL_MS / 1e3));
                    continue;
                }
                // sim ms for the remaining bits = bits / (bw Mbps * 1e3)
                let need_real_ms = remaining_bits / (bw * 1e3) * time_scale;
                if need_real_ms <= PACER_SLICE_REAL_MS {
                    if need_real_ms > 0.0 {
                        thread::sleep(Duration::from_secs_f64(need_real_ms / 1e3));
                    }
                    remaining_bits = 0.0;
                } else {
                    thread::sleep(Duration::from_secs_f64(PACER_SLICE_REAL_MS / 1e3));
                    remaining_bits -= PACER_SLICE_REAL_MS / time_scale * bw * 1e3;
                }
            }
            if !obs.is_empty() {
                let real_ms = frame.enqueued.elapsed().as_secs_f64() * 1e3;
                let ser_sim_ms = if time_scale > 0.0 {
                    real_ms / time_scale
                } else {
                    spec.transfer_ms(frame.bytes)
                };
                let o = TransferObs {
                    from: route.0,
                    to: route.1,
                    bytes: frame.bytes,
                    sim_ms: ser_sim_ms + spec.latency_ms,
                };
                for tx in &obs {
                    let _ = tx.send(o);
                }
            }
            let lat = spec.latency_ms * time_scale;
            let due = if lat.is_finite() && lat > 0.0 {
                Instant::now() + Duration::from_secs_f64(lat / 1e3)
            } else {
                Instant::now()
            };
            if deliver_tx.send((due, frame.payload)).is_err() {
                break;
            }
        }
    });
    (ShapedSender { tx: in_tx }, out_rx)
}

/// Full-mesh link specs for a cluster: `specs[a][b]` describes traffic a→b.
pub fn cluster_link_specs(cluster: &crate::cluster::Cluster) -> Vec<Vec<LinkSpec>> {
    let m = cluster.len();
    (0..m)
        .map(|a| {
            (0..m)
                .map(|b| LinkSpec::new(cluster.bandwidth_mbps[a][b], cluster.latency_ms[a][b]))
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_math() {
        let l = LinkSpec::new(8.0, 2.0);
        assert!((l.transfer_ms(1_000_000) - 1000.0).abs() < 1e-9);
        assert!((l.delivery_ms(0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn infinite_bandwidth_is_free() {
        let l = LinkSpec::new(f64::INFINITY, 0.0);
        assert_eq!(l.transfer_ms(u64::MAX / 16), 0.0);
        assert!(l.is_up());
    }

    #[test]
    fn dead_links_yield_infinity_not_nan() {
        // 0, negative, and NaN bandwidths all mean "down": planners see an
        // infinite cost instead of NaN poisoning the DP tables.
        for bw in [0.0, -5.0, f64::NAN] {
            let l = LinkSpec::new(bw, 1.0);
            assert!(!l.is_up(), "bw={bw}");
            assert_eq!(l.transfer_ms(0), f64::INFINITY, "bw={bw}");
            assert_eq!(l.transfer_ms(1000), f64::INFINITY, "bw={bw}");
            assert_eq!(l.delivery_ms(1000), f64::INFINITY, "bw={bw}");
        }
    }

    #[test]
    fn shaped_channel_delivers_in_order() {
        let (tx, rx) = shaped_channel(LinkSpec::new(1000.0, 0.0), 0.01);
        for i in 0..5 {
            tx.send(i, 1000).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn shaped_channel_delays_by_bandwidth() {
        // 1 MB at 8 Mbps = 1000 ms; at scale 0.05 → 50 ms.
        let (tx, rx) = shaped_channel(LinkSpec::new(8.0, 0.0), 0.05);
        let start = Instant::now();
        tx.send("x", 1_000_000).unwrap();
        rx.recv().unwrap();
        let ms = start.elapsed().as_secs_f64() * 1e3;
        assert!((35.0..500.0).contains(&ms), "elapsed={ms}ms");
    }

    #[test]
    fn link_serializes_back_to_back_frames() {
        let (tx, rx) = shaped_channel(LinkSpec::new(8.0, 0.0), 0.05);
        let start = Instant::now();
        tx.send(1, 500_000).unwrap();
        tx.send(2, 500_000).unwrap();
        rx.recv().unwrap();
        let t1 = start.elapsed().as_secs_f64() * 1e3;
        rx.recv().unwrap();
        let t2 = start.elapsed().as_secs_f64() * 1e3;
        // Second frame must wait for the first (~25 ms each at this scale).
        assert!(t2 > t1 + 10.0, "t1={t1} t2={t2}");
    }

    #[test]
    fn latency_link_preserves_order() {
        // Tiny control frames over a high-latency link must never reorder:
        // the coordinator's Free → Export → Shutdown protocol depends on
        // links being FIFO even though propagation overlaps serialization.
        let (tx, rx) = shaped_channel(LinkSpec::new(1e6, 500.0), 0.02);
        for i in 0..50 {
            tx.send(i, 16).unwrap();
        }
        for i in 0..50 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn zero_scale_is_instant() {
        let (tx, rx) = shaped_channel(LinkSpec::new(0.001, 100.0), 0.0);
        tx.send(7, 1 << 40).unwrap();
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn latency_overlaps_serialization() {
        // Two tiny frames over a high-latency fast link: both arrive about
        // one latency after send, not two latencies.
        let (tx, rx) = shaped_channel(LinkSpec::new(1e6, 1000.0), 0.05);
        let start = Instant::now();
        tx.send(1, 10).unwrap();
        tx.send(2, 10).unwrap();
        rx.recv().unwrap();
        rx.recv().unwrap();
        let ms = start.elapsed().as_secs_f64() * 1e3;
        assert!(ms < 140.0, "elapsed={ms}ms (latencies must overlap)");
    }

    #[test]
    fn live_link_change_applies_mid_frame() {
        // A frame that would take ~400 ms real at the initial rate speeds
        // up when the link is re-shaped 10× faster shortly after send.
        let link = LiveLink::new(LinkSpec::new(2.0, 0.0));
        let (tx, rx) = shaped_channel_live::<u32>(link.clone(), 0.1, (0, 1), Vec::new());
        let start = Instant::now();
        tx.send(1, 1_000_000).unwrap(); // 4000 ms sim → 400 ms real
        thread::sleep(Duration::from_millis(40));
        link.set_bandwidth(2000.0);
        rx.recv().unwrap();
        let ms = start.elapsed().as_secs_f64() * 1e3;
        assert!(ms < 250.0, "elapsed={ms}ms (re-shape must apply mid-frame)");
        assert!(ms > 30.0, "elapsed={ms}ms (initial slow phase must count)");
    }

    #[test]
    fn observations_report_bytes_and_time() {
        let link = LiveLink::new(LinkSpec::new(8.0, 3.0));
        let (obs_tx, obs_rx) = mpsc::channel();
        let (tx, rx) = shaped_channel_live::<u32>(link, 0.05, (2, 4), vec![obs_tx]);
        tx.send(9, 100_000).unwrap(); // 100 ms sim serialization
        rx.recv().unwrap();
        let o = obs_rx.recv().unwrap();
        assert_eq!((o.from, o.to, o.bytes), (2, 4, 100_000));
        // ~100 ms serialization + 3 ms latency, in sim ms (generous band:
        // the pacer sleeps in 2 ms real slices).
        assert!((80.0..250.0).contains(&o.sim_ms), "sim_ms={}", o.sim_ms);
    }

    #[test]
    fn observations_fan_out_to_every_sink() {
        let link = LiveLink::new(LinkSpec::new(1000.0, 0.0));
        let (a_tx, a_rx) = mpsc::channel();
        let (b_tx, b_rx) = mpsc::channel();
        let (tx, rx) = shaped_channel_live::<u32>(link, 0.0, (1, 2), vec![a_tx, b_tx]);
        tx.send(1, 4096).unwrap();
        rx.recv().unwrap();
        let a = a_rx.recv_timeout(Duration::from_secs(2)).unwrap();
        let b = b_rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(a, b);
        assert_eq!((a.from, a.to, a.bytes), (1, 2, 4096));
    }

    #[test]
    fn down_link_holds_frames_until_recovery() {
        let link = LiveLink::new(LinkSpec::new(1000.0, 0.0));
        let (tx, rx) = shaped_channel_live::<u32>(link.clone(), 0.05, (0, 1), Vec::new());
        link.set_bandwidth(0.0);
        tx.send(5, 1000).unwrap();
        assert!(rx
            .recv_timeout(Duration::from_millis(30))
            .is_err());
        link.set_bandwidth(1000.0);
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), 5);
    }

    #[test]
    fn cluster_specs_mirror_cluster() {
        let c = crate::cluster::presets::paper_testbed(1.0, 0);
        let specs = cluster_link_specs(&c);
        assert_eq!(specs[0][14].bandwidth_mbps, 1.0);
        assert_eq!(specs[3][4].bandwidth_mbps, c.bandwidth_mbps[3][4]);
    }
}
