//! Baseline deployment strategies from the paper's evaluation (§V.A):
//!
//! * **Edge-Solo** — whole model on the source edge device.
//! * **Cloud-Edge-Even** — model split in half: first half on the source,
//!   second half on the cloud server.
//! * **Cloud-Edge-Opt** — the EdgeShard DP restricted to {source, cloud}
//!   (the paper notes it is "a special case of EdgeShard").
//! * **EdgeShard-Even** — model split evenly across a given device list
//!   (the 70B comparison in §V.C).

use super::latency::algo1;
use super::throughput::algo2_exact;
use super::{Plan, PlanError, PlanObjective, Planner, Stage};
use crate::cluster::Cluster;
use crate::profiler::ProfiledTraces;

fn check_mem(
    stages: &[Stage],
    traces: &ProfiledTraces,
    cluster: &Cluster,
    batch: usize,
) -> Result<(), PlanError> {
    let mut used = vec![0u64; cluster.len()];
    for s in stages {
        used[s.device] += traces.range_mem_bytes(s.start, s.end, batch);
    }
    for (d, u) in used.iter().enumerate() {
        if *u > cluster.devices[d].usable_mem_bytes {
            return Err(PlanError::Oom);
        }
    }
    Ok(())
}

/// Whole model on the source device.
#[derive(Debug, Clone, Default)]
pub struct EdgeSolo {
    pub batch: usize,
}

impl EdgeSolo {
    pub fn new() -> Self {
        EdgeSolo { batch: 1 }
    }
}

impl Planner for EdgeSolo {
    fn name(&self) -> &'static str {
        "Edge-Solo"
    }

    fn plan(&self, traces: &ProfiledTraces, cluster: &Cluster) -> Result<Plan, PlanError> {
        let stages = vec![Stage {
            device: cluster.source,
            start: 0,
            end: traces.n_layers,
        }];
        check_mem(&stages, traces, cluster, self.batch.max(1))?;
        let predicted_ms = traces.range_avg_ms(0, traces.n_layers, cluster.source);
        Ok(Plan {
            objective: PlanObjective::Latency,
            stages,
            predicted_ms,
        })
    }
}

/// Even 50/50 split between the source and the (single) cloud server.
#[derive(Debug, Clone, Default)]
pub struct CloudEdgeEven {
    pub batch: usize,
}

impl CloudEdgeEven {
    pub fn new() -> Self {
        CloudEdgeEven { batch: 1 }
    }
}

impl Planner for CloudEdgeEven {
    fn name(&self) -> &'static str {
        "Cloud-Edge-Even"
    }

    fn plan(&self, traces: &ProfiledTraces, cluster: &Cluster) -> Result<Plan, PlanError> {
        let cloud = *cluster
            .cloud_ids()
            .first()
            .ok_or_else(|| PlanError::Infeasible("no cloud device".into()))?;
        let n = traces.n_layers;
        let mid = n / 2;
        let stages = vec![
            Stage {
                device: cluster.source,
                start: 0,
                end: mid,
            },
            Stage {
                device: cloud,
                start: mid,
                end: n,
            },
        ];
        check_mem(&stages, traces, cluster, self.batch.max(1))?;
        let plan = Plan {
            objective: PlanObjective::Latency,
            stages,
            predicted_ms: 0.0,
        };
        let predicted_ms = super::sequential_latency_ms(&plan, traces, cluster);
        Ok(Plan {
            predicted_ms,
            ..plan
        })
    }
}

/// The EdgeShard DP on the {source, cloud} pair only.
#[derive(Debug, Clone)]
pub struct CloudEdgeOpt {
    pub objective: PlanObjective,
    pub batch: usize,
}

impl CloudEdgeOpt {
    pub fn latency() -> Self {
        CloudEdgeOpt {
            objective: PlanObjective::Latency,
            batch: 1,
        }
    }

    pub fn throughput() -> Self {
        CloudEdgeOpt {
            objective: PlanObjective::Throughput,
            batch: 1,
        }
    }
}

impl Planner for CloudEdgeOpt {
    fn name(&self) -> &'static str {
        "Cloud-Edge-Opt"
    }

    fn plan(&self, traces: &ProfiledTraces, cluster: &Cluster) -> Result<Plan, PlanError> {
        let cloud = *cluster
            .cloud_ids()
            .first()
            .ok_or_else(|| PlanError::Infeasible("no cloud device".into()))?;
        let pool = vec![cluster.source, cloud];
        match self.objective {
            PlanObjective::Latency => algo1(traces, cluster, &pool, self.batch.max(1)),
            PlanObjective::Throughput => {
                algo2_exact(traces, cluster, &pool, self.batch.max(1))
            }
        }
    }
}

/// Even layer split across an explicit device list (EdgeShard-Even, §V.C).
#[derive(Debug, Clone)]
pub struct EdgeShardEven {
    pub devices: Vec<usize>,
    pub batch: usize,
}

impl EdgeShardEven {
    pub fn new(devices: Vec<usize>) -> Self {
        EdgeShardEven { devices, batch: 1 }
    }
}

impl Planner for EdgeShardEven {
    fn name(&self) -> &'static str {
        "EdgeShard-Even"
    }

    fn plan(&self, traces: &ProfiledTraces, cluster: &Cluster) -> Result<Plan, PlanError> {
        if self.devices.is_empty() {
            return Err(PlanError::Infeasible("no devices".into()));
        }
        if self.devices[0] != cluster.source {
            return Err(PlanError::Infeasible(
                "first device must be the source".into(),
            ));
        }
        let n = traces.n_layers;
        let d = self.devices.len().min(n);
        let mut stages = Vec::with_capacity(d);
        let mut start = 0;
        for (s, &dev) in self.devices[..d].iter().enumerate() {
            let end = (n * (s + 1)) / d;
            if end > start {
                stages.push(Stage {
                    device: dev,
                    start,
                    end,
                });
                start = end;
            }
        }
        check_mem(&stages, traces, cluster, self.batch.max(1))?;
        let plan = Plan {
            objective: PlanObjective::Throughput,
            stages,
            predicted_ms: 0.0,
        };
        let predicted_ms = super::pipeline_bottleneck_ms(&plan, traces, cluster);
        Ok(Plan {
            predicted_ms,
            ..plan
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::{llama2_13b, llama2_70b, llama2_7b};
    use crate::planner::{validate_plan, LatencyDp};
    use crate::profiler::{AnalyticProfiler, Workload};

    fn profile(model: &crate::model::ModelDesc, cluster: &Cluster) -> ProfiledTraces {
        AnalyticProfiler::default().profile(model, cluster, Workload::paper_default())
    }

    #[test]
    fn solo_7b_fits_on_agx() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        let p = EdgeSolo::new().plan(&t, &c).unwrap();
        validate_plan(&p, &t, &c, 1).unwrap();
        assert_eq!(p.n_stages(), 1);
    }

    #[test]
    fn solo_13b_oom() {
        // Table IV row 1: 13B/70B OOM on a single AGX Orin.
        let c = presets::paper_testbed(1.0, 0);
        assert_eq!(
            EdgeSolo::new().plan(&profile(&llama2_13b(), &c), &c),
            Err(PlanError::Oom)
        );
        assert_eq!(
            EdgeSolo::new().plan(&profile(&llama2_70b(), &c), &c),
            Err(PlanError::Oom)
        );
    }

    #[test]
    fn cloud_edge_even_7b_ok_70b_oom() {
        let c = presets::paper_testbed(1.0, 0);
        let p = CloudEdgeEven::new()
            .plan(&profile(&llama2_7b(), &c), &c)
            .unwrap();
        assert_eq!(p.n_stages(), 2);
        assert_eq!(p.stages[1].device, 14);
        assert_eq!(
            CloudEdgeEven::new().plan(&profile(&llama2_70b(), &c), &c),
            Err(PlanError::Oom)
        );
    }

    #[test]
    fn cloud_edge_opt_matches_restricted_dp() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        let opt = CloudEdgeOpt::latency().plan(&t, &c).unwrap();
        let dp = LatencyDp::restricted(vec![0, 14]).plan(&t, &c).unwrap();
        assert!((opt.predicted_ms - dp.predicted_ms).abs() < 1e-9);
    }

    #[test]
    fn cloud_edge_opt_at_1mbps_is_local() {
        // §V.B: "The optimal deployment strategy of Cloud-Edge-
        // Collaboration is local execution" at 1 Mbps.
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        let p = CloudEdgeOpt::latency().plan(&t, &c).unwrap();
        assert_eq!(p.n_stages(), 1);
        assert_eq!(p.stages[0].device, 0);
        let solo = EdgeSolo::new().plan(&t, &c).unwrap();
        assert!((p.predicted_ms - solo.predicted_ms).abs() < 1e-9);
    }

    #[test]
    fn even_70b_needs_12_devices() {
        // §V.C: EdgeShard-Even selects 11 AGX + 1 RTX 3090 for 70B.
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_70b(), &c);
        let mut devs: Vec<usize> = (0..12).collect(); // 12 AGX Orin
        devs.push(14);
        let p = EdgeShardEven::new(devs).plan(&t, &c).unwrap();
        validate_plan(&p, &t, &c, 1).unwrap();
        assert_eq!(p.n_stages(), 13);
    }

    #[test]
    fn even_rejects_wrong_source() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        assert!(matches!(
            EdgeShardEven::new(vec![3, 14]).plan(&t, &c),
            Err(PlanError::Infeasible(_))
        ));
    }

    #[test]
    fn even_split_balanced() {
        let c = presets::paper_testbed(50.0, 0);
        let t = profile(&llama2_7b(), &c);
        let p = EdgeShardEven::new(vec![0, 1, 2, 3]).plan(&t, &c).unwrap();
        let sizes: Vec<usize> = p.stages.iter().map(|s| s.len()).collect();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1, "sizes={sizes:?}");
    }
}
