//! Algorithm 1 — joint device selection + partition for **latency**.
//!
//! Faithful implementation of the paper's DP (Eqs. 6–8):
//!
//! ```text
//! DP(i,j) = min_k ( DP(i-1,k) + t_comp(i,j) + t_comm(i-1,k,j) )          1 ≤ i < N-1
//! DP(N-1,j) adds t_comm(N-1,j,source)   (token loopback, autoregression)
//! DP(0,source) = t_comp(0,source)       (privacy constraint, Eq. 4/7)
//! ```
//!
//! Memory (Eq. 5): the paper's pseudo-code greedily updates `Mem_j` while
//! filling the table (Algo 1 line 13).  That greedy update is subtly
//! lossy: the single cheapest path into state `(i,j)` may have loaded a
//! device so full that every *continuation* needs extra hops, while a
//! slightly costlier prefix would have finished cheaper overall.  We fix
//! it by keeping a small **Pareto frontier** of (cost, per-device memory)
//! candidates per state instead of one best path: a candidate survives if
//! no other is both cheaper and no more memory-hungry on every device.
//! With the frontier capped at [`PARETO_CAP`] the complexity stays
//! O(N·M²·K).  [`algo1_greedy`] preserves the paper's literal single-path
//! behaviour for comparison (always feasible, occasionally suboptimal —
//! see `tests::greedy_variant_can_be_suboptimal`).

use super::{Plan, PlanError, PlanObjective, Planner, Stage};
use crate::cluster::Cluster;
use crate::profiler::ProfiledTraces;

/// Latency planner (Algorithm 1).  `restrict` optionally limits the device
/// pool (e.g. `[source, cloud]` turns it into Cloud-Edge-Opt).
#[derive(Debug, Clone, Default)]
pub struct LatencyDp {
    pub restrict: Option<Vec<usize>>,
    /// Batch used for memory accounting (KV slots per sequence).
    pub batch: usize,
}

impl LatencyDp {
    pub fn new() -> Self {
        LatencyDp {
            restrict: None,
            batch: 1,
        }
    }

    pub fn restricted(devices: Vec<usize>) -> Self {
        LatencyDp {
            restrict: Some(devices),
            batch: 1,
        }
    }

    fn device_pool(&self, cluster: &Cluster) -> Vec<usize> {
        match &self.restrict {
            Some(v) => v.clone(),
            None => (0..cluster.len()).collect(),
        }
    }
}

impl Planner for LatencyDp {
    fn name(&self) -> &'static str {
        "EdgeShard-Latency(Algo1)"
    }

    fn plan(&self, traces: &ProfiledTraces, cluster: &Cluster) -> Result<Plan, PlanError> {
        algo1(traces, cluster, &self.device_pool(cluster), self.batch.max(1))
    }
}

/// Max Pareto candidates kept per DP state.
pub const PARETO_CAP: usize = 8;

#[derive(Clone)]
struct State {
    cost: f64,
    /// predecessor device (choice table)
    prev: usize,
    /// index of the predecessor candidate within dp[i-1][prev]
    prev_slot: usize,
    /// memory consumed on each device along this candidate's path
    mem_used: Vec<u64>,
}

fn dominates(a: &State, b: &State) -> bool {
    a.cost <= b.cost && a.mem_used.iter().zip(&b.mem_used).all(|(x, y)| x <= y)
}

/// Insert a candidate into a Pareto frontier (capped, cost-sorted).
fn pareto_insert(frontier: &mut Vec<State>, cand: State, cap: usize) {
    if frontier.iter().any(|s| dominates(s, &cand)) {
        return;
    }
    frontier.retain(|s| !dominates(&cand, s));
    let pos = frontier
        .iter()
        .position(|s| s.cost > cand.cost)
        .unwrap_or(frontier.len());
    frontier.insert(pos, cand);
    frontier.truncate(cap);
}

/// Algorithm 1 with the Pareto-frontier memory fix.  `pool` is the
/// candidate device set (must contain the source); `batch` sizes the KV
/// reservation.
pub fn algo1(
    traces: &ProfiledTraces,
    cluster: &Cluster,
    pool: &[usize],
    batch: usize,
) -> Result<Plan, PlanError> {
    algo1_impl(traces, cluster, pool, batch, PARETO_CAP)
}

/// The paper's literal Algorithm 1 (single best path per state, greedy
/// memory update) — kept for the ablation benches.
pub fn algo1_greedy(
    traces: &ProfiledTraces,
    cluster: &Cluster,
    pool: &[usize],
    batch: usize,
) -> Result<Plan, PlanError> {
    algo1_impl(traces, cluster, pool, batch, 1)
}

fn algo1_impl(
    traces: &ProfiledTraces,
    cluster: &Cluster,
    pool: &[usize],
    batch: usize,
    cap: usize,
) -> Result<Plan, PlanError> {
    let n = traces.n_layers;
    let src = cluster.source;
    if n == 0 {
        return Err(PlanError::Infeasible("no layers".into()));
    }
    if !pool.contains(&src) {
        return Err(PlanError::Infeasible("pool must contain source".into()));
    }
    let m = cluster.len();
    let layer_mem = |i: usize| traces.range_mem_bytes(i, i + 1, batch);
    let budget: Vec<u64> = (0..m).map(|d| cluster.devices[d].usable_mem_bytes).collect();

    // dp[i][j]: Pareto frontier of (cost, memory) candidates with layer i
    // on device j.
    let mut dp: Vec<Vec<Vec<State>>> = vec![vec![Vec::new(); m]; n];

    // init: privacy — layer 0 pinned to the source node (Eq. 7)
    if layer_mem(0) > budget[src] {
        return Err(PlanError::Oom);
    }
    let mut mem0 = vec![0u64; m];
    mem0[src] = layer_mem(0);
    dp[0][src].push(State {
        cost: traces.avg_ms[0][src],
        prev: usize::MAX,
        prev_slot: usize::MAX,
        mem_used: mem0,
    });

    for i in 1..n {
        let need = layer_mem(i);
        for &j in pool {
            let mut frontier: Vec<State> = Vec::new();
            for &k in pool {
                let comm = cluster.comm_ms(k, j, traces.act_bytes_avg[i - 1]);
                for (slot, prev) in dp[i - 1][k].iter().enumerate() {
                    // memory feasibility along this path (Algo 1 line 13)
                    if prev.mem_used[j] + need > budget[j] {
                        continue;
                    }
                    let mut cost = prev.cost + traces.avg_ms[i][j] + comm;
                    if i == n - 1 {
                        // Eq. 6 second branch: loopback to the source
                        cost += cluster.comm_ms(j, src, traces.act_bytes_avg[n - 1]);
                    }
                    let mut mem = prev.mem_used.clone();
                    mem[j] += need;
                    pareto_insert(
                        &mut frontier,
                        State {
                            cost,
                            prev: k,
                            prev_slot: slot,
                            mem_used: mem,
                        },
                        cap,
                    );
                }
            }
            dp[i][j] = frontier;
        }
    }

    // Eq. 8: best final state
    let (last_dev, last_slot, cost) = dp[n - 1]
        .iter()
        .enumerate()
        .flat_map(|(j, f)| f.iter().enumerate().map(move |(s, st)| (j, s, st.cost)))
        .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
        .ok_or(PlanError::Oom)?;

    // backtrace the choice table into a per-layer device list
    let mut assign = vec![0usize; n];
    let (mut j, mut slot) = (last_dev, last_slot);
    for i in (0..n).rev() {
        assign[i] = j;
        let st = &dp[i][j][slot];
        let (pj, ps) = (st.prev, st.prev_slot);
        j = pj;
        slot = ps;
    }

    Ok(Plan {
        objective: PlanObjective::Latency,
        stages: stages_from_assignment(&assign),
        predicted_ms: cost,
    })
}

/// Collapse a per-layer device assignment into contiguous stages.
pub fn stages_from_assignment(assign: &[usize]) -> Vec<Stage> {
    let mut stages: Vec<Stage> = Vec::new();
    for (i, &d) in assign.iter().enumerate() {
        match stages.last_mut() {
            Some(s) if s.device == d && s.end == i => s.end = i + 1,
            _ => stages.push(Stage {
                device: d,
                start: i,
                end: i + 1,
            }),
        }
    }
    stages
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::{llama2_13b, llama2_70b, llama2_7b};
    use crate::planner::{sequential_latency_ms, validate_plan};
    use crate::profiler::{AnalyticProfiler, Workload};

    fn profile(model: &crate::model::ModelDesc, cluster: &Cluster) -> ProfiledTraces {
        AnalyticProfiler::default().profile(model, cluster, Workload::paper_default())
    }

    #[test]
    fn plan_is_valid_7b() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        let p = LatencyDp::new().plan(&t, &c).unwrap();
        validate_plan(&p, &t, &c, 1).unwrap();
    }

    #[test]
    fn predicted_matches_evaluator() {
        // The DP's objective must equal the independent plan evaluator.
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        let p = LatencyDp::new().plan(&t, &c).unwrap();
        let eval = sequential_latency_ms(&p, &t, &c);
        assert!(
            (p.predicted_ms - eval).abs() < 1e-6,
            "dp={} eval={}",
            p.predicted_ms,
            eval
        );
    }

    #[test]
    fn edgeshard_beats_solo_7b() {
        // Table IV: EdgeShard ≈2× faster than Edge-Solo for 7B.
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        let p = LatencyDp::new().plan(&t, &c).unwrap();
        let solo = t.range_avg_ms(0, t.n_layers, 0);
        assert!(
            p.predicted_ms < solo * 0.8,
            "edgeshard={} solo={solo}",
            p.predicted_ms
        );
        assert!(p.n_stages() > 1, "expected sharding: {}", p.describe());
    }

    #[test]
    fn slow_cloud_link_avoided_with_two_devices() {
        // Cloud-Edge-Opt at 1 Mbps collapses to local execution (§V.B).
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        let p = LatencyDp::restricted(vec![0, 14]).plan(&t, &c).unwrap();
        assert_eq!(p.n_stages(), 1, "{}", p.describe());
        assert_eq!(p.stages[0].device, 0);
    }

    #[test]
    fn fast_cloud_link_used_with_two_devices() {
        // At 50 Mbps the optimal 2-device plan offloads to the 3090.
        let c = presets::paper_testbed(50.0, 0);
        let t = profile(&llama2_7b(), &c);
        let p = LatencyDp::restricted(vec![0, 14]).plan(&t, &c).unwrap();
        assert!(p.devices().contains(&14), "{}", p.describe());
    }

    #[test]
    fn first_layer_always_on_source() {
        for bw in [1.0, 10.0, 50.0] {
            let c = presets::paper_testbed(bw, 0);
            let t = profile(&llama2_7b(), &c);
            let p = LatencyDp::new().plan(&t, &c).unwrap();
            assert_eq!(p.stages[0].device, c.source);
        }
    }

    #[test]
    fn oom_when_model_exceeds_cluster() {
        // 70B fp32 (280 GB) on just the source AGX — OOM.
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_70b(), &c);
        let err = LatencyDp::restricted(vec![0]).plan(&t, &c).unwrap_err();
        assert_eq!(err, PlanError::Oom);
    }

    #[test]
    fn seventy_b_feasible_across_cluster() {
        // Only EdgeShard can host 70B (Table IV).
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_70b(), &c);
        let p = LatencyDp::new().plan(&t, &c).unwrap();
        validate_plan(&p, &t, &c, 1).unwrap();
        assert!(p.n_stages() >= 10, "70B needs many devices: {}", p.describe());
    }

    #[test]
    fn thirteen_b_oom_on_solo_but_plannable() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_13b(), &c);
        assert_eq!(
            LatencyDp::restricted(vec![0]).plan(&t, &c).unwrap_err(),
            PlanError::Oom
        );
        let p = LatencyDp::new().plan(&t, &c).unwrap();
        validate_plan(&p, &t, &c, 1).unwrap();
    }

    #[test]
    fn better_bandwidth_never_hurts() {
        let mut last = f64::INFINITY;
        for bw in [1.0, 5.0, 10.0, 25.0, 50.0] {
            let c = presets::paper_testbed(bw, 0);
            let t = profile(&llama2_7b(), &c);
            let p = LatencyDp::new().plan(&t, &c).unwrap();
            assert!(
                p.predicted_ms <= last * 1.02,
                "bw={bw}: {} > prev {last}",
                p.predicted_ms
            );
            last = p.predicted_ms;
        }
    }

    #[test]
    fn stages_from_assignment_collapses_runs() {
        let stages = stages_from_assignment(&[0, 0, 3, 3, 3, 1]);
        assert_eq!(stages.len(), 3);
        assert_eq!(stages[1], Stage { device: 3, start: 2, end: 5 });
    }

    #[test]
    fn greedy_variant_can_be_suboptimal() {
        // The paper's literal greedy memory update forces an extra hop at
        // 10 Mbps on the 2-device topology; the Pareto fix does not.
        let mut c = presets::cloud_edge_pair(10.0);
        c.set_latency(0, 1, 2.0);
        let t = profile(&llama2_7b(), &c);
        let pool = vec![0, 1];
        let greedy = algo1_greedy(&t, &c, &pool, 1).unwrap();
        let pareto = algo1(&t, &c, &pool, 1).unwrap();
        assert!(pareto.predicted_ms <= greedy.predicted_ms + 1e-9);
        // both remain feasible
        validate_plan(&pareto, &t, &c, 1).unwrap();
        validate_plan(&greedy, &t, &c, 1).unwrap();
    }

    #[test]
    fn pareto_insert_respects_dominance() {
        let mk = |cost: f64, mem: u64| State {
            cost,
            prev: 0,
            prev_slot: 0,
            mem_used: vec![mem],
        };
        let mut f = Vec::new();
        pareto_insert(&mut f, mk(10.0, 100), 8);
        // dominated: worse cost AND worse memory
        pareto_insert(&mut f, mk(11.0, 200), 8);
        assert_eq!(f.len(), 1);
        // incomparable: worse cost, better memory
        pareto_insert(&mut f, mk(11.0, 50), 8);
        assert_eq!(f.len(), 2);
        // dominates everything
        pareto_insert(&mut f, mk(1.0, 10), 8);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].cost, 1.0);
    }

    #[test]
    fn pool_without_source_rejected() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        assert!(matches!(
            LatencyDp::restricted(vec![3, 14]).plan(&t, &c),
            Err(PlanError::Infeasible(_))
        ));
    }
}
