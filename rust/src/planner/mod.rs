//! Joint device selection + model partition (paper §IV).
//!
//! * [`latency::algo1`] — the paper's Algorithm 1: `DP(i,j)` over
//!   (layer, device), O(N·M²), minimizing end-to-end per-token latency
//!   with the privacy constraint (layer 0 on the source node) and memory
//!   budgets (Eqs. 3–8).
//! * [`throughput::algo2_exact`] — the paper's Algorithm 2: `g(m, S, j)` over
//!   (boundary, used-device-set, last device), minimizing the slowest
//!   pipeline stage (Eqs. 9–13).  Exponential in device count as written
//!   (O(N²·2^M·M²)), so [`throughput::algo2_classes`] adds **device-class
//!   compression**: identical devices are interchangeable, collapsing the
//!   subset state to per-class usage counts — exact for clusters made of
//!   repeated hardware classes (the paper's 12+2+1 testbed) and fast
//!   enough for 80-layer models.
//! * [`baselines`] — Edge-Solo, Cloud-Edge-Even, Cloud-Edge-Opt, and
//!   EdgeShard-Even (§V.A / §V.C).

pub mod baselines;
pub mod latency;
pub mod replicas;
pub mod throughput;

pub use baselines::{CloudEdgeEven, CloudEdgeOpt, EdgeShardEven, EdgeSolo};
pub use latency::LatencyDp;
pub use replicas::{ReplicaPlan, ReplicaPlanner};
pub use throughput::ThroughputDp;

use crate::cluster::Cluster;
use crate::profiler::ProfiledTraces;

/// What the planner optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanObjective {
    /// Minimize sequential per-token latency (Algorithm 1).
    Latency,
    /// Minimize the slowest pipeline stage (Algorithm 2).
    Throughput,
}

/// A contiguous run of layers assigned to one device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Stage {
    pub device: usize,
    /// Layer indices `[start, end)`.
    pub start: usize,
    pub end: usize,
}

impl Stage {
    pub fn layers(&self) -> std::ops::Range<usize> {
        self.start..self.end
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }
}

/// A complete partition + allocation strategy.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub objective: PlanObjective,
    pub stages: Vec<Stage>,
    /// Objective value predicted by the DP: ms/token (latency) or
    /// bottleneck stage ms (throughput).
    pub predicted_ms: f64,
}

impl Plan {
    /// Device hosting layer `i`.
    pub fn device_of_layer(&self, i: usize) -> Option<usize> {
        self.stages
            .iter()
            .find(|s| s.layers().contains(&i))
            .map(|s| s.device)
    }

    /// Distinct devices used, in stage order.
    pub fn devices(&self) -> Vec<usize> {
        self.stages.iter().map(|s| s.device).collect()
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Human-readable strategy string, e.g. `[0:0..5 → 3:5..20 → 14:20..34]`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .stages
            .iter()
            .map(|s| format!("d{}:{}..{}", s.device, s.start, s.end))
            .collect();
        format!("[{}]", parts.join(" → "))
    }
}

/// Why planning failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanError {
    /// No allocation satisfies the memory budgets (Table IV "OOM").
    Oom,
    /// Structural problem (empty cluster, zero layers, bad restriction).
    Infeasible(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Oom => write!(f, "out of memory: no feasible allocation"),
            PlanError::Infeasible(s) => write!(f, "infeasible: {s}"),
        }
    }
}

impl std::error::Error for PlanError {}

/// Common planner interface (latency DP, throughput DP, and every baseline
/// implement this).
pub trait Planner {
    fn plan(&self, traces: &ProfiledTraces, cluster: &Cluster) -> Result<Plan, PlanError>;
    fn name(&self) -> &'static str;
}

/// Validate the structural invariants every legal plan must satisfy;
/// returns a violation description.  Used by tests and by proptest.
pub fn validate_plan(
    plan: &Plan,
    traces: &ProfiledTraces,
    cluster: &Cluster,
    batch: usize,
) -> Result<(), String> {
    if plan.stages.is_empty() {
        return Err("empty plan".into());
    }
    // 1. full contiguous coverage
    let mut next = 0;
    for s in &plan.stages {
        if s.start != next {
            return Err(format!("gap/overlap at layer {next}: {}", plan.describe()));
        }
        if s.is_empty() {
            return Err("empty stage".into());
        }
        next = s.end;
    }
    if next != traces.n_layers {
        return Err(format!("covers {next}/{} layers", traces.n_layers));
    }
    // 2. privacy: first layer on the source node (Eq. 4)
    if plan.stages[0].device != cluster.source {
        return Err(format!(
            "privacy violation: first stage on d{}, source is d{}",
            plan.stages[0].device, cluster.source
        ));
    }
    // 3. memory budgets (Eq. 5) — aggregate per device across stages
    let mut used = vec![0u64; cluster.len()];
    for s in &plan.stages {
        used[s.device] += traces.range_mem_bytes(s.start, s.end, batch);
    }
    for (d, u) in used.iter().enumerate() {
        if *u > cluster.devices[d].usable_mem_bytes {
            return Err(format!(
                "device {d} over budget: {} > {}",
                u, cluster.devices[d].usable_mem_bytes
            ));
        }
    }
    Ok(())
}

/// Evaluate the *sequential-inference* per-token latency of a plan
/// (Eq. 2 + the loopback term): Σ stage compute + Σ boundary comms + the
/// generated-token transmission back to the source.
pub fn sequential_latency_ms(plan: &Plan, traces: &ProfiledTraces, cluster: &Cluster) -> f64 {
    let mut total = 0.0;
    let mut prev: Option<usize> = None;
    for s in &plan.stages {
        if let Some(k) = prev {
            total += cluster.comm_ms(k, s.device, traces.act_bytes_avg[s.start - 1]);
        }
        total += traces.range_avg_ms(s.start, s.end, s.device);
        prev = Some(s.device);
    }
    let last = plan.stages.last().unwrap();
    total += cluster.comm_ms(
        last.device,
        cluster.source,
        traces.act_bytes_avg[traces.n_layers - 1],
    );
    total
}

/// Evaluate the pipeline bottleneck (Eq. 9/10): the slowest of every
/// stage's `max(compute, incoming-comm)`.
pub fn pipeline_bottleneck_ms(plan: &Plan, traces: &ProfiledTraces, cluster: &Cluster) -> f64 {
    let mut worst: f64 = 0.0;
    let mut prev: Option<usize> = None;
    for s in &plan.stages {
        let comp = traces.range_avg_ms(s.start, s.end, s.device);
        let comm = match prev {
            Some(k) => cluster.comm_ms(k, s.device, traces.act_bytes_avg[s.start - 1]),
            None => 0.0,
        };
        worst = worst.max(comp.max(comm));
        prev = Some(s.device);
    }
    // loopback of the generated token to the source also occupies a slot
    let last = plan.stages.last().unwrap();
    worst.max(cluster.comm_ms(
        last.device,
        cluster.source,
        traces.act_bytes_avg[traces.n_layers - 1],
    ))
}

/// Largest batch size every stage of `plan` can hold in memory.
pub fn max_feasible_batch(plan: &Plan, traces: &ProfiledTraces, cluster: &Cluster) -> usize {
    let mut best = usize::MAX;
    for s in &plan.stages {
        let mem = cluster.devices[s.device].usable_mem_bytes;
        let b = traces.max_batch_for(s.start, s.end, mem);
        best = best.min(b);
    }
    if best == usize::MAX {
        1
    } else {
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::llama2_7b;
    use crate::profiler::{AnalyticProfiler, Workload};

    fn setup() -> (ProfiledTraces, Cluster) {
        let cluster = presets::paper_testbed(1.0, 0);
        let traces = AnalyticProfiler::default().profile(
            &llama2_7b(),
            &cluster,
            Workload::paper_default(),
        );
        (traces, cluster)
    }

    fn solo_plan(n: usize) -> Plan {
        Plan {
            objective: PlanObjective::Latency,
            stages: vec![Stage {
                device: 0,
                start: 0,
                end: n,
            }],
            predicted_ms: 0.0,
        }
    }

    #[test]
    fn validate_accepts_solo() {
        let (t, c) = setup();
        assert!(validate_plan(&solo_plan(t.n_layers), &t, &c, 1).is_ok());
    }

    #[test]
    fn validate_rejects_gap() {
        let (t, c) = setup();
        let p = Plan {
            objective: PlanObjective::Latency,
            stages: vec![
                Stage { device: 0, start: 0, end: 5 },
                Stage { device: 1, start: 6, end: t.n_layers },
            ],
            predicted_ms: 0.0,
        };
        assert!(validate_plan(&p, &t, &c, 1).is_err());
    }

    #[test]
    fn validate_rejects_privacy_violation() {
        let (t, c) = setup();
        let p = Plan {
            objective: PlanObjective::Latency,
            stages: vec![Stage { device: 3, start: 0, end: t.n_layers }],
            predicted_ms: 0.0,
        };
        let err = validate_plan(&p, &t, &c, 1).unwrap_err();
        assert!(err.contains("privacy"));
    }

    #[test]
    fn validate_rejects_oom_on_small_device() {
        let (t, c) = setup();
        // all of 7B on the Orin NX (14GB usable) — must fail
        let p = Plan {
            objective: PlanObjective::Latency,
            stages: vec![
                Stage { device: 0, start: 0, end: 1 },
                Stage { device: 12, start: 1, end: t.n_layers },
            ],
            predicted_ms: 0.0,
        };
        let err = validate_plan(&p, &t, &c, 1).unwrap_err();
        assert!(err.contains("over budget"), "{err}");
    }

    #[test]
    fn sequential_latency_includes_loopback() {
        let (t, mut c) = setup();
        let p = Plan {
            objective: PlanObjective::Latency,
            stages: vec![
                Stage { device: 0, start: 0, end: 10 },
                Stage { device: 1, start: 10, end: t.n_layers },
            ],
            predicted_ms: 0.0,
        };
        let base = sequential_latency_ms(&p, &t, &c);
        // slow the return path: device1 -> source
        c.set_latency(1, 0, 50.0);
        let slow = sequential_latency_ms(&p, &t, &c);
        assert!(slow > base + 40.0, "base={base} slow={slow}");
    }

    #[test]
    fn bottleneck_is_max_not_sum() {
        let (t, c) = setup();
        let p = Plan {
            objective: PlanObjective::Throughput,
            stages: vec![
                Stage { device: 0, start: 0, end: 17 },
                Stage { device: 1, start: 17, end: t.n_layers },
            ],
            predicted_ms: 0.0,
        };
        let b = pipeline_bottleneck_ms(&p, &t, &c);
        let s = sequential_latency_ms(&p, &t, &c);
        assert!(b < s);
        assert!(b >= t.range_avg_ms(0, 17, 0).min(t.range_avg_ms(17, t.n_layers, 1)));
    }

    #[test]
    fn max_batch_decreases_with_more_layers_per_device() {
        let (t, c) = setup();
        let solo = solo_plan(t.n_layers);
        let split = Plan {
            objective: PlanObjective::Throughput,
            stages: vec![
                Stage { device: 0, start: 0, end: 17 },
                Stage { device: 1, start: 17, end: t.n_layers },
            ],
            predicted_ms: 0.0,
        };
        assert!(max_feasible_batch(&split, &t, &c) >= max_feasible_batch(&solo, &t, &c));
    }

    #[test]
    fn plan_describe_and_device_of_layer() {
        let p = Plan {
            objective: PlanObjective::Latency,
            stages: vec![
                Stage { device: 0, start: 0, end: 5 },
                Stage { device: 14, start: 5, end: 34 },
            ],
            predicted_ms: 1.0,
        };
        assert_eq!(p.device_of_layer(0), Some(0));
        assert_eq!(p.device_of_layer(5), Some(14));
        assert_eq!(p.device_of_layer(33), Some(14));
        assert_eq!(p.device_of_layer(34), None);
        assert!(p.describe().contains("d14:5..34"));
    }
}
