//! Algorithm 2 — joint device selection + partition for **throughput**
//! (pipeline parallelism), plus an exact latency variant used to
//! cross-validate Algorithm 1.
//!
//! The paper's DP (Eq. 11):
//!
//! ```text
//! g(m, S∪{j}, j) = min over i<m, k∈S of  max( g(i,S,k),
//!                                             t_comm(i-1,k,j),
//!                                             t_comp(i→m, j) )
//! ```
//!
//! i.e. stages are contiguous layer ranges, each on a fresh device, and the
//! objective is the slowest stage (compute or incoming link).  As written
//! this is O(N²·2^M·M²) — hopeless for the 15-device testbed.  We exploit
//! that the testbed is built from repeated *hardware classes* (12× AGX
//! Orin, 2× Orin NX, 1× RTX 3090): devices of one class are
//! interchangeable, so the subset `S` collapses to a **usage count per
//! class** (the source node is always split into its own singleton class —
//! it is special by the privacy constraint and by its shaped cloud link).
//! The compressed DP is exact for class-uniform link tables; with the
//! paper's ±20% jitter we plan on class-mean links (what profiling-stage
//! averaging produces) and evaluate plans on the true links.
//!
//! [`algo2_exact`] keeps the faithful exponential subset DP for small
//! device pools (used by Cloud-Edge-Opt, the tiny demo cluster, and the
//! equivalence tests against the compressed DP).

use super::{Plan, PlanError, PlanObjective, Planner, Stage};
use crate::cluster::Cluster;
use crate::profiler::ProfiledTraces;

/// Aggregation of per-stage costs into the plan objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Pipeline bottleneck (Algorithm 2): minimize the max stage cost.
    MaxStage,
    /// Sequential latency (exact Algorithm 1 cross-check): minimize the
    /// sum of stage costs.
    SumStages,
}

/// A group of interchangeable devices (one hardware class).
#[derive(Debug, Clone)]
pub struct Group {
    /// Concrete device ids, in allocation order.
    pub members: Vec<usize>,
}

/// Partition a device pool into groups: the source alone, then one group
/// per (class name, usable memory) pair.
pub fn groups_for(cluster: &Cluster, pool: &[usize]) -> Vec<Group> {
    let mut groups: Vec<Group> = Vec::new();
    let mut keyed: Vec<(String, Vec<usize>)> = Vec::new();
    for &d in pool {
        if d == cluster.source {
            continue;
        }
        let dev = &cluster.devices[d];
        let key = format!("{}/{}", dev.class.name, dev.usable_mem_bytes);
        match keyed.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => v.push(d),
            None => keyed.push((key, vec![d])),
        }
    }
    if pool.contains(&cluster.source) {
        groups.push(Group {
            members: vec![cluster.source],
        });
    }
    groups.extend(keyed.into_iter().map(|(_, members)| Group { members }));
    groups
}

/// One group per concrete device — turns the compressed DP into the
/// faithful exponential Algorithm 2.
pub fn singleton_groups(pool: &[usize]) -> Vec<Group> {
    pool.iter().map(|&d| Group { members: vec![d] }).collect()
}

/// Per-byte transfer cost + fixed latency between two groups
/// (class-mean over concrete pairs; used by the compressed DP).
fn group_comm_params(cluster: &Cluster, ga: &Group, gb: &Group) -> (f64, f64) {
    let mut per_byte = 0.0;
    let mut lat = 0.0;
    let mut n = 0.0;
    for &a in &ga.members {
        for &b in &gb.members {
            if a == b {
                continue;
            }
            per_byte += 8.0 / (cluster.bandwidth_mbps[a][b] * 1e6) * 1e3;
            lat += cluster.latency_ms[a][b];
            n += 1.0;
        }
    }
    if n == 0.0 {
        // single-member self pair: same device, free
        (0.0, 0.0)
    } else {
        (per_byte / n, lat / n)
    }
}

struct Choice {
    prev_boundary: u32,
    prev_group: u32,
    prev_usage: u32,
}

/// Generic grouped segment DP.  Returns the optimal plan under `objective`.
///
/// State: (boundary m = layers assigned so far, usage count per group,
/// last group).  Each stage consumes one fresh instance from its group.
pub fn algo2_groups(
    traces: &ProfiledTraces,
    cluster: &Cluster,
    groups: &[Group],
    objective: Objective,
    batch: usize,
) -> Result<Plan, PlanError> {
    let n = traces.n_layers;
    let g_count = groups.len();
    if n == 0 || g_count == 0 {
        return Err(PlanError::Infeasible("empty problem".into()));
    }
    let src_group = groups
        .iter()
        .position(|g| g.members.contains(&cluster.source))
        .ok_or_else(|| PlanError::Infeasible("pool must contain source".into()))?;

    // --- precomputation -------------------------------------------------
    // prefix sums of per-layer compute per group representative
    let rep: Vec<usize> = groups.iter().map(|g| g.members[0]).collect();
    let mut comp_prefix = vec![vec![0.0f64; n + 1]; g_count];
    for (gi, &r) in rep.iter().enumerate() {
        for i in 0..n {
            comp_prefix[gi][i + 1] = comp_prefix[gi][i] + traces.avg_ms[i][r];
        }
    }
    // prefix sums of per-layer memory (weights + batch·KV)
    let mut mem_prefix = vec![0u64; n + 1];
    for i in 0..n {
        mem_prefix[i + 1] = mem_prefix[i] + traces.range_mem_bytes(i, i + 1, batch);
    }
    let budget: Vec<u64> = rep
        .iter()
        .map(|&r| cluster.devices[r].usable_mem_bytes)
        .collect();
    // pairwise group comm params
    let comm: Vec<Vec<(f64, f64)>> = (0..g_count)
        .map(|a| {
            (0..g_count)
                .map(|b| group_comm_params(cluster, &groups[a], &groups[b]))
                .collect()
        })
        .collect();
    let comm_ms = |ga: usize, gb: usize, bytes: u64| -> f64 {
        let (pb, lat) = comm[ga][gb];
        pb * bytes as f64 + lat
    };

    // usage-count mixed-radix encoding
    let caps: Vec<u32> = groups.iter().map(|g| g.members.len() as u32).collect();
    let mut stride = vec![1u32; g_count];
    for gi in 1..g_count {
        stride[gi] = stride[gi - 1] * (caps[gi - 1] + 1);
    }
    let usage_space = (stride[g_count - 1] * (caps[g_count - 1] + 1)) as usize;
    let used_of = |usage: u32, gi: usize| (usage / stride[gi]) % (caps[gi] + 1);

    let state_count = (n + 1) * usage_space * g_count;
    if state_count > 200_000_000 {
        return Err(PlanError::Infeasible(format!(
            "state space too large: {state_count}"
        )));
    }
    let idx = |m: usize, usage: u32, g: usize| (m * usage_space + usage as usize) * g_count + g;
    let mut cost = vec![f64::INFINITY; state_count];
    let mut choice: Vec<Option<Choice>> = (0..state_count).map(|_| None).collect();

    // --- init: first stage [0, m) on the source (privacy, Eq. 13) -------
    let usage0 = stride[src_group];
    for m in 1..=n {
        if mem_prefix[m] > budget[src_group] {
            break;
        }
        let c = comp_prefix[src_group][m] - comp_prefix[src_group][0];
        let v = match objective {
            Objective::MaxStage => c,
            Objective::SumStages => c,
        };
        let id = idx(m, usage0, src_group);
        if v < cost[id] {
            cost[id] = v;
            choice[id] = Some(Choice {
                prev_boundary: 0,
                prev_group: u32::MAX,
                prev_usage: 0,
            });
        }
    }

    // --- transitions -----------------------------------------------------
    for i in 1..n {
        for usage in 0..usage_space as u32 {
            for ga in 0..g_count {
                let cur = cost[idx(i, usage, ga)];
                if !cur.is_finite() {
                    continue;
                }
                for gb in 0..g_count {
                    if used_of(usage, gb) >= caps[gb] {
                        continue;
                    }
                    let usage2 = usage + stride[gb];
                    let t_comm = comm_ms(ga, gb, traces.act_bytes_avg[i - 1]);
                    for m in (i + 1)..=n {
                        let mem = mem_prefix[m] - mem_prefix[i];
                        if mem > budget[gb] {
                            break;
                        }
                        let t_comp = comp_prefix[gb][m] - comp_prefix[gb][i];
                        let v = match objective {
                            Objective::MaxStage => cur.max(t_comm).max(t_comp),
                            Objective::SumStages => cur + t_comm + t_comp,
                        };
                        let id = idx(m, usage2, gb);
                        if v < cost[id] {
                            cost[id] = v;
                            choice[id] = Some(Choice {
                                prev_boundary: i as u32,
                                prev_group: ga as u32,
                                prev_usage: usage,
                            });
                        }
                    }
                }
            }
        }
    }

    // --- final sweep: add the token loopback to the source ---------------
    let loop_bytes = traces.act_bytes_avg[n - 1];
    let mut best: Option<(f64, u32, usize)> = None;
    for usage in 0..usage_space as u32 {
        for g in 0..g_count {
            let c = cost[idx(n, usage, g)];
            if !c.is_finite() {
                continue;
            }
            let lb = comm_ms(g, src_group, loop_bytes);
            let v = match objective {
                Objective::MaxStage => c.max(lb),
                Objective::SumStages => c + lb,
            };
            if best.map_or(true, |(bc, _, _)| v < bc) {
                best = Some((v, usage, g));
            }
        }
    }
    let (best_cost, mut usage, mut g) = best.ok_or(PlanError::Oom)?;

    // --- backtrace into stages -------------------------------------------
    let mut bounds: Vec<(usize, usize)> = Vec::new(); // (boundary, group)
    let mut m = n;
    loop {
        let ch = choice[idx(m, usage, g)]
            .as_ref()
            .expect("broken choice chain");
        bounds.push((m, g));
        if ch.prev_group == u32::MAX {
            break;
        }
        m = ch.prev_boundary as usize;
        let (pu, pg) = (ch.prev_usage, ch.prev_group as usize);
        usage = pu;
        g = pg;
    }
    bounds.reverse();

    // materialize concrete devices: per group, hand out instances in order
    let mut next_instance = vec![0usize; g_count];
    let mut stages = Vec::with_capacity(bounds.len());
    let mut start = 0usize;
    for (end, gi) in bounds {
        let dev = groups[gi].members[next_instance[gi]];
        next_instance[gi] += 1;
        stages.push(Stage {
            device: dev,
            start,
            end,
        });
        start = end;
    }

    Ok(Plan {
        objective: match objective {
            Objective::MaxStage => PlanObjective::Throughput,
            Objective::SumStages => PlanObjective::Latency,
        },
        stages,
        predicted_ms: best_cost,
    })
}

/// Faithful Algorithm 2 (exponential subset DP) — every device its own
/// group.  Only for small pools.
pub fn algo2_exact(
    traces: &ProfiledTraces,
    cluster: &Cluster,
    pool: &[usize],
    batch: usize,
) -> Result<Plan, PlanError> {
    algo2_groups(
        traces,
        cluster,
        &singleton_groups(pool),
        Objective::MaxStage,
        batch,
    )
}

/// Class-compressed Algorithm 2 — the production path for the testbed.
pub fn algo2_classes(
    traces: &ProfiledTraces,
    cluster: &Cluster,
    pool: &[usize],
    batch: usize,
) -> Result<Plan, PlanError> {
    algo2_groups(
        traces,
        cluster,
        &groups_for(cluster, pool),
        Objective::MaxStage,
        batch,
    )
}

/// Exact minimum *sequential latency* over device subsets — the oracle
/// Algorithm 1 is validated against.
pub fn exact_latency(
    traces: &ProfiledTraces,
    cluster: &Cluster,
    pool: &[usize],
    batch: usize,
) -> Result<Plan, PlanError> {
    algo2_groups(
        traces,
        cluster,
        &singleton_groups(pool),
        Objective::SumStages,
        batch,
    )
}

/// Throughput planner implementing [`Planner`].
#[derive(Debug, Clone, Default)]
pub struct ThroughputDp {
    pub restrict: Option<Vec<usize>>,
    pub batch: usize,
    /// Force the exponential exact DP regardless of pool size.
    pub exact: bool,
}

impl ThroughputDp {
    pub fn new() -> Self {
        ThroughputDp {
            restrict: None,
            batch: 1,
            exact: false,
        }
    }

    pub fn restricted(devices: Vec<usize>) -> Self {
        ThroughputDp {
            restrict: Some(devices),
            batch: 1,
            exact: false,
        }
    }
}

impl Planner for ThroughputDp {
    fn name(&self) -> &'static str {
        "EdgeShard-Throughput(Algo2)"
    }

    fn plan(&self, traces: &ProfiledTraces, cluster: &Cluster) -> Result<Plan, PlanError> {
        let pool: Vec<usize> = match &self.restrict {
            Some(v) => v.clone(),
            None => (0..cluster.len()).collect(),
        };
        let batch = self.batch.max(1);
        if self.exact || pool.len() <= 8 {
            algo2_exact(traces, cluster, &pool, batch)
        } else {
            algo2_classes(traces, cluster, &pool, batch)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::{llama2_13b, llama2_70b, llama2_7b};
    use crate::planner::latency::algo1;
    use crate::planner::{pipeline_bottleneck_ms, validate_plan};
    use crate::profiler::{AnalyticProfiler, Workload};

    fn profile(model: &crate::model::ModelDesc, cluster: &Cluster) -> ProfiledTraces {
        AnalyticProfiler::default().profile(model, cluster, Workload::paper_default())
    }

    #[test]
    fn plan_valid_and_matches_evaluator_7b() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        let p = algo2_classes(&t, &c, &(0..15).collect::<Vec<_>>(), 1).unwrap();
        validate_plan(&p, &t, &c, 1).unwrap();
        // evaluator on true (jittered) links vs DP on class means: close
        let eval = pipeline_bottleneck_ms(&p, &t, &c);
        assert!(
            (p.predicted_ms - eval).abs() / eval < 0.35,
            "dp={} eval={eval}",
            p.predicted_ms
        );
    }

    #[test]
    fn exact_equals_classes_on_uniform_links() {
        // With zero jitter all class members are identical, so the
        // compressed DP must equal the faithful subset DP.
        let mut devices = Vec::new();
        for i in 0..4 {
            devices.push(crate::cluster::Device::new(i, crate::cluster::DeviceClass::agx_orin()));
        }
        devices.push(crate::cluster::Device::new(4, crate::cluster::DeviceClass::rtx3090()));
        let mut c = Cluster::new(devices, 50.0, 0.5);
        c.set_bandwidth(0, 4, 1.0);
        let t = profile(&llama2_7b(), &c);
        let pool: Vec<usize> = (0..5).collect();
        let exact = algo2_exact(&t, &c, &pool, 1).unwrap();
        let classes = algo2_classes(&t, &c, &pool, 1).unwrap();
        assert!(
            (exact.predicted_ms - classes.predicted_ms).abs() < 1e-6,
            "exact={} classes={}",
            exact.predicted_ms,
            classes.predicted_ms
        );
    }

    #[test]
    fn throughput_bottleneck_below_sequential_latency() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        let tp = algo2_classes(&t, &c, &(0..15).collect::<Vec<_>>(), 1).unwrap();
        let lat = algo1(&t, &c, &(0..15).collect::<Vec<_>>(), 1).unwrap();
        assert!(tp.predicted_ms <= lat.predicted_ms + 1e-9);
    }

    #[test]
    fn algo1_close_to_exact_latency_oracle() {
        // Algorithm 1's greedy memory handling should match the exact
        // subset DP on a small pool.
        let mut c = presets::cloud_edge_pair(10.0);
        c.set_latency(0, 1, 2.0);
        let t = profile(&llama2_7b(), &c);
        let pool = vec![0, 1];
        let a1 = algo1(&t, &c, &pool, 1).unwrap();
        let oracle = exact_latency(&t, &c, &pool, 1).unwrap();
        assert!(
            (a1.predicted_ms - oracle.predicted_ms).abs() / oracle.predicted_ms < 0.01,
            "algo1={} oracle={}",
            a1.predicted_ms,
            oracle.predicted_ms
        );
    }

    #[test]
    fn seventy_b_only_feasible_with_full_cluster() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_70b(), &c);
        assert!(algo2_exact(&t, &c, &[0, 14], 1).is_err());
        let p = ThroughputDp::new().plan(&t, &c).unwrap();
        validate_plan(&p, &t, &c, 1).unwrap();
    }

    #[test]
    fn memory_constraint_respected_at_batch_8() {
        let c = presets::paper_testbed(10.0, 0);
        let model = llama2_13b();
        let t = AnalyticProfiler::default().profile(
            &model,
            &c,
            Workload::paper_default().with_batch(8),
        );
        let mut dp = ThroughputDp::new();
        dp.batch = 8;
        let p = dp.plan(&t, &c).unwrap();
        validate_plan(&p, &t, &c, 8).unwrap();
    }

    #[test]
    fn higher_bandwidth_not_worse() {
        let mut last = f64::INFINITY;
        for bw in [1.0, 10.0, 50.0] {
            let c = presets::paper_testbed(bw, 0);
            let t = profile(&llama2_7b(), &c);
            let p = ThroughputDp::new().plan(&t, &c).unwrap();
            assert!(p.predicted_ms <= last * 1.05, "bw={bw}");
            last = p.predicted_ms;
        }
    }

    #[test]
    fn stages_use_distinct_devices() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_70b(), &c);
        let p = ThroughputDp::new().plan(&t, &c).unwrap();
        let mut devs = p.devices();
        let n = devs.len();
        devs.sort_unstable();
        devs.dedup();
        assert_eq!(devs.len(), n, "devices must be used once: {}", p.describe());
    }

    #[test]
    fn first_stage_on_source() {
        let c = presets::paper_testbed(1.0, 0);
        for model in [llama2_7b(), llama2_13b()] {
            let t = profile(&model, &c);
            let p = ThroughputDp::new().plan(&t, &c).unwrap();
            assert_eq!(p.stages[0].device, c.source);
        }
    }

    #[test]
    fn exact_rejects_missing_source() {
        let c = presets::paper_testbed(1.0, 0);
        let t = profile(&llama2_7b(), &c);
        assert!(matches!(
            algo2_exact(&t, &c, &[1, 2], 1),
            Err(PlanError::Infeasible(_))
        ));
    }

    #[test]
    fn groups_partition_testbed() {
        let c = presets::paper_testbed(1.0, 0);
        let pool: Vec<usize> = (0..15).collect();
        let g = groups_for(&c, &pool);
        // source, 11 other AGX, 2 NX, 1 cloud
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].members, vec![0]);
        let sizes: Vec<usize> = g.iter().map(|x| x.members.len()).collect();
        assert_eq!(sizes, vec![1, 11, 2, 1]);
    }
}
