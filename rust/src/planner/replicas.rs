//! Replica-aware joint planning: how many parallel pipelines, over which
//! devices, with which partition each.
//!
//! The paper's Algorithm 2 maximizes the throughput of a *single*
//! pipeline over the device pool.  With a big pool the optimal serving
//! configuration is often **K parallel pipeline replicas**, each with its
//! own device subset and partition, behind one front-door router (see
//! [`crate::coordinator::router`]): depth stops paying once the
//! per-boundary communication floor dominates, while replicas multiply
//! aggregate tokens/s almost linearly.
//!
//! [`ReplicaPlanner`] solves the joint problem by reusing the existing
//! throughput DP as the inner solve:
//!
//! 1. every replica's pool **shares the source device** — the privacy
//!    constraint (Eq. 4) pins the embedding layer where prompts arrive,
//!    so each replica's first stage lives on the source and the
//!    remaining devices are partitioned **disjointly** across replicas.
//!    For K ≥ 2 the source is kept *thin*: layers past the pinned
//!    prefix are priced out on it (the source's compute is shared by
//!    every replica, so piling model layers onto it would let K fake
//!    pipelines time-share one physical device);
//! 2. for each candidate replica count K, the non-source pool is split
//!    by two deterministic strategies (class-balanced round-robin and
//!    contiguous blocks over the class-sorted device list), each subset
//!    is solved with [`algo2_exact`] / [`algo2_classes`], and a bounded
//!    local search migrates single devices from the fastest replica to
//!    the slowest while that improves the aggregate;
//! 3. candidates are scored by **aggregate tokens/s** with the source
//!    modeled as a shared serial server: replica `i` consumes
//!    `src_ms[i]` of source time per token, so admissible rates satisfy
//!    `Σ rate_i · src_ms[i] ≤ 1000 ms/s` — a waterfill over that budget
//!    (cheapest source users first) yields the score.  The source's
//!    memory is likewise charged once across *all* replica front
//!    stages;
//! 4. K = 1 runs the unmodified single-pipeline DP, so the degenerate
//!    case reproduces [`crate::planner::ThroughputDp`] exactly and
//!    existing plans are unchanged.

use super::throughput::{algo2_classes, algo2_exact};
use super::{pipeline_bottleneck_ms, Plan, PlanError};
use crate::cluster::Cluster;
use crate::profiler::ProfiledTraces;

/// Per-layer cost planted on the source for layers past the pinned
/// prefix when K ≥ 2 — high enough that the inner DP only places them
/// there when memory leaves no alternative (and the candidate then
/// scores ≈ 0, losing to smaller K).
const THIN_SOURCE_PENALTY_MS: f64 = 1e12;

/// A joint replica configuration: K per-replica plans over disjoint
/// device subsets (plus the shared source), scored by aggregate
/// throughput.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaPlan {
    /// One plan per replica; every plan's first stage is on the source.
    pub replicas: Vec<Plan>,
    /// Pipeline bottleneck per replica, ms/token (its solo rate).
    pub per_replica_ms: Vec<f64>,
    /// Source time consumed per token of each replica, ms — the shared
    /// front-door work (embedding stage and any other source-resident
    /// layers).
    pub source_ms: Vec<f64>,
    /// Predicted aggregate throughput, tokens/s, after waterfilling the
    /// shared source budget.
    pub predicted_tps: f64,
}

impl ReplicaPlan {
    /// Replica count K.
    pub fn k(&self) -> usize {
        self.replicas.len()
    }

    /// Human-readable strategy, e.g.
    /// `K=2: [d0:0..5 → d3:5..34] | [d0:0..2 → d7:2..34]`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self.replicas.iter().map(|p| p.describe()).collect();
        format!("K={}: {}", self.k(), parts.join(" | "))
    }
}

/// Joint replica-count / device-partition / layer-partition solver.
#[derive(Debug, Clone)]
pub struct ReplicaPlanner {
    /// Upper bound on the replica count explored.
    pub max_replicas: usize,
    /// Batch size the inner throughput DP sizes memory for.
    pub batch: usize,
    /// Local-search budget: single-device migrations tried per candidate.
    pub refine_moves: usize,
}

impl Default for ReplicaPlanner {
    fn default() -> Self {
        ReplicaPlanner {
            max_replicas: 4,
            batch: 1,
            refine_moves: 4,
        }
    }
}

/// One replica pool's inner solve — the same exact/class-compressed
/// switch as [`crate::planner::ThroughputDp`], so K = 1 reproduces the
/// single-pipeline planner bit for bit.
fn inner_solve(
    traces: &ProfiledTraces,
    cluster: &Cluster,
    pool: &[usize],
    batch: usize,
) -> Result<Plan, PlanError> {
    if pool.len() <= 8 {
        algo2_exact(traces, cluster, pool, batch)
    } else {
        algo2_classes(traces, cluster, pool, batch)
    }
}

/// Traces where every layer past the pinned prefix is priced out on the
/// source (memory footprints untouched, so feasibility is unchanged).
fn thin_source_traces(traces: &ProfiledTraces, source: usize) -> ProfiledTraces {
    let mut t = traces.clone();
    for i in 1..t.n_layers {
        t.avg_ms[i][source] = THIN_SOURCE_PENALTY_MS;
        t.prefill_ms[i][source] = THIN_SOURCE_PENALTY_MS;
        t.decode_ms[i][source] = THIN_SOURCE_PENALTY_MS;
    }
    t
}

/// Waterfill the shared source budget: replicas want their solo rate
/// `1000 / per_ms[i]` but each token costs `src_ms[i]` on the source,
/// which has 1000 ms of time per second.  Cheapest source users are
/// served first; the return value is the admissible aggregate tokens/s.
fn waterfill_tps(per_ms: &[f64], src_ms: &[f64]) -> f64 {
    let mut order: Vec<usize> = (0..per_ms.len()).collect();
    order.sort_by(|&a, &b| src_ms[a].total_cmp(&src_ms[b]));
    let mut budget = 1000.0;
    let mut total = 0.0;
    for &i in &order {
        let want = if per_ms[i] > 0.0 { 1000.0 / per_ms[i] } else { 0.0 };
        let granted = if src_ms[i] <= 1e-12 {
            want
        } else {
            want.min((budget / src_ms[i]).max(0.0))
        };
        total += granted;
        budget -= granted * src_ms[i];
    }
    total
}

impl ReplicaPlanner {
    pub fn new() -> Self {
        ReplicaPlanner::default()
    }

    /// Solve the joint problem over `pool` (must contain the source).
    /// Returns the best configuration found across K = 1..=`max_replicas`;
    /// K = 1 is always a candidate, so the result is never worse than the
    /// single-pipeline plan.
    pub fn solve(
        &self,
        traces: &ProfiledTraces,
        cluster: &Cluster,
        pool: &[usize],
    ) -> Result<ReplicaPlan, PlanError> {
        let batch = self.batch.max(1);
        if !pool.contains(&cluster.source) {
            return Err(PlanError::Infeasible("pool must contain source".into()));
        }
        // Non-source devices.  `others` keeps the caller's order (the K=1
        // degenerate case must enumerate exactly like ThroughputDp);
        // `sorted` is class-ordered so K >= 2 partitions are deterministic
        // and class-balanced (identical hardware is interchangeable).
        let others: Vec<usize> = pool
            .iter()
            .copied()
            .filter(|&d| d != cluster.source)
            .collect();
        let mut sorted = others.clone();
        sorted.sort_by(|&a, &b| {
            let da = &cluster.devices[a];
            let db = &cluster.devices[b];
            (&da.class.name, da.usable_mem_bytes, a).cmp(&(&db.class.name, db.usable_mem_bytes, b))
        });

        let k_max = self.max_replicas.max(1).min(others.len().max(1));
        let thin = if k_max >= 2 {
            Some(thin_source_traces(traces, cluster.source))
        } else {
            None
        };
        let mut best: Option<ReplicaPlan> = None;
        let mut first_err: Option<PlanError> = None;
        for k in 1..=k_max {
            let candidates: Vec<Vec<Vec<usize>>> = if k == 1 {
                vec![vec![others.clone()]]
            } else {
                vec![split_round_robin(&sorted, k), split_blocks(&sorted, k)]
            };
            // K = 1 keeps the source fully usable (single-pipeline DP);
            // K >= 2 sees the thinned source.
            let inner_traces = match &thin {
                Some(t) if k >= 2 => t,
                _ => traces,
            };
            for mut subsets in candidates {
                match self.solve_partition(traces, inner_traces, cluster, &mut subsets, batch) {
                    Ok(rp) => {
                        let better = best
                            .as_ref()
                            .map(|b| rp.predicted_tps > b.predicted_tps * (1.0 + 1e-9))
                            .unwrap_or(true);
                        if better {
                            best = Some(rp);
                        }
                    }
                    Err(e) => {
                        first_err.get_or_insert(e);
                    }
                }
            }
        }
        best.ok_or_else(|| {
            first_err.unwrap_or_else(|| PlanError::Infeasible("no feasible replica split".into()))
        })
    }

    /// Plan a single replica over `subset` ∪ {source} — used by the
    /// router's rebalance path to re-plan a dead replica's surviving
    /// devices into a fresh pipeline.  The source stays thin (other
    /// replicas are still running on it).
    pub fn plan_subset(
        &self,
        traces: &ProfiledTraces,
        cluster: &Cluster,
        subset: &[usize],
    ) -> Result<Plan, PlanError> {
        let thin = thin_source_traces(traces, cluster.source);
        let mut pool = vec![cluster.source];
        pool.extend(subset.iter().copied().filter(|&d| d != cluster.source));
        inner_solve(&thin, cluster, &pool, self.batch.max(1))
    }

    /// Solve one concrete partition, refine it with bounded single-device
    /// migrations, and enforce the shared-source memory budget.
    fn solve_partition(
        &self,
        traces: &ProfiledTraces,
        inner_traces: &ProfiledTraces,
        cluster: &Cluster,
        subsets: &mut [Vec<usize>],
        batch: usize,
    ) -> Result<ReplicaPlan, PlanError> {
        let mut plans = solve_subsets(inner_traces, cluster, subsets, batch)?;
        let mut score = self.score(&plans, traces, cluster, batch)?;
        // Local search: move one device from the fastest replica (lowest
        // bottleneck — the one with capacity to spare) to the slowest,
        // keep the move iff the waterfilled aggregate improves.
        if subsets.len() > 1 {
            for _ in 0..self.refine_moves {
                let worst = argmax(&score.per_replica_ms);
                let donor = argmin(&score.per_replica_ms);
                if donor == worst || subsets[donor].len() <= 1 {
                    break;
                }
                let mut improved = false;
                for di in 0..subsets[donor].len() {
                    let mut trial: Vec<Vec<usize>> = subsets.to_vec();
                    let dev = trial[donor].remove(di);
                    trial[worst].push(dev);
                    let trial_plans = match solve_subsets(inner_traces, cluster, &trial, batch) {
                        Ok(p) => p,
                        Err(_) => continue,
                    };
                    let trial_score = match self.score(&trial_plans, traces, cluster, batch) {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    if trial_score.predicted_tps > score.predicted_tps * (1.0 + 1e-9) {
                        subsets[donor].remove(di);
                        subsets[worst].push(dev);
                        plans = trial_plans;
                        score = trial_score;
                        improved = true;
                        break;
                    }
                }
                if !improved {
                    break;
                }
            }
        }
        Ok(ReplicaPlan {
            replicas: plans,
            per_replica_ms: score.per_replica_ms,
            source_ms: score.source_ms,
            predicted_tps: score.predicted_tps,
        })
    }

    /// Score a set of replica plans against the *real* traces: per-replica
    /// bottleneck, shared-source waterfill, shared-source memory budget.
    fn score(
        &self,
        plans: &[Plan],
        traces: &ProfiledTraces,
        cluster: &Cluster,
        batch: usize,
    ) -> Result<Score, PlanError> {
        let src = cluster.source;
        let mut source_bytes = 0u64;
        let mut per_replica_ms = Vec::with_capacity(plans.len());
        let mut source_ms = Vec::with_capacity(plans.len());
        for p in plans {
            per_replica_ms.push(pipeline_bottleneck_ms(p, traces, cluster));
            let mut c = 0.0;
            for s in p.stages.iter().filter(|s| s.device == src) {
                c += traces.range_avg_ms(s.start, s.end, src);
                source_bytes += traces.range_mem_bytes(s.start, s.end, batch);
            }
            source_ms.push(c);
        }
        // Every replica's source-resident stages charge the same physical
        // device, so the sum must fit.
        if source_bytes > cluster.devices[src].usable_mem_bytes {
            return Err(PlanError::Oom);
        }
        let predicted_tps = waterfill_tps(&per_replica_ms, &source_ms);
        Ok(Score {
            per_replica_ms,
            source_ms,
            predicted_tps,
        })
    }
}

struct Score {
    per_replica_ms: Vec<f64>,
    source_ms: Vec<f64>,
    predicted_tps: f64,
}

fn solve_subsets(
    traces: &ProfiledTraces,
    cluster: &Cluster,
    subsets: &[Vec<usize>],
    batch: usize,
) -> Result<Vec<Plan>, PlanError> {
    let mut plans = Vec::with_capacity(subsets.len());
    for subset in subsets {
        let mut pool = vec![cluster.source];
        pool.extend(subset.iter().copied());
        plans.push(inner_solve(traces, cluster, &pool, batch)?);
    }
    Ok(plans)
}

/// Deal the class-sorted devices round-robin into K subsets — each
/// replica gets a near-identical class mix.
fn split_round_robin(others: &[usize], k: usize) -> Vec<Vec<usize>> {
    let mut subsets = vec![Vec::new(); k];
    for (i, &d) in others.iter().enumerate() {
        subsets[i % k].push(d);
    }
    subsets
}

/// Contiguous blocks over the class-sorted list — replicas of
/// homogeneous hardware (useful when classes differ a lot and mixing
/// them would drag every replica down to the weakest device).
fn split_blocks(others: &[usize], k: usize) -> Vec<Vec<usize>> {
    let mut subsets = vec![Vec::new(); k];
    let per = others.len().div_ceil(k);
    for (i, &d) in others.iter().enumerate() {
        subsets[(i / per).min(k - 1)].push(d);
    }
    subsets
}

fn argmax(v: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

fn argmin(v: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x < v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::llama2_7b;
    use crate::planner::{validate_plan, Planner, ThroughputDp};
    use crate::profiler::{AnalyticProfiler, Workload};

    fn setup() -> (ProfiledTraces, Cluster) {
        let cluster = presets::paper_testbed(1.0, 0);
        let traces =
            AnalyticProfiler::default().profile(&llama2_7b(), &cluster, Workload::paper_default());
        (traces, cluster)
    }

    #[test]
    fn k1_reproduces_throughput_dp_exactly() {
        let (t, c) = setup();
        let pool: Vec<usize> = (0..6).collect();
        let single = ThroughputDp::restricted(pool.clone()).plan(&t, &c).unwrap();
        let rp = ReplicaPlanner {
            max_replicas: 1,
            ..ReplicaPlanner::default()
        }
        .solve(&t, &c, &pool)
        .unwrap();
        assert_eq!(rp.k(), 1);
        assert_eq!(rp.replicas[0], single);
        let solo = 1000.0 / pipeline_bottleneck_ms(&single, &t, &c);
        assert!((rp.predicted_tps - solo).abs() < 1e-9);
    }

    #[test]
    fn big_pool_prefers_multiple_replicas() {
        let (t, c) = setup();
        let pool: Vec<usize> = (0..c.len()).collect();
        let rp = ReplicaPlanner::default().solve(&t, &c, &pool).unwrap();
        let single = ThroughputDp::new().plan(&t, &c).unwrap();
        let single_tps = 1000.0 / pipeline_bottleneck_ms(&single, &t, &c);
        assert!(
            rp.k() >= 2,
            "expected K >= 2 on a {}-device pool, got {}",
            c.len(),
            rp.describe()
        );
        assert!(
            rp.predicted_tps > single_tps,
            "aggregate {} <= single-pipeline {}",
            rp.predicted_tps,
            single_tps
        );
    }

    #[test]
    fn every_replica_plan_is_valid_and_subsets_disjoint() {
        let (t, c) = setup();
        let pool: Vec<usize> = (0..c.len()).collect();
        let rp = ReplicaPlanner::default().solve(&t, &c, &pool).unwrap();
        let mut seen = std::collections::HashSet::new();
        for p in &rp.replicas {
            validate_plan(p, &t, &c, 1).unwrap();
            assert_eq!(p.stages[0].device, c.source, "first stage on source");
            for s in p.stages.iter().filter(|s| s.device != c.source) {
                assert!(
                    seen.insert(s.device),
                    "device {} used by two replicas: {}",
                    s.device,
                    rp.describe()
                );
            }
        }
    }

    #[test]
    fn pool_without_source_is_infeasible() {
        let (t, c) = setup();
        let err = ReplicaPlanner::default()
            .solve(&t, &c, &[1, 2, 3])
            .unwrap_err();
        assert!(matches!(err, PlanError::Infeasible(_)));
    }

    #[test]
    fn shared_source_memory_is_charged_once_across_replicas() {
        let (t, mut c) = setup();
        // source can hold ~1.5 front stages: any K >= 2 over-subscribes it
        let front = t.range_mem_bytes(0, 1, 1);
        c.devices[0].usable_mem_bytes = front + front / 2;
        let pool: Vec<usize> = (0..c.len()).collect();
        let rp = ReplicaPlanner::default().solve(&t, &c, &pool).unwrap();
        assert_eq!(rp.k(), 1, "source memory admits one front stage only");
        let mut source_bytes = 0u64;
        for p in &rp.replicas {
            for s in p.stages.iter().filter(|s| s.device == 0) {
                source_bytes += t.range_mem_bytes(s.start, s.end, 1);
            }
        }
        assert!(source_bytes <= c.devices[0].usable_mem_bytes);
    }

    #[test]
    fn waterfill_throttles_source_hogs() {
        // two replicas wholly on the source (c == b) cannot beat one
        let solo = waterfill_tps(&[10.0], &[10.0]);
        let two = waterfill_tps(&[10.0, 10.0], &[10.0, 10.0]);
        assert!((solo - 100.0).abs() < 1e-9);
        assert!((two - 100.0).abs() < 1e-6, "got {}", two);
        // thin front door (tiny c): replicas add up
        let thin = waterfill_tps(&[10.0, 10.0], &[0.1, 0.1]);
        assert!((thin - 200.0).abs() < 1e-6, "got {}", thin);
    }

    #[test]
    fn plan_subset_plans_over_subset_plus_source() {
        let (t, c) = setup();
        let p = ReplicaPlanner::default()
            .plan_subset(&t, &c, &[3, 4, 5])
            .unwrap();
        validate_plan(&p, &t, &c, 1).unwrap();
        for s in &p.stages {
            assert!([c.source, 3, 4, 5].contains(&s.device), "{}", p.describe());
        }
    }

    #[test]
    fn splits_are_deterministic_and_cover() {
        let others = vec![5, 1, 9, 2, 7];
        for k in 1..=3 {
            for split in [split_round_robin(&others, k), split_blocks(&others, k)] {
                let mut flat: Vec<usize> = split.iter().flatten().copied().collect();
                flat.sort_unstable();
                let mut want = others.clone();
                want.sort_unstable();
                assert_eq!(flat, want);
                assert_eq!(split.len(), k);
            }
        }
    }
}
