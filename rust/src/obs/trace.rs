//! Lock-cheap tracing: spans and events from the serving stack, exported
//! as Chrome trace-event JSON (openable in Perfetto) — plus a bounded
//! flight-recorder ring dumped on failure.
//!
//! A [`Tracer`] is a cheap cloneable handle.  The disabled tracer
//! ([`Tracer::off`], the `Default`) makes every emission a single relaxed
//! atomic increment — **no allocation, no lock, no channel** — which is
//! what the CI overhead gate asserts via [`events_suppressed`] /
//! [`events_recorded`].  An enabled tracer stamps a monotonic timestamp
//! at the emit site and sends the event over an mpsc channel to a
//! collector thread; hot paths never contend on a lock.
//!
//! Event sources:
//! * **compute spans** — the per-stage [`ComputeObs`] stream stage actors
//!   already emit for the adaptive monitor (fan-out, not stolen);
//! * **transfer spans** — the per-hop [`TransferObs`] stream from the
//!   shaped links;
//! * **lifecycle spans** — request (continuous/open-loop serving) and
//!   group (fixed/sequential serving) phases emitted by the drive loop:
//!   queue → prefill → decode;
//! * **decode-step spans** and **counters** from the drive loop;
//! * **instant events** from the adaptive runtime: replans, migrations,
//!   checkpoints, liveness verdicts, failover rounds.
//!
//! Span durations are **simulated** milliseconds placed on the real-time
//! axis at the moment the observation arrived (span end = arrival), so a
//! trace shows both where sim-time went and when the runtime learned it.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::metrics::ComputeObs;
use crate::netsim::TransferObs;
use crate::util::Json;

/// Events recorded by enabled tracers (allocation happened).
static RECORDED: AtomicU64 = AtomicU64::new(0);
/// Events suppressed by disabled tracers (the no-op fast path: one
/// relaxed increment, nothing else).
static SUPPRESSED: AtomicU64 = AtomicU64::new(0);

/// Total events recorded by enabled tracers since process start.
pub fn events_recorded() -> u64 {
    RECORDED.load(Ordering::Relaxed)
}

/// Total events suppressed by disabled tracers since process start.
pub fn events_suppressed() -> u64 {
    SUPPRESSED.load(Ordering::Relaxed)
}

/// Flight-recorder capacity (most recent events kept).
pub const FLIGHT_CAPACITY: usize = 1024;

/// Lifecycle span owner: a client request (continuous serving) or a
/// compiled group (fixed / sequential serving).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LifeKind {
    Request,
    Group,
}

impl LifeKind {
    fn cat(self) -> &'static str {
        match self {
            LifeKind::Request => "request",
            LifeKind::Group => "group",
        }
    }
}

/// Lifecycle phase of a request/group span.  `Whole` is the outermost
/// span (arrival → completion); the rest nest inside it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqPhase {
    Whole,
    Queue,
    Prefill,
    Decode,
}

impl ReqPhase {
    fn name(self) -> &'static str {
        match self {
            ReqPhase::Whole => "lifetime",
            ReqPhase::Queue => "queue",
            ReqPhase::Prefill => "prefill",
            ReqPhase::Decode => "decode",
        }
    }
}

/// One traced event (also the flight-recorder element).
#[derive(Debug, Clone)]
pub enum Event {
    /// Per-stage compute span from [`ComputeObs`] (sim-ms duration).
    Compute {
        device: usize,
        stage: usize,
        decode: bool,
        ms: f64,
        end_us: u64,
    },
    /// Per-hop transfer span from [`TransferObs`] (sim-ms duration).
    Transfer {
        from: usize,
        to: usize,
        bytes: u64,
        sim_ms: f64,
        end_us: u64,
    },
    /// One decode iteration of a pipeline run/group in the drive loop.
    Step {
        run: usize,
        rows: usize,
        dur_ms: f64,
        end_us: u64,
    },
    /// Request/group lifecycle edge (async span begin/end).
    Life {
        kind: LifeKind,
        id: u64,
        phase: ReqPhase,
        begin: bool,
        at_us: u64,
    },
    /// Control-plane instant: replan, migration, checkpoint, liveness
    /// verdict, failover round.
    Instant {
        name: &'static str,
        detail: String,
        at_us: u64,
    },
    /// Named counter sample (queue depth, KV bytes, ...).
    Counter {
        name: &'static str,
        value: f64,
        at_us: u64,
    },
}

impl Event {
    fn ts_us(&self) -> u64 {
        match self {
            Event::Compute { ms, end_us, .. } | Event::Transfer { sim_ms: ms, end_us, .. } => {
                end_us.saturating_sub((ms.max(0.0) * 1e3) as u64)
            }
            Event::Step { dur_ms, end_us, .. } => {
                end_us.saturating_sub((dur_ms.max(0.0) * 1e3) as u64)
            }
            Event::Life { at_us, .. } | Event::Instant { at_us, .. } | Event::Counter { at_us, .. } => *at_us,
        }
    }
}

enum Msg {
    Event(Event),
    Flush(Sender<()>),
}

struct Shared {
    /// The full event log (kept only when the tracer exports).
    events: Mutex<Vec<Event>>,
    /// Bounded ring of the most recent events (the flight recorder).
    flight: Mutex<VecDeque<Event>>,
}

struct Inner {
    t0: Instant,
    tx: Sender<Msg>,
    shared: Arc<Shared>,
}

/// Cheap cloneable tracing handle; see the module docs.
#[derive(Clone, Default)]
pub struct Tracer(Option<Arc<Inner>>);

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.0.is_some() { "Tracer(on)" } else { "Tracer(off)" })
    }
}

impl Tracer {
    /// The disabled tracer: every emission is one relaxed atomic add.
    pub fn off() -> Tracer {
        Tracer(None)
    }

    /// Full tracer: keeps every event for Chrome-trace export, plus the
    /// flight ring.
    pub fn on() -> Tracer {
        Tracer::start(true)
    }

    /// Flight-recorder-only tracer: bounded memory (the ring), no full
    /// export — what `repro churn` runs by default so crashes still
    /// leave a post-mortem artifact.
    pub fn flight_only() -> Tracer {
        Tracer::start(false)
    }

    fn start(keep_full: bool) -> Tracer {
        let (tx, rx) = channel::<Msg>();
        let shared = Arc::new(Shared {
            events: Mutex::new(Vec::new()),
            flight: Mutex::new(VecDeque::with_capacity(FLIGHT_CAPACITY)),
        });
        let worker = Arc::clone(&shared);
        std::thread::spawn(move || {
            for msg in rx {
                match msg {
                    Msg::Event(e) => {
                        {
                            let mut ring = worker.flight.lock().expect("flight ring poisoned");
                            if ring.len() == FLIGHT_CAPACITY {
                                ring.pop_front();
                            }
                            ring.push_back(e.clone());
                        }
                        if keep_full {
                            worker.events.lock().expect("trace log poisoned").push(e);
                        }
                    }
                    Msg::Flush(ack) => {
                        let _ = ack.send(());
                    }
                }
            }
        });
        Tracer(Some(Arc::new(Inner {
            t0: Instant::now(),
            tx,
            shared,
        })))
    }

    pub fn is_on(&self) -> bool {
        self.0.is_some()
    }

    /// Microseconds since the tracer started (0 when disabled).
    pub fn now_us(&self) -> u64 {
        self.0
            .as_ref()
            .map(|i| i.t0.elapsed().as_micros() as u64)
            .unwrap_or(0)
    }

    #[inline]
    fn emit(&self, build: impl FnOnce(u64) -> Event) {
        match &self.0 {
            None => {
                SUPPRESSED.fetch_add(1, Ordering::Relaxed);
            }
            Some(inner) => {
                RECORDED.fetch_add(1, Ordering::Relaxed);
                let at = inner.t0.elapsed().as_micros() as u64;
                let _ = inner.tx.send(Msg::Event(build(at)));
            }
        }
    }

    /// Begin a lifecycle phase span for a request/group.
    pub fn begin(&self, kind: LifeKind, id: u64, phase: ReqPhase) {
        self.emit(|at_us| Event::Life { kind, id, phase, begin: true, at_us });
    }

    /// End a lifecycle phase span for a request/group.
    pub fn end(&self, kind: LifeKind, id: u64, phase: ReqPhase) {
        self.emit(|at_us| Event::Life { kind, id, phase, begin: false, at_us });
    }

    /// Record one decode iteration of run/group `run` covering `rows`
    /// live rows, `dur_ms` after the previous one.
    pub fn step(&self, run: usize, rows: usize, dur_ms: f64) {
        self.emit(|end_us| Event::Step { run, rows, dur_ms, end_us });
    }

    /// Control-plane instant; the detail closure runs only when enabled.
    pub fn instant(&self, name: &'static str, detail: impl FnOnce() -> String) {
        self.emit(|at_us| Event::Instant { name, detail: detail(), at_us });
    }

    /// Sample a named counter track.
    pub fn counter(&self, name: &'static str, value: f64) {
        self.emit(|at_us| Event::Counter { name, value, at_us });
    }

    /// A sender to fan [`ComputeObs`] into this tracer (None when off).
    /// A forwarder thread stamps arrival time per observation.
    pub fn compute_sink(&self) -> Option<Sender<ComputeObs>> {
        self.0.as_ref()?;
        let tracer = self.clone();
        let (tx, rx) = channel::<ComputeObs>();
        std::thread::spawn(move || {
            for o in rx {
                tracer.emit(|end_us| Event::Compute {
                    device: o.device,
                    stage: o.stage,
                    decode: o.decode,
                    ms: o.ms,
                    end_us,
                });
            }
        });
        Some(tx)
    }

    /// A sender to fan [`TransferObs`] into this tracer (None when off).
    pub fn transfer_sink(&self) -> Option<Sender<TransferObs>> {
        self.0.as_ref()?;
        let tracer = self.clone();
        let (tx, rx) = channel::<TransferObs>();
        std::thread::spawn(move || {
            for o in rx {
                tracer.emit(|end_us| Event::Transfer {
                    from: o.from,
                    to: o.to,
                    bytes: o.bytes,
                    sim_ms: o.sim_ms,
                    end_us,
                });
            }
        });
        Some(tx)
    }

    /// Wait until every event sent so far has reached the collector.
    pub fn flush(&self) {
        if let Some(inner) = &self.0 {
            let (ack_tx, ack_rx) = channel();
            if inner.tx.send(Msg::Flush(ack_tx)).is_ok() {
                let _ = ack_rx.recv();
            }
        }
    }

    /// The full trace as a Chrome trace-event JSON array (None when the
    /// tracer is off).  Events are sorted by timestamp.
    pub fn chrome_json(&self) -> Option<Json> {
        let inner = self.0.as_ref()?;
        self.flush();
        let events = inner.shared.events.lock().expect("trace log poisoned");
        Some(chrome_array(&events))
    }

    /// Write the Chrome trace to `path`; returns false when the tracer
    /// is off (nothing written).
    pub fn export_chrome(&self, path: &std::path::Path) -> Result<bool> {
        match self.chrome_json() {
            None => Ok(false),
            Some(j) => {
                std::fs::write(path, j.to_string())
                    .with_context(|| format!("writing trace {path:?}"))?;
                Ok(true)
            }
        }
    }

    /// Snapshot the flight ring as a post-mortem JSON object (None when
    /// the tracer is off).
    pub fn flight_json(&self, reason: &str) -> Option<Json> {
        let inner = self.0.as_ref()?;
        self.flush();
        let ring = inner.shared.flight.lock().expect("flight ring poisoned");
        let mut root = BTreeMap::new();
        root.insert("reason".into(), Json::Str(reason.to_string()));
        root.insert("captured_events".into(), Json::Num(ring.len() as f64));
        root.insert("dumped_at_us".into(), Json::Num(self.now_us() as f64));
        root.insert(
            "events".into(),
            Json::Arr(ring.iter().map(flight_obj).collect()),
        );
        Some(Json::Obj(root))
    }

    /// Dump the flight ring to `path`; returns false when the tracer is
    /// off (nothing written).
    pub fn dump_flight(&self, path: &std::path::Path, reason: &str) -> Result<bool> {
        match self.flight_json(reason) {
            None => Ok(false),
            Some(j) => {
                std::fs::write(path, j.to_string())
                    .with_context(|| format!("writing flight record {path:?}"))?;
                Ok(true)
            }
        }
    }
}

// ---------------------------------------------------------------------
// Chrome trace-event rendering
// ---------------------------------------------------------------------

const PID_PIPELINE: f64 = 1.0;
const PID_NETWORK: f64 = 2.0;
const PID_DRIVER: f64 = 3.0;
const PID_REQUESTS: f64 = 4.0;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct TrackAlloc {
    /// (pid, track name) → tid, assigned in first-seen order per pid.
    tids: BTreeMap<(u64, String), u64>,
}

impl TrackAlloc {
    fn new() -> Self {
        TrackAlloc { tids: BTreeMap::new() }
    }

    fn tid(&mut self, pid: f64, name: String) -> f64 {
        let next = self
            .tids
            .keys()
            .filter(|(p, _)| *p == pid as u64)
            .count() as u64;
        *self.tids.entry((pid as u64, name)).or_insert(next) as f64
    }

    /// `thread_name` / `process_name` metadata events for Perfetto.
    fn metadata(&self) -> Vec<Json> {
        let mut out = vec![];
        for (pid, pname) in [
            (PID_PIPELINE, "pipeline stages"),
            (PID_NETWORK, "network links"),
            (PID_DRIVER, "drive loop"),
            (PID_REQUESTS, "requests"),
        ] {
            out.push(obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("process_name".into())),
                ("pid", Json::Num(pid)),
                ("tid", Json::Num(0.0)),
                ("ts", Json::Num(0.0)),
                ("args", obj(vec![("name", Json::Str(pname.into()))])),
            ]));
        }
        for ((pid, name), tid) in &self.tids {
            out.push(obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("thread_name".into())),
                ("pid", Json::Num(*pid as f64)),
                ("tid", Json::Num(*tid as f64)),
                ("ts", Json::Num(0.0)),
                ("args", obj(vec![("name", Json::Str(name.clone()))])),
            ]));
        }
        out
    }
}

fn chrome_event(e: &Event, tracks: &mut TrackAlloc) -> Json {
    let ts = Json::Num(e.ts_us() as f64);
    match e {
        Event::Compute { device, stage, decode, ms, .. } => {
            let tid = tracks.tid(PID_PIPELINE, format!("stage{stage} d{device}"));
            obj(vec![
                ("ph", Json::Str("X".into())),
                ("cat", Json::Str("compute".into())),
                ("name", Json::Str(if *decode { "decode" } else { "prefill" }.into())),
                ("pid", Json::Num(PID_PIPELINE)),
                ("tid", Json::Num(tid)),
                ("ts", ts),
                ("dur", Json::Num((ms.max(0.0) * 1e3).round())),
                ("args", obj(vec![
                    ("device", Json::Num(*device as f64)),
                    ("stage", Json::Num(*stage as f64)),
                    ("sim_ms", Json::Num(*ms)),
                ])),
            ])
        }
        Event::Transfer { from, to, bytes, sim_ms, .. } => {
            let tid = tracks.tid(PID_NETWORK, format!("link d{from}->d{to}"));
            obj(vec![
                ("ph", Json::Str("X".into())),
                ("cat", Json::Str("transfer".into())),
                ("name", Json::Str("transfer".into())),
                ("pid", Json::Num(PID_NETWORK)),
                ("tid", Json::Num(tid)),
                ("ts", ts),
                ("dur", Json::Num((sim_ms.max(0.0) * 1e3).round())),
                ("args", obj(vec![
                    ("bytes", Json::Num(*bytes as f64)),
                    ("sim_ms", Json::Num(*sim_ms)),
                ])),
            ])
        }
        Event::Step { run, rows, dur_ms, .. } => {
            let tid = tracks.tid(PID_DRIVER, format!("run{run}"));
            obj(vec![
                ("ph", Json::Str("X".into())),
                ("cat", Json::Str("step".into())),
                ("name", Json::Str("decode step".into())),
                ("pid", Json::Num(PID_DRIVER)),
                ("tid", Json::Num(tid)),
                ("ts", ts),
                ("dur", Json::Num((dur_ms.max(0.0) * 1e3).round())),
                ("args", obj(vec![("rows", Json::Num(*rows as f64))])),
            ])
        }
        Event::Life { kind, id, phase, begin, .. } => {
            let name = match phase {
                ReqPhase::Whole => format!(
                    "{} {id}",
                    if *kind == LifeKind::Request { "req" } else { "group" }
                ),
                p => p.name().to_string(),
            };
            obj(vec![
                ("ph", Json::Str(if *begin { "b" } else { "e" }.into())),
                ("cat", Json::Str(kind.cat().into())),
                ("id", Json::Str(format!("{id}"))),
                ("name", Json::Str(name)),
                ("pid", Json::Num(PID_REQUESTS)),
                ("tid", Json::Num(0.0)),
                ("ts", ts),
            ])
        }
        Event::Instant { name, detail, .. } => obj(vec![
            ("ph", Json::Str("i".into())),
            ("cat", Json::Str("control".into())),
            ("s", Json::Str("g".into())),
            ("name", Json::Str((*name).into())),
            ("pid", Json::Num(PID_DRIVER)),
            ("tid", Json::Num(tracks.tid(PID_DRIVER, "control".into()))),
            ("ts", ts),
            ("args", obj(vec![("detail", Json::Str(detail.clone()))])),
        ]),
        Event::Counter { name, value, .. } => obj(vec![
            ("ph", Json::Str("C".into())),
            ("name", Json::Str((*name).into())),
            ("pid", Json::Num(PID_DRIVER)),
            ("tid", Json::Num(0.0)),
            ("ts", ts),
            ("args", obj(vec![("value", Json::Num(*value))])),
        ]),
    }
}

/// Render events as a ts-sorted Chrome trace array with track metadata.
pub fn chrome_array(events: &[Event]) -> Json {
    let mut tracks = TrackAlloc::new();
    let mut sorted: Vec<&Event> = events.iter().collect();
    sorted.sort_by_key(|e| e.ts_us());
    let body: Vec<Json> = sorted.iter().map(|e| chrome_event(e, &mut tracks)).collect();
    let mut out = tracks.metadata();
    out.extend(body);
    Json::Arr(out)
}

/// Flat flight-recorder rendering of one event (kind + fields).
fn flight_obj(e: &Event) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![("ts_us", Json::Num(e.ts_us() as f64))];
    match e {
        Event::Compute { device, stage, decode, ms, .. } => {
            pairs.push(("kind", Json::Str("compute".into())));
            pairs.push(("device", Json::Num(*device as f64)));
            pairs.push(("stage", Json::Num(*stage as f64)));
            pairs.push(("decode", Json::Bool(*decode)));
            pairs.push(("sim_ms", Json::Num(*ms)));
        }
        Event::Transfer { from, to, bytes, sim_ms, .. } => {
            pairs.push(("kind", Json::Str("transfer".into())));
            pairs.push(("from", Json::Num(*from as f64)));
            pairs.push(("to", Json::Num(*to as f64)));
            pairs.push(("bytes", Json::Num(*bytes as f64)));
            pairs.push(("sim_ms", Json::Num(*sim_ms)));
        }
        Event::Step { run, rows, dur_ms, .. } => {
            pairs.push(("kind", Json::Str("step".into())));
            pairs.push(("run", Json::Num(*run as f64)));
            pairs.push(("rows", Json::Num(*rows as f64)));
            pairs.push(("dur_ms", Json::Num(*dur_ms)));
        }
        Event::Life { kind, id, phase, begin, .. } => {
            pairs.push(("kind", Json::Str("life".into())));
            pairs.push(("cat", Json::Str(kind.cat().into())));
            pairs.push(("id", Json::Num(*id as f64)));
            pairs.push(("phase", Json::Str(phase.name().into())));
            pairs.push(("begin", Json::Bool(*begin)));
        }
        Event::Instant { name, detail, .. } => {
            pairs.push(("kind", Json::Str("instant".into())));
            pairs.push(("name", Json::Str((*name).into())));
            pairs.push(("detail", Json::Str(detail.clone())));
        }
        Event::Counter { name, value, .. } => {
            pairs.push(("kind", Json::Str("counter".into())));
            pairs.push(("name", Json::Str((*name).into())));
            pairs.push(("value", Json::Num(*value)));
        }
    }
    obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_tracer_only_counts() {
        // the counters are global, so parallel tests may also bump them:
        // assert only the lower bound this tracer contributes
        let before_sup = events_suppressed();
        let t = Tracer::off();
        t.begin(LifeKind::Request, 1, ReqPhase::Whole);
        t.step(0, 2, 1.0);
        t.instant("x", || unreachable!("detail closure must not run when off"));
        t.counter("c", 1.0);
        assert!(events_suppressed() >= before_sup + 4);
        assert!(t.compute_sink().is_none());
        assert!(t.transfer_sink().is_none());
        assert!(t.chrome_json().is_none());
        assert!(t.flight_json("r").is_none());
    }

    #[test]
    fn chrome_export_shape() {
        let t = Tracer::on();
        t.begin(LifeKind::Request, 7, ReqPhase::Whole);
        t.begin(LifeKind::Request, 7, ReqPhase::Queue);
        t.end(LifeKind::Request, 7, ReqPhase::Queue);
        t.step(0, 1, 0.5);
        t.instant("replan_decided", || "plan A -> plan B".into());
        t.counter("queue_depth", 3.0);
        t.end(LifeKind::Request, 7, ReqPhase::Whole);
        if let Some(tx) = t.compute_sink() {
            tx.send(ComputeObs { device: 0, stage: 0, decode: true, ms: 1.0 }).unwrap();
            drop(tx);
        }
        if let Some(tx) = t.transfer_sink() {
            tx.send(TransferObs { from: 0, to: 1, bytes: 64, sim_ms: 2.0 }).unwrap();
            drop(tx);
        }
        // forwarder threads hop once; give them a beat before flushing
        std::thread::sleep(std::time::Duration::from_millis(20));
        let j = t.chrome_json().unwrap();
        let arr = j.as_arr().unwrap();
        // round-trips through the parser
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(&re, &j);
        let phases: Vec<&str> = arr
            .iter()
            .filter_map(|e| e.get("ph").and_then(|p| p.as_str()))
            .collect();
        for want in ["M", "X", "b", "e", "i", "C"] {
            assert!(phases.contains(&want), "missing ph {want}");
        }
        // ts monotone non-negative, dur non-negative
        let mut last = -1.0;
        for e in arr.iter().filter(|e| e.get("ph").and_then(|p| p.as_str()) != Some("M")) {
            let ts = e.get("ts").and_then(|t| t.as_f64()).unwrap();
            assert!(ts >= 0.0 && ts >= last, "ts not monotone: {ts} after {last}");
            last = ts;
            if let Some(d) = e.get("dur").and_then(|d| d.as_f64()) {
                assert!(d >= 0.0);
            }
        }
        // request async span balanced
        let b = phases.iter().filter(|p| **p == "b").count();
        let e = phases.iter().filter(|p| **p == "e").count();
        assert_eq!(b, e);
    }

    #[test]
    fn flight_ring_is_bounded_and_keeps_recent() {
        let t = Tracer::flight_only();
        for i in 0..(FLIGHT_CAPACITY as u64 + 100) {
            t.counter("i", i as f64);
        }
        let j = t.flight_json("test").unwrap();
        let events = j.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), FLIGHT_CAPACITY);
        let last = events.last().unwrap();
        assert_eq!(
            last.get("value").and_then(|v| v.as_f64()),
            Some(FLIGHT_CAPACITY as f64 + 99.0)
        );
        // flight-only keeps no full log
        let full = t.chrome_json().unwrap();
        let n_non_meta = full
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) != Some("M"))
            .count();
        assert_eq!(n_non_meta, 0);
    }
}
