//! Observability: end-to-end tracing, live metrics, leveled logging and
//! the failover flight recorder.
//!
//! EdgeShard's argument is about *where time goes* — per-device compute
//! vs inter-device transfer under time-varying links.  This subsystem
//! makes that visible on a timeline instead of only in post-hoc
//! aggregates:
//!
//! * [`trace`] — a lock-cheap [`trace::Tracer`] (mpsc into a collector
//!   thread) recording request/group lifecycle spans, per-stage compute
//!   and per-hop transfer spans (fanning out the same
//!   [`crate::metrics::ComputeObs`] / [`crate::netsim::TransferObs`]
//!   streams the adaptive monitor consumes), decode-step spans, counters,
//!   and control-plane instants (replans, migrations, checkpoints,
//!   liveness verdicts, failover rounds).  Exports Chrome trace-event
//!   JSON (`--trace out.json`, openable in Perfetto) and keeps a bounded
//!   flight-recorder ring that the failover path dumps automatically.
//! * [`metrics`] — [`metrics::MetricsRegistry`]: counters, gauges and
//!   bounded-memory log-bucket [`metrics::BucketHistogram`]s behind a
//!   cloneable handle; snapshot served by the TCP server's
//!   `{"cmd":"metrics"}` command.
//! * [`log`] — a tiny leveled logger (`EDGESHARD_LOG` / `--log`), off by
//!   default, so adaptive-runtime diagnostics are opt-in and test output
//!   stays quiet.
//!
//! Everything here has a no-op fast path: a disabled [`trace::Tracer`]
//! or [`metrics::MetricsRegistry`] costs one relaxed atomic increment
//! (asserted by the CI overhead gate via [`trace::events_suppressed`])
//! or a single branch per call.
//!
//! See `docs/OBSERVABILITY.md` for the event taxonomy and workflows.

pub mod log;
pub mod metrics;
pub mod trace;

pub use metrics::{BucketHistogram, MetricsRegistry};
pub use trace::{LifeKind, ReqPhase, Tracer};
