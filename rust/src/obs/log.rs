//! Tiny leveled logger — opt-in diagnostics for the adaptive runtime and
//! the coordinator, quiet by default.
//!
//! The level comes from the `EDGESHARD_LOG` environment variable
//! (`off|error|warn|info|debug`, or `0..=4`) or from [`set_level`] (the
//! CLI's `--log` flag).  Call sites pass a closure so a disabled level
//! costs one relaxed atomic load and never formats:
//!
//! ```
//! edgeshard::obs::log::debug("replan", || format!("evaluated {} plans", 3));
//! ```

use std::sync::atomic::{AtomicU8, Ordering};

/// Verbosity levels, ordered: a configured level enables itself and
/// everything below it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Parse a level name or digit; `None` on anything unrecognized.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.trim().to_ascii_lowercase().as_str() {
        "off" | "0" | "none" => Some(Level::Off),
        "error" | "1" => Some(Level::Error),
        "warn" | "warning" | "2" => Some(Level::Warn),
        "info" | "3" => Some(Level::Info),
        "debug" | "4" => Some(Level::Debug),
        _ => None,
    }
}

/// 255 = "not initialized yet: consult the environment on first read".
const UNSET: u8 = 255;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn from_u8(v: u8) -> Level {
    match v {
        1 => Level::Error,
        2 => Level::Warn,
        3 => Level::Info,
        4 => Level::Debug,
        _ => Level::Off,
    }
}

/// Force the level (CLI flag / tests) — wins over the environment.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// The active level (reads `EDGESHARD_LOG` on first call).
pub fn level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNSET {
        return from_u8(v);
    }
    let init = std::env::var("EDGESHARD_LOG")
        .ok()
        .and_then(|s| parse_level(&s))
        .unwrap_or(Level::Off);
    // racing initializers agree (env is stable), so a plain store is fine
    LEVEL.store(init as u8, Ordering::Relaxed);
    init
}

/// Is `l` currently enabled?
pub fn enabled(l: Level) -> bool {
    l <= level() && l != Level::Off
}

/// Log at `l` under a short target tag; the closure runs only when the
/// level is enabled.
pub fn log(l: Level, target: &str, msg: impl FnOnce() -> String) {
    if enabled(l) {
        eprintln!("[{:<5} {target}] {}", l.tag(), msg());
    }
}

pub fn error(target: &str, msg: impl FnOnce() -> String) {
    log(Level::Error, target, msg);
}

pub fn warn(target: &str, msg: impl FnOnce() -> String) {
    log(Level::Warn, target, msg);
}

pub fn info(target: &str, msg: impl FnOnce() -> String) {
    log(Level::Info, target, msg);
}

pub fn debug(target: &str, msg: impl FnOnce() -> String) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_names_and_digits() {
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("DEBUG"), Some(Level::Debug));
        assert_eq!(parse_level("2"), Some(Level::Warn));
        assert_eq!(parse_level("nope"), None);
    }

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Debug);
        assert!(Level::Off < Level::Error);
    }
}
