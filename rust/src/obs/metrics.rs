//! Live metrics: a bounded-memory log-bucket histogram and a process-wide
//! [`MetricsRegistry`] of counters / gauges / histograms.
//!
//! The exact [`crate::metrics::Histogram`] keeps every sample — right for
//! bounded experiments, wrong for a long-running `serve` loop.
//! [`BucketHistogram`] buckets values geometrically (ratio
//! [`BUCKET_GAMMA`]), so memory is bounded by the dynamic range of the
//! data (a few hundred buckets over ns→hours) and percentiles carry a
//! bounded *relative* error of `√γ − 1` (< 5%).  Buckets of two
//! histograms align exactly, so merging is count addition.
//!
//! [`MetricsRegistry`] is a cheap cloneable handle; a disabled registry
//! (`MetricsRegistry::off()`, the default) makes every operation a no-op
//! so the serving hot path pays nothing when metrics are off.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::metrics::ThroughputMeter;
use crate::util::Json;

/// Geometric bucket ratio: each bucket's upper bound is γ× the previous.
/// γ = 1.1 keeps the worst-case percentile error under `√1.1 − 1 ≈ 4.9%`.
pub const BUCKET_GAMMA: f64 = 1.1;

/// Bounded-memory log-bucket histogram (mergeable).
///
/// Bucket `i` covers `(γ^(i−1), γ^i]`; a recorded value lands in bucket
/// `ceil(ln v / ln γ)` and is reported back as the bucket's geometric
/// midpoint `γ^(i−1/2)`.  Zero and negative values count in a dedicated
/// zero bucket (reported as 0).
#[derive(Debug, Clone, Default)]
pub struct BucketHistogram {
    counts: BTreeMap<i32, u64>,
    zero: u64,
    count: u64,
    sum: f64,
}

impl BucketHistogram {
    pub fn new() -> Self {
        BucketHistogram::default()
    }

    fn bucket_of(v: f64) -> i32 {
        (v.ln() / BUCKET_GAMMA.ln()).ceil() as i32
    }

    /// Geometric midpoint of bucket `i` — the representative value.
    fn midpoint(i: i32) -> f64 {
        BUCKET_GAMMA.powf(i as f64 - 0.5)
    }

    pub fn record(&mut self, v: f64) {
        self.count += 1;
        if v > 0.0 && v.is_finite() {
            self.sum += v;
            *self.counts.entry(Self::bucket_of(v)).or_insert(0) += 1;
        } else {
            self.zero += 1;
        }
    }

    pub fn len(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Distinct buckets in use — the memory bound.
    pub fn buckets(&self) -> usize {
        self.counts.len() + usize::from(self.zero > 0)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Nearest-rank percentile over the bucketed distribution; the value
    /// returned is the holding bucket's geometric midpoint, so it is
    /// within `√γ` of the exact-sample percentile.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        if rank <= self.zero {
            return 0.0;
        }
        let mut seen = self.zero;
        for (&i, &c) in &self.counts {
            seen += c;
            if seen >= rank {
                return Self::midpoint(i);
            }
        }
        // rank beyond the last bucket (p > 100): clamp to the max bucket
        self.counts
            .keys()
            .next_back()
            .map(|&i| Self::midpoint(i))
            .unwrap_or(0.0)
    }

    /// Merge another histogram in; bucket boundaries are identical by
    /// construction, so this is exact.
    pub fn merge(&mut self, other: &BucketHistogram) {
        self.count += other.count;
        self.zero += other.zero;
        self.sum += other.sum;
        for (&i, &c) in &other.counts {
            *self.counts.entry(i).or_insert(0) += c;
        }
    }

    /// Snapshot as JSON (count / mean / p50 / p95 / p99 / buckets).
    pub fn to_json(&self) -> Json {
        let num = |v: f64| Json::Num((v * 1000.0).round() / 1000.0);
        let mut o = BTreeMap::new();
        o.insert("count".into(), Json::Num(self.count as f64));
        o.insert("mean".into(), num(self.mean()));
        o.insert("p50".into(), num(self.percentile(50.0)));
        o.insert("p95".into(), num(self.percentile(95.0)));
        o.insert("p99".into(), num(self.percentile(99.0)));
        o.insert("buckets".into(), Json::Num(self.buckets() as f64));
        Json::Obj(o)
    }
}

#[derive(Debug, Default)]
struct Reg {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, BucketHistogram>,
    tokens: ThroughputMeter,
}

/// Cloneable registry handle.  `off()` (the `Default`) is a no-op on
/// every path; `new()` shares one mutex-guarded map set between clones.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry(Option<Arc<Mutex<Reg>>>);

impl MetricsRegistry {
    /// The disabled registry — every operation is a no-op.
    pub fn off() -> Self {
        MetricsRegistry(None)
    }

    /// A live registry.
    pub fn new() -> Self {
        MetricsRegistry(Some(Arc::new(Mutex::new(Reg::default()))))
    }

    pub fn is_on(&self) -> bool {
        self.0.is_some()
    }

    fn with(&self, f: impl FnOnce(&mut Reg)) {
        if let Some(m) = &self.0 {
            if let Ok(mut reg) = m.lock() {
                f(&mut reg);
            }
        }
    }

    /// Add `n` to a monotonic counter.
    pub fn inc(&self, name: &'static str, n: u64) {
        self.with(|r| *r.counters.entry(name).or_insert(0) += n);
    }

    /// Set a gauge to its latest value.
    pub fn gauge(&self, name: &'static str, v: f64) {
        self.with(|r| {
            r.gauges.insert(name, v);
        });
    }

    /// Record a sample into a named [`BucketHistogram`].
    pub fn observe(&self, name: &'static str, v: f64) {
        self.with(|r| r.hists.entry(name).or_default().record(v));
    }

    /// Count generated tokens (feeds both the `tokens_total` counter and
    /// the live tokens/s meter, whose window starts at the first token).
    pub fn add_tokens(&self, n: u64) {
        self.with(|r| {
            *r.counters.entry("tokens_total").or_insert(0) += n;
            r.tokens.add(n);
        });
    }

    /// Snapshot everything as one JSON object (the `{"cmd":"metrics"}`
    /// server reply).
    pub fn snapshot(&self) -> Json {
        let mut root = BTreeMap::new();
        match &self.0 {
            None => {
                root.insert("enabled".into(), Json::Bool(false));
            }
            Some(m) => {
                let reg = m.lock().expect("metrics registry poisoned");
                root.insert("enabled".into(), Json::Bool(true));
                root.insert(
                    "tokens_per_s".into(),
                    Json::Num((reg.tokens.per_second() * 1000.0).round() / 1000.0),
                );
                root.insert(
                    "counters".into(),
                    Json::Obj(
                        reg.counters
                            .iter()
                            .map(|(k, v)| (k.to_string(), Json::Num(*v as f64)))
                            .collect(),
                    ),
                );
                root.insert(
                    "gauges".into(),
                    Json::Obj(
                        reg.gauges
                            .iter()
                            .map(|(k, v)| (k.to_string(), Json::Num(*v)))
                            .collect(),
                    ),
                );
                root.insert(
                    "histograms".into(),
                    Json::Obj(
                        reg.hists
                            .iter()
                            .map(|(k, h)| (k.to_string(), h.to_json()))
                            .collect(),
                    ),
                );
            }
        }
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Histogram;
    use crate::util::Rng;

    #[test]
    fn bucket_histogram_empty_safe() {
        let h = BucketHistogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.is_empty());
    }

    #[test]
    fn bucket_histogram_zero_and_negative_values() {
        let mut h = BucketHistogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(10.0);
        assert_eq!(h.len(), 3);
        assert_eq!(h.percentile(10.0), 0.0);
        assert!(h.percentile(99.0) > 9.0);
    }

    /// Property test (satellite): against the exact sample-vector
    /// histogram, bucket percentiles stay within the `√γ` relative
    /// bucket-error bound across seeds, sizes and dynamic ranges.
    #[test]
    fn bucket_percentiles_match_exact_within_bucket_error() {
        // √1.1 − 1 plus float slack
        let tol = BUCKET_GAMMA.sqrt() - 1.0 + 1e-9;
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed + 1);
            let n = 50 + (rng.next_below(2000) as usize);
            let mut exact = Histogram::new();
            let mut bucketed = BucketHistogram::new();
            for _ in 0..n {
                // span several orders of magnitude: 10^[−2, 4)
                let v = 10f64.powf(rng.uniform(-2.0, 4.0));
                exact.record(v);
                bucketed.record(v);
            }
            for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
                let e = exact.percentile(p);
                let b = bucketed.percentile(p);
                let rel = (b - e).abs() / e.abs().max(1e-12);
                assert!(
                    rel <= tol,
                    "seed {seed} n {n} p{p}: exact {e} bucketed {b} rel {rel}"
                );
            }
        }
    }

    #[test]
    fn bucket_histogram_merge_equals_combined() {
        let mut rng = Rng::new(7);
        let mut a = BucketHistogram::new();
        let mut b = BucketHistogram::new();
        let mut both = BucketHistogram::new();
        for i in 0..500 {
            let v = rng.uniform(0.1, 500.0);
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.len(), both.len());
        for p in [5.0, 50.0, 95.0, 99.0] {
            assert_eq!(a.percentile(p), both.percentile(p), "p{p}");
        }
        assert!((a.mean() - both.mean()).abs() < 1e-9);
    }

    #[test]
    fn bucket_memory_is_bounded() {
        let mut h = BucketHistogram::new();
        let mut rng = Rng::new(3);
        for _ in 0..100_000 {
            h.record(10f64.powf(rng.uniform(-3.0, 5.0))); // 8 decades
        }
        // 8 decades at γ=1.1 is ~194 buckets; leave slack
        assert!(h.buckets() < 250, "buckets = {}", h.buckets());
        assert_eq!(h.len(), 100_000);
    }

    #[test]
    fn registry_off_is_noop_and_snapshot_says_so() {
        let r = MetricsRegistry::off();
        r.inc("a", 1);
        r.observe("h", 5.0);
        r.add_tokens(10);
        let snap = r.snapshot();
        assert_eq!(snap.get("enabled").and_then(|j| j.as_bool()), Some(false));
        assert!(snap.get("counters").is_none());
    }

    #[test]
    fn registry_snapshot_carries_counters_gauges_hists() {
        let r = MetricsRegistry::new();
        let clone = r.clone(); // clones share the store
        clone.inc("replans_total", 2);
        r.gauge("queue_depth", 7.0);
        for v in [1.0, 2.0, 100.0] {
            r.observe("ttft_ms", v);
        }
        r.add_tokens(12);
        let snap = r.snapshot();
        assert_eq!(snap.get("enabled").and_then(|j| j.as_bool()), Some(true));
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.get("replans_total").and_then(|j| j.as_f64()), Some(2.0));
        assert_eq!(counters.get("tokens_total").and_then(|j| j.as_f64()), Some(12.0));
        assert_eq!(
            snap.get("gauges").unwrap().get("queue_depth").and_then(|j| j.as_f64()),
            Some(7.0)
        );
        let h = snap.get("histograms").unwrap().get("ttft_ms").unwrap();
        assert_eq!(h.get("count").and_then(|j| j.as_f64()), Some(3.0));
    }
}
