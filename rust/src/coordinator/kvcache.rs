//! KV-cache pool: pre-allocated, byte-accounted cache slots per stage.
//!
//! The paper: "We pre-allocate memory space for KV cache on each
//! participating device."  Each stage owns one pool sized from its
//! device's memory budget minus its weight shard; groups (micro-batches)
//! claim a slot at prefill and release it when generation completes.

use crate::runtime::TensorData;
use std::collections::HashMap;

/// Per-group cache state held by one stage.
#[derive(Debug, Clone)]
pub struct GroupCache {
    /// One (k, v) pair per decoder layer this stage hosts.
    pub layers: Vec<(TensorData, TensorData)>,
    pub batch: usize,
    pub bytes: u64,
}

/// Byte-budgeted cache pool.
#[derive(Debug)]
pub struct KvPool {
    budget_bytes: u64,
    used_bytes: u64,
    groups: HashMap<u64, GroupCache>,
    /// peak usage for reporting
    peak_bytes: u64,
}

impl KvPool {
    pub fn new(budget_bytes: u64) -> Self {
        KvPool {
            budget_bytes,
            used_bytes: 0,
            groups: HashMap::new(),
            peak_bytes: 0,
        }
    }

    /// Bytes one group needs on this stage: `layers × 2 × batch × kv_heads
    /// × max_seq × head_dim × 4`.
    pub fn group_bytes(
        n_layers: usize,
        batch: usize,
        kv_heads: usize,
        max_seq: usize,
        head_dim: usize,
    ) -> u64 {
        (n_layers * 2 * batch * kv_heads * max_seq * head_dim * 4) as u64
    }

    /// Whether a group of this size can be admitted right now.
    pub fn can_admit(&self, bytes: u64) -> bool {
        self.used_bytes + bytes <= self.budget_bytes
    }

    /// Install a freshly prefilled cache.  Fails if over budget (the
    /// batcher is responsible for never letting this happen).
    pub fn insert(&mut self, group: u64, cache: GroupCache) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.can_admit(cache.bytes),
            "KV pool over budget: used={} + group={} > budget={}",
            self.used_bytes,
            cache.bytes,
            self.budget_bytes
        );
        self.used_bytes += cache.bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        anyhow::ensure!(
            self.groups.insert(group, cache).is_none(),
            "group {group} already cached"
        );
        Ok(())
    }

    pub fn get_mut(&mut self, group: u64) -> Option<&mut GroupCache> {
        self.groups.get_mut(&group)
    }

    pub fn get(&self, group: u64) -> Option<&GroupCache> {
        self.groups.get(&group)
    }

    /// Iterate over resident groups (migration export reads the pool
    /// through this).
    pub fn iter(&self) -> impl Iterator<Item = (&u64, &GroupCache)> {
        self.groups.iter()
    }

    /// Release a finished group's slot.
    pub fn remove(&mut self, group: u64) -> Option<GroupCache> {
        let c = self.groups.remove(&group)?;
        self.used_bytes -= c.bytes;
        Some(c)
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_cache(bytes: u64) -> GroupCache {
        GroupCache {
            layers: vec![],
            batch: 1,
            bytes,
        }
    }

    #[test]
    fn admit_and_release() {
        let mut p = KvPool::new(1000);
        assert!(p.can_admit(600));
        p.insert(1, dummy_cache(600)).unwrap();
        assert_eq!(p.used_bytes(), 600);
        assert!(!p.can_admit(600));
        assert!(p.insert(2, dummy_cache(600)).is_err());
        p.insert(3, dummy_cache(400)).unwrap();
        assert_eq!(p.len(), 2);
        p.remove(1).unwrap();
        assert_eq!(p.used_bytes(), 400);
        assert!(p.can_admit(600));
        assert_eq!(p.peak_bytes(), 1000);
    }

    #[test]
    fn duplicate_group_rejected() {
        let mut p = KvPool::new(100);
        p.insert(1, dummy_cache(10)).unwrap();
        assert!(p.insert(1, dummy_cache(10)).is_err());
    }

    #[test]
    fn remove_missing_is_none() {
        let mut p = KvPool::new(100);
        assert!(p.remove(42).is_none());
    }

    #[test]
    fn group_bytes_formula() {
        // 4 layers, batch 8, 4 kv heads, 128 seq, 32 dim, f32:
        // 4*2*8*4*128*32*4 = 4 MiB
        assert_eq!(KvPool::group_bytes(4, 8, 4, 128, 32), 4 * 1024 * 1024);
    }
}
