//! KV-cache pool: pre-allocated, byte-accounted cache slots per stage.
//!
//! The paper: "We pre-allocate memory space for KV cache on each
//! participating device."  Each stage owns one pool sized from its
//! device's memory budget minus its weight shard.
//!
//! Two granularities coexist:
//!
//! * **Group-at-a-time** (classic serving): a micro-batch group claims a
//!   whole slot at prefill ([`KvPool::insert`]) and releases it when the
//!   group completes ([`KvPool::remove`]).  Padding rows are part of the
//!   slot — the price of static compiled shapes.
//! * **Row-granular** (continuous batching): a *run* owns one cache
//!   tensor per layer sized to a compiled batch, but rows are admitted
//!   ([`KvPool::insert_row`]), retired ([`KvPool::evict_row`]) and
//!   recomposed ([`KvPool::compact`]) individually, and the pool accounts
//!   bytes per **live row**, so a finished sequence's KV budget is
//!   reclaimed the moment it retires — not when its whole batch drains.

use crate::runtime::TensorData;
use std::collections::HashMap;
use std::sync::Arc;

/// Per-group cache state held by one stage.
#[derive(Debug, Clone)]
pub struct GroupCache {
    /// One (k, v) pair per decoder layer this stage hosts.  Dims are
    /// `[batch, kv_heads, max_seq, head_dim]`.
    pub layers: Vec<(TensorData, TensorData)>,
    pub batch: usize,
    /// Bytes this cache currently charges against the pool budget.  For
    /// group-granular caches this is the whole padded tensor; for
    /// row-granular caches it is `live rows × row_bytes`.
    pub bytes: u64,
    /// Row liveness, one flag per batch row.  Group-granular caches are
    /// fully live; row-granular caches toggle rows as sequences are
    /// admitted and retired.
    pub live: Vec<bool>,
}

impl GroupCache {
    /// Bytes one live row of this cache charges (the padded per-row K+V
    /// footprint across this stage's layers).
    pub fn row_bytes(&self) -> u64 {
        if self.batch == 0 {
            return 0;
        }
        let total: u64 = self.layers.iter().map(|(k, v)| k.bytes() + v.bytes()).sum();
        total / self.batch as u64
    }

    /// Live (charged) rows.
    pub fn live_rows(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }
}

/// Copy row `src_row` of `src` into row `dst_row` of `dst` (both
/// `[batch, …]` tensors with identical trailing dims).
fn copy_row(dst: &mut TensorData, dst_row: usize, src: &TensorData, src_row: usize) {
    let (TensorData::F32 { data: dd, dims: ddims }, TensorData::F32 { data: sd, dims: sdims }) =
        (dst, src)
    else {
        debug_assert!(false, "KV caches are f32");
        return;
    };
    let row_len: usize = ddims[1..].iter().product::<i64>() as usize;
    debug_assert_eq!(row_len, sdims[1..].iter().product::<i64>() as usize);
    let out = Arc::make_mut(dd);
    out[dst_row * row_len..(dst_row + 1) * row_len]
        .copy_from_slice(&sd[src_row * row_len..(src_row + 1) * row_len]);
}

/// Zero row `row` of a `[batch, …]` tensor.
fn zero_row(t: &mut TensorData, row: usize) {
    let TensorData::F32 { data, dims } = t else {
        debug_assert!(false, "KV caches are f32");
        return;
    };
    let row_len: usize = dims[1..].iter().product::<i64>() as usize;
    Arc::make_mut(data)[row * row_len..(row + 1) * row_len].fill(0.0);
}

/// A zeroed `[batch, …]` tensor with the trailing dims of `like`.
fn zeros_like_rows(like: &TensorData, batch: usize) -> TensorData {
    let dims = like.dims();
    let mut new_dims = dims.to_vec();
    new_dims[0] = batch as i64;
    let len: usize = new_dims.iter().product::<i64>() as usize;
    TensorData::f32(vec![0.0; len], new_dims)
}

/// Byte-budgeted cache pool.
#[derive(Debug)]
pub struct KvPool {
    budget_bytes: u64,
    used_bytes: u64,
    groups: HashMap<u64, GroupCache>,
    /// peak usage for reporting
    peak_bytes: u64,
}

impl KvPool {
    pub fn new(budget_bytes: u64) -> Self {
        KvPool {
            budget_bytes,
            used_bytes: 0,
            groups: HashMap::new(),
            peak_bytes: 0,
        }
    }

    /// Bytes one group needs on this stage: `layers × 2 × batch × kv_heads
    /// × max_seq × head_dim × 4`.
    pub fn group_bytes(
        n_layers: usize,
        batch: usize,
        kv_heads: usize,
        max_seq: usize,
        head_dim: usize,
    ) -> u64 {
        (n_layers * 2 * batch * kv_heads * max_seq * head_dim * 4) as u64
    }

    /// Whether a group of this size can be admitted right now.
    pub fn can_admit(&self, bytes: u64) -> bool {
        self.used_bytes + bytes <= self.budget_bytes
    }

    /// Install a freshly prefilled (or migrated/restored) cache.  Fails
    /// if over budget (the batcher is responsible for never letting this
    /// happen) or if the liveness mask does not match the batch — a
    /// half-full run must arrive with its occupancy intact, not a
    /// defaulted all-live mask.
    pub fn insert(&mut self, group: u64, cache: GroupCache) -> anyhow::Result<()> {
        anyhow::ensure!(
            cache.live.len() == cache.batch,
            "group {group}: liveness mask has {} flags for batch {}",
            cache.live.len(),
            cache.batch
        );
        anyhow::ensure!(
            self.can_admit(cache.bytes),
            "KV pool over budget: used={} + group={} > budget={}",
            self.used_bytes,
            cache.bytes,
            self.budget_bytes
        );
        self.used_bytes += cache.bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        anyhow::ensure!(
            self.groups.insert(group, cache).is_none(),
            "group {group} already cached"
        );
        Ok(())
    }

    /// Continuous batching: install one prefilled sequence as row `row`
    /// of run `run`'s cache, allocating a zeroed `run_batch`-row cache on
    /// the first admission.  `layer_rows` is one `[1, …]` (k, v) pair per
    /// local layer.  Only the admitted row is charged against the budget.
    pub fn insert_row(
        &mut self,
        run: u64,
        row: usize,
        run_batch: usize,
        layer_rows: Vec<(TensorData, TensorData)>,
    ) -> anyhow::Result<()> {
        let row_bytes: u64 = layer_rows.iter().map(|(k, v)| k.bytes() + v.bytes()).sum();
        anyhow::ensure!(
            self.can_admit(row_bytes),
            "KV pool over budget: used={} + row={} > budget={}",
            self.used_bytes,
            row_bytes,
            self.budget_bytes
        );
        anyhow::ensure!(row < run_batch, "row {row} outside run batch {run_batch}");
        let cache = self.groups.entry(run).or_insert_with(|| GroupCache {
            layers: layer_rows
                .iter()
                .map(|(k, v)| (zeros_like_rows(k, run_batch), zeros_like_rows(v, run_batch)))
                .collect(),
            batch: run_batch,
            bytes: 0,
            live: vec![false; run_batch],
        });
        anyhow::ensure!(
            cache.batch == run_batch,
            "run {run} cache has batch {}, admit says {run_batch}",
            cache.batch
        );
        anyhow::ensure!(
            cache.layers.len() == layer_rows.len(),
            "run {run}: {} layer rows for a {}-layer cache",
            layer_rows.len(),
            cache.layers.len()
        );
        anyhow::ensure!(!cache.live[row], "run {run} row {row} already live");
        for ((dk, dv), (sk, sv)) in cache.layers.iter_mut().zip(&layer_rows) {
            copy_row(dk, row, sk, 0);
            copy_row(dv, row, sv, 0);
        }
        cache.live[row] = true;
        cache.bytes += row_bytes;
        self.used_bytes += row_bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        Ok(())
    }

    /// Continuous batching: retire row `row` of run `run` — zero the row
    /// (hygiene: a later re-admission starts clean) and release its bytes
    /// immediately, per-row rather than per-group.
    pub fn evict_row(&mut self, run: u64, row: usize) -> anyhow::Result<u64> {
        let cache = self
            .groups
            .get_mut(&run)
            .ok_or_else(|| anyhow::anyhow!("evict: run {run} has no cache"))?;
        anyhow::ensure!(row < cache.batch, "evict: row {row} outside batch {}", cache.batch);
        anyhow::ensure!(cache.live[row], "evict: run {run} row {row} not live");
        let row_bytes = cache.row_bytes();
        for (k, v) in cache.layers.iter_mut() {
            zero_row(k, row);
            zero_row(v, row);
        }
        cache.live[row] = false;
        cache.bytes = cache.bytes.saturating_sub(row_bytes);
        self.used_bytes = self.used_bytes.saturating_sub(row_bytes);
        Ok(row_bytes)
    }

    /// Continuous batching: rebuild run `run`'s cache at `new_batch` rows,
    /// moving row `from` → `to` for each pair in `moves`.  Rows not named
    /// in `moves` are dropped — a live row left unnamed is released and
    /// its bytes freed.  Byte accounting follows the surviving live rows.
    ///
    /// Failover leans on exactly these semantics: a restored checkpoint
    /// cache is reconciled to the run's current composition with one
    /// compact — survivors move snapshot-slot → current-slot, and rows
    /// retired (or re-admitted) since the snapshot are simply unnamed.
    pub fn compact(
        &mut self,
        run: u64,
        new_batch: usize,
        moves: &[(usize, usize)],
    ) -> anyhow::Result<()> {
        let cache = self
            .groups
            .get_mut(&run)
            .ok_or_else(|| anyhow::anyhow!("compact: run {run} has no cache"))?;
        let row_bytes = cache.row_bytes();
        let mut new_live = vec![false; new_batch];
        for &(from, to) in moves {
            anyhow::ensure!(
                from < cache.batch && to < new_batch,
                "compact: move {from}→{to} outside {}→{new_batch}",
                cache.batch
            );
            anyhow::ensure!(cache.live[from], "compact: moving dead row {from}");
            anyhow::ensure!(!new_live[to], "compact: duplicate target row {to}");
            new_live[to] = true;
        }
        let mut new_layers = Vec::with_capacity(cache.layers.len());
        for (k, v) in &cache.layers {
            let mut nk = zeros_like_rows(k, new_batch);
            let mut nv = zeros_like_rows(v, new_batch);
            for &(from, to) in moves {
                copy_row(&mut nk, to, k, from);
                copy_row(&mut nv, to, v, from);
            }
            new_layers.push((nk, nv));
        }
        let new_bytes = moves.len() as u64 * row_bytes;
        self.used_bytes = self.used_bytes - cache.bytes + new_bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        cache.layers = new_layers;
        cache.batch = new_batch;
        cache.bytes = new_bytes;
        cache.live = new_live;
        Ok(())
    }

    pub fn get_mut(&mut self, group: u64) -> Option<&mut GroupCache> {
        self.groups.get_mut(&group)
    }

    pub fn get(&self, group: u64) -> Option<&GroupCache> {
        self.groups.get(&group)
    }

    /// Iterate over resident groups (migration export reads the pool
    /// through this).
    pub fn iter(&self) -> impl Iterator<Item = (&u64, &GroupCache)> {
        self.groups.iter()
    }

    /// Release a finished group's slot.
    pub fn remove(&mut self, group: u64) -> Option<GroupCache> {
        let c = self.groups.remove(&group)?;
        self.used_bytes -= c.bytes;
        Some(c)
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_cache(bytes: u64) -> GroupCache {
        GroupCache {
            layers: vec![],
            batch: 1,
            bytes,
            live: vec![true],
        }
    }

    /// A `[1, kv, seq, hd]` row tensor with every element `fill`.
    fn row(kv: usize, seq: usize, hd: usize, fill: f32) -> (TensorData, TensorData) {
        let dims = vec![1, kv as i64, seq as i64, hd as i64];
        let len = kv * seq * hd;
        (
            TensorData::f32(vec![fill; len], dims.clone()),
            TensorData::f32(vec![-fill; len], dims),
        )
    }

    #[test]
    fn admit_and_release() {
        let mut p = KvPool::new(1000);
        assert!(p.can_admit(600));
        p.insert(1, dummy_cache(600)).unwrap();
        assert_eq!(p.used_bytes(), 600);
        assert!(!p.can_admit(600));
        assert!(p.insert(2, dummy_cache(600)).is_err());
        p.insert(3, dummy_cache(400)).unwrap();
        assert_eq!(p.len(), 2);
        p.remove(1).unwrap();
        assert_eq!(p.used_bytes(), 400);
        assert!(p.can_admit(600));
        assert_eq!(p.peak_bytes(), 1000);
    }

    #[test]
    fn mask_batch_mismatch_rejected() {
        let mut p = KvPool::new(100);
        let bad = GroupCache {
            layers: vec![],
            batch: 4,
            bytes: 10,
            live: vec![true], // 1 flag for 4 rows
        };
        assert!(p.insert(1, bad).is_err());
        assert_eq!(p.used_bytes(), 0);
    }

    #[test]
    fn duplicate_group_rejected() {
        let mut p = KvPool::new(100);
        p.insert(1, dummy_cache(10)).unwrap();
        assert!(p.insert(1, dummy_cache(10)).is_err());
    }

    #[test]
    fn remove_missing_is_none() {
        let mut p = KvPool::new(100);
        assert!(p.remove(42).is_none());
    }

    #[test]
    fn group_bytes_formula() {
        // 4 layers, batch 8, 4 kv heads, 128 seq, 32 dim, f32:
        // 4*2*8*4*128*32*4 = 4 MiB
        assert_eq!(KvPool::group_bytes(4, 8, 4, 128, 32), 4 * 1024 * 1024);
    }

    #[test]
    fn row_insert_evict_accounting() {
        let (kv, seq, hd) = (2, 4, 2);
        let row_bytes = (2 * 2 * kv * seq * hd * 4) as u64; // 2 layers × (k+v)
        let mut p = KvPool::new(10 * row_bytes);
        p.insert_row(9, 0, 4, vec![row(kv, seq, hd, 1.0), row(kv, seq, hd, 2.0)])
            .unwrap();
        assert_eq!(p.used_bytes(), row_bytes);
        p.insert_row(9, 2, 4, vec![row(kv, seq, hd, 3.0), row(kv, seq, hd, 4.0)])
            .unwrap();
        assert_eq!(p.used_bytes(), 2 * row_bytes);
        let c = p.get(9).unwrap();
        assert_eq!(c.batch, 4);
        assert_eq!(c.live, vec![true, false, true, false]);
        // row 0 of layer 0 carries 1.0s, row 2 carries 3.0s, dead rows zero
        let k0 = c.layers[0].0.as_f32().unwrap();
        let row_len = kv * seq * hd;
        assert!(k0[..row_len].iter().all(|&x| x == 1.0));
        assert!(k0[row_len..2 * row_len].iter().all(|&x| x == 0.0));
        assert!(k0[2 * row_len..3 * row_len].iter().all(|&x| x == 3.0));

        // double-admit and dead-evict are rejected
        assert!(p
            .insert_row(9, 0, 4, vec![row(kv, seq, hd, 9.0), row(kv, seq, hd, 9.0)])
            .is_err());
        assert!(p.evict_row(9, 1).is_err());

        assert_eq!(p.evict_row(9, 0).unwrap(), row_bytes);
        assert_eq!(p.used_bytes(), row_bytes);
        // evicted row zeroed; slot can be re-admitted
        let c = p.get(9).unwrap();
        assert!(c.layers[0].0.as_f32().unwrap()[..row_len].iter().all(|&x| x == 0.0));
        p.insert_row(9, 0, 4, vec![row(kv, seq, hd, 5.0), row(kv, seq, hd, 5.0)])
            .unwrap();
        assert_eq!(p.used_bytes(), 2 * row_bytes);
        p.evict_row(9, 0).unwrap();
        p.evict_row(9, 2).unwrap();
        assert_eq!(p.used_bytes(), 0);
        // the (empty) cache allocation itself charges nothing; remove drops it
        p.remove(9).unwrap();
        assert_eq!(p.used_bytes(), 0);
    }

    #[test]
    fn compact_moves_rows_and_bytes() {
        let (kv, seq, hd) = (2, 4, 2);
        let row_len = kv * seq * hd;
        let mut p = KvPool::new(1 << 20);
        p.insert_row(5, 1, 8, vec![row(kv, seq, hd, 1.0)]).unwrap();
        p.insert_row(5, 6, 8, vec![row(kv, seq, hd, 2.0)]).unwrap();
        let row_bytes = p.get(5).unwrap().row_bytes();
        assert_eq!(p.used_bytes(), 2 * row_bytes);
        p.compact(5, 2, &[(1, 0), (6, 1)]).unwrap();
        let c = p.get(5).unwrap();
        assert_eq!(c.batch, 2);
        assert_eq!(c.live, vec![true, true]);
        let k = c.layers[0].0.as_f32().unwrap();
        assert!(k[..row_len].iter().all(|&x| x == 1.0));
        assert!(k[row_len..].iter().all(|&x| x == 2.0));
        assert_eq!(p.used_bytes(), 2 * row_bytes);
        // dropping a row via compact releases its bytes
        p.compact(5, 1, &[(0, 0)]).unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(p.used_bytes(), row_bytes);
        // duplicate targets are rejected
        assert!(p.compact(5, 1, &[(0, 0), (0, 0)]).is_err());
    }
}
