//! KV-cache pool: pre-allocated, byte-accounted cache slots per stage.
//!
//! The paper: "We pre-allocate memory space for KV cache on each
//! participating device."  Each stage owns one pool sized from its
//! device's memory budget minus its weight shard.
//!
//! Two granularities coexist:
//!
//! * **Group-at-a-time** (classic serving): a micro-batch group claims a
//!   whole slot at prefill ([`KvPool::insert`]) and releases it when the
//!   group completes ([`KvPool::remove`]).  Padding rows are part of the
//!   slot — the price of static compiled shapes.
//! * **Row-granular** (continuous batching): a *run* owns one cache
//!   tensor per layer sized to a compiled batch, but rows are admitted
//!   ([`KvPool::insert_row`]), retired ([`KvPool::evict_row`]) and
//!   recomposed ([`KvPool::compact`]) individually, and the pool accounts
//!   bytes per **live row**, so a finished sequence's KV budget is
//!   reclaimed the moment it retires — not when its whole batch drains.

use crate::runtime::TensorData;
use std::collections::HashMap;
use std::sync::Arc;

/// Per-group cache state held by one stage.
#[derive(Debug, Clone)]
pub struct GroupCache {
    /// One (k, v) pair per decoder layer this stage hosts.  Dims are
    /// `[batch, kv_heads, max_seq, head_dim]`.
    pub layers: Vec<(TensorData, TensorData)>,
    pub batch: usize,
    /// Bytes this cache currently charges against the pool budget.  For
    /// group-granular caches this is the whole padded tensor; for
    /// row-granular caches it is `live rows × row_bytes`.
    pub bytes: u64,
    /// Row liveness, one flag per batch row.  Group-granular caches are
    /// fully live; row-granular caches toggle rows as sequences are
    /// admitted and retired.
    pub live: Vec<bool>,
    /// Positions filled per row (0 for dead rows).  Exact for caches
    /// reconstructed from a paged pool (export/checkpoint) — that is
    /// what lets a paged stage re-chop a preloaded padded cache into
    /// exactly the live blocks it held before the trip.  Padded-mode
    /// caches track the prefill watermark only (decode steps do not
    /// advance it); their consumers never read it.
    pub written: Vec<usize>,
}

impl GroupCache {
    /// Bytes one live row of this cache charges (the padded per-row K+V
    /// footprint across this stage's layers).
    pub fn row_bytes(&self) -> u64 {
        if self.batch == 0 {
            return 0;
        }
        let total: u64 = self.layers.iter().map(|(k, v)| k.bytes() + v.bytes()).sum();
        total / self.batch as u64
    }

    /// Live (charged) rows.
    pub fn live_rows(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }
}

/// Copy row `src_row` of `src` into row `dst_row` of `dst` (both
/// `[batch, …]` tensors with identical trailing dims).
fn copy_row(dst: &mut TensorData, dst_row: usize, src: &TensorData, src_row: usize) {
    let (TensorData::F32 { data: dd, dims: ddims }, TensorData::F32 { data: sd, dims: sdims }) =
        (dst, src)
    else {
        debug_assert!(false, "KV caches are f32");
        return;
    };
    let row_len: usize = ddims[1..].iter().product::<i64>() as usize;
    debug_assert_eq!(row_len, sdims[1..].iter().product::<i64>() as usize);
    let out = Arc::make_mut(dd);
    out[dst_row * row_len..(dst_row + 1) * row_len]
        .copy_from_slice(&sd[src_row * row_len..(src_row + 1) * row_len]);
}

/// Zero row `row` of a `[batch, …]` tensor.
fn zero_row(t: &mut TensorData, row: usize) {
    let TensorData::F32 { data, dims } = t else {
        debug_assert!(false, "KV caches are f32");
        return;
    };
    let row_len: usize = dims[1..].iter().product::<i64>() as usize;
    Arc::make_mut(data)[row * row_len..(row + 1) * row_len].fill(0.0);
}

/// A zeroed `[batch, …]` tensor with the trailing dims of `like`.
fn zeros_like_rows(like: &TensorData, batch: usize) -> TensorData {
    let dims = like.dims();
    let mut new_dims = dims.to_vec();
    new_dims[0] = batch as i64;
    let len: usize = new_dims.iter().product::<i64>() as usize;
    TensorData::f32(vec![0.0; len], new_dims)
}

/// Byte-budgeted cache pool.
#[derive(Debug)]
pub struct KvPool {
    budget_bytes: u64,
    used_bytes: u64,
    groups: HashMap<u64, GroupCache>,
    /// peak usage for reporting
    peak_bytes: u64,
}

impl KvPool {
    pub fn new(budget_bytes: u64) -> Self {
        KvPool {
            budget_bytes,
            used_bytes: 0,
            groups: HashMap::new(),
            peak_bytes: 0,
        }
    }

    /// Bytes one group needs on this stage: `layers × 2 × batch × kv_heads
    /// × max_seq × head_dim × elem_bytes`.  The element size is a
    /// parameter (4 for the fp32 sim wire) so the paged pool and any
    /// future quantized cache share one accounting path instead of a
    /// hardcoded fp32 assumption.
    pub fn group_bytes(
        n_layers: usize,
        batch: usize,
        kv_heads: usize,
        max_seq: usize,
        head_dim: usize,
        elem_bytes: usize,
    ) -> u64 {
        (n_layers * 2 * batch * kv_heads * max_seq * head_dim * elem_bytes) as u64
    }

    /// Whether a group of this size can be admitted right now.
    pub fn can_admit(&self, bytes: u64) -> bool {
        self.used_bytes + bytes <= self.budget_bytes
    }

    /// Install a freshly prefilled (or migrated/restored) cache.  Fails
    /// if over budget (the batcher is responsible for never letting this
    /// happen) or if the liveness mask does not match the batch — a
    /// half-full run must arrive with its occupancy intact, not a
    /// defaulted all-live mask.
    pub fn insert(&mut self, group: u64, cache: GroupCache) -> anyhow::Result<()> {
        anyhow::ensure!(
            cache.live.len() == cache.batch,
            "group {group}: liveness mask has {} flags for batch {}",
            cache.live.len(),
            cache.batch
        );
        anyhow::ensure!(
            self.can_admit(cache.bytes),
            "KV pool over budget: used={} + group={} > budget={}",
            self.used_bytes,
            cache.bytes,
            self.budget_bytes
        );
        self.used_bytes += cache.bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        anyhow::ensure!(
            self.groups.insert(group, cache).is_none(),
            "group {group} already cached"
        );
        Ok(())
    }

    /// Continuous batching: install one prefilled sequence as row `row`
    /// of run `run`'s cache, allocating a zeroed `run_batch`-row cache on
    /// the first admission.  `layer_rows` is one `[1, …]` (k, v) pair per
    /// local layer, `written` the positions the prefill filled.  Only the
    /// admitted row is charged against the budget.
    pub fn insert_row(
        &mut self,
        run: u64,
        row: usize,
        run_batch: usize,
        written: usize,
        layer_rows: Vec<(TensorData, TensorData)>,
    ) -> anyhow::Result<()> {
        let row_bytes: u64 = layer_rows.iter().map(|(k, v)| k.bytes() + v.bytes()).sum();
        anyhow::ensure!(
            self.can_admit(row_bytes),
            "KV pool over budget: used={} + row={} > budget={}",
            self.used_bytes,
            row_bytes,
            self.budget_bytes
        );
        anyhow::ensure!(row < run_batch, "row {row} outside run batch {run_batch}");
        let cache = self.groups.entry(run).or_insert_with(|| GroupCache {
            layers: layer_rows
                .iter()
                .map(|(k, v)| (zeros_like_rows(k, run_batch), zeros_like_rows(v, run_batch)))
                .collect(),
            batch: run_batch,
            bytes: 0,
            live: vec![false; run_batch],
            written: vec![0; run_batch],
        });
        anyhow::ensure!(
            cache.batch == run_batch,
            "run {run} cache has batch {}, admit says {run_batch}",
            cache.batch
        );
        anyhow::ensure!(
            cache.layers.len() == layer_rows.len(),
            "run {run}: {} layer rows for a {}-layer cache",
            layer_rows.len(),
            cache.layers.len()
        );
        anyhow::ensure!(!cache.live[row], "run {run} row {row} already live");
        for ((dk, dv), (sk, sv)) in cache.layers.iter_mut().zip(&layer_rows) {
            copy_row(dk, row, sk, 0);
            copy_row(dv, row, sv, 0);
        }
        cache.live[row] = true;
        cache.written[row] = written;
        cache.bytes += row_bytes;
        self.used_bytes += row_bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        Ok(())
    }

    /// Continuous batching: retire row `row` of run `run` — zero the row
    /// (hygiene: a later re-admission starts clean) and release its bytes
    /// immediately, per-row rather than per-group.
    pub fn evict_row(&mut self, run: u64, row: usize) -> anyhow::Result<u64> {
        let cache = self
            .groups
            .get_mut(&run)
            .ok_or_else(|| anyhow::anyhow!("evict: run {run} has no cache"))?;
        anyhow::ensure!(row < cache.batch, "evict: row {row} outside batch {}", cache.batch);
        anyhow::ensure!(cache.live[row], "evict: run {run} row {row} not live");
        let row_bytes = cache.row_bytes();
        for (k, v) in cache.layers.iter_mut() {
            zero_row(k, row);
            zero_row(v, row);
        }
        cache.live[row] = false;
        cache.written[row] = 0;
        cache.bytes = cache.bytes.saturating_sub(row_bytes);
        self.used_bytes = self.used_bytes.saturating_sub(row_bytes);
        Ok(row_bytes)
    }

    /// Continuous batching: rebuild run `run`'s cache at `new_batch` rows,
    /// moving row `from` → `to` for each pair in `moves`.  Rows not named
    /// in `moves` are dropped — a live row left unnamed is released and
    /// its bytes freed.  Byte accounting follows the surviving live rows.
    ///
    /// Failover leans on exactly these semantics: a restored checkpoint
    /// cache is reconciled to the run's current composition with one
    /// compact — survivors move snapshot-slot → current-slot, and rows
    /// retired (or re-admitted) since the snapshot are simply unnamed.
    pub fn compact(
        &mut self,
        run: u64,
        new_batch: usize,
        moves: &[(usize, usize)],
    ) -> anyhow::Result<()> {
        let cache = self
            .groups
            .get_mut(&run)
            .ok_or_else(|| anyhow::anyhow!("compact: run {run} has no cache"))?;
        let row_bytes = cache.row_bytes();
        let mut new_live = vec![false; new_batch];
        let mut new_written = vec![0usize; new_batch];
        for &(from, to) in moves {
            anyhow::ensure!(
                from < cache.batch && to < new_batch,
                "compact: move {from}→{to} outside {}→{new_batch}",
                cache.batch
            );
            anyhow::ensure!(cache.live[from], "compact: moving dead row {from}");
            anyhow::ensure!(!new_live[to], "compact: duplicate target row {to}");
            new_live[to] = true;
            new_written[to] = cache.written[from];
        }
        let mut new_layers = Vec::with_capacity(cache.layers.len());
        for (k, v) in &cache.layers {
            let mut nk = zeros_like_rows(k, new_batch);
            let mut nv = zeros_like_rows(v, new_batch);
            for &(from, to) in moves {
                copy_row(&mut nk, to, k, from);
                copy_row(&mut nv, to, v, from);
            }
            new_layers.push((nk, nv));
        }
        let new_bytes = moves.len() as u64 * row_bytes;
        self.used_bytes = self.used_bytes - cache.bytes + new_bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        cache.layers = new_layers;
        cache.batch = new_batch;
        cache.bytes = new_bytes;
        cache.live = new_live;
        cache.written = new_written;
        Ok(())
    }

    pub fn get_mut(&mut self, group: u64) -> Option<&mut GroupCache> {
        self.groups.get_mut(&group)
    }

    pub fn get(&self, group: u64) -> Option<&GroupCache> {
        self.groups.get(&group)
    }

    /// Iterate over resident groups (migration export reads the pool
    /// through this).
    pub fn iter(&self) -> impl Iterator<Item = (&u64, &GroupCache)> {
        self.groups.iter()
    }

    /// Release a finished group's slot.
    pub fn remove(&mut self, group: u64) -> Option<GroupCache> {
        let c = self.groups.remove(&group)?;
        self.used_bytes -= c.bytes;
        Some(c)
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }
}

/// Element size of the fp32 sim wire — the one concrete element width
/// the pure-Rust backend ships today.  Every accounting call site passes
/// this instead of hardcoding `4`.
pub const ELEM_BYTES_F32: usize = 4;

/// Pre-allocation clamp on paged pools: slabs are zero-allocated up
/// front, so a generous byte budget (the 1 GiB default) must not turn
/// into a gigabyte of resident zeros per stage.  Capacity is capped at
/// `PAGED_MAX_POOL_POSITIONS / block_size` blocks — the same clamp is
/// applied by the engine when sizing the scheduler's pool view
/// (`coordinator::engine::driver_cfg`) and by each stage when building
/// its [`PagedPool`], so the two can never disagree.  65536 positions ≈
/// a thousand max-length rows on the sim models — far past what slot
/// admission can keep in flight.
pub const PAGED_MAX_POOL_POSITIONS: usize = 1 << 16;

/// Which KV cache layout an engine serves with.  Engine-global: every
/// stage, the scheduler's occupancy mirror, and the freight accounting
/// all key off the same choice, and the two layouts produce byte-identical
/// tokens (`rust/tests/paged_kv.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvLayout {
    /// Padded per-row slabs `[batch, kv_heads, max_seq, head_dim]` —
    /// capacity is charged at worst case up front.
    #[default]
    Padded,
    /// Block-granular paged pool: rows allocate `block_size`-position
    /// blocks on demand, capacity is charged at the live working set.
    Paged {
        block_size: usize,
    },
}

impl KvLayout {
    /// The paged block size, if paged.
    pub fn block_size(&self) -> Option<usize> {
        match self {
            KvLayout::Padded => None,
            KvLayout::Paged { block_size } => Some(*block_size),
        }
    }
}

/// Mutable f32 view of a cache tensor (copy-on-write via `Arc::make_mut`).
fn slab_mut(t: &mut TensorData) -> anyhow::Result<&mut [f32]> {
    match t {
        TensorData::F32 { data, .. } => Ok(Arc::make_mut(data)),
        _ => anyhow::bail!("KV slabs are f32"),
    }
}

/// One sequence's block table: the ordered physical blocks holding its
/// positions, plus the write watermark.
#[derive(Debug, Clone)]
struct PagedRow {
    blocks: Vec<u32>,
    written: usize,
}

#[derive(Debug)]
struct PagedRun {
    batch: usize,
    rows: Vec<Option<PagedRow>>,
}

/// Block-granular paged KV pool (vLLM PagedAttention style).
///
/// One *block* spans [`block_size`](Self::block_size) consecutive token
/// positions across **all** of a stage's local layers: per layer, the K
/// and V slabs are `[capacity, kv_heads, block_size, head_dim]` tensors,
/// and a row maps position `p` to slab row `blocks[p / block_size]`.
/// Capacity is fixed at construction; rows allocate blocks on demand
/// from a LIFO free list as their sequences extend, so pool occupancy
/// tracks the *working set* (live blocks), not the `max_seq` padding the
/// padded layout charges per row.
///
/// The sim decode kernel gathers K/V through the block table
/// (`runtime::sim`, 14-input decode form), reading exactly the same f32
/// values in exactly the same order as the padded slab — which is what
/// keeps paged serving byte-identical to padded serving
/// (`rust/tests/paged_kv.rs`).
#[derive(Debug)]
pub struct PagedPool {
    block_size: usize,
    kv_heads: usize,
    head_dim: usize,
    max_seq: usize,
    capacity: usize,
    free: Vec<u32>,
    /// Per local layer: (k, v) slabs `[capacity, kv_heads, block_size,
    /// head_dim]`.
    slabs: Vec<(TensorData, TensorData)>,
    runs: HashMap<u64, PagedRun>,
    peak_blocks: usize,
}

impl PagedPool {
    /// Bytes one block occupies across `n_layers` local layers (K + V).
    pub fn block_bytes_for(
        n_layers: usize,
        kv_heads: usize,
        block_size: usize,
        head_dim: usize,
    ) -> u64 {
        KvPool::group_bytes(n_layers, 1, kv_heads, block_size, head_dim, ELEM_BYTES_F32)
    }

    /// A pool of `capacity` blocks over `n_layers` local layers.  The
    /// slabs are allocated zeroed up front — pre-allocation is the
    /// paper's own KV story, paging just changes the granularity.
    pub fn new(
        block_size: usize,
        n_layers: usize,
        kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        capacity: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(block_size > 0, "paged pool needs a nonzero block size");
        anyhow::ensure!(capacity > 0, "paged pool needs a nonzero block capacity");
        anyhow::ensure!(
            u32::try_from(capacity).is_ok(),
            "paged pool capacity {capacity} overflows block ids"
        );
        let dims = vec![
            capacity as i64,
            kv_heads as i64,
            block_size as i64,
            head_dim as i64,
        ];
        let len = capacity * kv_heads * block_size * head_dim;
        let slabs = (0..n_layers)
            .map(|_| {
                (
                    TensorData::f32(vec![0.0; len], dims.clone()),
                    TensorData::f32(vec![0.0; len], dims.clone()),
                )
            })
            .collect();
        Ok(PagedPool {
            block_size,
            kv_heads,
            head_dim,
            max_seq,
            capacity,
            free: (0..capacity as u32).rev().collect(),
            slabs,
            runs: HashMap::new(),
            peak_blocks: 0,
        })
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Bytes one block occupies on this stage.
    pub fn block_bytes(&self) -> u64 {
        Self::block_bytes_for(self.slabs.len(), self.kv_heads, self.block_size, self.head_dim)
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently held by rows (the complement of the free list).
    pub fn occupied_blocks(&self) -> usize {
        self.runs
            .values()
            .flat_map(|r| r.rows.iter().flatten())
            .map(|row| row.blocks.len())
            .sum()
    }

    /// Live bytes: occupied blocks × block bytes.
    pub fn used_bytes(&self) -> u64 {
        self.occupied_blocks() as u64 * self.block_bytes()
    }

    pub fn peak_blocks(&self) -> usize {
        self.peak_blocks
    }

    /// Blocks a row holding `written` positions occupies.
    pub fn blocks_for(&self, written: usize) -> usize {
        written.div_ceil(self.block_size)
    }

    /// Positions filled by row `slot` of run `run` (None if not live).
    pub fn row_written(&self, run: u64, slot: usize) -> Option<usize> {
        self.runs
            .get(&run)?
            .rows
            .get(slot)?
            .as_ref()
            .map(|r| r.written)
    }

    /// Row liveness + write watermarks of run `run`, or None if the run
    /// holds no rows here.
    pub fn run_occupancy(&self, run: u64) -> Option<(usize, Vec<bool>, Vec<usize>)> {
        let r = self.runs.get(&run)?;
        let live: Vec<bool> = r.rows.iter().map(|x| x.is_some()).collect();
        let written: Vec<usize> = r
            .rows
            .iter()
            .map(|x| x.as_ref().map(|row| row.written).unwrap_or(0))
            .collect();
        Some((r.batch, live, written))
    }

    /// Resident run ids (export walks the pool through this).
    pub fn run_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.runs.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    fn alloc_block(&mut self) -> anyhow::Result<u32> {
        let blk = self
            .free
            .pop()
            .ok_or_else(|| anyhow::anyhow!("paged pool dry: all {} blocks occupied", self.capacity))?;
        self.peak_blocks = self.peak_blocks.max(self.capacity - self.free.len());
        Ok(blk)
    }

    /// Zero block `blk` in every layer slab and return it to the free
    /// list (hygiene: a reallocated block starts clean, exactly like the
    /// padded pool's `evict_row`).
    fn release_block(&mut self, blk: u32) -> anyhow::Result<()> {
        let span = self.kv_heads * self.block_size * self.head_dim;
        let off = blk as usize * span;
        for (k, v) in self.slabs.iter_mut() {
            slab_mut(k)?[off..off + span].fill(0.0);
            slab_mut(v)?[off..off + span].fill(0.0);
        }
        self.free.push(blk);
        Ok(())
    }

    /// Install one prefilled (or swapped-back-in) sequence as row `slot`
    /// of run `run`, chopping the padded `[1, kv_heads, src_seq,
    /// head_dim]` per-layer tensors into `ceil(written / block_size)`
    /// blocks.  Returns the bytes the row now charges.
    pub fn admit_row(
        &mut self,
        run: u64,
        slot: usize,
        run_batch: usize,
        written: usize,
        layer_rows: &[(TensorData, TensorData)],
    ) -> anyhow::Result<u64> {
        anyhow::ensure!(slot < run_batch, "row {slot} outside run batch {run_batch}");
        anyhow::ensure!(
            layer_rows.len() == self.slabs.len(),
            "run {run}: {} layer rows for a {}-layer pool",
            layer_rows.len(),
            self.slabs.len()
        );
        anyhow::ensure!(
            written >= 1 && written <= self.max_seq,
            "run {run} row {slot}: written {written} outside 1..={}",
            self.max_seq
        );
        let n_blocks = self.blocks_for(written);
        anyhow::ensure!(
            self.free.len() >= n_blocks,
            "paged pool dry: admit needs {n_blocks} blocks, {} free of {}",
            self.free.len(),
            self.capacity_blocks()
        );
        {
            let r = self.runs.entry(run).or_insert_with(|| PagedRun {
                batch: run_batch,
                rows: vec![None; run_batch],
            });
            anyhow::ensure!(
                r.batch == run_batch,
                "run {run} pool has batch {}, admit says {run_batch}",
                r.batch
            );
            anyhow::ensure!(r.rows[slot].is_none(), "run {run} row {slot} already live");
        }
        let mut blocks = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            blocks.push(self.alloc_block()?);
        }
        for (li, (sk, sv)) in layer_rows.iter().enumerate() {
            self.chop_row(li, sk, sv, 0, written, &blocks)?;
        }
        let row = PagedRow { blocks, written };
        self.runs.get_mut(&run).unwrap().rows[slot] = Some(row);
        Ok(n_blocks as u64 * self.block_bytes())
    }

    /// Copy positions `0..written` of row `src_row` out of a padded
    /// `[batch, kv_heads, src_seq, head_dim]` (k, v) pair into `blocks`
    /// of layer `li`'s slabs.
    fn chop_row(
        &mut self,
        li: usize,
        src_k: &TensorData,
        src_v: &TensorData,
        src_row: usize,
        written: usize,
        blocks: &[u32],
    ) -> anyhow::Result<()> {
        let dims = src_k.dims().to_vec();
        anyhow::ensure!(
            dims.len() == 4
                && src_row < dims[0] as usize
                && dims[1] as usize == self.kv_heads
                && written <= dims[2] as usize
                && dims[3] as usize == self.head_dim,
            "chop: source dims {dims:?} can't hold row {src_row} × {written} positions"
        );
        let src_seq = dims[2] as usize;
        let (sk, sv) = (src_k.as_f32()?, src_v.as_f32()?);
        let (hd, bs, kv) = (self.head_dim, self.block_size, self.kv_heads);
        let (k, v) = &mut self.slabs[li];
        let dk = slab_mut(k)?;
        for p in 0..written {
            let blk = blocks[p / bs] as usize;
            for kh in 0..kv {
                let s = ((src_row * kv + kh) * src_seq + p) * hd;
                let d = ((blk * kv + kh) * bs + p % bs) * hd;
                dk[d..d + hd].copy_from_slice(&sk[s..s + hd]);
            }
        }
        let dv = slab_mut(v)?;
        for p in 0..written {
            let blk = blocks[p / bs] as usize;
            for kh in 0..kv {
                let s = ((src_row * kv + kh) * src_seq + p) * hd;
                let d = ((blk * kv + kh) * bs + p % bs) * hd;
                dv[d..d + hd].copy_from_slice(&sv[s..s + hd]);
            }
        }
        Ok(())
    }

    /// Install a padded [`GroupCache`] wholesale (group prefill, stage
    /// preload at migration): every live row is chopped at its own
    /// watermark.  Returns the bytes charged.
    pub fn admit_cache(&mut self, run: u64, cache: &GroupCache) -> anyhow::Result<u64> {
        anyhow::ensure!(
            cache.layers.len() == self.slabs.len(),
            "run {run}: {} cache layers for a {}-layer pool",
            cache.layers.len(),
            self.slabs.len()
        );
        anyhow::ensure!(
            cache.live.len() == cache.batch && cache.written.len() == cache.batch,
            "run {run}: liveness/watermark vectors don't match batch {}",
            cache.batch
        );
        anyhow::ensure!(!self.runs.contains_key(&run), "run {run} already resident");
        let mut need = 0usize;
        for b in 0..cache.batch {
            if cache.live[b] {
                anyhow::ensure!(
                    cache.written[b] >= 1 && cache.written[b] <= self.max_seq,
                    "run {run} row {b}: watermark {} outside 1..={}",
                    cache.written[b],
                    self.max_seq
                );
                need += self.blocks_for(cache.written[b]);
            }
        }
        anyhow::ensure!(
            self.free.len() >= need,
            "paged pool dry: run {run} needs {need} blocks, {} free of {}",
            self.free.len(),
            self.capacity
        );
        let mut rows: Vec<Option<PagedRow>> = vec![None; cache.batch];
        for b in 0..cache.batch {
            if !cache.live[b] {
                continue;
            }
            let written = cache.written[b];
            let n_blocks = self.blocks_for(written);
            let mut blocks = Vec::with_capacity(n_blocks);
            for _ in 0..n_blocks {
                blocks.push(self.alloc_block()?);
            }
            for (li, (sk, sv)) in cache.layers.iter().enumerate() {
                self.chop_row(li, sk, sv, b, written, &blocks)?;
            }
            rows[b] = Some(PagedRow { blocks, written });
        }
        self.runs.insert(
            run,
            PagedRun {
                batch: cache.batch,
                rows,
            },
        );
        Ok(need as u64 * self.block_bytes())
    }

    /// Extend every stepping row's block table to cover its write
    /// position — called once per decode iteration, *before* the layer
    /// loop, so one block allocation serves all layers.  `pos[i] < 0`
    /// marks a dead row; replay rewrites (`pos < written`) are
    /// idempotent and allocate nothing.
    pub fn prepare_step(&mut self, run: u64, pos: &[i32]) -> anyhow::Result<()> {
        for (slot, &p) in pos.iter().enumerate() {
            if p < 0 {
                continue;
            }
            let p = p as usize;
            anyhow::ensure!(p < self.max_seq, "run {run} row {slot}: pos {p} >= max_seq");
            let (needs_block, stale) = {
                let r = self
                    .runs
                    .get(&run)
                    .ok_or_else(|| anyhow::anyhow!("step: run {run} has no pool rows"))?;
                let row = r.rows[slot]
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("step: run {run} row {slot} not live"))?;
                anyhow::ensure!(
                    p <= row.written,
                    "run {run} row {slot}: write at {p} skips past watermark {}",
                    row.written
                );
                (p == row.written && p % self.block_size == 0, p < row.written)
            };
            if stale {
                continue; // replay rewrite into an existing block
            }
            let blk = if needs_block { Some(self.alloc_block()?) } else { None };
            let row = self.runs.get_mut(&run).unwrap().rows[slot].as_mut().unwrap();
            if let Some(b) = blk {
                row.blocks.push(b);
            }
            row.written = p + 1;
        }
        Ok(())
    }

    /// Write one row's freshly computed K/V head vectors at position `p`
    /// of layer `layer` (the block must already exist — see
    /// [`Self::prepare_step`]).  `k_new`/`v_new` are `kv_heads × head_dim`
    /// slices of the kernel's `[batch, kv_heads, head_dim]` outputs.
    pub fn write_pos(
        &mut self,
        layer: usize,
        run: u64,
        slot: usize,
        p: usize,
        k_new: &[f32],
        v_new: &[f32],
    ) -> anyhow::Result<()> {
        let blk = {
            let r = self
                .runs
                .get(&run)
                .ok_or_else(|| anyhow::anyhow!("write: run {run} has no pool rows"))?;
            let row = r.rows[slot]
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("write: run {run} row {slot} not live"))?;
            anyhow::ensure!(p < row.written, "write at {p} beyond watermark {}", row.written);
            row.blocks[p / self.block_size] as usize
        };
        let (k, v) = &mut self.slabs[layer];
        let dk = slab_mut(k)?;
        for kh in 0..self.kv_heads {
            let d = ((blk * self.kv_heads + kh) * self.block_size + (p % self.block_size))
                * self.head_dim;
            dk[d..d + self.head_dim]
                .copy_from_slice(&k_new[kh * self.head_dim..(kh + 1) * self.head_dim]);
        }
        let dv = slab_mut(v)?;
        for kh in 0..self.kv_heads {
            let d = ((blk * self.kv_heads + kh) * self.block_size + (p % self.block_size))
                * self.head_dim;
            dv[d..d + self.head_dim]
                .copy_from_slice(&v_new[kh * self.head_dim..(kh + 1) * self.head_dim]);
        }
        Ok(())
    }

    /// The (k, v) slab pair of layer `layer` (cheap `Arc` clones for a
    /// kernel call).
    pub fn layer_slabs(&self, layer: usize) -> (TensorData, TensorData) {
        let (k, v) = &self.slabs[layer];
        (k.clone(), v.clone())
    }

    /// Block table of run `run` as an i32 `[batch, ceil(max_seq /
    /// block_size)]` tensor, `-1`-filled past each row's blocks (and for
    /// dead rows — the kernel never dereferences them).
    pub fn table(&self, run: u64) -> anyhow::Result<TensorData> {
        let r = self
            .runs
            .get(&run)
            .ok_or_else(|| anyhow::anyhow!("table: run {run} has no pool rows"))?;
        let width = self.max_seq.div_ceil(self.block_size);
        let mut t = vec![-1i32; r.batch * width];
        for (slot, row) in r.rows.iter().enumerate() {
            if let Some(row) = row {
                for (bi, &blk) in row.blocks.iter().enumerate() {
                    t[slot * width + bi] = blk as i32;
                }
            }
        }
        Ok(TensorData::i32(t, vec![r.batch as i64, width as i64]))
    }

    /// Retire row `slot` of run `run`: zero + free its blocks.  Returns
    /// the freed bytes.
    pub fn evict_row(&mut self, run: u64, slot: usize) -> anyhow::Result<u64> {
        let row = {
            let r = self
                .runs
                .get_mut(&run)
                .ok_or_else(|| anyhow::anyhow!("evict: run {run} has no pool rows"))?;
            anyhow::ensure!(slot < r.batch, "evict: row {slot} outside batch {}", r.batch);
            r.rows[slot]
                .take()
                .ok_or_else(|| anyhow::anyhow!("evict: run {run} row {slot} not live"))?
        };
        let freed = row.blocks.len() as u64 * self.block_bytes();
        for blk in row.blocks {
            self.release_block(blk)?;
        }
        Ok(freed)
    }

    /// Recompose run `run` at `new_batch` rows, moving `from → to` for
    /// each pair.  A pure block-table remap — **no KV bytes move**, which
    /// is the paged layout's win over the padded `compact`'s full-tensor
    /// rebuild.  Live rows left unnamed are released, matching the padded
    /// semantics failover leans on.
    pub fn compact(
        &mut self,
        run: u64,
        new_batch: usize,
        moves: &[(usize, usize)],
    ) -> anyhow::Result<()> {
        let mut new_rows: Vec<Option<PagedRow>> = vec![None; new_batch];
        let dropped: Vec<PagedRow> = {
            let r = self
                .runs
                .get_mut(&run)
                .ok_or_else(|| anyhow::anyhow!("compact: run {run} has no pool rows"))?;
            for &(from, to) in moves {
                anyhow::ensure!(
                    from < r.batch && to < new_batch,
                    "compact: move {from}→{to} outside {}→{new_batch}",
                    r.batch
                );
                anyhow::ensure!(r.rows[from].is_some(), "compact: moving dead row {from}");
                anyhow::ensure!(new_rows[to].is_none(), "compact: duplicate target row {to}");
                new_rows[to] = r.rows[from].take();
            }
            let dropped = r.rows.iter_mut().filter_map(|x| x.take()).collect();
            r.rows = new_rows;
            r.batch = new_batch;
            dropped
        };
        for row in dropped {
            for blk in row.blocks {
                self.release_block(blk)?;
            }
        }
        Ok(())
    }

    /// Release every row of run `run` (the `Free` frame / run teardown).
    pub fn remove_run(&mut self, run: u64) -> anyhow::Result<u64> {
        let Some(r) = self.runs.remove(&run) else {
            return Ok(0);
        };
        let mut freed = 0u64;
        for row in r.rows.into_iter().flatten() {
            freed += row.blocks.len() as u64 * self.block_bytes();
            for blk in row.blocks {
                self.release_block(blk)?;
            }
        }
        Ok(freed)
    }

    /// Reconstruct run `run` as a padded [`GroupCache`] — byte-identical
    /// to what a padded pool would hold (positions past each row's
    /// watermark zeroed) — for the `Export` snapshot path.  `bytes` is
    /// the run's **live-block** footprint, so checkpoint/migration
    /// freight is charged for what actually moves, not the padding.
    pub fn reconstruct_padded(&self, run: u64) -> anyhow::Result<GroupCache> {
        let r = self
            .runs
            .get(&run)
            .ok_or_else(|| anyhow::anyhow!("export: run {run} has no pool rows"))?;
        let mut layers = Vec::with_capacity(self.slabs.len());
        for (k, v) in &self.slabs {
            let (sk, sv) = (k.as_f32()?, v.as_f32()?);
            let dims = vec![
                r.batch as i64,
                self.kv_heads as i64,
                self.max_seq as i64,
                self.head_dim as i64,
            ];
            let len = r.batch * self.kv_heads * self.max_seq * self.head_dim;
            let (mut dk, mut dv) = (vec![0.0f32; len], vec![0.0f32; len]);
            for (slot, row) in r.rows.iter().enumerate() {
                let Some(row) = row else { continue };
                for p in 0..row.written {
                    let blk = row.blocks[p / self.block_size] as usize;
                    for kh in 0..self.kv_heads {
                        let s = ((blk * self.kv_heads + kh) * self.block_size
                            + (p % self.block_size))
                            * self.head_dim;
                        let d = ((slot * self.kv_heads + kh) * self.max_seq + p) * self.head_dim;
                        dk[d..d + self.head_dim].copy_from_slice(&sk[s..s + self.head_dim]);
                        dv[d..d + self.head_dim].copy_from_slice(&sv[s..s + self.head_dim]);
                    }
                }
            }
            layers.push((TensorData::f32(dk, dims.clone()), TensorData::f32(dv, dims)));
        }
        let live: Vec<bool> = r.rows.iter().map(|x| x.is_some()).collect();
        let written: Vec<usize> = r
            .rows
            .iter()
            .map(|x| x.as_ref().map(|row| row.written).unwrap_or(0))
            .collect();
        let blocks: u64 = r
            .rows
            .iter()
            .flatten()
            .map(|row| row.blocks.len() as u64)
            .sum();
        Ok(GroupCache {
            layers,
            batch: r.batch,
            bytes: blocks * self.block_bytes(),
            live,
            written,
        })
    }

    /// Extract row `slot` of run `run` as compact per-layer `[1,
    /// kv_heads, blocks × block_size, head_dim]` tensors (the swap-out
    /// freight: exactly the live blocks, no `max_seq` padding).  The row
    /// stays resident — pair with [`Self::evict_row`] to complete the
    /// swap-out.
    pub fn extract_row(
        &self,
        run: u64,
        slot: usize,
    ) -> anyhow::Result<(usize, Vec<(TensorData, TensorData)>)> {
        let r = self
            .runs
            .get(&run)
            .ok_or_else(|| anyhow::anyhow!("extract: run {run} has no pool rows"))?;
        let row = r.rows[slot]
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("extract: run {run} row {slot} not live"))?;
        let seq = row.blocks.len() * self.block_size;
        let dims = vec![1, self.kv_heads as i64, seq as i64, self.head_dim as i64];
        let len = self.kv_heads * seq * self.head_dim;
        let mut out = Vec::with_capacity(self.slabs.len());
        for (k, v) in &self.slabs {
            let (sk, sv) = (k.as_f32()?, v.as_f32()?);
            let (mut dk, mut dv) = (vec![0.0f32; len], vec![0.0f32; len]);
            for p in 0..row.written {
                let blk = row.blocks[p / self.block_size] as usize;
                for kh in 0..self.kv_heads {
                    let s = ((blk * self.kv_heads + kh) * self.block_size
                        + (p % self.block_size))
                        * self.head_dim;
                    let d = (kh * seq + p) * self.head_dim;
                    dk[d..d + self.head_dim].copy_from_slice(&sk[s..s + self.head_dim]);
                    dv[d..d + self.head_dim].copy_from_slice(&sv[s..s + self.head_dim]);
                }
            }
            out.push((TensorData::f32(dk, dims.clone()), TensorData::f32(dv, dims.clone())));
        }
        Ok((row.written, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_cache(bytes: u64) -> GroupCache {
        GroupCache {
            layers: vec![],
            batch: 1,
            bytes,
            live: vec![true],
            written: vec![0],
        }
    }

    /// A `[1, kv, seq, hd]` row tensor with every element `fill`.
    fn row(kv: usize, seq: usize, hd: usize, fill: f32) -> (TensorData, TensorData) {
        let dims = vec![1, kv as i64, seq as i64, hd as i64];
        let len = kv * seq * hd;
        (
            TensorData::f32(vec![fill; len], dims.clone()),
            TensorData::f32(vec![-fill; len], dims),
        )
    }

    #[test]
    fn admit_and_release() {
        let mut p = KvPool::new(1000);
        assert!(p.can_admit(600));
        p.insert(1, dummy_cache(600)).unwrap();
        assert_eq!(p.used_bytes(), 600);
        assert!(!p.can_admit(600));
        assert!(p.insert(2, dummy_cache(600)).is_err());
        p.insert(3, dummy_cache(400)).unwrap();
        assert_eq!(p.len(), 2);
        p.remove(1).unwrap();
        assert_eq!(p.used_bytes(), 400);
        assert!(p.can_admit(600));
        assert_eq!(p.peak_bytes(), 1000);
    }

    #[test]
    fn mask_batch_mismatch_rejected() {
        let mut p = KvPool::new(100);
        let bad = GroupCache {
            layers: vec![],
            batch: 4,
            bytes: 10,
            live: vec![true], // 1 flag for 4 rows
            written: vec![0; 4],
        };
        assert!(p.insert(1, bad).is_err());
        assert_eq!(p.used_bytes(), 0);
    }

    #[test]
    fn duplicate_group_rejected() {
        let mut p = KvPool::new(100);
        p.insert(1, dummy_cache(10)).unwrap();
        assert!(p.insert(1, dummy_cache(10)).is_err());
    }

    #[test]
    fn remove_missing_is_none() {
        let mut p = KvPool::new(100);
        assert!(p.remove(42).is_none());
    }

    #[test]
    fn group_bytes_formula() {
        // 4 layers, batch 8, 4 kv heads, 128 seq, 32 dim, f32:
        // 4*2*8*4*128*32*4 = 4 MiB
        assert_eq!(KvPool::group_bytes(4, 8, 4, 128, 32, ELEM_BYTES_F32), 4 * 1024 * 1024);
    }

    /// Regression for the hardcoded `* 4` the formula used to bake in:
    /// element size must scale the result, so a 2-byte (fp16) wire
    /// charges exactly half the fp32 bytes and a 1-byte (int8) wire a
    /// quarter.
    #[test]
    fn group_bytes_scales_with_element_size() {
        let f32_bytes = KvPool::group_bytes(4, 8, 4, 128, 32, 4);
        assert_eq!(KvPool::group_bytes(4, 8, 4, 128, 32, 2), f32_bytes / 2);
        assert_eq!(KvPool::group_bytes(4, 8, 4, 128, 32, 1), f32_bytes / 4);
        assert_eq!(ELEM_BYTES_F32, 4);
    }

    #[test]
    fn row_insert_evict_accounting() {
        let (kv, seq, hd) = (2, 4, 2);
        let row_bytes = (2 * 2 * kv * seq * hd * 4) as u64; // 2 layers × (k+v)
        let mut p = KvPool::new(10 * row_bytes);
        p.insert_row(9, 0, 4, seq, vec![row(kv, seq, hd, 1.0), row(kv, seq, hd, 2.0)])
            .unwrap();
        assert_eq!(p.used_bytes(), row_bytes);
        p.insert_row(9, 2, 4, seq, vec![row(kv, seq, hd, 3.0), row(kv, seq, hd, 4.0)])
            .unwrap();
        assert_eq!(p.used_bytes(), 2 * row_bytes);
        let c = p.get(9).unwrap();
        assert_eq!(c.batch, 4);
        assert_eq!(c.live, vec![true, false, true, false]);
        // row 0 of layer 0 carries 1.0s, row 2 carries 3.0s, dead rows zero
        let k0 = c.layers[0].0.as_f32().unwrap();
        let row_len = kv * seq * hd;
        assert!(k0[..row_len].iter().all(|&x| x == 1.0));
        assert!(k0[row_len..2 * row_len].iter().all(|&x| x == 0.0));
        assert!(k0[2 * row_len..3 * row_len].iter().all(|&x| x == 3.0));

        // double-admit and dead-evict are rejected
        assert!(p
            .insert_row(9, 0, 4, seq, vec![row(kv, seq, hd, 9.0), row(kv, seq, hd, 9.0)])
            .is_err());
        assert!(p.evict_row(9, 1).is_err());

        assert_eq!(p.evict_row(9, 0).unwrap(), row_bytes);
        assert_eq!(p.used_bytes(), row_bytes);
        // evicted row zeroed; slot can be re-admitted
        let c = p.get(9).unwrap();
        assert!(c.layers[0].0.as_f32().unwrap()[..row_len].iter().all(|&x| x == 0.0));
        p.insert_row(9, 0, 4, seq, vec![row(kv, seq, hd, 5.0), row(kv, seq, hd, 5.0)])
            .unwrap();
        assert_eq!(p.used_bytes(), 2 * row_bytes);
        p.evict_row(9, 0).unwrap();
        p.evict_row(9, 2).unwrap();
        assert_eq!(p.used_bytes(), 0);
        // the (empty) cache allocation itself charges nothing; remove drops it
        p.remove(9).unwrap();
        assert_eq!(p.used_bytes(), 0);
    }

    #[test]
    fn compact_moves_rows_and_bytes() {
        let (kv, seq, hd) = (2, 4, 2);
        let row_len = kv * seq * hd;
        let mut p = KvPool::new(1 << 20);
        p.insert_row(5, 1, 8, seq, vec![row(kv, seq, hd, 1.0)]).unwrap();
        p.insert_row(5, 6, 8, seq, vec![row(kv, seq, hd, 2.0)]).unwrap();
        let row_bytes = p.get(5).unwrap().row_bytes();
        assert_eq!(p.used_bytes(), 2 * row_bytes);
        p.compact(5, 2, &[(1, 0), (6, 1)]).unwrap();
        let c = p.get(5).unwrap();
        assert_eq!(c.batch, 2);
        assert_eq!(c.live, vec![true, true]);
        let k = c.layers[0].0.as_f32().unwrap();
        assert!(k[..row_len].iter().all(|&x| x == 1.0));
        assert!(k[row_len..].iter().all(|&x| x == 2.0));
        assert_eq!(p.used_bytes(), 2 * row_bytes);
        // dropping a row via compact releases its bytes
        p.compact(5, 1, &[(0, 0)]).unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(p.used_bytes(), row_bytes);
        // duplicate targets are rejected
        assert!(p.compact(5, 1, &[(0, 0), (0, 0)]).is_err());
    }

    // ---- paged pool ----

    /// A `[1, kv, seq, hd]` row pair whose element at (kh, p, d) encodes
    /// its own coordinates — catches any index shuffle in the chop /
    /// gather / reconstruct paths.
    fn coded_row(kv: usize, seq: usize, hd: usize, tag: f32) -> (TensorData, TensorData) {
        let dims = vec![1, kv as i64, seq as i64, hd as i64];
        let mut k = vec![0.0f32; kv * seq * hd];
        for kh in 0..kv {
            for p in 0..seq {
                for d in 0..hd {
                    k[(kh * seq + p) * hd + d] = tag + (kh * 1000 + p * 10 + d) as f32;
                }
            }
        }
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        (TensorData::f32(k, dims.clone()), TensorData::f32(v, dims))
    }

    #[test]
    fn paged_admit_roundtrips_through_padded_reconstruction() {
        let (bs, kv, hd, ms) = (4usize, 2usize, 3usize, 16usize);
        let mut p = PagedPool::new(bs, 2, kv, hd, ms, 8).unwrap();
        let written = 6; // 2 blocks: one full, one half
        let rows = vec![coded_row(kv, ms, hd, 100.0), coded_row(kv, ms, hd, 5000.0)];
        let charged = p.admit_row(7, 1, 4, written, &rows).unwrap();
        assert_eq!(charged, 2 * p.block_bytes());
        assert_eq!(p.occupied_blocks(), 2);
        assert_eq!(p.used_bytes(), 2 * p.block_bytes());

        let c = p.reconstruct_padded(7).unwrap();
        assert_eq!(c.batch, 4);
        assert_eq!(c.live, vec![false, true, false, false]);
        assert_eq!(c.written, vec![0, written, 0, 0]);
        assert_eq!(c.bytes, 2 * p.block_bytes());
        for (li, (src_k, _)) in rows.iter().enumerate() {
            let (sk, rk) = (src_k.as_f32().unwrap(), c.layers[li].0.as_f32().unwrap());
            for kh in 0..kv {
                for pos in 0..ms {
                    for d in 0..hd {
                        let got = rk[((kv + kh) * ms + pos) * hd + d]; // row 1
                        let want = if pos < written { sk[(kh * ms + pos) * hd + d] } else { 0.0 };
                        assert_eq!(got, want, "layer {li} kh {kh} pos {pos} d {d}");
                    }
                }
            }
        }
    }

    #[test]
    fn paged_step_allocates_only_on_block_boundaries() {
        let (bs, kv, hd, ms) = (4usize, 1usize, 2usize, 16usize);
        let mut p = PagedPool::new(bs, 1, kv, hd, ms, 4).unwrap();
        p.admit_row(1, 0, 1, 3, &[row(kv, ms, hd, 1.0)]).unwrap();
        assert_eq!(p.occupied_blocks(), 1);
        // pos 3 fits the half-full block
        p.prepare_step(1, &[3]).unwrap();
        assert_eq!(p.occupied_blocks(), 1);
        assert_eq!(p.row_written(1, 0), Some(4));
        // pos 4 crosses a boundary → new block
        p.prepare_step(1, &[4]).unwrap();
        assert_eq!(p.occupied_blocks(), 2);
        p.write_pos(0, 1, 0, 4, &[7.0, 8.0], &[-7.0, -8.0]).unwrap();
        // replay rewrite at an old position allocates nothing
        p.prepare_step(1, &[2]).unwrap();
        assert_eq!(p.occupied_blocks(), 2);
        assert_eq!(p.row_written(1, 0), Some(5));
        // skipping past the watermark is rejected
        assert!(p.prepare_step(1, &[9]).is_err());
        // dead rows are ignored
        p.prepare_step(1, &[-1]).unwrap();

        let c = p.reconstruct_padded(1).unwrap();
        let k = c.layers[0].0.as_f32().unwrap();
        assert_eq!(&k[4 * hd..5 * hd], &[7.0, 8.0]);
        assert!(k[..3 * hd].iter().all(|&x| x == 1.0));
        assert!(k[5 * hd..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn paged_compact_remaps_tables_without_moving_bytes() {
        let (bs, kv, hd, ms) = (4usize, 1usize, 2usize, 8usize);
        let mut p = PagedPool::new(bs, 1, kv, hd, ms, 6).unwrap();
        p.admit_row(3, 1, 4, 5, &[row(kv, ms, hd, 1.0)]).unwrap();
        p.admit_row(3, 3, 4, 2, &[row(kv, ms, hd, 2.0)]).unwrap();
        assert_eq!(p.occupied_blocks(), 3);
        p.compact(3, 2, &[(1, 0), (3, 1)]).unwrap();
        assert_eq!(p.occupied_blocks(), 3); // nothing freed, nothing copied
        let (batch, live, written) = p.run_occupancy(3).unwrap();
        assert_eq!((batch, live, written), (2, vec![true, true], vec![5, 2]));
        let c = p.reconstruct_padded(3).unwrap();
        let k = c.layers[0].0.as_f32().unwrap();
        assert!(k[..5 * hd].iter().all(|&x| x == 1.0)); // row 0 = old row 1
        let r1 = &k[ms * hd..];
        assert!(r1[..2 * hd].iter().all(|&x| x == 2.0)); // row 1 = old row 3
        // unnamed live rows are released by compact
        p.compact(3, 1, &[(0, 0)]).unwrap();
        assert_eq!(p.occupied_blocks(), 2);
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    fn paged_extract_row_carries_exactly_the_live_blocks() {
        let (bs, kv, hd, ms) = (4usize, 2usize, 2usize, 16usize);
        let mut p = PagedPool::new(bs, 1, kv, hd, ms, 8).unwrap();
        let src = coded_row(kv, ms, hd, 0.0);
        p.admit_row(2, 0, 2, 6, &[src.clone()]).unwrap();
        let (written, freight) = p.extract_row(2, 0).unwrap();
        assert_eq!(written, 6);
        // freight is 2 blocks = 8 positions, not max_seq = 16
        assert_eq!(freight[0].0.dims(), &[1, kv as i64, 8, hd as i64]);
        // swap back in to a fresh pool: byte-identical reconstruction
        let mut p2 = PagedPool::new(bs, 1, kv, hd, ms, 8).unwrap();
        p2.admit_row(2, 0, 2, written, &freight).unwrap();
        let (a, b) = (p.reconstruct_padded(2).unwrap(), p2.reconstruct_padded(2).unwrap());
        assert_eq!(a.layers[0].0.as_f32().unwrap(), b.layers[0].0.as_f32().unwrap());
        assert_eq!(a.layers[0].1.as_f32().unwrap(), b.layers[0].1.as_f32().unwrap());
    }

    #[test]
    fn paged_admission_fails_closed_when_dry() {
        let (bs, kv, hd, ms) = (4usize, 1usize, 2usize, 16usize);
        let mut p = PagedPool::new(bs, 1, kv, hd, ms, 2).unwrap();
        p.admit_row(1, 0, 2, 8, &[row(kv, ms, hd, 1.0)]).unwrap();
        assert_eq!(p.free_blocks(), 0);
        // admit with zero free blocks: rejected, state untouched
        assert!(p.admit_row(1, 1, 2, 1, &[row(kv, ms, hd, 2.0)]).is_err());
        assert_eq!(p.occupied_blocks(), 2);
        // step onto a boundary with zero free blocks: rejected
        assert!(p.prepare_step(1, &[8]).is_err());
        assert_eq!(p.row_written(1, 0), Some(8));
        // eviction recovers the blocks and they are clean on reuse
        p.evict_row(1, 0).unwrap();
        assert_eq!(p.free_blocks(), 2);
        p.admit_row(1, 0, 2, 1, &[row(kv, ms, hd, 3.0)]).unwrap();
        let c = p.reconstruct_padded(1).unwrap();
        let k = c.layers[0].0.as_f32().unwrap();
        assert!(k[hd..ms * hd].iter().all(|&x| x == 0.0));
    }

    /// Block-pool invariants under randomized admit/append/evict/compact
    /// sequences (hand-rolled property test — no proptest crate in the
    /// vendored set).  After every operation:
    ///   1. no block id is ever held by two rows or by a row and the
    ///      free list (never double-allocate),
    ///   2. free-list + occupied blocks sum to pool capacity,
    ///   3. `used_bytes` equals live blocks × block bytes.
    #[test]
    fn paged_pool_invariants_under_random_ops() {
        let (bs, kv, hd, ms) = (4usize, 2usize, 2usize, 32usize);
        for seed in 0..20u64 {
            let mut rng = crate::util::Rng::new(0xB10C + seed);
            let capacity = 4 + rng.next_below(28) as usize;
            let mut p = PagedPool::new(bs, 2, kv, hd, ms, capacity).unwrap();
            // mirror: run → rows → written (None = dead)
            let mut mirror: HashMap<u64, Vec<Option<usize>>> = HashMap::new();
            let check = |p: &PagedPool, mirror: &HashMap<u64, Vec<Option<usize>>>| {
                let mut seen: Vec<u32> = p
                    .runs
                    .values()
                    .flat_map(|r| r.rows.iter().flatten())
                    .flat_map(|row| row.blocks.iter().copied())
                    .chain(p.free.iter().copied())
                    .collect();
                seen.sort_unstable();
                let all: Vec<u32> = (0..capacity as u32).collect();
                assert_eq!(seen, all, "seed {seed}: block ids not a permutation of the pool");
                assert_eq!(
                    p.free_blocks() + p.occupied_blocks(),
                    capacity,
                    "seed {seed}: free + occupied != capacity"
                );
                assert_eq!(
                    p.used_bytes(),
                    p.occupied_blocks() as u64 * p.block_bytes(),
                    "seed {seed}: used_bytes drifted from live blocks"
                );
                let expect_occ: usize = mirror
                    .values()
                    .flat_map(|rows| rows.iter().flatten())
                    .map(|w| w.div_ceil(bs))
                    .sum();
                assert_eq!(p.occupied_blocks(), expect_occ, "seed {seed}: mirror drift");
            };
            for _ in 0..300 {
                let run = 1 + rng.next_below(3);
                match rng.next_below(10) {
                    // admit into a free slot of a batch-4 run
                    0..=3 => {
                        let rows = mirror.entry(run).or_insert_with(|| vec![None; 4]);
                        let slot = rng.next_below(4) as usize;
                        if rows[slot].is_none() {
                            let written = 1 + rng.next_below(ms as u64 - 1) as usize;
                            let need = written.div_ceil(bs);
                            let lr =
                                vec![row(kv, ms, hd, 1.0), row(kv, ms, hd, 2.0)];
                            let free_before = p.free_blocks();
                            let res = p.admit_row(run, slot, 4, written, &lr);
                            if need <= free_before {
                                res.unwrap_or_else(|e| {
                                    panic!("seed {seed}: admit failed with room to spare: {e}")
                                });
                                rows[slot] = Some(written);
                            } else {
                                assert!(res.is_err(), "seed {seed}: admit succeeded past budget");
                            }
                        }
                    }
                    // append: step one live row at its watermark (or replay below it)
                    4..=6 => {
                        if let Some(rows) = mirror.get_mut(&run) {
                            let slot = rng.next_below(4) as usize;
                            if let Some(w) = rows[slot] {
                                let replay = w > 1 && rng.next_below(4) == 0;
                                let pos = if replay {
                                    rng.next_below(w as u64) as usize
                                } else {
                                    w
                                };
                                if pos >= ms {
                                    continue;
                                }
                                let mut pv = vec![-1i32; 4];
                                pv[slot] = pos as i32;
                                let needs = pos == w && pos % bs == 0;
                                let res = p.prepare_step(run, &pv);
                                if res.is_ok() {
                                    rows[slot] = Some(w.max(pos + 1));
                                } else {
                                    assert!(
                                        needs && p.free_blocks() == 0,
                                        "seed {seed}: step failed with free blocks"
                                    );
                                }
                            }
                        }
                    }
                    // evict one live row
                    7..=8 => {
                        if let Some(rows) = mirror.get_mut(&run) {
                            let slot = rng.next_below(4) as usize;
                            if rows[slot].is_some() {
                                p.evict_row(run, slot).unwrap();
                                rows[slot] = None;
                            }
                        }
                    }
                    // compact the run down to its live rows (or drop it)
                    _ => {
                        if let Some(rows) = mirror.get_mut(&run) {
                            let live: Vec<usize> = (0..rows.len())
                                .filter(|&i| rows[i].is_some())
                                .collect();
                            if live.is_empty() {
                                p.remove_run(run).unwrap();
                                mirror.remove(&run);
                            } else {
                                let moves: Vec<(usize, usize)> =
                                    live.iter().enumerate().map(|(to, &from)| (from, to)).collect();
                                p.compact(run, live.len().max(4), &moves).unwrap();
                                let mut nr = vec![None; live.len().max(4)];
                                for (to, &from) in live.iter().enumerate() {
                                    nr[to] = rows[from];
                                }
                                *rows = nr;
                            }
                        }
                    }
                }
                check(&p, &mirror);
            }
        }
    }
}
