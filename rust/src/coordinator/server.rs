//! JSON-lines TCP serving front-end.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"id": 1, "prompt": "Today is a", "max_new_tokens": 16}
//! ← {"id": 1, "text": "…", "tokens": [..], "ttft_ms": 12.3, "total_ms": 87.0}
//! ```
//!
//! Requests may carry an SLO class and a TTFT deadline:
//! `{"prompt": "…", "class": "batch"}` queues in the batch class (default
//! `"interactive"`), and `{"prompt": "…", "deadline_ms": 150}` asks the
//! server to drop the request rather than serve a first token later than
//! 150 ms after arrival.  Under an SLO admission policy
//! ([`AdmissionPolicy::SloPriority`]) overload is answered with
//! **structured rejects** instead of unbounded queueing:
//!
//! ```text
//! ← {"id": 7, "shed": true, "class": "batch", "error": "shed: batch queue at bound"}
//! ← {"id": 9, "expired": true, "class": "interactive", "waited_ms": 162.1, "error": "…"}
//! ```
//!
//! A shed reply is written the moment the class queue is at its bound —
//! that is the backpressure: a client sees the reject immediately (the
//! serving stack never buffers more than the class bounds), instead of
//! its request silently queueing forever.
//!
//! Besides generation requests the protocol answers one control command:
//! `{"cmd": "metrics"}` replies with a [`crate::obs::MetricsRegistry`]
//! snapshot (counters, gauges, histogram summaries) without entering the
//! serving queue — a live health probe that stays answerable even while
//! the serving queue is saturated (asserted by `tests/overload.rs`).
//!
//! Requests are byte-tokenized (the tiny model's 256-entry vocabulary)
//! and served **continuously**: every connection handler feeds a shared
//! [`LiveSource`], and one [`Engine::generate_from_source`] drive admits
//! each request into a compiled batch slot the moment capacity frees up
//! — no gather window, no fixed-group packing.  A request's reply is
//! written the instant it retires (mid-drive), and its reported
//! `ttft_ms` is measured from when the handler parsed it, so queue wait
//! under load is visible to the client.  This is the demo front door,
//! not a hardened production server.
//!
//! ## Lifecycle
//!
//! `serve` owns three kinds of thread: one **acceptor** (blocking
//! `accept` loop), one **handler** per connection (blocking line reads
//! with a short read timeout so it can observe shutdown), and the
//! calling thread, which runs the serving drive itself.  When
//! `max_requests` is reached the drive returns, the acceptor is woken
//! with a loopback connection and joined, and every handler is joined —
//! repeated in-process serves (tests) don't accumulate threads.

use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{AdmissionPolicy, AdmissionQueue, IncomingRequest, LiveSource};
use super::api::{GenRequest, GenResult, ServeReply, SloClass};
use super::engine::Engine;
use super::router::{drive_replicated, RouterConfig};
use super::scheduler::ContinuousConfig;
use crate::util::Json;
use crate::workload::Corpus;

/// How long a handler's blocking line read may sleep before it re-checks
/// the shutdown flag.
const HANDLER_READ_TIMEOUT: Duration = Duration::from_millis(100);

/// Server tuning knobs.
#[derive(Debug, Clone, Default)]
pub struct ServerConfig {
    /// Stop after serving this many requests (None = run forever).
    pub max_requests: Option<usize>,
    /// Continuous-batching knobs (runs, max batch, …).
    pub continuous: ContinuousConfig,
    /// Admission policy ([`AdmissionPolicy::Fifo`], or a bound on how
    /// many prefills may delay an in-flight decode step).
    pub policy: AdmissionPolicy,
    /// Registry answering `{"cmd": "metrics"}` probes.  Share it with
    /// the engine ([`Engine::set_metrics`]) so the snapshot carries the
    /// serving counters; the default (off) registry answers
    /// `{"enabled": false}`.
    pub metrics: crate::obs::MetricsRegistry,
}

/// Run the serving loop on `listener` until `max_requests` (if set) have
/// been answered, then tear every server thread down.  Returns the
/// number served.
pub fn serve(listener: TcpListener, engine: &mut Engine, cfg: &ServerConfig) -> Result<usize> {
    let addr = listener.local_addr().context("listener addr")?;
    let (in_tx, in_rx) = mpsc::channel::<IncomingRequest>();
    let stop = Arc::new(AtomicBool::new(false));
    let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let acceptor = spawn_acceptor(listener, &stop, &handlers, in_tx, cfg.metrics.clone())?;

    // the serving drive: continuous batching over the live source, until
    // the source closes (max_requests accepted, all of them served)
    let source = LiveSource::new(in_rx, cfg.max_requests, engine.max_new_cap());
    let mut queue = AdmissionQueue::new(Box::new(source), cfg.policy.clone());
    let drive = engine.generate_from_source(&mut queue, &cfg.continuous);

    // tear down whether the drive succeeded or not: wake the acceptor
    // out of its blocking accept with a loopback connection, then join
    // it and every handler (handlers wake on their read timeout).
    // Dropping the queue first is load-bearing: it drops every request
    // the closed source never accepted, erroring their handlers' reply
    // waits — otherwise those joins would deadlock.
    stop.store(true, Ordering::Relaxed);
    drop(queue);
    join_server_threads(addr, acceptor, &handlers);

    let (results, _stats) = drive?;
    Ok(results.len())
}

/// [`serve`] over K pipeline replicas behind a
/// [`super::router::Router`]: every connection feeds one shared
/// [`LiveSource`]; the router scores each request onto a replica
/// (least outstanding work, session affinity via the request's
/// `"session"` field) and each replica runs its own serving drive.
/// `cfg.policy` governs the per-replica admission queues (the
/// `rcfg.policy` field is overwritten); `rcfg` controls routing,
/// failover, and respawn.  Returns the number of requests answered with
/// a result.
pub fn serve_replicated(
    listener: TcpListener,
    engines: Vec<Engine>,
    cfg: &ServerConfig,
    mut rcfg: RouterConfig,
) -> Result<usize> {
    anyhow::ensure!(!engines.is_empty(), "serve_replicated needs at least one engine");
    let addr = listener.local_addr().context("listener addr")?;
    let (in_tx, in_rx) = mpsc::channel::<IncomingRequest>();
    let stop = Arc::new(AtomicBool::new(false));
    let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let acceptor = spawn_acceptor(listener, &stop, &handlers, in_tx, cfg.metrics.clone())?;

    // every replica clamps to the tightest compiled shape so any replica
    // can serve any request
    let max_new_cap = engines.iter().map(|e| e.max_new_cap()).min().unwrap_or(1);
    let source = LiveSource::new(in_rx, cfg.max_requests, max_new_cap);
    rcfg.policy = cfg.policy.clone();
    let outcome = drive_replicated(engines, Box::new(source), &cfg.continuous, &rcfg);

    // same teardown as `serve`: by the time `drive_replicated` returns
    // the router (and with it the live source) is dropped, so pending
    // reply waits have already errored out.
    stop.store(true, Ordering::Relaxed);
    join_server_threads(addr, acceptor, &handlers);

    Ok(outcome?.results.len())
}

/// Acceptor thread: one handler thread per connection.
fn spawn_acceptor(
    listener: TcpListener,
    stop: &Arc<AtomicBool>,
    handlers: &Arc<Mutex<Vec<JoinHandle<()>>>>,
    in_tx: Sender<IncomingRequest>,
    metrics: crate::obs::MetricsRegistry,
) -> Result<JoinHandle<()>> {
    listener.set_nonblocking(false).context("listener mode")?;
    let stop = stop.clone();
    let handlers = handlers.clone();
    std::thread::Builder::new()
        .name("serve-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let tx = in_tx.clone();
                let hstop = stop.clone();
                let hmetrics = metrics.clone();
                let Ok(h) = std::thread::Builder::new()
                    .name("serve-conn".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, tx, hstop, hmetrics);
                    })
                else {
                    continue;
                };
                let mut hs = handlers.lock().expect("handlers lock");
                // reap handlers whose connection already ended, so a
                // run-forever server under connection churn doesn't
                // accumulate finished threads (dropping a finished
                // handle detaches and reclaims it)
                hs.retain(|h| !h.is_finished());
                hs.push(h);
            }
        })
        .context("spawning acceptor")
}

/// Wake the acceptor with a loopback connection, then join it and every
/// handler (handlers wake on their read timeout).
fn join_server_threads(
    addr: std::net::SocketAddr,
    acceptor: JoinHandle<()>,
    handlers: &Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    let _ = TcpStream::connect(addr);
    let _ = acceptor.join();
    let hs = std::mem::take(&mut *handlers.lock().expect("handlers lock"));
    for h in hs {
        let _ = h.join();
    }
}

/// True iff the line is the `{"cmd": "metrics"}` control command (any
/// object with `cmd == "metrics"` qualifies).
fn is_metrics_cmd(line: &str) -> bool {
    Json::parse(line)
        .ok()
        .and_then(|j| j.get("cmd").and_then(|c| c.as_str().map(String::from)))
        .is_some_and(|c| c == "metrics")
}

fn handle_conn(
    stream: TcpStream,
    tx: Sender<IncomingRequest>,
    stop: Arc<AtomicBool>,
    metrics: crate::obs::MetricsRegistry,
) -> Result<()> {
    // a short read timeout lets the handler observe server shutdown even
    // while its client holds the connection open silently
    stream.set_read_timeout(Some(HANDLER_READ_TIMEOUT))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Accumulate raw bytes, not a String: `read_line` would *discard* a
    // call's bytes when a timeout lands mid-way through a multi-byte
    // UTF-8 character (its validity guard truncates on error), whereas
    // `read_until` keeps everything appended — so a slow line survives
    // any number of timeout wakeups intact.
    let mut line: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_until(b'\n', &mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {
                let text = String::from_utf8_lossy(&line);
                let trimmed = text.trim();
                if is_metrics_cmd(trimmed) {
                    // answered inline — a health probe must not queue
                    // behind the serving drive
                    writeln!(writer, "{}", metrics.snapshot())?;
                } else if !trimmed.is_empty() {
                    match parse_request(trimmed) {
                        Ok(req) => {
                            let (rtx, rrx) = mpsc::channel();
                            let inc = IncomingRequest {
                                req,
                                reply: rtx,
                                at: Instant::now(),
                            };
                            if tx.send(inc).is_err() {
                                writeln!(writer, "{{\"error\":\"server stopped\"}}")?;
                                break;
                            }
                            match rrx.recv() {
                                Ok(reply) => {
                                    writeln!(writer, "{}", render_reply(&reply))?;
                                }
                                Err(_) => {
                                    writeln!(writer, "{{\"error\":\"engine unavailable\"}}")?;
                                }
                            }
                        }
                        Err(e) => {
                            writeln!(writer, "{{\"error\":\"{e}\"}}")?;
                        }
                    }
                }
                line.clear();
            }
            // read timeout: partial bytes stay buffered in `line`; go
            // around and re-check the stop flag
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                continue;
            }
            Err(_) => break,
        }
    }
    Ok(())
}

/// Parse one request line (client-supplied id is ignored; the server
/// assigns its own).
pub fn parse_request(line: &str) -> Result<GenRequest> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let prompt: Vec<i32> = if let Some(text) = j.get("prompt").and_then(|p| p.as_str()) {
        text.bytes().map(|b| b as i32).collect()
    } else if let Some(arr) = j.get("tokens").and_then(|p| p.as_arr()) {
        arr.iter().filter_map(|x| x.as_i64()).map(|x| x as i32).collect()
    } else {
        anyhow::bail!("need `prompt` (string) or `tokens` (array)");
    };
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = j
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(16);
    let class = match j.get("class").and_then(|c| c.as_str()) {
        None | Some("interactive") => SloClass::Interactive,
        Some("batch") => SloClass::Batch,
        Some(other) => anyhow::bail!("unknown class `{other}` (interactive|batch)"),
    };
    let deadline_ms = j.get("deadline_ms").and_then(|x| x.as_f64());
    if let Some(d) = deadline_ms {
        anyhow::ensure!(d.is_finite() && d > 0.0, "deadline_ms must be positive");
    }
    // the engine-specific cap (compiled max_seq − prompt_len) is applied
    // at admission by the LiveSource; this only rejects nonsense
    let mut req = GenRequest::new(0, prompt, max_new.clamp(1, 96)).with_class(class);
    req.deadline_ms = deadline_ms;
    if let Some(s) = j.get("session").and_then(|x| x.as_usize()) {
        req = req.with_session(s as u64);
    }
    Ok(req)
}

/// Render a result line.
pub fn render_result(r: &GenResult) -> String {
    use std::collections::BTreeMap;
    let mut obj = BTreeMap::new();
    obj.insert("id".to_string(), Json::Num(r.id as f64));
    obj.insert(
        "tokens".to_string(),
        Json::Arr(r.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
    );
    obj.insert(
        "text".to_string(),
        Json::Str(Corpus::detokenize(&r.tokens)),
    );
    obj.insert("ttft_ms".to_string(), Json::Num((r.ttft_ms * 100.0).round() / 100.0));
    obj.insert(
        "total_ms".to_string(),
        Json::Num((r.total_ms * 100.0).round() / 100.0),
    );
    Json::Obj(obj).to_string()
}

/// Render any serve reply: completion, or one of the structured
/// admission rejects (`shed` / `expired`, each also carrying `error` so
/// naive clients that only look for an error key still see the reject).
pub fn render_reply(reply: &ServeReply) -> String {
    use std::collections::BTreeMap;
    match reply {
        ServeReply::Done(r) => render_result(r),
        ServeReply::Shed { id, class } => {
            let mut obj = BTreeMap::new();
            obj.insert("id".to_string(), Json::Num(*id as f64));
            obj.insert("shed".to_string(), Json::Bool(true));
            obj.insert("class".to_string(), Json::Str(class.name().to_string()));
            obj.insert(
                "error".to_string(),
                Json::Str(format!("shed: {} queue at bound", class.name())),
            );
            Json::Obj(obj).to_string()
        }
        ServeReply::Expired {
            id,
            class,
            waited_ms,
        } => {
            let mut obj = BTreeMap::new();
            obj.insert("id".to_string(), Json::Num(*id as f64));
            obj.insert("expired".to_string(), Json::Bool(true));
            obj.insert("class".to_string(), Json::Str(class.name().to_string()));
            obj.insert(
                "waited_ms".to_string(),
                Json::Num((waited_ms * 100.0).round() / 100.0),
            );
            obj.insert(
                "error".to_string(),
                Json::Str("expired: TTFT deadline passed while queued".to_string()),
            );
            Json::Obj(obj).to_string()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_text_prompt() {
        let r = parse_request(r#"{"prompt": "hello", "max_new_tokens": 8}"#).unwrap();
        assert_eq!(r.prompt, vec![104, 101, 108, 108, 111]);
        assert_eq!(r.max_new_tokens, 8);
    }

    #[test]
    fn parse_session_handle() {
        let r = parse_request(r#"{"prompt": "hi", "session": 42}"#).unwrap();
        assert_eq!(r.session, Some(42));
        let r = parse_request(r#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(r.session, None);
    }

    #[test]
    fn parse_token_prompt() {
        let r = parse_request(r#"{"tokens": [1, 2, 3]}"#).unwrap();
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 16);
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"max_new_tokens": 5}"#).is_err());
        assert!(parse_request(r#"{"prompt": ""}"#).is_err());
    }

    #[test]
    fn max_new_clamped() {
        let r = parse_request(r#"{"prompt": "x", "max_new_tokens": 10000}"#).unwrap();
        assert_eq!(r.max_new_tokens, 96);
    }

    #[test]
    fn parse_class_and_deadline() {
        let r = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(r.class, SloClass::Interactive);
        assert_eq!(r.deadline_ms, None);
        let r = parse_request(r#"{"prompt": "x", "class": "batch"}"#).unwrap();
        assert_eq!(r.class, SloClass::Batch);
        let r = parse_request(r#"{"prompt": "x", "deadline_ms": 150}"#).unwrap();
        assert_eq!(r.deadline_ms, Some(150.0));
        assert!(parse_request(r#"{"prompt": "x", "class": "gold"}"#).is_err());
        assert!(parse_request(r#"{"prompt": "x", "deadline_ms": -5}"#).is_err());
    }

    #[test]
    fn render_rejects_carry_structure_and_error() {
        let shed = render_reply(&ServeReply::Shed {
            id: 7,
            class: SloClass::Batch,
        });
        let j = Json::parse(&shed).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("shed").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("class").unwrap().as_str(), Some("batch"));
        assert!(j.get("error").is_some());
        let exp = render_reply(&ServeReply::Expired {
            id: 9,
            class: SloClass::Interactive,
            waited_ms: 162.128,
        });
        let j = Json::parse(&exp).unwrap();
        assert_eq!(j.get("expired").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("waited_ms").unwrap().as_f64(), Some(162.13));
        // a Done reply renders exactly like render_result
        let res = GenResult {
            id: 1,
            tokens: vec![104],
            ttft_ms: 1.0,
            total_ms: 2.0,
        };
        assert_eq!(render_reply(&ServeReply::Done(res.clone())), render_result(&res));
    }

    #[test]
    fn metrics_cmd_detected() {
        assert!(is_metrics_cmd(r#"{"cmd": "metrics"}"#));
        assert!(!is_metrics_cmd(r#"{"cmd": "shutdown"}"#));
        assert!(!is_metrics_cmd(r#"{"prompt": "hi"}"#));
        assert!(!is_metrics_cmd("not json"));
    }

    #[test]
    fn render_roundtrips() {
        let res = GenResult {
            id: 3,
            tokens: vec![104, 105],
            ttft_ms: 1.234,
            total_ms: 5.678,
        };
        let line = render_result(&res);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("text").unwrap().as_str(), Some("hi"));
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }
}
