//! JSON-lines TCP serving front-end.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"id": 1, "prompt": "Today is a", "max_new_tokens": 16}
//! ← {"id": 1, "text": "…", "tokens": [..], "ttft_ms": 12.3, "total_ms": 87.0}
//! ```
//!
//! Requests are byte-tokenized (the tiny model's 256-entry vocabulary),
//! batched by [`super::Batcher`] with a small gather window, and executed
//! on the pipelined engine.  This is the demo front door, not a hardened
//! production server.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::time::Duration;

use super::api::{GenRequest, GenResult};
use super::batcher::Batcher;
use super::engine::Engine;
use crate::pipeline::Strategy;
use crate::util::Json;
use crate::workload::Corpus;

/// A parsed client line.
struct Incoming {
    req: GenRequest,
    reply: Sender<GenResult>,
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// How long to gather requests into a batch before dispatching.
    pub gather_window_ms: u64,
    pub strategy: Strategy,
    /// Stop after serving this many requests (None = run forever).
    pub max_requests: Option<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            gather_window_ms: 20,
            strategy: Strategy::NoBubble,
            max_requests: None,
        }
    }
}

/// Run the serving loop on `listener` until `max_requests` (if set) have
/// been answered.  Returns the number served.
pub fn serve(
    listener: TcpListener,
    engine: &mut Engine,
    batcher: &mut Batcher,
    cfg: &ServerConfig,
) -> Result<usize> {
    let (in_tx, in_rx) = mpsc::channel::<Incoming>();

    // acceptor thread: one handler thread per connection
    let accept_tx = in_tx.clone();
    listener
        .set_nonblocking(false)
        .context("listener mode")?;
    let listener2 = listener.try_clone()?;
    std::thread::spawn(move || {
        for stream in listener2.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = accept_tx.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx);
            });
        }
    });
    drop(in_tx);

    let mut served = 0usize;
    let mut next_id = 1u64;
    loop {
        if let Some(max) = cfg.max_requests {
            if served >= max {
                return Ok(served);
            }
        }
        // block for the first request, then gather a window
        let first = match in_rx.recv_timeout(Duration::from_millis(250)) {
            Ok(x) => x,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return Ok(served),
        };
        let mut pending = vec![first];
        let deadline = std::time::Instant::now() + Duration::from_millis(cfg.gather_window_ms);
        while pending.len() < batcher.max_batch() {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            if left.is_zero() {
                break;
            }
            match in_rx.recv_timeout(left) {
                Ok(x) => pending.push(x),
                Err(_) => break,
            }
        }
        // assign ids and pack
        let mut replies: BTreeMap<u64, Sender<GenResult>> = BTreeMap::new();
        let reqs: Vec<GenRequest> = pending
            .into_iter()
            .map(|mut inc| {
                inc.req.id = next_id;
                next_id += 1;
                replies.insert(inc.req.id, inc.reply);
                inc.req
            })
            .collect();
        let groups = batcher.pack(&reqs);
        let (results, _stats) = engine.generate_pipelined(&groups, cfg.strategy)?;
        for r in results {
            served += 1;
            if let Some(tx) = replies.remove(&r.id) {
                let _ = tx.send(r);
            }
        }
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<Incoming>) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Ok(req) => {
                let (rtx, rrx) = mpsc::channel();
                tx.send(Incoming { req, reply: rtx })
                    .map_err(|_| anyhow::anyhow!("server stopped"))?;
                match rrx.recv() {
                    Ok(res) => {
                        writeln!(writer, "{}", render_result(&res))?;
                    }
                    Err(_) => {
                        writeln!(writer, "{{\"error\":\"engine unavailable\"}}")?;
                    }
                }
            }
            Err(e) => {
                writeln!(writer, "{{\"error\":\"{e}\"}}")?;
            }
        }
    }
    let _ = peer;
    Ok(())
}

/// Parse one request line (client-supplied id is ignored; the server
/// assigns its own).
pub fn parse_request(line: &str) -> Result<GenRequest> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let prompt: Vec<i32> = if let Some(text) = j.get("prompt").and_then(|p| p.as_str()) {
        text.bytes().map(|b| b as i32).collect()
    } else if let Some(arr) = j.get("tokens").and_then(|p| p.as_arr()) {
        arr.iter().filter_map(|x| x.as_i64()).map(|x| x as i32).collect()
    } else {
        anyhow::bail!("need `prompt` (string) or `tokens` (array)");
    };
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = j
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(16);
    Ok(GenRequest {
        id: 0,
        prompt,
        max_new_tokens: max_new.clamp(1, 96),
    })
}

/// Render a result line.
pub fn render_result(r: &GenResult) -> String {
    use std::collections::BTreeMap;
    let mut obj = BTreeMap::new();
    obj.insert("id".to_string(), Json::Num(r.id as f64));
    obj.insert(
        "tokens".to_string(),
        Json::Arr(r.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
    );
    obj.insert(
        "text".to_string(),
        Json::Str(Corpus::detokenize(&r.tokens)),
    );
    obj.insert("ttft_ms".to_string(), Json::Num((r.ttft_ms * 100.0).round() / 100.0));
    obj.insert(
        "total_ms".to_string(),
        Json::Num((r.total_ms * 100.0).round() / 100.0),
    );
    Json::Obj(obj).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_text_prompt() {
        let r = parse_request(r#"{"prompt": "hello", "max_new_tokens": 8}"#).unwrap();
        assert_eq!(r.prompt, vec![104, 101, 108, 108, 111]);
        assert_eq!(r.max_new_tokens, 8);
    }

    #[test]
    fn parse_token_prompt() {
        let r = parse_request(r#"{"tokens": [1, 2, 3]}"#).unwrap();
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 16);
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"max_new_tokens": 5}"#).is_err());
        assert!(parse_request(r#"{"prompt": ""}"#).is_err());
    }

    #[test]
    fn max_new_clamped() {
        let r = parse_request(r#"{"prompt": "x", "max_new_tokens": 10000}"#).unwrap();
        assert_eq!(r.max_new_tokens, 96);
    }

    #[test]
    fn render_roundtrips() {
        let res = GenResult {
            id: 3,
            tokens: vec![104, 105],
            ttft_ms: 1.234,
            total_ms: 5.678,
        };
        let line = render_result(&res);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("text").unwrap().as_str(), Some("hi"));
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }
}
