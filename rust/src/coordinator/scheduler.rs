//! Iteration-level slot scheduler — the continuous-batching policy.
//!
//! Classic serving packs requests into fixed groups up front and drives
//! each group to completion: padding rows burn compute and KV bytes for
//! the group's whole lifetime, and a group holds its pipeline slot until
//! its *longest* request finishes.  This module replaces "pack once,
//! drive to completion" with vLLM/Orca-style **iteration-level
//! scheduling**: the unit of work is one decode iteration of a *run* (a
//! persistent compiled-batch of slots), and the scheduler recomposes
//! every run's batch between iterations.
//!
//! ## Slot lifecycle
//!
//! ```text
//! waiting ── admit ──▶ Prefilling ── first token ──▶ Active ──┐
//!    ▲                (StageMsg::Admit in flight)             │ decode steps
//!    │                                                        ▼
//!  Free ◀──────────────── retire (StageMsg::Evict) ◀── max_new reached
//! ```
//!
//! * **Admission**: whenever a run has a `Free` slot and requests are
//!   waiting, the scheduler emits [`Action::Admit`] — a batch-1 prefill
//!   that travels the pipeline and installs its KV as *one row* of the
//!   run's cache ([`crate::coordinator::kvcache::KvPool::insert_row`]).
//!   Admission order over the arrival queue is governed by the
//!   [`super::admission::AdmissionPolicy`] — FIFO, or FIFO with a bound
//!   on how many batch-1 prefills may be dispatched ahead of an
//!   in-flight decode step; because stage channels are FIFO too, an
//!   admission sent before a decode step is guaranteed to be resident
//!   before that step executes.  The queue itself may be fed live: an
//!   **open** scheduler ([`SlotScheduler::new_open`]) accepts arrivals
//!   via [`SlotScheduler::push_request`] and keeps drained runs
//!   allocated until [`SlotScheduler::close`].
//! * **Iteration**: each [`Action::Step`] carries the per-iteration slot
//!   map — per-row absolute positions, `-1` for dead rows, which the
//!   kernels skip — so a composed batch mixes sequences at unrelated
//!   positions.  One step per run is in flight at a time (autoregressive
//!   feedback); pipeline depth comes from multiple independent runs,
//!   exactly like micro-batches in classic pipelined serving.
//! * **Retirement**: a sequence that reaches `max_new_tokens` frees its
//!   KV bytes *immediately* ([`Action::Evict`], per-row accounting) and
//!   its slot becomes admissible in the very next iteration — short
//!   requests no longer queue behind long groups.
//! * **Recomposition**: when the arrival queue drains, runs shrink to the
//!   smallest compiled batch that holds their live rows
//!   ([`Action::Compact`]), and grow back (next compiled size) when
//!   demand returns.
//!
//! ## Interaction with migration barriers and failover
//!
//! The scheduler is pure policy: it never touches channels or clocks, so
//! the generation driver ([`super::driver`]) can stop pumping it at any
//! quiesce point — exactly the contract the adaptive engine's migration
//! barrier needs (drain in-flight iterations, move KV, resume).  Run
//! caches are ordinary [`crate::coordinator::kvcache::GroupCache`]s, so
//! [`crate::coordinator::stage::StageMsg::Export`] snapshots them like
//! any group's, and the driver's slot loop drains to a real barrier for
//! the adaptive engine's migration.
//!
//! Device-loss failover rides the same purity: [`SlotScheduler::snapshot`]
//! re-derives every occupied slot's replay state (request, prompt, served
//! history — position and last token fall out of the history length), and
//! [`SlotScheduler::on_failover`] resets the in-flight bookkeeping after
//! the pipeline has been replaced — dead steps are recomposed from the
//! unchanged per-row state on the next pump, and admissions whose first
//! token died in flight are re-queued verbatim.

use std::collections::{HashMap, VecDeque};

use super::admission::AdmissionPolicy;
use super::api::{GenRequest, SloClass};
use super::batcher::fit_prompt;
use super::stage::{TokenMsg, TokenOrigin};
use anyhow::{bail, ensure, Result};

/// Continuous-batching runs get ids far above the classic batcher's group
/// counter so the two id spaces can never collide inside one engine.
const RUN_ID_BASE: u64 = 1 << 32;

/// Smallest of `batch_sizes` (ascending) that holds `want` rows, clamped
/// to the largest available.
fn fit_batch(batch_sizes: &[usize], want: usize) -> usize {
    batch_sizes
        .iter()
        .copied()
        .find(|&b| b >= want)
        .unwrap_or_else(|| *batch_sizes.last().expect("no batch sizes"))
}

/// How the scheduler frees paged-KV blocks when the pool runs dry and a
/// composed decode step needs more ([`SlotScheduler::set_paged`]).
/// Either way the victim's served history is preserved and its tokens
/// are byte-identical to an unconstrained run — preemption trades
/// latency, never output.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptMode {
    /// Swap the victim row's KV bytes out through the Export freight
    /// path ([`Action::SwapOut`]); resume re-installs them verbatim
    /// ([`Action::SwapIn`]).  Costs wire bytes, no recompute.
    #[default]
    SwapOut,
    /// Drop the victim row's KV ([`Action::Evict`]) and re-queue the
    /// request; re-admission re-prefills and replays the served history
    /// (verified token-by-token).  Costs compute, no freight.
    Recompute,
}

/// Knobs of the continuous-batching scheduler.
#[derive(Debug, Clone)]
pub struct ContinuousConfig {
    /// Independent runs (micro-batches) kept in flight — the pipeline
    /// depth.  One decode step per run is outstanding at a time.
    pub runs: usize,
    /// Cap on the compiled batch a run may use (None = largest compiled).
    pub max_batch: Option<usize>,
    /// Compiled batch runs start at (None = sized from the arrival
    /// queue).  Mostly a test/bench knob: starting small exercises the
    /// grow path.
    pub initial_batch: Option<usize>,
    /// Dead-man interval, real ms: with no stall hook (or a hook that
    /// never recovers), a pipeline silent this long makes the drive
    /// error out instead of hanging the server.  Defaults to
    /// [`super::driver::DEAD_PIPELINE_REAL_MS`]; tests shrink it.
    pub dead_man_real_ms: f64,
    /// How to free paged-KV blocks under pressure (paged layout only).
    pub preempt: PreemptMode,
}

impl Default for ContinuousConfig {
    fn default() -> Self {
        ContinuousConfig {
            runs: 2,
            max_batch: None,
            initial_batch: None,
            dead_man_real_ms: super::driver::DEAD_PIPELINE_REAL_MS,
            preempt: PreemptMode::default(),
        }
    }
}

/// One instruction the driver must turn into a [`super::stage::StageMsg`].
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Prefill `prompt` (already fitted to the compiled length) at batch
    /// 1 and install it as row `slot` of run `run`.  `req` is the
    /// admitted request's id — the driver stamps its queue delay
    /// (arrival → this dispatch) off it.
    Admit {
        run: u64,
        slot: usize,
        run_batch: usize,
        req: u64,
        prompt: Vec<i32>,
    },
    /// One decode iteration over run `run`'s composed batch: `tokens` is
    /// the per-slot feedback (dead rows carry token 0), `pos` the slot
    /// map (`-1` = dead row).
    Step {
        run: u64,
        iter: usize,
        batch: usize,
        pos: Vec<i32>,
        tokens: Vec<i32>,
    },
    /// Retire row `slot` of run `run` (frees its KV bytes per-row).
    Evict { run: u64, slot: usize },
    /// Recompose run `run`'s cache at `new_batch` rows.
    Compact {
        run: u64,
        new_batch: usize,
        moves: Vec<(usize, usize)>,
    },
    /// The run drained: drop its cache allocation everywhere.
    FreeRun { run: u64 },
    /// Paged-pool pressure preemption: extract row `slot` of run `run`
    /// from the pool (its live blocks travel as compact KV freight) and
    /// free its blocks.  The driver holds the freight until the
    /// matching [`Action::SwapIn`].
    SwapOut { run: u64, slot: usize, req: u64 },
    /// Re-install request `req`'s swapped-out KV as row `slot` of run
    /// `run` and resume decoding: `written` positions are resident, so
    /// the next step processes the row's last folded token at absolute
    /// position `written`.
    SwapIn {
        run: u64,
        slot: usize,
        run_batch: usize,
        req: u64,
        written: usize,
    },
}

/// What one folded [`TokenMsg`] meant for the sequences involved.
#[derive(Debug, Clone, PartialEq)]
pub enum SeqEvent {
    /// A request's first token arrived (its TTFT sample point).
    First { req_id: u64 },
    /// One decode step of a run landed, carrying `live` real tokens.
    StepDone { run: u64, live: usize },
    /// A request finished; `tokens` is its full generation.
    Finished { req_id: u64, tokens: Vec<i32> },
}

#[derive(Debug)]
struct SeqState {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    generated: Vec<i32>,
    class: SloClass,
}

/// Replay state of one occupied slot, as checkpointing and failover see
/// it.  Everything a rebuilt pipeline needs to reconstruct the row:
/// `generated` is the served history (its length pins the row's absolute
/// position at `prompt_len + generated.len() - 1`, its last element is
/// the next step's feedback token), and `prompt` is the fitted prompt an
/// [`Action::Admit`] would carry.
#[derive(Debug, Clone, PartialEq)]
pub struct RowSnap {
    pub slot: usize,
    pub req_id: u64,
    /// Fitted prompt (exactly what the original admission sent).
    pub prompt: Vec<i32>,
    /// Folded tokens so far (empty while the admission is in flight).
    pub generated: Vec<i32>,
    /// Admission in flight — no first token yet; after a failover the
    /// driver re-admits this row live (its TTFT is still unmeasured).
    pub prefilling: bool,
}

/// One live run's composition: batch plus every occupied slot's
/// [`RowSnap`].  Produced by [`SlotScheduler::snapshot`] for the driver's
/// slot-mode stall view and for checkpoint watermarks.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSnap {
    pub run: u64,
    pub batch: usize,
    pub rows: Vec<RowSnap>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Slot {
    Free,
    /// `Admit` in flight; the first token has not returned yet.
    Prefilling { seq: usize },
    /// Decoding: the next step processes `last_tok` at absolute `pos`.
    Active { seq: usize, pos: i32, last_tok: i32 },
}

#[derive(Debug)]
struct Run {
    id: u64,
    batch: usize,
    slots: Vec<Slot>,
    iter: usize,
    /// Composition snapshot of the in-flight step (slot → seq index).
    step_live: Option<Vec<Option<usize>>>,
    /// Whether any admission was ever sent (stages hold a cache).
    allocated: bool,
    freed: bool,
}

/// Paged-pool view the scheduler admits against ([`SlotScheduler::set_paged`]).
#[derive(Debug, Clone, Copy)]
struct PagedSched {
    block_size: usize,
    capacity_blocks: usize,
}

/// A row preempted by [`PreemptMode::SwapOut`]: its KV freight is held
/// by the driver; the scheduler only needs what recomposes the slot on
/// resume (`written` falls out of the served history length).
#[derive(Debug, Clone, Copy)]
struct Parked {
    seq: usize,
    last_tok: i32,
}

impl Run {
    fn count(&self, f: impl Fn(&Slot) -> bool) -> usize {
        self.slots.iter().filter(|&s| f(s)).count()
    }

    fn live(&self) -> usize {
        self.count(|s| matches!(s, Slot::Active { .. }))
    }

    fn prefilling(&self) -> usize {
        self.count(|s| matches!(s, Slot::Prefilling { .. }))
    }

    fn free(&self) -> usize {
        self.count(|s| matches!(s, Slot::Free))
    }
}

/// The iteration-level scheduler: pure state machine, no channels, no
/// clocks.  The driver alternates [`SlotScheduler::pump`] (actions to
/// send) and [`SlotScheduler::on_token`] (fold one head token message).
#[derive(Debug)]
pub struct SlotScheduler {
    prompt_len: usize,
    /// Compiled batch sizes ≤ the configured cap, ascending.
    batch_sizes: Vec<usize>,
    waiting: VecDeque<usize>,
    seqs: Vec<SeqState>,
    runs: Vec<Run>,
    outbox: Vec<Action>,
    rows_real: u64,
    rows_total: u64,
    /// Admission-order policy ([`SlotScheduler::set_policy`]).
    policy: AdmissionPolicy,
    /// An open scheduler expects more arrivals ([`SlotScheduler::push_request`])
    /// and therefore keeps drained runs allocated (no [`Action::FreeRun`])
    /// until [`SlotScheduler::close`].
    open: bool,
    /// Anti-starvation flag ([`SlotScheduler::set_batch_aged`]): the next
    /// pump promotes one aged batch request ahead of interactive
    /// admissions, exempt from the batch prefill cap.  Consumed on use.
    batch_aged: bool,
    /// Stale in-flight admissions per `(run, slot)`: a preempted
    /// prefill's first token is still traveling the pipeline and must be
    /// swallowed, not folded.  Stage channels are FIFO, so the stale
    /// token is guaranteed to arrive before any later admission's token
    /// for the same slot — [`SlotScheduler::on_token`] drops exactly
    /// this many admit tokens per slot.
    ghosts: HashMap<(u64, usize), u32>,
    /// Paged-pool budget ([`SlotScheduler::set_paged`]): admission and
    /// step composition gate on block occupancy instead of worst-case
    /// rows.  `None` = padded layout, no block accounting.
    paged: Option<PagedSched>,
    /// Preemption flavor under paged pressure (from [`ContinuousConfig`]).
    preempt: PreemptMode,
    /// Swapped-out rows awaiting resume, FIFO (oldest preempted first).
    parked: VecDeque<Parked>,
    /// Highest number of rows simultaneously occupying slots — the
    /// concurrency the KV budget actually supported.
    peak_live: usize,
}

impl SlotScheduler {
    /// Closed-loop construction: the whole request queue is known up
    /// front (and sizes the initial compiled batch).
    pub fn new(
        cfg: &ContinuousConfig,
        prompt_len: usize,
        batch_sizes: Vec<usize>,
        requests: &[GenRequest],
    ) -> Result<Self> {
        let seqs: Vec<SeqState> = requests
            .iter()
            .map(|r| {
                ensure!(r.max_new_tokens >= 1, "request {}: zero max_new_tokens", r.id);
                ensure!(!r.prompt.is_empty(), "request {}: empty prompt", r.id);
                Ok(SeqState {
                    id: r.id,
                    prompt: fit_prompt(&r.prompt, prompt_len),
                    max_new: r.max_new_tokens,
                    generated: Vec::new(),
                    class: r.class,
                })
            })
            .collect::<Result<_>>()?;
        Self::build(cfg, prompt_len, batch_sizes, seqs, false)
    }

    /// Open-loop construction: requests arrive later through
    /// [`SlotScheduler::push_request`], so runs start at the smallest
    /// compiled batch (or `initial_batch`) and grow with demand, and
    /// drained runs stay allocated until [`SlotScheduler::close`].
    pub fn new_open(
        cfg: &ContinuousConfig,
        prompt_len: usize,
        batch_sizes: Vec<usize>,
    ) -> Result<Self> {
        Self::build(cfg, prompt_len, batch_sizes, Vec::new(), true)
    }

    fn build(
        cfg: &ContinuousConfig,
        prompt_len: usize,
        mut batch_sizes: Vec<usize>,
        seqs: Vec<SeqState>,
        open: bool,
    ) -> Result<Self> {
        batch_sizes.sort_unstable();
        batch_sizes.dedup();
        ensure!(!batch_sizes.is_empty(), "need at least one compiled batch size");
        let max_batch = cfg.max_batch.unwrap_or(*batch_sizes.last().unwrap());
        ensure!(
            batch_sizes.contains(&max_batch),
            "max_batch {max_batch} not compiled (have {batch_sizes:?})"
        );
        batch_sizes.retain(|&b| b <= max_batch);
        if let Some(ib) = cfg.initial_batch {
            ensure!(
                batch_sizes.contains(&ib),
                "initial_batch {ib} not compiled (have {batch_sizes:?})"
            );
        }

        let n = seqs.len();
        let n_runs = if open {
            cfg.runs.max(1)
        } else {
            cfg.runs.max(1).min(n.max(1))
        };
        let init = cfg.initial_batch.unwrap_or_else(|| {
            if open {
                batch_sizes[0]
            } else {
                fit_batch(&batch_sizes, n.div_ceil(n_runs).max(1))
            }
        });
        let runs = (0..n_runs)
            .map(|i| Run {
                id: RUN_ID_BASE + i as u64,
                batch: init,
                slots: vec![Slot::Free; init],
                iter: 0,
                step_live: None,
                allocated: false,
                freed: false,
            })
            .collect();
        Ok(SlotScheduler {
            prompt_len,
            batch_sizes,
            waiting: (0..n).collect(),
            seqs,
            runs,
            outbox: Vec::new(),
            rows_real: 0,
            rows_total: 0,
            policy: AdmissionPolicy::Fifo,
            open,
            batch_aged: false,
            ghosts: HashMap::new(),
            paged: None,
            preempt: cfg.preempt,
            parked: VecDeque::new(),
            peak_live: 0,
        })
    }

    /// Switch admission control to paged-pool block accounting: the
    /// per-stage KV pool holds `capacity_blocks` blocks of `block_size`
    /// positions each, and every admission / composed step is gated on
    /// current occupancy (deferred, or served by preempting a later
    /// arrival per [`PreemptMode`]) instead of the padded worst-case
    /// row bound.
    pub fn set_paged(&mut self, block_size: usize, capacity_blocks: usize) -> Result<()> {
        ensure!(block_size > 0, "paged block size must be positive");
        ensure!(
            capacity_blocks >= self.prompt_len.div_ceil(block_size) + 1,
            "paged pool ({capacity_blocks} blocks x {block_size}) cannot hold one \
             prefilled prompt of {} positions plus a block of decode headroom",
            self.prompt_len
        );
        self.paged = Some(PagedSched {
            block_size,
            capacity_blocks,
        });
        Ok(())
    }

    /// Blocks the scheduler believes are live in each stage's paged pool
    /// right now (0 in padded mode).  Computed fresh from slot state so
    /// it survives failover: prefilling rows hold their prompt's blocks,
    /// active rows hold `ceil(written / block_size)` counting the
    /// position an in-flight step is about to write.  Parked rows hold
    /// nothing — their bytes live in driver-held swap freight.
    pub fn used_blocks(&self) -> usize {
        let Some(p) = &self.paged else { return 0 };
        let bs = p.block_size;
        self.runs
            .iter()
            .filter(|r| !r.freed)
            .flat_map(|r| {
                r.slots.iter().enumerate().map(move |(slot, s)| match s {
                    Slot::Free => 0,
                    Slot::Prefilling { .. } => self.prompt_len.div_ceil(bs),
                    Slot::Active { pos, .. } => {
                        let infl = r
                            .step_live
                            .as_ref()
                            .is_some_and(|l| l.get(slot).copied().flatten().is_some());
                        (*pos as usize + infl as usize).div_ceil(bs)
                    }
                })
            })
            .sum()
    }

    /// Free blocks in the paged pool (`usize::MAX` when padded — no gate).
    fn free_blocks_now(&self) -> usize {
        self.paged
            .as_ref()
            .map_or(usize::MAX, |p| p.capacity_blocks.saturating_sub(self.used_blocks()))
    }

    /// Highest number of rows ever simultaneously resident — how much
    /// concurrency the KV budget actually carried (the paged layout's
    /// headline win over padded worst-case admission).
    pub fn peak_live_rows(&self) -> usize {
        self.peak_live
    }

    /// Latest-arrival preemptible row: Active (its prefill is paid and
    /// its history replayable), in a run with no step in flight (an
    /// in-flight composition still references the row).  LIFO choice —
    /// early arrivals keep their blocks and run to completion, which is
    /// what guarantees the pool drains forward under pressure.
    fn pick_victim(&self) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize, usize)> = None;
        for (ri, r) in self.runs.iter().enumerate() {
            if r.freed || r.step_live.is_some() {
                continue;
            }
            for (slot, s) in r.slots.iter().enumerate() {
                if let Slot::Active { seq, .. } = s {
                    if best.is_none_or(|(bseq, _, _)| *seq > bseq) {
                        best = Some((*seq, ri, slot));
                    }
                }
            }
        }
        best.map(|(_, ri, slot)| (ri, slot))
    }

    /// Preempt one Active row to free its blocks: swap its KV out (the
    /// driver holds the freight) or drop it for recompute, per the
    /// configured [`PreemptMode`].  Either way the slot frees now — the
    /// frame ordering (SwapOut/Evict ahead of any later frame on the
    /// FIFO stage channels) means the blocks are free at the stages
    /// before anything subsequent executes.
    fn preempt_row(&mut self, ri: usize, slot: usize, out: &mut Vec<Action>) {
        let Slot::Active { seq, last_tok, .. } = self.runs[ri].slots[slot] else {
            return;
        };
        let run_id = self.runs[ri].id;
        match self.preempt {
            PreemptMode::SwapOut => {
                out.push(Action::SwapOut {
                    run: run_id,
                    slot,
                    req: self.seqs[seq].id,
                });
                self.parked.push_back(Parked { seq, last_tok });
            }
            PreemptMode::Recompute => {
                out.push(Action::Evict { run: run_id, slot });
                self.waiting.push_front(seq);
            }
        }
        self.runs[ri].slots[slot] = Slot::Free;
    }

    /// Swap the admission policy (applies from the next pump).
    pub fn set_policy(&mut self, policy: AdmissionPolicy) {
        self.policy = policy;
    }

    /// Enqueue one more request (open-loop arrival).  Validation matches
    /// [`SlotScheduler::new`]; ids must be unique per drive (the TTFT
    /// and result bookkeeping is keyed by them).
    pub fn push_request(&mut self, r: &GenRequest) -> Result<()> {
        ensure!(r.max_new_tokens >= 1, "request {}: zero max_new_tokens", r.id);
        ensure!(!r.prompt.is_empty(), "request {}: empty prompt", r.id);
        self.seqs.push(SeqState {
            id: r.id,
            prompt: fit_prompt(&r.prompt, self.prompt_len),
            max_new: r.max_new_tokens,
            generated: Vec::new(),
            class: r.class,
        });
        self.waiting.push_back(self.seqs.len() - 1);
        Ok(())
    }

    /// Arm (or clear) the anti-starvation promotion: when armed, the
    /// next pump admits one waiting batch request ahead of interactive
    /// ones, exempt from [`super::admission::SloPolicy::batch_prefill_cap`].
    /// The driver arms it when the oldest queued batch request has waited
    /// past `aging_ms`.
    pub fn set_batch_aged(&mut self, aged: bool) {
        self.batch_aged = aged;
    }

    /// Waiting (not yet admitted) interactive requests.
    pub fn waiting_interactive(&self) -> usize {
        self.waiting
            .iter()
            .filter(|&&seq| self.seqs[seq].class == SloClass::Interactive)
            .count()
    }

    /// Free slots across live runs — admission capacity of the next pump.
    pub fn free_slots(&self) -> usize {
        self.runs.iter().filter(|r| !r.freed).map(|r| r.free()).sum()
    }

    /// Drop waiting requests whose id matches `pred` (deadline expiry):
    /// they leave the queue without ever dispatching a prefill.  Returns
    /// the dropped request ids.  Admitted requests are never touched —
    /// their prefill is already paid for.  A recompute-preempted request
    /// (back in the queue but with served history) is likewise immune:
    /// its tokens were already delivered, it only owes a replay.
    pub fn drop_waiting(&mut self, pred: impl Fn(u64) -> bool) -> Vec<u64> {
        let mut dropped = Vec::new();
        self.waiting.retain(|&seq| {
            if self.seqs[seq].generated.is_empty() && pred(self.seqs[seq].id) {
                dropped.push(self.seqs[seq].id);
                false
            } else {
                true
            }
        });
        dropped
    }

    /// Preempt up to `max_n` in-flight *batch* prefills (admitted, first
    /// token not yet back) to make room for waiting interactive work:
    /// each one is evicted (reusing the failover evict/re-queue path),
    /// its slot freed for the next pump's admission, and the request
    /// put back at the front of the waiting queue.  The stale first
    /// token still traveling the pipeline is ghost-swallowed by
    /// [`SlotScheduler::on_token`].  Returns how many were preempted.
    pub fn preempt_batch_prefills(&mut self, max_n: usize) -> usize {
        let mut preempted = 0usize;
        for ri in 0..self.runs.len() {
            if preempted >= max_n {
                break;
            }
            if self.runs[ri].freed {
                continue;
            }
            for slot in 0..self.runs[ri].batch {
                if preempted >= max_n {
                    break;
                }
                let Slot::Prefilling { seq } = self.runs[ri].slots[slot] else {
                    continue;
                };
                if self.seqs[seq].class != SloClass::Batch {
                    continue;
                }
                let run_id = self.runs[ri].id;
                self.outbox.push(Action::Evict { run: run_id, slot });
                self.runs[ri].slots[slot] = Slot::Free;
                *self.ghosts.entry((run_id, slot)).or_insert(0) += 1;
                self.waiting.push_front(seq);
                preempted += 1;
            }
        }
        preempted
    }

    /// The source is exhausted: no further [`SlotScheduler::push_request`]
    /// will come, so drained runs may free their caches.
    pub fn close(&mut self) {
        self.open = false;
    }

    /// Smallest compiled batch ≥ `want` (clamped to the largest allowed).
    fn fit(&self, want: usize) -> usize {
        fit_batch(&self.batch_sizes, want)
    }

    /// Upper bound on rows ever resident at once — every run at the
    /// largest allowed batch (an open scheduler cannot bound by request
    /// count: arrivals are unbounded; a closed one never exceeds its
    /// queue) — what admission control must budget for.
    pub fn worst_case_rows(&self) -> usize {
        let cap = self.runs.len() * self.batch_sizes.last().copied().unwrap_or(1);
        if self.open {
            cap
        } else {
            cap.min(self.seqs.len())
        }
    }

    /// Decode iterations still owed to the furthest-from-done admitted or
    /// waiting sequence — a conservative lower bound on how many more
    /// iterations this drive will run, which is what replan
    /// cost-awareness amortizes a migration pause over.
    pub fn max_remaining(&self) -> u64 {
        let occupied = self.runs.iter().flat_map(|r| &r.slots).filter_map(|s| match s {
            Slot::Prefilling { seq } | Slot::Active { seq, .. } => Some(*seq),
            Slot::Free => None,
        });
        occupied
            .chain(self.waiting.iter().copied())
            .chain(self.parked.iter().map(|p| p.seq))
            .map(|seq| {
                let s = &self.seqs[seq];
                s.max_new.saturating_sub(s.generated.len()) as u64
            })
            .max()
            .unwrap_or(0)
    }

    /// Next compiled batch strictly above `b`, if any.
    fn next_bigger(&self, b: usize) -> Option<usize> {
        self.batch_sizes.iter().copied().find(|&x| x > b)
    }

    /// Everything to send right now: retirements queued by
    /// [`Self::on_token`], then per-run recomposition, admissions and the
    /// next iteration for every run without a step in flight.
    pub fn pump(&mut self) -> Vec<Action> {
        let mut out: Vec<Action> = std::mem::take(&mut self.outbox);
        for ri in 0..self.runs.len() {
            self.pump_run(ri, &mut out);
        }
        let live: usize = self
            .runs
            .iter()
            .filter(|r| !r.freed)
            .map(|r| r.count(|s| !matches!(s, Slot::Free)))
            .sum();
        self.peak_live = self.peak_live.max(live);
        out
    }

    fn pump_run(&mut self, ri: usize, out: &mut Vec<Action>) {
        if self.runs[ri].step_live.is_some() || self.runs[ri].freed {
            return;
        }

        // grow: demand exceeds capacity and a bigger compiled batch exists
        if !self.waiting.is_empty() && self.runs[ri].free() == 0 {
            if let Some(bigger) = self.next_bigger(self.runs[ri].batch) {
                let run = &mut self.runs[ri];
                if run.allocated {
                    let moves: Vec<(usize, usize)> = run
                        .slots
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| !matches!(s, Slot::Free))
                        .map(|(i, _)| (i, i))
                        .collect();
                    out.push(Action::Compact {
                        run: run.id,
                        new_batch: bigger,
                        moves,
                    });
                }
                run.slots.resize(bigger, Slot::Free);
                run.batch = bigger;
            }
        }

        // resume swapped-out rows before new admissions: their prefill
        // (and possibly a long decode) is already paid for, so they
        // outrank everything in the arrival queue.  A resume needs the
        // row's full written footprint back, plus one block of headroom
        // so the next step cannot immediately re-preempt it.
        while !self.parked.is_empty() {
            let Some(slot) = (0..self.runs[ri].batch)
                .find(|&s| matches!(self.runs[ri].slots[s], Slot::Free))
            else {
                break;
            };
            let pk = *self.parked.front().unwrap();
            let written = self.prompt_len + self.seqs[pk.seq].generated.len() - 1;
            let bs = self.paged.map(|p| p.block_size).unwrap_or(1);
            if written.div_ceil(bs) + 1 > self.free_blocks_now() {
                break;
            }
            self.parked.pop_front();
            let run = &mut self.runs[ri];
            out.push(Action::SwapIn {
                run: run.id,
                slot,
                run_batch: run.batch,
                req: self.seqs[pk.seq].id,
                written,
            });
            run.slots[slot] = Slot::Active {
                seq: pk.seq,
                pos: written as i32,
                last_tok: pk.last_tok,
            };
            run.allocated = true;
        }

        // admissions: fill free slots from the arrival queue.  The
        // BoundedPrefill policy caps how many batch-1 prefills may be
        // dispatched ahead of this run's next decode step (each one is a
        // full pipeline pass the step must wait behind); a run with no
        // live rows has no decode step to delay and admits freely.  The
        // SloPriority policy admits interactive-first and applies the
        // prefill cap to batch admissions only (one aged batch request
        // may jump the line cap-free — anti-starvation).
        let decoding = self.runs[ri].live() > 0;
        let (cap, batch_cap) = match &self.policy {
            AdmissionPolicy::Fifo => (usize::MAX, usize::MAX),
            AdmissionPolicy::BoundedPrefill(k) => {
                (if decoding { *k } else { usize::MAX }, usize::MAX)
            }
            AdmissionPolicy::SloPriority(p) => (
                usize::MAX,
                if decoding { p.batch_prefill_cap } else { usize::MAX },
            ),
        };
        let slo = matches!(self.policy, AdmissionPolicy::SloPriority(_));
        let mut admits = 0usize;
        let mut batch_admits = 0usize;
        for slot in 0..self.runs[ri].batch {
            if admits >= cap {
                break;
            }
            if !matches!(self.runs[ri].slots[slot], Slot::Free) {
                continue;
            }
            // paged pressure: defer admission (don't refuse) unless the
            // pool holds the prompt's blocks plus one block of decode
            // headroom right now — occupancy, not worst case
            if let Some(p) = self.paged {
                let need = self.prompt_len.div_ceil(p.block_size) + 1;
                if need > self.free_blocks_now() {
                    break;
                }
            }
            let picked = if slo {
                self.pick_waiting_slo(batch_cap, &mut batch_admits)
            } else {
                self.waiting.pop_front()
            };
            let Some(seq) = picked else { break };
            // a recompute-preempted request replays its whole served
            // history after re-prefill: gate on the final footprint so
            // the replay doesn't thrash straight back out
            if let Some(p) = self.paged {
                let g = self.seqs[seq].generated.len();
                if g > 0 {
                    let need = (self.prompt_len + g - 1).div_ceil(p.block_size) + 1;
                    if need > self.free_blocks_now() {
                        self.waiting.push_front(seq);
                        break;
                    }
                }
            }
            let run = &mut self.runs[ri];
            out.push(Action::Admit {
                run: run.id,
                slot,
                run_batch: run.batch,
                req: self.seqs[seq].id,
                prompt: self.seqs[seq].prompt.clone(),
            });
            run.slots[slot] = Slot::Prefilling { seq };
            run.allocated = true;
            admits += 1;
            self.rows_real += 1;
            self.rows_total += 1;
        }

        // shrink: the queue drained and the live rows fit a smaller
        // compiled batch — recompose so the tail stops carrying dead rows
        let run = &self.runs[ri];
        let live = run.live();
        if self.waiting.is_empty() && run.prefilling() == 0 && live > 0 {
            let target = self.fit(live);
            if target < run.batch {
                let run = &mut self.runs[ri];
                let mut moves = Vec::with_capacity(live);
                let mut new_slots = vec![Slot::Free; target];
                let mut to = 0usize;
                for (from, s) in run.slots.iter().enumerate() {
                    if let Slot::Active { .. } = s {
                        moves.push((from, to));
                        new_slots[to] = *s;
                        to += 1;
                    }
                }
                out.push(Action::Compact {
                    run: run.id,
                    new_batch: target,
                    moves,
                });
                run.slots = new_slots;
                run.batch = target;
            }
        }

        // paged pressure: the composed step writes one position per
        // live row, which may cross block boundaries.  Preempt latest
        // arrivals (LIFO — swap-out or recompute per mode) until the
        // new blocks fit the pool; a victim inside this run simply
        // drops out of the composition.  If nothing is preemptible the
        // step waits for the next pump (in-flight folds free blocks).
        if let Some(p) = self.paged {
            loop {
                let extra: usize = self.runs[ri]
                    .slots
                    .iter()
                    .map(|s| match s {
                        Slot::Active { pos, .. } => {
                            let w = *pos as usize;
                            (w + 1).div_ceil(p.block_size) - w.div_ceil(p.block_size)
                        }
                        _ => 0,
                    })
                    .sum();
                if extra <= self.free_blocks_now() {
                    break;
                }
                match self.pick_victim() {
                    Some((vri, vslot)) => self.preempt_row(vri, vslot, out),
                    None => return,
                }
            }
        }

        // compose the next iteration over the live slots
        let run = &mut self.runs[ri];
        if run.live() > 0 {
            let mut pos = Vec::with_capacity(run.batch);
            let mut tokens = Vec::with_capacity(run.batch);
            let mut live_map = Vec::with_capacity(run.batch);
            for s in &run.slots {
                match s {
                    Slot::Active {
                        seq,
                        pos: p,
                        last_tok,
                    } => {
                        pos.push(*p);
                        tokens.push(*last_tok);
                        live_map.push(Some(*seq));
                    }
                    _ => {
                        pos.push(-1);
                        tokens.push(0);
                        live_map.push(None);
                    }
                }
            }
            let live = live_map.iter().flatten().count();
            out.push(Action::Step {
                run: run.id,
                iter: run.iter,
                batch: run.batch,
                pos,
                tokens,
            });
            run.step_live = Some(live_map);
            run.iter += 1;
            self.rows_real += live as u64;
            self.rows_total += run.batch as u64;
        } else if !self.open && run.prefilling() == 0 && self.waiting.is_empty() && run.allocated {
            // an open scheduler keeps the drained run's (empty) cache
            // allocation: the next arrival re-admits into it, whereas a
            // freed run can never serve again
            out.push(Action::FreeRun { run: run.id });
            self.runs[ri].freed = true;
        }
    }

    /// Pick the next admissible waiting request under SloPriority:
    /// one aged batch request first (cap-free, consumes the flag), then
    /// oldest interactive, then oldest batch while under `batch_cap`.
    fn pick_waiting_slo(&mut self, batch_cap: usize, batch_admits: &mut usize) -> Option<usize> {
        if self.batch_aged {
            if let Some(ix) = self
                .waiting
                .iter()
                .position(|&seq| self.seqs[seq].class == SloClass::Batch)
            {
                self.batch_aged = false;
                return self.waiting.remove(ix);
            }
        }
        if let Some(ix) = self
            .waiting
            .iter()
            .position(|&seq| self.seqs[seq].class == SloClass::Interactive)
        {
            return self.waiting.remove(ix);
        }
        if *batch_admits >= batch_cap {
            return None;
        }
        let seq = self.waiting.pop_front()?;
        *batch_admits += 1;
        Some(seq)
    }

    /// Fold one head token message; returns what it meant per sequence.
    pub fn on_token(&mut self, msg: &TokenMsg) -> Result<Vec<SeqEvent>> {
        let ri = self
            .runs
            .iter()
            .position(|r| r.id == msg.group)
            .ok_or_else(|| anyhow::anyhow!("token for unknown run {}", msg.group))?;
        let mut events = Vec::new();
        match msg.origin {
            TokenOrigin::Admit { slot } => {
                // a preempted prefill's stale first token: swallow it
                // (FIFO channels guarantee it precedes any later
                // admission's token for this slot)
                if let Some(n) = self.ghosts.get_mut(&(msg.group, slot)) {
                    *n -= 1;
                    if *n == 0 {
                        self.ghosts.remove(&(msg.group, slot));
                    }
                    return Ok(events);
                }
                let Slot::Prefilling { seq } = self.runs[ri].slots[slot] else {
                    bail!("admit token for run {} slot {slot} not prefilling", msg.group);
                };
                ensure!(msg.tokens.len() == 1, "admit token batch must be 1");
                let tok = msg.tokens[0];
                if !self.seqs[seq].generated.is_empty() {
                    // recompute re-admission: the re-prefill must
                    // reproduce the served history (the model is
                    // deterministic), and the request's first token was
                    // already delivered — verify, don't re-emit First
                    ensure!(
                        tok == self.seqs[seq].generated[0],
                        "recompute replay diverged for request {}: re-prefill produced \
                         token {tok}, history starts with {}",
                        self.seqs[seq].id,
                        self.seqs[seq].generated[0]
                    );
                    self.runs[ri].slots[slot] = Slot::Active {
                        seq,
                        pos: self.prompt_len as i32,
                        last_tok: tok,
                    };
                    return Ok(events);
                }
                self.seqs[seq].generated.push(tok);
                events.push(SeqEvent::First {
                    req_id: self.seqs[seq].id,
                });
                if self.seqs[seq].generated.len() >= self.seqs[seq].max_new {
                    self.retire(ri, slot, seq, &mut events);
                } else {
                    self.runs[ri].slots[slot] = Slot::Active {
                        seq,
                        pos: self.prompt_len as i32,
                        last_tok: tok,
                    };
                }
            }
            TokenOrigin::Step => {
                let live = self.runs[ri].step_live.take().ok_or_else(|| {
                    anyhow::anyhow!("step token for run {} with no step in flight", msg.group)
                })?;
                ensure!(
                    msg.tokens.len() == live.len(),
                    "step token batch {} != composed batch {}",
                    msg.tokens.len(),
                    live.len()
                );
                let mut n_live = 0usize;
                for (slot, maybe_seq) in live.iter().enumerate() {
                    let Some(seq) = *maybe_seq else { continue };
                    n_live += 1;
                    let tok = msg.tokens[slot];
                    let Slot::Active { pos: row_pos, .. } = self.runs[ri].slots[slot] else {
                        bail!("stepped slot {slot} of run {} not active", msg.group);
                    };
                    // idx of the token this step produced in the served
                    // history; < len means a recompute replay step —
                    // verify determinism and advance without re-serving
                    let idx = row_pos as usize + 1 - self.prompt_len;
                    if idx < self.seqs[seq].generated.len() {
                        ensure!(
                            tok == self.seqs[seq].generated[idx],
                            "recompute replay diverged for request {} at position \
                             {row_pos}: step produced token {tok}, history holds {}",
                            self.seqs[seq].id,
                            self.seqs[seq].generated[idx]
                        );
                        let Slot::Active { pos, last_tok, .. } = &mut self.runs[ri].slots[slot]
                        else {
                            unreachable!()
                        };
                        *pos += 1;
                        *last_tok = tok;
                        continue;
                    }
                    self.seqs[seq].generated.push(tok);
                    if self.seqs[seq].generated.len() >= self.seqs[seq].max_new {
                        self.retire(ri, slot, seq, &mut events);
                    } else {
                        let Slot::Active { pos, last_tok, .. } = &mut self.runs[ri].slots[slot]
                        else {
                            unreachable!()
                        };
                        *pos += 1;
                        *last_tok = tok;
                    }
                }
                events.push(SeqEvent::StepDone {
                    run: msg.group,
                    live: n_live,
                });
            }
            TokenOrigin::Group => bail!("classic group token in continuous mode"),
        }
        Ok(events)
    }

    fn retire(&mut self, ri: usize, slot: usize, seq: usize, events: &mut Vec<SeqEvent>) {
        events.push(SeqEvent::Finished {
            req_id: self.seqs[seq].id,
            tokens: self.seqs[seq].generated.clone(),
        });
        self.outbox.push(Action::Evict {
            run: self.runs[ri].id,
            slot,
        });
        self.runs[ri].slots[slot] = Slot::Free;
    }

    /// Every live run's composition and per-row replay state — what a
    /// checkpoint records as its watermark and what failover reconstructs
    /// from.  Runs with no occupied slot (drained or never allocated) are
    /// omitted: there is nothing of theirs to rebuild.
    pub fn snapshot(&self) -> Vec<RunSnap> {
        self.runs
            .iter()
            .filter(|r| !r.freed)
            .filter_map(|r| {
                let rows: Vec<RowSnap> = r
                    .slots
                    .iter()
                    .enumerate()
                    .filter_map(|(slot, s)| {
                        let (seq, prefilling) = match s {
                            Slot::Prefilling { seq } => (*seq, true),
                            Slot::Active { seq, .. } => (*seq, false),
                            Slot::Free => return None,
                        };
                        Some(RowSnap {
                            slot,
                            req_id: self.seqs[seq].id,
                            prompt: self.seqs[seq].prompt.clone(),
                            generated: self.seqs[seq].generated.clone(),
                            prefilling,
                        })
                    })
                    .collect();
                (!rows.is_empty()).then_some(RunSnap {
                    run: r.id,
                    batch: r.batch,
                    rows,
                })
            })
            .collect()
    }

    /// Batch sizes of the runs still holding occupied slots — the cheap
    /// (no history cloning) slice of [`SlotScheduler::snapshot`] the
    /// per-token drive view needs.
    pub fn run_batches(&self) -> Vec<usize> {
        self.runs
            .iter()
            .filter(|r| !r.freed && r.slots.iter().any(|s| !matches!(s, Slot::Free)))
            .map(|r| r.batch)
            .collect()
    }

    /// Whether any admission is currently in flight.
    pub fn any_prefilling(&self) -> bool {
        self.runs.iter().any(|r| r.prefilling() > 0)
    }

    /// The pipeline was replaced under us (failover): every frame in
    /// flight died with it.  Per-row state (position, last token, served
    /// history) is untouched — it only ever advances on folds — so the
    /// next [`SlotScheduler::pump`] recomposes each run's dead step
    /// verbatim.  Admissions whose first token died are re-queued; queued
    /// retirements are dropped, because the hook rebuilt the new
    /// pipeline's caches from the *current* composition, which already
    /// excludes retired rows.
    pub fn on_failover(&mut self) {
        self.outbox.clear();
        // ghost (preempted) admit tokens died with the pipeline: a
        // surviving ghost entry would swallow a *re-sent* admission's
        // real first token
        self.ghosts.clear();
        for ri in 0..self.runs.len() {
            self.runs[ri].step_live = None;
            for slot in 0..self.runs[ri].batch {
                let Slot::Prefilling { seq } = self.runs[ri].slots[slot] else {
                    continue;
                };
                let run = &self.runs[ri];
                self.outbox.push(Action::Admit {
                    run: run.id,
                    slot,
                    run_batch: run.batch,
                    req: self.seqs[seq].id,
                    prompt: self.seqs[seq].prompt.clone(),
                });
                // the re-sent frame carries a real row again
                self.rows_real += 1;
                self.rows_total += 1;
            }
        }
    }

    /// Nothing queued, composed or in flight — though runs may still
    /// hold idle cache allocations while the scheduler is open (an idle
    /// open scheduler is waiting for arrivals, not finished).
    pub fn idle(&self) -> bool {
        self.waiting.is_empty()
            && self.parked.is_empty()
            && self.outbox.is_empty()
            && self.runs.iter().all(|r| {
                r.step_live.is_none() && r.slots.iter().all(|s| matches!(s, Slot::Free))
            })
    }

    /// All sequences served, all retirements flushed, all runs freed.
    pub fn done(&self) -> bool {
        self.idle() && self.runs.iter().all(|r| r.freed || !r.allocated)
    }

    /// (real rows, total rows) carried by every frame sent so far — the
    /// padding-efficiency numerator/denominator.
    pub fn rows(&self) -> (u64, u64) {
        (self.rows_real, self.rows_total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(max_news: &[usize]) -> Vec<GenRequest> {
        max_news
            .iter()
            .enumerate()
            .map(|(i, &m)| GenRequest::new(100 + i as u64, vec![1, 2, 3], m))
            .collect()
    }

    fn tok(run: u64, iter: usize, tokens: Vec<i32>, origin: TokenOrigin) -> TokenMsg {
        TokenMsg {
            group: run,
            iter,
            tokens,
            origin,
        }
    }

    /// Drive the scheduler without an engine: every Admit/Step is
    /// answered with a synthetic token.  Returns per-request token counts.
    fn drive(sched: &mut SlotScheduler) -> std::collections::HashMap<u64, usize> {
        let mut finished = std::collections::HashMap::new();
        let mut pending: VecDeque<TokenMsg> = VecDeque::new();
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 10_000, "scheduler did not converge");
            for a in sched.pump() {
                match a {
                    Action::Admit { run, slot, .. } => {
                        pending.push_back(tok(run, 0, vec![7], TokenOrigin::Admit { slot }))
                    }
                    Action::Step {
                        run, iter, batch, ..
                    } => pending.push_back(tok(run, iter, vec![9; batch], TokenOrigin::Step)),
                    _ => {}
                }
            }
            let Some(t) = pending.pop_front() else { break };
            for ev in sched.on_token(&t).unwrap() {
                if let SeqEvent::Finished { req_id, tokens } = ev {
                    assert!(finished.insert(req_id, tokens.len()).is_none());
                }
            }
        }
        assert!(sched.done(), "scheduler not drained");
        finished
    }

    #[test]
    fn serves_every_request_to_its_own_length() {
        let rs = reqs(&[3, 1, 5, 2, 4, 1, 1, 6, 2, 3]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig::default(),
            8,
            vec![1, 4],
            &rs,
        )
        .unwrap();
        let fin = drive(&mut s);
        assert_eq!(fin.len(), rs.len());
        for r in &rs {
            assert_eq!(fin[&r.id], r.max_new_tokens, "request {}", r.id);
        }
        let (real, total) = s.rows();
        assert!(real > 0 && total >= real);
    }

    #[test]
    fn retirement_frees_slots_for_waiting_requests() {
        // capacity 2 (1 run × batch 2), 4 requests: the two short ones
        // must be admitted as soon as the first pair retires.
        let rs = reqs(&[2, 2, 1, 1]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig {
                runs: 1,
                max_batch: Some(2),
                ..ContinuousConfig::default()
            },
            4,
            vec![1, 2],
            &rs,
        )
        .unwrap();
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 4);
    }

    #[test]
    fn grows_from_a_small_initial_batch() {
        let rs = reqs(&[4, 4, 4, 4, 4]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig {
                runs: 1,
                initial_batch: Some(1),
                ..ContinuousConfig::default()
            },
            4,
            vec![1, 2, 8],
            &rs,
        )
        .unwrap();
        // first pump admits one and (queue still long) grows next pump
        let acts = s.pump();
        assert!(acts.iter().any(|a| matches!(a, Action::Admit { run_batch: 1, .. })));
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 5);
        assert!(s.runs[0].batch > 1, "never grew");
    }

    #[test]
    fn shrinks_at_the_tail() {
        let rs = reqs(&[6, 1, 1, 1]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig {
                runs: 1,
                ..ContinuousConfig::default()
            },
            4,
            vec![1, 4],
            &rs,
        )
        .unwrap();
        let mut saw_shrink = false;
        let mut pending: VecDeque<TokenMsg> = VecDeque::new();
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 1000);
            for a in s.pump() {
                match a {
                    Action::Admit { run, slot, .. } => {
                        pending.push_back(tok(run, 0, vec![7], TokenOrigin::Admit { slot }))
                    }
                    Action::Step {
                        run, iter, batch, ..
                    } => pending.push_back(tok(run, iter, vec![9; batch], TokenOrigin::Step)),
                    Action::Compact { new_batch, .. } => saw_shrink |= new_batch == 1,
                    _ => {}
                }
            }
            let Some(t) = pending.pop_front() else { break };
            s.on_token(&t).unwrap();
        }
        assert!(s.done());
        assert!(saw_shrink, "tail never compacted to batch 1");
    }

    #[test]
    fn snapshot_rederives_row_state_and_failover_requeues_prefills() {
        let rs = reqs(&[4, 4, 4]);
        let mut s =
            SlotScheduler::new(&ContinuousConfig { runs: 1, ..Default::default() }, 4, vec![1, 4], &rs)
                .unwrap();
        // first pump: three admits (+ no step yet)
        let acts = s.pump();
        let admits: Vec<_> = acts
            .iter()
            .filter(|a| matches!(a, Action::Admit { .. }))
            .cloned()
            .collect();
        assert_eq!(admits.len(), 3);
        // fold two first tokens, leave slot 2 prefilling
        s.on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot: 0 })).unwrap();
        s.on_token(&tok(RUN_ID_BASE, 0, vec![8], TokenOrigin::Admit { slot: 1 })).unwrap();
        // compose + fold one decode step over the two active rows
        let acts = s.pump();
        let Some(Action::Step { batch, .. }) =
            acts.iter().find(|a| matches!(a, Action::Step { .. }))
        else {
            panic!("no step composed: {acts:?}")
        };
        s.on_token(&tok(RUN_ID_BASE, 0, vec![9; *batch], TokenOrigin::Step)).unwrap();

        let snap = s.snapshot();
        assert_eq!(snap.len(), 1);
        let run = &snap[0];
        assert_eq!(run.run, RUN_ID_BASE);
        assert_eq!(run.rows.len(), 3);
        let row0 = run.rows.iter().find(|r| r.slot == 0).unwrap();
        assert_eq!(row0.req_id, 100);
        assert_eq!(row0.generated, vec![7, 9]);
        assert!(!row0.prefilling);
        assert_eq!(row0.prompt.len(), 4, "prompt fitted to prompt_len");
        let row2 = run.rows.iter().find(|r| r.slot == 2).unwrap();
        assert!(row2.prefilling);
        assert!(row2.generated.is_empty());

        // kill the pipeline mid-step: compose a step, then fail over
        let acts = s.pump();
        assert!(acts.iter().any(|a| matches!(a, Action::Step { .. })));
        s.on_failover();
        let acts = s.pump();
        // the dead admit is re-queued and the dead step recomposed with
        // the identical feedback tokens/positions
        let readmit = acts.iter().find(|a| matches!(a, Action::Admit { slot: 2, .. }));
        assert!(readmit.is_some(), "prefilling row not re-admitted: {acts:?}");
        let step = acts
            .iter()
            .find_map(|a| match a {
                Action::Step { pos, tokens, .. } => Some((pos.clone(), tokens.clone())),
                _ => None,
            })
            .expect("dead step not recomposed");
        // rows 0 and 1 decode at absolute position prompt_len + 1 with
        // their last folded token; slots 2/3 are dead in the map
        assert_eq!(step.0, vec![5, 5, -1, -1]);
        assert_eq!(step.1[0], 9);
        assert_eq!(step.1[1], 9);
        // answer the re-sent frames; the scheduler then drains normally
        s.on_token(&tok(RUN_ID_BASE, 0, vec![9; 4], TokenOrigin::Step)).unwrap();
        s.on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot: 2 })).unwrap();
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 3);
        assert!(fin.values().all(|&n| n == 4));
    }

    #[test]
    fn single_token_requests_retire_at_admission() {
        let rs = reqs(&[1, 1, 1]);
        let mut s =
            SlotScheduler::new(&ContinuousConfig::default(), 4, vec![1, 2], &rs).unwrap();
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 3);
        assert!(fin.values().all(|&n| n == 1));
    }

    #[test]
    fn open_scheduler_serves_arrivals_across_lulls() {
        // An open scheduler must keep its runs alive through a drained
        // queue (no FreeRun) so a later arrival can be admitted, and
        // must free them only after close().
        let mut s = SlotScheduler::new_open(
            &ContinuousConfig { runs: 1, ..ContinuousConfig::default() },
            4,
            vec![1, 2],
        )
        .unwrap();
        // drive() asserts done(), which an open scheduler never reaches:
        // answer frames by hand until it goes idle instead
        fn drive_to_idle(s: &mut SlotScheduler) -> std::collections::HashMap<u64, usize> {
            let mut finished = std::collections::HashMap::new();
            let mut pending: VecDeque<TokenMsg> = VecDeque::new();
            let mut guard = 0;
            loop {
                guard += 1;
                assert!(guard < 1000, "open scheduler did not go idle");
                for a in s.pump() {
                    match a {
                        Action::Admit { run, slot, .. } => {
                            pending.push_back(tok(run, 0, vec![7], TokenOrigin::Admit { slot }))
                        }
                        Action::Step { run, iter, batch, .. } => {
                            pending.push_back(tok(run, iter, vec![9; batch], TokenOrigin::Step))
                        }
                        _ => {}
                    }
                }
                let Some(t) = pending.pop_front() else { break };
                for ev in s.on_token(&t).unwrap() {
                    if let SeqEvent::Finished { req_id, tokens } = ev {
                        assert!(finished.insert(req_id, tokens.len()).is_none());
                    }
                }
            }
            finished
        }

        assert!(s.idle() && s.done(), "fresh open scheduler is idle");
        s.push_request(&reqs(&[2])[0]).unwrap();
        let fin = drive_to_idle(&mut s);
        assert_eq!(fin.len(), 1);
        // drained, but open: idle yes, done no (the run stays allocated)
        assert!(s.idle());
        assert!(!s.done(), "open scheduler freed its run during a lull");
        // a second wave after the lull is served by the same run
        s.push_request(&GenRequest::new(200, vec![4, 5], 3)).unwrap();
        let fin = drive_to_idle(&mut s);
        assert_eq!(fin[&200], 3);
        assert!(!s.done());
        // close(): the next pump frees the drained run and done() flips
        s.close();
        let acts = s.pump();
        assert!(acts.iter().any(|a| matches!(a, Action::FreeRun { .. })));
        assert!(s.done());
    }

    #[test]
    fn bounded_prefill_policy_caps_admissions_ahead_of_a_decode_step() {
        // 2 one-token requests retire at admission, freeing 2 slots while
        // 6 active rows keep decoding and 2 more requests wait.  FIFO
        // stacks both waiting prefills ahead of the next decode step; a
        // BoundedPrefill(1) policy admits exactly one per step gap.
        let lens = [1usize, 1, 4, 4, 4, 4, 4, 4, 4, 4];
        let mk = |policy: AdmissionPolicy| {
            let rs = reqs(&lens);
            let mut s = SlotScheduler::new(
                &ContinuousConfig { runs: 1, ..ContinuousConfig::default() },
                4,
                vec![1, 8],
                &rs,
            )
            .unwrap();
            s.set_policy(policy);
            // first pump: 8 admissions (no decode step in flight yet —
            // the bound only protects in-flight decodes)
            let acts = s.pump();
            assert_eq!(
                acts.iter().filter(|a| matches!(a, Action::Admit { .. })).count(),
                8
            );
            // slots 0 and 1 retire at admission (max_new 1); 2..8 decode
            for slot in 0..8 {
                s.on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot }))
                    .unwrap();
            }
            // next pump: 2 free slots, 2 waiting, 6 live rows
            s.pump()
        };

        let fifo = mk(AdmissionPolicy::Fifo);
        assert_eq!(
            fifo.iter().filter(|a| matches!(a, Action::Admit { .. })).count(),
            2,
            "FIFO fills every free slot: {fifo:?}"
        );
        let bounded = mk(AdmissionPolicy::BoundedPrefill(1));
        assert_eq!(
            bounded.iter().filter(|a| matches!(a, Action::Admit { .. })).count(),
            1,
            "bounded policy must admit exactly one prefill: {bounded:?}"
        );
        // the decode step still rides behind the single admission
        assert!(bounded.iter().any(|a| matches!(a, Action::Step { .. })));
        // and the bound starves nobody: the scheduler still drains fully
        let rs = reqs(&lens);
        let mut s = SlotScheduler::new(
            &ContinuousConfig { runs: 1, ..ContinuousConfig::default() },
            4,
            vec![1, 8],
            &rs,
        )
        .unwrap();
        s.set_policy(AdmissionPolicy::BoundedPrefill(1));
        let fin = drive(&mut s);
        assert_eq!(fin.len(), lens.len());
    }

    use super::super::admission::SloPolicy;

    /// Interleaved batch/interactive arrivals: one slot free per pump,
    /// SLO admission must pull every interactive request first.
    #[test]
    fn slo_priority_admits_interactive_first() {
        // ids 100 (batch), 101 (int), 102 (batch), 103 (int)
        let rs: Vec<GenRequest> = reqs(&[2, 2, 2, 2])
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                r.with_class(if i % 2 == 0 { SloClass::Batch } else { SloClass::Interactive })
            })
            .collect();
        let mut s = SlotScheduler::new(
            &ContinuousConfig { runs: 1, max_batch: Some(1), ..Default::default() },
            4,
            vec![1],
            &rs,
        )
        .unwrap();
        s.set_policy(AdmissionPolicy::SloPriority(SloPolicy::default()));
        let acts = s.pump();
        let first = acts
            .iter()
            .find_map(|a| match a {
                Action::Admit { req, .. } => Some(*req),
                _ => None,
            })
            .expect("no admission");
        assert_eq!(first, 101, "oldest interactive jumps the batch head");
        // everything still drains (batch is not starved once interactive
        // work is done)
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 4);
    }

    /// The aged-batch flag promotes exactly one batch request ahead of
    /// interactive admissions, then clears.
    #[test]
    fn slo_aged_batch_promotion_jumps_the_line_once() {
        let rs: Vec<GenRequest> = vec![
            reqs(&[2])[0].clone().with_class(SloClass::Batch),
            GenRequest::new(200, vec![1, 2], 2),
            GenRequest::new(201, vec![1, 2], 2),
        ];
        let mut s = SlotScheduler::new(
            &ContinuousConfig { runs: 1, max_batch: Some(2), ..Default::default() },
            4,
            vec![2],
            &rs,
        )
        .unwrap();
        s.set_policy(AdmissionPolicy::SloPriority(SloPolicy::default()));
        s.set_batch_aged(true);
        let acts = s.pump();
        let admitted: Vec<u64> = acts
            .iter()
            .filter_map(|a| match a {
                Action::Admit { req, .. } => Some(*req),
                _ => None,
            })
            .collect();
        // aged batch request first, then the oldest interactive
        assert_eq!(admitted, vec![100, 200]);
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 3);
    }

    /// Preempting an in-flight batch prefill evicts the slot, re-queues
    /// the request, and ghost-swallows the stale first token so a later
    /// admission into the same slot folds correctly.
    #[test]
    fn preempted_batch_prefill_requeues_and_swallows_stale_token() {
        let rs: Vec<GenRequest> = vec![
            reqs(&[3])[0].clone().with_class(SloClass::Batch),
            GenRequest::new(200, vec![4, 5], 3),
        ];
        let mut s = SlotScheduler::new(
            &ContinuousConfig { runs: 1, max_batch: Some(1), ..Default::default() },
            4,
            vec![1],
            &rs,
        )
        .unwrap();
        s.set_policy(AdmissionPolicy::SloPriority(SloPolicy::default()));
        // interactive 200 admitted first (priority), batch 100 waits;
        // serve 200 out of the way so the batch prefill goes in flight
        let acts = s.pump();
        assert!(matches!(acts[0], Action::Admit { req: 200, .. }), "{acts:?}");
        s.on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot: 0 })).unwrap();
        for _ in 0..2 {
            let acts = s.pump();
            assert!(acts.iter().any(|a| matches!(a, Action::Step { .. })), "{acts:?}");
            s.on_token(&tok(RUN_ID_BASE, 0, vec![9], TokenOrigin::Step)).unwrap();
        }
        // 200 retired; batch 100's prefill dispatches now
        let acts = s.pump();
        assert!(
            acts.iter().any(|a| matches!(a, Action::Admit { req: 100, .. })),
            "{acts:?}"
        );
        assert!(s.any_prefilling());
        // preempt it while its first token is in flight
        assert_eq!(s.preempt_batch_prefills(4), 1);
        let acts = s.pump();
        // the eviction flushes, and the request is re-admitted (nothing
        // else waits) — a second Admit for the same slot
        assert!(acts.iter().any(|a| matches!(a, Action::Evict { slot: 0, .. })), "{acts:?}");
        assert!(
            acts.iter().any(|a| matches!(a, Action::Admit { req: 100, .. })),
            "{acts:?}"
        );
        // stale first token (from the preempted admission) is swallowed
        let evs = s
            .on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot: 0 }))
            .unwrap();
        assert!(evs.is_empty(), "ghost token must fold to nothing: {evs:?}");
        // the re-sent admission's token folds normally
        let evs = s
            .on_token(&tok(RUN_ID_BASE, 0, vec![8], TokenOrigin::Admit { slot: 0 }))
            .unwrap();
        assert!(
            evs.iter().any(|e| matches!(e, SeqEvent::First { req_id: 100 })),
            "{evs:?}"
        );
        let fin = drive(&mut s);
        assert_eq!(fin[&100], 3);
    }

    /// drop_waiting removes only matching queued requests and reports
    /// their ids; admitted requests are untouched.
    #[test]
    fn drop_waiting_expires_queued_only() {
        let rs = reqs(&[2, 2, 2]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig { runs: 1, max_batch: Some(1), ..Default::default() },
            4,
            vec![1],
            &rs,
        )
        .unwrap();
        let acts = s.pump();
        assert!(matches!(acts[0], Action::Admit { req: 100, .. }));
        // 100 is admitted; expire 101 but not 102
        let dropped = s.drop_waiting(|id| id == 101 || id == 100);
        assert_eq!(dropped, vec![101]);
        s.on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot: 0 })).unwrap();
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 2, "100 and 102 served, 101 expired: {fin:?}");
        assert!(fin.contains_key(&100) && fin.contains_key(&102));
    }

    /// The deterministic "model" the paged tests drive against: every
    /// token is a pure function of (request id, token index), exactly
    /// the property a real deterministic pipeline has.  The scheduler's
    /// replay verification cross-checks preempted rows against it.
    fn model_tok(req: u64, idx: usize) -> i32 {
        ((req * 31 + idx as u64 * 7) % 97) as i32 + 1
    }

    enum Pend {
        Admit { run: u64, slot: usize },
        Step { run: u64, pos: Vec<i32> },
    }

    /// Drive the scheduler answering every frame from [`model_tok`],
    /// asserting after each pump that block occupancy never exceeds the
    /// paged budget and that no request sees a duplicate First event.
    /// Returns (req -> tokens, swap-outs seen, swap-ins seen).
    fn drive_model(
        s: &mut SlotScheduler,
        prompt_len: usize,
    ) -> (std::collections::HashMap<u64, Vec<i32>>, usize, usize) {
        let mut finished = std::collections::HashMap::new();
        let mut firsts: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
        let mut pending: VecDeque<Pend> = VecDeque::new();
        let (mut swap_outs, mut swap_ins) = (0usize, 0usize);
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 100_000, "scheduler did not converge");
            for a in s.pump() {
                match a {
                    Action::Admit { run, slot, .. } => pending.push_back(Pend::Admit { run, slot }),
                    Action::Step { run, pos, .. } => pending.push_back(Pend::Step { run, pos }),
                    Action::SwapOut { .. } => swap_outs += 1,
                    Action::SwapIn { .. } => swap_ins += 1,
                    _ => {}
                }
            }
            if let Some(p) = &s.paged {
                assert!(
                    s.used_blocks() <= p.capacity_blocks,
                    "block budget exceeded: {} used of {}",
                    s.used_blocks(),
                    p.capacity_blocks
                );
            }
            let Some(p) = pending.pop_front() else { break };
            let snap = s.snapshot();
            let req_at = |run: u64, slot: usize| -> u64 {
                snap.iter()
                    .find(|r| r.run == run)
                    .and_then(|r| r.rows.iter().find(|x| x.slot == slot))
                    .map(|x| x.req_id)
                    .unwrap_or_else(|| panic!("no row at run {run} slot {slot}"))
            };
            let t = match p {
                Pend::Admit { run, slot } => tok(
                    run,
                    0,
                    vec![model_tok(req_at(run, slot), 0)],
                    TokenOrigin::Admit { slot },
                ),
                Pend::Step { run, pos } => {
                    let toks = pos
                        .iter()
                        .enumerate()
                        .map(|(slot, &p)| {
                            if p < 0 {
                                0
                            } else {
                                model_tok(req_at(run, slot), p as usize + 1 - prompt_len)
                            }
                        })
                        .collect();
                    tok(run, 0, toks, TokenOrigin::Step)
                }
            };
            for ev in s.on_token(&t).unwrap() {
                match ev {
                    SeqEvent::First { req_id } => *firsts.entry(req_id).or_insert(0) += 1,
                    SeqEvent::Finished { req_id, tokens } => {
                        assert!(finished.insert(req_id, tokens).is_none());
                    }
                    SeqEvent::StepDone { .. } => {}
                }
            }
        }
        assert!(s.done(), "scheduler not drained");
        for (req, n) in &firsts {
            assert_eq!(*n, 1, "request {req} got {n} First events");
        }
        for req in finished.keys() {
            assert_eq!(firsts.get(req), Some(&1), "request {req} finished without First");
        }
        (finished, swap_outs, swap_ins)
    }

    fn expected_tokens(req: u64, n: usize) -> Vec<i32> {
        (0..n).map(|i| model_tok(req, i)).collect()
    }

    /// A pool too small for every row at once: admissions defer (never
    /// refuse), swap-out preemption parks later arrivals, and every
    /// request is still served its exact unconstrained token sequence.
    #[test]
    fn paged_swapout_preempts_resumes_and_serves_identically() {
        let rs = reqs(&[6, 6, 6, 6]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig {
                runs: 1,
                preempt: PreemptMode::SwapOut,
                ..ContinuousConfig::default()
            },
            4,
            vec![1, 4],
            &rs,
        )
        .unwrap();
        // 4 positions/prompt at block 2 = 2 blocks per prefill; 4 rows
        // decoding to 6 tokens want 4*ceil(9/2) = 20 blocks; give 7 so
        // the pool saturates and preemption must kick in
        s.set_paged(2, 7).unwrap();
        let (fin, outs, ins) = drive_model(&mut s, 4);
        assert_eq!(fin.len(), rs.len());
        for r in &rs {
            assert_eq!(
                fin[&r.id],
                expected_tokens(r.id, r.max_new_tokens),
                "request {} tokens differ from unconstrained run",
                r.id
            );
        }
        assert!(outs > 0, "pool this tight must preempt");
        assert_eq!(outs, ins, "every swapped-out row must swap back in");
        assert!(s.peak_live_rows() >= 2, "paged pool should hold 2+ rows");
    }

    /// Recompute preemption: the victim's KV is dropped, the request
    /// re-queued, and on re-admission its served history is replayed
    /// and verified — the caller still sees each token exactly once.
    #[test]
    fn paged_recompute_replays_history_verbatim() {
        let rs = reqs(&[6, 6, 6, 6]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig {
                runs: 1,
                preempt: PreemptMode::Recompute,
                ..ContinuousConfig::default()
            },
            4,
            vec![1, 4],
            &rs,
        )
        .unwrap();
        s.set_paged(2, 7).unwrap();
        let (fin, outs, ins) = drive_model(&mut s, 4);
        assert_eq!((outs, ins), (0, 0), "recompute mode never swaps");
        assert_eq!(fin.len(), rs.len());
        for r in &rs {
            assert_eq!(fin[&r.id], expected_tokens(r.id, r.max_new_tokens));
        }
    }

    /// Randomized pressure property: over random block budgets and
    /// ragged arrival mixes, in both preempt modes, admission never
    /// exceeds the budget (asserted inside [`drive_model`] after every
    /// pump), every deferred or preempted request is eventually served,
    /// and the tokens are byte-identical to an unconstrained run.
    #[test]
    fn paged_pressure_randomized_never_overflows_and_serves_all() {
        let prompt_len = 4usize;
        for seed in 0..24u64 {
            let mut rng = crate::util::Rng::new(0x9A6ED + seed);
            let n_reqs = 3 + rng.next_below(8) as usize;
            let lens: Vec<usize> = (0..n_reqs).map(|_| 1 + rng.next_below(10) as usize).collect();
            let block_size = 1 + rng.next_below(4) as usize;
            // between "one row barely fits" and "everything fits"
            let min_cap = prompt_len.div_ceil(block_size)
                + (prompt_len + 10).div_ceil(block_size)
                + 1;
            let capacity = min_cap + rng.next_below(12) as usize;
            let preempt = if seed % 2 == 0 { PreemptMode::SwapOut } else { PreemptMode::Recompute };
            let rs = reqs(&lens);
            let mut s = SlotScheduler::new(
                &ContinuousConfig {
                    runs: 1 + rng.next_below(2) as usize,
                    preempt,
                    ..ContinuousConfig::default()
                },
                prompt_len,
                vec![1, 2, 4],
                &rs,
            )
            .unwrap();
            s.set_paged(block_size, capacity).unwrap();
            let (fin, outs, ins) = drive_model(&mut s, prompt_len);
            assert_eq!(fin.len(), rs.len(), "seed {seed}: not every request served");
            for r in &rs {
                assert_eq!(
                    fin[&r.id],
                    expected_tokens(r.id, r.max_new_tokens),
                    "seed {seed}: request {} diverged from unconstrained run",
                    r.id
                );
            }
            assert_eq!(outs, ins, "seed {seed}: swap-out/in mismatch");
        }
    }
}
