//! Iteration-level slot scheduler — the continuous-batching policy.
//!
//! Classic serving packs requests into fixed groups up front and drives
//! each group to completion: padding rows burn compute and KV bytes for
//! the group's whole lifetime, and a group holds its pipeline slot until
//! its *longest* request finishes.  This module replaces "pack once,
//! drive to completion" with vLLM/Orca-style **iteration-level
//! scheduling**: the unit of work is one decode iteration of a *run* (a
//! persistent compiled-batch of slots), and the scheduler recomposes
//! every run's batch between iterations.
//!
//! ## Slot lifecycle
//!
//! ```text
//! waiting ── admit ──▶ Prefilling ── first token ──▶ Active ──┐
//!    ▲                (StageMsg::Admit in flight)             │ decode steps
//!    │                                                        ▼
//!  Free ◀──────────────── retire (StageMsg::Evict) ◀── max_new reached
//! ```
//!
//! * **Admission**: whenever a run has a `Free` slot and requests are
//!   waiting, the scheduler emits [`Action::Admit`] — a batch-1 prefill
//!   that travels the pipeline and installs its KV as *one row* of the
//!   run's cache ([`crate::coordinator::kvcache::KvPool::insert_row`]).
//!   Admission order over the arrival queue is governed by the
//!   [`super::admission::AdmissionPolicy`] — FIFO, or FIFO with a bound
//!   on how many batch-1 prefills may be dispatched ahead of an
//!   in-flight decode step; because stage channels are FIFO too, an
//!   admission sent before a decode step is guaranteed to be resident
//!   before that step executes.  The queue itself may be fed live: an
//!   **open** scheduler ([`SlotScheduler::new_open`]) accepts arrivals
//!   via [`SlotScheduler::push_request`] and keeps drained runs
//!   allocated until [`SlotScheduler::close`].
//! * **Iteration**: each [`Action::Step`] carries the per-iteration slot
//!   map — per-row absolute positions, `-1` for dead rows, which the
//!   kernels skip — so a composed batch mixes sequences at unrelated
//!   positions.  One step per run is in flight at a time (autoregressive
//!   feedback); pipeline depth comes from multiple independent runs,
//!   exactly like micro-batches in classic pipelined serving.
//! * **Retirement**: a sequence that reaches `max_new_tokens` frees its
//!   KV bytes *immediately* ([`Action::Evict`], per-row accounting) and
//!   its slot becomes admissible in the very next iteration — short
//!   requests no longer queue behind long groups.
//! * **Recomposition**: when the arrival queue drains, runs shrink to the
//!   smallest compiled batch that holds their live rows
//!   ([`Action::Compact`]), and grow back (next compiled size) when
//!   demand returns.
//!
//! ## Interaction with migration barriers and failover
//!
//! The scheduler is pure policy: it never touches channels or clocks, so
//! the generation driver ([`super::driver`]) can stop pumping it at any
//! quiesce point — exactly the contract the adaptive engine's migration
//! barrier needs (drain in-flight iterations, move KV, resume).  Run
//! caches are ordinary [`crate::coordinator::kvcache::GroupCache`]s, so
//! [`crate::coordinator::stage::StageMsg::Export`] snapshots them like
//! any group's, and the driver's slot loop drains to a real barrier for
//! the adaptive engine's migration.
//!
//! Device-loss failover rides the same purity: [`SlotScheduler::snapshot`]
//! re-derives every occupied slot's replay state (request, prompt, served
//! history — position and last token fall out of the history length), and
//! [`SlotScheduler::on_failover`] resets the in-flight bookkeeping after
//! the pipeline has been replaced — dead steps are recomposed from the
//! unchanged per-row state on the next pump, and admissions whose first
//! token died in flight are re-queued verbatim.

use std::collections::{HashMap, VecDeque};

use super::admission::AdmissionPolicy;
use super::api::{GenRequest, SloClass};
use super::batcher::fit_prompt;
use super::stage::{TokenMsg, TokenOrigin};
use anyhow::{bail, ensure, Result};

/// Continuous-batching runs get ids far above the classic batcher's group
/// counter so the two id spaces can never collide inside one engine.
const RUN_ID_BASE: u64 = 1 << 32;

/// Smallest of `batch_sizes` (ascending) that holds `want` rows, clamped
/// to the largest available.
fn fit_batch(batch_sizes: &[usize], want: usize) -> usize {
    batch_sizes
        .iter()
        .copied()
        .find(|&b| b >= want)
        .unwrap_or_else(|| *batch_sizes.last().expect("no batch sizes"))
}

/// Knobs of the continuous-batching scheduler.
#[derive(Debug, Clone)]
pub struct ContinuousConfig {
    /// Independent runs (micro-batches) kept in flight — the pipeline
    /// depth.  One decode step per run is outstanding at a time.
    pub runs: usize,
    /// Cap on the compiled batch a run may use (None = largest compiled).
    pub max_batch: Option<usize>,
    /// Compiled batch runs start at (None = sized from the arrival
    /// queue).  Mostly a test/bench knob: starting small exercises the
    /// grow path.
    pub initial_batch: Option<usize>,
    /// Dead-man interval, real ms: with no stall hook (or a hook that
    /// never recovers), a pipeline silent this long makes the drive
    /// error out instead of hanging the server.  Defaults to
    /// [`super::driver::DEAD_PIPELINE_REAL_MS`]; tests shrink it.
    pub dead_man_real_ms: f64,
}

impl Default for ContinuousConfig {
    fn default() -> Self {
        ContinuousConfig {
            runs: 2,
            max_batch: None,
            initial_batch: None,
            dead_man_real_ms: super::driver::DEAD_PIPELINE_REAL_MS,
        }
    }
}

/// One instruction the driver must turn into a [`super::stage::StageMsg`].
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Prefill `prompt` (already fitted to the compiled length) at batch
    /// 1 and install it as row `slot` of run `run`.  `req` is the
    /// admitted request's id — the driver stamps its queue delay
    /// (arrival → this dispatch) off it.
    Admit {
        run: u64,
        slot: usize,
        run_batch: usize,
        req: u64,
        prompt: Vec<i32>,
    },
    /// One decode iteration over run `run`'s composed batch: `tokens` is
    /// the per-slot feedback (dead rows carry token 0), `pos` the slot
    /// map (`-1` = dead row).
    Step {
        run: u64,
        iter: usize,
        batch: usize,
        pos: Vec<i32>,
        tokens: Vec<i32>,
    },
    /// Retire row `slot` of run `run` (frees its KV bytes per-row).
    Evict { run: u64, slot: usize },
    /// Recompose run `run`'s cache at `new_batch` rows.
    Compact {
        run: u64,
        new_batch: usize,
        moves: Vec<(usize, usize)>,
    },
    /// The run drained: drop its cache allocation everywhere.
    FreeRun { run: u64 },
}

/// What one folded [`TokenMsg`] meant for the sequences involved.
#[derive(Debug, Clone, PartialEq)]
pub enum SeqEvent {
    /// A request's first token arrived (its TTFT sample point).
    First { req_id: u64 },
    /// One decode step of a run landed, carrying `live` real tokens.
    StepDone { run: u64, live: usize },
    /// A request finished; `tokens` is its full generation.
    Finished { req_id: u64, tokens: Vec<i32> },
}

#[derive(Debug)]
struct SeqState {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    generated: Vec<i32>,
    class: SloClass,
}

/// Replay state of one occupied slot, as checkpointing and failover see
/// it.  Everything a rebuilt pipeline needs to reconstruct the row:
/// `generated` is the served history (its length pins the row's absolute
/// position at `prompt_len + generated.len() - 1`, its last element is
/// the next step's feedback token), and `prompt` is the fitted prompt an
/// [`Action::Admit`] would carry.
#[derive(Debug, Clone, PartialEq)]
pub struct RowSnap {
    pub slot: usize,
    pub req_id: u64,
    /// Fitted prompt (exactly what the original admission sent).
    pub prompt: Vec<i32>,
    /// Folded tokens so far (empty while the admission is in flight).
    pub generated: Vec<i32>,
    /// Admission in flight — no first token yet; after a failover the
    /// driver re-admits this row live (its TTFT is still unmeasured).
    pub prefilling: bool,
}

/// One live run's composition: batch plus every occupied slot's
/// [`RowSnap`].  Produced by [`SlotScheduler::snapshot`] for the driver's
/// slot-mode stall view and for checkpoint watermarks.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSnap {
    pub run: u64,
    pub batch: usize,
    pub rows: Vec<RowSnap>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Slot {
    Free,
    /// `Admit` in flight; the first token has not returned yet.
    Prefilling { seq: usize },
    /// Decoding: the next step processes `last_tok` at absolute `pos`.
    Active { seq: usize, pos: i32, last_tok: i32 },
}

#[derive(Debug)]
struct Run {
    id: u64,
    batch: usize,
    slots: Vec<Slot>,
    iter: usize,
    /// Composition snapshot of the in-flight step (slot → seq index).
    step_live: Option<Vec<Option<usize>>>,
    /// Whether any admission was ever sent (stages hold a cache).
    allocated: bool,
    freed: bool,
}

impl Run {
    fn count(&self, f: impl Fn(&Slot) -> bool) -> usize {
        self.slots.iter().filter(|&s| f(s)).count()
    }

    fn live(&self) -> usize {
        self.count(|s| matches!(s, Slot::Active { .. }))
    }

    fn prefilling(&self) -> usize {
        self.count(|s| matches!(s, Slot::Prefilling { .. }))
    }

    fn free(&self) -> usize {
        self.count(|s| matches!(s, Slot::Free))
    }
}

/// The iteration-level scheduler: pure state machine, no channels, no
/// clocks.  The driver alternates [`SlotScheduler::pump`] (actions to
/// send) and [`SlotScheduler::on_token`] (fold one head token message).
#[derive(Debug)]
pub struct SlotScheduler {
    prompt_len: usize,
    /// Compiled batch sizes ≤ the configured cap, ascending.
    batch_sizes: Vec<usize>,
    waiting: VecDeque<usize>,
    seqs: Vec<SeqState>,
    runs: Vec<Run>,
    outbox: Vec<Action>,
    rows_real: u64,
    rows_total: u64,
    /// Admission-order policy ([`SlotScheduler::set_policy`]).
    policy: AdmissionPolicy,
    /// An open scheduler expects more arrivals ([`SlotScheduler::push_request`])
    /// and therefore keeps drained runs allocated (no [`Action::FreeRun`])
    /// until [`SlotScheduler::close`].
    open: bool,
    /// Anti-starvation flag ([`SlotScheduler::set_batch_aged`]): the next
    /// pump promotes one aged batch request ahead of interactive
    /// admissions, exempt from the batch prefill cap.  Consumed on use.
    batch_aged: bool,
    /// Stale in-flight admissions per `(run, slot)`: a preempted
    /// prefill's first token is still traveling the pipeline and must be
    /// swallowed, not folded.  Stage channels are FIFO, so the stale
    /// token is guaranteed to arrive before any later admission's token
    /// for the same slot — [`SlotScheduler::on_token`] drops exactly
    /// this many admit tokens per slot.
    ghosts: HashMap<(u64, usize), u32>,
}

impl SlotScheduler {
    /// Closed-loop construction: the whole request queue is known up
    /// front (and sizes the initial compiled batch).
    pub fn new(
        cfg: &ContinuousConfig,
        prompt_len: usize,
        batch_sizes: Vec<usize>,
        requests: &[GenRequest],
    ) -> Result<Self> {
        let seqs: Vec<SeqState> = requests
            .iter()
            .map(|r| {
                ensure!(r.max_new_tokens >= 1, "request {}: zero max_new_tokens", r.id);
                ensure!(!r.prompt.is_empty(), "request {}: empty prompt", r.id);
                Ok(SeqState {
                    id: r.id,
                    prompt: fit_prompt(&r.prompt, prompt_len),
                    max_new: r.max_new_tokens,
                    generated: Vec::new(),
                    class: r.class,
                })
            })
            .collect::<Result<_>>()?;
        Self::build(cfg, prompt_len, batch_sizes, seqs, false)
    }

    /// Open-loop construction: requests arrive later through
    /// [`SlotScheduler::push_request`], so runs start at the smallest
    /// compiled batch (or `initial_batch`) and grow with demand, and
    /// drained runs stay allocated until [`SlotScheduler::close`].
    pub fn new_open(
        cfg: &ContinuousConfig,
        prompt_len: usize,
        batch_sizes: Vec<usize>,
    ) -> Result<Self> {
        Self::build(cfg, prompt_len, batch_sizes, Vec::new(), true)
    }

    fn build(
        cfg: &ContinuousConfig,
        prompt_len: usize,
        mut batch_sizes: Vec<usize>,
        seqs: Vec<SeqState>,
        open: bool,
    ) -> Result<Self> {
        batch_sizes.sort_unstable();
        batch_sizes.dedup();
        ensure!(!batch_sizes.is_empty(), "need at least one compiled batch size");
        let max_batch = cfg.max_batch.unwrap_or(*batch_sizes.last().unwrap());
        ensure!(
            batch_sizes.contains(&max_batch),
            "max_batch {max_batch} not compiled (have {batch_sizes:?})"
        );
        batch_sizes.retain(|&b| b <= max_batch);
        if let Some(ib) = cfg.initial_batch {
            ensure!(
                batch_sizes.contains(&ib),
                "initial_batch {ib} not compiled (have {batch_sizes:?})"
            );
        }

        let n = seqs.len();
        let n_runs = if open {
            cfg.runs.max(1)
        } else {
            cfg.runs.max(1).min(n.max(1))
        };
        let init = cfg.initial_batch.unwrap_or_else(|| {
            if open {
                batch_sizes[0]
            } else {
                fit_batch(&batch_sizes, n.div_ceil(n_runs).max(1))
            }
        });
        let runs = (0..n_runs)
            .map(|i| Run {
                id: RUN_ID_BASE + i as u64,
                batch: init,
                slots: vec![Slot::Free; init],
                iter: 0,
                step_live: None,
                allocated: false,
                freed: false,
            })
            .collect();
        Ok(SlotScheduler {
            prompt_len,
            batch_sizes,
            waiting: (0..n).collect(),
            seqs,
            runs,
            outbox: Vec::new(),
            rows_real: 0,
            rows_total: 0,
            policy: AdmissionPolicy::Fifo,
            open,
            batch_aged: false,
            ghosts: HashMap::new(),
        })
    }

    /// Swap the admission policy (applies from the next pump).
    pub fn set_policy(&mut self, policy: AdmissionPolicy) {
        self.policy = policy;
    }

    /// Enqueue one more request (open-loop arrival).  Validation matches
    /// [`SlotScheduler::new`]; ids must be unique per drive (the TTFT
    /// and result bookkeeping is keyed by them).
    pub fn push_request(&mut self, r: &GenRequest) -> Result<()> {
        ensure!(r.max_new_tokens >= 1, "request {}: zero max_new_tokens", r.id);
        ensure!(!r.prompt.is_empty(), "request {}: empty prompt", r.id);
        self.seqs.push(SeqState {
            id: r.id,
            prompt: fit_prompt(&r.prompt, self.prompt_len),
            max_new: r.max_new_tokens,
            generated: Vec::new(),
            class: r.class,
        });
        self.waiting.push_back(self.seqs.len() - 1);
        Ok(())
    }

    /// Arm (or clear) the anti-starvation promotion: when armed, the
    /// next pump admits one waiting batch request ahead of interactive
    /// ones, exempt from [`super::admission::SloPolicy::batch_prefill_cap`].
    /// The driver arms it when the oldest queued batch request has waited
    /// past `aging_ms`.
    pub fn set_batch_aged(&mut self, aged: bool) {
        self.batch_aged = aged;
    }

    /// Waiting (not yet admitted) interactive requests.
    pub fn waiting_interactive(&self) -> usize {
        self.waiting
            .iter()
            .filter(|&&seq| self.seqs[seq].class == SloClass::Interactive)
            .count()
    }

    /// Free slots across live runs — admission capacity of the next pump.
    pub fn free_slots(&self) -> usize {
        self.runs.iter().filter(|r| !r.freed).map(|r| r.free()).sum()
    }

    /// Drop waiting requests whose id matches `pred` (deadline expiry):
    /// they leave the queue without ever dispatching a prefill.  Returns
    /// the dropped request ids.  Admitted requests are never touched —
    /// their prefill is already paid for.
    pub fn drop_waiting(&mut self, pred: impl Fn(u64) -> bool) -> Vec<u64> {
        let mut dropped = Vec::new();
        self.waiting.retain(|&seq| {
            if pred(self.seqs[seq].id) {
                dropped.push(self.seqs[seq].id);
                false
            } else {
                true
            }
        });
        dropped
    }

    /// Preempt up to `max_n` in-flight *batch* prefills (admitted, first
    /// token not yet back) to make room for waiting interactive work:
    /// each one is evicted (reusing the failover evict/re-queue path),
    /// its slot freed for the next pump's admission, and the request
    /// put back at the front of the waiting queue.  The stale first
    /// token still traveling the pipeline is ghost-swallowed by
    /// [`SlotScheduler::on_token`].  Returns how many were preempted.
    pub fn preempt_batch_prefills(&mut self, max_n: usize) -> usize {
        let mut preempted = 0usize;
        for ri in 0..self.runs.len() {
            if preempted >= max_n {
                break;
            }
            if self.runs[ri].freed {
                continue;
            }
            for slot in 0..self.runs[ri].batch {
                if preempted >= max_n {
                    break;
                }
                let Slot::Prefilling { seq } = self.runs[ri].slots[slot] else {
                    continue;
                };
                if self.seqs[seq].class != SloClass::Batch {
                    continue;
                }
                let run_id = self.runs[ri].id;
                self.outbox.push(Action::Evict { run: run_id, slot });
                self.runs[ri].slots[slot] = Slot::Free;
                *self.ghosts.entry((run_id, slot)).or_insert(0) += 1;
                self.waiting.push_front(seq);
                preempted += 1;
            }
        }
        preempted
    }

    /// The source is exhausted: no further [`SlotScheduler::push_request`]
    /// will come, so drained runs may free their caches.
    pub fn close(&mut self) {
        self.open = false;
    }

    /// Smallest compiled batch ≥ `want` (clamped to the largest allowed).
    fn fit(&self, want: usize) -> usize {
        fit_batch(&self.batch_sizes, want)
    }

    /// Upper bound on rows ever resident at once — every run at the
    /// largest allowed batch (an open scheduler cannot bound by request
    /// count: arrivals are unbounded; a closed one never exceeds its
    /// queue) — what admission control must budget for.
    pub fn worst_case_rows(&self) -> usize {
        let cap = self.runs.len() * self.batch_sizes.last().copied().unwrap_or(1);
        if self.open {
            cap
        } else {
            cap.min(self.seqs.len())
        }
    }

    /// Decode iterations still owed to the furthest-from-done admitted or
    /// waiting sequence — a conservative lower bound on how many more
    /// iterations this drive will run, which is what replan
    /// cost-awareness amortizes a migration pause over.
    pub fn max_remaining(&self) -> u64 {
        let occupied = self.runs.iter().flat_map(|r| &r.slots).filter_map(|s| match s {
            Slot::Prefilling { seq } | Slot::Active { seq, .. } => Some(*seq),
            Slot::Free => None,
        });
        occupied
            .chain(self.waiting.iter().copied())
            .map(|seq| {
                let s = &self.seqs[seq];
                s.max_new.saturating_sub(s.generated.len()) as u64
            })
            .max()
            .unwrap_or(0)
    }

    /// Next compiled batch strictly above `b`, if any.
    fn next_bigger(&self, b: usize) -> Option<usize> {
        self.batch_sizes.iter().copied().find(|&x| x > b)
    }

    /// Everything to send right now: retirements queued by
    /// [`Self::on_token`], then per-run recomposition, admissions and the
    /// next iteration for every run without a step in flight.
    pub fn pump(&mut self) -> Vec<Action> {
        let mut out: Vec<Action> = std::mem::take(&mut self.outbox);
        for ri in 0..self.runs.len() {
            self.pump_run(ri, &mut out);
        }
        out
    }

    fn pump_run(&mut self, ri: usize, out: &mut Vec<Action>) {
        if self.runs[ri].step_live.is_some() || self.runs[ri].freed {
            return;
        }

        // grow: demand exceeds capacity and a bigger compiled batch exists
        if !self.waiting.is_empty() && self.runs[ri].free() == 0 {
            if let Some(bigger) = self.next_bigger(self.runs[ri].batch) {
                let run = &mut self.runs[ri];
                if run.allocated {
                    let moves: Vec<(usize, usize)> = run
                        .slots
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| !matches!(s, Slot::Free))
                        .map(|(i, _)| (i, i))
                        .collect();
                    out.push(Action::Compact {
                        run: run.id,
                        new_batch: bigger,
                        moves,
                    });
                }
                run.slots.resize(bigger, Slot::Free);
                run.batch = bigger;
            }
        }

        // admissions: fill free slots from the arrival queue.  The
        // BoundedPrefill policy caps how many batch-1 prefills may be
        // dispatched ahead of this run's next decode step (each one is a
        // full pipeline pass the step must wait behind); a run with no
        // live rows has no decode step to delay and admits freely.  The
        // SloPriority policy admits interactive-first and applies the
        // prefill cap to batch admissions only (one aged batch request
        // may jump the line cap-free — anti-starvation).
        let decoding = self.runs[ri].live() > 0;
        let (cap, batch_cap) = match &self.policy {
            AdmissionPolicy::Fifo => (usize::MAX, usize::MAX),
            AdmissionPolicy::BoundedPrefill(k) => {
                (if decoding { *k } else { usize::MAX }, usize::MAX)
            }
            AdmissionPolicy::SloPriority(p) => (
                usize::MAX,
                if decoding { p.batch_prefill_cap } else { usize::MAX },
            ),
        };
        let slo = matches!(self.policy, AdmissionPolicy::SloPriority(_));
        let mut admits = 0usize;
        let mut batch_admits = 0usize;
        for slot in 0..self.runs[ri].batch {
            if admits >= cap {
                break;
            }
            if !matches!(self.runs[ri].slots[slot], Slot::Free) {
                continue;
            }
            let picked = if slo {
                self.pick_waiting_slo(batch_cap, &mut batch_admits)
            } else {
                self.waiting.pop_front()
            };
            let Some(seq) = picked else { break };
            let run = &mut self.runs[ri];
            out.push(Action::Admit {
                run: run.id,
                slot,
                run_batch: run.batch,
                req: self.seqs[seq].id,
                prompt: self.seqs[seq].prompt.clone(),
            });
            run.slots[slot] = Slot::Prefilling { seq };
            run.allocated = true;
            admits += 1;
            self.rows_real += 1;
            self.rows_total += 1;
        }

        // shrink: the queue drained and the live rows fit a smaller
        // compiled batch — recompose so the tail stops carrying dead rows
        let run = &self.runs[ri];
        let live = run.live();
        if self.waiting.is_empty() && run.prefilling() == 0 && live > 0 {
            let target = self.fit(live);
            if target < run.batch {
                let run = &mut self.runs[ri];
                let mut moves = Vec::with_capacity(live);
                let mut new_slots = vec![Slot::Free; target];
                let mut to = 0usize;
                for (from, s) in run.slots.iter().enumerate() {
                    if let Slot::Active { .. } = s {
                        moves.push((from, to));
                        new_slots[to] = *s;
                        to += 1;
                    }
                }
                out.push(Action::Compact {
                    run: run.id,
                    new_batch: target,
                    moves,
                });
                run.slots = new_slots;
                run.batch = target;
            }
        }

        // compose the next iteration over the live slots
        let run = &mut self.runs[ri];
        if run.live() > 0 {
            let mut pos = Vec::with_capacity(run.batch);
            let mut tokens = Vec::with_capacity(run.batch);
            let mut live_map = Vec::with_capacity(run.batch);
            for s in &run.slots {
                match s {
                    Slot::Active {
                        seq,
                        pos: p,
                        last_tok,
                    } => {
                        pos.push(*p);
                        tokens.push(*last_tok);
                        live_map.push(Some(*seq));
                    }
                    _ => {
                        pos.push(-1);
                        tokens.push(0);
                        live_map.push(None);
                    }
                }
            }
            let live = live_map.iter().flatten().count();
            out.push(Action::Step {
                run: run.id,
                iter: run.iter,
                batch: run.batch,
                pos,
                tokens,
            });
            run.step_live = Some(live_map);
            run.iter += 1;
            self.rows_real += live as u64;
            self.rows_total += run.batch as u64;
        } else if !self.open && run.prefilling() == 0 && self.waiting.is_empty() && run.allocated {
            // an open scheduler keeps the drained run's (empty) cache
            // allocation: the next arrival re-admits into it, whereas a
            // freed run can never serve again
            out.push(Action::FreeRun { run: run.id });
            self.runs[ri].freed = true;
        }
    }

    /// Pick the next admissible waiting request under SloPriority:
    /// one aged batch request first (cap-free, consumes the flag), then
    /// oldest interactive, then oldest batch while under `batch_cap`.
    fn pick_waiting_slo(&mut self, batch_cap: usize, batch_admits: &mut usize) -> Option<usize> {
        if self.batch_aged {
            if let Some(ix) = self
                .waiting
                .iter()
                .position(|&seq| self.seqs[seq].class == SloClass::Batch)
            {
                self.batch_aged = false;
                return self.waiting.remove(ix);
            }
        }
        if let Some(ix) = self
            .waiting
            .iter()
            .position(|&seq| self.seqs[seq].class == SloClass::Interactive)
        {
            return self.waiting.remove(ix);
        }
        if *batch_admits >= batch_cap {
            return None;
        }
        let seq = self.waiting.pop_front()?;
        *batch_admits += 1;
        Some(seq)
    }

    /// Fold one head token message; returns what it meant per sequence.
    pub fn on_token(&mut self, msg: &TokenMsg) -> Result<Vec<SeqEvent>> {
        let ri = self
            .runs
            .iter()
            .position(|r| r.id == msg.group)
            .ok_or_else(|| anyhow::anyhow!("token for unknown run {}", msg.group))?;
        let mut events = Vec::new();
        match msg.origin {
            TokenOrigin::Admit { slot } => {
                // a preempted prefill's stale first token: swallow it
                // (FIFO channels guarantee it precedes any later
                // admission's token for this slot)
                if let Some(n) = self.ghosts.get_mut(&(msg.group, slot)) {
                    *n -= 1;
                    if *n == 0 {
                        self.ghosts.remove(&(msg.group, slot));
                    }
                    return Ok(events);
                }
                let Slot::Prefilling { seq } = self.runs[ri].slots[slot] else {
                    bail!("admit token for run {} slot {slot} not prefilling", msg.group);
                };
                ensure!(msg.tokens.len() == 1, "admit token batch must be 1");
                let tok = msg.tokens[0];
                self.seqs[seq].generated.push(tok);
                events.push(SeqEvent::First {
                    req_id: self.seqs[seq].id,
                });
                if self.seqs[seq].generated.len() >= self.seqs[seq].max_new {
                    self.retire(ri, slot, seq, &mut events);
                } else {
                    self.runs[ri].slots[slot] = Slot::Active {
                        seq,
                        pos: self.prompt_len as i32,
                        last_tok: tok,
                    };
                }
            }
            TokenOrigin::Step => {
                let live = self.runs[ri].step_live.take().ok_or_else(|| {
                    anyhow::anyhow!("step token for run {} with no step in flight", msg.group)
                })?;
                ensure!(
                    msg.tokens.len() == live.len(),
                    "step token batch {} != composed batch {}",
                    msg.tokens.len(),
                    live.len()
                );
                let mut n_live = 0usize;
                for (slot, maybe_seq) in live.iter().enumerate() {
                    let Some(seq) = *maybe_seq else { continue };
                    n_live += 1;
                    let tok = msg.tokens[slot];
                    self.seqs[seq].generated.push(tok);
                    if self.seqs[seq].generated.len() >= self.seqs[seq].max_new {
                        self.retire(ri, slot, seq, &mut events);
                    } else {
                        let Slot::Active { pos, last_tok, .. } = &mut self.runs[ri].slots[slot]
                        else {
                            bail!("stepped slot {slot} of run {} not active", msg.group);
                        };
                        *pos += 1;
                        *last_tok = tok;
                    }
                }
                events.push(SeqEvent::StepDone {
                    run: msg.group,
                    live: n_live,
                });
            }
            TokenOrigin::Group => bail!("classic group token in continuous mode"),
        }
        Ok(events)
    }

    fn retire(&mut self, ri: usize, slot: usize, seq: usize, events: &mut Vec<SeqEvent>) {
        events.push(SeqEvent::Finished {
            req_id: self.seqs[seq].id,
            tokens: self.seqs[seq].generated.clone(),
        });
        self.outbox.push(Action::Evict {
            run: self.runs[ri].id,
            slot,
        });
        self.runs[ri].slots[slot] = Slot::Free;
    }

    /// Every live run's composition and per-row replay state — what a
    /// checkpoint records as its watermark and what failover reconstructs
    /// from.  Runs with no occupied slot (drained or never allocated) are
    /// omitted: there is nothing of theirs to rebuild.
    pub fn snapshot(&self) -> Vec<RunSnap> {
        self.runs
            .iter()
            .filter(|r| !r.freed)
            .filter_map(|r| {
                let rows: Vec<RowSnap> = r
                    .slots
                    .iter()
                    .enumerate()
                    .filter_map(|(slot, s)| {
                        let (seq, prefilling) = match s {
                            Slot::Prefilling { seq } => (*seq, true),
                            Slot::Active { seq, .. } => (*seq, false),
                            Slot::Free => return None,
                        };
                        Some(RowSnap {
                            slot,
                            req_id: self.seqs[seq].id,
                            prompt: self.seqs[seq].prompt.clone(),
                            generated: self.seqs[seq].generated.clone(),
                            prefilling,
                        })
                    })
                    .collect();
                (!rows.is_empty()).then_some(RunSnap {
                    run: r.id,
                    batch: r.batch,
                    rows,
                })
            })
            .collect()
    }

    /// Batch sizes of the runs still holding occupied slots — the cheap
    /// (no history cloning) slice of [`SlotScheduler::snapshot`] the
    /// per-token drive view needs.
    pub fn run_batches(&self) -> Vec<usize> {
        self.runs
            .iter()
            .filter(|r| !r.freed && r.slots.iter().any(|s| !matches!(s, Slot::Free)))
            .map(|r| r.batch)
            .collect()
    }

    /// Whether any admission is currently in flight.
    pub fn any_prefilling(&self) -> bool {
        self.runs.iter().any(|r| r.prefilling() > 0)
    }

    /// The pipeline was replaced under us (failover): every frame in
    /// flight died with it.  Per-row state (position, last token, served
    /// history) is untouched — it only ever advances on folds — so the
    /// next [`SlotScheduler::pump`] recomposes each run's dead step
    /// verbatim.  Admissions whose first token died are re-queued; queued
    /// retirements are dropped, because the hook rebuilt the new
    /// pipeline's caches from the *current* composition, which already
    /// excludes retired rows.
    pub fn on_failover(&mut self) {
        self.outbox.clear();
        // ghost (preempted) admit tokens died with the pipeline: a
        // surviving ghost entry would swallow a *re-sent* admission's
        // real first token
        self.ghosts.clear();
        for ri in 0..self.runs.len() {
            self.runs[ri].step_live = None;
            for slot in 0..self.runs[ri].batch {
                let Slot::Prefilling { seq } = self.runs[ri].slots[slot] else {
                    continue;
                };
                let run = &self.runs[ri];
                self.outbox.push(Action::Admit {
                    run: run.id,
                    slot,
                    run_batch: run.batch,
                    req: self.seqs[seq].id,
                    prompt: self.seqs[seq].prompt.clone(),
                });
                // the re-sent frame carries a real row again
                self.rows_real += 1;
                self.rows_total += 1;
            }
        }
    }

    /// Nothing queued, composed or in flight — though runs may still
    /// hold idle cache allocations while the scheduler is open (an idle
    /// open scheduler is waiting for arrivals, not finished).
    pub fn idle(&self) -> bool {
        self.waiting.is_empty()
            && self.outbox.is_empty()
            && self.runs.iter().all(|r| {
                r.step_live.is_none() && r.slots.iter().all(|s| matches!(s, Slot::Free))
            })
    }

    /// All sequences served, all retirements flushed, all runs freed.
    pub fn done(&self) -> bool {
        self.idle() && self.runs.iter().all(|r| r.freed || !r.allocated)
    }

    /// (real rows, total rows) carried by every frame sent so far — the
    /// padding-efficiency numerator/denominator.
    pub fn rows(&self) -> (u64, u64) {
        (self.rows_real, self.rows_total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(max_news: &[usize]) -> Vec<GenRequest> {
        max_news
            .iter()
            .enumerate()
            .map(|(i, &m)| GenRequest::new(100 + i as u64, vec![1, 2, 3], m))
            .collect()
    }

    fn tok(run: u64, iter: usize, tokens: Vec<i32>, origin: TokenOrigin) -> TokenMsg {
        TokenMsg {
            group: run,
            iter,
            tokens,
            origin,
        }
    }

    /// Drive the scheduler without an engine: every Admit/Step is
    /// answered with a synthetic token.  Returns per-request token counts.
    fn drive(sched: &mut SlotScheduler) -> std::collections::HashMap<u64, usize> {
        let mut finished = std::collections::HashMap::new();
        let mut pending: VecDeque<TokenMsg> = VecDeque::new();
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 10_000, "scheduler did not converge");
            for a in sched.pump() {
                match a {
                    Action::Admit { run, slot, .. } => {
                        pending.push_back(tok(run, 0, vec![7], TokenOrigin::Admit { slot }))
                    }
                    Action::Step {
                        run, iter, batch, ..
                    } => pending.push_back(tok(run, iter, vec![9; batch], TokenOrigin::Step)),
                    _ => {}
                }
            }
            let Some(t) = pending.pop_front() else { break };
            for ev in sched.on_token(&t).unwrap() {
                if let SeqEvent::Finished { req_id, tokens } = ev {
                    assert!(finished.insert(req_id, tokens.len()).is_none());
                }
            }
        }
        assert!(sched.done(), "scheduler not drained");
        finished
    }

    #[test]
    fn serves_every_request_to_its_own_length() {
        let rs = reqs(&[3, 1, 5, 2, 4, 1, 1, 6, 2, 3]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig::default(),
            8,
            vec![1, 4],
            &rs,
        )
        .unwrap();
        let fin = drive(&mut s);
        assert_eq!(fin.len(), rs.len());
        for r in &rs {
            assert_eq!(fin[&r.id], r.max_new_tokens, "request {}", r.id);
        }
        let (real, total) = s.rows();
        assert!(real > 0 && total >= real);
    }

    #[test]
    fn retirement_frees_slots_for_waiting_requests() {
        // capacity 2 (1 run × batch 2), 4 requests: the two short ones
        // must be admitted as soon as the first pair retires.
        let rs = reqs(&[2, 2, 1, 1]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig {
                runs: 1,
                max_batch: Some(2),
                ..ContinuousConfig::default()
            },
            4,
            vec![1, 2],
            &rs,
        )
        .unwrap();
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 4);
    }

    #[test]
    fn grows_from_a_small_initial_batch() {
        let rs = reqs(&[4, 4, 4, 4, 4]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig {
                runs: 1,
                initial_batch: Some(1),
                ..ContinuousConfig::default()
            },
            4,
            vec![1, 2, 8],
            &rs,
        )
        .unwrap();
        // first pump admits one and (queue still long) grows next pump
        let acts = s.pump();
        assert!(acts.iter().any(|a| matches!(a, Action::Admit { run_batch: 1, .. })));
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 5);
        assert!(s.runs[0].batch > 1, "never grew");
    }

    #[test]
    fn shrinks_at_the_tail() {
        let rs = reqs(&[6, 1, 1, 1]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig {
                runs: 1,
                ..ContinuousConfig::default()
            },
            4,
            vec![1, 4],
            &rs,
        )
        .unwrap();
        let mut saw_shrink = false;
        let mut pending: VecDeque<TokenMsg> = VecDeque::new();
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 1000);
            for a in s.pump() {
                match a {
                    Action::Admit { run, slot, .. } => {
                        pending.push_back(tok(run, 0, vec![7], TokenOrigin::Admit { slot }))
                    }
                    Action::Step {
                        run, iter, batch, ..
                    } => pending.push_back(tok(run, iter, vec![9; batch], TokenOrigin::Step)),
                    Action::Compact { new_batch, .. } => saw_shrink |= new_batch == 1,
                    _ => {}
                }
            }
            let Some(t) = pending.pop_front() else { break };
            s.on_token(&t).unwrap();
        }
        assert!(s.done());
        assert!(saw_shrink, "tail never compacted to batch 1");
    }

    #[test]
    fn snapshot_rederives_row_state_and_failover_requeues_prefills() {
        let rs = reqs(&[4, 4, 4]);
        let mut s =
            SlotScheduler::new(&ContinuousConfig { runs: 1, ..Default::default() }, 4, vec![1, 4], &rs)
                .unwrap();
        // first pump: three admits (+ no step yet)
        let acts = s.pump();
        let admits: Vec<_> = acts
            .iter()
            .filter(|a| matches!(a, Action::Admit { .. }))
            .cloned()
            .collect();
        assert_eq!(admits.len(), 3);
        // fold two first tokens, leave slot 2 prefilling
        s.on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot: 0 })).unwrap();
        s.on_token(&tok(RUN_ID_BASE, 0, vec![8], TokenOrigin::Admit { slot: 1 })).unwrap();
        // compose + fold one decode step over the two active rows
        let acts = s.pump();
        let Some(Action::Step { batch, .. }) =
            acts.iter().find(|a| matches!(a, Action::Step { .. }))
        else {
            panic!("no step composed: {acts:?}")
        };
        s.on_token(&tok(RUN_ID_BASE, 0, vec![9; *batch], TokenOrigin::Step)).unwrap();

        let snap = s.snapshot();
        assert_eq!(snap.len(), 1);
        let run = &snap[0];
        assert_eq!(run.run, RUN_ID_BASE);
        assert_eq!(run.rows.len(), 3);
        let row0 = run.rows.iter().find(|r| r.slot == 0).unwrap();
        assert_eq!(row0.req_id, 100);
        assert_eq!(row0.generated, vec![7, 9]);
        assert!(!row0.prefilling);
        assert_eq!(row0.prompt.len(), 4, "prompt fitted to prompt_len");
        let row2 = run.rows.iter().find(|r| r.slot == 2).unwrap();
        assert!(row2.prefilling);
        assert!(row2.generated.is_empty());

        // kill the pipeline mid-step: compose a step, then fail over
        let acts = s.pump();
        assert!(acts.iter().any(|a| matches!(a, Action::Step { .. })));
        s.on_failover();
        let acts = s.pump();
        // the dead admit is re-queued and the dead step recomposed with
        // the identical feedback tokens/positions
        let readmit = acts.iter().find(|a| matches!(a, Action::Admit { slot: 2, .. }));
        assert!(readmit.is_some(), "prefilling row not re-admitted: {acts:?}");
        let step = acts
            .iter()
            .find_map(|a| match a {
                Action::Step { pos, tokens, .. } => Some((pos.clone(), tokens.clone())),
                _ => None,
            })
            .expect("dead step not recomposed");
        // rows 0 and 1 decode at absolute position prompt_len + 1 with
        // their last folded token; slots 2/3 are dead in the map
        assert_eq!(step.0, vec![5, 5, -1, -1]);
        assert_eq!(step.1[0], 9);
        assert_eq!(step.1[1], 9);
        // answer the re-sent frames; the scheduler then drains normally
        s.on_token(&tok(RUN_ID_BASE, 0, vec![9; 4], TokenOrigin::Step)).unwrap();
        s.on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot: 2 })).unwrap();
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 3);
        assert!(fin.values().all(|&n| n == 4));
    }

    #[test]
    fn single_token_requests_retire_at_admission() {
        let rs = reqs(&[1, 1, 1]);
        let mut s =
            SlotScheduler::new(&ContinuousConfig::default(), 4, vec![1, 2], &rs).unwrap();
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 3);
        assert!(fin.values().all(|&n| n == 1));
    }

    #[test]
    fn open_scheduler_serves_arrivals_across_lulls() {
        // An open scheduler must keep its runs alive through a drained
        // queue (no FreeRun) so a later arrival can be admitted, and
        // must free them only after close().
        let mut s = SlotScheduler::new_open(
            &ContinuousConfig { runs: 1, ..ContinuousConfig::default() },
            4,
            vec![1, 2],
        )
        .unwrap();
        // drive() asserts done(), which an open scheduler never reaches:
        // answer frames by hand until it goes idle instead
        fn drive_to_idle(s: &mut SlotScheduler) -> std::collections::HashMap<u64, usize> {
            let mut finished = std::collections::HashMap::new();
            let mut pending: VecDeque<TokenMsg> = VecDeque::new();
            let mut guard = 0;
            loop {
                guard += 1;
                assert!(guard < 1000, "open scheduler did not go idle");
                for a in s.pump() {
                    match a {
                        Action::Admit { run, slot, .. } => {
                            pending.push_back(tok(run, 0, vec![7], TokenOrigin::Admit { slot }))
                        }
                        Action::Step { run, iter, batch, .. } => {
                            pending.push_back(tok(run, iter, vec![9; batch], TokenOrigin::Step))
                        }
                        _ => {}
                    }
                }
                let Some(t) = pending.pop_front() else { break };
                for ev in s.on_token(&t).unwrap() {
                    if let SeqEvent::Finished { req_id, tokens } = ev {
                        assert!(finished.insert(req_id, tokens.len()).is_none());
                    }
                }
            }
            finished
        }

        assert!(s.idle() && s.done(), "fresh open scheduler is idle");
        s.push_request(&reqs(&[2])[0]).unwrap();
        let fin = drive_to_idle(&mut s);
        assert_eq!(fin.len(), 1);
        // drained, but open: idle yes, done no (the run stays allocated)
        assert!(s.idle());
        assert!(!s.done(), "open scheduler freed its run during a lull");
        // a second wave after the lull is served by the same run
        s.push_request(&GenRequest::new(200, vec![4, 5], 3)).unwrap();
        let fin = drive_to_idle(&mut s);
        assert_eq!(fin[&200], 3);
        assert!(!s.done());
        // close(): the next pump frees the drained run and done() flips
        s.close();
        let acts = s.pump();
        assert!(acts.iter().any(|a| matches!(a, Action::FreeRun { .. })));
        assert!(s.done());
    }

    #[test]
    fn bounded_prefill_policy_caps_admissions_ahead_of_a_decode_step() {
        // 2 one-token requests retire at admission, freeing 2 slots while
        // 6 active rows keep decoding and 2 more requests wait.  FIFO
        // stacks both waiting prefills ahead of the next decode step; a
        // BoundedPrefill(1) policy admits exactly one per step gap.
        let lens = [1usize, 1, 4, 4, 4, 4, 4, 4, 4, 4];
        let mk = |policy: AdmissionPolicy| {
            let rs = reqs(&lens);
            let mut s = SlotScheduler::new(
                &ContinuousConfig { runs: 1, ..ContinuousConfig::default() },
                4,
                vec![1, 8],
                &rs,
            )
            .unwrap();
            s.set_policy(policy);
            // first pump: 8 admissions (no decode step in flight yet —
            // the bound only protects in-flight decodes)
            let acts = s.pump();
            assert_eq!(
                acts.iter().filter(|a| matches!(a, Action::Admit { .. })).count(),
                8
            );
            // slots 0 and 1 retire at admission (max_new 1); 2..8 decode
            for slot in 0..8 {
                s.on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot }))
                    .unwrap();
            }
            // next pump: 2 free slots, 2 waiting, 6 live rows
            s.pump()
        };

        let fifo = mk(AdmissionPolicy::Fifo);
        assert_eq!(
            fifo.iter().filter(|a| matches!(a, Action::Admit { .. })).count(),
            2,
            "FIFO fills every free slot: {fifo:?}"
        );
        let bounded = mk(AdmissionPolicy::BoundedPrefill(1));
        assert_eq!(
            bounded.iter().filter(|a| matches!(a, Action::Admit { .. })).count(),
            1,
            "bounded policy must admit exactly one prefill: {bounded:?}"
        );
        // the decode step still rides behind the single admission
        assert!(bounded.iter().any(|a| matches!(a, Action::Step { .. })));
        // and the bound starves nobody: the scheduler still drains fully
        let rs = reqs(&lens);
        let mut s = SlotScheduler::new(
            &ContinuousConfig { runs: 1, ..ContinuousConfig::default() },
            4,
            vec![1, 8],
            &rs,
        )
        .unwrap();
        s.set_policy(AdmissionPolicy::BoundedPrefill(1));
        let fin = drive(&mut s);
        assert_eq!(fin.len(), lens.len());
    }

    use super::super::admission::SloPolicy;

    /// Interleaved batch/interactive arrivals: one slot free per pump,
    /// SLO admission must pull every interactive request first.
    #[test]
    fn slo_priority_admits_interactive_first() {
        // ids 100 (batch), 101 (int), 102 (batch), 103 (int)
        let rs: Vec<GenRequest> = reqs(&[2, 2, 2, 2])
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                r.with_class(if i % 2 == 0 { SloClass::Batch } else { SloClass::Interactive })
            })
            .collect();
        let mut s = SlotScheduler::new(
            &ContinuousConfig { runs: 1, max_batch: Some(1), ..Default::default() },
            4,
            vec![1],
            &rs,
        )
        .unwrap();
        s.set_policy(AdmissionPolicy::SloPriority(SloPolicy::default()));
        let acts = s.pump();
        let first = acts
            .iter()
            .find_map(|a| match a {
                Action::Admit { req, .. } => Some(*req),
                _ => None,
            })
            .expect("no admission");
        assert_eq!(first, 101, "oldest interactive jumps the batch head");
        // everything still drains (batch is not starved once interactive
        // work is done)
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 4);
    }

    /// The aged-batch flag promotes exactly one batch request ahead of
    /// interactive admissions, then clears.
    #[test]
    fn slo_aged_batch_promotion_jumps_the_line_once() {
        let rs: Vec<GenRequest> = vec![
            reqs(&[2])[0].clone().with_class(SloClass::Batch),
            GenRequest::new(200, vec![1, 2], 2),
            GenRequest::new(201, vec![1, 2], 2),
        ];
        let mut s = SlotScheduler::new(
            &ContinuousConfig { runs: 1, max_batch: Some(2), ..Default::default() },
            4,
            vec![2],
            &rs,
        )
        .unwrap();
        s.set_policy(AdmissionPolicy::SloPriority(SloPolicy::default()));
        s.set_batch_aged(true);
        let acts = s.pump();
        let admitted: Vec<u64> = acts
            .iter()
            .filter_map(|a| match a {
                Action::Admit { req, .. } => Some(*req),
                _ => None,
            })
            .collect();
        // aged batch request first, then the oldest interactive
        assert_eq!(admitted, vec![100, 200]);
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 3);
    }

    /// Preempting an in-flight batch prefill evicts the slot, re-queues
    /// the request, and ghost-swallows the stale first token so a later
    /// admission into the same slot folds correctly.
    #[test]
    fn preempted_batch_prefill_requeues_and_swallows_stale_token() {
        let rs: Vec<GenRequest> = vec![
            reqs(&[3])[0].clone().with_class(SloClass::Batch),
            GenRequest::new(200, vec![4, 5], 3),
        ];
        let mut s = SlotScheduler::new(
            &ContinuousConfig { runs: 1, max_batch: Some(1), ..Default::default() },
            4,
            vec![1],
            &rs,
        )
        .unwrap();
        s.set_policy(AdmissionPolicy::SloPriority(SloPolicy::default()));
        // interactive 200 admitted first (priority), batch 100 waits;
        // serve 200 out of the way so the batch prefill goes in flight
        let acts = s.pump();
        assert!(matches!(acts[0], Action::Admit { req: 200, .. }), "{acts:?}");
        s.on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot: 0 })).unwrap();
        for _ in 0..2 {
            let acts = s.pump();
            assert!(acts.iter().any(|a| matches!(a, Action::Step { .. })), "{acts:?}");
            s.on_token(&tok(RUN_ID_BASE, 0, vec![9], TokenOrigin::Step)).unwrap();
        }
        // 200 retired; batch 100's prefill dispatches now
        let acts = s.pump();
        assert!(
            acts.iter().any(|a| matches!(a, Action::Admit { req: 100, .. })),
            "{acts:?}"
        );
        assert!(s.any_prefilling());
        // preempt it while its first token is in flight
        assert_eq!(s.preempt_batch_prefills(4), 1);
        let acts = s.pump();
        // the eviction flushes, and the request is re-admitted (nothing
        // else waits) — a second Admit for the same slot
        assert!(acts.iter().any(|a| matches!(a, Action::Evict { slot: 0, .. })), "{acts:?}");
        assert!(
            acts.iter().any(|a| matches!(a, Action::Admit { req: 100, .. })),
            "{acts:?}"
        );
        // stale first token (from the preempted admission) is swallowed
        let evs = s
            .on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot: 0 }))
            .unwrap();
        assert!(evs.is_empty(), "ghost token must fold to nothing: {evs:?}");
        // the re-sent admission's token folds normally
        let evs = s
            .on_token(&tok(RUN_ID_BASE, 0, vec![8], TokenOrigin::Admit { slot: 0 }))
            .unwrap();
        assert!(
            evs.iter().any(|e| matches!(e, SeqEvent::First { req_id: 100 })),
            "{evs:?}"
        );
        let fin = drive(&mut s);
        assert_eq!(fin[&100], 3);
    }

    /// drop_waiting removes only matching queued requests and reports
    /// their ids; admitted requests are untouched.
    #[test]
    fn drop_waiting_expires_queued_only() {
        let rs = reqs(&[2, 2, 2]);
        let mut s = SlotScheduler::new(
            &ContinuousConfig { runs: 1, max_batch: Some(1), ..Default::default() },
            4,
            vec![1],
            &rs,
        )
        .unwrap();
        let acts = s.pump();
        assert!(matches!(acts[0], Action::Admit { req: 100, .. }));
        // 100 is admitted; expire 101 but not 102
        let dropped = s.drop_waiting(|id| id == 101 || id == 100);
        assert_eq!(dropped, vec![101]);
        s.on_token(&tok(RUN_ID_BASE, 0, vec![7], TokenOrigin::Admit { slot: 0 })).unwrap();
        let fin = drive(&mut s);
        assert_eq!(fin.len(), 2, "100 and 102 served, 101 expired: {fin:?}");
        assert!(fin.contains_key(&100) && fin.contains_key(&102));
    }
}
