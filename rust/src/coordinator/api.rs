//! Request/response types of the serving path.

/// Service-level-objective class of a request: which admission queue it
/// waits in and how the scheduler trades it off under load.
///
/// Admission is class-aware end to end (see [`super::admission`]): each
/// class has its own bounded queue, `Interactive` requests are admitted
/// ahead of `Batch` ones (with anti-starvation aging so batch work is
/// never starved outright), and under saturation shedding is confined to
/// whichever class overflows its own bound.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub enum SloClass {
    /// Latency-sensitive: admitted first, may carry a TTFT deadline.
    #[default]
    Interactive,
    /// Throughput work: admitted into spare capacity, deferred or
    /// preempted when interactive queue depth rises, shed first.
    Batch,
}

impl SloClass {
    /// Stable lowercase name (wire protocol, metrics keys, reports).
    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }
}

/// One user request (already tokenized).
#[derive(Debug, Clone, PartialEq)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// SLO class ([`SloClass::Interactive`] unless the client says
    /// otherwise).
    pub class: SloClass,
    /// TTFT deadline, milliseconds from *arrival*: a request still
    /// queued this long past its arrival is dropped (answered with an
    /// expiry reject) instead of wasting a prefill it can no longer use.
    /// `None` = wait forever.
    pub deadline_ms: Option<f64>,
    /// Multi-turn conversation handle.  The replica router keeps every
    /// request of a session on the replica whose pipeline already holds
    /// the session's KV rows (affinity); `None` = free to route anywhere.
    pub session: Option<u64>,
}

impl GenRequest {
    /// An interactive request with no deadline — the default shape every
    /// pre-SLO call site used.
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            class: SloClass::Interactive,
            deadline_ms: None,
            session: None,
        }
    }

    /// Builder-style class override.
    pub fn with_class(mut self, class: SloClass) -> Self {
        self.class = class;
        self
    }

    /// Builder-style TTFT deadline (ms from arrival).
    pub fn with_deadline_ms(mut self, deadline_ms: f64) -> Self {
        self.deadline_ms = Some(deadline_ms);
        self
    }

    /// Builder-style session handle for router affinity.
    pub fn with_session(mut self, session: u64) -> Self {
        self.session = Some(session);
        self
    }
}

/// A batched group the engine executes as one unit: `batch` sequences,
/// all with the same (padded) prompt length.
#[derive(Debug, Clone)]
pub struct GroupRequest {
    pub group_id: u64,
    /// Original request ids, one per real (non-padding) sequence.
    pub request_ids: Vec<u64>,
    /// Flattened prompts, `batch × prompt_len`, padding rows replicated.
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
}

impl GroupRequest {
    /// Real (non-padding) sequences in the group.
    pub fn real(&self) -> usize {
        self.request_ids.len()
    }
}

/// Completed generation for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time-to-first-token, milliseconds, measured from when serving
    /// started (queue wait included — what a client would observe), the
    /// same baseline in every serving mode.
    pub ttft_ms: f64,
    /// Completion wall time, milliseconds, on the same drive-start
    /// baseline as `ttft_ms` (so `ttft_ms <= total_ms` always; for a
    /// request served alone this is exactly its generation time, the
    /// paper's latency metric).
    pub total_ms: f64,
}

impl GenResult {
    /// Mean milliseconds per generated token (the paper's latency metric).
    pub fn ms_per_token(&self) -> f64 {
        if self.tokens.is_empty() {
            0.0
        } else {
            self.total_ms / self.tokens.len() as f64
        }
    }
}

/// Everything a request's client can hear back: a completed generation,
/// or one of the two structured admission rejects.  Admission states:
/// `queued → admitted (Done)` / `shed` / `expired`.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeReply {
    /// The request was served to completion.
    Done(GenResult),
    /// Rejected at admission: its class queue was at its bound.  Sent
    /// the moment the bound is hit — the client sees backpressure
    /// immediately instead of silent unbounded buffering.
    Shed { id: u64, class: SloClass },
    /// Dropped from the queue: its TTFT deadline passed before a prefill
    /// was dispatched (`waited_ms` = how long it sat queued).
    Expired {
        id: u64,
        class: SloClass,
        waited_ms: f64,
    },
}

impl ServeReply {
    /// The request id this reply answers.
    pub fn id(&self) -> u64 {
        match self {
            ServeReply::Done(r) => r.id,
            ServeReply::Shed { id, .. } | ServeReply::Expired { id, .. } => *id,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ms_per_token() {
        let r = GenResult {
            id: 1,
            tokens: vec![1, 2, 3, 4],
            ttft_ms: 10.0,
            total_ms: 100.0,
        };
        assert_eq!(r.ms_per_token(), 25.0);
    }

    #[test]
    fn empty_tokens_safe() {
        let r = GenResult {
            id: 1,
            tokens: vec![],
            ttft_ms: 0.0,
            total_ms: 5.0,
        };
        assert_eq!(r.ms_per_token(), 0.0);
    }

    #[test]
    fn group_real_count() {
        let g = GroupRequest {
            group_id: 0,
            request_ids: vec![3, 4],
            tokens: vec![0; 8 * 32],
            batch: 8,
            prompt_len: 32,
            max_new_tokens: 96,
        };
        assert_eq!(g.real(), 2);
    }

    #[test]
    fn request_defaults_interactive_no_deadline() {
        let r = GenRequest::new(1, vec![1], 4);
        assert_eq!(r.class, SloClass::Interactive);
        assert_eq!(r.deadline_ms, None);
        let b = GenRequest::new(2, vec![1], 4)
            .with_class(SloClass::Batch)
            .with_deadline_ms(50.0);
        assert_eq!(b.class, SloClass::Batch);
        assert_eq!(b.deadline_ms, Some(50.0));
        assert_eq!(b.class.name(), "batch");
    }

    #[test]
    fn reply_id_covers_every_variant() {
        let done = ServeReply::Done(GenResult {
            id: 7,
            tokens: vec![],
            ttft_ms: 0.0,
            total_ms: 0.0,
        });
        assert_eq!(done.id(), 7);
        assert_eq!(
            ServeReply::Shed {
                id: 8,
                class: SloClass::Batch
            }
            .id(),
            8
        );
        assert_eq!(
            ServeReply::Expired {
                id: 9,
                class: SloClass::Interactive,
                waited_ms: 10.0
            }
            .id(),
            9
        );
    }
}
