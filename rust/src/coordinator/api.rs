//! Request/response types of the serving path.

/// One user request (already tokenized).
#[derive(Debug, Clone, PartialEq)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A batched group the engine executes as one unit: `batch` sequences,
/// all with the same (padded) prompt length.
#[derive(Debug, Clone)]
pub struct GroupRequest {
    pub group_id: u64,
    /// Original request ids, one per real (non-padding) sequence.
    pub request_ids: Vec<u64>,
    /// Flattened prompts, `batch × prompt_len`, padding rows replicated.
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
}

impl GroupRequest {
    /// Real (non-padding) sequences in the group.
    pub fn real(&self) -> usize {
        self.request_ids.len()
    }
}

/// Completed generation for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time-to-first-token, milliseconds, measured from when serving
    /// started (queue wait included — what a client would observe), the
    /// same baseline in every serving mode.
    pub ttft_ms: f64,
    /// Completion wall time, milliseconds, on the same drive-start
    /// baseline as `ttft_ms` (so `ttft_ms <= total_ms` always; for a
    /// request served alone this is exactly its generation time, the
    /// paper's latency metric).
    pub total_ms: f64,
}

impl GenResult {
    /// Mean milliseconds per generated token (the paper's latency metric).
    pub fn ms_per_token(&self) -> f64 {
        if self.tokens.is_empty() {
            0.0
        } else {
            self.total_ms / self.tokens.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ms_per_token() {
        let r = GenResult {
            id: 1,
            tokens: vec![1, 2, 3, 4],
            ttft_ms: 10.0,
            total_ms: 100.0,
        };
        assert_eq!(r.ms_per_token(), 25.0);
    }

    #[test]
    fn empty_tokens_safe() {
        let r = GenResult {
            id: 1,
            tokens: vec![],
            ttft_ms: 0.0,
            total_ms: 5.0,
        };
        assert_eq!(r.ms_per_token(), 0.0);
    }

    #[test]
    fn group_real_count() {
        let g = GroupRequest {
            group_id: 0,
            request_ids: vec![3, 4],
            tokens: vec![0; 8 * 32],
            batch: 8,
            prompt_len: 32,
            max_new_tokens: 96,
        };
        assert_eq!(g.real(), 2);
    }
}
