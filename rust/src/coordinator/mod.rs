//! Layer-3 coordinator: collaborative inference over the planned shards
//! (paper §III "Collaborative inference").
//!
//! * [`api`] — request/response types shared by engine, batcher, server.
//! * [`admission`] — how requests *enter*: [`admission::RequestSource`]s
//!   (closed-loop queue, Poisson trace replay, live TCP channel) behind
//!   an [`admission::AdmissionQueue`] with a pluggable admission policy
//!   (FIFO / bounded prefill interleaving / SLO-class priority with
//!   bounded per-class queues and shedding).  Arrival timestamps flow
//!   into the stats, so TTFT decomposes into queue delay + prefill.
//! * [`kvcache`] — per-stage KV-cache pool with byte accounting (the
//!   paper pre-allocates KV space on each participating device).
//! * [`stage`] — one device actor: runs its layer range through the PJRT
//!   [`crate::runtime::ExecService`], keeps its shard's KV caches, and
//!   forwards activations over shaped links.
//! * [`engine`] — wires stage actors according to a [`crate::planner::Plan`]
//!   and exposes generation: **sequential** inference (one request at a
//!   time, §III Fig. 4a), **pipelined** inference with the Bubble /
//!   No-bubble strategies (§IV-B, Fig. 5), and **continuous batching**.
//! * [`driver`] — the one generation drive loop every mode (and the
//!   adaptive engine, via [`driver::DriveHooks`]) runs through.
//! * [`scheduler`] — the iteration-level slot scheduler behind
//!   [`engine::Engine::generate_continuous`]: per-iteration admission,
//!   per-row retirement, batch recomposition.
//! * [`batcher`] — groups incoming requests into the compiled batch sizes.
//! * [`router`] — the front door over K pipeline replicas: least-work /
//!   session-affinity routing, per-replica admission queues, and
//!   cross-replica failover (a dead replica's queued + in-flight
//!   requests re-enter routing).
//! * [`server`] — a JSON-lines TCP front-end over the engine.
//!
//! Stages report per-message compute timings and links report per-frame
//! transfer timings when wired with [`engine::ObsSinks`]; together with
//! [`stage::StageMsg::Export`] (KV snapshot for migration) these are the
//! hooks the [`crate::adaptive`] runtime drives live replanning through.

pub mod admission;
pub mod api;
pub mod batcher;
pub mod driver;
pub mod engine;
pub mod kvcache;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod stage;

pub use admission::{
    AdmissionPolicy, AdmissionQueue, ArrivedRequest, LiveSource, QueueSource, RequestSource,
    SloPolicy, TraceSource,
};
pub use api::{GenRequest, GenResult, GroupRequest, ServeReply, SloClass};
pub use batcher::Batcher;
pub use driver::{
    DriveHooks, DriveStats, DriveView, DriverCfg, GroupProgress, NoHooks, StallGroup, StallView,
};
pub use engine::{Engine, EngineConfig, EngineStats};
pub use kvcache::{GroupCache, KvLayout, KvPool, PagedPool, ELEM_BYTES_F32};
pub use router::{
    drive_replicated, ReplicaOutcome, ReplicatedOutcome, Router, RouterConfig, RouterSource,
};
pub use scheduler::{ContinuousConfig, PreemptMode, RowSnap, RunSnap, SlotScheduler};
pub use stage::{KvEntry, StageExport, WireFormat};
