//! The unified generation driver.
//!
//! Exactly one loop owns the autoregressive feedback cycle — receive a
//! head token frame, fold stats, release the next iteration — for every
//! serving mode:
//!
//! * **Group serving** ([`drive_groups`]): the classic sequential /
//!   pipelined paths.  [`crate::coordinator::Engine`] drives it with
//!   [`NoHooks`]; the adaptive engine drives the *same* loop with hooks
//!   that interpose its replan control loop and migration barrier — so a
//!   stats fix or admission change lands in both engines by construction
//!   (previously `Engine::run` and `AdaptiveEngine::run` were duplicated).
//! * **Continuous batching** ([`drive_slots`]): iteration-level
//!   scheduling via the [`super::scheduler::SlotScheduler`] — admissions,
//!   per-iteration slot maps, per-row retirement.
//!
//! ## Barriers
//!
//! Hooks request a **drain barrier** by returning `true` from
//! [`DriveHooks::after_token`]: the driver stops releasing decode
//! iterations (holding them in a queue), waits until every unfinished
//! group has no iteration in flight, then calls
//! [`DriveHooks::at_barrier`] — which may tear down and replace the wired
//! pipeline (KV migration) — and finally releases the held iterations and
//! re-primes the admission window.  The Bubble strategy's per-iteration
//! barrier is the degenerate in-loop case of the same machinery.
//!
//! ## Stalls and failover
//!
//! A barrier assumes in-flight iterations can land; a **dead stage host**
//! breaks that assumption.  When a hook opts in via
//! [`DriveHooks::stall_poll_real_ms`], the driver polls the token channel
//! with a timeout and reports silence through [`DriveHooks::on_stall`]
//! with a [`StallView`]: each unfinished group's request + folded token
//! history in group mode, each live run's per-row [`RunSnap`] in slot
//! mode.  A hook that answers `true` has *replaced* the pipeline —
//! detected the loss, replanned onto survivors, recovered KV (see
//! [`crate::adaptive::engine`]) — and the driver re-derives the next live
//! work from served history: in group mode the next iteration of every
//! unfinished group (a group without a first token is re-prefilled), in
//! slot mode the scheduler recomposes every dead step and re-queues
//! in-flight admissions ([`SlotScheduler::on_failover`]).  Barrier state
//! is dropped, and everything the old pipeline still owed is discarded:
//! its late tokens can never fold, which is what keeps a false-positive
//! failover merely wasteful instead of incorrect.
//!
//! Even with hooks disabled (or a hook that never recovers) a dead stage
//! must not wedge the server: both loops give up with an error once the
//! pipeline has been silent for a generous dead-man interval
//! ([`DEAD_PIPELINE_REAL_MS`]) — a hook recovery resets the clock.
//!
//! ## Stats
//!
//! TTFT is recorded per group/request on its first token, measured from
//! the request's **arrival** (drive start for the closed-loop sources,
//! where every request arrives at t = 0) — client-observed, queue wait
//! included.  In slot mode the queue wait is also recorded separately
//! ([`DriveStats::queue_delay`]: arrival → batch-1 prefill dispatch), so
//! TTFT decomposes into queue delay + prefill.  The first token's
//! latency is *not* recorded into `iter_latency` (it includes prefill —
//! mixing it in polluted the decode-step histogram).
//! `padding_efficiency` = real rows / total rows carried by every frame:
//! 1.0 means no compute or KV was spent on padding or dead slots.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use super::admission::{AdmissionEvent, AdmissionPolicy, AdmissionQueue};
use super::api::{GenResult, GroupRequest, ServeReply, SloClass};
use super::engine::Wired;
use super::scheduler::{Action, ContinuousConfig, RunSnap, SeqEvent, SlotScheduler};
use super::stage::{Payload, Phase, PrefillChunk, StageMsg, TokenMsg, TokenOrigin};
use crate::metrics::Histogram;
use crate::obs::{LifeKind, ReqPhase};
use crate::pipeline::Strategy;

/// Dead-man interval, real ms: once the pipeline has delivered nothing
/// for this long — across every stall-poll tick and hook consultation —
/// the drive errors out instead of waiting forever.  Sized orders of
/// magnitude above any legitimate iteration (including a failover
/// recovery, which resets the clock); a hook that wants to keep waiting
/// longer should recover or abort explicitly instead.
pub const DEAD_PIPELINE_REAL_MS: f64 = 60_000.0;

/// Upper bound (real ms) on one idle wait for arrivals.  Trace replays
/// sleep exactly to their next arrival (clamped here); a live source
/// *blocks* on its channel for up to this long and wakes the instant a
/// request lands — so the bound never adds latency, it only caps how
/// long the drive goes between source-closure checks.
const IDLE_WAIT_REAL_MS: f64 = 250.0;

/// Paged KV layout parameters, as admission control sees them
/// ([`DriverCfg::paged`]): block-granular occupancy replaces the padded
/// worst-case row bound.
#[derive(Debug, Clone, Copy)]
pub struct PagedCfg {
    /// Positions per block.
    pub block_size: usize,
    /// Blocks the tightest stage's pool holds under the KV budget —
    /// what the scheduler admits against.
    pub pool_blocks: usize,
}

/// Compiled-shape contract the driver validates admissions against.
#[derive(Debug, Clone)]
pub struct DriverCfg {
    pub prompt_len: usize,
    /// Chunked prefill: dispatch each prefill as successive partial
    /// frames of at most this many tokens, overlapping stage compute
    /// with transfer (`0` = monolithic).  Token streams are identical
    /// either way — the head answers on the final chunk.
    pub prefill_chunk: usize,
    pub batch_sizes: Vec<usize>,
    /// Longest absolute position the compiled caches hold.
    pub max_seq: usize,
    /// Per-stage KV budget, bytes.
    pub kv_budget_bytes: u64,
    /// Paged KV layout (None = padded): continuous-batching admission
    /// gates on live block occupancy instead of worst-case rows, and
    /// pool exhaustion preempts via swap-out/recompute instead of
    /// refusing up front.
    pub paged: Option<PagedCfg>,
    /// Padded KV bytes one sequence row costs on the *heaviest* stage —
    /// continuous-batching admission control budgets against this (0 =
    /// unknown, check skipped).
    pub row_bytes_worst: u64,
    /// Tracer for request/group lifecycle spans, decode-step spans and
    /// queue-depth counters.  Defaults to [`crate::obs::Tracer::off`]: the
    /// disabled path costs one relaxed atomic increment per would-be
    /// event (asserted by the CI overhead gate).
    pub trace: crate::obs::Tracer,
    /// Live serving metrics (tokens/s, TTFT, queue depth, …).  Defaults
    /// to [`crate::obs::MetricsRegistry::off`]: a single branch per call.
    pub metrics: crate::obs::MetricsRegistry,
}

/// Aggregate statistics of one drive, embedded into
/// [`super::engine::EngineStats`] / `AdaptiveStats`.
#[derive(Debug)]
pub struct DriveStats {
    pub makespan_ms: f64,
    /// Real (non-padding) tokens generated.
    pub tokens: u64,
    pub throughput_tps: f64,
    pub ttft: Histogram,
    /// Decode-step latency (first tokens excluded — they are TTFT).
    pub iter_latency: Histogram,
    /// Admission-queue wait, one sample per request: arrival → batch-1
    /// prefill dispatch (slot mode; first dispatch only, so failover
    /// re-admits don't re-record).  Together with the prefill time this
    /// decomposes TTFT: `ttft ≈ queue_delay + prefill`.  Empty in group
    /// mode (groups are packed before the drive starts).
    pub queue_delay: Histogram,
    /// Real rows / total rows over every work frame sent.
    pub padding_efficiency: f64,
    /// Arrivals shed at their class bound (slot mode, SLO policy only):
    /// `[interactive, batch]`.
    pub shed: [u64; 2],
    /// Queued requests dropped at their TTFT deadline before a prefill
    /// was dispatched: `[interactive, batch]`.
    pub expired: [u64; 2],
    /// Highest arrived-not-yet-dispatched queue depth observed — under a
    /// bounded SLO policy this can never exceed the sum of the class
    /// bounds (the bench gates on it).
    pub peak_queue_depth: usize,
    /// Highest number of sequences simultaneously holding KV rows (slot
    /// mode) — the concurrency the KV budget actually supported, which
    /// is the paged layout's headline win over padded admission.
    pub peak_live_rows: usize,
}

/// Progress of one still-unfinished group, as the hooks see it.
#[derive(Debug, Clone)]
pub struct GroupProgress {
    pub group_id: u64,
    pub batch: usize,
    /// Highest iteration dispatched into the pipeline (prefill = 0) —
    /// every KV write up to this iteration precedes anything the hook
    /// sends next, which is exactly what a checkpoint snapshot covers.
    pub sent: usize,
    /// Token frames folded so far (= the next iteration to dispatch).
    pub folded: usize,
}

/// What the hooks may inspect after each folded token frame.
#[derive(Debug)]
pub struct DriveView {
    pub received: u64,
    /// Batch sizes of the groups still generating (run batches in slot
    /// mode).
    pub unfinished_batches: Vec<usize>,
    /// Whether every active group got its first token (prefill settled).
    /// Slot mode: no admission is currently in flight.
    pub all_prefilled: bool,
    /// Per-group progress of the groups still generating (group mode
    /// only).
    pub groups: Vec<GroupProgress>,
    /// Per-run composition + per-row served history (slot mode only) —
    /// what a checkpoint records as its restore watermark.
    pub runs: Vec<RunSnap>,
    /// Decode iterations still owed to the furthest-from-done admitted
    /// (or queued) request — the conservative horizon replan
    /// cost-awareness amortizes a migration pause over.
    pub remaining_iters: u64,
}

/// One still-unfinished group at a pipeline stall: the request plus its
/// folded token history — everything a failover needs to re-prefill or
/// replay the group on a rebuilt pipeline.
#[derive(Debug)]
pub struct StallGroup<'a> {
    pub req: &'a GroupRequest,
    /// Folded tokens, `[row][iter]` (every row has `folded` entries).
    pub rows: &'a [Vec<i32>],
}

/// What the hooks see when the pipeline has delivered nothing for a full
/// stall-poll tick.  Exactly one of `groups` / `runs` is populated:
/// groups in group mode ([`drive_groups`]), per-row run snapshots in
/// slot mode ([`drive_slots`]).
#[derive(Debug)]
pub struct StallView<'a> {
    pub received: u64,
    /// Real ms since the last delivered token (or drive start).
    pub stalled_real_ms: f64,
    pub groups: Vec<StallGroup<'a>>,
    /// Slot mode: each live run's composition and served history —
    /// everything a failover needs to rebuild, re-admit and replay rows
    /// on a new pipeline.
    pub runs: Vec<RunSnap>,
}

/// Interposition points for adaptive serving.  The default impls are
/// no-ops: plain static serving.
pub trait DriveHooks {
    /// Whether this hook wants per-token callbacks at all.  Defaults to
    /// `true`; [`NoHooks`] opts out so plain serving skips building the
    /// per-token [`DriveView`].
    fn enabled(&self) -> bool {
        true
    }

    /// Cheap per-token pre-gate, called (with the running token count)
    /// before the driver pays for a [`DriveView`].  Return `false` to
    /// skip [`DriveHooks::after_token`] this token — e.g. the adaptive
    /// control loop only evaluates every `check_every` tokens.
    fn wants_view(&mut self, received: u64) -> bool {
        let _ = received;
        true
    }

    /// Called after a folded token frame that passed
    /// [`DriveHooks::wants_view`].  `wired` is shared (not `&mut`): a
    /// hook may *send* through the pipeline here — e.g. a periodic
    /// [`crate::coordinator::stage::StageMsg::Export`] checkpoint probe —
    /// but may only replace it at [`DriveHooks::at_barrier`] /
    /// [`DriveHooks::on_stall`].  Return `true` to request a drain
    /// barrier before any further decode iteration is released.
    fn after_token(&mut self, wired: &Wired, view: &DriveView) -> Result<bool> {
        let _ = (wired, view);
        Ok(false)
    }

    /// Called once the requested barrier is reached (no unfinished group
    /// has an iteration in flight).  May replace `wired` wholesale — the
    /// driver continues on whatever pipeline this leaves behind.
    fn at_barrier(&mut self, wired: &mut Wired) -> Result<()> {
        let _ = wired;
        Ok(())
    }

    /// Whether this token's [`DriveView`] must include the full per-run
    /// snapshot (slot mode only).  Deep-copying every row's prompt and
    /// served history is the expensive part of a view, and only a
    /// checkpoint start consumes it — the adaptive hook answers `true`
    /// exactly on its checkpoint cadence.  Defaults to `true` so hooks
    /// that don't implement the gate still see full views.
    fn wants_run_snapshot(&self, received: u64) -> bool {
        let _ = received;
        true
    }

    /// How long (real ms) the driver may block on the token channel
    /// before reporting a stall via [`DriveHooks::on_stall`].  `None`
    /// (the default) keeps the plain blocking receive — no stall
    /// detection, no failover.
    fn stall_poll_real_ms(&self) -> Option<f64> {
        None
    }

    /// Called each time no token has arrived within the stall-poll tick.
    /// Return `Ok(false)` to keep waiting.  Return `Ok(true)` to signal
    /// the hook **replaced the pipeline** (failover): any KV recovery and
    /// history replay must already have happened on the new `wired` —
    /// the driver then re-derives the dead in-flight work (group mode:
    /// the next live iteration, or the prefill, of every unfinished
    /// group; slot mode: the scheduler re-queues dead admissions and
    /// recomposes dead steps), abandons all barrier state, and resumes
    /// folding.  An `Err` aborts generation.
    fn on_stall(&mut self, wired: &mut Wired, view: &StallView<'_>) -> Result<bool> {
        let _ = (wired, view);
        Ok(false)
    }
}

/// Plain static serving: no control loop, no barriers.
pub struct NoHooks;
impl DriveHooks for NoHooks {
    fn enabled(&self) -> bool {
        false
    }
}

/// Dispatch a group prefill: one monolithic frame (`prefill_chunk == 0`)
/// or a stream of chunk frames released back-to-back, so stage *i+1*
/// computes chunk *k* while stage *i* computes chunk *k+1*.  The head
/// answers once, on the final chunk, either way.
pub(crate) fn send_prefill(wired: &Wired, prefill_chunk: usize, g: &GroupRequest) -> Result<()> {
    let p = g.prompt_len;
    for span in PrefillChunk::spans(p, prefill_chunk) {
        let tokens = match span {
            None => g.tokens.clone(),
            Some(c) => {
                // row-major [batch, prompt] → the chunk's columns of
                // every row
                let mut t = Vec::with_capacity(g.batch * c.len);
                for b in 0..g.batch {
                    t.extend_from_slice(&g.tokens[b * p + c.start..b * p + c.start + c.len]);
                }
                t
            }
        };
        let msg = StageMsg::Work {
            group: g.group_id,
            iter: 0,
            pos: 0,
            phase: Phase::Prefill,
            batch: g.batch,
            prompt_len: p,
            chunk: span,
            payload: Payload::Tokens(tokens),
        };
        let bytes = msg.wire_bytes();
        wired.to_first.send(msg, bytes)?;
    }
    Ok(())
}

/// Replay-compressed re-prefill: extend each row's prompt with its first
/// `extra` served tokens (from `rows`) and prefill the whole span in one
/// pass — chunked per `prefill_chunk` like any other prefill.  KV lands
/// for positions `0..prompt_len+extra-1` and the head's single reply
/// re-derives served token index `extra` per row, replacing `extra`
/// per-[`Phase::Decode`] replay frames with one pipelined prefill.
pub(crate) fn send_prefill_ext(
    wired: &Wired,
    prefill_chunk: usize,
    g: &GroupRequest,
    rows: &[Vec<i32>],
    extra: usize,
) -> Result<()> {
    let p0 = g.prompt_len;
    let p = p0 + extra;
    let mut all = Vec::with_capacity(g.batch * p);
    for b in 0..g.batch {
        all.extend_from_slice(&g.tokens[b * p0..(b + 1) * p0]);
        if extra > 0 {
            all.extend_from_slice(&rows[b][..extra]);
        }
    }
    for span in PrefillChunk::spans(p, prefill_chunk) {
        let tokens = match span {
            None => all.clone(),
            Some(c) => {
                let mut t = Vec::with_capacity(g.batch * c.len);
                for b in 0..g.batch {
                    t.extend_from_slice(&all[b * p + c.start..b * p + c.start + c.len]);
                }
                t
            }
        };
        let msg = StageMsg::Work {
            group: g.group_id,
            iter: 0,
            pos: 0,
            phase: Phase::Prefill,
            batch: g.batch,
            prompt_len: p,
            chunk: span,
            payload: Payload::Tokens(tokens),
        };
        let bytes = msg.wire_bytes();
        wired.to_first.send(msg, bytes)?;
    }
    Ok(())
}

pub(crate) fn send_decode(
    wired: &Wired,
    g: &GroupRequest,
    iter: usize,
    tokens: Vec<i32>,
) -> Result<()> {
    let pos = (g.prompt_len + iter - 1) as i32;
    let msg = StageMsg::Work {
        group: g.group_id,
        iter,
        pos,
        phase: Phase::Decode,
        batch: g.batch,
        prompt_len: g.prompt_len,
        chunk: None,
        payload: Payload::Tokens(tokens),
    };
    let bytes = msg.wire_bytes();
    wired.to_first.send(msg, bytes)
}

fn send_control(wired: &Wired, msg: StageMsg) -> Result<()> {
    let bytes = msg.wire_bytes();
    wired.to_first.send(msg, bytes)
}

/// Outcome of one token-channel receive attempt ([`poll_token`]).
enum Polled {
    /// A head token frame arrived.
    Token(TokenMsg),
    /// A stall-poll tick elapsed with nothing delivered; the hook was
    /// consulted — `recovered` means it replaced the pipeline and the
    /// caller must re-derive the dead in-flight work.
    Stalled { recovered: bool },
}

/// One receive attempt, shared by both drive loops.  With no stall hook
/// active, blocks up to the dead-man interval and errors on silence — a
/// dead stage must surface as an error, never a hang.  With a stall
/// hook, blocks one poll tick; on a silent tick it builds a
/// [`StallView`] from `make_view` (the caller populates the group or
/// run side), consults [`DriveHooks::on_stall`], and enforces the
/// dead-man backstop when the hook keeps declining to recover.
fn poll_token<'v>(
    wired: &mut Wired,
    stall_poll: Option<f64>,
    dead_man_real_ms: f64,
    last_progress: &Instant,
    received: u64,
    hooks: &mut dyn DriveHooks,
    make_view: impl FnOnce() -> (Vec<StallGroup<'v>>, Vec<RunSnap>),
) -> Result<Polled> {
    let tick_ms = match stall_poll {
        None => {
            return match wired
                .token_rx
                .recv_timeout(Duration::from_secs_f64(dead_man_real_ms / 1e3))
            {
                Ok(t) => Ok(Polled::Token(t)),
                Err(RecvTimeoutError::Disconnected) => {
                    Err(anyhow!("pipeline closed unexpectedly"))
                }
                Err(RecvTimeoutError::Timeout) => Err(anyhow!(
                    "pipeline delivered nothing for {dead_man_real_ms:.0} real ms \
                     (stage host dead?) and no stall/failover hook is active"
                )),
            }
        }
        Some(t) => t,
    };
    match wired
        .token_rx
        .recv_timeout(Duration::from_secs_f64(tick_ms.max(1.0) / 1e3))
    {
        Ok(t) => Ok(Polled::Token(t)),
        Err(RecvTimeoutError::Disconnected) => Err(anyhow!("pipeline closed unexpectedly")),
        Err(RecvTimeoutError::Timeout) => {
            let stalled_real_ms = last_progress.elapsed().as_secs_f64() * 1e3;
            let recovered = {
                let (groups, runs) = make_view();
                let view = StallView {
                    received,
                    stalled_real_ms,
                    groups,
                    runs,
                };
                hooks.on_stall(wired, &view)?
            };
            anyhow::ensure!(
                recovered || stalled_real_ms < dead_man_real_ms,
                "pipeline silent for {stalled_real_ms:.0} real ms and the stall hook \
                 never recovered it"
            );
            Ok(Polled::Stalled { recovered })
        }
    }
}

/// Drive a set of pre-packed groups to completion: `window` groups in
/// flight, Bubble / No-bubble release policy, hooks for the adaptive
/// control loop.  See the module docs.
pub fn drive_groups(
    wired: &mut Wired,
    cfg: &DriverCfg,
    groups: &[GroupRequest],
    window: usize,
    strategy: Strategy,
    hooks: &mut dyn DriveHooks,
) -> Result<(Vec<GenResult>, DriveStats)> {
    struct Active<'a> {
        req: &'a GroupRequest,
        rows: Vec<Vec<i32>>,
        ttft_ms: Option<f64>,
        last_iter_at: Instant,
        done: bool,
        in_flight: bool,
        /// Highest iteration dispatched (prefill = 0).
        sent: usize,
    }
    impl Active<'_> {
        /// Token frames folded so far (= the next iteration to dispatch).
        fn folded(&self) -> usize {
            self.rows.first().map(|r| r.len()).unwrap_or(0)
        }
    }
    fn admit<'a>(trace: &crate::obs::Tracer, g: &'a GroupRequest) -> Active<'a> {
        // lifecycle spans open here, on first admission only — a failover
        // re-prefill re-sends work for an already-open group
        trace.begin(LifeKind::Group, g.group_id, ReqPhase::Whole);
        trace.begin(LifeKind::Group, g.group_id, ReqPhase::Prefill);
        Active {
            req: g,
            rows: vec![Vec::new(); g.batch],
            ttft_ms: None,
            last_iter_at: Instant::now(),
            done: false,
            in_flight: true,
            sent: 0,
        }
    }

    // Same admission contract for every caller — reject up front rather
    // than letting a stage thread die on a missing compiled variant.
    for g in groups {
        anyhow::ensure!(
            cfg.batch_sizes.contains(&g.batch),
            "batch {} not compiled (have {:?})",
            g.batch,
            cfg.batch_sizes
        );
        anyhow::ensure!(
            g.prompt_len == cfg.prompt_len,
            "prompt len {} != compiled {}",
            g.prompt_len,
            cfg.prompt_len
        );
    }

    let t0 = Instant::now();
    let mut ttft = Histogram::new();
    let mut iter_lat = Histogram::new();
    let mut results = Vec::new();
    let mut active: HashMap<u64, Active> = HashMap::new();
    // admission cursor into `groups` (an index, not an iterator, so the
    // hook view can still see what is queued but not yet admitted)
    let mut next_group = 0usize;
    let mut in_flight_groups = 0usize;
    let mut received = 0u64;
    let mut real_tokens = 0u64;
    let mut rows_real = 0u64;
    let mut rows_total = 0u64;
    // iterations held back: by the Bubble strategy (per-iteration sync)
    let mut bubble_barrier: Vec<(u64, usize, Vec<i32>)> = Vec::new();
    // …or by a hook-requested drain barrier (e.g. pending migration)
    let mut pending_barrier = false;
    let mut held: Vec<(u64, usize, Vec<i32>)> = Vec::new();

    // prime the window
    while in_flight_groups < window && next_group < groups.len() {
        let g = &groups[next_group];
        next_group += 1;
        send_prefill(wired, cfg.prefill_chunk, g)?;
        rows_real += g.real() as u64;
        rows_total += g.batch as u64;
        active.insert(g.group_id, admit(&cfg.trace, g));
        in_flight_groups += 1;
    }

    // stall detection: real time since the last delivered token
    let mut last_progress = Instant::now();
    let stall_poll = if hooks.enabled() {
        hooks.stall_poll_real_ms()
    } else {
        None
    };

    while in_flight_groups > 0 {
        let polled = poll_token(
            wired,
            stall_poll,
            DEAD_PIPELINE_REAL_MS,
            &last_progress,
            received,
            hooks,
            || {
                (
                    active
                        .values()
                        .filter(|a| !a.done)
                        .map(|a| StallGroup {
                            req: a.req,
                            rows: &a.rows,
                        })
                        .collect(),
                    Vec::new(),
                )
            },
        )?;
        let tok = match polled {
            Polled::Token(t) => t,
            Polled::Stalled { recovered } => {
                if recovered {
                    // Failover: the hook rebuilt the pipeline and already
                    // replayed every *folded* iteration's KV.  Whatever
                    // was in flight or held died with the old pipeline —
                    // re-derive the next live iteration of every
                    // unfinished group from its token history and resume.
                    pending_barrier = false;
                    held.clear();
                    bubble_barrier.clear();
                    for a in active.values_mut().filter(|a| !a.done) {
                        let folded = a.folded();
                        if folded == 0 {
                            send_prefill(wired, cfg.prefill_chunk, a.req)?;
                            a.sent = 0;
                        } else {
                            let toks: Vec<i32> =
                                a.rows.iter().map(|r| r[folded - 1]).collect();
                            send_decode(wired, a.req, folded, toks)?;
                            a.sent = folded;
                        }
                        rows_real += a.req.real() as u64;
                        rows_total += a.req.batch as u64;
                        a.in_flight = true;
                    }
                    last_progress = Instant::now();
                }
                continue;
            }
        };
        anyhow::ensure!(
            tok.origin == TokenOrigin::Group,
            "continuous-batching token in group mode"
        );
        received += 1;
        let a = active
            .get_mut(&tok.group)
            .with_context(|| format!("unknown group {}", tok.group))?;
        a.in_flight = false;
        let now = Instant::now();
        if a.ttft_ms.is_none() {
            // client-observed TTFT: measured from drive start (queue wait
            // included), recorded once per real request so the histogram
            // weights clients equally across serving modes
            let ms = now.duration_since(t0).as_secs_f64() * 1e3;
            a.ttft_ms = Some(ms);
            cfg.trace.end(LifeKind::Group, tok.group, ReqPhase::Prefill);
            cfg.trace.begin(LifeKind::Group, tok.group, ReqPhase::Decode);
            for _ in 0..a.req.real() {
                ttft.record(ms);
                cfg.metrics.observe("ttft_ms", ms);
            }
        } else {
            // the first token's latency IS the TTFT (prefill included) —
            // only subsequent gaps are decode-step latency
            let gap = now.duration_since(a.last_iter_at).as_secs_f64() * 1e3;
            iter_lat.record(gap);
            cfg.metrics.observe("iter_ms", gap);
            cfg.trace.step(tok.group as usize, a.req.batch, gap);
        }
        a.last_iter_at = now;
        for (row, &t) in a.rows.iter_mut().zip(&tok.tokens) {
            row.push(t);
        }
        real_tokens += a.req.real() as u64;
        cfg.metrics.add_tokens(a.req.real() as u64);
        let next_iter = tok.iter + 1;
        if next_iter < a.req.max_new_tokens {
            if pending_barrier {
                held.push((tok.group, next_iter, tok.tokens));
            } else {
                match strategy {
                    Strategy::Bubble => bubble_barrier.push((tok.group, next_iter, tok.tokens)),
                    _ => {
                        send_decode(wired, a.req, next_iter, tok.tokens)?;
                        rows_real += a.req.real() as u64;
                        rows_total += a.req.batch as u64;
                        a.in_flight = true;
                        a.sent = next_iter;
                    }
                }
            }
        } else {
            // group complete — completion time shares the drive-start
            // baseline with ttft_ms (and with drive_slots), so the two
            // are ordered and comparable across serving modes
            a.done = true;
            cfg.trace.end(LifeKind::Group, tok.group, ReqPhase::Decode);
            cfg.trace.end(LifeKind::Group, tok.group, ReqPhase::Whole);
            cfg.metrics.inc("requests_completed", a.req.real() as u64);
            let total = now.duration_since(t0).as_secs_f64() * 1e3;
            // the group's first fold recorded its TTFT; a missing entry
            // is a folding bug and must not masquerade as a 0 ms TTFT
            let group_ttft = a
                .ttft_ms
                .with_context(|| format!("group {} finished without a recorded TTFT", tok.group))?;
            for (i, &rid) in a.req.request_ids.iter().enumerate() {
                results.push(GenResult {
                    id: rid,
                    tokens: a.rows[i].clone(),
                    ttft_ms: group_ttft,
                    total_ms: total,
                });
            }
            send_control(wired, StageMsg::Free { group: tok.group })?;
            in_flight_groups -= 1;
            // admit the next queued group (deferred while a barrier is
            // pending: the window re-primes after the barrier)
            if !pending_barrier {
                if let Some(g) = groups.get(next_group) {
                    next_group += 1;
                    send_prefill(wired, cfg.prefill_chunk, g)?;
                    rows_real += g.real() as u64;
                    rows_total += g.batch as u64;
                    active.insert(g.group_id, admit(&cfg.trace, g));
                    in_flight_groups += 1;
                }
            }
        }

        // Bubble barrier: release the next iteration only when every
        // unfinished group has delivered the current one.
        if strategy == Strategy::Bubble && !pending_barrier {
            let waiting = active.values().filter(|a| !a.done).count();
            if bubble_barrier.len() == waiting && !bubble_barrier.is_empty() {
                for (gid, it, toks) in bubble_barrier.drain(..) {
                    let a = active.get_mut(&gid).expect("barrier group vanished");
                    send_decode(wired, a.req, it, toks)?;
                    rows_real += a.req.real() as u64;
                    rows_total += a.req.batch as u64;
                    a.in_flight = true;
                    a.sent = it;
                }
            }
        }

        // hooks: the adaptive control loop rides here (skipped entirely
        // for plain serving, and gated by the cheap counter check before
        // the view — which costs an allocation — is built)
        if hooks.enabled() && hooks.wants_view(received) {
            let view = DriveView {
                received,
                unfinished_batches: active
                    .values()
                    .filter(|x| !x.done)
                    .map(|x| x.req.batch)
                    .collect(),
                all_prefilled: active.values().all(|x| x.done || x.ttft_ms.is_some()),
                groups: active
                    .values()
                    .filter(|x| !x.done)
                    .map(|x| GroupProgress {
                        group_id: x.req.group_id,
                        batch: x.req.batch,
                        sent: x.sent,
                        folded: x.folded(),
                    })
                    .collect(),
                runs: Vec::new(),
                // queued-but-unadmitted groups count toward the horizon
                // too — they will be served on whatever plan this drive
                // ends up on, so a migration amortizes over them as well
                remaining_iters: active
                    .values()
                    .filter(|x| !x.done)
                    .map(|x| x.req.max_new_tokens.saturating_sub(x.folded()) as u64)
                    .chain(groups[next_group..].iter().map(|g| g.max_new_tokens as u64))
                    .max()
                    .unwrap_or(0),
            };
            if hooks.after_token(wired, &view)? {
                pending_barrier = true;
            }
        }

        // drain barrier reached? (no unfinished group has work in flight)
        if pending_barrier && active.values().all(|x| x.done || !x.in_flight) {
            // anything the Bubble strategy was staging is drained too
            held.append(&mut bubble_barrier);
            hooks.at_barrier(wired)?;
            pending_barrier = false;
            for (gid, it, toks) in held.drain(..) {
                let a = active
                    .get_mut(&gid)
                    .with_context(|| format!("held group {gid} vanished"))?;
                send_decode(wired, a.req, it, toks)?;
                rows_real += a.req.real() as u64;
                rows_total += a.req.batch as u64;
                a.in_flight = true;
                a.sent = it;
            }
            while in_flight_groups < window && next_group < groups.len() {
                let g = &groups[next_group];
                next_group += 1;
                send_prefill(wired, cfg.prefill_chunk, g)?;
                rows_real += g.real() as u64;
                rows_total += g.batch as u64;
                active.insert(g.group_id, admit(&cfg.trace, g));
                in_flight_groups += 1;
            }
        }

        // Reset the stall clock only now: folding, a blocking hook call
        // (checkpoint probe) or a barrier migration pause may have eaten
        // real time that must not read as pipeline silence — only the
        // recv-timeout path above accumulates stall time.
        last_progress = Instant::now();
    }

    let stats = finish_stats(
        t0,
        real_tokens,
        ttft,
        iter_lat,
        Histogram::new(),
        rows_real,
        rows_total,
    );
    Ok((results, stats))
}

/// Drive an [`AdmissionQueue`] through the iteration-level slot
/// scheduler (continuous batching).  Requests are pulled from the queue
/// as they arrive, admitted into compiled batch slots as capacity frees
/// up, retire individually, and every frame carries a per-iteration slot
/// map.  See [`super::scheduler`] and [`super::admission`].
///
/// The queue's source decides the serving regime: the closed-loop
/// [`super::admission::QueueSource`] reproduces the old fixed-queue
/// behavior exactly (everything arrives at t = 0), a
/// [`super::admission::TraceSource`] replays Poisson arrivals open-loop
/// on the drive clock, and a [`super::admission::LiveSource`] serves the
/// TCP front door.  Arrival timestamps flow into the stats: TTFT and
/// per-request completion are measured from *arrival*, and
/// [`DriveStats::queue_delay`] records arrival → prefill dispatch.
///
/// `hooks` interpose exactly as in [`drive_groups`]: `after_token` may
/// request a drain barrier (the loop stops pumping, lets every in-flight
/// frame land, then calls `at_barrier` — KV migration works on runs the
/// same as on groups), and `stall_poll_real_ms`/`on_stall` enable
/// device-loss failover — the hook receives each live run's [`RunSnap`]
/// and, on recovery, the scheduler re-queues dead admissions and
/// recomposes dead steps ([`SlotScheduler::on_failover`]); queued
/// arrivals ride out a failover untouched (only in-flight frames die).
/// Static serving passes [`NoHooks`].
pub fn drive_slots(
    wired: &mut Wired,
    cfg: &DriverCfg,
    queue: &mut AdmissionQueue,
    ccfg: &ContinuousConfig,
    hooks: &mut dyn DriveHooks,
) -> Result<(Vec<GenResult>, DriveStats)> {
    // admissions prefill at batch 1, so that variant must be compiled
    anyhow::ensure!(
        cfg.batch_sizes.contains(&1),
        "continuous batching needs a compiled batch-1 prefill (have {:?})",
        cfg.batch_sizes
    );
    let t0 = Instant::now();
    // Every arrived request's prompt must fit the compiled shapes.
    let fits = |id: u64, max_new: usize| -> Result<()> {
        anyhow::ensure!(
            cfg.prompt_len + max_new <= cfg.max_seq,
            "request {id}: {} prompt + {max_new} new tokens exceeds compiled max_seq {}",
            cfg.prompt_len,
            cfg.max_seq
        );
        Ok(())
    };
    let mut arrival_by_req: HashMap<u64, f64> = HashMap::new();
    // SLO bookkeeping: class per accepted request, absolute expiry (ms
    // on the drive clock) for deadlined ones, and the queued batch
    // requests in arrival order (aging scans its front; entries are
    // lazily discarded once dispatched or expired)
    let mut class_by_req: HashMap<u64, SloClass> = HashMap::new();
    let mut deadline_by_req: HashMap<u64, f64> = HashMap::new();
    let mut pending_batch: std::collections::VecDeque<(u64, f64)> =
        std::collections::VecDeque::new();
    let mut shed = [0u64; 2];
    let mut expired = [0u64; 2];
    let mut peak_queue_depth = 0usize;
    let slo_policy = match queue.policy() {
        AdmissionPolicy::SloPriority(p) => Some(p.clone()),
        _ => None,
    };

    // The degenerate closed-loop source delivers everything at t = 0:
    // take the whole queue up front so the initial compiled batch is
    // sized from it, exactly like pre-admission-layer serving.  An open
    // source starts the scheduler empty (smallest batch, grows with
    // demand).
    let initial = queue.poll(0.0);
    for a in &initial {
        fits(a.req.id, a.req.max_new_tokens)?;
        let arr = a.arrival_ms.max(0.0);
        arrival_by_req.insert(a.req.id, arr);
        class_by_req.insert(a.req.id, a.req.class);
        if let Some(d) = a.req.deadline_ms {
            deadline_by_req.insert(a.req.id, arr + d);
        }
        if a.req.class == SloClass::Batch {
            pending_batch.push_back((a.req.id, arr));
        }
        cfg.trace.begin(LifeKind::Request, a.req.id, ReqPhase::Whole);
        cfg.trace.begin(LifeKind::Request, a.req.id, ReqPhase::Queue);
    }
    let mut sched = if queue.closed() {
        let reqs: Vec<_> = initial.iter().map(|a| a.req.clone()).collect();
        SlotScheduler::new(ccfg, cfg.prompt_len, cfg.batch_sizes.clone(), &reqs)?
    } else {
        let mut s = SlotScheduler::new_open(ccfg, cfg.prompt_len, cfg.batch_sizes.clone())?;
        for a in &initial {
            s.push_request(&a.req)?;
        }
        s
    };
    sched.set_policy(queue.policy().clone());
    if let Some(p) = &cfg.paged {
        // Paged layout: admission gates on live block occupancy, pump
        // by pump, and pool exhaustion preempts (swap-out / recompute)
        // instead of refusing — the worst-case row bound below would
        // defeat the whole point.  The only hard floor is that one
        // fully-grown row plus a block of headroom must fit, or a lone
        // sequence could wedge against its own footprint.
        anyhow::ensure!(
            p.pool_blocks > cfg.max_seq.div_ceil(p.block_size),
            "paged KV pool ({} blocks x {} positions) cannot hold one max_seq={} \
             row plus decode headroom: raise the KV budget",
            p.pool_blocks,
            p.block_size,
            cfg.max_seq
        );
        sched.set_paged(p.block_size, p.pool_blocks)?;
    } else {
        // Padded layout: reject up front a slot configuration whose
        // fully-admitted state could not fit the per-stage KV budget —
        // failing here beats a stage thread dying on an over-budget
        // insert_row mid-generation.
        let worst = sched.worst_case_rows() as u64 * cfg.row_bytes_worst;
        anyhow::ensure!(
            cfg.row_bytes_worst == 0 || worst <= cfg.kv_budget_bytes,
            "continuous-batching slots need up to {} KV bytes on the heaviest stage \
             (budget {}): lower `runs`/`max_batch` or raise the KV budget",
            worst,
            cfg.kv_budget_bytes
        );
    }
    // Swapped-out KV freight, keyed by request id.  Held here — not in
    // the pipeline — so it survives a failover teardown; the matching
    // SwapIn re-installs it into whatever pipeline is wired then.
    let mut swapped: HashMap<u64, Vec<super::stage::KvEntry>> = HashMap::new();

    let mut ttft = Histogram::new();
    let mut iter_lat = Histogram::new();
    let mut queue_delay = Histogram::new();
    // requests whose queue delay is already recorded (failover re-admits
    // must not re-record)
    let mut delay_recorded: std::collections::HashSet<u64> = std::collections::HashSet::new();
    let mut results = Vec::new();
    let mut real_tokens = 0u64;
    // TTFT is measured from each request's *arrival* (0 for the
    // closed-loop source), so queue wait is included — the number a
    // client of the serving system would see
    let mut ttft_by_req: HashMap<u64, f64> = HashMap::new();
    // Per-run decode-gap baseline.  Run ids are stable across Compact
    // recomposition (the scheduler recomposes in place), so the baseline
    // carries through a grow/shrink and the cross-recomposition gap still
    // lands in `iter_latency`; entries are pruned when the run is freed.
    let mut last_step_at: HashMap<u64, Instant> = HashMap::new();
    let mut expecting = 0usize;
    let mut received = 0u64;
    // hook-requested drain barrier: stop pumping new work, let every
    // in-flight frame land, run `at_barrier` (e.g. a KV migration onto a
    // better plan), resume pumping on whatever pipeline it left behind
    let mut pending_barrier = false;

    let stall_poll = if hooks.enabled() {
        hooks.stall_poll_real_ms()
    } else {
        None
    };
    let dead_man_real_ms = ccfg.dead_man_real_ms.max(1.0);
    let mut last_progress = Instant::now();
    // (queue depth, admitted requests) at the last gauge emission
    let mut last_queue_gauge = (usize::MAX, usize::MAX);

    loop {
        // ingest arrivals first: anything that has arrived by now is
        // admissible in this very pump (the closed-loop source is
        // already drained and returns nothing)
        let now_ms = t0.elapsed().as_secs_f64() * 1e3;
        for a in queue.poll(now_ms) {
            fits(a.req.id, a.req.max_new_tokens)?;
            let arr = a.arrival_ms.max(0.0);
            arrival_by_req.insert(a.req.id, arr);
            class_by_req.insert(a.req.id, a.req.class);
            if let Some(d) = a.req.deadline_ms {
                deadline_by_req.insert(a.req.id, arr + d);
            }
            if a.req.class == SloClass::Batch {
                pending_batch.push_back((a.req.id, arr));
            }
            cfg.trace.begin(LifeKind::Request, a.req.id, ReqPhase::Whole);
            cfg.trace.begin(LifeKind::Request, a.req.id, ReqPhase::Queue);
            sched.push_request(&a.req)?;
        }
        // arrivals the admission queue shed at their class bound: the
        // client was already answered (structured reject through the
        // source); count and trace them here
        for ev in queue.take_events() {
            let AdmissionEvent::Shed { id, class } = ev;
            shed[class_ix(class)] += 1;
            cfg.metrics.inc("requests_shed", 1);
            cfg.metrics.inc(shed_key(class), 1);
            cfg.trace
                .instant("request_shed", || format!("req {id} ({})", class.name()));
        }
        // deadline expiry: a queued request past its TTFT deadline can
        // no longer be served in time — drop it before wasting a prefill
        // on it.  Only never-dispatched requests are eligible (an
        // admitted row's prefill is already paid for).
        if !deadline_by_req.is_empty() {
            let overdue: std::collections::HashSet<u64> = deadline_by_req
                .iter()
                .filter(|(id, &exp)| now_ms >= exp && !delay_recorded.contains(id))
                .map(|(&id, _)| id)
                .collect();
            if !overdue.is_empty() {
                for id in sched.drop_waiting(|id| overdue.contains(&id)) {
                    let class = class_by_req.get(&id).copied().unwrap_or_default();
                    let arr = arrival_by_req.remove(&id).unwrap_or(0.0);
                    deadline_by_req.remove(&id);
                    expired[class_ix(class)] += 1;
                    cfg.metrics.inc("requests_expired", 1);
                    cfg.metrics.inc(expired_key(class), 1);
                    cfg.trace
                        .instant("request_expired", || format!("req {id} ({})", class.name()));
                    cfg.trace.end(LifeKind::Request, id, ReqPhase::Queue);
                    cfg.trace.end(LifeKind::Request, id, ReqPhase::Whole);
                    queue.on_reject(&ServeReply::Expired {
                        id,
                        class,
                        waited_ms: (now_ms - arr).max(0.0),
                    });
                }
            }
        }
        if let Some(p) = &slo_policy {
            // anti-starvation aging: arm the scheduler's one-shot batch
            // promotion when the oldest still-queued batch request has
            // waited past aging_ms
            while let Some(&(id, _)) = pending_batch.front() {
                if delay_recorded.contains(&id) || !arrival_by_req.contains_key(&id) {
                    pending_batch.pop_front();
                } else {
                    break;
                }
            }
            let aged = pending_batch
                .front()
                .map(|&(_, arr)| now_ms - arr >= p.aging_ms)
                .unwrap_or(false);
            sched.set_batch_aged(aged);
            // interactive pressure: if waiting interactive requests
            // outnumber free slots, preempt in-flight *batch* prefills
            // (evict + re-queue; the stale first token is ghost-swallowed
            // by the scheduler) so the next pump admits interactive work
            let need = sched.waiting_interactive();
            let free = sched.free_slots();
            if need > free {
                let n = sched.preempt_batch_prefills(need - free);
                if n > 0 {
                    cfg.metrics.inc("batch_prefills_preempted", n as u64);
                    cfg.trace
                        .instant("batch_preempt", || format!("{n} prefill(s) evicted"));
                }
            }
        }
        if queue.closed() {
            // no further arrivals: drained runs may free their caches
            sched.close();
        }
        let mut pumped = 0usize;
        if !pending_barrier {
            for action in sched.pump() {
                pumped += 1;
                match action {
                    Action::Admit {
                        run,
                        slot,
                        run_batch,
                        req,
                        prompt,
                    } => {
                        // the request leaves the admission queue here:
                        // its queue delay is now known (first dispatch
                        // only — a failover re-admit is not queue wait)
                        if delay_recorded.insert(req) {
                            let arr = arrival_by_req.get(&req).copied().unwrap_or(0.0);
                            let now = t0.elapsed().as_secs_f64() * 1e3;
                            let wait = (now - arr).max(0.0);
                            queue_delay.record(wait);
                            cfg.metrics.observe("queue_delay_ms", wait);
                            cfg.trace.end(LifeKind::Request, req, ReqPhase::Queue);
                            cfg.trace.begin(LifeKind::Request, req, ReqPhase::Prefill);
                            // the request leaves the bounded class queue:
                            // its slot of the bound frees up (first
                            // dispatch only — failover/preemption
                            // re-admits are not queue departures)
                            queue.on_dispatched(
                                class_by_req.get(&req).copied().unwrap_or_default(),
                            );
                        }
                        // Chunked prefill streams the admission as
                        // successive partial frames; exactly one token
                        // comes back (on the final chunk), so the
                        // in-flight count still increments once.
                        for span in PrefillChunk::spans(cfg.prompt_len, cfg.prefill_chunk) {
                            let tokens = match span {
                                None => prompt.clone(),
                                Some(c) => prompt[c.start..c.start + c.len].to_vec(),
                            };
                            let msg = StageMsg::Admit {
                                run,
                                slot,
                                run_batch,
                                prompt_len: cfg.prompt_len,
                                chunk: span,
                                payload: Payload::Tokens(tokens),
                            };
                            let bytes = msg.wire_bytes();
                            wired.to_first.send(msg, bytes)?;
                        }
                        expecting += 1;
                    }
                    Action::Step {
                        run,
                        iter,
                        batch,
                        pos,
                        tokens,
                    } => {
                        let msg = StageMsg::Step {
                            run,
                            iter,
                            batch,
                            pos,
                            payload: Payload::Tokens(tokens),
                        };
                        let bytes = msg.wire_bytes();
                        wired.to_first.send(msg, bytes)?;
                        expecting += 1;
                    }
                    Action::Evict { run, slot } => {
                        cfg.trace
                            .instant("slot_evict", || format!("run {run} slot {slot}"));
                        send_control(wired, StageMsg::Evict { run, slot })?
                    }
                    Action::Compact {
                        run,
                        new_batch,
                        moves,
                    } => send_control(
                        wired,
                        StageMsg::Compact {
                            run,
                            new_batch,
                            moves,
                        },
                    )?,
                    Action::FreeRun { run } => {
                        // a freed run can never step again: drop its
                        // decode-gap baseline instead of leaking it
                        last_step_at.remove(&run);
                        send_control(wired, StageMsg::Free { group: run })?
                    }
                    Action::SwapOut { run, slot, req } => {
                        // Pool pressure: extract the victim row's live
                        // blocks from every stage (compact freight over
                        // the Export reply path) and hold them here
                        // until the scheduler resumes the row.  The
                        // collect blocks the pump, not the pipeline —
                        // stages keep draining their FIFO inboxes and
                        // the token channel is unbounded, so frames in
                        // front of the swap-out land normally.
                        cfg.trace
                            .instant("kv_swap_out", || format!("run {run} slot {slot} req {req}"));
                        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
                        send_control(
                            wired,
                            StageMsg::SwapOut {
                                run,
                                slot,
                                reply: reply_tx,
                            },
                        )?;
                        let mut entries = Vec::new();
                        for _ in 0..wired.handles.len() {
                            let ex = reply_rx
                                .recv_timeout(Duration::from_secs_f64(dead_man_real_ms / 1e3))
                                .map_err(|_| {
                                    anyhow!(
                                        "swap-out freight for request {req} lost \
                                         (stage died mid-swap?)"
                                    )
                                })?;
                            entries.extend(ex.entries);
                        }
                        let bytes: u64 =
                            entries.iter().map(|e| e.k.bytes() + e.v.bytes()).sum();
                        cfg.metrics.inc("kv_swaps_out", 1);
                        cfg.metrics.inc("kv_swap_bytes_out", bytes);
                        anyhow::ensure!(
                            swapped.insert(req, entries).is_none(),
                            "request {req} swapped out twice without a swap-in"
                        );
                    }
                    Action::SwapIn {
                        run,
                        slot,
                        run_batch,
                        req,
                        written,
                    } => {
                        let entries = swapped.remove(&req).with_context(|| {
                            format!("swap-in for request {req} with no stored freight")
                        })?;
                        cfg.trace
                            .instant("kv_swap_in", || format!("run {run} slot {slot} req {req}"));
                        cfg.metrics.inc("kv_swaps_in", 1);
                        send_control(
                            wired,
                            StageMsg::SwapIn {
                                run,
                                slot,
                                run_batch,
                                written,
                                layers: entries.into_iter().map(|e| (e.layer, e.k, e.v)).collect(),
                            },
                        )?;
                    }
                }
            }
        }
        // queue depth (arrived, not yet dispatched) and admitted-KV
        // pressure: emitted only on change so the trace stays compact
        let depth = arrival_by_req.len().saturating_sub(delay_recorded.len());
        peak_queue_depth = peak_queue_depth.max(depth);
        let admitted = delay_recorded.len() - results.len();
        if (depth, admitted) != last_queue_gauge {
            last_queue_gauge = (depth, admitted);
            cfg.trace.counter("queue_depth", depth as f64);
            cfg.metrics.gauge("queue_depth", depth as f64);
            if cfg.paged.is_some() {
                // block-granular truth beats the padded worst case
                cfg.metrics.gauge("kv_blocks_used", sched.used_blocks() as f64);
            } else {
                cfg.metrics.gauge(
                    "kv_bytes_admitted",
                    (admitted as u64 * cfg.row_bytes_worst) as f64,
                );
            }
        }
        if expecting == 0 {
            if pending_barrier {
                // no frame is in flight anywhere: the barrier is reached
                hooks.at_barrier(wired)?;
                pending_barrier = false;
                // barrier work (a migration pause) is not pipeline silence
                last_progress = Instant::now();
                continue;
            }
            if sched.done() && queue.closed() {
                break;
            }
            if sched.idle() {
                // nothing queued or in flight, but the source is still
                // open: wait for the next arrival — exactly (trace
                // replay knows its next arrival time) or blocking on the
                // live channel — bounded so closure is still noticed
                let now_ms = t0.elapsed().as_secs_f64() * 1e3;
                let wait_ms = match queue.next_arrival_ms() {
                    Some(t) => (t - now_ms).clamp(0.0, IDLE_WAIT_REAL_MS),
                    None => IDLE_WAIT_REAL_MS,
                };
                if wait_ms > 0.0 {
                    queue.wait(Duration::from_secs_f64(wait_ms / 1e3));
                }
                // idle waiting for arrivals is not pipeline silence
                last_progress = Instant::now();
                continue;
            }
            // not idle with nothing in flight: this pump must have made
            // progress (e.g. flushed retirements / frees) — a pump that
            // emits nothing here means the scheduler wedged
            anyhow::ensure!(pumped > 0, "slot scheduler stalled with work left");
            continue;
        }
        let polled = poll_token(
            wired,
            stall_poll,
            dead_man_real_ms,
            &last_progress,
            received,
            hooks,
            || (Vec::new(), sched.snapshot()),
        )?;
        let tok = match polled {
            Polled::Token(t) => t,
            Polled::Stalled { recovered } => {
                if recovered {
                    // Failover: the hook rebuilt the pipeline and already
                    // restored/replayed every folded row's KV.  Whatever
                    // was in flight or held died with the old pipeline —
                    // reset, and let the scheduler re-queue dead
                    // admissions and recompose dead steps on the next
                    // pump.
                    pending_barrier = false;
                    expecting = 0;
                    sched.on_failover();
                    last_progress = Instant::now();
                }
                continue;
            }
        };
        expecting -= 1;
        received += 1;
        let now = Instant::now();
        for ev in sched.on_token(&tok)? {
            match ev {
                SeqEvent::First { req_id } => {
                    real_tokens += 1;
                    cfg.metrics.add_tokens(1);
                    let arr = arrival_by_req.get(&req_id).copied().unwrap_or(0.0);
                    let ms = (now.duration_since(t0).as_secs_f64() * 1e3 - arr).max(0.0);
                    ttft.record(ms);
                    cfg.metrics.observe("ttft_ms", ms);
                    cfg.trace.end(LifeKind::Request, req_id, ReqPhase::Prefill);
                    cfg.trace.begin(LifeKind::Request, req_id, ReqPhase::Decode);
                    ttft_by_req.insert(req_id, ms);
                }
                SeqEvent::StepDone { run, live } => {
                    real_tokens += live as u64;
                    cfg.metrics.add_tokens(live as u64);
                    // gaps between a run's consecutive steps are the
                    // decode-step latency; the first has no predecessor
                    if let Some(prev) = last_step_at.insert(run, now) {
                        let gap = now.duration_since(prev).as_secs_f64() * 1e3;
                        iter_lat.record(gap);
                        cfg.metrics.observe("iter_ms", gap);
                        cfg.trace.step(run as usize, live, gap);
                    }
                }
                SeqEvent::Finished { req_id, tokens } => {
                    // the sequence's First event recorded its TTFT; a
                    // missing entry is a folding bug and must not
                    // masquerade as a perfect 0 ms TTFT in the histogram
                    let req_ttft = ttft_by_req.get(&req_id).copied().with_context(|| {
                        format!("request {req_id} finished without a recorded first token")
                    })?;
                    cfg.trace.end(LifeKind::Request, req_id, ReqPhase::Decode);
                    cfg.trace.end(LifeKind::Request, req_id, ReqPhase::Whole);
                    cfg.metrics.inc("requests_completed", 1);
                    let arr = arrival_by_req.get(&req_id).copied().unwrap_or(0.0);
                    results.push(GenResult {
                        id: req_id,
                        tokens,
                        ttft_ms: req_ttft,
                        total_ms: (now.duration_since(t0).as_secs_f64() * 1e3 - arr).max(0.0),
                    });
                    // live sources answer their client right here,
                    // mid-drive, instead of at the end of the loop
                    queue.on_result(results.last().expect("just pushed"));
                }
            }
        }
        // hooks: checkpointing and the replan control loop ride here,
        // exactly as in group mode.  The deep per-row snapshot is built
        // only when the hook will actually consume it (checkpoint start);
        // every other gated token gets the cheap composition fields.
        if hooks.enabled() && hooks.wants_view(received) {
            let runs = if hooks.wants_run_snapshot(received) {
                sched.snapshot()
            } else {
                Vec::new()
            };
            let view = DriveView {
                received,
                unfinished_batches: sched.run_batches(),
                all_prefilled: !sched.any_prefilling(),
                groups: Vec::new(),
                runs,
                remaining_iters: sched.max_remaining(),
            };
            if hooks.after_token(wired, &view)? {
                pending_barrier = true;
            }
        }
        // only the recv-timeout path above accumulates stall time
        last_progress = Instant::now();
    }
    anyhow::ensure!(sched.done(), "slot scheduler stalled with work left");

    let (rows_real, rows_total) = sched.rows();
    let mut stats = finish_stats(
        t0,
        real_tokens,
        ttft,
        iter_lat,
        queue_delay,
        rows_real,
        rows_total,
    );
    stats.shed = shed;
    stats.expired = expired;
    stats.peak_queue_depth = peak_queue_depth;
    stats.peak_live_rows = sched.peak_live_rows();
    Ok((results, stats))
}

fn finish_stats(
    t0: Instant,
    tokens: u64,
    ttft: Histogram,
    iter_latency: Histogram,
    queue_delay: Histogram,
    rows_real: u64,
    rows_total: u64,
) -> DriveStats {
    let makespan_ms = t0.elapsed().as_secs_f64() * 1e3;
    DriveStats {
        makespan_ms,
        tokens,
        throughput_tps: if makespan_ms > 0.0 {
            tokens as f64 / (makespan_ms / 1e3)
        } else {
            0.0
        },
        ttft,
        iter_latency,
        queue_delay,
        padding_efficiency: if rows_total > 0 {
            rows_real as f64 / rows_total as f64
        } else {
            1.0
        },
        shed: [0, 0],
        expired: [0, 0],
        peak_queue_depth: 0,
        peak_live_rows: 0,
    }
}

fn class_ix(c: SloClass) -> usize {
    match c {
        SloClass::Interactive => 0,
        SloClass::Batch => 1,
    }
}

/// Per-class metrics key for sheds (static strings: the registry is
/// keyed by `&'static str`).
fn shed_key(c: SloClass) -> &'static str {
    match c {
        SloClass::Interactive => "requests_shed_interactive",
        SloClass::Batch => "requests_shed_batch",
    }
}

fn expired_key(c: SloClass) -> &'static str {
    match c {
        SloClass::Interactive => "requests_expired_interactive",
        SloClass::Batch => "requests_expired_batch",
    }
}
