//! Stage actor: one simulated device executing its contiguous layer range.
//!
//! A stage owns (a) its shard's weights as prebuilt [`TensorData`],
//! (b) a [`KvPool`] holding the caches of every group in flight, and
//! (c) the outgoing shaped link.  It processes [`StageMsg`]s FIFO — the
//! arrival order over the links *is* the pipeline schedule, so the Bubble
//! / No-bubble distinction lives entirely in when the driver releases the
//! next iteration (see [`super::driver`]).
//!
//! Continuous batching adds four frames: [`StageMsg::Admit`] (batch-1
//! prefill installed as one row of a run's cache), [`StageMsg::Step`]
//! (one decode iteration over a composed slot batch, carrying the
//! per-row position map), and the row-granular [`StageMsg::Evict`] /
//! [`StageMsg::Compact`] cache operations.  FIFO ordering is what makes
//! them safe: an admission sent before a step is resident before that
//! step executes on every stage it passes.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;

use super::kvcache::{
    GroupCache, KvLayout, KvPool, PagedPool, ELEM_BYTES_F32, PAGED_MAX_POOL_POSITIONS,
};
use crate::cluster::DeviceLiveness;
use crate::metrics::ComputeObs;
use crate::netsim::ShapedSender;
use crate::obs::Tracer;
use crate::runtime::manifest::Manifest;
use crate::runtime::shard::RegId;
use crate::runtime::sim::{dequantize_rows_i8, quantize_rows_i8};
use crate::runtime::{ExecServiceHandle, TensorData, WeightStore};

/// Phase of a token iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// On-the-wire encoding of inter-stage activation frames.
///
/// `F32` ships full-precision hidden states (byte-identical to the
/// historical wire).  `Int8` quantizes each hidden-state frame with
/// per-row (= per-token) symmetric scales at the sending stage and
/// dequantizes on receipt — the frame shrinks ~4×, and because every
/// token row carries its own scale the encoding is independent of how
/// the prompt is chunked across frames.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum WireFormat {
    #[default]
    F32,
    Int8,
}

impl WireFormat {
    /// Multiplier this format applies to profiled activation byte counts
    /// (`act_bytes_*` in [`crate::profiler::ProfiledTraces`]): an f32 row
    /// of `d_model` values becomes `d_model` int8 values plus one f32
    /// scale.
    pub fn act_scale(self, d_model: usize) -> f64 {
        match self {
            WireFormat::F32 => 1.0,
            WireFormat::Int8 => {
                let d = d_model.max(1) as f64;
                (d + 4.0) / (4.0 * d)
            }
        }
    }
}

/// A hidden-state tensor quantized for the wire: int8 values plus one
/// f32 scale per row (trailing-axis slice).  Logical dims are the f32
/// tensor's, so receivers reconstruct the exact shape.
#[derive(Debug, Clone)]
pub struct QuantTensor {
    pub data: Arc<Vec<i8>>,
    pub scales: Vec<f32>,
    pub dims: Vec<i64>,
}

impl QuantTensor {
    /// Bytes this tensor occupies on the wire.
    pub fn wire_bytes(&self) -> u64 {
        self.data.len() as u64 + self.scales.len() as u64 * 4
    }

    /// Quantize a hidden-state tensor (rows = everything but the last
    /// axis).
    pub fn quantize(h: &TensorData) -> Result<QuantTensor> {
        let data = h.as_f32()?;
        let dims = h.dims().to_vec();
        let d = dims.last().copied().unwrap_or(1).max(1) as usize;
        anyhow::ensure!(data.len() % d == 0, "quantize: ragged tensor {dims:?}");
        if data.is_empty() {
            return Ok(QuantTensor {
                data: Arc::new(Vec::new()),
                scales: Vec::new(),
                dims,
            });
        }
        let (q, scales) = quantize_rows_i8(data, data.len() / d);
        Ok(QuantTensor {
            data: Arc::new(q),
            scales,
            dims,
        })
    }

    /// Reconstruct the f32 tensor.
    pub fn dequantize(&self) -> TensorData {
        if self.data.is_empty() {
            return TensorData::f32(Vec::new(), self.dims.clone());
        }
        let f = dequantize_rows_i8(&self.data, &self.scales, self.scales.len());
        TensorData::f32(f, self.dims.clone())
    }
}

/// Payload entering a stage.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Token ids for the source stage (prefill prompt or decode feedback).
    Tokens(Vec<i32>),
    /// Hidden activations from the previous stage.
    Hidden(TensorData),
    /// Hidden activations quantized per [`WireFormat::Int8`].
    Quant(QuantTensor),
}

/// Position of one prefill chunk within a chunked (streamed) prefill.
/// `None` chunk on a Work/Admit frame = the whole prompt in one frame
/// (the historical monolithic path, byte-identical to before).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillChunk {
    /// Absolute position of the chunk's first token.
    pub start: usize,
    /// Tokens in this chunk.
    pub len: usize,
    /// Final chunk: the stage installs the accumulated KV and the head
    /// emits the admission's token.
    pub last: bool,
}

impl PrefillChunk {
    /// Split a prompt of `total` tokens into chunk spans of at most
    /// `chunk` tokens each.  `chunk == 0` (chunking disabled) or a chunk
    /// covering the whole prompt yields the single monolithic span
    /// (`None`), which keeps the wire byte-identical to the unchunked
    /// path.
    pub fn spans(total: usize, chunk: usize) -> Vec<Option<PrefillChunk>> {
        if chunk == 0 || chunk >= total || total == 0 {
            return vec![None];
        }
        let mut out = Vec::with_capacity(total.div_ceil(chunk));
        let mut start = 0;
        while start < total {
            let len = chunk.min(total - start);
            out.push(Some(PrefillChunk {
                start,
                len,
                last: start + len == total,
            }));
            start += len;
        }
        out
    }
}

/// Wire size of a control frame (Free/Evict/Compact/Export/Shutdown) on
/// the shaped links — a small fixed header, not a payload.
pub const CONTROL_FRAME_BYTES: u64 = 16;

/// Messages travelling between driver and stages.
#[derive(Debug, Clone)]
pub enum StageMsg {
    Work {
        group: u64,
        iter: usize,
        /// Absolute position of the token being decoded (unused in prefill).
        pos: i32,
        phase: Phase,
        batch: usize,
        prompt_len: usize,
        /// Chunked prefill: which slice of the prompt this frame carries
        /// (`None` = whole prompt, the monolithic path).  Decode frames
        /// never chunk.
        chunk: Option<PrefillChunk>,
        payload: Payload,
    },
    /// Continuous batching: prefill one sequence at batch 1 and install
    /// the resulting KV as row `slot` of run `run`'s cache (allocated
    /// zeroed at `run_batch` rows on the first admission).  The head
    /// stage answers with the sequence's first token
    /// ([`TokenOrigin::Admit`]).
    Admit {
        run: u64,
        slot: usize,
        run_batch: usize,
        prompt_len: usize,
        /// Chunked prefill: which slice of the prompt this frame carries
        /// (`None` = whole prompt).  The head answers only on the final
        /// chunk.
        chunk: Option<PrefillChunk>,
        payload: Payload,
    },
    /// Continuous batching: one decode iteration over run `run`'s
    /// composed slot batch.  `pos` is the per-iteration slot map: row i
    /// decodes at absolute position `pos[i]`, and `pos[i] < 0` marks a
    /// dead row the kernels skip (its token/output is discarded by the
    /// driver).
    Step {
        run: u64,
        iter: usize,
        batch: usize,
        pos: Vec<i32>,
        payload: Payload,
    },
    /// Continuous batching: retire row `slot` of run `run`, freeing its
    /// KV bytes immediately (per-row, not per-group).
    Evict { run: u64, slot: usize },
    /// Continuous batching: recompose run `run`'s cache at `new_batch`
    /// rows, moving row `from` → `to` for each `(from, to)` pair.
    Compact {
        run: u64,
        new_batch: usize,
        moves: Vec<(usize, usize)>,
    },
    /// Release the group's KV slot and forward downstream.
    Free { group: u64 },
    /// Migration / checkpoint probe: every stage snapshots its resident
    /// KV caches to `reply` (keyed by **global** decoder index) and
    /// forwards the probe, so the driver collects exactly one export per
    /// stage.  The adaptive engine sends this both at a migration barrier
    /// and on a periodic token cadence to keep a failover checkpoint.
    /// FIFO makes the snapshot consistent at the probe's position in the
    /// send stream — in particular, an [`StageMsg::Admit`] sent before
    /// the probe is fully inside the snapshot on every stage, which is
    /// what lets continuous-batching failover restore rows that were
    /// still prefilling when the checkpoint was taken.
    Export { reply: Sender<StageExport> },
    /// Pressure preemption (paged pools only): extract row `slot` of run
    /// `run` as compact live-block freight to `reply`, free its blocks,
    /// and forward — every stage answers once, like [`StageMsg::Export`].
    /// FIFO ordering makes the extraction consistent: a `Step` sent
    /// before the swap-out has fully landed on every stage the frame
    /// passes.
    SwapOut {
        run: u64,
        slot: usize,
        reply: Sender<StageExport>,
    },
    /// Re-install a previously swapped-out row as row `slot` of run
    /// `run`.  `layers` is keyed by **global** decoder index; each stage
    /// installs the layers in its own decoder range and forwards only
    /// the remainder, so the re-entry freight drains as it travels.
    SwapIn {
        run: u64,
        slot: usize,
        run_batch: usize,
        written: usize,
        layers: Vec<(usize, TensorData, TensorData)>,
    },
    Shutdown,
}

/// One (group, global decoder layer) KV pair leaving a stage at migration
/// or checkpoint export.
#[derive(Debug, Clone)]
pub struct KvEntry {
    pub group: u64,
    /// Global decoder-layer index (`decoders.start + local`).
    pub layer: usize,
    pub k: TensorData,
    pub v: TensorData,
    pub batch: usize,
    /// Row liveness, one flag per batch row — carried through so a
    /// half-full continuous-batching run exports/migrates with its slot
    /// occupancy (and per-live-row byte accounting) intact.  Group caches
    /// are fully live.
    pub live: Vec<bool>,
    /// Positions actually written per row.  Exact when the exporting
    /// stage serves paged (the pool tracks every write); in padded mode
    /// it is the prefill watermark only and is not consumed.
    pub written: Vec<usize>,
}

impl KvEntry {
    /// Bytes this entry actually moves as checkpoint / migration /
    /// swap freight.  Paged serving (`block_size` given) charges the
    /// live blocks of live rows; padded serving charges the full padded
    /// tensors, exactly as before.
    pub fn freight_bytes(&self, block_size: Option<usize>) -> u64 {
        match block_size {
            None => self.k.bytes() + self.v.bytes(),
            Some(bs) => {
                let dims = self.k.dims();
                // [batch, kv_heads, seq, head_dim] → bytes per position
                let pos_bytes = (dims[1] * dims[3]) as u64 * ELEM_BYTES_F32 as u64;
                self.live
                    .iter()
                    .zip(&self.written)
                    .filter(|(l, _)| **l)
                    .map(|(_, w)| (w.div_ceil(bs) * bs) as u64 * pos_bytes * 2)
                    .sum()
            }
        }
    }
}

/// A stage's KV snapshot, produced in response to [`StageMsg::Export`].
#[derive(Debug, Clone)]
pub struct StageExport {
    pub stage_idx: usize,
    pub device: usize,
    pub entries: Vec<KvEntry>,
}

impl Payload {
    fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Tokens(t) => t.len() as u64 * 4,
            Payload::Hidden(h) => h.bytes(),
            Payload::Quant(q) => q.wire_bytes(),
        }
    }
}

impl StageMsg {
    /// Wire size of this frame on the shaped links: payload bytes for
    /// work-bearing frames (plus the slot map for [`StageMsg::Step`]),
    /// [`CONTROL_FRAME_BYTES`] for control frames.  Every send must use
    /// this — no call site hardcodes frame sizes.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            StageMsg::Work { payload, .. } | StageMsg::Admit { payload, .. } => {
                payload.wire_bytes()
            }
            StageMsg::Step { payload, pos, .. } => payload.wire_bytes() + pos.len() as u64 * 4,
            // Swap-in carries the row's live-block KV back up the
            // pipeline: the freight is the tensors themselves (compact,
            // no max_seq padding), shrinking as stages strip their
            // layers.
            StageMsg::SwapIn { layers, .. } => {
                CONTROL_FRAME_BYTES
                    + layers
                        .iter()
                        .map(|(_, k, v)| k.bytes() + v.bytes())
                        .sum::<u64>()
            }
            StageMsg::Evict { .. }
            | StageMsg::Compact { .. }
            | StageMsg::Free { .. }
            | StageMsg::Export { .. }
            | StageMsg::SwapOut { .. }
            | StageMsg::Shutdown => CONTROL_FRAME_BYTES,
        }
    }
}

/// What produced a [`TokenMsg`] — classic group serving or one of the
/// continuous-batching paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenOrigin {
    /// Classic group-at-a-time serving ([`StageMsg::Work`]).
    Group,
    /// First token of a continuous-batching admission into `slot`
    /// ([`StageMsg::Admit`]; `group` is the run id).
    Admit { slot: usize },
    /// One continuous-batching decode step ([`StageMsg::Step`]; `group`
    /// is the run id, tokens of dead rows are meaningless).
    Step,
}

/// Token batch emitted by the head stage back to the driver (one shaped
/// hop: the autoregressive loopback of Eq. 6).
#[derive(Debug, Clone)]
pub struct TokenMsg {
    pub group: u64,
    pub iter: usize,
    pub tokens: Vec<i32>,
    pub origin: TokenOrigin,
}

impl TokenMsg {
    pub fn wire_bytes(&self) -> u64 {
        self.tokens.len() as u64 * 4
    }
}

/// Decoder-layer indices `[lo, hi)` for a stage hosting model layers
/// `model_layers` out of `n_model_layers` total (model layer 0 is the
/// embedding, the last is the head).  Shared by stage construction and by
/// the migration coordinator, which must agree on the mapping exactly.
pub fn stage_decoders(
    model_layers: &std::ops::Range<usize>,
    n_model_layers: usize,
) -> std::ops::Range<usize> {
    let dec_lo = model_layers.start.max(1) - 1;
    let dec_hi = (model_layers.end.min(n_model_layers - 1)).max(1) - 1;
    dec_lo..dec_hi.max(dec_lo)
}

/// Where a stage sends its output.
pub enum NextHop {
    /// Forward activations to the next stage.
    Stage(ShapedSender<StageMsg>),
    /// This is the head stage: send sampled tokens to the driver.
    Driver(ShapedSender<TokenMsg>),
}

/// Static + mutable state of one stage actor.
pub struct StageActor {
    pub stage_idx: usize,
    pub device_id: usize,
    /// Decoder-layer indices `[lo, hi)` this stage hosts (model layers
    /// shifted by the embedding layer).
    pub decoders: std::ops::Range<usize>,
    pub has_embed: bool,
    pub has_head: bool,
    pub exec: ExecServiceHandle,
    pub kv: KvPool,
    /// Block-granular pool when serving paged (and this stage hosts
    /// decoder layers); `None` means the padded [`KvPool`] above is
    /// authoritative.
    pub paged: Option<PagedPool>,
    pub next: NextHop,
    /// Extra simulated compute slowdown (1.0 = run at real CPU speed).
    pub compute_scale: f64,
    /// Sinks for per-message compute timings (adaptive monitor, tracer);
    /// every observation is fanned out to each sender.
    pub obs: Vec<Sender<ComputeObs>>,
    /// Shared ground-truth device liveness (churn scenarios).  While this
    /// device is flagged dead every frame reaching it is dropped — no
    /// compute, no forwarding, no observations — exactly as if the host
    /// vanished with its KV state.
    pub liveness: Option<DeviceLiveness>,
    /// Encoding applied to outgoing hidden-state frames.
    pub wire: WireFormat,
    /// Trace sink for `wire_compress` / `chunk_flush` instants and the
    /// per-hop `wire_bytes_sent` counter (off by default: zero cost).
    pub trace: Tracer,
    // weights registered inside the exec service (converted to literals
    // once — the per-token decode loop never copies weights again)
    embed_w: Option<RegId>,
    head_w: Option<RegId>,
    layer_w: Vec<RegId>,
    // model dims
    kv_heads: usize,
    max_seq: usize,
    head_dim: usize,
    vocab: usize,
    // telemetry
    pub exec_ms_total: f64,
    pub msgs_processed: u64,
    /// Total bytes this stage has pushed onto its outgoing link.
    pub wire_bytes_sent: u64,
    /// `wire_bytes_sent[s{idx}]` — Tracer counters key on `&'static str`,
    /// so the per-stage name is leaked once at construction.
    wire_counter: &'static str,
    /// In-flight chunked prefills: accumulated per-layer padded caches,
    /// keyed `(group, None)` for Work frames and `(run, Some(slot))` for
    /// Admit frames.  Installed into the pool on the final chunk.
    pending: HashMap<(u64, Option<usize>), Vec<(TensorData, TensorData)>>,
}

impl StageActor {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        stage_idx: usize,
        device_id: usize,
        manifest: &Manifest,
        weights: &WeightStore,
        model_layers: std::ops::Range<usize>,
        n_model_layers: usize,
        exec: ExecServiceHandle,
        kv_budget_bytes: u64,
        layout: KvLayout,
        next: NextHop,
        preload: Vec<(u64, GroupCache)>,
    ) -> Result<Self> {
        let c = &manifest.config;
        let has_embed = model_layers.start == 0;
        let has_head = model_layers.end == n_model_layers;
        let decoders = stage_decoders(&model_layers, n_model_layers);

        let as_td = |data: &[f32], shape: &[usize]| {
            TensorData::f32(data.to_vec(), shape.iter().map(|&x| x as i64).collect())
        };
        let embed_w = if has_embed {
            let (d, s) = weights.get("tok_emb")?;
            Some(exec.register(vec![as_td(d, s)])?)
        } else {
            None
        };
        let head_w = if has_head {
            let (n, ns) = weights.get("final_norm")?;
            let (l, ls) = weights.get("lm_head")?;
            Some(exec.register(vec![as_td(n, ns), as_td(l, ls)])?)
        } else {
            None
        };
        let layer_w = decoders
            .clone()
            .map(|l| {
                let tensors: Vec<TensorData> = weights
                    .layer_params(manifest, l)?
                    .into_iter()
                    .map(|(d, s)| as_td(d, s))
                    .collect();
                exec.register(tensors)
            })
            .collect::<Result<Vec<_>>>()?;

        // Migration hands a stage its predecessors' KV state before any
        // message flows; admission rules are the same as at prefill.
        let mut kv = KvPool::new(kv_budget_bytes);
        let mut paged = match layout {
            KvLayout::Paged { block_size } if !layer_w.is_empty() => {
                let bb = PagedPool::block_bytes_for(
                    layer_w.len(),
                    c.n_kv_heads,
                    block_size,
                    c.head_dim(),
                );
                // Same clamp as `engine::driver_cfg` applies to the
                // scheduler's pool view — keep them in lockstep.
                let capacity =
                    ((kv_budget_bytes / bb) as usize).min(PAGED_MAX_POOL_POSITIONS / block_size);
                anyhow::ensure!(
                    capacity >= c.max_seq.div_ceil(block_size),
                    "stage {stage_idx}: paged budget {kv_budget_bytes} holds {capacity} \
                     blocks, fewer than one max_seq row"
                );
                Some(PagedPool::new(
                    block_size,
                    layer_w.len(),
                    c.n_kv_heads,
                    c.head_dim(),
                    c.max_seq,
                    capacity,
                )?)
            }
            _ => None,
        };
        for (gid, cache) in preload {
            if let Some(pool) = paged.as_mut() {
                pool.admit_cache(gid, &cache)
                    .with_context(|| format!("preloading migrated KV for group {gid}"))?;
            } else {
                kv.insert(gid, cache)
                    .with_context(|| format!("preloading migrated KV for group {gid}"))?;
            }
        }

        Ok(StageActor {
            stage_idx,
            device_id,
            decoders,
            has_embed,
            has_head,
            exec,
            kv,
            paged,
            next,
            compute_scale: 1.0,
            obs: Vec::new(),
            liveness: None,
            wire: WireFormat::F32,
            trace: Tracer::default(),
            embed_w,
            head_w,
            layer_w,
            kv_heads: c.n_kv_heads,
            max_seq: c.max_seq,
            head_dim: c.head_dim(),
            vocab: c.vocab_size,
            exec_ms_total: 0.0,
            msgs_processed: 0,
            wire_bytes_sent: 0,
            wire_counter: Box::leak(
                format!("wire_bytes_sent[s{stage_idx}]").into_boxed_str(),
            ),
            pending: HashMap::new(),
        })
    }

    fn exec_scaled(
        &mut self,
        prefix: Option<RegId>,
        variant: &str,
        inputs: Vec<TensorData>,
    ) -> Result<Vec<TensorData>> {
        let (out, ms) = self.exec.exec_prefixed(prefix, variant, inputs)?;
        self.exec_ms_total += ms * self.compute_scale;
        if self.compute_scale > 1.0 {
            let extra = ms * (self.compute_scale - 1.0);
            std::thread::sleep(std::time::Duration::from_secs_f64(extra / 1e3));
        }
        Ok(out)
    }

    /// Process messages until `Shutdown` or the input channel closes.
    pub fn run(mut self, rx: std::sync::mpsc::Receiver<StageMsg>) -> Result<()> {
        while let Ok(msg) = rx.recv() {
            // A dead host consumes nothing: frames delivered to it vanish
            // (with whatever KV they would have touched), and the thread
            // exits only when its channel closes — the failover path in
            // `crate::adaptive` abandons rather than joins it.
            if let Some(l) = &self.liveness {
                if !l.is_alive(self.device_id) {
                    continue;
                }
            }
            match msg {
                StageMsg::Shutdown => {
                    self.forward_control(StageMsg::Shutdown)?;
                    break;
                }
                StageMsg::Free { group } => {
                    if let Some(pool) = self.paged.as_mut() {
                        pool.remove_run(group)?;
                    } else {
                        self.kv.remove(group);
                    }
                    self.forward_control(StageMsg::Free { group })?;
                }
                StageMsg::Evict { run, slot } => {
                    // Stages hosting no decoder layers never allocated a
                    // run cache; everyone else must have one.
                    if !self.layer_w.is_empty() {
                        if let Some(pool) = self.paged.as_mut() {
                            pool.evict_row(run, slot)?;
                        } else {
                            self.kv.evict_row(run, slot)?;
                        }
                    }
                    self.forward_control(StageMsg::Evict { run, slot })?;
                }
                StageMsg::Compact {
                    run,
                    new_batch,
                    moves,
                } => {
                    if !self.layer_w.is_empty() {
                        if let Some(pool) = self.paged.as_mut() {
                            pool.compact(run, new_batch, &moves)?;
                        } else {
                            self.kv.compact(run, new_batch, &moves)?;
                        }
                    }
                    self.forward_control(StageMsg::Compact {
                        run,
                        new_batch,
                        moves,
                    })?;
                }
                StageMsg::SwapOut { run, slot, reply } => {
                    let entries = if self.layer_w.is_empty() {
                        Vec::new()
                    } else {
                        let pool = self
                            .paged
                            .as_mut()
                            .context("swap-out reached a padded stage")?;
                        let (written, freight) = pool.extract_row(run, slot)?;
                        pool.evict_row(run, slot)?;
                        freight
                            .into_iter()
                            .enumerate()
                            .map(|(li, (k, v))| KvEntry {
                                group: run,
                                layer: self.decoders.start + li,
                                k,
                                v,
                                batch: 1,
                                live: vec![true],
                                written: vec![written],
                            })
                            .collect()
                    };
                    let _ = reply.send(StageExport {
                        stage_idx: self.stage_idx,
                        device: self.device_id,
                        entries,
                    });
                    self.forward_control(StageMsg::SwapOut { run, slot, reply })?;
                }
                StageMsg::SwapIn {
                    run,
                    slot,
                    run_batch,
                    written,
                    layers,
                } => {
                    let (mine, rest): (Vec<_>, Vec<_>) = layers
                        .into_iter()
                        .partition(|(gl, _, _)| self.decoders.contains(gl));
                    if !self.layer_w.is_empty() {
                        let pool = self
                            .paged
                            .as_mut()
                            .context("swap-in reached a padded stage")?;
                        let mut mine = mine;
                        mine.sort_by_key(|e| e.0);
                        anyhow::ensure!(
                            mine.len() == self.layer_w.len(),
                            "stage {} swap-in: {} layers for {} local",
                            self.stage_idx,
                            mine.len(),
                            self.layer_w.len()
                        );
                        let rows: Vec<(TensorData, TensorData)> =
                            mine.into_iter().map(|(_, k, v)| (k, v)).collect();
                        pool.admit_row(run, slot, run_batch, written, &rows)
                            .with_context(|| {
                                format!(
                                    "stage {} (device {}) swapping run {run} slot {slot} back in",
                                    self.stage_idx, self.device_id
                                )
                            })?;
                    }
                    self.forward_control(StageMsg::SwapIn {
                        run,
                        slot,
                        run_batch,
                        written,
                        layers: rest,
                    })?;
                }
                StageMsg::Admit {
                    run,
                    slot,
                    run_batch,
                    prompt_len,
                    chunk,
                    payload,
                } => {
                    self.msgs_processed += 1;
                    let exec_ms_before = self.exec_ms_total;
                    let seg = chunk.map(|c| c.len).unwrap_or(prompt_len);
                    let hidden = self.input_hidden(Phase::Prefill, 1, seg, payload)?;
                    let (hidden, layers, written) = match chunk {
                        None => {
                            let (h, layers) = self.prefill_compute(1, hidden)?;
                            (h, layers, Some(prompt_len))
                        }
                        Some(c) => {
                            let (h, layers) =
                                self.chunk_compute(1, hidden, (run, Some(slot)), c)?;
                            (h, layers, c.last.then(|| c.start + c.len))
                        }
                    };
                    if let Some(written) = written {
                        if !layers.is_empty() {
                            if let Some(pool) = self.paged.as_mut() {
                                pool.admit_row(run, slot, run_batch, written, &layers)
                            } else {
                                self.kv
                                    .insert_row(run, slot, run_batch, written, layers)
                                    .map(|_| 0)
                            }
                            .with_context(|| {
                                format!(
                                    "stage {} (device {}) admitting run {run} slot {slot}",
                                    self.stage_idx, self.device_id
                                )
                            })?;
                            if chunk.is_some() {
                                self.trace.instant("chunk_flush", || {
                                    format!("run {run} slot {slot} written {written}")
                                });
                            }
                        }
                    }
                    self.record_obs(false, exec_ms_before);
                    let last = chunk.map(|c| c.last).unwrap_or(true);
                    if self.has_head {
                        if last {
                            let tokens = self.head_tokens(1, Phase::Prefill, hidden)?;
                            self.send_tokens(TokenMsg {
                                group: run,
                                iter: 0,
                                tokens,
                                origin: TokenOrigin::Admit { slot },
                            })?;
                        }
                    } else {
                        let payload = self.encode_hidden(hidden)?;
                        self.forward_work(StageMsg::Admit {
                            run,
                            slot,
                            run_batch,
                            prompt_len,
                            chunk,
                            payload,
                        })?;
                    }
                }
                StageMsg::Step {
                    run,
                    iter,
                    batch,
                    pos,
                    payload,
                } => {
                    self.msgs_processed += 1;
                    let exec_ms_before = self.exec_ms_total;
                    let hidden = self.input_hidden(Phase::Decode, batch, 0, payload)?;
                    let hidden = self.run_step(run, batch, &pos, hidden)?;
                    self.record_obs(true, exec_ms_before);
                    if self.has_head {
                        let tokens = self.head_tokens(batch, Phase::Decode, hidden)?;
                        self.send_tokens(TokenMsg {
                            group: run,
                            iter,
                            tokens,
                            origin: TokenOrigin::Step,
                        })?;
                    } else {
                        let payload = self.encode_hidden(hidden)?;
                        self.forward_work(StageMsg::Step {
                            run,
                            iter,
                            batch,
                            pos,
                            payload,
                        })?;
                    }
                }
                StageMsg::Export { reply } => {
                    let mut entries = Vec::new();
                    // Paged stages snapshot by reconstructing each run as
                    // a padded cache — byte-identical to what a padded
                    // stage would export — with exact per-row watermarks
                    // so freight is charged at live-block bytes.
                    let snapshots: Vec<(u64, GroupCache)> = if let Some(pool) = &self.paged {
                        pool.run_ids()
                            .into_iter()
                            .map(|gid| Ok((gid, pool.reconstruct_padded(gid)?)))
                            .collect::<Result<_>>()?
                    } else {
                        self.kv
                            .iter()
                            .map(|(gid, cache)| (*gid, cache.clone()))
                            .collect()
                    };
                    for (gid, cache) in &snapshots {
                        for (li, (k, v)) in cache.layers.iter().enumerate() {
                            entries.push(KvEntry {
                                group: *gid,
                                layer: self.decoders.start + li,
                                k: k.clone(),
                                v: v.clone(),
                                batch: cache.batch,
                                live: cache.live.clone(),
                                written: cache.written.clone(),
                            });
                        }
                    }
                    let _ = reply.send(StageExport {
                        stage_idx: self.stage_idx,
                        device: self.device_id,
                        entries,
                    });
                    self.forward_control(StageMsg::Export { reply })?;
                }
                StageMsg::Work {
                    group,
                    iter,
                    pos,
                    phase,
                    batch,
                    prompt_len,
                    chunk,
                    payload,
                } => {
                    self.msgs_processed += 1;
                    let exec_ms_before = self.exec_ms_total;
                    let seg = match (phase, chunk) {
                        (Phase::Prefill, Some(c)) => c.len,
                        _ => prompt_len,
                    };
                    let hidden = self.input_hidden(phase, batch, seg, payload)?;
                    let hidden = match (phase, chunk) {
                        (Phase::Prefill, Some(c)) => {
                            let (h, layers) =
                                self.chunk_compute(batch, hidden, (group, None), c)?;
                            if c.last {
                                self.install_group(group, batch, c.start + c.len, layers)?;
                                self.trace.instant("chunk_flush", || {
                                    format!("group {group} written {}", c.start + c.len)
                                });
                            }
                            h
                        }
                        (Phase::Prefill, None) => self.run_prefill(group, batch, hidden)?,
                        (Phase::Decode, _) => self.run_decode(group, batch, pos, hidden)?,
                    };
                    self.record_obs(phase == Phase::Decode, exec_ms_before);
                    let last = phase == Phase::Decode || chunk.map(|c| c.last).unwrap_or(true);
                    if self.has_head {
                        if last {
                            let tokens = self.head_tokens(batch, phase, hidden)?;
                            self.send_tokens(TokenMsg {
                                group,
                                iter,
                                tokens,
                                origin: TokenOrigin::Group,
                            })?;
                        }
                    } else {
                        let payload = self.encode_hidden(hidden)?;
                        self.forward_work(StageMsg::Work {
                            group,
                            iter,
                            pos,
                            phase,
                            batch,
                            prompt_len,
                            chunk,
                            payload,
                        })?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Whether this stage's host is (still) up.  Checked again right
    /// before any output leaves the stage: a host that dies *mid-compute*
    /// must not emit observations or forward frames — it died with them.
    fn host_alive(&self) -> bool {
        self.liveness
            .as_ref()
            .map(|l| l.is_alive(self.device_id))
            .unwrap_or(true)
    }

    /// Charge `bytes` to the per-hop telemetry counter.
    fn note_sent(&mut self, bytes: u64) {
        self.wire_bytes_sent += bytes;
        self.trace.counter(self.wire_counter, self.wire_bytes_sent as f64);
    }

    fn forward_control(&mut self, msg: StageMsg) -> Result<()> {
        if !self.host_alive() {
            return Ok(());
        }
        if let NextHop::Stage(tx) = &self.next {
            let bytes = msg.wire_bytes();
            tx.send(msg, bytes)?;
            self.note_sent(bytes);
        }
        Ok(())
    }

    /// Forward a work-bearing frame to the next stage.
    fn forward_work(&mut self, msg: StageMsg) -> Result<()> {
        if !self.host_alive() {
            return Ok(());
        }
        let bytes = msg.wire_bytes();
        match &self.next {
            NextHop::Stage(tx) => tx.send(msg, bytes)?,
            NextHop::Driver(_) => anyhow::bail!("non-head stage wired to driver"),
        }
        self.note_sent(bytes);
        Ok(())
    }

    /// Send sampled tokens to the driver (head stage only).
    fn send_tokens(&mut self, msg: TokenMsg) -> Result<()> {
        if !self.host_alive() {
            return Ok(());
        }
        let bytes = msg.wire_bytes();
        match &self.next {
            NextHop::Driver(tx) => tx.send(msg, bytes)?,
            NextHop::Stage(_) => anyhow::bail!("head stage wired to another stage"),
        }
        self.note_sent(bytes);
        Ok(())
    }

    /// Encode an outgoing hidden-state frame per the configured wire
    /// format.
    fn encode_hidden(&mut self, h: TensorData) -> Result<Payload> {
        match self.wire {
            WireFormat::F32 => Ok(Payload::Hidden(h)),
            WireFormat::Int8 => {
                let raw = h.bytes();
                let q = QuantTensor::quantize(&h)?;
                let packed = q.wire_bytes();
                self.trace
                    .instant("wire_compress", || format!("{raw}B -> {packed}B"));
                Ok(Payload::Quant(q))
            }
        }
    }

    fn record_obs(&self, decode: bool, exec_ms_before: f64) {
        if !self.host_alive() || self.obs.is_empty() {
            return;
        }
        let o = ComputeObs {
            device: self.device_id,
            stage: self.stage_idx,
            decode,
            ms: self.exec_ms_total - exec_ms_before,
        };
        for tx in &self.obs {
            let _ = tx.send(o);
        }
    }

    /// Resolve the incoming payload to hidden activations.
    fn input_hidden(
        &mut self,
        phase: Phase,
        batch: usize,
        prompt_len: usize,
        payload: Payload,
    ) -> Result<TensorData> {
        match payload {
            Payload::Hidden(h) => Ok(h),
            Payload::Quant(q) => Ok(q.dequantize()),
            Payload::Tokens(tokens) => {
                anyhow::ensure!(self.has_embed, "tokens sent to a non-source stage");
                let emb = self.embed_w.context("missing tok_emb")?;
                let (variant, dims) = match phase {
                    Phase::Prefill => (
                        format!("embed_prefill_b{batch}"),
                        vec![batch as i64, prompt_len as i64],
                    ),
                    Phase::Decode => (format!("embed_decode_b{batch}"), vec![batch as i64, 1]),
                };
                let toks = TensorData::i32(tokens, dims);
                let out = self.exec_scaled(Some(emb), &variant, vec![toks])?;
                out.into_iter()
                    .next()
                    .ok_or_else(|| anyhow!("embed returned nothing"))
            }
        }
    }

    /// Run this stage's layers in prefill mode, returning the outgoing
    /// hidden plus the per-layer (k, v) caches — installation is the
    /// caller's business (whole group vs one continuous-batching row).
    fn prefill_compute(
        &mut self,
        batch: usize,
        mut h: TensorData,
    ) -> Result<(TensorData, Vec<(TensorData, TensorData)>)> {
        let variant = format!("layer_prefill_b{batch}");
        let mut layers = Vec::with_capacity(self.layer_w.len());
        for w in self.layer_w.clone() {
            let mut out = self.exec_scaled(Some(w), &variant, vec![h])?;
            anyhow::ensure!(out.len() == 3, "layer_prefill must return 3 outputs");
            let vc = out.pop().unwrap();
            let kc = out.pop().unwrap();
            h = out.pop().unwrap();
            layers.push((kc, vc));
        }
        Ok((h, layers))
    }

    /// One chunk of a streamed prefill through this stage's layers.
    /// Chunk 0 runs the ordinary fresh-prefill kernel; later chunks run
    /// the append kernel against the caches accumulated in `pending`.
    /// Returns the chunk's outgoing hidden plus — on the final chunk
    /// only — the complete per-layer caches ready for installation
    /// (empty `Vec` otherwise, or when this stage hosts no decoders).
    fn chunk_compute(
        &mut self,
        batch: usize,
        mut h: TensorData,
        key: (u64, Option<usize>),
        c: PrefillChunk,
    ) -> Result<(TensorData, Vec<(TensorData, TensorData)>)> {
        anyhow::ensure!(c.len > 0, "empty prefill chunk");
        let layers = if c.start == 0 {
            anyhow::ensure!(
                !self.pending.contains_key(&key),
                "stage {}: chunk 0 for {key:?} over a live chunked prefill",
                self.stage_idx
            );
            let (h2, layers) = self.prefill_compute(batch, h)?;
            h = h2;
            layers
        } else {
            let prev = self.pending.remove(&key).with_context(|| {
                format!(
                    "stage {}: chunk at {} for {key:?} without prior chunks",
                    self.stage_idx, c.start
                )
            })?;
            let variant = format!("layer_prefill_b{batch}");
            let start_t = TensorData::scalar_i32(c.start as i32);
            let mut layers = Vec::with_capacity(prev.len());
            for (w, (kc, vc)) in self.layer_w.clone().into_iter().zip(prev) {
                let inputs = vec![h, kc, vc, start_t.clone()];
                let mut out = self.exec_scaled(Some(w), &variant, inputs)?;
                anyhow::ensure!(out.len() == 3, "layer_prefill append must return 3 outputs");
                let vc = out.pop().unwrap();
                let kc = out.pop().unwrap();
                h = out.pop().unwrap();
                layers.push((kc, vc));
            }
            layers
        };
        if c.last {
            Ok((h, layers))
        } else {
            if !layers.is_empty() {
                self.pending.insert(key, layers);
            }
            Ok((h, Vec::new()))
        }
    }

    /// Install a fully accumulated chunked group prefill, mirroring the
    /// admission rules of the monolithic [`Self::run_prefill`] path.
    fn install_group(
        &mut self,
        group: u64,
        batch: usize,
        written: usize,
        layers: Vec<(TensorData, TensorData)>,
    ) -> Result<()> {
        if layers.is_empty() {
            return Ok(());
        }
        if let Some(pool) = self.paged.as_mut() {
            let cache = GroupCache {
                layers,
                batch,
                bytes: 0,
                live: vec![true; batch],
                written: vec![written; batch],
            };
            return pool.admit_cache(group, &cache).with_context(|| {
                format!(
                    "stage {} (device {}) admitting chunked group {group}",
                    self.stage_idx, self.device_id
                )
            });
        }
        let bytes = KvPool::group_bytes(
            self.layer_w.len(),
            batch,
            self.kv_heads,
            self.max_seq,
            self.head_dim,
            ELEM_BYTES_F32,
        );
        anyhow::ensure!(
            self.kv.can_admit(bytes),
            "stage {} (device {}) KV pool full: admit {} used {} budget {}",
            self.stage_idx,
            self.device_id,
            bytes,
            self.kv.used_bytes(),
            self.kv.budget_bytes()
        );
        self.kv.insert(
            group,
            GroupCache {
                layers,
                batch,
                bytes,
                live: vec![true; batch],
                written: vec![written; batch],
            },
        )?;
        Ok(())
    }

    fn run_prefill(&mut self, group: u64, batch: usize, h: TensorData) -> Result<TensorData> {
        let n_local = self.layer_w.len();
        let prompt = h.dims()[1] as usize;
        if self.paged.is_some() {
            // Paged group admission charges the working set, not the
            // padded worst case: prompt blocks now, one block at a time
            // as rows extend.
            let (h, layers) = self.prefill_compute(batch, h)?;
            let cache = GroupCache {
                layers,
                batch,
                bytes: 0,
                live: vec![true; batch],
                written: vec![prompt; batch],
            };
            self.paged
                .as_mut()
                .unwrap()
                .admit_cache(group, &cache)
                .with_context(|| {
                    format!(
                        "stage {} (device {}) admitting group {group}",
                        self.stage_idx, self.device_id
                    )
                })?;
            return Ok(h);
        }
        let bytes = KvPool::group_bytes(
            n_local,
            batch,
            self.kv_heads,
            self.max_seq,
            self.head_dim,
            ELEM_BYTES_F32,
        );
        anyhow::ensure!(
            self.kv.can_admit(bytes),
            "stage {} (device {}) KV pool full: admit {} used {} budget {}",
            self.stage_idx,
            self.device_id,
            bytes,
            self.kv.used_bytes(),
            self.kv.budget_bytes()
        );
        let (h, layers) = self.prefill_compute(batch, h)?;
        if n_local > 0 {
            self.kv.insert(
                group,
                GroupCache {
                    layers,
                    batch,
                    bytes,
                    live: vec![true; batch],
                    written: vec![prompt; batch],
                },
            )?;
        }
        Ok(h)
    }

    /// One continuous-batching decode iteration: every local layer runs
    /// the composed batch against run `run`'s cache with the per-row
    /// position map (`pos[i] < 0` rows are skipped by the kernel).
    fn run_step(
        &mut self,
        run: u64,
        batch: usize,
        pos: &[i32],
        mut h: TensorData,
    ) -> Result<TensorData> {
        anyhow::ensure!(pos.len() == batch, "slot map len {} != batch {batch}", pos.len());
        let n_local = self.layer_w.len();
        if n_local == 0 {
            return Ok(h);
        }
        if self.paged.is_some() {
            return self.paged_step(run, batch, pos, h);
        }
        let variant = format!("layer_decode_b{batch}");
        let pos_t = TensorData::i32(pos.to_vec(), vec![batch as i64]);
        for li in 0..n_local {
            let (kc, vc) = {
                let cache = self
                    .kv
                    .get(run)
                    .with_context(|| format!("no cache for run {run}"))?;
                anyhow::ensure!(
                    cache.batch == batch,
                    "run {run} cache batch {} != step batch {batch}",
                    cache.batch
                );
                cache.layers[li].clone()
            };
            let w = self.layer_w[li];
            let inputs = vec![h, kc, vc, pos_t.clone()];
            let mut out = self.exec_scaled(Some(w), &variant, inputs)?;
            anyhow::ensure!(out.len() == 3, "layer_decode must return 3 outputs");
            let vc = out.pop().unwrap();
            let kc = out.pop().unwrap();
            h = out.pop().unwrap();
            let cache = self.kv.get_mut(run).unwrap();
            cache.layers[li] = (kc, vc);
        }
        Ok(h)
    }

    /// One paged decode iteration, shared by group decode and
    /// continuous-batching steps: extend the block tables once, then run
    /// every local layer through the table-gather kernel and write the
    /// returned K/V head vectors into the pool.
    fn paged_step(
        &mut self,
        run: u64,
        batch: usize,
        pos: &[i32],
        mut h: TensorData,
    ) -> Result<TensorData> {
        let pool = self.paged.as_mut().unwrap();
        pool.prepare_step(run, pos).with_context(|| {
            format!(
                "stage {} (device {}) stepping run {run}",
                self.stage_idx, self.device_id
            )
        })?;
        let table = pool.table(run)?;
        let pos_t = TensorData::i32(pos.to_vec(), vec![batch as i64]);
        let variant = format!("layer_decode_b{batch}");
        let row_len = self.kv_heads * self.head_dim;
        for li in 0..self.layer_w.len() {
            let (ks, vs) = self.paged.as_ref().unwrap().layer_slabs(li);
            let w = self.layer_w[li];
            let inputs = vec![h, ks, vs, table.clone(), pos_t.clone()];
            let mut out = self.exec_scaled(Some(w), &variant, inputs)?;
            anyhow::ensure!(out.len() == 3, "paged layer_decode must return 3 outputs");
            let v_new = out.pop().unwrap();
            let k_new = out.pop().unwrap();
            h = out.pop().unwrap();
            let (kf, vf) = (k_new.as_f32()?, v_new.as_f32()?);
            let pool = self.paged.as_mut().unwrap();
            for (b, &p) in pos.iter().enumerate() {
                if p < 0 {
                    continue;
                }
                pool.write_pos(
                    li,
                    run,
                    b,
                    p as usize,
                    &kf[b * row_len..(b + 1) * row_len],
                    &vf[b * row_len..(b + 1) * row_len],
                )?;
            }
        }
        Ok(h)
    }

    fn run_decode(
        &mut self,
        group: u64,
        batch: usize,
        pos: i32,
        mut h: TensorData,
    ) -> Result<TensorData> {
        if self.paged.is_some() {
            return self.paged_step(group, batch, &vec![pos; batch], h);
        }
        let variant = format!("layer_decode_b{batch}");
        let n_local = self.layer_w.len();
        for li in 0..n_local {
            let (kc, vc) = {
                let cache = self
                    .kv
                    .get(group)
                    .with_context(|| format!("no cache for group {group}"))?;
                cache.layers[li].clone()
            };
            let w = self.layer_w[li];
            let inputs = vec![h, kc, vc, TensorData::scalar_i32(pos)];
            let mut out = self.exec_scaled(Some(w), &variant, inputs)?;
            anyhow::ensure!(out.len() == 3, "layer_decode must return 3 outputs");
            let vc = out.pop().unwrap();
            let kc = out.pop().unwrap();
            h = out.pop().unwrap();
            let cache = self.kv.get_mut(group).unwrap();
            cache.layers[li] = (kc, vc);
        }
        Ok(h)
    }

    /// Run the head shard and greedy-sample one token per row.
    fn head_tokens(&mut self, batch: usize, phase: Phase, hidden: TensorData) -> Result<Vec<i32>> {
        let hw = self.head_w.context("missing head weights")?;
        let variant = match phase {
            Phase::Prefill => format!("head_prefill_b{batch}"),
            Phase::Decode => format!("head_decode_b{batch}"),
        };
        let out = self.exec_scaled(Some(hw), &variant, vec![hidden])?;
        let logits = out[0].as_f32()?;
        Ok((0..batch)
            .map(|b| {
                let row = &logits[b * self.vocab..(b + 1) * self.vocab];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_bytes() {
        let m = StageMsg::Work {
            group: 0,
            iter: 0,
            pos: 0,
            phase: Phase::Prefill,
            batch: 1,
            prompt_len: 4,
            chunk: None,
            payload: Payload::Tokens(vec![1, 2, 3, 4]),
        };
        assert_eq!(m.wire_bytes(), 16);
        assert_eq!(StageMsg::Free { group: 1 }.wire_bytes(), CONTROL_FRAME_BYTES);
        assert_eq!(
            StageMsg::Evict { run: 0, slot: 3 }.wire_bytes(),
            CONTROL_FRAME_BYTES
        );
        assert_eq!(StageMsg::Shutdown.wire_bytes(), CONTROL_FRAME_BYTES);
        // a Step frame pays for its feedback tokens AND its slot map
        let s = StageMsg::Step {
            run: 0,
            iter: 1,
            batch: 4,
            pos: vec![5, -1, 9, -1],
            payload: Payload::Tokens(vec![1, 2, 3, 4]),
        };
        assert_eq!(s.wire_bytes(), 32);
        let t = TokenMsg {
            group: 0,
            iter: 0,
            tokens: vec![1; 8],
            origin: TokenOrigin::Group,
        };
        assert_eq!(t.wire_bytes(), 32);
    }

    #[test]
    fn quant_frames_charge_compressed_bytes() {
        // [2, 3, 4] f32 hidden = 96B raw; int8 wire = 24 values + 6
        // row scales = 48B.
        let h = TensorData::f32((0..24).map(|i| i as f32 - 11.5).collect(), vec![2, 3, 4]);
        assert_eq!(Payload::Hidden(h.clone()).wire_bytes(), 96);
        let q = QuantTensor::quantize(&h).unwrap();
        assert_eq!(q.wire_bytes(), 24 + 6 * 4);
        let m = StageMsg::Admit {
            run: 0,
            slot: 0,
            run_batch: 1,
            prompt_len: 3,
            chunk: Some(PrefillChunk {
                start: 0,
                len: 3,
                last: false,
            }),
            payload: Payload::Quant(q.clone()),
        };
        assert_eq!(m.wire_bytes(), 48);
        // round trip reconstructs shape and stays within the per-row
        // quantization error bound
        let back = q.dequantize();
        assert_eq!(back.dims(), h.dims());
        let (a, b) = (h.as_f32().unwrap(), back.as_f32().unwrap());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() <= 11.5 / 127.0 * 0.5 + 1e-6);
        }
    }

    #[test]
    fn act_scale_matches_wire_ratio() {
        // one row of d f32 values vs d int8 values + one f32 scale
        for d in [16usize, 64, 4096] {
            let f32_bytes = (d * 4) as f64;
            let int8_bytes = (d + 4) as f64;
            let ratio = int8_bytes / f32_bytes;
            assert!((WireFormat::Int8.act_scale(d) - ratio).abs() < 1e-12);
            assert_eq!(WireFormat::F32.act_scale(d), 1.0);
        }
    }
}
