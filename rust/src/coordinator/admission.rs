//! Arrival-driven admission: how requests enter the serving stack.
//!
//! Before this layer existed the front door packed fixed groups behind a
//! gather window and the continuous-batching engine only ever drained a
//! pre-materialized closed-loop queue — offered load, queue delay and
//! the cost of a failover under load were all invisible.  The admission
//! layer splits "where requests come from" from "how slots are filled":
//!
//! * a [`RequestSource`] produces requests over the **drive clock**
//!   (real milliseconds since the generation drive started).  Three
//!   sources ship: [`QueueSource`] (the closed-loop fixed queue as the
//!   degenerate everything-arrives-at-t=0 case), [`TraceSource`]
//!   (deterministic replay of a [`crate::workload::TraceGen`] /
//!   [`crate::workload::RaggedTraceGen`] Poisson trace), and
//!   [`LiveSource`] (the TCP front door's connection handlers feeding an
//!   mpsc channel);
//! * an [`AdmissionQueue`] wraps the source with a pluggable
//!   [`AdmissionPolicy`] — plain FIFO, FIFO with a bound on prefills
//!   dispatched ahead of an in-flight decode step, or the SLO-class
//!   priority policy ([`SloPolicy`]): **per-class bounded queues**,
//!   interactive-first admission with anti-starvation aging, and
//!   graceful shedding at the bound;
//! * the slot drive loop ([`super::driver::drive_slots`]) polls the
//!   queue between iterations and pushes arrivals into the
//!   [`super::scheduler::SlotScheduler`] as slots free up.  Arrival
//!   timestamps flow into the stats, so TTFT decomposes into
//!   **queue delay** (arrival → batch-1 prefill dispatch) plus
//!   **prefill** (dispatch → first token).
//!
//! ## Admission states under SLO-class serving
//!
//! ```text
//! arrival ──▶ queued ──▶ admitted (prefill dispatched) ──▶ served
//!               │
//!               ├─▶ shed     (class queue at its bound at arrival)
//!               └─▶ expired  (TTFT deadline passed while queued)
//! ```
//!
//! A shed happens the instant its class queue is full — the client is
//! answered with a structured reject immediately, which *is* the
//! backpressure: at most `interactive_bound + batch_bound` requests are
//! ever buffered inside the serving stack, so queue memory and queue
//! delay are both bounded no matter the offered load.  The bound counts
//! **queued** requests (accepted but no prefill dispatched yet); the
//! drive reports dispatches back via [`AdmissionQueue::on_dispatched`]
//! and rejects via [`AdmissionQueue::on_reject`], which is what moves a
//! slot of the bound back to "available".
//!
//! Token numerics are arrival-independent by construction: every row of
//! a composed batch decodes at its own absolute position, so *when* a
//! request was admitted never changes *what* it generates — the
//! open-loop replay of a trace emits byte-identical tokens to serving
//! the same requests closed-loop (asserted in `tests/open_loop.rs`), and
//! SLO-priority reordering leaves every served token stream byte-equal
//! to FIFO (asserted in `tests/admission_slo.rs`).

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

use super::api::{GenRequest, GenResult, ServeReply, SloClass};
use crate::workload::Request;

/// One request stamped with its arrival time (drive-clock ms).
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivedRequest {
    pub req: GenRequest,
    /// Arrival offset from drive start, milliseconds on the drive clock.
    pub arrival_ms: f64,
}

/// Where requests come from.  Implementations are polled (never blocked
/// on) by the slot drive loop between iterations.
pub trait RequestSource: Send {
    /// Every request that has arrived by `now_ms` (drive-clock ms) and
    /// has not been returned before.  Must not block.
    fn poll(&mut self, now_ms: f64) -> Vec<ArrivedRequest>;

    /// Drive-clock ms of the next known arrival, if the source knows it
    /// (trace replay does; a live socket does not).  Lets an idle drive
    /// sleep until the next arrival instead of spinning.
    fn next_arrival_ms(&self) -> Option<f64>;

    /// `true` once no further request will ever arrive — everything the
    /// source will ever produce has been returned by [`Self::poll`].
    fn closed(&self) -> bool;

    /// A request this source produced has finished.  Live sources use
    /// this to answer their client immediately (mid-drive) instead of
    /// waiting for the whole drive to return.
    fn on_result(&mut self, result: &GenResult) {
        let _ = result;
    }

    /// A request this source produced was rejected — shed at the
    /// admission bound or expired in the queue.  Live sources answer
    /// their client with the structured reject right away; replay
    /// sources default to ignoring it (the drive stats carry the
    /// counts).
    fn on_reject(&mut self, reply: &ServeReply) {
        let _ = reply;
    }

    /// Block up to `timeout` waiting for the next arrival — called by an
    /// *idle* drive (nothing queued or in flight).  The default sleeps
    /// the whole timeout, which is exact for sources that know their
    /// next arrival time (the drive sizes the timeout from
    /// [`Self::next_arrival_ms`]); a live source should instead block on
    /// its channel so an idle server neither spins nor adds latency.
    fn wait(&mut self, timeout: Duration) {
        std::thread::sleep(timeout);
    }
}

/// The degenerate closed-loop source: a fixed queue, everything arrives
/// at t = 0.
#[derive(Debug)]
pub struct QueueSource {
    pending: VecDeque<GenRequest>,
}

impl QueueSource {
    pub fn new(requests: &[GenRequest]) -> Self {
        QueueSource {
            pending: requests.iter().cloned().collect(),
        }
    }
}

impl RequestSource for QueueSource {
    fn poll(&mut self, _now_ms: f64) -> Vec<ArrivedRequest> {
        self.pending
            .drain(..)
            .map(|req| ArrivedRequest {
                req,
                arrival_ms: 0.0,
            })
            .collect()
    }

    fn next_arrival_ms(&self) -> Option<f64> {
        if self.pending.is_empty() {
            None
        } else {
            Some(0.0)
        }
    }

    fn closed(&self) -> bool {
        self.pending.is_empty()
    }
}

/// Deterministic open-loop replay of a generated request trace
/// ([`crate::workload::TraceGen`] / [`crate::workload::RaggedTraceGen`]):
/// each request becomes visible exactly at its `arrival_ms` on the drive
/// clock.  With the engine's `time_scale` at 1.0 the drive clock and the
/// simulated clock coincide, so trace arrivals line up with scenario
/// schedules (crash times, link drops).
#[derive(Debug)]
pub struct TraceSource {
    /// Sorted by arrival.
    trace: Vec<ArrivedRequest>,
    next: usize,
}

impl TraceSource {
    pub fn new(mut trace: Vec<ArrivedRequest>) -> Self {
        trace.sort_by(|a, b| {
            a.arrival_ms
                .partial_cmp(&b.arrival_ms)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        TraceSource { trace, next: 0 }
    }

    /// Replay a [`crate::workload`] trace verbatim (every request
    /// interactive, no deadline — callers layer classes on top with
    /// [`TraceSource::new`]).
    pub fn from_trace(trace: &[Request]) -> Self {
        Self::new(
            trace
                .iter()
                .map(|r| ArrivedRequest {
                    req: GenRequest::new(r.id, r.prompt.clone(), r.max_new_tokens),
                    arrival_ms: r.arrival_ms.max(0.0),
                })
                .collect(),
        )
    }
}

impl RequestSource for TraceSource {
    fn poll(&mut self, now_ms: f64) -> Vec<ArrivedRequest> {
        let mut out = Vec::new();
        while self.next < self.trace.len() && self.trace[self.next].arrival_ms <= now_ms {
            out.push(self.trace[self.next].clone());
            self.next += 1;
        }
        out
    }

    fn next_arrival_ms(&self) -> Option<f64> {
        self.trace.get(self.next).map(|a| a.arrival_ms)
    }

    fn closed(&self) -> bool {
        self.next >= self.trace.len()
    }
}

/// One live request as the TCP connection handlers hand it over: the
/// parsed request, the channel its reply rides back on, and the instant
/// it arrived (stamped by the handler, so queueing inside the channel is
/// part of the measured queue delay).
pub struct IncomingRequest {
    pub req: GenRequest,
    pub reply: Sender<ServeReply>,
    pub at: Instant,
}

/// Live arrivals from the TCP front door: connection handler threads
/// push [`IncomingRequest`]s into an mpsc channel; the drive loop polls
/// it between iterations.  The source assigns its own dense request ids
/// (client-supplied ids are ignored), clamps `max_new_tokens` to what
/// the compiled shapes can hold, and answers each client the moment its
/// request finishes ([`RequestSource::on_result`]) or is rejected
/// ([`RequestSource::on_reject`]) — a shed or expiry reply rides the
/// same per-request channel, so overload rejects reach the client even
/// while the serving queue is saturated.
pub struct LiveSource {
    rx: Receiver<IncomingRequest>,
    start: Instant,
    next_id: u64,
    accepted: usize,
    /// Stop accepting after this many requests (None = serve forever).
    max_requests: Option<usize>,
    /// Upper bound on `max_new_tokens` (compiled `max_seq - prompt_len`).
    max_new_cap: usize,
    replies: HashMap<u64, Sender<ServeReply>>,
    /// A request received by a blocking [`RequestSource::wait`], handed
    /// to the next [`RequestSource::poll`].
    stashed: Option<IncomingRequest>,
    disconnected: bool,
}

impl LiveSource {
    pub fn new(
        rx: Receiver<IncomingRequest>,
        max_requests: Option<usize>,
        max_new_cap: usize,
    ) -> Self {
        LiveSource {
            rx,
            start: Instant::now(),
            next_id: 1,
            accepted: 0,
            max_requests,
            max_new_cap: max_new_cap.max(1),
            replies: HashMap::new(),
            stashed: None,
            disconnected: false,
        }
    }

    /// Accept one raw incoming request: assign the server-side id, clamp
    /// the generation length, stamp the arrival.
    fn accept(&mut self, mut inc: IncomingRequest) -> ArrivedRequest {
        inc.req.id = self.next_id;
        self.next_id += 1;
        self.accepted += 1;
        inc.req.max_new_tokens = inc.req.max_new_tokens.clamp(1, self.max_new_cap);
        // saturates to 0 for requests racing the drive start
        let arrival_ms = inc.at.duration_since(self.start).as_secs_f64() * 1e3;
        self.replies.insert(inc.req.id, inc.reply);
        ArrivedRequest {
            req: inc.req,
            arrival_ms,
        }
    }
}

impl RequestSource for LiveSource {
    fn poll(&mut self, _now_ms: f64) -> Vec<ArrivedRequest> {
        let mut out = Vec::new();
        if let Some(inc) = self.stashed.take() {
            if self.closed() {
                // raced max_requests: the stash was never accepted; drop
                // it so its handler gets "engine unavailable"
                drop(inc);
            } else {
                out.push(self.accept(inc));
            }
        }
        while !self.closed() {
            match self.rx.try_recv() {
                Ok(inc) => out.push(self.accept(inc)),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.disconnected = true;
                    break;
                }
            }
        }
        out
    }

    /// Block on the channel instead of sleeping: an idle front door
    /// wakes the moment a request lands, with zero polling in between.
    fn wait(&mut self, timeout: Duration) {
        if self.stashed.is_some() || self.closed() {
            return;
        }
        match self.rx.recv_timeout(timeout) {
            Ok(inc) => self.stashed = Some(inc),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => self.disconnected = true,
        }
    }

    fn next_arrival_ms(&self) -> Option<f64> {
        None
    }

    fn closed(&self) -> bool {
        self.disconnected
            || self
                .max_requests
                .map(|m| self.accepted >= m)
                .unwrap_or(false)
    }

    fn on_result(&mut self, result: &GenResult) {
        if let Some(tx) = self.replies.remove(&result.id) {
            // a vanished client is not a serving error
            let _ = tx.send(ServeReply::Done(result.clone()));
        }
    }

    fn on_reject(&mut self, reply: &ServeReply) {
        if let Some(tx) = self.replies.remove(&reply.id()) {
            let _ = tx.send(reply.clone());
        }
    }
}

/// Knobs of the SLO-class priority policy
/// ([`AdmissionPolicy::SloPriority`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SloPolicy {
    /// Max interactive requests queued (accepted, no prefill dispatched
    /// yet) before further interactive arrivals are shed.
    pub interactive_bound: usize,
    /// Max batch requests queued before further batch arrivals are shed.
    pub batch_bound: usize,
    /// Anti-starvation aging: a batch request queued this long is
    /// promoted ahead of interactive admissions (one per promotion), so
    /// sustained interactive load can delay batch work by at most this
    /// plus one admission round per batch request.
    pub aging_ms: f64,
    /// Class-aware prefill/decode interleaving: at most this many
    /// *batch* prefills may be dispatched ahead of an in-flight decode
    /// step per pump (interactive prefills are never capped — they are
    /// the latency-sensitive class the cap protects).  A run with no
    /// live rows admits freely, as under
    /// [`AdmissionPolicy::BoundedPrefill`].
    pub batch_prefill_cap: usize,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            interactive_bound: 64,
            batch_bound: 64,
            aging_ms: 500.0,
            batch_prefill_cap: 1,
        }
    }
}

/// How waiting requests may be admitted into free slots.
#[derive(Debug, Clone, Default, PartialEq)]
pub enum AdmissionPolicy {
    /// Fill every free slot, oldest request first (unbounded: a burst of
    /// arrivals may stack a whole batch of batch-1 prefills ahead of an
    /// in-flight run's next decode step).
    #[default]
    Fifo,
    /// FIFO, but at most this many batch-1 prefill admissions may be
    /// dispatched ahead of any single decode step of a run that already
    /// has live rows — bounding how long a prefill burst can delay
    /// in-flight decodes (each admission costs one full pipeline pass
    /// before the step behind it executes).  Runs with no live rows
    /// admit freely: there is no decode step to delay.
    BoundedPrefill(usize),
    /// SLO-class serving: per-class bounded queues with shedding at the
    /// bound, interactive-first admission with anti-starvation aging,
    /// and a class-aware prefill cap.  See [`SloPolicy`].
    SloPriority(SloPolicy),
}

/// One admission-layer rejection, reported to the drive loop so it can
/// count it ([`crate::obs::MetricsRegistry`]) and trace it (obs
/// instants).  The client-facing reply already went out through
/// [`RequestSource::on_reject`].
#[derive(Debug, Clone, PartialEq)]
pub enum AdmissionEvent {
    /// Arrival shed at its class bound.
    Shed { id: u64, class: SloClass },
}

/// A [`RequestSource`] plus the [`AdmissionPolicy`] the slot scheduler
/// must apply to it — the one handle [`super::driver::drive_slots`]
/// serves from.  Under [`AdmissionPolicy::SloPriority`] it also owns the
/// per-class bound accounting: [`AdmissionQueue::poll`] sheds arrivals
/// whose class queue is full, and the drive reports queue departures
/// back through [`AdmissionQueue::on_dispatched`] /
/// [`AdmissionQueue::on_reject`].
pub struct AdmissionQueue {
    source: Box<dyn RequestSource>,
    policy: AdmissionPolicy,
    /// Queued (accepted, not yet prefill-dispatched) per class:
    /// `[interactive, batch]`.  Only maintained under `SloPriority`.
    queued: [usize; 2],
    /// Rejections since the last [`AdmissionQueue::take_events`].
    events: Vec<AdmissionEvent>,
}

fn class_ix(c: SloClass) -> usize {
    match c {
        SloClass::Interactive => 0,
        SloClass::Batch => 1,
    }
}

impl AdmissionQueue {
    pub fn new(source: Box<dyn RequestSource>, policy: AdmissionPolicy) -> Self {
        AdmissionQueue {
            source,
            policy,
            queued: [0, 0],
            events: Vec::new(),
        }
    }

    /// The degenerate closed-loop queue: everything arrives at t = 0,
    /// FIFO admission — exactly the pre-admission-layer behavior.
    pub fn closed_loop(requests: &[GenRequest]) -> Self {
        Self::new(Box::new(QueueSource::new(requests)), AdmissionPolicy::Fifo)
    }

    /// Open-loop replay of a workload trace (FIFO admission).
    pub fn replay(trace: &[Request]) -> Self {
        Self::new(
            Box::new(TraceSource::from_trace(trace)),
            AdmissionPolicy::Fifo,
        )
    }

    /// Swap the admission policy (builder style).
    pub fn with_policy(mut self, policy: AdmissionPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn policy(&self) -> &AdmissionPolicy {
        &self.policy
    }

    /// Pull every arrival up to `now_ms`, shedding past-bound arrivals
    /// under [`AdmissionPolicy::SloPriority`] (the shed client is
    /// answered immediately via [`RequestSource::on_reject`]; the drive
    /// collects the counts via [`AdmissionQueue::take_events`]).  Only
    /// accepted requests are returned.
    pub fn poll(&mut self, now_ms: f64) -> Vec<ArrivedRequest> {
        let arrivals = self.source.poll(now_ms);
        let AdmissionPolicy::SloPriority(p) = &self.policy else {
            return arrivals;
        };
        let bounds = [p.interactive_bound, p.batch_bound];
        let mut accepted = Vec::with_capacity(arrivals.len());
        for a in arrivals {
            let ix = class_ix(a.req.class);
            if self.queued[ix] >= bounds[ix] {
                let reply = ServeReply::Shed {
                    id: a.req.id,
                    class: a.req.class,
                };
                self.source.on_reject(&reply);
                self.events.push(AdmissionEvent::Shed {
                    id: a.req.id,
                    class: a.req.class,
                });
            } else {
                self.queued[ix] += 1;
                accepted.push(a);
            }
        }
        accepted
    }

    /// Rejections (sheds) since the last call — the drive loop's hook
    /// for metrics counters and trace instants.
    pub fn take_events(&mut self) -> Vec<AdmissionEvent> {
        std::mem::take(&mut self.events)
    }

    /// Queued (accepted, not yet dispatched) requests of `class`.
    pub fn queued(&self, class: SloClass) -> usize {
        self.queued[class_ix(class)]
    }

    /// A queued request's prefill was dispatched: it left the bounded
    /// queue, freeing one slot of its class bound.
    pub fn on_dispatched(&mut self, class: SloClass) {
        let ix = class_ix(class);
        self.queued[ix] = self.queued[ix].saturating_sub(1);
    }

    pub fn next_arrival_ms(&self) -> Option<f64> {
        self.source.next_arrival_ms()
    }

    pub fn closed(&self) -> bool {
        self.source.closed()
    }

    pub fn on_result(&mut self, result: &GenResult) {
        self.source.on_result(result);
    }

    /// A queued request was rejected after acceptance (deadline expiry,
    /// detected by the drive loop, which owns the clock): answer the
    /// client and release its slot of the class bound.
    pub fn on_reject(&mut self, reply: &ServeReply) {
        if let ServeReply::Expired { class, .. } | ServeReply::Shed { class, .. } = reply {
            let ix = class_ix(*class);
            self.queued[ix] = self.queued[ix].saturating_sub(1);
        }
        self.source.on_reject(reply);
    }

    /// Block up to `timeout` for the next arrival (idle drive) — see
    /// [`RequestSource::wait`].
    pub fn wait(&mut self, timeout: Duration) {
        self.source.wait(timeout);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64) -> GenRequest {
        GenRequest::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn queue_source_delivers_everything_at_zero() {
        let mut s = QueueSource::new(&[req(1), req(2)]);
        assert!(!s.closed());
        assert_eq!(s.next_arrival_ms(), Some(0.0));
        let got = s.poll(0.0);
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|a| a.arrival_ms == 0.0));
        assert!(s.closed());
        assert!(s.poll(100.0).is_empty());
        assert_eq!(s.next_arrival_ms(), None);
    }

    #[test]
    fn trace_source_releases_by_arrival() {
        let trace = vec![
            Request {
                id: 1,
                arrival_ms: 0.0,
                prompt: vec![1],
                max_new_tokens: 2,
            },
            Request {
                id: 2,
                arrival_ms: 50.0,
                prompt: vec![2],
                max_new_tokens: 2,
            },
            Request {
                id: 3,
                arrival_ms: 90.0,
                prompt: vec![3],
                max_new_tokens: 2,
            },
        ];
        let mut s = TraceSource::from_trace(&trace);
        let first = s.poll(0.0);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].req.id, 1);
        assert_eq!(first[0].req.class, SloClass::Interactive);
        assert!(!s.closed());
        assert_eq!(s.next_arrival_ms(), Some(50.0));
        // nothing between arrivals
        assert!(s.poll(49.9).is_empty());
        let mid = s.poll(90.0);
        assert_eq!(mid.len(), 2);
        assert_eq!(mid[1].arrival_ms, 90.0);
        assert!(s.closed());
    }

    #[test]
    fn live_source_assigns_ids_clamps_and_replies() {
        let (tx, rx) = mpsc::channel();
        let mut s = LiveSource::new(rx, Some(2), 8);
        let (rtx, rrx) = mpsc::channel();
        tx.send(IncomingRequest {
            req: GenRequest::new(999, vec![5], 1000),
            reply: rtx,
            at: Instant::now(),
        })
        .unwrap();
        let got = s.poll(0.0);
        assert_eq!(got.len(), 1);
        // server-assigned id, clamped generation length
        assert_eq!(got[0].req.id, 1);
        assert_eq!(got[0].req.max_new_tokens, 8);
        assert!(got[0].arrival_ms >= 0.0);
        assert!(!s.closed());
        // the reply rides back through on_result
        let result = GenResult {
            id: 1,
            tokens: vec![7, 8],
            ttft_ms: 1.0,
            total_ms: 2.0,
        };
        s.on_result(&result);
        assert_eq!(rrx.recv().unwrap(), ServeReply::Done(result));
        // second accept hits max_requests and closes the source
        let (rtx2, _rrx2) = mpsc::channel();
        tx.send(IncomingRequest {
            req: req(7),
            reply: rtx2,
            at: Instant::now(),
        })
        .unwrap();
        assert_eq!(s.poll(1.0).len(), 1);
        assert!(s.closed());
        assert!(s.poll(2.0).is_empty());
    }

    #[test]
    fn live_source_answers_rejects_on_the_reply_channel() {
        let (tx, rx) = mpsc::channel();
        let mut s = LiveSource::new(rx, None, 8);
        let (rtx, rrx) = mpsc::channel();
        tx.send(IncomingRequest {
            req: req(1).with_class(SloClass::Batch),
            reply: rtx,
            at: Instant::now(),
        })
        .unwrap();
        let got = s.poll(0.0);
        assert_eq!(got.len(), 1);
        let reply = ServeReply::Shed {
            id: got[0].req.id,
            class: SloClass::Batch,
        };
        s.on_reject(&reply);
        assert_eq!(rrx.recv().unwrap(), reply);
    }

    #[test]
    fn live_source_wait_blocks_then_hands_over_via_poll() {
        let (tx, rx) = mpsc::channel();
        let mut s = LiveSource::new(rx, None, 8);
        // nothing pending: wait times out without stashing
        let t = Instant::now();
        s.wait(Duration::from_millis(5));
        assert!(t.elapsed() >= Duration::from_millis(5));
        assert!(s.poll(0.0).is_empty());
        // a pending request is picked up by wait and delivered by poll
        tx.send(IncomingRequest {
            req: req(1),
            reply: mpsc::channel().0,
            at: Instant::now(),
        })
        .unwrap();
        s.wait(Duration::from_secs(5));
        let got = s.poll(1.0);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].req.id, 1);
        // sender gone: wait marks the source closed
        drop(tx);
        s.wait(Duration::from_secs(5));
        assert!(s.closed());
    }

    #[test]
    fn admission_queue_wraps_source_and_policy() {
        let mut q = AdmissionQueue::closed_loop(&[req(1)])
            .with_policy(AdmissionPolicy::BoundedPrefill(2));
        assert_eq!(*q.policy(), AdmissionPolicy::BoundedPrefill(2));
        assert_eq!(q.poll(0.0).len(), 1);
        assert!(q.closed());
    }

    #[test]
    fn slo_queue_sheds_past_the_class_bound() {
        // bounds: 2 interactive, 1 batch — a burst of 4 + 3 sheds 2 + 2
        let reqs: Vec<GenRequest> = (1..=4)
            .map(req)
            .chain((5..=7).map(|i| req(i).with_class(SloClass::Batch)))
            .collect();
        let mut q = AdmissionQueue::new(
            Box::new(QueueSource::new(&reqs)),
            AdmissionPolicy::SloPriority(SloPolicy {
                interactive_bound: 2,
                batch_bound: 1,
                ..SloPolicy::default()
            }),
        );
        let accepted = q.poll(0.0);
        assert_eq!(accepted.len(), 3);
        assert_eq!(q.queued(SloClass::Interactive), 2);
        assert_eq!(q.queued(SloClass::Batch), 1);
        let events = q.take_events();
        assert_eq!(events.len(), 4);
        let shed_batch = events
            .iter()
            .filter(|e| matches!(e, AdmissionEvent::Shed { class: SloClass::Batch, .. }))
            .count();
        assert_eq!(shed_batch, 2);
        assert!(q.take_events().is_empty(), "events drained");
        // a dispatch frees one slot of the interactive bound
        q.on_dispatched(SloClass::Interactive);
        assert_eq!(q.queued(SloClass::Interactive), 1);
        // an expiry reject frees its class slot too
        q.on_reject(&ServeReply::Expired {
            id: 2,
            class: SloClass::Interactive,
            waited_ms: 9.0,
        });
        assert_eq!(q.queued(SloClass::Interactive), 0);
    }
}
