//! Dynamic batcher: packs incoming requests into the AOT-compiled batch
//! sizes.
//!
//! The compiled model has static shapes, so a group's batch must be one of
//! `manifest.batch_sizes` and its prompt exactly `prefill_len` tokens.
//! The batcher (a) pads/cycles prompts to the compiled prompt length,
//! (b) packs up to `max_batch` requests per group, padding the remainder
//! by replicating the first row (padding rows are dropped from results —
//! their KV/memory cost is the price of static shapes, exactly like
//! bucketing in production TPU serving).

use super::api::{GenRequest, GroupRequest};

/// Normalize one prompt to the compiled length (cycle if short, truncate
/// if long).  Shared by the batcher and the continuous-batching slot
/// scheduler so every serving mode fits prompts identically.
pub fn fit_prompt(prompt: &[i32], prompt_len: usize) -> Vec<i32> {
    assert!(!prompt.is_empty(), "empty prompt");
    (0..prompt_len).map(|i| prompt[i % prompt.len()]).collect()
}

/// Request → group packing.
#[derive(Debug, Clone)]
pub struct Batcher {
    pub prompt_len: usize,
    /// Compiled batch sizes, ascending (e.g. [1, 8]).
    pub batch_sizes: Vec<usize>,
    next_group: u64,
}

impl Batcher {
    pub fn new(prompt_len: usize, mut batch_sizes: Vec<usize>) -> Self {
        batch_sizes.sort_unstable();
        assert!(!batch_sizes.is_empty(), "need at least one batch size");
        Batcher {
            prompt_len,
            batch_sizes,
            next_group: 0,
        }
    }

    /// Smallest compiled batch ≥ n, or the largest available.
    pub fn fit_batch(&self, n: usize) -> usize {
        self.batch_sizes
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or(*self.batch_sizes.last().unwrap())
    }

    pub fn max_batch(&self) -> usize {
        *self.batch_sizes.last().unwrap()
    }

    /// Normalize one prompt to the compiled length (cycle if short).
    fn fit_prompt(&self, prompt: &[i32]) -> Vec<i32> {
        fit_prompt(prompt, self.prompt_len)
    }

    /// Pack a slice of requests into groups.  `max_new` must be uniform
    /// per group; we split on differing values to keep shapes static.
    pub fn pack(&mut self, requests: &[GenRequest]) -> Vec<GroupRequest> {
        let mut groups = Vec::new();
        let mut i = 0;
        while i < requests.len() {
            // take a run with the same max_new_tokens, up to max_batch
            let max_new = requests[i].max_new_tokens;
            let mut run = Vec::new();
            while i < requests.len()
                && requests[i].max_new_tokens == max_new
                && run.len() < self.max_batch()
            {
                run.push(&requests[i]);
                i += 1;
            }
            let batch = self.fit_batch(run.len());
            let mut tokens = Vec::with_capacity(batch * self.prompt_len);
            for r in &run {
                tokens.extend(self.fit_prompt(&r.prompt));
            }
            // pad with copies of the first prompt
            let pad_row = self.fit_prompt(&run[0].prompt);
            for _ in run.len()..batch {
                tokens.extend(&pad_row);
            }
            groups.push(GroupRequest {
                group_id: self.next_group,
                request_ids: run.iter().map(|r| r.id).collect(),
                tokens,
                batch,
                prompt_len: self.prompt_len,
                max_new_tokens: max_new,
            });
            self.next_group += 1;
        }
        groups
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize, max_new: usize) -> GenRequest {
        GenRequest::new(id, (0..len as i32).collect(), max_new)
    }

    #[test]
    fn fit_batch_rounds_up() {
        let b = Batcher::new(32, vec![8, 1]);
        assert_eq!(b.fit_batch(1), 1);
        assert_eq!(b.fit_batch(2), 8);
        assert_eq!(b.fit_batch(8), 8);
        assert_eq!(b.fit_batch(20), 8); // clamp to largest
    }

    #[test]
    fn pack_single() {
        let mut b = Batcher::new(32, vec![1, 8]);
        let g = b.pack(&[req(5, 10, 96)]);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].batch, 1);
        assert_eq!(g[0].tokens.len(), 32);
        assert_eq!(g[0].request_ids, vec![5]);
        // prompt cycled to 32 tokens
        assert_eq!(g[0].tokens[0], 0);
        assert_eq!(g[0].tokens[10], 0);
        assert_eq!(g[0].tokens[11], 1);
    }

    #[test]
    fn pack_pads_to_compiled_batch() {
        let mut b = Batcher::new(32, vec![1, 8]);
        let reqs: Vec<GenRequest> = (0..3).map(|i| req(i, 32, 16)).collect();
        let g = b.pack(&reqs);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].batch, 8);
        assert_eq!(g[0].real(), 3);
        assert_eq!(g[0].tokens.len(), 8 * 32);
    }

    #[test]
    fn pack_splits_large_runs() {
        let mut b = Batcher::new(32, vec![1, 8]);
        let reqs: Vec<GenRequest> = (0..20).map(|i| req(i, 32, 16)).collect();
        let g = b.pack(&reqs);
        assert_eq!(g.len(), 3); // 8 + 8 + 4(padded to 8)
        assert_eq!(g[0].batch, 8);
        assert_eq!(g[2].real(), 4);
        // unique group ids
        let ids: std::collections::HashSet<u64> = g.iter().map(|x| x.group_id).collect();
        assert_eq!(ids.len(), 3);
    }

    #[test]
    fn pack_splits_on_max_new() {
        let mut b = Batcher::new(32, vec![1, 8]);
        let g = b.pack(&[req(0, 32, 16), req(1, 32, 32)]);
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].max_new_tokens, 16);
        assert_eq!(g[1].max_new_tokens, 32);
    }

    #[test]
    fn long_prompt_truncated() {
        let mut b = Batcher::new(8, vec![1]);
        let g = b.pack(&[req(0, 100, 4)]);
        assert_eq!(g[0].tokens.len(), 8);
        assert_eq!(g[0].tokens, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }
}
