//! Front-door router over K pipeline replicas.
//!
//! The [`crate::planner::ReplicaPlanner`] decides *how many* pipelines to
//! run and over which devices; this module runs them.  One shared
//! [`RequestSource`] (trace replay, live TCP channel, closed-loop queue)
//! feeds a [`Router`], which scores every arrival onto a replica —
//! least-outstanding-work first, with **session affinity**: a multi-turn
//! request carries [`crate::coordinator::GenRequest::session`] and is
//! pinned to the replica whose pipeline already holds that session's KV
//! rows.  Each replica then runs the *existing*
//! [`drive_slots`](super::driver::drive_slots) loop in its own thread,
//! over its own [`Engine`], behind its own [`AdmissionQueue`] (so
//! SLO-class bounds and shedding stay per-replica).
//!
//! **Cross-replica failover.**  Every assignment is remembered until it
//! resolves (result or reject).  When a replica dies — its drive loop
//! returns an error, here simulated with an abort hook killable
//! per-replica — the router immediately re-enters its queued *and*
//! in-flight requests into routing ([`Router::kill`] /
//! [`drive_replicated`]'s death path), keeping their original arrival
//! stamps so the recovery window shows up in TTFT.  Requests are
//! deduplicated by id at the result boundary, so a request that was
//! racing through a dying pipeline while its reroute finished elsewhere
//! is still answered exactly once (token streams are position-encoded
//! and byte-identical on every replica, so either copy is correct).  An
//! optional respawn factory may rebuild the dead replica (typically via
//! [`crate::planner::ReplicaPlanner::plan_subset`] over its surviving
//! devices) and re-enter it into rotation — the rebalance lifecycle.
//!
//! Router decisions surface as trace instants: `route_assign` (every
//! placement, reroutes included), `replica_drain` (death: how many
//! requests re-entered routing), `replica_rebalance` (respawn).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::admission::{AdmissionPolicy, AdmissionQueue, ArrivedRequest, RequestSource};
use super::api::{GenResult, ServeReply};
use super::driver::{DriveHooks, DriveView};
use super::engine::{Engine, EngineStats, Wired};
use super::scheduler::ContinuousConfig;
use crate::obs::{MetricsRegistry, Tracer};

/// How [`drive_replicated`] runs its fleet.
pub struct RouterConfig {
    /// Admission policy instantiated per replica (bounds and shedding
    /// are per-replica, matching its own capacity).
    pub policy: AdmissionPolicy,
    /// Pin sessions to the replica that first served them.
    pub affinity: bool,
    /// Tracer for router instants (`route_assign`, `replica_drain`,
    /// `replica_rebalance`).
    pub trace: Tracer,
    /// Per-replica metrics registries; index r is installed on replica
    /// r's engine (empty = keep whatever the engines carry).
    pub metrics: Vec<MetricsRegistry>,
    /// Deterministic kill switches: `(replica, token_budget)` — replica
    /// r aborts its drive after producing `token_budget` folded token
    /// frames.  Used by failover tests and the capacity bench.
    pub kill_after_tokens: Vec<(usize, u64)>,
    /// Rebuild a dead replica: called with the replica index after its
    /// requests were rerouted; returning an engine re-enters the replica
    /// into rotation (`replica_rebalance`).
    pub respawn: Option<RespawnFn>,
}

/// Factory that rebuilds a dead replica's engine (e.g. re-planning its
/// surviving devices with [`crate::planner::ReplicaPlanner::plan_subset`]).
pub type RespawnFn = Box<dyn Fn(usize) -> Option<Engine> + Send + Sync>;

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            policy: AdmissionPolicy::Fifo,
            affinity: true,
            trace: Tracer::default(),
            metrics: Vec::new(),
            kill_after_tokens: Vec::new(),
            respawn: None,
        }
    }
}

/// What one replica did over the whole run.
#[derive(Debug)]
pub struct ReplicaOutcome {
    pub replica: usize,
    /// Stats of the replica's final, successfully completed drive
    /// (`None` if it died and was not respawned).
    pub stats: Option<EngineStats>,
    /// Times this replica's drive loop died.
    pub deaths: u32,
    /// Requests this replica resolved with a result.
    pub served: u64,
}

/// Everything [`drive_replicated`] hands back.
#[derive(Debug)]
pub struct ReplicatedOutcome {
    /// One result per served request, deduplicated by id, sorted by id.
    pub results: Vec<GenResult>,
    pub replicas: Vec<ReplicaOutcome>,
    /// Every placement in order, `(request id, replica)` — reroutes
    /// append a second entry for the same id.
    pub assignments: Vec<(u64, usize)>,
    /// Requests left unresolved because every replica was dead.
    pub stranded: usize,
}

/// Router state shared by every replica's [`RouterSource`].
struct Shared {
    front: Box<dyn RequestSource>,
    /// Assigned but not yet handed to the replica's admission queue.
    pending: Vec<VecDeque<ArrivedRequest>>,
    /// Handed to the replica (queued or in flight), awaiting resolution.
    outstanding: Vec<HashMap<u64, ArrivedRequest>>,
    /// Σ max_new_tokens over pending + outstanding — the routing score.
    work: Vec<f64>,
    /// session id → pinned replica.
    affinity: HashMap<u64, usize>,
    alive: Vec<bool>,
    /// Requests answered (result or reject) — the exactly-once boundary.
    resolved: HashSet<u64>,
    results: Vec<GenResult>,
    served_by: Vec<u64>,
    assignments: Vec<(u64, usize)>,
    /// Assigned and not yet resolved, across all replicas.
    unresolved: usize,
    /// Orphans with no live replica to go to.
    stranded: Vec<ArrivedRequest>,
    use_affinity: bool,
    trace: Tracer,
}

impl Shared {
    /// Route one request: affinity pin if live, else least outstanding
    /// work (ties: fewest requests, lowest index).  `None` if no replica
    /// is alive.
    fn place(&mut self, a: ArrivedRequest, count_new: bool) {
        let n = self.pending.len();
        let mut choice: Option<usize> = None;
        if self.use_affinity {
            if let Some(s) = a.req.session {
                match self.affinity.get(&s) {
                    Some(&r) if self.alive[r] => choice = Some(r),
                    _ => {}
                }
            }
        }
        if choice.is_none() {
            let mut best_key = (f64::INFINITY, usize::MAX);
            for r in 0..n {
                if !self.alive[r] {
                    continue;
                }
                let key = (self.work[r], self.pending[r].len() + self.outstanding[r].len());
                let better = match choice {
                    None => true,
                    Some(_) => key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1),
                };
                if better {
                    choice = Some(r);
                    best_key = key;
                }
            }
        }
        if count_new {
            self.unresolved += 1;
        }
        let Some(r) = choice else {
            self.stranded.push(a);
            return;
        };
        if self.use_affinity {
            if let Some(s) = a.req.session {
                self.affinity.insert(s, r);
            }
        }
        let id = a.req.id;
        self.work[r] += a.req.max_new_tokens as f64;
        self.assignments.push((id, r));
        self.pending[r].push_back(a);
        self.trace
            .instant("route_assign", || format!("req={id} replica={r}"));
    }

    /// A request of replica `r` resolved (result or reject).  Returns
    /// `true` the first time this id resolves.
    fn resolve(&mut self, r: usize, id: u64) -> bool {
        if let Some(a) = self.outstanding[r].remove(&id) {
            self.work[r] -= a.req.max_new_tokens as f64;
        }
        if !self.resolved.insert(id) {
            return false;
        }
        self.unresolved -= 1;
        true
    }
}

/// Shared front door: clones are handles onto the same routing state.
#[derive(Clone)]
pub struct Router {
    shared: Arc<Mutex<Shared>>,
    kill_flags: Vec<Arc<AtomicBool>>,
}

impl Router {
    /// A router over `n_replicas` fed by `front`.
    pub fn new(
        front: Box<dyn RequestSource>,
        n_replicas: usize,
        affinity: bool,
        trace: Tracer,
    ) -> Self {
        assert!(n_replicas >= 1, "router needs at least one replica");
        Router {
            shared: Arc::new(Mutex::new(Shared {
                front,
                pending: vec![VecDeque::new(); n_replicas],
                outstanding: vec![HashMap::new(); n_replicas],
                work: vec![0.0; n_replicas],
                affinity: HashMap::new(),
                alive: vec![true; n_replicas],
                resolved: HashSet::new(),
                results: Vec::new(),
                served_by: vec![0; n_replicas],
                assignments: Vec::new(),
                unresolved: 0,
                stranded: Vec::new(),
                use_affinity: affinity,
                trace,
            })),
            kill_flags: (0..n_replicas).map(|_| Arc::new(AtomicBool::new(false))).collect(),
        }
    }

    /// The per-replica [`RequestSource`] to put behind replica `r`'s
    /// [`AdmissionQueue`].
    pub fn source(&self, replica: usize) -> RouterSource {
        RouterSource {
            shared: Arc::clone(&self.shared),
            replica,
        }
    }

    /// Kill replica `r`: its queued and in-flight requests re-enter
    /// routing immediately and its drive loop aborts at the next token
    /// (via [`Router::abort_hooks`]).  Idempotent.
    pub fn kill(&self, replica: usize) {
        self.kill_flags[replica].store(true, Ordering::SeqCst);
        self.drain_dead(replica);
    }

    /// Mark `r` dead and reroute everything it owned.  Called by
    /// [`Router::kill`] and by [`drive_replicated`] when a drive loop
    /// dies on its own.  Idempotent.
    pub fn drain_dead(&self, replica: usize) {
        let mut sh = self.shared.lock().unwrap();
        if !sh.alive[replica] {
            return;
        }
        sh.alive[replica] = false;
        let mut orphans: Vec<ArrivedRequest> = sh.pending[replica].drain(..).collect();
        orphans.extend(sh.outstanding[replica].drain().map(|(_, a)| a));
        sh.work[replica] = 0.0;
        sh.affinity.retain(|_, r| *r != replica);
        let n = orphans.len();
        sh.trace
            .instant("replica_drain", || format!("replica={replica} rerouted={n}"));
        for a in orphans {
            if sh.resolved.contains(&a.req.id) {
                continue;
            }
            sh.place(a, false);
        }
    }

    /// Re-enter a respawned replica into rotation and hand it any
    /// stranded requests.
    pub fn revive(&self, replica: usize) {
        self.kill_flags[replica].store(false, Ordering::SeqCst);
        let mut sh = self.shared.lock().unwrap();
        sh.alive[replica] = true;
        sh.trace
            .instant("replica_rebalance", || format!("replica={replica} revived"));
        let stranded = std::mem::take(&mut sh.stranded);
        for a in stranded {
            sh.place(a, false);
        }
    }

    /// Abort hooks for replica `r`'s drive: trip on [`Router::kill`] or
    /// after a deterministic token budget.
    pub fn abort_hooks(&self, replica: usize, kill_after_tokens: Option<u64>) -> AbortHooks {
        AbortHooks {
            router: self.clone(),
            replica,
            flag: Arc::clone(&self.kill_flags[replica]),
            budget: kill_after_tokens,
        }
    }

    fn killed(&self, replica: usize) -> bool {
        self.kill_flags[replica].load(Ordering::SeqCst)
    }

    /// Results so far (insertion order), deduplicated by id.
    pub fn results(&self) -> Vec<GenResult> {
        self.shared.lock().unwrap().results.clone()
    }

    /// Every placement in order, `(request id, replica)`.
    pub fn assignments(&self) -> Vec<(u64, usize)> {
        self.shared.lock().unwrap().assignments.clone()
    }

    fn served_by(&self, replica: usize) -> u64 {
        self.shared.lock().unwrap().served_by[replica]
    }

    fn stranded(&self) -> usize {
        self.shared.lock().unwrap().stranded.len()
    }
}

/// Replica r's view of the shared router — a [`RequestSource`] that
/// pumps the front door and drains its own assignment queue.
pub struct RouterSource {
    shared: Arc<Mutex<Shared>>,
    replica: usize,
}

impl RequestSource for RouterSource {
    fn poll(&mut self, now_ms: f64) -> Vec<ArrivedRequest> {
        let mut sh = self.shared.lock().unwrap();
        let arrivals = sh.front.poll(now_ms);
        for a in arrivals {
            sh.place(a, true);
        }
        if !sh.alive[self.replica] {
            return Vec::new();
        }
        let mine: Vec<ArrivedRequest> = sh.pending[self.replica].drain(..).collect();
        for a in &mine {
            sh.outstanding[self.replica].insert(a.req.id, a.clone());
        }
        mine
    }

    fn next_arrival_ms(&self) -> Option<f64> {
        let sh = self.shared.lock().unwrap();
        if !sh.pending[self.replica].is_empty() {
            // work already assigned: poll immediately
            return Some(0.0);
        }
        sh.front.next_arrival_ms()
    }

    fn closed(&self) -> bool {
        let sh = self.shared.lock().unwrap();
        if !sh.alive[self.replica] {
            return true;
        }
        sh.front.closed() && sh.unresolved == 0
    }

    fn on_result(&mut self, result: &GenResult) {
        let mut sh = self.shared.lock().unwrap();
        if !sh.resolve(self.replica, result.id) {
            return; // late duplicate from a drained replica
        }
        sh.served_by[self.replica] += 1;
        sh.results.push(result.clone());
        sh.front.on_result(result);
    }

    fn on_reject(&mut self, reply: &ServeReply) {
        let mut sh = self.shared.lock().unwrap();
        if !sh.resolve(self.replica, reply.id()) {
            return;
        }
        sh.front.on_reject(reply);
    }

    fn wait(&mut self, timeout: Duration) {
        // Sleep in short slices *outside* the lock: another replica's
        // poll may route work to us meanwhile, and the front door is
        // shared — blocking inside it would stall the whole fleet.
        let deadline = Instant::now() + timeout;
        loop {
            {
                let sh = self.shared.lock().unwrap();
                if !sh.pending[self.replica].is_empty() || !sh.alive[self.replica] {
                    return;
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return;
            }
            std::thread::sleep((deadline - now).min(Duration::from_millis(2)));
        }
    }
}

/// Drive hooks that abort a replica's drive loop when its kill flag
/// trips — externally via [`Router::kill`], or on its own after a
/// deterministic token budget (the failover tests' kill switch).
pub struct AbortHooks {
    router: Router,
    replica: usize,
    flag: Arc<AtomicBool>,
    budget: Option<u64>,
}

impl DriveHooks for AbortHooks {
    fn wants_view(&mut self, received: u64) -> bool {
        if let Some(b) = self.budget {
            if received >= b && !self.flag.load(Ordering::SeqCst) {
                // reroutes this replica's work, then trips our flag
                self.router.kill(self.replica);
            }
        }
        self.flag.load(Ordering::SeqCst)
    }

    fn after_token(&mut self, _wired: &Wired, _view: &DriveView) -> Result<bool> {
        // only reached when the flag is set (wants_view gates the call)
        anyhow::bail!("replica {} killed", self.replica)
    }
}

/// Run `engines` as pipeline replicas behind one router fed by `front`.
///
/// Each replica runs [`Engine::generate_from_source_hooked`] in its own
/// thread over its own [`AdmissionQueue`] (policy cloned from
/// `cfg.policy`).  A replica whose drive dies has its requests rerouted
/// to survivors; with `cfg.respawn` it may then be rebuilt and revived.
/// Returns once every replica's drive loop has exited — i.e. the front
/// source is closed and every accepted request was resolved (or no
/// replica is left to resolve it).
pub fn drive_replicated(
    engines: Vec<Engine>,
    front: Box<dyn RequestSource>,
    ccfg: &ContinuousConfig,
    cfg: &RouterConfig,
) -> Result<ReplicatedOutcome> {
    let n = engines.len();
    anyhow::ensure!(n >= 1, "drive_replicated needs at least one engine");
    let router = Router::new(front, n, cfg.affinity, cfg.trace.clone());
    let budgets: Vec<Option<u64>> = (0..n)
        .map(|r| {
            cfg.kill_after_tokens
                .iter()
                .find(|(kr, _)| *kr == r)
                .map(|(_, b)| *b)
        })
        .collect();
    let respawn = &cfg.respawn;
    let mut outcomes: Vec<ReplicaOutcome> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(n);
        for (r, mut engine) in engines.into_iter().enumerate() {
            if let Some(m) = cfg.metrics.get(r) {
                engine.set_metrics(m);
            }
            let router = router.clone();
            let policy = cfg.policy.clone();
            let budget = budgets[r];
            handles.push(s.spawn(move || {
                let mut deaths = 0u32;
                let mut stats = None;
                let mut engine_opt = Some(engine);
                while let Some(mut engine) = engine_opt.take() {
                    let mut queue =
                        AdmissionQueue::new(Box::new(router.source(r)), policy.clone());
                    // budget applies to the first life only — a respawned
                    // replica is not re-killed
                    let budget = if deaths == 0 { budget } else { None };
                    let mut hooks = router.abort_hooks(r, budget);
                    match engine.generate_from_source_hooked(&mut queue, ccfg, &mut hooks) {
                        Ok((_, st)) => {
                            stats = Some(st);
                            let _ = engine.shutdown();
                            if router.killed(r) {
                                // killed while idle: nothing was lost, but
                                // make sure the replica is out of rotation
                                router.drain_dead(r);
                            }
                        }
                        Err(_) => {
                            deaths += 1;
                            drop(queue);
                            let _ = engine.shutdown();
                            router.drain_dead(r);
                            if let Some(f) = respawn {
                                if let Some(fresh) = f(r) {
                                    router.revive(r);
                                    engine_opt = Some(fresh);
                                }
                            }
                        }
                    }
                }
                ReplicaOutcome {
                    replica: r,
                    stats,
                    deaths,
                    served: router.served_by(r),
                }
            }));
        }
        for h in handles {
            match h.join() {
                Ok(o) => outcomes.push(o),
                Err(_) => outcomes.push(ReplicaOutcome {
                    replica: outcomes.len(),
                    stats: None,
                    deaths: 1,
                    served: 0,
                }),
            }
        }
    });
    let mut results = router.results();
    results.sort_by_key(|r| r.id);
    Ok(ReplicatedOutcome {
        results,
        replicas: outcomes,
        assignments: router.assignments(),
        stranded: router.stranded(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::QueueSource;
    use crate::coordinator::api::GenRequest;

    fn reqs(n: u64) -> Vec<GenRequest> {
        (1..=n).map(|i| GenRequest::new(i, vec![1, 2, 3], 8)).collect()
    }

    #[test]
    fn least_loaded_placement_balances() {
        let front = Box::new(QueueSource::new(&reqs(6)));
        let router = Router::new(front, 3, true, Tracer::default());
        let mut s0 = router.source(0);
        let got = s0.poll(0.0);
        // all six arrive at once; least-work routing deals them 2-2-2
        assert_eq!(got.len(), 2, "replica 0 should get a third of the burst");
        let mut s1 = router.source(1);
        let mut s2 = router.source(2);
        assert_eq!(s1.poll(0.0).len(), 2);
        assert_eq!(s2.poll(0.0).len(), 2);
    }

    #[test]
    fn affinity_pins_sessions() {
        let rs: Vec<GenRequest> = (1..=4u64)
            .map(|i| GenRequest::new(i, vec![1], 8).with_session(7))
            .collect();
        let front = Box::new(QueueSource::new(&rs));
        let router = Router::new(front, 2, true, Tracer::default());
        let mut s0 = router.source(0);
        let mut s1 = router.source(1);
        let a = s0.poll(0.0).len() + s1.poll(0.0).len();
        assert_eq!(a, 4);
        let by_replica: HashSet<usize> =
            router.assignments().iter().map(|&(_, r)| r).collect();
        assert_eq!(by_replica.len(), 1, "one session must stay on one replica");
    }

    #[test]
    fn drain_dead_reroutes_pending_and_outstanding() {
        let front = Box::new(QueueSource::new(&reqs(4)));
        let router = Router::new(front, 2, false, Tracer::default());
        let mut s0 = router.source(0);
        let mut s1 = router.source(1);
        let mine0 = s0.poll(0.0); // 0's share moves to outstanding
        assert!(!mine0.is_empty());
        router.kill(0);
        // everything replica 0 owned is re-assigned to replica 1
        let mine1 = s1.poll(0.0);
        assert_eq!(mine1.len(), 4, "survivor owns the whole queue");
        assert!(s0.closed(), "dead replica's source reports closed");
        // resolve all on replica 1 → router closes for everyone
        for a in &mine1 {
            s1.on_result(&GenResult {
                id: a.req.id,
                tokens: vec![1],
                ttft_ms: 1.0,
                total_ms: 2.0,
            });
        }
        assert!(s1.closed());
        assert_eq!(router.results().len(), 4);
    }

    #[test]
    fn duplicate_results_resolve_once() {
        let front = Box::new(QueueSource::new(&reqs(1)));
        let router = Router::new(front, 2, false, Tracer::default());
        let mut s0 = router.source(0);
        let mut s1 = router.source(1);
        let got = s0.poll(0.0);
        assert_eq!(got.len(), 1);
        router.kill(0); // reroutes req 1 to replica 1
        let got1 = s1.poll(0.0);
        assert_eq!(got1.len(), 1);
        let res = GenResult {
            id: 1,
            tokens: vec![5],
            ttft_ms: 1.0,
            total_ms: 2.0,
        };
        s0.on_result(&res); // late completion from the dying pipeline
        s1.on_result(&res);
        assert_eq!(router.results().len(), 1, "exactly one answer per id");
        assert!(s1.closed());
    }
}
