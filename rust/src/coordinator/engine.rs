//! Collaborative-inference engine: wires stage actors per a plan and
//! drives generation.
//!
//! * **Sequential inference** (paper Fig. 4a): one group in flight —
//!   [`Engine::generate_sequential`].
//! * **Pipelined inference** (paper Fig. 5): several micro-batch groups in
//!   flight; the driver releases a group's next iteration either
//!   immediately when its token returns (**No-bubble**) or after every
//!   group finishes the current iteration (**Bubble**) —
//!   [`Engine::generate_pipelined`].
//! * **Continuous batching** (vLLM/Orca-style iteration-level
//!   scheduling): requests are admitted into compiled batch slots and
//!   retired per-row every iteration — [`Engine::generate_continuous`],
//!   policy in [`super::scheduler`], drive loop in [`super::driver`].
//!
//! All modes run through the one shared generation driver in
//! [`super::driver`] — the same loop the adaptive engine interposes its
//! migration barrier on.
//!
//! All activations move through [`crate::netsim`] shaped links with the
//! cluster's per-pair bandwidth/latency, so the real numerics experience
//! the same network the planner optimized for.

use anyhow::{Context, Result};
use std::sync::mpsc::{Receiver, Sender};

use super::admission::AdmissionQueue;
use super::api::{GenRequest, GenResult, GroupRequest};
use super::driver::{drive_groups, drive_slots, DriveHooks, DriverCfg, NoHooks};
use super::kvcache::{
    GroupCache, KvLayout, KvPool, PagedPool, ELEM_BYTES_F32, PAGED_MAX_POOL_POSITIONS,
};
use super::scheduler::ContinuousConfig;
use super::stage::{stage_decoders, NextHop, StageActor, StageMsg, TokenMsg, WireFormat};
use crate::cluster::Cluster;
use crate::metrics::{ComputeObs, Histogram};
use crate::netsim::{
    shaped_channel_live, LinkSpec, LiveLink, RoutedLink, ShapedSender, TransferObs,
};
use crate::pipeline::Strategy;
use crate::planner::Plan;
use crate::runtime::manifest::Manifest;
use crate::runtime::{ExecServiceHandle, WeightStore};

/// Engine construction parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Compression factor for simulated link delays (1.0 = real time).
    pub time_scale: f64,
    /// Per-device compute slowdown factors (index = device id); empty =
    /// run everything at raw CPU speed.
    pub compute_scale: Vec<f64>,
    /// KV budget per stage, bytes (generous default for the tiny model).
    pub kv_budget_bytes: u64,
    /// KV cache layout — padded worst-case slabs (default) or the
    /// block-granular paged pool.  Token streams are byte-identical
    /// either way; what changes is how capacity is charged.
    pub kv_layout: KvLayout,
    /// Encoding of inter-stage activation frames.  [`WireFormat::F32`]
    /// (default) is byte-identical to the historical wire;
    /// [`WireFormat::Int8`] quantizes hidden states with per-row scales,
    /// shrinking every activation frame ~4× on the shaped links.
    pub wire_format: WireFormat,
    /// Chunked prefill: split each prompt into chunks of at most this
    /// many tokens and stream them through the pipeline as successive
    /// partial frames, so stage *i+1* computes chunk *k* while stage *i*
    /// computes chunk *k+1*.  `0` (default) = monolithic prefill.
    pub prefill_chunk: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            time_scale: 1.0,
            compute_scale: Vec::new(),
            kv_budget_bytes: 1 << 30,
            kv_layout: KvLayout::default(),
            wire_format: WireFormat::F32,
            prefill_chunk: 0,
        }
    }
}

/// Aggregate serving statistics of one engine run.
#[derive(Debug)]
pub struct EngineStats {
    pub makespan_ms: f64,
    /// Real (non-padding) tokens generated.
    pub tokens: u64,
    pub throughput_tps: f64,
    /// Time-to-first-token, one sample per real request, measured from
    /// the request's arrival (drive start for closed-loop serving; queue
    /// wait included — the client-observed number).
    pub ttft: Histogram,
    /// Per-iteration latency samples (decode steps only; the first token
    /// of a group is TTFT, not an inter-token gap).
    pub iter_latency: Histogram,
    /// Admission-queue wait per request (arrival → batch-1 prefill
    /// dispatch; continuous serving only — empty in group modes).
    pub queue_delay: Histogram,
    /// Real rows / total rows over every frame sent: 1.0 = no compute or
    /// KV spent on padding rows or dead slots.
    pub padding_efficiency: f64,
    /// Arrivals shed at their class bound (`[interactive, batch]`; SLO
    /// admission policy only — always zero otherwise).
    pub shed: [u64; 2],
    /// Queued requests dropped at their TTFT deadline before a prefill
    /// was dispatched (`[interactive, batch]`).
    pub expired: [u64; 2],
    /// Highest arrived-not-yet-dispatched queue depth observed during the
    /// drive — bounded by the class bounds under the SLO policy.
    pub peak_queue_depth: usize,
    /// Highest number of sequences simultaneously holding KV (prefilling
    /// + active rows across runs; continuous serving only).  Under a tight
    /// budget this is the concurrency the layout actually achieved —
    /// paged serving's headline win over padded worst-case admission.
    pub peak_live_rows: usize,
}

impl From<super::driver::DriveStats> for EngineStats {
    fn from(d: super::driver::DriveStats) -> Self {
        EngineStats {
            makespan_ms: d.makespan_ms,
            tokens: d.tokens,
            throughput_tps: d.throughput_tps,
            ttft: d.ttft,
            iter_latency: d.iter_latency,
            queue_delay: d.queue_delay,
            padding_efficiency: d.padding_efficiency,
            shed: d.shed,
            expired: d.expired,
            peak_queue_depth: d.peak_queue_depth,
            peak_live_rows: d.peak_live_rows,
        }
    }
}

/// Observation sinks threaded into a wired pipeline — taps on stage
/// compute and link transfers.  Each observation fans out to *every*
/// sender, so the adaptive monitor and the tracer can listen to the same
/// streams independently (both obs types are `Copy`).
#[derive(Clone, Default)]
pub struct ObsSinks {
    pub compute: Vec<Sender<ComputeObs>>,
    pub transfer: Vec<Sender<TransferObs>>,
    /// Tracer handed to each stage actor for `wire_compress` /
    /// `chunk_flush` instants and per-hop `wire_bytes_sent` counters
    /// (`Tracer::off()` by default — zero cost).
    pub tracer: crate::obs::Tracer,
}

impl ObsSinks {
    /// Add the tracer's taps (no-op when the tracer is off).
    pub fn add_tracer(&mut self, tracer: &crate::obs::Tracer) {
        if let Some(tx) = tracer.compute_sink() {
            self.compute.push(tx);
        }
        if let Some(tx) = tracer.transfer_sink() {
            self.transfer.push(tx);
        }
        self.tracer = tracer.clone();
    }
}

/// A fully wired pipeline: stage actor threads connected by live shaped
/// links.  [`Engine`] wraps one for static serving; the adaptive engine
/// drives (and at migration, rebuilds) one directly.
pub struct Wired {
    pub to_first: ShapedSender<StageMsg>,
    pub token_rx: Receiver<TokenMsg>,
    pub handles: Vec<std::thread::JoinHandle<Result<()>>>,
    /// Inter-device links in use: one ingress link per stage > 0 plus the
    /// token loopback.  Live — re-shaping them affects in-flight traffic.
    pub links: Vec<RoutedLink>,
}

/// Build stage actors for `plan` over `cluster` and connect them with
/// live shaped links.
///
/// `preloads[i]` seeds stage *i*'s KV pool (migration hand-off); shorter
/// or empty vectors mean no preload.  `obs` taps every stage and link
/// for the adaptive monitor.  `liveness` is the shared ground-truth
/// device-churn state (see [`crate::cluster::DeviceLiveness`]): when set,
/// a stage whose device is flagged dead drops every frame it receives.
#[allow(clippy::too_many_arguments)]
pub fn wire(
    manifest: &Manifest,
    weights: &WeightStore,
    exec: ExecServiceHandle,
    plan: &Plan,
    cluster: &Cluster,
    cfg: &EngineConfig,
    obs: Option<&ObsSinks>,
    liveness: Option<&crate::cluster::DeviceLiveness>,
    mut preloads: Vec<Vec<(u64, GroupCache)>>,
) -> Result<Wired> {
    let n_model_layers = manifest.config.n_layers + 2;
    anyhow::ensure!(
        plan.stages.last().map(|s| s.end) == Some(n_model_layers),
        "plan covers {:?} layers, model has {n_model_layers}",
        plan.stages.last().map(|s| s.end)
    );
    let s_count = plan.n_stages();
    let mut links = Vec::new();
    let transfer_txs: Vec<Sender<TransferObs>> =
        obs.map(|o| o.transfer.clone()).unwrap_or_default();

    // token loopback: head device -> source
    let head_dev = plan.stages.last().unwrap().device;
    let loop_link = LiveLink::new(cluster.link(head_dev, cluster.source));
    links.push(RoutedLink {
        from: head_dev,
        to: cluster.source,
        link: loop_link.clone(),
    });
    let (token_tx, token_rx) = shaped_channel_live::<TokenMsg>(
        loop_link,
        cfg.time_scale,
        (head_dev, cluster.source),
        transfer_txs.clone(),
    );

    // per-stage ingress links: stage i receives over the link
    // (stage i-1's device) → (stage i's device); stage 0 receives from
    // the driver, which lives on the source device (free link).
    let mut receivers: Vec<Option<Receiver<StageMsg>>> = (0..s_count).map(|_| None).collect();
    let mut senders: Vec<Option<ShapedSender<StageMsg>>> = (0..s_count).map(|_| None).collect();
    for i in 0..s_count {
        let (route, spec) = if i == 0 {
            (
                (cluster.source, cluster.source),
                LinkSpec::new(f64::INFINITY, 0.0),
            )
        } else {
            let prev = plan.stages[i - 1].device;
            let dev = plan.stages[i].device;
            ((prev, dev), cluster.link(prev, dev))
        };
        let live = LiveLink::new(spec);
        if i > 0 {
            links.push(RoutedLink {
                from: route.0,
                to: route.1,
                link: live.clone(),
            });
        }
        let (tx, rx) = shaped_channel_live::<StageMsg>(
            live,
            cfg.time_scale,
            route,
            if i > 0 { transfer_txs.clone() } else { Vec::new() },
        );
        receivers[i] = Some(rx);
        senders[i] = Some(tx);
    }

    // spawn actors front to back, threading the "next" hops
    let mut handles = Vec::with_capacity(s_count);
    for (i, st) in plan.stages.iter().enumerate() {
        let next = if i + 1 < s_count {
            NextHop::Stage(senders[i + 1].clone().unwrap())
        } else {
            NextHop::Driver(token_tx.clone())
        };
        let pre = if i < preloads.len() {
            std::mem::take(&mut preloads[i])
        } else {
            Vec::new()
        };
        let mut actor = StageActor::new(
            i,
            st.device,
            manifest,
            weights,
            st.start..st.end,
            n_model_layers,
            exec.clone(),
            cfg.kv_budget_bytes,
            cfg.kv_layout,
            next,
            pre,
        )?;
        actor.compute_scale = cfg.compute_scale.get(st.device).copied().unwrap_or(1.0);
        actor.obs = obs.map(|o| o.compute.clone()).unwrap_or_default();
        actor.liveness = liveness.cloned();
        actor.wire = cfg.wire_format;
        actor.trace = obs.map(|o| o.tracer.clone()).unwrap_or_default();
        let rx = receivers[i].take().unwrap();
        handles.push(
            std::thread::Builder::new()
                .name(format!("stage-{i}"))
                .spawn(move || actor.run(rx))
                .context("spawning stage")?,
        );
    }

    Ok(Wired {
        to_first: senders[0].clone().unwrap(),
        token_rx,
        handles,
        links,
    })
}

/// The compiled-shape + budget contract the generation driver enforces,
/// derived from the manifest and the plan's heaviest stage.
pub fn driver_cfg(manifest: &Manifest, plan: &Plan, cfg: &EngineConfig) -> DriverCfg {
    let c = &manifest.config;
    let n_model_layers = c.n_layers + 2;
    let row_bytes_worst = plan
        .stages
        .iter()
        .map(|s| {
            let n_local = stage_decoders(&(s.start..s.end), n_model_layers).len();
            KvPool::group_bytes(n_local, 1, c.n_kv_heads, c.max_seq, c.head_dim(), ELEM_BYTES_F32)
        })
        .max()
        .unwrap_or(0);
    // Paged serving: every stage allocates the same *count* of blocks, so
    // the schedulable pool is the tightest stage's — the one whose
    // per-block bytes (∝ local layer count) divide the budget fewest
    // times.  Clamped by PAGED_MAX_POOL_POSITIONS exactly as each stage
    // clamps its own slab allocation, so the scheduler's view of the
    // pool never exceeds what the stages actually built.
    let paged = cfg.kv_layout.block_size().map(|block_size| {
        let pool_blocks = plan
            .stages
            .iter()
            .filter_map(|s| {
                let n_local = stage_decoders(&(s.start..s.end), n_model_layers).len();
                (n_local > 0).then(|| {
                    let bb = PagedPool::block_bytes_for(
                        n_local,
                        c.n_kv_heads,
                        block_size,
                        c.head_dim(),
                    );
                    ((cfg.kv_budget_bytes / bb) as usize)
                        .min(PAGED_MAX_POOL_POSITIONS / block_size)
                })
            })
            .min()
            .unwrap_or(0);
        super::driver::PagedCfg {
            block_size,
            pool_blocks,
        }
    });
    DriverCfg {
        prompt_len: c.prefill_len,
        prefill_chunk: cfg.prefill_chunk,
        batch_sizes: manifest.batch_sizes.clone(),
        max_seq: c.max_seq,
        kv_budget_bytes: cfg.kv_budget_bytes,
        row_bytes_worst,
        paged,
        trace: crate::obs::Tracer::off(),
        metrics: crate::obs::MetricsRegistry::off(),
    }
}

/// The wired pipeline.
pub struct Engine {
    wired: Wired,
    driver_cfg: DriverCfg,
}

impl Engine {
    /// Build stage actors for `plan` over `cluster` and connect them with
    /// shaped links.
    pub fn build(
        manifest: &Manifest,
        weights: &WeightStore,
        exec: ExecServiceHandle,
        plan: &Plan,
        cluster: &Cluster,
        cfg: &EngineConfig,
    ) -> Result<Self> {
        Self::build_traced(
            manifest,
            weights,
            exec,
            plan,
            cluster,
            cfg,
            &crate::obs::Tracer::off(),
        )
    }

    /// Build with a [`crate::obs::Tracer`] tapping every stage and link,
    /// and recording lifecycle/step spans in the drive loop.  With
    /// `Tracer::off()` this is exactly [`Engine::build`].
    pub fn build_traced(
        manifest: &Manifest,
        weights: &WeightStore,
        exec: ExecServiceHandle,
        plan: &Plan,
        cluster: &Cluster,
        cfg: &EngineConfig,
        tracer: &crate::obs::Tracer,
    ) -> Result<Self> {
        let mut sinks = ObsSinks::default();
        sinks.add_tracer(tracer);
        let obs = if tracer.is_on() { Some(&sinks) } else { None };
        let wired = wire(manifest, weights, exec, plan, cluster, cfg, obs, None, Vec::new())?;
        let mut dc = driver_cfg(manifest, plan, cfg);
        dc.trace = tracer.clone();
        Ok(Engine { wired, driver_cfg: dc })
    }

    /// Attach a live [`crate::obs::MetricsRegistry`] that the drive loop
    /// updates (tokens, TTFT, queue delay, queue depth, KV bytes).
    pub fn set_metrics(&mut self, metrics: &crate::obs::MetricsRegistry) {
        self.driver_cfg.metrics = metrics.clone();
    }

    /// The live inter-device links this engine's traffic flows over
    /// (loopback first).  Re-shaping them — e.g. from a
    /// [`crate::adaptive::dynamics::DynamicsDriver`] — affects in-flight
    /// frames, which is exactly how the network-drop scenarios degrade a
    /// running static engine.
    pub fn routed_links(&self) -> Vec<RoutedLink> {
        self.wired.links.clone()
    }

    /// Largest compiled batch size.
    pub fn max_batch(&self) -> usize {
        self.driver_cfg.batch_sizes.iter().copied().max().unwrap_or(1)
    }

    /// Serve groups one at a time (paper's sequential inference).
    pub fn generate_sequential(
        &mut self,
        groups: &[GroupRequest],
    ) -> Result<(Vec<GenResult>, EngineStats)> {
        self.run(groups, 1, Strategy::NoBubble)
    }

    /// Serve all groups as a micro-batched pipeline.
    pub fn generate_pipelined(
        &mut self,
        groups: &[GroupRequest],
        strategy: Strategy,
    ) -> Result<(Vec<GenResult>, EngineStats)> {
        self.run(groups, groups.len().max(1), Strategy::from_pipeline(strategy))
    }

    /// Serve a fixed request queue with **continuous batching**:
    /// iteration-level admission into compiled batch slots, per-row
    /// retirement and KV accounting, batch recomposition between
    /// iterations.  Requests need no pre-packing (the slot scheduler
    /// replaces the batcher); token streams are byte-identical to
    /// sequential serving.  This is the closed-loop degenerate case of
    /// [`Engine::generate_from_source`] — everything arrives at t = 0.
    ///
    /// Requires a backend with per-row-position decode support (the sim
    /// backend has it; PJRT artifacts need recompiled decode variants).
    pub fn generate_continuous(
        &mut self,
        requests: &[GenRequest],
        ccfg: &ContinuousConfig,
    ) -> Result<(Vec<GenResult>, EngineStats)> {
        let mut queue = AdmissionQueue::closed_loop(requests);
        self.generate_from_source(&mut queue, ccfg)
    }

    /// Serve an [`AdmissionQueue`] with continuous batching: requests
    /// are pulled from the queue's source as they arrive — a Poisson
    /// trace replay, the TCP front door's live channel, or the
    /// closed-loop fixed queue — and admitted into slots as capacity
    /// frees up, under the queue's
    /// [`super::admission::AdmissionPolicy`].  TTFT and
    /// [`EngineStats::queue_delay`] are measured from each request's
    /// arrival.
    pub fn generate_from_source(
        &mut self,
        queue: &mut AdmissionQueue,
        ccfg: &ContinuousConfig,
    ) -> Result<(Vec<GenResult>, EngineStats)> {
        let (results, stats) =
            drive_slots(&mut self.wired, &self.driver_cfg, queue, ccfg, &mut NoHooks)?;
        Ok((results, stats.into()))
    }

    /// [`Engine::generate_from_source`] with caller-supplied
    /// [`DriveHooks`] — the replica router uses this to plant its abort
    /// switch (a hook error stops the drive mid-flight, simulating a
    /// replica death).
    pub fn generate_from_source_hooked(
        &mut self,
        queue: &mut AdmissionQueue,
        ccfg: &ContinuousConfig,
        hooks: &mut dyn DriveHooks,
    ) -> Result<(Vec<GenResult>, EngineStats)> {
        let (results, stats) = drive_slots(&mut self.wired, &self.driver_cfg, queue, ccfg, hooks)?;
        Ok((results, stats.into()))
    }

    /// Longest generation the compiled shapes can hold
    /// (`max_seq - prompt_len`) — what a front door should clamp
    /// client-requested `max_new_tokens` to.
    pub fn max_new_cap(&self) -> usize {
        self.driver_cfg.max_seq.saturating_sub(self.driver_cfg.prompt_len).max(1)
    }

    fn run(
        &mut self,
        groups: &[GroupRequest],
        window: usize,
        strategy: Strategy,
    ) -> Result<(Vec<GenResult>, EngineStats)> {
        let (results, stats) = drive_groups(
            &mut self.wired,
            &self.driver_cfg,
            groups,
            window,
            strategy,
            &mut NoHooks,
        )?;
        Ok((results, stats.into()))
    }

    /// Shut the pipeline down and join the actors.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self
            .wired
            .to_first
            .send(StageMsg::Shutdown, StageMsg::Shutdown.wire_bytes());
        for h in self.wired.handles.drain(..) {
            match h.join() {
                Ok(r) => r?,
                Err(_) => anyhow::bail!("stage thread panicked"),
            }
        }
        Ok(())
    }
}

impl Strategy {
    /// Normalize: the engine distinguishes only barrier vs immediate.
    fn from_pipeline(s: Strategy) -> Strategy {
        match s {
            Strategy::Bubble => Strategy::Bubble,
            _ => Strategy::NoBubble,
        }
    }
}
