//! Collaborative-inference engine: wires stage actors per a plan and
//! drives generation.
//!
//! * **Sequential inference** (paper Fig. 4a): one group in flight —
//!   [`Engine::generate_sequential`].
//! * **Pipelined inference** (paper Fig. 5): several micro-batch groups in
//!   flight; the driver releases a group's next iteration either
//!   immediately when its token returns (**No-bubble**) or after every
//!   group finishes the current iteration (**Bubble**) —
//!   [`Engine::generate_pipelined`].
//!
//! All activations move through [`crate::netsim`] shaped links with the
//! cluster's per-pair bandwidth/latency, so the real numerics experience
//! the same network the planner optimized for.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use super::api::{GenResult, GroupRequest};
use super::kvcache::GroupCache;
use super::stage::{NextHop, Payload, Phase, StageActor, StageMsg, TokenMsg};
use crate::cluster::Cluster;
use crate::metrics::{ComputeObs, Histogram};
use crate::netsim::{
    shaped_channel_live, LinkSpec, LiveLink, RoutedLink, ShapedSender, TransferObs,
};
use crate::pipeline::Strategy;
use crate::planner::Plan;
use crate::runtime::manifest::Manifest;
use crate::runtime::{ExecServiceHandle, WeightStore};

/// Engine construction parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Compression factor for simulated link delays (1.0 = real time).
    pub time_scale: f64,
    /// Per-device compute slowdown factors (index = device id); empty =
    /// run everything at raw CPU speed.
    pub compute_scale: Vec<f64>,
    /// KV budget per stage, bytes (generous default for the tiny model).
    pub kv_budget_bytes: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            time_scale: 1.0,
            compute_scale: Vec::new(),
            kv_budget_bytes: 1 << 30,
        }
    }
}

/// Aggregate serving statistics of one engine run.
#[derive(Debug)]
pub struct EngineStats {
    pub makespan_ms: f64,
    /// Real (non-padding) tokens generated.
    pub tokens: u64,
    pub throughput_tps: f64,
    /// Time-to-first-token per group.
    pub ttft: Histogram,
    /// Per-iteration latency samples (decode steps).
    pub iter_latency: Histogram,
}

/// Observation sinks threaded into a wired pipeline — the adaptive
/// monitor's taps on stage compute and link transfers.
#[derive(Clone)]
pub struct ObsSinks {
    pub compute: Sender<ComputeObs>,
    pub transfer: Sender<TransferObs>,
}

/// A fully wired pipeline: stage actor threads connected by live shaped
/// links.  [`Engine`] wraps one for static serving; the adaptive engine
/// drives (and at migration, rebuilds) one directly.
pub struct Wired {
    pub to_first: ShapedSender<StageMsg>,
    pub token_rx: Receiver<TokenMsg>,
    pub handles: Vec<std::thread::JoinHandle<Result<()>>>,
    /// Inter-device links in use: one ingress link per stage > 0 plus the
    /// token loopback.  Live — re-shaping them affects in-flight traffic.
    pub links: Vec<RoutedLink>,
}

/// Build stage actors for `plan` over `cluster` and connect them with
/// live shaped links.
///
/// `preloads[i]` seeds stage *i*'s KV pool (migration hand-off); shorter
/// or empty vectors mean no preload.  `obs` taps every stage and link
/// for the adaptive monitor.
#[allow(clippy::too_many_arguments)]
pub fn wire(
    manifest: &Manifest,
    weights: &WeightStore,
    exec: ExecServiceHandle,
    plan: &Plan,
    cluster: &Cluster,
    cfg: &EngineConfig,
    obs: Option<&ObsSinks>,
    mut preloads: Vec<Vec<(u64, GroupCache)>>,
) -> Result<Wired> {
    let n_model_layers = manifest.config.n_layers + 2;
    anyhow::ensure!(
        plan.stages.last().map(|s| s.end) == Some(n_model_layers),
        "plan covers {:?} layers, model has {n_model_layers}",
        plan.stages.last().map(|s| s.end)
    );
    let s_count = plan.n_stages();
    let mut links = Vec::new();
    let transfer_tx = obs.map(|o| o.transfer.clone());

    // token loopback: head device -> source
    let head_dev = plan.stages.last().unwrap().device;
    let loop_link = LiveLink::new(cluster.link(head_dev, cluster.source));
    links.push(RoutedLink {
        from: head_dev,
        to: cluster.source,
        link: loop_link.clone(),
    });
    let (token_tx, token_rx) = shaped_channel_live::<TokenMsg>(
        loop_link,
        cfg.time_scale,
        (head_dev, cluster.source),
        transfer_tx.clone(),
    );

    // per-stage ingress links: stage i receives over the link
    // (stage i-1's device) → (stage i's device); stage 0 receives from
    // the driver, which lives on the source device (free link).
    let mut receivers: Vec<Option<Receiver<StageMsg>>> = (0..s_count).map(|_| None).collect();
    let mut senders: Vec<Option<ShapedSender<StageMsg>>> = (0..s_count).map(|_| None).collect();
    for i in 0..s_count {
        let (route, spec) = if i == 0 {
            (
                (cluster.source, cluster.source),
                LinkSpec::new(f64::INFINITY, 0.0),
            )
        } else {
            let prev = plan.stages[i - 1].device;
            let dev = plan.stages[i].device;
            ((prev, dev), cluster.link(prev, dev))
        };
        let live = LiveLink::new(spec);
        if i > 0 {
            links.push(RoutedLink {
                from: route.0,
                to: route.1,
                link: live.clone(),
            });
        }
        let (tx, rx) = shaped_channel_live::<StageMsg>(
            live,
            cfg.time_scale,
            route,
            if i > 0 { transfer_tx.clone() } else { None },
        );
        receivers[i] = Some(rx);
        senders[i] = Some(tx);
    }

    // spawn actors front to back, threading the "next" hops
    let mut handles = Vec::with_capacity(s_count);
    for (i, st) in plan.stages.iter().enumerate() {
        let next = if i + 1 < s_count {
            NextHop::Stage(senders[i + 1].clone().unwrap())
        } else {
            NextHop::Driver(token_tx.clone())
        };
        let pre = if i < preloads.len() {
            std::mem::take(&mut preloads[i])
        } else {
            Vec::new()
        };
        let mut actor = StageActor::new(
            i,
            st.device,
            manifest,
            weights,
            st.start..st.end,
            n_model_layers,
            exec.clone(),
            cfg.kv_budget_bytes,
            next,
            pre,
        )?;
        actor.compute_scale = cfg.compute_scale.get(st.device).copied().unwrap_or(1.0);
        actor.obs = obs.map(|o| o.compute.clone());
        let rx = receivers[i].take().unwrap();
        handles.push(
            std::thread::Builder::new()
                .name(format!("stage-{i}"))
                .spawn(move || actor.run(rx))
                .context("spawning stage")?,
        );
    }

    Ok(Wired {
        to_first: senders[0].clone().unwrap(),
        token_rx,
        handles,
        links,
    })
}

/// The wired pipeline.
pub struct Engine {
    to_first: ShapedSender<StageMsg>,
    token_rx: Receiver<TokenMsg>,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
    links: Vec<RoutedLink>,
    prompt_len: usize,
    batch_sizes: Vec<usize>,
}

impl Engine {
    /// Build stage actors for `plan` over `cluster` and connect them with
    /// shaped links.
    pub fn build(
        manifest: &Manifest,
        weights: &WeightStore,
        exec: ExecServiceHandle,
        plan: &Plan,
        cluster: &Cluster,
        cfg: &EngineConfig,
    ) -> Result<Self> {
        let wired = wire(manifest, weights, exec, plan, cluster, cfg, None, Vec::new())?;
        Ok(Engine {
            to_first: wired.to_first,
            token_rx: wired.token_rx,
            handles: wired.handles,
            links: wired.links,
            prompt_len: manifest.config.prefill_len,
            batch_sizes: manifest.batch_sizes.clone(),
        })
    }

    /// The live inter-device links this engine's traffic flows over
    /// (loopback first).  Re-shaping them — e.g. from a
    /// [`crate::adaptive::dynamics::DynamicsDriver`] — affects in-flight
    /// frames, which is exactly how the network-drop scenarios degrade a
    /// running static engine.
    pub fn routed_links(&self) -> Vec<RoutedLink> {
        self.links.clone()
    }

    /// Largest compiled batch size.
    pub fn max_batch(&self) -> usize {
        self.batch_sizes.iter().copied().max().unwrap_or(1)
    }

    fn send_prefill(&self, g: &GroupRequest) -> Result<()> {
        anyhow::ensure!(
            self.batch_sizes.contains(&g.batch),
            "batch {} not compiled (have {:?})",
            g.batch,
            self.batch_sizes
        );
        anyhow::ensure!(
            g.prompt_len == self.prompt_len,
            "prompt len {} != compiled {}",
            g.prompt_len,
            self.prompt_len
        );
        let msg = StageMsg::Work {
            group: g.group_id,
            iter: 0,
            pos: 0,
            phase: Phase::Prefill,
            batch: g.batch,
            prompt_len: g.prompt_len,
            payload: Payload::Tokens(g.tokens.clone()),
        };
        let bytes = msg.bytes();
        self.to_first.send(msg, bytes)
    }

    fn send_decode(&self, g: &GroupRequest, iter: usize, tokens: Vec<i32>) -> Result<()> {
        let pos = (g.prompt_len + iter - 1) as i32;
        let msg = StageMsg::Work {
            group: g.group_id,
            iter,
            pos,
            phase: Phase::Decode,
            batch: g.batch,
            prompt_len: g.prompt_len,
            payload: Payload::Tokens(tokens),
        };
        let bytes = msg.bytes();
        self.to_first.send(msg, bytes)
    }

    /// Serve groups one at a time (paper's sequential inference).
    pub fn generate_sequential(
        &self,
        groups: &[GroupRequest],
    ) -> Result<(Vec<GenResult>, EngineStats)> {
        self.run(groups, 1, Strategy::NoBubble)
    }

    /// Serve all groups as a micro-batched pipeline.
    pub fn generate_pipelined(
        &self,
        groups: &[GroupRequest],
        strategy: Strategy,
    ) -> Result<(Vec<GenResult>, EngineStats)> {
        self.run(groups, groups.len().max(1), Strategy::from_pipeline(strategy))
    }

    fn run(
        &self,
        groups: &[GroupRequest],
        window: usize,
        strategy: Strategy,
    ) -> Result<(Vec<GenResult>, EngineStats)> {
        struct Active<'a> {
            req: &'a GroupRequest,
            rows: Vec<Vec<i32>>,
            start: Instant,
            ttft_ms: Option<f64>,
            last_iter_at: Instant,
            done: bool,
        }
        let t0 = Instant::now();
        let mut ttft = Histogram::new();
        let mut iter_lat = Histogram::new();
        let mut results = Vec::new();
        let mut active: HashMap<u64, Active> = HashMap::new();
        let mut queue = groups.iter();
        let mut in_flight = 0usize;
        let mut real_tokens = 0u64;
        // barrier bookkeeping for the Bubble strategy
        let mut barrier: Vec<(u64, usize, Vec<i32>)> = Vec::new();

        // prime the window
        while in_flight < window {
            let Some(g) = queue.next() else { break };
            self.send_prefill(g)?;
            active.insert(
                g.group_id,
                Active {
                    req: g,
                    rows: vec![Vec::new(); g.batch],
                    start: Instant::now(),
                    ttft_ms: None,
                    last_iter_at: Instant::now(),
                    done: false,
                },
            );
            in_flight += 1;
        }

        while in_flight > 0 {
            let tok = self
                .token_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("pipeline closed unexpectedly"))?;
            let a = active
                .get_mut(&tok.group)
                .with_context(|| format!("unknown group {}", tok.group))?;
            let now = Instant::now();
            iter_lat.record(now.duration_since(a.last_iter_at).as_secs_f64() * 1e3);
            a.last_iter_at = now;
            if a.ttft_ms.is_none() {
                let ms = now.duration_since(a.start).as_secs_f64() * 1e3;
                a.ttft_ms = Some(ms);
                ttft.record(ms);
            }
            for (row, &t) in a.rows.iter_mut().zip(&tok.tokens) {
                row.push(t);
            }
            real_tokens += a.req.real() as u64;
            let next_iter = tok.iter + 1;
            if next_iter < a.req.max_new_tokens {
                match strategy {
                    Strategy::Bubble => barrier.push((tok.group, next_iter, tok.tokens)),
                    _ => self.send_decode(a.req, next_iter, tok.tokens)?,
                }
            } else {
                // group complete
                a.done = true;
                let total = now.duration_since(a.start).as_secs_f64() * 1e3;
                for (i, &rid) in a.req.request_ids.iter().enumerate() {
                    results.push(GenResult {
                        id: rid,
                        tokens: a.rows[i].clone(),
                        ttft_ms: a.ttft_ms.unwrap_or(0.0),
                        total_ms: total,
                    });
                }
                self.to_first.send(StageMsg::Free { group: tok.group }, 16)?;
                in_flight -= 1;
                // admit the next queued group
                if let Some(g) = queue.next() {
                    self.send_prefill(g)?;
                    active.insert(
                        g.group_id,
                        Active {
                            req: g,
                            rows: vec![Vec::new(); g.batch],
                            start: Instant::now(),
                            ttft_ms: None,
                            last_iter_at: Instant::now(),
                            done: false,
                        },
                    );
                    in_flight += 1;
                }
            }
            // Bubble barrier: release the next iteration only when every
            // unfinished group has delivered the current one.
            if strategy == Strategy::Bubble {
                let waiting = active.values().filter(|a| !a.done).count();
                if barrier.len() == waiting && !barrier.is_empty() {
                    for (gid, it, toks) in barrier.drain(..) {
                        let req = active[&gid].req;
                        self.send_decode(req, it, toks)?;
                    }
                }
            }
        }

        let makespan = t0.elapsed().as_secs_f64() * 1e3;
        let stats = EngineStats {
            makespan_ms: makespan,
            tokens: real_tokens,
            throughput_tps: if makespan > 0.0 {
                real_tokens as f64 / (makespan / 1e3)
            } else {
                0.0
            },
            ttft,
            iter_latency: iter_lat,
        };
        Ok((results, stats))
    }

    /// Shut the pipeline down and join the actors.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.to_first.send(StageMsg::Shutdown, 16);
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(r) => r?,
                Err(_) => anyhow::bail!("stage thread panicked"),
            }
        }
        Ok(())
    }
}

impl Strategy {
    /// Normalize: the engine distinguishes only barrier vs immediate.
    fn from_pipeline(s: Strategy) -> Strategy {
        match s {
            Strategy::Bubble => Strategy::Bubble,
            _ => Strategy::NoBubble,
        }
    }
}
