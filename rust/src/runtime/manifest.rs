//! `artifacts/manifest.json` — the python→rust interchange contract
//! written by `python/compile/aot.py`.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

use crate::util::Json;

/// Architecture of the AOT-compiled model.
#[derive(Debug, Clone)]
pub struct ManifestConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub layer_param_order: Vec<String>,
}

impl ManifestConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// The mini sim-backend model (vocab 64, d_model 32, 4 layers, 2
    /// heads, d_ff 64) shared by the adaptive scenarios, the serving
    /// bench and the continuous-batching tests — small enough that
    /// debug-build compute stays well under the simulated network costs.
    /// `prefill_len`/`max_seq` vary per harness.
    pub fn mini_sim(name: &str, prefill_len: usize, max_seq: usize) -> ManifestConfig {
        ManifestConfig {
            name: name.into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 4,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 64,
            max_seq,
            prefill_len,
            layer_param_order: [
                "attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up", "w_down",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        }
    }
}

/// Dtype + shape of one HLO parameter or result.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSig {
            dtype: j.req("dtype")?.as_str().context("dtype")?.to_string(),
            shape: j
                .req("shape")?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|x| x.as_usize().context("shape elem"))
                .collect::<Result<_>>()?,
        })
    }
}

/// One AOT-lowered shard variant.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// One tensor in `weights.bin`.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub offset_bytes: usize,
    pub shape: Vec<usize>,
}

impl WeightEntry {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ManifestConfig,
    pub batch_sizes: Vec<usize>,
    pub weights_file: String,
    pub weights_total_bytes: usize,
    pub weights: Vec<WeightEntry>,
    pub artifacts: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let c = j.req("config")?;
        let config = ManifestConfig {
            name: c.req("name")?.as_str().context("name")?.to_string(),
            vocab_size: c.req("vocab_size")?.as_usize().context("vocab_size")?,
            d_model: c.req("d_model")?.as_usize().context("d_model")?,
            n_layers: c.req("n_layers")?.as_usize().context("n_layers")?,
            n_heads: c.req("n_heads")?.as_usize().context("n_heads")?,
            n_kv_heads: c.req("n_kv_heads")?.as_usize().context("n_kv_heads")?,
            d_ff: c.req("d_ff")?.as_usize().context("d_ff")?,
            max_seq: c.req("max_seq")?.as_usize().context("max_seq")?,
            prefill_len: c.req("prefill_len")?.as_usize().context("prefill_len")?,
            layer_param_order: c
                .req("layer_param_order")?
                .as_arr()
                .context("layer_param_order")?
                .iter()
                .map(|x| x.as_str().unwrap_or("").to_string())
                .collect(),
        };

        let batch_sizes = j
            .req("batch_sizes")?
            .as_arr()
            .context("batch_sizes")?
            .iter()
            .map(|x| x.as_usize().context("batch size"))
            .collect::<Result<_>>()?;

        let weights = j
            .req("weights")?
            .as_arr()
            .context("weights")?
            .iter()
            .map(|w| {
                Ok(WeightEntry {
                    name: w.req("name")?.as_str().context("w.name")?.to_string(),
                    offset_bytes: w.req("offset_bytes")?.as_usize().context("offset")?,
                    shape: w
                        .req("shape")?
                        .as_arr()
                        .context("w.shape")?
                        .iter()
                        .map(|x| x.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let artifacts = j
            .req("artifacts")?
            .as_arr()
            .context("artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactEntry {
                    name: a.req("name")?.as_str().context("a.name")?.to_string(),
                    file: a.req("file")?.as_str().context("a.file")?.to_string(),
                    inputs: a
                        .req("inputs")?
                        .as_arr()
                        .context("inputs")?
                        .iter()
                        .map(TensorSig::from_json)
                        .collect::<Result<_>>()?,
                    outputs: a
                        .req("outputs")?
                        .as_arr()
                        .context("outputs")?
                        .iter()
                        .map(TensorSig::from_json)
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            config,
            batch_sizes,
            weights_file: j
                .req("weights_file")?
                .as_str()
                .context("weights_file")?
                .to_string(),
            weights_total_bytes: j
                .req("weights_total_bytes")?
                .as_usize()
                .context("weights_total_bytes")?,
            weights,
            artifacts,
            dir,
        })
    }

    /// Build an **in-memory** manifest for `config` — no files involved.
    ///
    /// This is the entry point of the pure-rust sim backend
    /// ([`crate::runtime::sim`]): together with
    /// [`crate::runtime::WeightStore::synthetic`] and
    /// [`crate::runtime::ExecService::start_sim`] it lets the full
    /// coordinator stack (stage actors, shaped links, KV migration) run
    /// end-to-end without `make artifacts` or PJRT.
    ///
    /// The weight table uses the canonical export layout of
    /// `python/compile/aot.py` (tok_emb, per-layer params in
    /// `layer_param_order`, final_norm, lm_head); artifact entries carry
    /// the variant names with empty files since nothing is compiled.
    pub fn synthetic(config: ManifestConfig, batch_sizes: Vec<usize>) -> Manifest {
        let c = &config;
        let mut weights = Vec::new();
        let mut offset = 0usize;
        let mut push = |name: String, shape: Vec<usize>, offset: &mut usize| {
            let elems: usize = shape.iter().product();
            weights.push(WeightEntry {
                name,
                offset_bytes: *offset,
                shape,
            });
            *offset += elems * 4;
        };
        let d = c.d_model;
        let hd = c.head_dim();
        push("tok_emb".into(), vec![c.vocab_size, d], &mut offset);
        for i in 0..c.n_layers {
            for p in &c.layer_param_order {
                let shape = match p.as_str() {
                    "attn_norm" | "ffn_norm" => vec![d],
                    "wq" => vec![d, c.n_heads * hd],
                    "wk" | "wv" => vec![d, c.n_kv_heads * hd],
                    "wo" => vec![c.n_heads * hd, d],
                    "w_gate" | "w_up" => vec![d, c.d_ff],
                    "w_down" => vec![c.d_ff, d],
                    other => panic!("unknown layer param `{other}`"),
                };
                push(format!("layers.{i}.{p}"), shape, &mut offset);
            }
        }
        push("final_norm".into(), vec![d], &mut offset);
        push("lm_head".into(), vec![d, c.vocab_size], &mut offset);

        let mut artifacts = Vec::new();
        for &b in &batch_sizes {
            for fam in ["embed", "layer", "head"] {
                for phase in ["prefill", "decode"] {
                    artifacts.push(ArtifactEntry {
                        name: format!("{fam}_{phase}_b{b}"),
                        file: String::new(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                    });
                }
            }
        }

        Manifest {
            config,
            batch_sizes,
            weights_file: String::new(),
            weights_total_bytes: offset,
            weights,
            artifacts,
            dir: PathBuf::new(),
        }
    }

    /// Synthetic manifest mirroring the python `TINY` config
    /// (`tinyllama-4l`), the model every sim-backend test and the adaptive
    /// scenarios run.
    pub fn synthetic_tiny() -> Manifest {
        Manifest::synthetic(
            ManifestConfig {
                name: "tinyllama-4l-sim".into(),
                vocab_size: 256,
                d_model: 128,
                n_layers: 4,
                n_heads: 4,
                n_kv_heads: 4,
                d_ff: 256,
                max_seq: 128,
                prefill_len: 32,
                layer_param_order: [
                    "attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up", "w_down",
                ]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            },
            vec![1, 8],
        )
    }

    /// Default artifact directory: `$EDGESHARD_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("EDGESHARD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact `{name}` not in manifest"))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights_file)
    }

    pub fn weight(&self, name: &str) -> Result<&WeightEntry> {
        self.weights
            .iter()
            .find(|w| w.name == name)
            .with_context(|| format!("weight `{name}` not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> Option<PathBuf> {
        let d = Manifest::default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_built_artifacts() {
        let Some(dir) = art_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.config.d_model, 128);
        assert_eq!(m.config.layer_param_order.len(), 9);
        assert!(m.artifact("layer_decode_b1").is_ok());
        assert!(m.artifact("nope").is_err());
        assert!(m.artifact_path("layer_decode_b1").unwrap().exists());
        assert!(m.weights_path().exists());
        assert_eq!(m.batch_sizes, vec![1, 8]);
    }

    #[test]
    fn weight_lookup() {
        let Some(dir) = art_dir() else {
            return;
        };
        let m = Manifest::load(dir).unwrap();
        let w = m.weight("layers.0.wq").unwrap();
        assert_eq!(w.shape, vec![m.config.d_model, m.config.d_model]);
        assert!(m.weight("layers.99.wq").is_err());
    }

    #[test]
    fn artifact_signatures_parsed() {
        let Some(dir) = art_dir() else {
            return;
        };
        let m = Manifest::load(dir).unwrap();
        let a = m.artifact("layer_decode_b1").unwrap();
        assert_eq!(a.inputs.len(), 13);
        assert_eq!(a.outputs.len(), 3);
        assert_eq!(a.inputs[12].dtype, "int32");
        assert_eq!(a.inputs[12].shape, Vec::<usize>::new());
    }

    #[test]
    fn synthetic_tiny_layout() {
        let m = Manifest::synthetic_tiny();
        assert_eq!(m.config.d_model, 128);
        assert_eq!(m.config.layer_param_order.len(), 9);
        assert_eq!(m.batch_sizes, vec![1, 8]);
        // tok_emb + 4×9 layer params + final_norm + lm_head
        assert_eq!(m.weights.len(), 1 + 4 * 9 + 2);
        let wq = m.weight("layers.0.wq").unwrap();
        assert_eq!(wq.shape, vec![128, 128]);
        // offsets are contiguous f32s
        let total: usize = m.weights.iter().map(|w| w.elems() * 4).sum();
        assert_eq!(total, m.weights_total_bytes);
        let last = m.weights.last().unwrap();
        assert_eq!(last.offset_bytes + last.elems() * 4, m.weights_total_bytes);
        assert!(m.artifact("layer_decode_b8").is_ok());
        assert!(m.artifact("layer_decode_b3").is_err());
    }

    #[test]
    fn tensor_sig_elems() {
        let t = TensorSig {
            dtype: "float32".into(),
            shape: vec![2, 3, 4],
        };
        assert_eq!(t.elems(), 24);
    }
}
