//! Weight store: loads `artifacts/weights.bin` (flat little-endian f32,
//! layout defined by the manifest's weight table) and serves per-tensor
//! slices.  In a real EdgeShard deployment each device loads only its
//! shard's weights; [`WeightStore::stage_bytes`] reports exactly that
//! footprint for the memory accounting tests.

use anyhow::{ensure, Context, Result};
use std::sync::Arc;

use super::manifest::Manifest;

/// All model weights, resident once per process and shared by stages.
#[derive(Debug, Clone)]
pub struct WeightStore {
    data: Arc<Vec<f32>>,
    entries: Vec<(String, usize, usize, Vec<usize>)>, // name, offset_elems, len, shape
}

impl WeightStore {
    /// Read the full weight blob described by `manifest`.
    pub fn load(manifest: &Manifest) -> Result<Self> {
        let path = manifest.weights_path();
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        ensure!(
            bytes.len() == manifest.weights_total_bytes,
            "weights.bin size {} != manifest {}",
            bytes.len(),
            manifest.weights_total_bytes
        );
        ensure!(bytes.len() % 4 == 0, "weights.bin not f32-aligned");
        let mut data = vec![0f32; bytes.len() / 4];
        for (i, ch) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        let entries = manifest
            .weights
            .iter()
            .map(|w| {
                ensure!(w.offset_bytes % 4 == 0, "misaligned weight {}", w.name);
                Ok((
                    w.name.clone(),
                    w.offset_bytes / 4,
                    w.elems(),
                    w.shape.clone(),
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(WeightStore {
            data: Arc::new(data),
            entries,
        })
    }

    /// Deterministic synthetic weights for a manifest built with
    /// [`Manifest::synthetic`] — the sim-backend analogue of
    /// `python/compile/model.py::init_weights`: norm weights are ones,
    /// everything else is normal(0, 0.02²), drawn from the crate's seeded
    /// RNG (different numbers than JAX's PRNG, but the same structure).
    pub fn synthetic(manifest: &Manifest, seed: u64) -> WeightStore {
        let mut rng = crate::util::Rng::new(seed.wrapping_add(0x5EED));
        let mut data = vec![0f32; manifest.weights_total_bytes / 4];
        let mut entries = Vec::with_capacity(manifest.weights.len());
        for w in &manifest.weights {
            let off = w.offset_bytes / 4;
            let len = w.elems();
            let ones = w.name.ends_with("norm");
            for x in data[off..off + len].iter_mut() {
                *x = if ones {
                    1.0
                } else {
                    (rng.normal() * 0.02) as f32
                };
            }
            entries.push((w.name.clone(), off, len, w.shape.clone()));
        }
        WeightStore {
            data: Arc::new(data),
            entries,
        }
    }

    /// Slice of one named tensor.
    pub fn get(&self, name: &str) -> Result<(&[f32], &[usize])> {
        let (_, off, len, shape) = self
            .entries
            .iter()
            .find(|(n, ..)| n == name)
            .with_context(|| format!("weight `{name}` not found"))?;
        Ok((&self.data[*off..*off + *len], shape))
    }

    /// The nine per-layer tensors of decoder layer `i`, in the canonical
    /// order the `layer_*` HLO parameters expect.
    pub fn layer_params(
        &self,
        manifest: &Manifest,
        layer: usize,
    ) -> Result<Vec<(&[f32], &[usize])>> {
        manifest
            .config
            .layer_param_order
            .iter()
            .map(|p| self.get(&format!("layers.{layer}.{p}")))
            .collect()
    }

    /// Bytes of weights a stage holding decoder layers `[lo, hi)` (plus
    /// optionally embed / head) keeps resident.
    pub fn stage_bytes(
        &self,
        manifest: &Manifest,
        decoders: std::ops::Range<usize>,
        has_embed: bool,
        has_head: bool,
    ) -> usize {
        let mut total = 0usize;
        if has_embed {
            total += self.get("tok_emb").map(|(d, _)| d.len() * 4).unwrap_or(0);
        }
        for l in decoders {
            for p in &manifest.config.layer_param_order {
                total += self
                    .get(&format!("layers.{l}.{p}"))
                    .map(|(d, _)| d.len() * 4)
                    .unwrap_or(0);
            }
        }
        if has_head {
            total += self.get("final_norm").map(|(d, _)| d.len() * 4).unwrap_or(0);
            total += self.get("lm_head").map(|(d, _)| d.len() * 4).unwrap_or(0);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load() -> Option<(Manifest, WeightStore)> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let m = Manifest::load(dir).unwrap();
        let w = WeightStore::load(&m).unwrap();
        Some((m, w))
    }

    #[test]
    fn loads_and_slices() {
        let Some((m, w)) = load() else { return };
        let (emb, shape) = w.get("tok_emb").unwrap();
        assert_eq!(shape, &[m.config.vocab_size, m.config.d_model]);
        assert_eq!(emb.len(), m.config.vocab_size * m.config.d_model);
        // weights are random-normal scaled 0.02 — check magnitude sanity
        let mean_abs: f32 = emb.iter().map(|x| x.abs()).sum::<f32>() / emb.len() as f32;
        assert!(mean_abs > 0.001 && mean_abs < 0.1, "mean_abs={mean_abs}");
    }

    #[test]
    fn layer_params_order_and_shapes() {
        let Some((m, w)) = load() else { return };
        let params = w.layer_params(&m, 0).unwrap();
        assert_eq!(params.len(), 9);
        // attn_norm first: shape [d_model]
        assert_eq!(params[0].1, &[m.config.d_model]);
        // wq second: [d_model, n_heads*head_dim]
        assert_eq!(
            params[1].1,
            &[m.config.d_model, m.config.n_heads * m.config.head_dim()]
        );
        // w_down last: [d_ff, d_model]
        assert_eq!(params[8].1, &[m.config.d_ff, m.config.d_model]);
    }

    #[test]
    fn norm_weights_are_ones() {
        let Some((_m, w)) = load() else { return };
        let (norm, _) = w.get("final_norm").unwrap();
        assert!(norm.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn missing_weight_errors() {
        let Some((_m, w)) = load() else { return };
        assert!(w.get("layers.7.wq").is_err());
    }

    #[test]
    fn synthetic_weights_layout_and_stats() {
        let m = Manifest::synthetic_tiny();
        let w = WeightStore::synthetic(&m, 0);
        let (emb, shape) = w.get("tok_emb").unwrap();
        assert_eq!(shape, &[m.config.vocab_size, m.config.d_model]);
        let mean_abs: f32 = emb.iter().map(|x| x.abs()).sum::<f32>() / emb.len() as f32;
        assert!(mean_abs > 0.005 && mean_abs < 0.05, "mean_abs={mean_abs}");
        let (norm, _) = w.get("layers.2.ffn_norm").unwrap();
        assert!(norm.iter().all(|&x| x == 1.0));
        assert_eq!(w.layer_params(&m, 3).unwrap().len(), 9);
        // deterministic per seed
        let w2 = WeightStore::synthetic(&m, 0);
        assert_eq!(w.get("lm_head").unwrap().0, w2.get("lm_head").unwrap().0);
        let w3 = WeightStore::synthetic(&m, 1);
        assert_ne!(w.get("lm_head").unwrap().0, w3.get("lm_head").unwrap().0);
        // partitions cover the whole blob
        let all = w.stage_bytes(&m, 0..m.config.n_layers, true, true);
        assert_eq!(all, m.weights_total_bytes);
    }

    #[test]
    fn stage_bytes_partitions_total() {
        let Some((m, w)) = load() else { return };
        let all = w.stage_bytes(&m, 0..m.config.n_layers, true, true);
        assert_eq!(all, m.weights_total_bytes);
        let a = w.stage_bytes(&m, 0..2, true, false);
        let b = w.stage_bytes(&m, 2..m.config.n_layers, false, true);
        assert_eq!(a + b, all);
    }
}
