//! Pure-rust reference executor for the tiny model shards — the **sim
//! backend**.
//!
//! Mirrors the shard semantics of `python/compile/model.py` (RMSNorm →
//! RoPE → causal/GQA attention → SwiGLU, residual connections, KV caches
//! padded to `max_seq`) in plain scalar rust, so the full coordinator
//! stack — stage actors, shaped links, KV-cache migration, the adaptive
//! runtime — runs end-to-end in environments without `make artifacts` or
//! PJRT.  Weights come from [`crate::runtime::WeightStore::synthetic`]
//! (not the python seed-0 weights, so tokens differ from the python
//! oracle), and the math is deterministic: any partition of the layers
//! across stages — and any mid-generation migration — must reproduce the
//! exact same token stream, which the adaptive tests assert.
//!
//! Performance note: this is honest compute, not a sleep stand-in.  The
//! measured per-shard wall time feeds [`crate::runtime::MeasuredProfiler`]
//! the same way PJRT timings would.

use anyhow::{anyhow, bail, ensure, Result};

use super::manifest::ManifestConfig;
use super::shard::TensorData;

/// Which shard family a variant name addresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Family {
    Embed,
    Layer,
    Head,
}

/// Parse `"<family>_<phase>_b<batch>"`, e.g. `layer_decode_b8`.
fn parse_variant(name: &str) -> Result<(Family, bool, usize)> {
    let parts: Vec<&str> = name.split('_').collect();
    ensure!(parts.len() == 3, "sim: unknown variant `{name}`");
    let family = match parts[0] {
        "embed" => Family::Embed,
        "layer" => Family::Layer,
        "head" => Family::Head,
        _ => bail!("sim: unknown shard family in `{name}`"),
    };
    let prefill = match parts[1] {
        "prefill" => true,
        "decode" => false,
        _ => bail!("sim: unknown phase in `{name}`"),
    };
    let batch: usize = parts[2]
        .strip_prefix('b')
        .ok_or_else(|| anyhow!("sim: bad batch suffix in `{name}`"))?
        .parse()
        .map_err(|_| anyhow!("sim: bad batch suffix in `{name}`"))?;
    ensure!(batch > 0, "sim: zero batch in `{name}`");
    Ok((family, prefill, batch))
}

fn f32_input<'a>(t: &'a TensorData, what: &str) -> Result<(&'a [f32], &'a [i64])> {
    Ok((t.as_f32().map_err(|e| anyhow!("sim: {what}: {e}"))?, t.dims()))
}

/// RMSNorm over the last axis: rows × d.
fn rms_norm(x: &[f32], w: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0f32; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut ss = 0f32;
        for &v in xr {
            ss += v * v;
        }
        let inv = 1.0 / (ss / d as f32 + 1e-5).sqrt();
        let out_row = &mut out[r * d..(r + 1) * d];
        for ((o, &xv), &wv) in out_row.iter_mut().zip(xr).zip(w) {
            *o = xv * inv * wv;
        }
    }
    out
}

/// `x [rows, d_in] @ w [d_in, d_out]` (row-major), accumulated in f32.
fn matmul(x: &[f32], w: &[f32], rows: usize, d_in: usize, d_out: usize) -> Vec<f32> {
    let mut out = vec![0f32; rows * d_out];
    for r in 0..rows {
        let xr = &x[r * d_in..(r + 1) * d_in];
        let out_row = &mut out[r * d_out..(r + 1) * d_out];
        for (i, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w[i * d_out..(i + 1) * d_out];
            for (o, &wv) in out_row.iter_mut().zip(wr) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// In-place rotary embedding of one head vector at absolute `pos`.
fn rope_rotate(v: &mut [f32], pos: usize, theta: f64) {
    let hd = v.len();
    let half = hd / 2;
    for j in 0..half {
        let freq = theta.powf(-(j as f64) / half as f64);
        let angle = pos as f64 * freq;
        let (sin, cos) = (angle.sin() as f32, angle.cos() as f32);
        let (x1, x2) = (v[j], v[j + half]);
        v[j] = x1 * cos - x2 * sin;
        v[j + half] = x1 * sin + x2 * cos;
    }
}

/// Softmax in place.
fn softmax(s: &mut [f32]) {
    let mut mx = f32::NEG_INFINITY;
    for &v in s.iter() {
        mx = mx.max(v);
    }
    let mut sum = 0f32;
    for v in s.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    if sum > 0.0 {
        for v in s.iter_mut() {
            *v /= sum;
        }
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Symmetric per-row int8 quantization: each of `rows` rows gets its own
/// scale `max|x| / 127`, values are rounded to the nearest step and
/// clamped to `[-127, 127]`.  An all-zero row stores scale 0 and
/// dequantizes back to exact zeros.  Per-*row* (= per-token) scales are
/// what make chunked transmission equal monolithic transmission: a row's
/// scale depends only on that row, never on its neighbors in the frame.
pub fn quantize_rows_i8(data: &[f32], rows: usize) -> (Vec<i8>, Vec<f32>) {
    assert!(rows > 0 && data.len() % rows == 0, "quantize: ragged rows");
    let row_len = data.len() / rows;
    let mut q = vec![0i8; data.len()];
    let mut scales = vec![0f32; rows];
    for r in 0..rows {
        let src = &data[r * row_len..(r + 1) * row_len];
        let mut max_abs = 0f32;
        for &v in src {
            max_abs = max_abs.max(v.abs());
        }
        if max_abs == 0.0 || !max_abs.is_finite() {
            continue; // scale stays 0, row stays 0
        }
        let scale = max_abs / 127.0;
        scales[r] = scale;
        let dst = &mut q[r * row_len..(r + 1) * row_len];
        for (o, &v) in dst.iter_mut().zip(src) {
            *o = (v / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scales)
}

/// Inverse of [`quantize_rows_i8`]: `x̂ = q · scale` per row.
pub fn dequantize_rows_i8(data: &[i8], scales: &[f32], rows: usize) -> Vec<f32> {
    assert!(rows > 0 && data.len() % rows == 0 && scales.len() == rows);
    let row_len = data.len() / rows;
    let mut out = vec![0f32; data.len()];
    for r in 0..rows {
        let scale = scales[r];
        if scale == 0.0 {
            continue;
        }
        let src = &data[r * row_len..(r + 1) * row_len];
        let dst = &mut out[r * row_len..(r + 1) * row_len];
        for (o, &v) in dst.iter_mut().zip(src) {
            *o = v as f32 * scale;
        }
    }
    out
}

/// Execute one shard variant. `inputs` is registered weights (prefix)
/// followed by the dynamic activations, exactly as the PJRT path would
/// receive them.
pub fn run_variant(
    cfg: &ManifestConfig,
    variant: &str,
    inputs: &[TensorData],
) -> Result<Vec<TensorData>> {
    let (family, prefill, batch) = parse_variant(variant)?;
    match family {
        Family::Embed => run_embed(cfg, prefill, batch, inputs),
        Family::Layer => run_layer(cfg, prefill, batch, inputs),
        Family::Head => run_head(cfg, batch, inputs),
    }
}

fn run_embed(
    cfg: &ManifestConfig,
    prefill: bool,
    batch: usize,
    inputs: &[TensorData],
) -> Result<Vec<TensorData>> {
    ensure!(inputs.len() == 2, "sim embed: want [tok_emb, tokens]");
    let (emb, emb_dims) = f32_input(&inputs[0], "tok_emb")?;
    let toks = inputs[1].as_i32()?;
    let d = cfg.d_model;
    ensure!(
        emb_dims == [cfg.vocab_size as i64, d as i64],
        "sim embed: tok_emb dims {emb_dims:?}"
    );
    let s = if prefill { toks.len() / batch } else { 1 };
    ensure!(toks.len() == batch * s, "sim embed: token count");
    let mut h = vec![0f32; batch * s * d];
    for (i, &t) in toks.iter().enumerate() {
        ensure!(
            (0..cfg.vocab_size as i32).contains(&t),
            "sim embed: token {t} out of vocab"
        );
        let src = &emb[t as usize * d..(t as usize + 1) * d];
        h[i * d..(i + 1) * d].copy_from_slice(src);
    }
    Ok(vec![TensorData::f32(
        h,
        vec![batch as i64, s as i64, d as i64],
    )])
}

struct LayerWeights<'a> {
    attn_norm: &'a [f32],
    wq: &'a [f32],
    wk: &'a [f32],
    wv: &'a [f32],
    wo: &'a [f32],
    ffn_norm: &'a [f32],
    w_gate: &'a [f32],
    w_up: &'a [f32],
    w_down: &'a [f32],
}

fn layer_weights<'a>(cfg: &ManifestConfig, inputs: &'a [TensorData]) -> Result<LayerWeights<'a>> {
    ensure!(
        inputs.len() >= 9,
        "sim layer: want 9 weight tensors, got {}",
        inputs.len()
    );
    let d = cfg.d_model;
    let hd = cfg.head_dim();
    let get = |i: usize, want: &[usize], what: &str| -> Result<&'a [f32]> {
        let (data, dims) = f32_input(&inputs[i], what)?;
        let want_i64: Vec<i64> = want.iter().map(|&x| x as i64).collect();
        ensure!(
            dims == want_i64.as_slice(),
            "sim layer: {what} dims {dims:?} != {want_i64:?}"
        );
        Ok(data)
    };
    Ok(LayerWeights {
        attn_norm: get(0, &[d], "attn_norm")?,
        wq: get(1, &[d, cfg.n_heads * hd], "wq")?,
        wk: get(2, &[d, cfg.n_kv_heads * hd], "wk")?,
        wv: get(3, &[d, cfg.n_kv_heads * hd], "wv")?,
        wo: get(4, &[cfg.n_heads * hd, d], "wo")?,
        ffn_norm: get(5, &[d], "ffn_norm")?,
        w_gate: get(6, &[d, cfg.d_ff], "w_gate")?,
        w_up: get(7, &[d, cfg.d_ff], "w_up")?,
        w_down: get(8, &[cfg.d_ff, d], "w_down")?,
    })
}

/// Shared epilogue: `h += attn @ wo; h += swiglu(rmsnorm(h))`.
fn attn_out_and_mlp(
    cfg: &ManifestConfig,
    w: &LayerWeights<'_>,
    h: &mut [f32],
    attn: &[f32],
    tokens: usize,
) {
    let d = cfg.d_model;
    let proj = matmul(attn, w.wo, tokens, cfg.n_heads * cfg.head_dim(), d);
    for (hv, pv) in h.iter_mut().zip(&proj) {
        *hv += *pv;
    }
    let x = rms_norm(h, w.ffn_norm, tokens, d);
    let g = matmul(&x, w.w_gate, tokens, d, cfg.d_ff);
    let u = matmul(&x, w.w_up, tokens, d, cfg.d_ff);
    let mut act = vec![0f32; tokens * cfg.d_ff];
    for ((a, &gv), &uv) in act.iter_mut().zip(&g).zip(&u) {
        *a = silu(gv) * uv;
    }
    let mlp = matmul(&act, w.w_down, tokens, cfg.d_ff, d);
    for (hv, mv) in h.iter_mut().zip(&mlp) {
        *hv += *mv;
    }
}

fn run_layer(
    cfg: &ManifestConfig,
    prefill: bool,
    batch: usize,
    inputs: &[TensorData],
) -> Result<Vec<TensorData>> {
    let w = layer_weights(cfg, inputs)?;
    let d = cfg.d_model;
    let hd = cfg.head_dim();
    let (nh, nkv, ms) = (cfg.n_heads, cfg.n_kv_heads, cfg.max_seq);
    let reps = nh / nkv.max(1);
    let scale = 1.0 / (hd as f32).sqrt();
    let cache_dims = vec![batch as i64, nkv as i64, ms as i64, hd as i64];
    let cache_at = |b: usize, kh: usize, s: usize| ((b * nkv + kh) * ms + s) * hd;

    if prefill && inputs.len() == 13 {
        // Chunked-prefill append: like the fresh branch below but the
        // chunk starts at absolute position `start` with the positions
        // `0..start` already resident in the passed-in padded caches
        // (written by earlier chunks).  Every query attends through the
        // cache in ascending `ki` order — the same f32 values in the same
        // accumulation order as a monolithic prefill, so chunked serving
        // stays bitwise identical (the same argument that keeps
        // decode-after-prefill equal to a longer prefill).
        let (h_in, h_dims) = f32_input(&inputs[9], "h")?;
        ensure!(
            h_dims.len() == 3 && h_dims[0] == batch as i64 && h_dims[2] == d as i64,
            "sim layer prefill append: h dims {h_dims:?}"
        );
        let s = h_dims[1] as usize;
        let (kc_in, kc_dims) = f32_input(&inputs[10], "k_cache")?;
        let (vc_in, vc_dims) = f32_input(&inputs[11], "v_cache")?;
        ensure!(
            kc_dims == cache_dims.as_slice() && vc_dims == cache_dims.as_slice(),
            "sim layer prefill append: cache dims {kc_dims:?}/{vc_dims:?}"
        );
        let start_raw = inputs[12].as_i32()?;
        ensure!(
            inputs[12].dims().is_empty() && start_raw[0] >= 0,
            "sim layer prefill append: start must be a non-negative scalar"
        );
        let start = start_raw[0] as usize;
        ensure!(
            start + s <= ms,
            "sim layer prefill append: start {start} + chunk {s} > max_seq {ms}"
        );
        let tokens = batch * s;
        let x = rms_norm(h_in, w.attn_norm, tokens, d);
        let mut q = matmul(&x, w.wq, tokens, d, nh * hd);
        let mut k = matmul(&x, w.wk, tokens, d, nkv * hd);
        let v = matmul(&x, w.wv, tokens, d, nkv * hd);
        // RoPE at absolute positions start..start+s
        for b in 0..batch {
            for si in 0..s {
                let t = b * s + si;
                for hh in 0..nh {
                    let off = t * nh * hd + hh * hd;
                    rope_rotate(&mut q[off..off + hd], start + si, 10000.0);
                }
                for kh in 0..nkv {
                    let off = t * nkv * hd + kh * hd;
                    rope_rotate(&mut k[off..off + hd], start + si, 10000.0);
                }
            }
        }
        // write the chunk's K/V into the caches first, then attend purely
        // through the caches (ascending ki covers earlier chunks and the
        // causal part of this one)
        let mut kc = kc_in.to_vec();
        let mut vc = vc_in.to_vec();
        for b in 0..batch {
            for si in 0..s {
                for kh in 0..nkv {
                    let src = (b * s + si) * nkv * hd + kh * hd;
                    let dst = cache_at(b, kh, start + si);
                    kc[dst..dst + hd].copy_from_slice(&k[src..src + hd]);
                    vc[dst..dst + hd].copy_from_slice(&v[src..src + hd]);
                }
            }
        }
        let mut attn = vec![0f32; tokens * nh * hd];
        for b in 0..batch {
            for hh in 0..nh {
                let kh = hh / reps.max(1);
                for qi in 0..s {
                    let pos = start + qi;
                    let qoff = (b * s + qi) * nh * hd + hh * hd;
                    let qv = &q[qoff..qoff + hd];
                    let mut scores = vec![0f32; pos + 1];
                    for (ki, sc) in scores.iter_mut().enumerate() {
                        let koff = cache_at(b, kh, ki);
                        let mut dot = 0f32;
                        for (a, b_) in qv.iter().zip(&kc[koff..koff + hd]) {
                            dot += a * b_;
                        }
                        *sc = dot * scale;
                    }
                    softmax(&mut scores);
                    let arow = &mut attn[qoff..qoff + hd];
                    for (ki, &p) in scores.iter().enumerate() {
                        let voff = cache_at(b, kh, ki);
                        for (a, b_) in arow.iter_mut().zip(&vc[voff..voff + hd]) {
                            *a += p * b_;
                        }
                    }
                }
            }
        }
        let mut h = h_in.to_vec();
        attn_out_and_mlp(cfg, &w, &mut h, &attn, tokens);
        return Ok(vec![
            TensorData::f32(h, vec![batch as i64, s as i64, d as i64]),
            TensorData::f32(kc, cache_dims.clone()),
            TensorData::f32(vc, cache_dims),
        ]);
    }
    if prefill {
        ensure!(inputs.len() == 10, "sim layer prefill: want 9 weights + h");
        let (h_in, h_dims) = f32_input(&inputs[9], "h")?;
        ensure!(
            h_dims.len() == 3 && h_dims[0] == batch as i64 && h_dims[2] == d as i64,
            "sim layer prefill: h dims {h_dims:?}"
        );
        let s = h_dims[1] as usize;
        ensure!(s <= ms, "sim layer prefill: seq {s} > max_seq {ms}");
        let tokens = batch * s;
        let x = rms_norm(h_in, w.attn_norm, tokens, d);
        let mut q = matmul(&x, w.wq, tokens, d, nh * hd);
        let mut k = matmul(&x, w.wk, tokens, d, nkv * hd);
        let v = matmul(&x, w.wv, tokens, d, nkv * hd);
        // RoPE per (token, head) at absolute positions 0..s
        for b in 0..batch {
            for si in 0..s {
                let t = b * s + si;
                for hh in 0..nh {
                    let off = t * nh * hd + hh * hd;
                    rope_rotate(&mut q[off..off + hd], si, 10000.0);
                }
                for kh in 0..nkv {
                    let off = t * nkv * hd + kh * hd;
                    rope_rotate(&mut k[off..off + hd], si, 10000.0);
                }
            }
        }
        // causal attention → attn [tokens, nh*hd]
        let mut attn = vec![0f32; tokens * nh * hd];
        let mut scores = vec![0f32; s];
        for b in 0..batch {
            for hh in 0..nh {
                let kh = hh / reps.max(1);
                for qi in 0..s {
                    let qoff = (b * s + qi) * nh * hd + hh * hd;
                    let qv = &q[qoff..qoff + hd];
                    for (ki, sc) in scores.iter_mut().enumerate().take(qi + 1) {
                        let koff = (b * s + ki) * nkv * hd + kh * hd;
                        let mut dot = 0f32;
                        for (a, b_) in qv.iter().zip(&k[koff..koff + hd]) {
                            dot += a * b_;
                        }
                        *sc = dot * scale;
                    }
                    softmax(&mut scores[..qi + 1]);
                    let arow = &mut attn[qoff..qoff + hd];
                    for (ki, &p) in scores.iter().enumerate().take(qi + 1) {
                        let voff = (b * s + ki) * nkv * hd + kh * hd;
                        for (a, b_) in arow.iter_mut().zip(&v[voff..voff + hd]) {
                            *a += p * b_;
                        }
                    }
                }
            }
        }
        let mut h = h_in.to_vec();
        attn_out_and_mlp(cfg, &w, &mut h, &attn, tokens);
        // caches [B, KV, max_seq, hd], zero-padded past s
        let mut kc = vec![0f32; batch * nkv * ms * hd];
        let mut vc = vec![0f32; batch * nkv * ms * hd];
        for b in 0..batch {
            for si in 0..s {
                for kh in 0..nkv {
                    let src = (b * s + si) * nkv * hd + kh * hd;
                    let dst = cache_at(b, kh, si);
                    kc[dst..dst + hd].copy_from_slice(&k[src..src + hd]);
                    vc[dst..dst + hd].copy_from_slice(&v[src..src + hd]);
                }
            }
        }
        Ok(vec![
            TensorData::f32(h, vec![batch as i64, s as i64, d as i64]),
            TensorData::f32(kc, cache_dims.clone()),
            TensorData::f32(vc, cache_dims),
        ])
    } else if inputs.len() == 14 {
        // Paged decode: same math as the padded branch below, but K/V
        // for positions `< pos` are gathered through a per-row block
        // table out of `[capacity, kv_heads, block_size, head_dim]`
        // slabs, and the freshly computed K/V head vectors are
        // *returned* (`[batch, kv_heads, head_dim]`) for the stage
        // actor to write into its pool — the kernel never mutates the
        // slabs.  Position `pos` itself attends through the locally
        // roped k/v, which is bitwise what the padded branch reads back
        // after its own cache write, so paged and padded serving stay
        // byte-identical (`rust/tests/paged_kv.rs`).
        let (h_in, h_dims) = f32_input(&inputs[9], "h")?;
        ensure!(
            h_dims == [batch as i64, 1, d as i64],
            "sim paged decode: h dims {h_dims:?}"
        );
        let (ks, ks_dims) = f32_input(&inputs[10], "k_slab")?;
        let (vs, vs_dims) = f32_input(&inputs[11], "v_slab")?;
        ensure!(
            ks_dims == vs_dims
                && ks_dims.len() == 4
                && ks_dims[1] == nkv as i64
                && ks_dims[3] == hd as i64,
            "sim paged decode: slab dims {ks_dims:?}/{vs_dims:?}"
        );
        let (cap, bs) = (ks_dims[0] as usize, ks_dims[2] as usize);
        ensure!(bs > 0, "sim paged decode: zero block size");
        let table = inputs[12].as_i32()?;
        let t_dims = inputs[12].dims();
        ensure!(
            t_dims.len() == 2 && t_dims[0] == batch as i64,
            "sim paged decode: table dims {t_dims:?}"
        );
        let mb = t_dims[1] as usize;
        let pos_raw = inputs[13].as_i32()?;
        ensure!(
            !inputs[13].dims().is_empty() && pos_raw.len() == batch,
            "sim paged decode: pos must be a [batch] vector"
        );
        let pos_rows = pos_raw.to_vec();
        for &p in &pos_rows {
            ensure!(
                p < ms as i32 && (p < 0 || (p as usize / bs) < mb),
                "sim paged decode: pos {p} out of range"
            );
        }
        let slab_at = |blk: usize, kh: usize, s: usize| ((blk * nkv + kh) * bs + s) * hd;
        let mut x = rms_norm(h_in, w.attn_norm, batch, d);
        for (b, &p) in pos_rows.iter().enumerate() {
            if p < 0 {
                x[b * d..(b + 1) * d].fill(0.0);
            }
        }
        let mut q = matmul(&x, w.wq, batch, d, nh * hd);
        let mut k = matmul(&x, w.wk, batch, d, nkv * hd);
        let v = matmul(&x, w.wv, batch, d, nkv * hd);
        for (b, &p) in pos_rows.iter().enumerate() {
            if p < 0 {
                continue;
            }
            for hh in 0..nh {
                let off = b * nh * hd + hh * hd;
                rope_rotate(&mut q[off..off + hd], p as usize, 10000.0);
            }
            for kh in 0..nkv {
                let off = b * nkv * hd + kh * hd;
                rope_rotate(&mut k[off..off + hd], p as usize, 10000.0);
            }
        }
        let mut attn = vec![0f32; batch * nh * hd];
        for (b, &p) in pos_rows.iter().enumerate() {
            if p < 0 {
                continue;
            }
            let pos = p as usize;
            let mut scores = vec![0f32; pos + 1];
            for hh in 0..nh {
                let kh = hh / reps.max(1);
                let qoff = b * nh * hd + hh * hd;
                let qv = &q[qoff..qoff + hd];
                let self_off = b * nkv * hd + kh * hd;
                for (ki, sc) in scores.iter_mut().enumerate() {
                    let krow = if ki == pos {
                        &k[self_off..self_off + hd]
                    } else {
                        let blk = table[b * mb + ki / bs];
                        ensure!(
                            blk >= 0 && (blk as usize) < cap,
                            "sim paged decode: row {b} position {ki} unmapped"
                        );
                        let koff = slab_at(blk as usize, kh, ki % bs);
                        &ks[koff..koff + hd]
                    };
                    let mut dot = 0f32;
                    for (a, b_) in qv.iter().zip(krow) {
                        dot += a * b_;
                    }
                    *sc = dot * scale;
                }
                softmax(&mut scores);
                let arow = &mut attn[qoff..qoff + hd];
                for (ki, &sp) in scores.iter().enumerate() {
                    let vrow = if ki == pos {
                        &v[self_off..self_off + hd]
                    } else {
                        let blk = table[b * mb + ki / bs] as usize;
                        let voff = slab_at(blk, kh, ki % bs);
                        &vs[voff..voff + hd]
                    };
                    for (a, b_) in arow.iter_mut().zip(vrow) {
                        *a += sp * b_;
                    }
                }
            }
        }
        let mut h = h_in.to_vec();
        for (b, &p) in pos_rows.iter().enumerate() {
            if p < 0 {
                h[b * d..(b + 1) * d].fill(0.0);
            }
        }
        attn_out_and_mlp(cfg, &w, &mut h, &attn, batch);
        let kv_dims = vec![batch as i64, nkv as i64, hd as i64];
        Ok(vec![
            TensorData::f32(h, vec![batch as i64, 1, d as i64]),
            TensorData::f32(k, kv_dims.clone()),
            TensorData::f32(v, kv_dims),
        ])
    } else {
        ensure!(
            inputs.len() == 13,
            "sim layer decode: want 9 weights + h + kc + vc + pos"
        );
        let (h_in, h_dims) = f32_input(&inputs[9], "h")?;
        ensure!(
            h_dims == [batch as i64, 1, d as i64],
            "sim layer decode: h dims {h_dims:?}"
        );
        let (kc_in, kc_dims) = f32_input(&inputs[10], "k_cache")?;
        let (vc_in, vc_dims) = f32_input(&inputs[11], "v_cache")?;
        ensure!(
            kc_dims == cache_dims.as_slice() && vc_dims == cache_dims.as_slice(),
            "sim layer decode: cache dims {kc_dims:?}/{vc_dims:?}"
        );
        // `pos` is either a scalar (classic group decode: every row at the
        // same absolute position) or a `[batch]` vector (continuous
        // batching: the per-iteration slot map — row i decodes at
        // `pos[i]`, and `pos[i] < 0` marks a dead row that is skipped
        // entirely: no compute, no cache write, zero output).
        let pos_raw = inputs[12].as_i32()?;
        let pos_rows: Vec<i32> = if inputs[12].dims().is_empty() {
            // scalar form is the classic whole-batch decode: dead-row
            // sentinels are only meaningful in the per-row slot map
            ensure!(
                pos_raw[0] >= 0,
                "sim layer decode: pos {} out of range",
                pos_raw[0]
            );
            vec![pos_raw[0]; batch]
        } else {
            ensure!(
                pos_raw.len() == batch,
                "sim layer decode: pos len {} != batch {batch}",
                pos_raw.len()
            );
            pos_raw.to_vec()
        };
        for &p in &pos_rows {
            ensure!(p < ms as i32, "sim layer decode: pos {p} out of range");
        }
        let mut x = rms_norm(h_in, w.attn_norm, batch, d);
        // Zero dead rows before the projections: the zero-skip fast path
        // in `matmul` makes them near-free, and row independence keeps
        // live rows byte-identical to a batch of any other composition.
        for (b, &p) in pos_rows.iter().enumerate() {
            if p < 0 {
                x[b * d..(b + 1) * d].fill(0.0);
            }
        }
        let mut q = matmul(&x, w.wq, batch, d, nh * hd);
        let mut k = matmul(&x, w.wk, batch, d, nkv * hd);
        let v = matmul(&x, w.wv, batch, d, nkv * hd);
        for (b, &p) in pos_rows.iter().enumerate() {
            if p < 0 {
                continue;
            }
            for hh in 0..nh {
                let off = b * nh * hd + hh * hd;
                rope_rotate(&mut q[off..off + hd], p as usize, 10000.0);
            }
            for kh in 0..nkv {
                let off = b * nkv * hd + kh * hd;
                rope_rotate(&mut k[off..off + hd], p as usize, 10000.0);
            }
        }
        let mut kc = kc_in.to_vec();
        let mut vc = vc_in.to_vec();
        for (b, &p) in pos_rows.iter().enumerate() {
            if p < 0 {
                continue;
            }
            for kh in 0..nkv {
                let dst = cache_at(b, kh, p as usize);
                let src = b * nkv * hd + kh * hd;
                kc[dst..dst + hd].copy_from_slice(&k[src..src + hd]);
                vc[dst..dst + hd].copy_from_slice(&v[src..src + hd]);
            }
        }
        let mut attn = vec![0f32; batch * nh * hd];
        for (b, &p) in pos_rows.iter().enumerate() {
            if p < 0 {
                continue;
            }
            let pos = p as usize;
            let mut scores = vec![0f32; pos + 1];
            for hh in 0..nh {
                let kh = hh / reps.max(1);
                let qoff = b * nh * hd + hh * hd;
                let qv = &q[qoff..qoff + hd];
                for (ki, sc) in scores.iter_mut().enumerate() {
                    let koff = cache_at(b, kh, ki);
                    let mut dot = 0f32;
                    for (a, b_) in qv.iter().zip(&kc[koff..koff + hd]) {
                        dot += a * b_;
                    }
                    *sc = dot * scale;
                }
                softmax(&mut scores);
                let arow = &mut attn[qoff..qoff + hd];
                for (ki, &sp) in scores.iter().enumerate() {
                    let voff = cache_at(b, kh, ki);
                    for (a, b_) in arow.iter_mut().zip(&vc[voff..voff + hd]) {
                        *a += sp * b_;
                    }
                }
            }
        }
        let mut h = h_in.to_vec();
        // Dead rows leave the layer as zeros (the residual stream of a
        // dead slot is not meaningful and must stay cheap downstream).
        for (b, &p) in pos_rows.iter().enumerate() {
            if p < 0 {
                h[b * d..(b + 1) * d].fill(0.0);
            }
        }
        attn_out_and_mlp(cfg, &w, &mut h, &attn, batch);
        Ok(vec![
            TensorData::f32(h, vec![batch as i64, 1, d as i64]),
            TensorData::f32(kc, cache_dims.clone()),
            TensorData::f32(vc, cache_dims),
        ])
    }
}

fn run_head(cfg: &ManifestConfig, batch: usize, inputs: &[TensorData]) -> Result<Vec<TensorData>> {
    ensure!(inputs.len() == 3, "sim head: want [final_norm, lm_head, h]");
    let d = cfg.d_model;
    let v = cfg.vocab_size;
    let (norm, norm_dims) = f32_input(&inputs[0], "final_norm")?;
    ensure!(norm_dims == [d as i64], "sim head: final_norm dims");
    let (lm, lm_dims) = f32_input(&inputs[1], "lm_head")?;
    ensure!(lm_dims == [d as i64, v as i64], "sim head: lm_head dims");
    let (h, h_dims) = f32_input(&inputs[2], "h")?;
    ensure!(
        h_dims.len() == 3 && h_dims[0] == batch as i64 && h_dims[2] == d as i64,
        "sim head: h dims {h_dims:?}"
    );
    let s = h_dims[1] as usize;
    // last position only, like python head_shard
    let mut last = vec![0f32; batch * d];
    for b in 0..batch {
        let src = (b * s + (s - 1)) * d;
        last[b * d..(b + 1) * d].copy_from_slice(&h[src..src + d]);
    }
    let x = rms_norm(&last, norm, batch, d);
    let logits = matmul(&x, lm, batch, d, v);
    Ok(vec![TensorData::f32(logits, vec![batch as i64, v as i64])])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Manifest, WeightStore};

    fn setup() -> (Manifest, WeightStore) {
        let m = Manifest::synthetic_tiny();
        let w = WeightStore::synthetic(&m, 0);
        (m, w)
    }

    fn as_td(data: &[f32], shape: &[usize]) -> TensorData {
        TensorData::f32(data.to_vec(), shape.iter().map(|&x| x as i64).collect())
    }

    fn layer_inputs(m: &Manifest, w: &WeightStore, layer: usize) -> Vec<TensorData> {
        w.layer_params(m, layer)
            .unwrap()
            .into_iter()
            .map(|(d, s)| as_td(d, s))
            .collect()
    }

    #[test]
    fn embed_is_table_lookup() {
        let (m, w) = setup();
        let (emb, _) = w.get("tok_emb").unwrap();
        let d = m.config.d_model;
        let mut inputs = vec![as_td(emb, &[m.config.vocab_size, d])];
        inputs.push(TensorData::i32(vec![5], vec![1, 1]));
        let out = run_variant(&m.config, "embed_decode_b1", &inputs).unwrap();
        assert_eq!(out[0].dims(), &[1, 1, d as i64]);
        let got = out[0].as_f32().unwrap();
        assert_eq!(got, &emb[5 * d..6 * d]);
    }

    #[test]
    fn prefill_then_decode_matches_full_prefill() {
        // Core KV-cache invariant: prefilling S tokens then decoding token
        // S must equal prefilling S+1 tokens directly (same final hidden).
        let (m, w) = setup();
        let c = &m.config;
        let d = c.d_model;
        let toks: Vec<i32> = (0..9).map(|i| (i * 7 + 3) % c.vocab_size as i32).collect();
        let (emb, _) = w.get("tok_emb").unwrap();
        let embed = |tokens: &[i32]| -> Vec<f32> {
            let mut h = Vec::new();
            for &t in tokens {
                h.extend_from_slice(&emb[t as usize * d..(t as usize + 1) * d]);
            }
            h
        };

        // full prefill over 9 tokens
        let h9 = embed(&toks);
        let mut inputs = layer_inputs(&m, &w, 0);
        inputs.push(as_td(&h9, &[1, 9, d]));
        let full = run_variant(c, "layer_prefill_b1", &inputs).unwrap();
        let h_full = full[0].as_f32().unwrap();

        // prefill 8, then decode the 9th through the cache
        let h8 = embed(&toks[..8]);
        let mut inputs = layer_inputs(&m, &w, 0);
        inputs.push(as_td(&h8, &[1, 8, d]));
        let pre = run_variant(c, "layer_prefill_b1", &inputs).unwrap();
        let mut inputs = layer_inputs(&m, &w, 0);
        inputs.push(as_td(&embed(&toks[8..9]), &[1, 1, d]));
        inputs.push(pre[1].clone());
        inputs.push(pre[2].clone());
        inputs.push(TensorData::scalar_i32(8));
        let dec = run_variant(c, "layer_decode_b1", &inputs).unwrap();
        let h_dec = dec[0].as_f32().unwrap();

        let last_full = &h_full[8 * d..9 * d];
        for (a, b) in last_full.iter().zip(h_dec) {
            assert!((a - b).abs() < 1e-4, "full={a} dec={b}");
        }
    }

    #[test]
    fn decode_writes_cache_at_pos_only() {
        let (m, w) = setup();
        let c = &m.config;
        let (nkv, ms, hd, d) = (c.n_kv_heads, c.max_seq, c.head_dim(), c.d_model);
        let cache_len = nkv * ms * hd;
        let mut inputs = layer_inputs(&m, &w, 0);
        inputs.push(as_td(&vec![0.1; d], &[1, 1, d]));
        inputs.push(as_td(&vec![0.0; cache_len], &[1, nkv, ms, hd]));
        inputs.push(as_td(&vec![0.0; cache_len], &[1, nkv, ms, hd]));
        inputs.push(TensorData::scalar_i32(3));
        let out = run_variant(c, "layer_decode_b1", &inputs).unwrap();
        assert_eq!(out.len(), 3);
        let kc = out[1].as_f32().unwrap();
        let at = |pos: usize| -> f32 {
            (0..nkv)
                .map(|kh| {
                    kc[kh * ms * hd + pos * hd..kh * ms * hd + pos * hd + hd]
                        .iter()
                        .map(|x| x.abs())
                        .sum::<f32>()
                })
                .sum()
        };
        assert!(at(3) > 0.0);
        assert_eq!(at(2), 0.0);
        assert_eq!(at(4), 0.0);
    }

    #[test]
    fn head_takes_last_position() {
        let (m, w) = setup();
        let c = &m.config;
        let d = c.d_model;
        let (norm, _) = w.get("final_norm").unwrap();
        let (lm, _) = w.get("lm_head").unwrap();
        let mut h = vec![0.0f32; 2 * 3 * d];
        // batch 2, seq 3 — make the last position distinctive per row
        for b in 0..2 {
            for i in 0..d {
                h[(b * 3 + 2) * d + i] = (i as f32 + 1.0) * (b as f32 + 1.0) * 0.01;
            }
        }
        let inputs = vec![
            as_td(norm, &[d]),
            as_td(lm, &[d, c.vocab_size]),
            as_td(&h, &[2, 3, d]),
        ];
        let out = run_variant(c, "head_prefill_b2", &inputs).unwrap();
        assert_eq!(out[0].dims(), &[2, c.vocab_size as i64]);
        let logits = out[0].as_f32().unwrap();
        // rows differ (different last hidden) and are finite
        assert!(logits.iter().all(|x| x.is_finite()));
        let r0 = &logits[..c.vocab_size];
        let r1 = &logits[c.vocab_size..];
        assert!(r0.iter().zip(r1).any(|(a, b)| (a - b).abs() > 1e-9));
    }

    #[test]
    fn per_row_pos_matches_scalar_and_skips_dead_rows() {
        // Continuous batching decodes a composed batch where each row sits
        // at its own absolute position; live rows must be byte-identical
        // to a scalar-pos decode of the same row, and dead rows (pos < 0)
        // must produce zero output and leave their cache rows untouched.
        let (m, w) = setup();
        let c = &m.config;
        let (d, nkv, ms, hd) = (c.d_model, c.n_kv_heads, c.max_seq, c.head_dim());
        let cache_len = nkv * ms * hd;

        // reference: row alone at pos 5, batch 1, scalar pos
        let h_row: Vec<f32> = (0..d).map(|i| ((i % 7) as f32 - 3.0) * 0.07).collect();
        let mut inputs = layer_inputs(&m, &w, 0);
        inputs.push(as_td(&h_row, &[1, 1, d]));
        inputs.push(as_td(&vec![0.25; cache_len], &[1, nkv, ms, hd]));
        inputs.push(as_td(&vec![0.5; cache_len], &[1, nkv, ms, hd]));
        inputs.push(TensorData::scalar_i32(5));
        let solo = run_variant(c, "layer_decode_b1", &inputs).unwrap();

        // batch 3: dead row, the live row at pos 5, another dead row
        let mut h3 = vec![0.9f32; 3 * d]; // garbage in dead rows
        h3[d..2 * d].copy_from_slice(&h_row);
        let mut kc3 = vec![7.0f32; 3 * cache_len]; // sentinel in dead rows
        let mut vc3 = vec![8.0f32; 3 * cache_len];
        kc3[cache_len..2 * cache_len].fill(0.25);
        vc3[cache_len..2 * cache_len].fill(0.5);
        let mut inputs = layer_inputs(&m, &w, 0);
        inputs.push(as_td(&h3, &[3, 1, d]));
        inputs.push(as_td(&kc3, &[3, nkv, ms, hd]));
        inputs.push(as_td(&vc3, &[3, nkv, ms, hd]));
        inputs.push(TensorData::i32(vec![-1, 5, -1], vec![3]));
        let mixed = run_variant(c, "layer_decode_b3", &inputs).unwrap();

        let h_solo = solo[0].as_f32().unwrap();
        let h_mixed = mixed[0].as_f32().unwrap();
        assert_eq!(&h_mixed[d..2 * d], h_solo, "live row diverged");
        assert!(h_mixed[..d].iter().all(|&x| x == 0.0), "dead row not zeroed");
        assert!(h_mixed[2 * d..].iter().all(|&x| x == 0.0));
        let kc_out = mixed[1].as_f32().unwrap();
        assert_eq!(
            &kc_out[cache_len..2 * cache_len],
            solo[1].as_f32().unwrap(),
            "live cache row diverged"
        );
        assert!(kc_out[..cache_len].iter().all(|&x| x == 7.0), "dead cache row touched");
    }

    #[test]
    fn paged_decode_is_bitwise_identical_to_padded() {
        // The paged branch must read exactly the same f32 values in
        // exactly the same order as the padded branch — scattering the
        // blocks non-contiguously through the slab proves the table
        // indirection, and bitwise equality (==, not approx) proves the
        // accumulation order never changed.
        let (m, w) = setup();
        let c = &m.config;
        let (d, nkv, ms, hd) = (c.d_model, c.n_kv_heads, c.max_seq, c.head_dim());
        let prompt = 6usize;
        let bs = 4usize; // prompt spans 2 blocks, the second half-full

        // prefill a row to get a real padded cache
        let h_pre: Vec<f32> = (0..prompt * d).map(|i| ((i % 11) as f32 - 5.0) * 0.03).collect();
        let mut inputs = layer_inputs(&m, &w, 0);
        inputs.push(as_td(&h_pre, &[1, prompt, d]));
        let pre = run_variant(c, "layer_prefill_b1", &inputs).unwrap();

        // padded decode at pos = prompt
        let h_row: Vec<f32> = (0..d).map(|i| ((i % 5) as f32 - 2.0) * 0.05).collect();
        let mut inputs = layer_inputs(&m, &w, 0);
        inputs.push(as_td(&h_row, &[1, 1, d]));
        inputs.push(pre[1].clone());
        inputs.push(pre[2].clone());
        inputs.push(TensorData::i32(vec![prompt as i32], vec![1]));
        let padded = run_variant(c, "layer_decode_b1", &inputs).unwrap();

        // chop the prefill cache into scattered slab blocks [5, 2]
        let blocks = [5usize, 2usize];
        let cap = 7usize;
        let (kc, vc) = (pre[1].as_f32().unwrap(), pre[2].as_f32().unwrap());
        let slab_len = cap * nkv * bs * hd;
        let (mut ks, mut vs) = (vec![0f32; slab_len], vec![0f32; slab_len]);
        for p in 0..prompt {
            let blk = blocks[p / bs];
            for kh in 0..nkv {
                let s = (kh * ms + p) * hd;
                let dst = ((blk * nkv + kh) * bs + p % bs) * hd;
                ks[dst..dst + hd].copy_from_slice(&kc[s..s + hd]);
                vs[dst..dst + hd].copy_from_slice(&vc[s..s + hd]);
            }
        }
        let mb = ms.div_ceil(bs);
        let mut table = vec![-1i32; mb];
        table[0] = blocks[0] as i32;
        table[1] = blocks[1] as i32;

        let mut inputs = layer_inputs(&m, &w, 0);
        inputs.push(as_td(&h_row, &[1, 1, d]));
        inputs.push(as_td(&ks, &[cap, nkv, bs, hd]));
        inputs.push(as_td(&vs, &[cap, nkv, bs, hd]));
        inputs.push(TensorData::i32(table, vec![1, mb as i64]));
        inputs.push(TensorData::i32(vec![prompt as i32], vec![1]));
        let paged = run_variant(c, "layer_decode_b1", &inputs).unwrap();

        assert_eq!(
            paged[0].as_f32().unwrap(),
            padded[0].as_f32().unwrap(),
            "paged hidden diverged from padded"
        );
        // returned k/v head vectors == what the padded branch wrote at pos
        assert_eq!(paged[1].dims(), &[1, nkv as i64, hd as i64]);
        let kc_out = padded[1].as_f32().unwrap();
        let k_new = paged[1].as_f32().unwrap();
        for kh in 0..nkv {
            let s = (kh * ms + prompt) * hd;
            assert_eq!(&k_new[kh * hd..(kh + 1) * hd], &kc_out[s..s + hd]);
        }
    }

    #[test]
    fn gqa_heads_share_kv() {
        // A GQA config (4 q heads, 2 kv heads) must run and keep cache
        // dims at kv-head granularity.
        let mut cfg = Manifest::synthetic_tiny().config;
        cfg.n_heads = 4;
        cfg.n_kv_heads = 2;
        cfg.d_model = 64;
        cfg.d_ff = 128;
        let m = Manifest::synthetic(cfg, vec![1]);
        let w = WeightStore::synthetic(&m, 0);
        let c = &m.config;
        let mut inputs: Vec<TensorData> = w
            .layer_params(&m, 0)
            .unwrap()
            .into_iter()
            .map(|(d, s)| as_td(d, s))
            .collect();
        inputs.push(as_td(&vec![0.05; 4 * c.d_model], &[1, 4, c.d_model]));
        let out = run_variant(c, "layer_prefill_b1", &inputs).unwrap();
        assert_eq!(
            out[1].dims(),
            &[1, c.n_kv_heads as i64, c.max_seq as i64, c.head_dim() as i64]
        );
    }

    #[test]
    fn chunked_prefill_matches_monolithic_bitwise() {
        // Splitting a prompt into chunks streamed through the append
        // branch must reproduce the monolithic prefill exactly (==, not
        // approx): hidden rows and final caches.
        let (m, w) = setup();
        let c = &m.config;
        let d = c.d_model;
        let s = 9usize;
        let h_full: Vec<f32> = (0..s * d).map(|i| ((i % 13) as f32 - 6.0) * 0.04).collect();
        let mut inputs = layer_inputs(&m, &w, 0);
        inputs.push(as_td(&h_full, &[1, s, d]));
        let mono = run_variant(c, "layer_prefill_b1", &inputs).unwrap();

        for chunk in [1usize, 2, 4, 5, 8] {
            // chunk 0 through the fresh branch
            let c0 = chunk.min(s);
            let mut inputs = layer_inputs(&m, &w, 0);
            inputs.push(as_td(&h_full[..c0 * d], &[1, c0, d]));
            let mut out = run_variant(c, "layer_prefill_b1", &inputs).unwrap();
            let mut h_parts: Vec<f32> = out[0].as_f32().unwrap().to_vec();
            let mut start = c0;
            while start < s {
                let len = chunk.min(s - start);
                let mut inputs = layer_inputs(&m, &w, 0);
                inputs.push(as_td(&h_full[start * d..(start + len) * d], &[1, len, d]));
                inputs.push(out[1].clone());
                inputs.push(out[2].clone());
                inputs.push(TensorData::scalar_i32(start as i32));
                out = run_variant(c, "layer_prefill_b1", &inputs).unwrap();
                h_parts.extend_from_slice(out[0].as_f32().unwrap());
                start += len;
            }
            assert_eq!(h_parts, mono[0].as_f32().unwrap(), "chunk={chunk} hidden diverged");
            assert_eq!(out[1].as_f32().unwrap(), mono[1].as_f32().unwrap(), "chunk={chunk} k cache");
            assert_eq!(out[2].as_f32().unwrap(), mono[2].as_f32().unwrap(), "chunk={chunk} v cache");
        }
    }

    #[test]
    fn chunk_append_rejects_overflow_and_bad_start() {
        let (m, w) = setup();
        let c = &m.config;
        let (d, nkv, ms, hd) = (c.d_model, c.n_kv_heads, c.max_seq, c.head_dim());
        let cache_len = nkv * ms * hd;
        let mut base = layer_inputs(&m, &w, 0);
        base.push(as_td(&vec![0.1; 2 * d], &[1, 2, d]));
        base.push(as_td(&vec![0.0; cache_len], &[1, nkv, ms, hd]));
        base.push(as_td(&vec![0.0; cache_len], &[1, nkv, ms, hd]));
        let mut over = base.clone();
        over.push(TensorData::scalar_i32(ms as i32 - 1)); // start+2 > max_seq
        assert!(run_variant(c, "layer_prefill_b1", &over).is_err());
        let mut neg = base.clone();
        neg.push(TensorData::scalar_i32(-1));
        assert!(run_variant(c, "layer_prefill_b1", &neg).is_err());
    }

    #[test]
    fn quantize_round_trip_error_bounded() {
        // Property over seeded pseudo-random tensors: per-row round trip
        // stays within half a quantization step of each row's own scale.
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            // uniform-ish in [-8, 8) with varying magnitude per draw
            ((state >> 11) as f64 / (1u64 << 53) as f64 * 16.0 - 8.0) as f32
        };
        for (rows, row_len) in [(1usize, 64usize), (7, 33), (16, 128), (3, 1)] {
            let mut data = vec![0f32; rows * row_len];
            for v in data.iter_mut() {
                *v = next();
            }
            // exercise wildly different scales per row
            for r in 0..rows {
                let amp = 10f32.powi(r as i32 % 7 - 3);
                for v in data[r * row_len..(r + 1) * row_len].iter_mut() {
                    *v *= amp;
                }
            }
            let (q, scales) = quantize_rows_i8(&data, rows);
            assert_eq!(scales.len(), rows);
            let back = dequantize_rows_i8(&q, &scales, rows);
            for r in 0..rows {
                let row = &data[r * row_len..(r + 1) * row_len];
                let max_abs = row.iter().fold(0f32, |m, v| m.max(v.abs()));
                let bound = max_abs / 127.0 * 0.5 + 1e-12;
                for (a, b) in row.iter().zip(&back[r * row_len..(r + 1) * row_len]) {
                    assert!(
                        (a - b).abs() <= bound * 1.001,
                        "rows={rows} row={r}: {a} vs {b} (bound {bound})"
                    );
                }
            }
        }
        // zero rows survive exactly
        let (q, s) = quantize_rows_i8(&[0.0; 8], 2);
        assert!(q.iter().all(|&x| x == 0) && s.iter().all(|&x| x == 0.0));
        assert_eq!(dequantize_rows_i8(&q, &s, 2), vec![0.0; 8]);
    }

    #[test]
    fn unknown_variants_rejected() {
        let (m, _) = setup();
        assert!(run_variant(&m.config, "layer_train_b1", &[]).is_err());
        assert!(run_variant(&m.config, "nope", &[]).is_err());
        assert!(run_variant(&m.config, "layer_decode_bx", &[]).is_err());
    }
}
