//! Shard execution: compile `artifacts/*.hlo.txt` on the PJRT CPU client
//! and serve execution requests from the device actors.
//!
//! The `xla` crate's handles wrap raw pointers behind `Rc`, so they are
//! `!Send`: [`ExecService`] therefore owns the client + every compiled
//! executable on ONE dedicated thread and exposes a cloneable, `Send`
//! [`ExecServiceHandle`] speaking plain-data [`TensorData`] over channels.
//! (On this testbed all simulated devices share one physical CPU, so a
//! single execution queue is also the honest performance model.)

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::manifest::Manifest;

/// Plain-data tensor crossing thread / simulated-network boundaries.
///
/// Payloads are `Arc`-shared: stage actors clone per-layer weight tensors
/// into every execution request, and KV caches are re-submitted each
/// decode step — `clone()` must stay O(1) for the hot path.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32 { data: Arc<Vec<f32>>, dims: Vec<i64> },
    I32 { data: Arc<Vec<i32>>, dims: Vec<i64> },
}

impl TensorData {
    pub fn f32(data: Vec<f32>, dims: Vec<i64>) -> Self {
        debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
        TensorData::F32 {
            data: Arc::new(data),
            dims,
        }
    }

    pub fn i32(data: Vec<i32>, dims: Vec<i64>) -> Self {
        debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
        TensorData::I32 {
            data: Arc::new(data),
            dims,
        }
    }

    pub fn scalar_i32(v: i32) -> Self {
        TensorData::I32 {
            data: Arc::new(vec![v]),
            dims: vec![],
        }
    }

    pub fn dims(&self) -> &[i64] {
        match self {
            TensorData::F32 { dims, .. } | TensorData::I32 { dims, .. } => dims,
        }
    }

    /// Wire size in bytes (for the shaped links).
    pub fn bytes(&self) -> u64 {
        match self {
            TensorData::F32 { data, .. } => data.len() as u64 * 4,
            TensorData::I32 { data, .. } => data.len() as u64 * 4,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorData::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorData::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            TensorData::F32 { data, dims } => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(dims)?
                }
            }
            TensorData::I32 { data, dims } => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(dims)?
                }
            }
        })
    }

    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<i64> = shape.dims().to_vec();
        match shape.ty() {
            xla::ElementType::F32 => Ok(TensorData::F32 {
                data: Arc::new(lit.to_vec::<f32>()?),
                dims,
            }),
            xla::ElementType::S32 => Ok(TensorData::I32 {
                data: Arc::new(lit.to_vec::<i32>()?),
                dims,
            }),
            other => bail!("unsupported element type {other:?}"),
        }
    }
}

/// Handle to a set of tensors registered (converted to literals once)
/// inside the exec service — the weight tensors of one shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegId(u64);

enum Req {
    /// Convert `tensors` to literals once; subsequent `Exec` calls can
    /// reference them as an input prefix.  This is the hot-path
    /// optimization that keeps per-token weight copies out of the decode
    /// loop (EXPERIMENTS.md §Perf).
    Register {
        tensors: Vec<TensorData>,
        reply: Sender<Result<RegId>>,
    },
    Exec {
        variant: String,
        /// Registered literals prepended to `inputs`.
        prefix: Option<RegId>,
        inputs: Vec<TensorData>,
        reply: Sender<Result<(Vec<TensorData>, f64)>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the execution thread.
#[derive(Clone)]
pub struct ExecServiceHandle {
    tx: Sender<Req>,
}

impl ExecServiceHandle {
    /// Register tensors (typically a shard's weights) once; returns a
    /// handle usable as an input prefix in [`Self::exec_prefixed`].
    pub fn register(&self, tensors: Vec<TensorData>) -> Result<RegId> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Register { tensors, reply })
            .map_err(|_| anyhow!("exec service stopped"))?;
        rx.recv().map_err(|_| anyhow!("exec service dropped reply"))?
    }

    /// Execute artifact `variant` with `inputs`; returns the decomposed
    /// tuple outputs plus the pure-execution wall time in ms.
    pub fn exec_timed(
        &self,
        variant: &str,
        inputs: Vec<TensorData>,
    ) -> Result<(Vec<TensorData>, f64)> {
        self.exec_prefixed(None, variant, inputs)
    }

    /// Like [`Self::exec_timed`], with registered literals prepended.
    pub fn exec_prefixed(
        &self,
        prefix: Option<RegId>,
        variant: &str,
        inputs: Vec<TensorData>,
    ) -> Result<(Vec<TensorData>, f64)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Exec {
                variant: variant.to_string(),
                prefix,
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("exec service stopped"))?;
        rx.recv().map_err(|_| anyhow!("exec service dropped reply"))?
    }

    pub fn exec(&self, variant: &str, inputs: Vec<TensorData>) -> Result<Vec<TensorData>> {
        Ok(self.exec_timed(variant, inputs)?.0)
    }
}

/// Owns the PJRT client thread; dropping shuts it down.
pub struct ExecService {
    tx: Sender<Req>,
    join: Option<JoinHandle<()>>,
}

impl ExecService {
    /// Start the **sim backend**: the same service-thread protocol, but
    /// every variant executes through the pure-rust reference math in
    /// [`crate::runtime::sim`] instead of PJRT.  Works with a synthetic
    /// manifest ([`Manifest::synthetic`]) — no artifacts, no `xla`.
    pub fn start_sim(manifest: &Manifest) -> Result<(Self, ExecServiceHandle)> {
        let (tx, rx) = mpsc::channel::<Req>();
        let cfg = manifest.config.clone();
        let join = std::thread::Builder::new()
            .name("sim-exec".into())
            .spawn(move || {
                let mut registered: Vec<Vec<TensorData>> = Vec::new();
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Shutdown => break,
                        Req::Register { tensors, reply } => {
                            registered.push(tensors);
                            let _ = reply.send(Ok(RegId(registered.len() as u64 - 1)));
                        }
                        Req::Exec {
                            variant,
                            prefix,
                            inputs,
                            reply,
                        } => {
                            let out = (|| -> Result<(Vec<TensorData>, f64)> {
                                let mut all: Vec<TensorData> = Vec::new();
                                if let Some(RegId(i)) = prefix {
                                    let pre = registered
                                        .get(i as usize)
                                        .ok_or_else(|| anyhow!("bad RegId"))?;
                                    all.extend(pre.iter().cloned());
                                }
                                all.extend(inputs);
                                let start = Instant::now();
                                let outputs = super::sim::run_variant(&cfg, &variant, &all)?;
                                let ms = start.elapsed().as_secs_f64() * 1e3;
                                Ok((outputs, ms))
                            })();
                            let _ = reply.send(out);
                        }
                    }
                }
            })
            .context("spawning sim-exec thread")?;
        Ok((
            ExecService {
                tx: tx.clone(),
                join: Some(join),
            },
            ExecServiceHandle { tx },
        ))
    }

    /// Compile every artifact in the manifest on a fresh CPU client.
    pub fn start(manifest: &Manifest) -> Result<(Self, ExecServiceHandle)> {
        let (tx, rx) = mpsc::channel::<Req>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let dir = manifest.dir.clone();
        let names: Vec<(String, String)> = manifest
            .artifacts
            .iter()
            .map(|a| (a.name.clone(), a.file.clone()))
            .collect();
        let join = std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || {
                let setup = (|| -> Result<HashMap<String, xla::PjRtLoadedExecutable>> {
                    let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
                    let mut exes = HashMap::new();
                    for (name, file) in &names {
                        let path = dir.join(file);
                        let proto = xla::HloModuleProto::from_text_file(&path)
                            .with_context(|| format!("parsing {path:?}"))?;
                        let comp = xla::XlaComputation::from_proto(&proto);
                        let exe = client
                            .compile(&comp)
                            .with_context(|| format!("compiling {name}"))?;
                        exes.insert(name.clone(), exe);
                    }
                    Ok(exes)
                })();
                let exes = match setup {
                    Ok(exes) => {
                        let _ = ready_tx.send(Ok(()));
                        exes
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut registered: Vec<Vec<xla::Literal>> = Vec::new();
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Shutdown => break,
                        Req::Register { tensors, reply } => {
                            let lits: Result<Vec<xla::Literal>> =
                                tensors.iter().map(|t| t.to_literal()).collect();
                            let _ = reply.send(lits.map(|l| {
                                registered.push(l);
                                RegId(registered.len() as u64 - 1)
                            }));
                        }
                        Req::Exec {
                            variant,
                            prefix,
                            inputs,
                            reply,
                        } => {
                            let pre = prefix.map(|RegId(i)| registered.get(i as usize));
                            let out = match pre {
                                Some(None) => Err(anyhow!("bad RegId")),
                                Some(Some(p)) => run_one(&exes, &variant, Some(p), inputs),
                                None => run_one(&exes, &variant, None, inputs),
                            };
                            let _ = reply.send(out);
                        }
                    }
                }
            })
            .context("spawning pjrt-exec thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("exec thread died during setup"))??;
        Ok((
            ExecService {
                tx: tx.clone(),
                join: Some(join),
            },
            ExecServiceHandle { tx },
        ))
    }
}

impl Drop for ExecService {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn run_one(
    exes: &HashMap<String, xla::PjRtLoadedExecutable>,
    variant: &str,
    prefix: Option<&Vec<xla::Literal>>,
    inputs: Vec<TensorData>,
) -> Result<(Vec<TensorData>, f64)> {
    let exe = exes
        .get(variant)
        .ok_or_else(|| anyhow!("unknown artifact `{variant}`"))?;
    let dyn_lits: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<_>>()?;
    let all: Vec<&xla::Literal> = prefix
        .map(|p| p.iter())
        .into_iter()
        .flatten()
        .chain(dyn_lits.iter())
        .collect();
    let start = Instant::now();
    let result = exe.execute::<&xla::Literal>(&all)?;
    let tuple = result[0][0].to_literal_sync()?;
    let ms = start.elapsed().as_secs_f64() * 1e3;
    // aot.py lowers with return_tuple=True: single tuple output.
    let parts = tuple.to_tuple()?;
    let outputs = parts
        .iter()
        .map(TensorData::from_literal)
        .collect::<Result<_>>()?;
    Ok((outputs, ms))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service() -> Option<(ExecService, ExecServiceHandle, Manifest)> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let m = Manifest::load(dir).unwrap();
        let (svc, h) = ExecService::start(&m).unwrap();
        Some((svc, h, m))
    }

    #[test]
    fn embed_lookup_matches_weights() {
        let Some((_svc, h, m)) = service() else { return };
        let w = super::super::WeightStore::load(&m).unwrap();
        let (emb, _) = w.get("tok_emb").unwrap();
        let d = m.config.d_model;
        let tok = 7i32;
        let out = h
            .exec(
                "embed_decode_b1",
                vec![
                    TensorData::f32(emb.to_vec(), vec![m.config.vocab_size as i64, d as i64]),
                    TensorData::i32(vec![tok], vec![1, 1]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let h_out = out[0].as_f32().unwrap();
        assert_eq!(h_out.len(), d);
        let expect = &emb[tok as usize * d..(tok as usize + 1) * d];
        for (a, b) in h_out.iter().zip(expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn layer_decode_shapes_and_cache_write() {
        let Some((_svc, h, m)) = service() else { return };
        let w = super::super::WeightStore::load(&m).unwrap();
        let c = &m.config;
        let (d, kv, ms_, hd) = (c.d_model, c.n_kv_heads, c.max_seq, c.head_dim());
        let mut inputs: Vec<TensorData> = w
            .layer_params(&m, 0)
            .unwrap()
            .into_iter()
            .map(|(data, shape)| {
                TensorData::f32(data.to_vec(), shape.iter().map(|&x| x as i64).collect())
            })
            .collect();
        inputs.push(TensorData::f32(vec![0.1; d], vec![1, 1, d as i64]));
        let cache_dims = vec![1, kv as i64, ms_ as i64, hd as i64];
        let cache_len = kv * ms_ * hd;
        inputs.push(TensorData::f32(vec![0.0; cache_len], cache_dims.clone()));
        inputs.push(TensorData::f32(vec![0.0; cache_len], cache_dims.clone()));
        inputs.push(TensorData::scalar_i32(3));
        let out = h.exec("layer_decode_b1", inputs).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].dims(), &[1, 1, d as i64]);
        assert_eq!(out[1].dims(), cache_dims.as_slice());
        // position 3 of the k-cache must now be non-zero, position 4 zero
        let kc = out[1].as_f32().unwrap();
        let at = |pos: usize| -> f32 {
            (0..kv)
                .map(|h_| {
                    kc[h_ * ms_ * hd + pos * hd..h_ * ms_ * hd + pos * hd + hd]
                        .iter()
                        .map(|x| x.abs())
                        .sum::<f32>()
                })
                .sum()
        };
        assert!(at(3) > 0.0);
        assert_eq!(at(4), 0.0);
        assert_eq!(at(2), 0.0);
    }

    #[test]
    fn unknown_variant_errors() {
        let Some((_svc, h, _m)) = service() else { return };
        assert!(h.exec("nope", vec![]).is_err());
    }

    #[test]
    fn sim_service_executes_registered_weights() {
        let m = Manifest::synthetic_tiny();
        let w = super::super::WeightStore::synthetic(&m, 0);
        let (_svc, h) = ExecService::start_sim(&m).unwrap();
        let (emb, s) = w.get("tok_emb").unwrap();
        let reg = h
            .register(vec![TensorData::f32(
                emb.to_vec(),
                s.iter().map(|&x| x as i64).collect(),
            )])
            .unwrap();
        let (out, ms) = h
            .exec_prefixed(
                Some(reg),
                "embed_decode_b1",
                vec![TensorData::i32(vec![3], vec![1, 1])],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims(), &[1, 1, m.config.d_model as i64]);
        assert!(ms >= 0.0);
        assert!(h.exec("layer_decode_b1", vec![]).is_err());
    }

    #[test]
    fn tensor_data_roundtrip() {
        let t = TensorData::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let lit = t.to_literal().unwrap();
        let back = TensorData::from_literal(&lit).unwrap();
        assert_eq!(t, back);
        let s = TensorData::scalar_i32(42);
        let lit = s.to_literal().unwrap();
        assert_eq!(TensorData::from_literal(&lit).unwrap(), s);
    }

    #[test]
    fn bytes_accounting() {
        assert_eq!(TensorData::f32(vec![0.0; 8], vec![8]).bytes(), 32);
        assert_eq!(TensorData::scalar_i32(1).bytes(), 4);
    }
}
