//! Measured profiler: the paper's offline profiling stage, run against the
//! REAL AOT shard executables through PJRT.
//!
//! The testbed simulates M heterogeneous devices on one physical CPU, so
//! the measured per-shard wall time is taken as the cost on a reference
//! device class and scaled by each class's relative decode/prefill speed
//! (memory-bandwidth ratio for decode, TFLOPS ratio for prefill — the same
//! roofline reasoning as [`crate::profiler::AnalyticProfiler`], now
//! anchored to real measurements instead of first principles).

use anyhow::Result;

use super::manifest::Manifest;
use super::shard::{ExecServiceHandle, TensorData};
use super::weights::WeightStore;
use crate::cluster::Cluster;
use crate::model::ModelDesc;
use crate::profiler::{ProfiledTraces, Workload};

/// Profiles the tiny model's real shards.
pub struct MeasuredProfiler<'a> {
    pub manifest: &'a Manifest,
    pub weights: &'a WeightStore,
    pub exec: ExecServiceHandle,
    /// Timing repetitions (median taken).
    pub reps: usize,
}

impl<'a> MeasuredProfiler<'a> {
    pub fn new(
        manifest: &'a Manifest,
        weights: &'a WeightStore,
        exec: ExecServiceHandle,
    ) -> Self {
        MeasuredProfiler {
            manifest,
            weights,
            exec,
            reps: 3,
        }
    }

    fn weight_inputs(&self, names: &[(&str, Vec<i64>)]) -> Result<Vec<TensorData>> {
        names
            .iter()
            .map(|(n, dims)| {
                let (data, _) = self.weights.get(n)?;
                Ok(TensorData::f32(data.to_vec(), dims.clone()))
            })
            .collect()
    }

    fn median(&self, variant: &str, inputs: &[TensorData]) -> Result<f64> {
        let mut times = Vec::with_capacity(self.reps);
        for _ in 0..self.reps.max(1) {
            let (_, ms) = self.exec.exec_timed(variant, inputs.to_vec())?;
            times.push(ms);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(times[times.len() / 2])
    }

    /// Measure (embed, layer, head) cost for one phase/batch variant.
    ///
    /// Returns per-shard ms on this CPU.
    pub fn measure_phase(&self, phase: &str, batch: usize) -> Result<(f64, f64, f64)> {
        let c = &self.manifest.config;
        let (d, kv, ms_, hd, v) = (
            c.d_model,
            c.n_kv_heads,
            c.max_seq,
            c.head_dim(),
            c.vocab_size,
        );
        let s = if phase == "prefill" { c.prefill_len } else { 1 };
        let b = batch as i64;

        // embed
        let mut inputs = self.weight_inputs(&[("tok_emb", vec![v as i64, d as i64])])?;
        inputs.push(TensorData::i32(
            vec![1; batch * s],
            vec![b, s as i64],
        ));
        let t_embed = self.median(&format!("embed_{phase}_b{batch}"), &inputs)?;

        // decoder layer
        let mut inputs: Vec<TensorData> = self
            .weights
            .layer_params(self.manifest, 0)?
            .into_iter()
            .map(|(data, shape)| {
                TensorData::f32(data.to_vec(), shape.iter().map(|&x| x as i64).collect())
            })
            .collect();
        inputs.push(TensorData::f32(
            vec![0.01; batch * s * d],
            vec![b, s as i64, d as i64],
        ));
        if phase == "decode" {
            let cache_dims = vec![b, kv as i64, ms_ as i64, hd as i64];
            let cache_len = batch * kv * ms_ * hd;
            inputs.push(TensorData::f32(vec![0.0; cache_len], cache_dims.clone()));
            inputs.push(TensorData::f32(vec![0.0; cache_len], cache_dims));
            inputs.push(TensorData::scalar_i32(c.prefill_len as i32));
        }
        let t_layer = self.median(&format!("layer_{phase}_b{batch}"), &inputs)?;

        // head
        let mut inputs = self.weight_inputs(&[
            ("final_norm", vec![d as i64]),
            ("lm_head", vec![d as i64, v as i64]),
        ])?;
        inputs.push(TensorData::f32(
            vec![0.01; batch * s * d],
            vec![b, s as i64, d as i64],
        ));
        let t_head = self.median(&format!("head_{phase}_b{batch}"), &inputs)?;

        Ok((t_embed, t_layer, t_head))
    }

    /// Build [`ProfiledTraces`] for the tiny model on `cluster`, scaling
    /// the measured reference times by per-class speed ratios.
    pub fn profile(&self, cluster: &Cluster, workload: Workload) -> Result<ProfiledTraces> {
        let batch = workload
            .batch
            .min(*self.manifest.batch_sizes.iter().max().unwrap_or(&1));
        let batch = if self.manifest.batch_sizes.contains(&batch) {
            batch
        } else {
            1
        };
        let (pe, pl, ph) = self.measure_phase("prefill", batch)?;
        let (de, dl, dh) = self.measure_phase("decode", batch)?;

        let model: ModelDesc = crate::model::tiny_from_manifest(self.manifest);
        let n = model.n_layers();
        let m = cluster.len();
        // reference class = the fastest (the physical CPU measurement)
        let ref_bw = cluster
            .devices
            .iter()
            .map(|d| d.class.mem_bw_gbps)
            .fold(0.0f64, f64::max);
        let ref_tf = cluster
            .devices
            .iter()
            .map(|d| d.class.tflops)
            .fold(0.0f64, f64::max);

        let iters = workload.iterations() as f64;
        let mut prefill = vec![vec![0.0; m]; n];
        let mut decode = vec![vec![0.0; m]; n];
        let mut avg = vec![vec![0.0; m]; n];
        for i in 0..n {
            let (p0, d0) = if i == 0 {
                (pe, de)
            } else if i == n - 1 {
                (ph, dh)
            } else {
                (pl, dl)
            };
            for j in 0..m {
                let dev = &cluster.devices[j].class;
                // decode is bandwidth-bound, prefill compute-bound
                let p = p0 * (ref_tf / dev.tflops);
                let dcd = d0 * (ref_bw / dev.mem_bw_gbps);
                prefill[i][j] = p;
                decode[i][j] = dcd;
                avg[i][j] = (p + (iters - 1.0) * dcd) / iters;
            }
        }
        let act_decode: Vec<u64> = (0..n)
            .map(|i| model.activation_bytes(i, 1) * batch as u64)
            .collect();
        let act_prefill: Vec<u64> = (0..n)
            .map(|i| model.activation_bytes(i, workload.prompt_len) * batch as u64)
            .collect();
        let act_avg: Vec<u64> = (0..n)
            .map(|i| {
                ((act_prefill[i] as f64 + (iters - 1.0) * act_decode[i] as f64) / iters) as u64
            })
            .collect();
        Ok(ProfiledTraces {
            model_name: model.name.clone(),
            n_layers: n,
            n_devices: m,
            workload,
            prefill_ms: prefill,
            decode_ms: decode,
            avg_ms: avg,
            act_bytes_decode: act_decode,
            act_bytes_prefill: act_prefill,
            act_bytes_avg: act_avg,
            weight_bytes: (0..n).map(|i| model.layer_weight_bytes(i)).collect(),
            kv_bytes_per_seq: (0..n)
                .map(|i| model.range_kv_bytes_per_seq(i, i + 1))
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::runtime::shard::ExecService;

    #[test]
    fn measured_traces_shape_and_scaling() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(dir).unwrap();
        let w = WeightStore::load(&m).unwrap();
        let (_svc, h) = ExecService::start(&m).unwrap();
        let mut p = MeasuredProfiler::new(&m, &w, h);
        p.reps = 1;
        let cluster = presets::tiny_demo(0);
        let t = p.profile(&cluster, Workload::paper_default()).unwrap();
        assert_eq!(t.n_layers, m.config.n_layers + 2);
        assert_eq!(t.n_devices, 3);
        // the 3090 (device 2) must be faster than the Orin NX (device 1)
        assert!(t.decode_ms[1][2] < t.decode_ms[1][1]);
        // all times positive
        assert!(t.decode_ms.iter().flatten().all(|&x| x > 0.0));
    }
}
