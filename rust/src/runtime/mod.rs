//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the request path — python is never involved.
//!
//! * [`manifest`] — the python→rust interchange contract.
//! * [`weights`] — loads `weights.bin` and slices per-layer tensors.
//! * [`shard`] — compiles `*.hlo.txt` on the PJRT CPU client
//!   (`HloModuleProto::from_text_file` → `client.compile`) and runs them.
//!   [`shard::ExecService`] owns the client on a dedicated thread so the
//!   multi-threaded device actors in [`crate::coordinator`] can share it
//!   (the `xla` crate's handles are deliberately `!Send`).
//! * [`measured`] — profiles the real shard executables to produce
//!   [`crate::profiler::ProfiledTraces`] for the tiny model, scaled per
//!   device class.

pub mod manifest;
pub mod measured;
pub mod shard;
pub mod weights;

pub use manifest::Manifest;
pub use measured::MeasuredProfiler;
pub use shard::{ExecService, ExecServiceHandle, TensorData};
pub use weights::WeightStore;
