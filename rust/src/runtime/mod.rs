//! Model runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them on the request path — python
//! is never involved.
//!
//! * [`manifest`] — the python→rust interchange contract (plus
//!   [`Manifest::synthetic`] for file-less operation).
//! * [`weights`] — loads `weights.bin` and slices per-layer tensors (plus
//!   [`WeightStore::synthetic`] deterministic init).
//! * [`shard`] — compiles `*.hlo.txt` on the PJRT CPU client
//!   (`HloModuleProto::from_text_file` → `client.compile`) and runs them.
//!   [`shard::ExecService`] owns the client on a dedicated thread so the
//!   multi-threaded device actors in [`crate::coordinator`] can share it
//!   (the `xla` crate's handles are deliberately `!Send`).
//! * [`sim`] — the pure-rust reference executor behind
//!   [`shard::ExecService::start_sim`]: same shard semantics, no PJRT, no
//!   artifacts.  This is what CI and the adaptive scenarios run; the
//!   vendored `rust/vendor/xla` stub quarantines the real PJRT
//!   dependency, and artifact-requiring tests skip when absent.
//! * [`measured`] — profiles the real shard executables (either backend)
//!   to produce [`crate::profiler::ProfiledTraces`], scaled per device
//!   class.

pub mod manifest;
pub mod measured;
pub mod shard;
pub mod sim;
pub mod weights;

pub use manifest::Manifest;
pub use measured::MeasuredProfiler;
pub use shard::{ExecService, ExecServiceHandle, TensorData};
pub use weights::WeightStore;
