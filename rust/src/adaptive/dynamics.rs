//! Time-varying link *and device* schedules — the weather the adaptive
//! runtime lives in.
//!
//! A [`ScheduleShape`] is a pure function `sim_time_ms → Mbps`, so replays
//! are deterministic and a schedule can be sampled by planners, tests and
//! the [`DynamicsDriver`] alike.  The driver is the only mutator: it
//! periodically samples every [`LinkSchedule`] and writes the result into
//! both the ground-truth [`LiveCluster`] and the engine's in-flight
//! [`RoutedLink`] pacers (mid-frame — a drop stretches the remaining bits
//! of whatever is on the wire).
//!
//! Device churn works the same way: a [`DeviceShape`] is a pure function
//! `sim_time_ms → alive?`.  When a scheduled device is down the driver
//! (a) flips its flag in the shared [`DeviceLiveness`] — stage actors
//! consult it per message, so frames reaching a dead host vanish with its
//! KV state — and (b) forces every live link touching the device to zero
//! bandwidth, so in-flight frames stall exactly like traffic to a
//! disappeared host.  On rejoin the flag flips back and the links are
//! restored from the ground-truth cluster; the rejoined device has **cold
//! KV** (whatever it held died with it) and only re-enters service when a
//! replan migrates state onto it.

use crate::cluster::{DeviceLiveness, LiveCluster};
use crate::netsim::RoutedLink;
use crate::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Floor for any scheduled bandwidth (Mbps): keeps degraded links valid
/// for [`crate::cluster::Cluster::set_bandwidth`] and the pacers (a true
/// 0 would mean "down forever", which deadlocks a pipeline that still has
/// traffic queued on the link).
pub const MIN_MBPS: f64 = 0.01;

/// Upper bound on random-walk steps evaluated per sample (guards the
/// stateless replay when asked for the bandwidth at `t = ∞`).
const MAX_WALK_STEPS: usize = 100_000;

/// Bandwidth-over-time shape of one link.
#[derive(Debug, Clone)]
pub enum ScheduleShape {
    /// Fixed rate (useful to pin a link in a scenario).
    Constant(f64),
    /// Hard drop/jump at `at_ms`.
    Step {
        at_ms: f64,
        before_mbps: f64,
        after_mbps: f64,
    },
    /// Linear glide from `from_mbps` to `to_mbps` over `[start_ms, end_ms]`.
    Ramp {
        start_ms: f64,
        end_ms: f64,
        from_mbps: f64,
        to_mbps: f64,
    },
    /// Square-wave congestion: `high_mbps` for the first `duty` fraction
    /// of every `period_ms`, `low_mbps` for the rest.
    Periodic {
        period_ms: f64,
        duty: f64,
        high_mbps: f64,
        low_mbps: f64,
    },
    /// Seeded multiplicative random walk in `[floor_mbps, ceil_mbps]`,
    /// stepping every `step_ms`.  Deterministic per seed: the walk is
    /// replayed from t=0 at every sample.
    RandomWalk {
        seed: u64,
        start_mbps: f64,
        step_ms: f64,
        vol: f64,
        floor_mbps: f64,
        ceil_mbps: f64,
    },
    /// Replay of a recorded `(t_ms, mbps)` trace (step-wise, sorted by
    /// time; before the first point the first value holds).
    Trace(Vec<(f64, f64)>),
}

impl ScheduleShape {
    /// Bandwidth at simulated time `t_ms` (clamped to [`MIN_MBPS`]).
    pub fn mbps_at(&self, t_ms: f64) -> f64 {
        self.value_at(t_ms, MIN_MBPS)
    }

    /// One-way latency at simulated time `t_ms` (clamped at 0 — a zero
    /// propagation delay is legitimate, unlike a zero bandwidth).  The
    /// shape vocabulary is unit-agnostic; latency schedules read the
    /// `*_mbps` fields as milliseconds.
    pub fn latency_ms_at(&self, t_ms: f64) -> f64 {
        self.value_at(t_ms, 0.0)
    }

    /// Raw scheduled value at `t_ms`, floored at `floor`.
    fn value_at(&self, t_ms: f64, floor: f64) -> f64 {
        let t = t_ms.max(0.0);
        let raw = match self {
            ScheduleShape::Constant(v) => *v,
            ScheduleShape::Step {
                at_ms,
                before_mbps,
                after_mbps,
            } => {
                if t < *at_ms {
                    *before_mbps
                } else {
                    *after_mbps
                }
            }
            ScheduleShape::Ramp {
                start_ms,
                end_ms,
                from_mbps,
                to_mbps,
            } => {
                if t <= *start_ms {
                    *from_mbps
                } else if t >= *end_ms {
                    *to_mbps
                } else {
                    let f = (t - start_ms) / (end_ms - start_ms).max(1e-9);
                    from_mbps + f * (to_mbps - from_mbps)
                }
            }
            ScheduleShape::Periodic {
                period_ms,
                duty,
                high_mbps,
                low_mbps,
            } => {
                let phase = t.rem_euclid(period_ms.max(1e-9));
                if phase < duty.clamp(0.0, 1.0) * period_ms {
                    *high_mbps
                } else {
                    *low_mbps
                }
            }
            ScheduleShape::RandomWalk {
                seed,
                start_mbps,
                step_ms,
                vol,
                floor_mbps,
                ceil_mbps,
            } => {
                let steps = if step_ms.is_finite() && *step_ms > 0.0 && t.is_finite() {
                    ((t / step_ms) as usize).min(MAX_WALK_STEPS)
                } else {
                    0
                };
                let mut rng = Rng::new(*seed);
                let mut bw = *start_mbps;
                for _ in 0..steps {
                    bw *= 1.0 + rng.uniform(-*vol, *vol);
                    bw = bw.clamp(*floor_mbps, *ceil_mbps);
                }
                bw
            }
            ScheduleShape::Trace(points) => points
                .iter()
                .take_while(|(pt, _)| *pt <= t)
                .last()
                .or(points.first())
                .map(|(_, v)| *v)
                .unwrap_or(floor),
        };
        raw.max(floor)
    }
}

/// Which direction(s) of a link a [`LinkSchedule`] shapes.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum LinkDirection {
    /// Symmetric: the schedule shapes both `a→b` and `b→a` (like
    /// [`crate::cluster::Cluster::set_bandwidth`]) — the historical
    /// behavior and the default.
    #[default]
    Both,
    /// Asymmetric: the schedule shapes only `a→b`, leaving `b→a` to its
    /// own schedule (or the ground truth).  Two `OneWay` schedules give a
    /// link the cellular shape — an uplink an order of magnitude slower
    /// than the downlink.
    OneWay,
}

/// One link's schedule (symmetric unless `direction` says otherwise).
/// A schedule may shape bandwidth, one-way latency, or both — the two
/// dimensions degrade independently on real paths (a congested bottleneck
/// queue inflates delay long before it caps throughput, and vice versa).
#[derive(Debug, Clone)]
pub struct LinkSchedule {
    pub a: usize,
    pub b: usize,
    /// Bandwidth over time (Mbps), if this schedule shapes bandwidth.
    pub bandwidth: Option<ScheduleShape>,
    /// One-way propagation delay over time (ms), if shaped.
    pub latency: Option<ScheduleShape>,
    pub direction: LinkDirection,
}

/// Liveness-over-time shape of one device (pure `sim_time_ms → alive?`,
/// deterministic like [`ScheduleShape`]).
#[derive(Debug, Clone)]
pub enum DeviceShape {
    /// Hard crash at `at_ms`: alive before, gone forever after.
    CrashAt(f64),
    /// Crash at `down_ms`, rejoin (with cold KV) at `up_ms`.
    DownBetween { down_ms: f64, up_ms: f64 },
    /// Square-wave flapping: up for the first `up_duty` fraction of every
    /// `period_ms`, down for the rest.  Frames that reach the device while
    /// it is down are lost, so even a brief blip costs a recovery — this
    /// models a genuinely crashing host, not heartbeat jitter (model the
    /// latter as a [`ScheduleShape::Periodic`] link degradation).
    Flapping { period_ms: f64, up_duty: f64 },
}

impl DeviceShape {
    /// Whether the device is up at simulated time `t_ms`.
    pub fn alive_at(&self, t_ms: f64) -> bool {
        let t = t_ms.max(0.0);
        match self {
            DeviceShape::CrashAt(at_ms) => t < *at_ms,
            DeviceShape::DownBetween { down_ms, up_ms } => t < *down_ms || t >= *up_ms,
            DeviceShape::Flapping { period_ms, up_duty } => {
                let phase = t.rem_euclid(period_ms.max(1e-9));
                phase < up_duty.clamp(0.0, 1.0) * period_ms
            }
        }
    }
}

impl LinkSchedule {
    /// Whether this schedule shapes the `from→to` direction.
    fn covers(&self, from: usize, to: usize) -> bool {
        match self.direction {
            LinkDirection::Both => {
                (self.a == from && self.b == to) || (self.a == to && self.b == from)
            }
            LinkDirection::OneWay => self.a == from && self.b == to,
        }
    }
}

/// One device's churn schedule.
#[derive(Debug, Clone)]
pub struct DeviceSchedule {
    pub device: usize,
    pub shape: DeviceShape,
}

/// The full weather forecast: per-link bandwidth schedules plus per-device
/// churn schedules.
#[derive(Debug, Clone, Default)]
pub struct NetworkDynamics {
    pub links: Vec<LinkSchedule>,
    pub devices: Vec<DeviceSchedule>,
}

impl NetworkDynamics {
    pub fn new() -> Self {
        NetworkDynamics::default()
    }

    /// Add a bandwidth schedule for the (symmetric) link `a↔b`.
    pub fn link(mut self, a: usize, b: usize, shape: ScheduleShape) -> Self {
        self.links.push(LinkSchedule {
            a,
            b,
            bandwidth: Some(shape),
            latency: None,
            direction: LinkDirection::Both,
        });
        self
    }

    /// Add a bandwidth schedule for the `a→b` direction only (the `b→a`
    /// direction keeps its ground truth, or its own one-way schedule).
    pub fn link_oneway(mut self, a: usize, b: usize, shape: ScheduleShape) -> Self {
        self.links.push(LinkSchedule {
            a,
            b,
            bandwidth: Some(shape),
            latency: None,
            direction: LinkDirection::OneWay,
        });
        self
    }

    /// Add a one-way-latency schedule for the (symmetric) link `a↔b` —
    /// the shape's values are read as milliseconds.
    pub fn link_latency(mut self, a: usize, b: usize, shape: ScheduleShape) -> Self {
        self.links.push(LinkSchedule {
            a,
            b,
            bandwidth: None,
            latency: Some(shape),
            direction: LinkDirection::Both,
        });
        self
    }

    /// Add a latency schedule for the `a→b` direction only — how
    /// bufferbloat is modelled: one direction's queueing delay balloons
    /// while the reverse path stays flat.
    pub fn link_latency_oneway(mut self, a: usize, b: usize, shape: ScheduleShape) -> Self {
        self.links.push(LinkSchedule {
            a,
            b,
            bandwidth: None,
            latency: Some(shape),
            direction: LinkDirection::OneWay,
        });
        self
    }

    /// Add a churn schedule for `device`.
    pub fn device(mut self, device: usize, shape: DeviceShape) -> Self {
        self.devices.push(DeviceSchedule { device, shape });
        self
    }

    /// Scheduled bandwidth of the `a→b` direction at `t_ms`, if a
    /// bandwidth schedule covers it (a symmetric schedule covers both
    /// directions; a one-way schedule only its own).
    pub fn mbps_at(&self, a: usize, b: usize, t_ms: f64) -> Option<f64> {
        self.links
            .iter()
            .filter(|l| l.covers(a, b))
            .find_map(|l| l.bandwidth.as_ref().map(|s| s.mbps_at(t_ms)))
    }

    /// Scheduled one-way latency of the `a→b` direction at `t_ms`, if a
    /// latency schedule covers it.
    pub fn latency_ms_at(&self, a: usize, b: usize, t_ms: f64) -> Option<f64> {
        self.links
            .iter()
            .filter(|l| l.covers(a, b))
            .find_map(|l| l.latency.as_ref().map(|s| s.latency_ms_at(t_ms)))
    }

    /// Scheduled liveness of `device` at `t_ms` (`None` = no schedule,
    /// i.e. always up).
    pub fn device_alive_at(&self, device: usize, t_ms: f64) -> Option<bool> {
        self.devices
            .iter()
            .find(|d| d.device == device)
            .map(|d| d.shape.alive_at(t_ms))
    }

    /// Whether any device churn is scheduled at all (engines use this to
    /// decide whether to allocate a [`DeviceLiveness`]).
    pub fn has_device_churn(&self) -> bool {
        !self.devices.is_empty()
    }

    /// Write the state at `t_ms` into the ground-truth cluster and any
    /// affected live links.
    pub fn apply(&self, cluster: &LiveCluster, links: &[RoutedLink], t_ms: f64) {
        self.apply_full(cluster, links, None, t_ms);
    }

    /// [`NetworkDynamics::apply`] plus device churn: dead devices get
    /// their [`DeviceLiveness`] flag cleared (frames reaching them vanish)
    /// and every live link touching them forced down; rejoined devices get
    /// the flag restored and their links re-shaped from the ground truth.
    ///
    /// The ground-truth *cluster* is never written with a zero bandwidth
    /// (planners must keep seeing a routable topology around the corpse);
    /// only the in-flight pacers are.
    pub fn apply_full(
        &self,
        cluster: &LiveCluster,
        links: &[RoutedLink],
        liveness: Option<&DeviceLiveness>,
        t_ms: f64,
    ) {
        for l in &self.links {
            if let Some(shape) = &l.bandwidth {
                let mbps = shape.mbps_at(t_ms);
                match l.direction {
                    LinkDirection::Both => cluster.set_bandwidth(l.a, l.b, mbps),
                    LinkDirection::OneWay => cluster.set_bandwidth_oneway(l.a, l.b, mbps),
                }
                for rl in links {
                    if l.covers(rl.from, rl.to) {
                        rl.link.set_bandwidth(mbps);
                    }
                }
            }
            if let Some(shape) = &l.latency {
                let ms = shape.latency_ms_at(t_ms);
                match l.direction {
                    LinkDirection::Both => cluster.set_latency(l.a, l.b, ms),
                    LinkDirection::OneWay => cluster.set_latency_oneway(l.a, l.b, ms),
                }
                for rl in links {
                    if l.covers(rl.from, rl.to) {
                        rl.link.set_latency(ms);
                    }
                }
            }
        }
        // resolve every scheduled device's aliveness first: a link is up
        // only if NEITHER endpoint is a scheduled-dead device, so two
        // schedules sharing a link cannot re-open it for a corpse
        // regardless of schedule order
        let dead: Vec<usize> = self
            .devices
            .iter()
            .filter(|d| !d.shape.alive_at(t_ms))
            .map(|d| d.device)
            .collect();
        for d in &self.devices {
            let alive = !dead.contains(&d.device);
            // flag first: a stage must never process a frame after its
            // links are already down (the frame would vanish into a wire
            // the monitor can still hear)
            if let Some(lv) = liveness {
                lv.set_alive(d.device, alive);
            }
            for rl in links {
                if rl.from != d.device && rl.to != d.device {
                    continue;
                }
                if dead.contains(&rl.from) || dead.contains(&rl.to) {
                    rl.link.set_bandwidth(0.0);
                } else {
                    // restore from the ground truth (which includes any
                    // link schedule applied above)
                    rl.link
                        .set_bandwidth(cluster.bandwidth(rl.from, rl.to));
                    rl.link.set_latency(cluster.latency(rl.from, rl.to));
                }
            }
        }
    }
}

/// Background thread replaying a [`NetworkDynamics`] onto a live cluster
/// and a (swappable) set of routed links, on the engine's simulated
/// clock: `sim_ms = real_elapsed_ms / time_scale`.
pub struct DynamicsDriver {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl DynamicsDriver {
    /// Start replaying.  `links` is shared so a migration can swap in the
    /// freshly wired links without restarting the driver.  Requires
    /// `time_scale > 0` to have a meaningful clock (at 0 the schedule
    /// collapses to its end state).
    pub fn spawn(
        dynamics: NetworkDynamics,
        cluster: LiveCluster,
        links: Arc<Mutex<Vec<RoutedLink>>>,
        time_scale: f64,
        tick_real_ms: f64,
    ) -> DynamicsDriver {
        Self::spawn_full(dynamics, cluster, links, None, time_scale, tick_real_ms)
    }

    /// [`DynamicsDriver::spawn`] plus a shared [`DeviceLiveness`] the
    /// device-churn schedules are replayed onto.
    pub fn spawn_full(
        dynamics: NetworkDynamics,
        cluster: LiveCluster,
        links: Arc<Mutex<Vec<RoutedLink>>>,
        liveness: Option<DeviceLiveness>,
        time_scale: f64,
        tick_real_ms: f64,
    ) -> DynamicsDriver {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::Builder::new()
            .name("net-dynamics".into())
            .spawn(move || {
                let t0 = Instant::now();
                while !stop2.load(Ordering::Relaxed) {
                    let sim_ms = if time_scale > 0.0 {
                        t0.elapsed().as_secs_f64() * 1e3 / time_scale
                    } else {
                        f64::INFINITY
                    };
                    {
                        let snapshot = links.lock().expect("links lock poisoned");
                        dynamics.apply_full(&cluster, &snapshot, liveness.as_ref(), sim_ms);
                    }
                    std::thread::sleep(Duration::from_secs_f64(tick_real_ms.max(0.5) / 1e3));
                }
            })
            .expect("spawning net-dynamics thread");
        DynamicsDriver {
            stop,
            join: Some(join),
        }
    }

    /// Stop replaying and join the thread.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for DynamicsDriver {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    #[test]
    fn step_and_ramp_shapes() {
        let s = ScheduleShape::Step {
            at_ms: 100.0,
            before_mbps: 1000.0,
            after_mbps: 50.0,
        };
        assert_eq!(s.mbps_at(0.0), 1000.0);
        assert_eq!(s.mbps_at(99.9), 1000.0);
        assert_eq!(s.mbps_at(100.0), 50.0);
        assert_eq!(s.mbps_at(f64::INFINITY), 50.0);

        let r = ScheduleShape::Ramp {
            start_ms: 0.0,
            end_ms: 100.0,
            from_mbps: 100.0,
            to_mbps: 200.0,
        };
        assert_eq!(r.mbps_at(0.0), 100.0);
        assert!((r.mbps_at(50.0) - 150.0).abs() < 1e-9);
        assert_eq!(r.mbps_at(1e9), 200.0);
    }

    #[test]
    fn periodic_duty_cycle() {
        let p = ScheduleShape::Periodic {
            period_ms: 100.0,
            duty: 0.6,
            high_mbps: 500.0,
            low_mbps: 10.0,
        };
        assert_eq!(p.mbps_at(10.0), 500.0);
        assert_eq!(p.mbps_at(59.0), 500.0);
        assert_eq!(p.mbps_at(61.0), 10.0);
        assert_eq!(p.mbps_at(161.0), 10.0);
        assert_eq!(p.mbps_at(210.0), 500.0);
    }

    #[test]
    fn random_walk_deterministic_and_bounded() {
        let w = ScheduleShape::RandomWalk {
            seed: 7,
            start_mbps: 100.0,
            step_ms: 10.0,
            vol: 0.2,
            floor_mbps: 20.0,
            ceil_mbps: 400.0,
        };
        for t in [0.0, 55.0, 123.0, 999.0] {
            let a = w.mbps_at(t);
            let b = w.mbps_at(t);
            assert_eq!(a, b);
            assert!((20.0..=400.0).contains(&a), "t={t} bw={a}");
        }
        // actually walks
        assert_ne!(w.mbps_at(0.0), w.mbps_at(999.0));
        // infinite time terminates (step cap)
        assert!(w.mbps_at(f64::INFINITY).is_finite());
    }

    #[test]
    fn trace_replay_stepwise() {
        let tr = ScheduleShape::Trace(vec![(0.0, 100.0), (50.0, 10.0), (80.0, 300.0)]);
        assert_eq!(tr.mbps_at(0.0), 100.0);
        assert_eq!(tr.mbps_at(49.0), 100.0);
        assert_eq!(tr.mbps_at(50.0), 10.0);
        assert_eq!(tr.mbps_at(79.0), 10.0);
        assert_eq!(tr.mbps_at(1e6), 300.0);
    }

    #[test]
    fn schedules_floor_at_min() {
        let s = ScheduleShape::Constant(0.0);
        assert_eq!(s.mbps_at(5.0), MIN_MBPS);
        let s = ScheduleShape::Step {
            at_ms: 0.0,
            before_mbps: 10.0,
            after_mbps: -3.0,
        };
        assert_eq!(s.mbps_at(1.0), MIN_MBPS);
    }

    #[test]
    fn dynamics_apply_updates_cluster_and_links() {
        let live = LiveCluster::new(presets::tiny_demo(0));
        let dynamics = NetworkDynamics::new().link(
            0,
            1,
            ScheduleShape::Step {
                at_ms: 100.0,
                before_mbps: 1000.0,
                after_mbps: 2.0,
            },
        );
        let rl = RoutedLink {
            from: 1,
            to: 0,
            link: crate::netsim::LiveLink::new(crate::netsim::LinkSpec::new(1000.0, 0.5)),
        };
        dynamics.apply(&live, std::slice::from_ref(&rl), 0.0);
        assert_eq!(live.bandwidth(0, 1), 1000.0);
        assert_eq!(rl.link.get().bandwidth_mbps, 1000.0);
        dynamics.apply(&live, std::slice::from_ref(&rl), 200.0);
        assert_eq!(live.bandwidth(1, 0), 2.0);
        assert_eq!(rl.link.get().bandwidth_mbps, 2.0);
        assert_eq!(dynamics.mbps_at(1, 0, 200.0), Some(2.0));
        assert_eq!(dynamics.mbps_at(0, 2, 200.0), None);
    }

    #[test]
    fn oneway_schedules_shape_directions_independently() {
        // cellular shape: slow uplink 1→0, fast downlink 0→1
        let live = LiveCluster::new(presets::tiny_demo(0));
        let dynamics = NetworkDynamics::new()
            .link_oneway(1, 0, ScheduleShape::Constant(4.0))
            .link_oneway(0, 1, ScheduleShape::Constant(400.0));
        let up = RoutedLink {
            from: 1,
            to: 0,
            link: crate::netsim::LiveLink::new(crate::netsim::LinkSpec::new(100.0, 0.5)),
        };
        let down = RoutedLink {
            from: 0,
            to: 1,
            link: crate::netsim::LiveLink::new(crate::netsim::LinkSpec::new(100.0, 0.5)),
        };
        let links = [up, down];
        dynamics.apply(&live, &links, 0.0);
        assert_eq!(live.bandwidth(1, 0), 4.0);
        assert_eq!(live.bandwidth(0, 1), 400.0);
        assert_eq!(links[0].link.get().bandwidth_mbps, 4.0);
        assert_eq!(links[1].link.get().bandwidth_mbps, 400.0);
        assert_eq!(dynamics.mbps_at(1, 0, 0.0), Some(4.0));
        assert_eq!(dynamics.mbps_at(0, 1, 0.0), Some(400.0));
    }

    #[test]
    fn oneway_schedule_leaves_reverse_direction_alone() {
        let live = LiveCluster::new(presets::tiny_demo(0));
        let base = live.bandwidth(0, 1);
        let dynamics = NetworkDynamics::new().link_oneway(1, 0, ScheduleShape::Constant(4.0));
        let reverse = RoutedLink {
            from: 0,
            to: 1,
            link: crate::netsim::LiveLink::new(crate::netsim::LinkSpec::new(base, 0.5)),
        };
        dynamics.apply(&live, std::slice::from_ref(&reverse), 50.0);
        assert_eq!(live.bandwidth(1, 0), 4.0);
        assert_eq!(live.bandwidth(0, 1), base, "reverse ground truth untouched");
        assert_eq!(
            reverse.link.get().bandwidth_mbps,
            base,
            "reverse pacer untouched"
        );
        assert_eq!(dynamics.mbps_at(0, 1, 50.0), None);
    }

    #[test]
    fn latency_schedules_shape_delay_independently_of_bandwidth() {
        let live = LiveCluster::new(presets::tiny_demo(0));
        let base_bw = live.bandwidth(0, 1);
        let base_rev_lat = live.latency(2, 0);
        let dynamics = NetworkDynamics::new()
            .link_latency(
                0,
                1,
                ScheduleShape::Step {
                    at_ms: 100.0,
                    before_mbps: 2.0, // read as ms
                    after_mbps: 40.0,
                },
            )
            .link_latency_oneway(0, 2, ScheduleShape::Constant(15.0));
        let covered = RoutedLink {
            from: 1,
            to: 0,
            link: crate::netsim::LiveLink::new(crate::netsim::LinkSpec::new(base_bw, 0.5)),
        };
        let reverse = RoutedLink {
            from: 2,
            to: 0,
            link: crate::netsim::LiveLink::new(crate::netsim::LinkSpec::new(300.0, 0.5)),
        };
        let links = [covered, reverse];
        dynamics.apply(&live, &links, 0.0);
        // symmetric latency schedule lands in ground truth and pacers
        assert_eq!(live.latency(0, 1), 2.0);
        assert_eq!(live.latency(1, 0), 2.0);
        assert_eq!(links[0].link.get().latency_ms, 2.0);
        // bandwidth untouched by a latency-only schedule
        assert_eq!(live.bandwidth(0, 1), base_bw);
        assert_eq!(links[0].link.get().bandwidth_mbps, base_bw);
        // one-way schedule leaves the reverse direction alone
        assert_eq!(live.latency(0, 2), 15.0);
        assert_eq!(live.latency(2, 0), base_rev_lat);
        assert_eq!(links[1].link.get().latency_ms, 0.5);
        dynamics.apply(&live, &links, 200.0);
        assert_eq!(live.latency(0, 1), 40.0);
        assert_eq!(links[0].link.get().latency_ms, 40.0);
        // query surface mirrors the bandwidth one
        assert_eq!(dynamics.latency_ms_at(1, 0, 0.0), Some(2.0));
        assert_eq!(dynamics.latency_ms_at(2, 0, 0.0), None);
        assert_eq!(dynamics.mbps_at(1, 0, 0.0), None);
    }

    #[test]
    fn latency_floors_at_zero_not_min_mbps() {
        let s = ScheduleShape::Constant(0.0);
        assert_eq!(s.latency_ms_at(5.0), 0.0);
        assert_eq!(s.mbps_at(5.0), MIN_MBPS);
        let s = ScheduleShape::Ramp {
            start_ms: 0.0,
            end_ms: 100.0,
            from_mbps: -5.0,
            to_mbps: 5.0,
        };
        assert_eq!(s.latency_ms_at(0.0), 0.0);
    }

    #[test]
    fn device_shapes_replay_deterministically() {
        let crash = DeviceShape::CrashAt(100.0);
        assert!(crash.alive_at(0.0));
        assert!(crash.alive_at(99.9));
        assert!(!crash.alive_at(100.0));
        assert!(!crash.alive_at(f64::INFINITY));

        let blip = DeviceShape::DownBetween {
            down_ms: 50.0,
            up_ms: 80.0,
        };
        assert!(blip.alive_at(49.0));
        assert!(!blip.alive_at(50.0));
        assert!(!blip.alive_at(79.0));
        assert!(blip.alive_at(80.0));
        assert!(blip.alive_at(1e9));

        let flap = DeviceShape::Flapping {
            period_ms: 100.0,
            up_duty: 0.7,
        };
        assert!(flap.alive_at(10.0));
        assert!(flap.alive_at(69.0));
        assert!(!flap.alive_at(71.0));
        assert!(flap.alive_at(110.0));
    }

    #[test]
    fn device_churn_downs_links_and_flags_then_restores() {
        let live = LiveCluster::new(presets::tiny_demo(0));
        let base_bw = live.bandwidth(0, 1);
        let dynamics = NetworkDynamics::new().device(
            1,
            DeviceShape::DownBetween {
                down_ms: 100.0,
                up_ms: 200.0,
            },
        );
        assert!(dynamics.has_device_churn());
        assert_eq!(dynamics.device_alive_at(1, 150.0), Some(false));
        assert_eq!(dynamics.device_alive_at(2, 150.0), None);
        let liveness = crate::cluster::DeviceLiveness::new(3);
        let touching = RoutedLink {
            from: 0,
            to: 1,
            link: crate::netsim::LiveLink::new(crate::netsim::LinkSpec::new(base_bw, 0.5)),
        };
        let elsewhere = RoutedLink {
            from: 0,
            to: 2,
            link: crate::netsim::LiveLink::new(crate::netsim::LinkSpec::new(300.0, 0.5)),
        };
        let links = [touching, elsewhere];
        dynamics.apply_full(&live, &links, Some(&liveness), 150.0);
        assert!(!liveness.is_alive(1));
        assert_eq!(links[0].link.get().bandwidth_mbps, 0.0);
        assert_eq!(links[1].link.get().bandwidth_mbps, 300.0);
        // the ground-truth cluster keeps a routable topology
        assert!(live.bandwidth(0, 1) > 0.0);
        // rejoin restores the flag and the link from the ground truth
        dynamics.apply_full(&live, &links, Some(&liveness), 250.0);
        assert!(liveness.is_alive(1));
        assert_eq!(links[0].link.get().bandwidth_mbps, base_bw);
    }

    #[test]
    fn shared_link_stays_down_while_either_endpoint_dead() {
        // two schedules sharing a link: the rejoined device must not
        // re-open the wire to the still-dead one, whatever the schedule
        // order
        let live = LiveCluster::new(presets::tiny_demo(0));
        let dynamics = NetworkDynamics::new()
            .device(1, DeviceShape::CrashAt(100.0))
            .device(
                2,
                DeviceShape::DownBetween {
                    down_ms: 0.0,
                    up_ms: 50.0,
                },
            );
        let liveness = crate::cluster::DeviceLiveness::new(3);
        let links = [RoutedLink {
            from: 1,
            to: 2,
            link: crate::netsim::LiveLink::new(crate::netsim::LinkSpec::new(300.0, 0.5)),
        }];
        // t=150: device 2 rejoined, device 1 crashed for good
        dynamics.apply_full(&live, &links, Some(&liveness), 150.0);
        assert!(!liveness.is_alive(1));
        assert!(liveness.is_alive(2));
        assert_eq!(links[0].link.get().bandwidth_mbps, 0.0);
    }

    #[test]
    fn driver_replays_on_sim_clock() {
        let live = LiveCluster::new(presets::tiny_demo(0));
        let dynamics = NetworkDynamics::new().link(
            0,
            1,
            ScheduleShape::Step {
                at_ms: 400.0,
                before_mbps: 777.0,
                after_mbps: 5.0,
            },
        );
        let links = Arc::new(Mutex::new(Vec::new()));
        // time_scale 0.1 → 400 sim ms arrive after 40 real ms
        let driver = DynamicsDriver::spawn(dynamics, live.clone(), links, 0.1, 2.0);
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(live.bandwidth(0, 1), 777.0);
        std::thread::sleep(Duration::from_millis(120));
        assert_eq!(live.bandwidth(0, 1), 5.0);
        driver.stop();
    }
}
