//! Online estimators: reconstruct network + compute state from the
//! timings that already flow through the serving stack.
//!
//! The monitor never touches the ground-truth [`crate::cluster::Cluster`]
//! or the [`crate::netsim::LiveLink`] specs.  Its only inputs are:
//!
//! * [`TransferObs`] — per-frame (bytes, sim-ms) timings reported by the
//!   shaped-link pacers the engine already routes activations through;
//! * [`ComputeObs`] — per-message shard execution times reported by the
//!   stage actors.
//!
//! From these it maintains EWMA estimates of effective link bandwidth and
//! per-device compute speed, and can materialize an **observed**
//! [`Cluster`] / [`ProfiledTraces`] pair for the replanner — the same
//! schema the offline profiler produces, now estimated live.
//!
//! The same observation streams double as **heartbeats**: every compute
//! timing (and every delivered frame's sender) proves a device was alive
//! moments ago.  [`Monitor::drain_at`] stamps each drained observation
//! with the caller's simulated clock, and the [`LivenessDetector`] turns
//! a stalled pipeline plus a per-device silence ranking into a failover
//! verdict — still without ever reading the ground-truth
//! [`crate::cluster::DeviceLiveness`].

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, Sender};

use crate::cluster::Cluster;
use crate::coordinator::engine::ObsSinks;
use crate::metrics::ComputeObs;
use crate::netsim::TransferObs;
use crate::planner::Plan;
use crate::profiler::ProfiledTraces;

/// Exponentially weighted moving average.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
    samples: u64,
}

impl Ewma {
    /// `alpha` ∈ (0, 1]: weight of the newest sample.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0,1]");
        Ewma {
            alpha,
            value: None,
            samples: 0,
        }
    }

    pub fn observe(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        self.samples += 1;
        self.value = Some(match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }
}

/// Cloneable sender half handed to engines; see [`Monitor::new`].
#[derive(Clone)]
pub struct MonitorHandle {
    pub transfer: Sender<TransferObs>,
    pub compute: Sender<ComputeObs>,
}

impl MonitorHandle {
    /// The observation taps in the shape [`crate::coordinator::engine::wire`]
    /// wants.
    pub fn sinks(&self) -> ObsSinks {
        ObsSinks {
            compute: vec![self.compute.clone()],
            transfer: vec![self.transfer.clone()],
            tracer: crate::obs::Tracer::off(),
        }
    }
}

/// The estimator state.  Single-consumer: engines send observations
/// through a [`MonitorHandle`]; the driver loop calls [`Monitor::drain`]
/// before consulting the estimates.
pub struct Monitor {
    /// Prior beliefs (the cluster the initial plan was solved against) —
    /// also the source of the latency term subtracted from transfer
    /// timings, and of link values no observation has touched yet.
    base: Cluster,
    alpha: f64,
    /// Frames smaller than this carry no usable bandwidth signal (their
    /// timing is dominated by propagation latency + scheduler noise).
    pub min_sample_bytes: u64,
    transfer_rx: Receiver<TransferObs>,
    compute_rx: Receiver<ComputeObs>,
    /// EWMA over **ms-per-bit** (inverse bandwidth): averaging transfer
    /// *time* makes a bandwidth collapse dominate the estimate within a
    /// couple of frames (1000 → 1 Mbps is a 1000× jump in ms/bit), while
    /// plain Mbps-averaging would need ~log₂(1000) samples to halve its
    /// way down — far too slow to react to a link drop.
    link_inv: HashMap<(usize, usize), Ewma>,
    /// **Directed** per-link one-way latency EWMAs (ms).  Fed by the
    /// frames too small to carry a bandwidth signal: a control frame
    /// serializes in negligible time, so its delivery timing is almost
    /// pure propagation delay — the control traffic doubles as latency
    /// probes, and latency drift is estimated separately from bandwidth
    /// drift.  Keyed by direction because one-way shaping (bufferbloat)
    /// makes delay asymmetric.
    link_lat: HashMap<(usize, usize), Ewma>,
    /// Keyed by (device, is_decode).
    stage_ms: HashMap<(usize, bool), Ewma>,
    /// Last evidence of life per device (a compute timing, or sending a
    /// frame that got delivered): `(sequence, simulated ms)`.  The
    /// sequence increments per drained observation, so it preserves the
    /// *causal* pipeline order even when a whole backlog drains in one
    /// call and shares a timestamp — which is exactly the situation right
    /// after a crash.  Only updated by [`Monitor::drain_at`]; plain
    /// [`Monitor::drain`] calls carry no clock.
    last_seen: HashMap<usize, (u64, f64)>,
    obs_seq: u64,
}

impl Monitor {
    pub fn new(base: Cluster, alpha: f64) -> (Monitor, MonitorHandle) {
        let (transfer_tx, transfer_rx) = mpsc::channel();
        let (compute_tx, compute_rx) = mpsc::channel();
        (
            Monitor {
                base,
                alpha,
                min_sample_bytes: 256,
                transfer_rx,
                compute_rx,
                link_inv: HashMap::new(),
                link_lat: HashMap::new(),
                stage_ms: HashMap::new(),
                last_seen: HashMap::new(),
                obs_seq: 0,
            },
            MonitorHandle {
                transfer: transfer_tx,
                compute: compute_tx,
            },
        )
    }

    /// Ingest every pending observation; returns how many arrived.
    pub fn drain(&mut self) -> usize {
        self.drain_inner(None)
    }

    /// [`Monitor::drain`] that also stamps each drained observation's
    /// device as heard-from at `now_ms` (simulated): the sending device of
    /// a delivered frame and the executing device of a compute timing.
    /// Observations queued since the previous drain get this drain's
    /// stamp — a granularity the [`LivenessDetector`] timeout must (and
    /// does, via the stall precondition) tolerate.
    pub fn drain_at(&mut self, now_ms: f64) -> usize {
        self.drain_inner(Some(now_ms))
    }

    fn drain_inner(&mut self, now_ms: Option<f64>) -> usize {
        let mut n = 0;
        while let Ok(o) = self.transfer_rx.try_recv() {
            if let Some(t) = now_ms {
                self.obs_seq += 1;
                self.last_seen.insert(o.from, (self.obs_seq, t));
            }
            self.ingest_transfer(o);
            n += 1;
        }
        while let Ok(o) = self.compute_rx.try_recv() {
            if let Some(t) = now_ms {
                self.obs_seq += 1;
                self.last_seen.insert(o.device, (self.obs_seq, t));
            }
            self.ingest_compute(o);
            n += 1;
        }
        n
    }

    /// Simulated ms `device` last produced evidence of life (`None` =
    /// never heard from it through a stamped drain).
    pub fn last_seen_ms(&self, device: usize) -> Option<f64> {
        self.last_seen.get(&device).map(|&(_, t)| t)
    }

    /// Causal rank of `device`'s last evidence of life: higher = heard
    /// from more recently in pipeline order.  Unlike the timestamp this
    /// distinguishes observations that drained in one batch, so the
    /// silence ranking stays meaningful right after a crash.
    pub fn last_seen_seq(&self, device: usize) -> Option<u64> {
        self.last_seen.get(&device).map(|&(s, _)| s)
    }

    /// Fold one transfer timing into the link estimates.  Public so tests
    /// and offline replays can feed observations directly.
    ///
    /// Big frames update the bandwidth estimate, small frames the latency
    /// estimate: below [`Monitor::min_sample_bytes`] a frame's timing is
    /// dominated by propagation delay, above it by serialization, so each
    /// frame feeds whichever quantity it actually measures.
    pub fn ingest_transfer(&mut self, o: TransferObs) {
        if o.from == o.to || !o.sim_ms.is_finite() {
            return;
        }
        if o.bytes < self.min_sample_bytes {
            // Latency probe: subtract the (negligible) serialization the
            // nominal rate predicts and attribute the rest to one-way
            // delay.  Queueing behind a data frame inflates a sample, but
            // the EWMA rides it out the same way it rides out congestion
            // in the bandwidth estimate.
            let ser_est = self.base.link(o.from, o.to).transfer_ms(o.bytes);
            let lat = (o.sim_ms - ser_est).max(0.0);
            self.link_lat
                .entry((o.from, o.to))
                .or_insert_with(|| Ewma::new(self.alpha))
                .observe(lat);
            return;
        }
        // Serialization time ≈ total − propagation.  Prefer the *live*
        // latency estimate (the probes above track drift); fall back to
        // the prior belief.  Clamp so a timing at or below the latency
        // floor still yields a (large) finite estimate instead of a
        // division blow-up.
        let latency = self
            .latency_estimate_ms(o.from, o.to)
            .unwrap_or(self.base.latency_ms[o.from][o.to]);
        let ser_ms = (o.sim_ms - latency).max(o.sim_ms * 0.02).max(1e-3);
        let ms_per_bit = ser_ms / (o.bytes as f64 * 8.0);
        let key = (o.from.min(o.to), o.from.max(o.to));
        self.link_inv
            .entry(key)
            .or_insert_with(|| Ewma::new(self.alpha))
            .observe(ms_per_bit);
    }

    /// Fold one stage-compute timing into the device estimate.
    pub fn ingest_compute(&mut self, o: ComputeObs) {
        if o.ms.is_nan() || o.ms < 0.0 {
            return;
        }
        self.stage_ms
            .entry((o.device, o.decode))
            .or_insert_with(|| Ewma::new(self.alpha))
            .observe(o.ms);
    }

    /// Current bandwidth estimate for the (symmetric) link `a↔b`.
    pub fn link_estimate_mbps(&self, a: usize, b: usize) -> Option<f64> {
        self.link_inv
            .get(&(a.min(b), a.max(b)))
            .and_then(|e| e.get())
            .map(|ms_per_bit| 1.0 / (ms_per_bit * 1e3))
    }

    /// Current one-way latency estimate for the **directed** link `a→b`
    /// (ms), if any probe frames have crossed it.
    pub fn latency_estimate_ms(&self, a: usize, b: usize) -> Option<f64> {
        self.link_lat.get(&(a, b)).and_then(|e| e.get())
    }

    /// Observed per-iteration compute for `device` (decode phase).
    pub fn stage_estimate_ms(&self, device: usize, decode: bool) -> Option<f64> {
        self.stage_ms.get(&(device, decode)).and_then(|e| e.get())
    }

    /// Prior beliefs the monitor was constructed with.
    pub fn base(&self) -> &Cluster {
        &self.base
    }

    /// The cluster as currently observed: prior beliefs overridden by
    /// every bandwidth *and* one-way latency estimate the traffic has
    /// produced (latency overrides are directed — asymmetric delay
    /// survives into the replanner's view).
    pub fn observed_cluster(&self) -> Cluster {
        let mut c = self.base.clone();
        for &(a, b) in self.link_inv.keys() {
            if let Some(mbps) = self.link_estimate_mbps(a, b) {
                c.set_bandwidth(a, b, mbps.max(crate::adaptive::dynamics::MIN_MBPS));
            }
        }
        for &(a, b) in self.link_lat.keys() {
            if let Some(ms) = self.latency_estimate_ms(a, b) {
                c.set_latency_oneway(a, b, ms.max(0.0));
            }
        }
        c
    }

    /// Observed traces: `base` with each planned device's compute columns
    /// scaled by (observed stage ms / predicted stage ms) and the
    /// workload-averaged column rebuilt.  Devices without observations
    /// keep their profiled values.
    pub fn observed_traces(&self, base: &ProfiledTraces, plan: &Plan) -> ProfiledTraces {
        let mut t = base.clone();
        let mut scales: HashMap<usize, (f64, f64)> = HashMap::new();
        for s in &plan.stages {
            let dev = s.device;
            if scales.contains_key(&dev) {
                continue;
            }
            let decode_scale = self
                .stage_estimate_ms(dev, true)
                .map(|obs| {
                    let pred = base.range_decode_ms(s.start, s.end, dev);
                    if pred > 1e-9 {
                        obs / pred
                    } else {
                        1.0
                    }
                })
                .unwrap_or(1.0);
            let prefill_scale = self
                .stage_estimate_ms(dev, false)
                .map(|obs| {
                    let pred = base.range_prefill_ms(s.start, s.end, dev);
                    if pred > 1e-9 {
                        obs / pred
                    } else {
                        1.0
                    }
                })
                .unwrap_or(1.0);
            scales.insert(dev, (prefill_scale, decode_scale));
        }
        if scales.is_empty() {
            return t;
        }
        let iters = t.workload.iterations().max(1) as f64;
        for i in 0..t.n_layers {
            for (&dev, &(ps, ds)) in &scales {
                t.prefill_ms[i][dev] *= ps;
                t.decode_ms[i][dev] *= ds;
                t.avg_ms[i][dev] =
                    (t.prefill_ms[i][dev] + (iters - 1.0) * t.decode_ms[i][dev]) / iters;
            }
        }
        t
    }
}

/// Heartbeat-timeout device-loss detection over the monitor's silence
/// records.
///
/// The rule: failover is considered only once the whole pipeline has been
/// **stalled** (no token delivered) for at least `timeout_ms` of simulated
/// time — jitter, slow links and slow-but-alive stages never trigger it,
/// because tokens keep (however slowly) arriving and reset the stall
/// clock.  Once stalled past the timeout, the suspect is the *most
/// upstream* plan device among those silent the longest: stages ahead of
/// the stuck frame carry fresh timings, the dead stage and everything
/// behind it carry timings from the previous iteration, and FIFO pipeline
/// order makes the first of the stale ones the blocking host.
///
/// A verdict is a heuristic, not ground truth: failover stays correct
/// under a wrong blame (the rebuilt pipeline re-derives every token
/// deterministically), it just costs another detection round.  The
/// engine runs that round itself: a wrong blame surfaces as the
/// recovery replay stalling against the corpse-bearing plan, after
/// which it re-runs [`LivenessDetector::suspect`] over the *new* plan's
/// devices (the replay traffic refreshed every healthy heartbeat) and
/// re-solves — bounded to one retry — while
/// [`LivenessDetector::demote_to`] retracts stale verdicts whenever the
/// surviving pool becomes unplannable.
#[derive(Debug, Clone)]
pub struct LivenessDetector {
    /// Simulated ms of pipeline stall before a device may be declared dead.
    pub timeout_ms: f64,
    /// Simulated ms a dead verdict stays standing before
    /// [`LivenessDetector::expire`] retracts it (`INFINITY` = a verdict
    /// never expires).  The TTL is what lets a crashed-and-rejoined
    /// device be re-adopted: an *excluded* device produces no
    /// observations, so no amount of healthy uptime can clear its
    /// verdict — only expiry can.  A wrong expiry is cheap (the next
    /// stall re-blames the corpse, costing one failover round), a
    /// never-expiring verdict on recovered hardware is a permanent
    /// capacity loss.
    pub verdict_ttl_ms: f64,
    /// Devices declared dead with their verdict times, oldest first.
    dead: Vec<(usize, f64)>,
}

impl LivenessDetector {
    pub fn new(timeout_ms: f64) -> Self {
        Self::with_ttl(timeout_ms, f64::INFINITY)
    }

    /// A detector whose verdicts expire after `verdict_ttl_ms` simulated
    /// ms (see [`LivenessDetector::expire`]).
    pub fn with_ttl(timeout_ms: f64, verdict_ttl_ms: f64) -> Self {
        LivenessDetector {
            timeout_ms,
            verdict_ttl_ms,
            dead: Vec::new(),
        }
    }

    pub fn is_dead(&self, device: usize) -> bool {
        self.dead.iter().any(|&(d, _)| d == device)
    }

    /// Devices currently declared dead, oldest verdict first.
    pub fn dead(&self) -> Vec<usize> {
        self.dead.iter().map(|&(d, _)| d).collect()
    }

    /// Record a verdict at `now_ms` (idempotent; the original verdict
    /// time wins, so re-blaming cannot keep refreshing a TTL).
    pub fn mark_dead(&mut self, device: usize, now_ms: f64) {
        if !self.is_dead(device) {
            self.dead.push((device, now_ms));
        }
    }

    /// Retract a verdict (e.g. fresh evidence of life).
    pub fn mark_alive(&mut self, device: usize) {
        self.dead.retain(|&(d, _)| d != device);
    }

    /// Retract every verdict older than the TTL.  Call sites pass the
    /// same simulated clock they stamp observations with, so expiry and
    /// heartbeats share a timeline.
    pub fn expire(&mut self, now_ms: f64) {
        if self.verdict_ttl_ms.is_finite() {
            self.dead.retain(|&(_, at)| now_ms - at < self.verdict_ttl_ms);
        }
    }

    /// Remove and return every verdict older than the TTL, oldest first —
    /// the active-probe variant of [`LivenessDetector::expire`]: the
    /// caller decides re-admission (e.g. after probing the device) and
    /// re-arms a still-dead host with [`LivenessDetector::mark_dead`].
    pub fn take_expired(&mut self, now_ms: f64) -> Vec<usize> {
        if !self.verdict_ttl_ms.is_finite() {
            return Vec::new();
        }
        let ttl = self.verdict_ttl_ms;
        let (expired, standing): (Vec<_>, Vec<_>) =
            self.dead.drain(..).partition(|&(_, at)| now_ms - at >= ttl);
        self.dead = standing;
        expired.into_iter().map(|(d, _)| d).collect()
    }

    /// Keep only the `n` most recent verdicts — the self-healing path
    /// when an earlier blame was wrong and the shrunken pool has become
    /// unplannable.
    pub fn demote_to(&mut self, n: usize) {
        let excess = self.dead.len().saturating_sub(n);
        self.dead.drain(..excess);
    }

    /// The device to blame for a pipeline stalled `stalled_ms` (simulated),
    /// or `None` while the stall is still within the heartbeat timeout.
    /// `plan_devices` must be in stage order (upstream first).
    pub fn suspect(
        &self,
        plan_devices: &[usize],
        monitor: &Monitor,
        stalled_ms: f64,
    ) -> Option<usize> {
        if stalled_ms.is_nan() || stalled_ms < self.timeout_ms {
            return None;
        }
        plan_devices
            .iter()
            .copied()
            .filter(|d| !self.is_dead(*d))
            .min_by_key(|&d| monitor.last_seen_seq(d).unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::llama2_7b;
    use crate::planner::{PlanObjective, Stage};
    use crate::profiler::{AnalyticProfiler, Workload};

    #[test]
    fn ewma_converges_to_constant() {
        let mut e = Ewma::new(0.3);
        assert_eq!(e.get(), None);
        for _ in 0..50 {
            e.observe(42.0);
        }
        assert!((e.get().unwrap() - 42.0).abs() < 1e-9);
        assert_eq!(e.samples(), 50);
    }

    #[test]
    fn ewma_tracks_level_shift_geometrically() {
        let mut e = Ewma::new(0.5);
        e.observe(100.0);
        for _ in 0..10 {
            e.observe(10.0);
        }
        // after 10 half-weight steps the old level is ~90/1024 away
        assert!((e.get().unwrap() - 10.0).abs() < 0.1);
        // and a fresh shift moves halfway in one step
        e.observe(20.0);
        assert!((e.get().unwrap() - 15.0).abs() < 0.1);
    }

    #[test]
    fn ewma_ignores_non_finite() {
        let mut e = Ewma::new(0.5);
        e.observe(f64::NAN);
        e.observe(f64::INFINITY);
        assert_eq!(e.get(), None);
        e.observe(5.0);
        assert_eq!(e.get(), Some(5.0));
    }

    fn obs(from: usize, to: usize, bytes: u64, sim_ms: f64) -> TransferObs {
        TransferObs {
            from,
            to,
            bytes,
            sim_ms,
        }
    }

    #[test]
    fn link_estimate_recovers_bandwidth() {
        let mut c = presets::tiny_demo(0);
        c.set_latency(0, 1, 0.5);
        let (mut m, _h) = Monitor::new(c, 0.5);
        // 100 KB in 8.5 sim ms minus 0.5 latency → 100 Mbps
        for _ in 0..8 {
            m.ingest_transfer(obs(0, 1, 100_000, 8.5));
        }
        let est = m.link_estimate_mbps(0, 1).unwrap();
        assert!((est - 100.0).abs() < 5.0, "est={est}");
        // symmetric lookup
        assert!(m.link_estimate_mbps(1, 0).is_some());
        assert!(m.link_estimate_mbps(0, 2).is_none());
    }

    #[test]
    fn bandwidth_drop_detected_within_a_few_frames() {
        // 1 KB frames: healthy link delivers in ~0.008 ms (1000 Mbps),
        // then the link collapses to ~0.4 Mbps (20.5 ms per frame).
        // Because the monitor averages ms-per-bit, two collapsed frames
        // must drag the estimate below 1 Mbps.
        let mut c = presets::tiny_demo(0);
        c.set_latency(0, 1, 0.5);
        let (mut m, _h) = Monitor::new(c, 0.5);
        for _ in 0..20 {
            m.ingest_transfer(obs(0, 1, 1000, 0.508));
        }
        let healthy = m.link_estimate_mbps(0, 1).unwrap();
        assert!(healthy > 100.0, "healthy={healthy}");
        for _ in 0..2 {
            m.ingest_transfer(obs(0, 1, 1000, 21.0));
        }
        let degraded = m.link_estimate_mbps(0, 1).unwrap();
        assert!(degraded < 1.0, "degraded={degraded}");
    }

    #[test]
    fn tiny_frames_probe_latency_not_bandwidth() {
        let c = presets::tiny_demo(0);
        let (mut m, _h) = Monitor::new(c, 0.5);
        m.ingest_transfer(obs(0, 1, 32, 0.6)); // below min_sample_bytes
        m.ingest_transfer(obs(1, 1, 1 << 20, 4.0)); // self link
        // a control frame carries no bandwidth signal…
        assert!(m.link_estimate_mbps(0, 1).is_none());
        // …but it is a latency probe for its own direction
        let lat = m.latency_estimate_ms(0, 1).unwrap();
        assert!((0.0..=0.6).contains(&lat), "lat={lat}");
        assert!(m.latency_estimate_ms(1, 0).is_none());
        // self links feed nothing at all
        assert!(m.latency_estimate_ms(1, 1).is_none());
    }

    #[test]
    fn latency_probes_track_drift_and_sharpen_bandwidth() {
        let mut c = presets::tiny_demo(0);
        c.set_latency(0, 1, 0.5);
        let (mut m, _h) = Monitor::new(c, 0.5);
        // control-frame probes see 4 ms one-way delay (up from 0.5 base)
        for _ in 0..10 {
            m.ingest_transfer(obs(0, 1, 16, 4.0));
        }
        let lat = m.latency_estimate_ms(0, 1).unwrap();
        assert!((lat - 4.0).abs() < 0.1, "lat={lat}");
        // directed: the reverse path keeps its prior
        assert!(m.latency_estimate_ms(1, 0).is_none());
        let oc = m.observed_cluster();
        assert!((oc.latency_ms[0][1] - lat).abs() < 1e-9);
        assert_eq!(oc.latency_ms[1][0], 0.5);
        // data frames subtract the *drifted* latency, not the stale base:
        // 1 KB in 5 ms = 1 ms serialization at 4 ms delay → ~8 Mbps (the
        // stale 0.5 ms prior would have read the link at ~1.8 Mbps)
        for _ in 0..10 {
            m.ingest_transfer(obs(0, 1, 1000, 5.0));
        }
        let bw = m.link_estimate_mbps(0, 1).unwrap();
        assert!((bw - 8.0).abs() < 0.5, "bw={bw}");
    }

    #[test]
    fn observed_cluster_overrides_only_measured_links() {
        let mut base = presets::tiny_demo(0);
        base.set_latency(0, 1, 0.0);
        let before_02 = base.bandwidth_mbps[0][2];
        let (mut m, _h) = Monitor::new(base, 0.5);
        // measure 0↔1 at ~2 Mbps (1 KB in 4 sim ms)
        for _ in 0..10 {
            m.ingest_transfer(obs(0, 1, 1000, 4.0));
        }
        let oc = m.observed_cluster();
        assert!((oc.bandwidth_mbps[0][1] - 2.0).abs() < 0.3, "est={}", oc.bandwidth_mbps[0][1]);
        assert_eq!(oc.bandwidth_mbps[0][2], before_02);
    }

    #[test]
    fn drain_pulls_from_handles() {
        let c = presets::tiny_demo(0);
        let (mut m, h) = Monitor::new(c, 0.5);
        h.transfer.send(obs(0, 1, 10_000, 2.0)).unwrap();
        h.compute
            .send(ComputeObs {
                device: 1,
                stage: 1,
                decode: true,
                ms: 3.0,
            })
            .unwrap();
        assert_eq!(m.drain(), 2);
        assert!(m.link_estimate_mbps(0, 1).is_some());
        assert_eq!(m.stage_estimate_ms(1, true), Some(3.0));
    }

    #[test]
    fn drain_at_stamps_heartbeats() {
        let c = presets::tiny_demo(0);
        let (mut m, h) = Monitor::new(c, 0.5);
        h.transfer.send(obs(0, 1, 10_000, 2.0)).unwrap();
        h.compute
            .send(ComputeObs {
                device: 2,
                stage: 2,
                decode: true,
                ms: 1.0,
            })
            .unwrap();
        assert_eq!(m.drain_at(100.0), 2);
        // the frame's *sender* and the computing device are stamped
        assert_eq!(m.last_seen_ms(0), Some(100.0));
        assert_eq!(m.last_seen_ms(2), Some(100.0));
        assert_eq!(m.last_seen_ms(1), None);
        // a later drain refreshes only devices with new evidence
        h.compute
            .send(ComputeObs {
                device: 0,
                stage: 0,
                decode: true,
                ms: 1.0,
            })
            .unwrap();
        m.drain_at(250.0);
        assert_eq!(m.last_seen_ms(0), Some(250.0));
        assert_eq!(m.last_seen_ms(2), Some(100.0));
        // causal order survives same-batch draining via the sequence
        assert!(m.last_seen_seq(0).unwrap() > m.last_seen_seq(2).unwrap());
    }

    fn beat(m: &mut Monitor, h: &MonitorHandle, device: usize, now_ms: f64) {
        h.compute
            .send(ComputeObs {
                device,
                stage: device,
                decode: true,
                ms: 1.0,
            })
            .unwrap();
        m.drain_at(now_ms);
    }

    #[test]
    fn detector_waits_out_jitter_below_timeout() {
        let c = presets::tiny_demo(0);
        let (mut m, h) = Monitor::new(c, 0.5);
        let det = LivenessDetector::new(500.0);
        for d in 0..3 {
            beat(&mut m, &h, d, 100.0);
        }
        // slow-but-alive: the stall clock never reaches the timeout
        assert_eq!(det.suspect(&[0, 1, 2], &m, 0.0), None);
        assert_eq!(det.suspect(&[0, 1, 2], &m, 499.9), None);
        assert_eq!(det.suspect(&[0, 1, 2], &m, f64::NAN), None);
    }

    #[test]
    fn detector_blames_most_upstream_silent_device() {
        let c = presets::tiny_demo(0);
        let (mut m, h) = Monitor::new(c, 0.5);
        let mut det = LivenessDetector::new(500.0);
        // iteration k-1 passed every stage; iteration k got through the
        // source (device 0) only — devices 1 and 2 are silent since, and
        // 1 is the most upstream of the stale pair
        beat(&mut m, &h, 1, 90.0);
        beat(&mut m, &h, 2, 95.0);
        beat(&mut m, &h, 0, 700.0);
        assert_eq!(det.suspect(&[0, 1, 2], &m, 600.0), Some(1));
        // never-heard devices rank as silent forever
        assert_eq!(det.suspect(&[0, 7, 1], &m, 600.0), Some(7));
        // verdicts are excluded from later rounds, and demotable
        det.mark_dead(1, 700.0);
        assert!(det.is_dead(1));
        assert_eq!(det.suspect(&[0, 1, 2], &m, 600.0), Some(2));
        det.mark_dead(2, 710.0);
        assert_eq!(det.dead(), &[1, 2]);
        det.demote_to(1);
        assert_eq!(det.dead(), &[2]);
        det.mark_alive(2);
        assert!(!det.is_dead(2));
    }

    #[test]
    fn verdicts_expire_after_ttl() {
        let mut det = LivenessDetector::with_ttl(500.0, 1000.0);
        det.mark_dead(1, 100.0);
        assert!(det.is_dead(1));
        // inside the TTL the verdict stands
        det.expire(1099.0);
        assert!(det.is_dead(1));
        // re-blaming never refreshes the original verdict time
        det.mark_dead(1, 1050.0);
        det.expire(1100.0);
        assert!(!det.is_dead(1), "verdict survived its TTL");
        assert!(det.dead().is_empty());
        // the default (infinite TTL) never expires
        let mut det = LivenessDetector::new(500.0);
        det.mark_dead(2, 0.0);
        det.expire(f64::MAX);
        assert!(det.is_dead(2));
    }

    #[test]
    fn take_expired_hands_back_only_lapsed_verdicts() {
        let mut det = LivenessDetector::with_ttl(500.0, 1000.0);
        det.mark_dead(1, 100.0);
        det.mark_dead(2, 800.0);
        // only device 1's verdict has lapsed at t=1100
        assert_eq!(det.take_expired(1100.0), vec![1]);
        assert!(!det.is_dead(1));
        assert!(det.is_dead(2));
        // the caller may re-arm a still-dead host with a fresh verdict time
        det.mark_dead(1, 1100.0);
        assert!(det.is_dead(1));
        assert!(det.take_expired(1100.0).is_empty());
        // infinite TTL never hands anything back
        let mut det = LivenessDetector::new(500.0);
        det.mark_dead(3, 0.0);
        assert!(det.take_expired(f64::MAX).is_empty());
        assert!(det.is_dead(3));
    }

    #[test]
    fn observed_traces_scale_planned_devices() {
        let cluster = presets::paper_testbed(1.0, 0);
        let base =
            AnalyticProfiler::default().profile(&llama2_7b(), &cluster, Workload::paper_default());
        let (mut m, _h) = Monitor::new(cluster, 0.5);
        let plan = Plan {
            objective: PlanObjective::Latency,
            stages: vec![
                Stage { device: 0, start: 0, end: 10 },
                Stage { device: 3, start: 10, end: base.n_layers },
            ],
            predicted_ms: 0.0,
        };
        // device 3 decodes 2× slower than profiled
        let pred = base.range_decode_ms(10, base.n_layers, 3);
        m.ingest_compute(ComputeObs {
            device: 3,
            stage: 1,
            decode: true,
            ms: pred * 2.0,
        });
        let t = m.observed_traces(&base, &plan);
        let ratio = t.range_decode_ms(10, t.n_layers, 3) / pred;
        assert!((ratio - 2.0).abs() < 1e-6, "ratio={ratio}");
        // unobserved device unchanged
        assert_eq!(t.decode_ms[5][7], base.decode_ms[5][7]);
        // avg rebuilt consistently: avg = (prefill + (iters-1)*decode)/iters
        let iters = t.workload.iterations() as f64;
        let want = (t.prefill_ms[12][3] + (iters - 1.0) * t.decode_ms[12][3]) / iters;
        assert!((t.avg_ms[12][3] - want).abs() < 1e-9);
    }
}
