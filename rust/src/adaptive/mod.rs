//! Adaptive runtime: network dynamics, online monitoring, and live
//! replanning with KV-cache migration.
//!
//! The paper formulates device selection + partition as an *adaptive*
//! problem, but a plan solved once against a frozen [`crate::cluster`]
//! goes stale the moment an edge link degrades.  This subsystem closes
//! the loop:
//!
//! ```text
//!            ┌──────────── ground truth ────────────┐
//!  dynamics ─┤ LiveCluster + LiveLink pacers        │  (scheduled drops,
//!            └──────┬───────────────────────────────┘   ramps, walks)
//!                   │ transfer / compute timings (the only signal)
//!            ┌──────▼───────────────────────────────┐
//!  monitor ──┤ EWMA link + stage estimators         │  observed Cluster
//!            └──────┬───────────────────────────────┘  + ProfiledTraces
//!                   │ drift vs. the current plan's prediction
//!            ┌──────▼───────────────────────────────┐
//!  replan ───┤ hysteresis trigger → DP re-solve     │  migration diff
//!            └──────┬───────────────────────────────┘
//!                   │ drain → export KV → transfer → rewire → resume
//!            ┌──────▼───────────────────────────────┐
//!  engine ───┤ AdaptiveEngine over coordinator wire │
//!            └──────────────────────────────────────┘
//! ```
//!
//! * [`dynamics`] — time-varying [`crate::netsim::LinkSpec`] schedules
//!   (step drops, ramps, periodic congestion, seeded random walks, trace
//!   replay) **and device churn schedules** (crash, crash-and-rejoin,
//!   flapping), replayed by the [`dynamics::DynamicsDriver`] onto a
//!   [`crate::cluster::LiveCluster`], the engine's live links, and the
//!   shared [`crate::cluster::DeviceLiveness`] flags.
//! * [`monitor`] — EWMA estimators over the per-hop
//!   [`crate::netsim::TransferObs`] and per-stage
//!   [`crate::metrics::ComputeObs`] streams, reconstructing an *observed*
//!   cluster and traces without ground-truth access; the same streams
//!   double as heartbeats for the [`monitor::LivenessDetector`].
//! * [`replan`] — the trigger policy (estimate drift beyond a hysteresis
//!   band) plus DP re-solve, emitting a [`replan::MigrationDiff`] that is
//!   never predicted-worse than keeping the current plan; for device
//!   loss, [`replan::Replanner::solve_over`] re-solves unconditionally
//!   over the surviving pool (keeping is infeasible, so the hysteresis
//!   comparison does not apply).
//! * [`engine`] — [`engine::AdaptiveEngine`]: drives generation, drains
//!   in-flight groups at a barrier, hands KV caches across shaped links
//!   (charging real transfer time), rewires stage actors and resumes.
//!   On a detected device loss it **fails over**: abandons the dead
//!   pipeline, rewires the survivors, and recovers the lost KV from a
//!   periodic [`crate::coordinator::stage::StageMsg::Export`] checkpoint
//!   or by re-prefilling from token history.
//! * [`scenario`] — canned end-to-end experiments (mid-generation
//!   bandwidth drop, mid-generation device crash) shared by tests, the
//!   `adaptive_recovery` example and `edgeshard repro adaptive|churn`.

pub mod dynamics;
pub mod engine;
pub mod monitor;
pub mod replan;
pub mod scenario;

pub use dynamics::{
    DeviceSchedule, DeviceShape, DynamicsDriver, LinkDirection, LinkSchedule, NetworkDynamics,
    ScheduleShape,
};
pub use engine::{
    AdaptiveConfig, AdaptiveEngine, AdaptiveStats, CheckpointPolicy, FailoverRecord,
    MigrationRecord,
};
pub use monitor::{Ewma, LivenessDetector, Monitor, MonitorHandle};
pub use replan::{Decision, MigrationDiff, Replanner, StageMove, TriggerPolicy};
