//! Adaptive runtime: network dynamics, online monitoring, and live
//! replanning with KV-cache migration.
//!
//! The paper formulates device selection + partition as an *adaptive*
//! problem, but a plan solved once against a frozen [`crate::cluster`]
//! goes stale the moment an edge link degrades.  This subsystem closes
//! the loop:
//!
//! ```text
//!            ┌──────────── ground truth ────────────┐
//!  dynamics ─┤ LiveCluster + LiveLink pacers        │  (scheduled drops,
//!            └──────┬───────────────────────────────┘   ramps, walks)
//!                   │ transfer / compute timings (the only signal)
//!            ┌──────▼───────────────────────────────┐
//!  monitor ──┤ EWMA link + stage estimators         │  observed Cluster
//!            └──────┬───────────────────────────────┘  + ProfiledTraces
//!                   │ drift vs. the current plan's prediction
//!            ┌──────▼───────────────────────────────┐
//!  replan ───┤ hysteresis trigger → DP re-solve     │  migration diff
//!            └──────┬───────────────────────────────┘
//!                   │ drain → export KV → transfer → rewire → resume
//!            ┌──────▼───────────────────────────────┐
//!  engine ───┤ AdaptiveEngine over coordinator wire │
//!            └──────────────────────────────────────┘
//! ```
//!
//! * [`dynamics`] — time-varying [`crate::netsim::LinkSpec`] schedules
//!   (step drops, ramps, periodic congestion, seeded random walks, trace
//!   replay) and the [`dynamics::DynamicsDriver`] that replays them onto a
//!   [`crate::cluster::LiveCluster`] and the engine's live links.
//! * [`monitor`] — EWMA estimators over the per-hop
//!   [`crate::netsim::TransferObs`] and per-stage
//!   [`crate::metrics::ComputeObs`] streams, reconstructing an *observed*
//!   cluster and traces without ground-truth access.
//! * [`replan`] — the trigger policy (estimate drift beyond a hysteresis
//!   band) plus DP re-solve, emitting a [`replan::MigrationDiff`] that is
//!   never predicted-worse than keeping the current plan.
//! * [`engine`] — [`engine::AdaptiveEngine`]: drives generation, drains
//!   in-flight groups at a barrier, hands KV caches across shaped links
//!   (charging real transfer time), rewires stage actors and resumes.
//! * [`scenario`] — canned end-to-end experiments (mid-generation
//!   bandwidth drop, adaptive vs. static) shared by tests, the
//!   `adaptive_recovery` example and `edgeshard repro adaptive`.

pub mod dynamics;
pub mod engine;
pub mod monitor;
pub mod replan;
pub mod scenario;

pub use dynamics::{DynamicsDriver, LinkSchedule, NetworkDynamics, ScheduleShape};
pub use engine::{AdaptiveConfig, AdaptiveEngine, AdaptiveStats, MigrationRecord};
pub use monitor::{Ewma, Monitor, MonitorHandle};
pub use replan::{Decision, MigrationDiff, Replanner, StageMove, TriggerPolicy};
