//! Canned adaptive-runtime experiments, shared by the integration tests,
//! the `adaptive_recovery` example and `edgeshard repro adaptive` /
//! `edgeshard repro churn`.
//!
//! The flagship scenario is [`link_drop_scenario`]: a 3-device edge
//! cluster serves batched generation over a fast source↔worker link;
//! mid-generation the link collapses (e.g. 1000 → 0.4 Mbps).  The same
//! trace is served three times:
//!
//! 1. **adaptive** — monitors its own timings, detects the collapse,
//!    re-plans onto the healthy device, migrates KV caches over the
//!    still-fast link, and keeps decoding;
//! 2. **static + dynamics** — the paper's one-shot plan, suffering the
//!    collapsed link for every remaining iteration;
//! 3. **static, clean network** — the control: dynamics disabled must
//!    leave the static engine's numbers (and tokens) untouched.
//!
//! All three must produce byte-identical token streams — migration moves
//! KV tensors, never changes math — which is the scenario's correctness
//! anchor, while tokens/s and p95 inter-token latency are its performance
//! verdict.
//!
//! [`device_churn_scenario`] is the fault-tolerance counterpart: a stage
//! host **crashes** mid-generation (taking its KV with it).  The adaptive
//! engine must detect the loss from missing heartbeats alone, replan onto
//! the survivors, recover the lost KV — once via periodic checkpoint
//! replay, once via re-prefill from token history — and still emit the
//! exact token stream of an uninterrupted run.  A static engine cannot
//! serve this trace at all (it would block forever on the dead host), so
//! the comparison is adaptive-under-churn vs. static-on-a-clean-network.
//!
//! [`continuous_churn_scenario`] repeats the crash experiment on the
//! **continuous-batching** path: a ragged request mix keeps the slot
//! scheduler admitting, retiring and recomposing rows, the crash lands
//! mid-run, and recovery is per row — checkpoint restore reconciled
//! against the mutated composition in one run, per-row re-prefill in the
//! other — with the same byte-identical anchor against a clean
//! continuous control run.

use anyhow::{Context, Result};
use std::sync::{Arc, Mutex};

use super::dynamics::{DeviceShape, DynamicsDriver, NetworkDynamics, ScheduleShape};
use super::engine::{AdaptiveConfig, AdaptiveEngine, FailoverRecord, MigrationRecord};
use crate::cluster::{Cluster, Device, DeviceClass, LiveCluster};
use crate::coordinator::api::{GenRequest, GenResult, GroupRequest};
use crate::coordinator::scheduler::ContinuousConfig;
use crate::coordinator::{Engine, EngineConfig};
use crate::planner::latency::algo1;
use crate::planner::{Plan, PlanObjective, Stage};
use crate::profiler::Workload;
use crate::runtime::manifest::ManifestConfig;
use crate::runtime::{ExecService, Manifest, MeasuredProfiler, WeightStore};
use crate::util::markdown_table;

/// Scenario knobs (defaults are what the e2e test runs).
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub max_new_tokens: usize,
    pub batch: usize,
    /// When the bottleneck link collapses, simulated ms after serving
    /// starts.
    pub drop_at_ms: f64,
    pub drop_to_mbps: f64,
    pub time_scale: f64,
    pub seed: u64,
    /// KV layout every engine in the scenario runs under (padded rows or
    /// the paged block pool) — differential tests flip this and compare
    /// token streams byte-for-byte.
    pub kv_layout: crate::coordinator::KvLayout,
    /// Wire format every engine in the scenario runs under — the int8
    /// greedy-match gate flips this and compares against fp32 streams.
    pub wire_format: crate::coordinator::WireFormat,
    /// Chunked-prefill size every engine runs under (0 = monolithic).
    pub prefill_chunk: usize,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            max_new_tokens: 96,
            batch: 8,
            drop_at_ms: 120.0,
            drop_to_mbps: 0.4,
            time_scale: 1.0,
            seed: 0,
            kv_layout: crate::coordinator::KvLayout::default(),
            wire_format: crate::coordinator::WireFormat::F32,
            prefill_chunk: 0,
        }
    }
}

/// One engine run, summarized.
#[derive(Debug)]
pub struct RunSummary {
    pub label: String,
    pub tokens_per_s: f64,
    pub p95_iter_ms: f64,
    pub makespan_ms: f64,
    /// Real rows / total rows over every frame the engine sent.
    pub padding_efficiency: f64,
    pub results: Vec<GenResult>,
}

impl RunSummary {
    /// Token rows sorted by request id (the cross-run comparison key).
    pub fn token_rows(&self) -> Vec<Vec<i32>> {
        let mut rs: Vec<&GenResult> = self.results.iter().collect();
        rs.sort_by_key(|r| r.id);
        rs.iter().map(|r| r.tokens.clone()).collect()
    }
}

/// Everything the link-drop experiment produced.
#[derive(Debug)]
pub struct ScenarioReport {
    pub initial_plan: String,
    pub adaptive: RunSummary,
    pub static_dynamic: RunSummary,
    pub static_clean: RunSummary,
    pub migrations: Vec<MigrationRecord>,
    pub replan_evaluations: u64,
    pub final_plan: String,
}

/// The tiny-but-fast model config the scenarios run.
fn mini_config() -> ManifestConfig {
    ManifestConfig::mini_sim("tinyllama-mini-sim", 16, 128)
}

/// The scenario's 3-device cluster: the source (d0), the initially
/// preferred worker (d1, fast 1000 Mbps link) and the alternative (d2,
/// 300 Mbps links).  Memory budgets are sized so no single device can
/// host the whole model — partitioning is forced, exactly the regime the
/// paper targets.
fn mini_cluster(manifest: &Manifest, workload: Workload) -> Cluster {
    let model = crate::model::tiny_from_manifest(manifest);
    let total = model.range_memory_bytes(0, model.n_layers(), workload.batch);
    let budget = (total as f64 * 0.6) as u64;
    let devices = vec![
        Device::with_usable_mem(0, DeviceClass::agx_orin(), budget),
        Device::with_usable_mem(1, DeviceClass::agx_orin(), budget),
        Device::with_usable_mem(2, DeviceClass::agx_orin(), budget),
    ];
    let mut c = Cluster::new(devices, 300.0, 3.0);
    c.set_bandwidth(0, 1, 1000.0);
    c
}

fn mini_group(
    batch: usize,
    seed: u64,
    max_new_tokens: usize,
    vocab: usize,
    prompt_len: usize,
) -> GroupRequest {
    let mut tokens = Vec::with_capacity(batch * prompt_len);
    for r in 0..batch {
        for i in 0..prompt_len {
            tokens.push(((i * 7 + r * 13 + seed as usize) % vocab) as i32);
        }
    }
    GroupRequest {
        group_id: 1,
        request_ids: (1..=batch as u64).collect(),
        tokens,
        batch,
        prompt_len,
        max_new_tokens,
    }
}

fn summarize(
    label: &str,
    results: Vec<GenResult>,
    tokens: u64,
    makespan_ms: f64,
    iter_latency: &mut crate::metrics::Histogram,
    padding_efficiency: f64,
) -> RunSummary {
    RunSummary {
        label: label.to_string(),
        tokens_per_s: if makespan_ms > 0.0 {
            tokens as f64 / (makespan_ms / 1e3)
        } else {
            0.0
        },
        p95_iter_ms: iter_latency.percentile(95.0),
        makespan_ms,
        padding_efficiency,
        results,
    }
}

/// Run the mid-generation link-drop experiment; see the module docs.
pub fn link_drop_scenario(cfg: &ScenarioConfig) -> Result<ScenarioReport> {
    let manifest = Manifest::synthetic(mini_config(), vec![1, cfg.batch]);
    let weights = WeightStore::synthetic(&manifest, cfg.seed);
    let (_svc, exec) = ExecService::start_sim(&manifest)?;

    let workload = Workload {
        prompt_len: manifest.config.prefill_len,
        gen_len: cfg.max_new_tokens,
        batch: cfg.batch,
    };
    let cluster = mini_cluster(&manifest, workload);

    // offline profiling through the very backend that will serve
    let mut profiler = MeasuredProfiler::new(&manifest, &weights, exec.clone());
    profiler.reps = 2;
    let traces = profiler.profile(&cluster, workload)?;

    let pool: Vec<usize> = (0..cluster.len()).collect();
    let plan: Plan = algo1(&traces, &cluster, &pool, cfg.batch)
        .map_err(|e| anyhow::anyhow!("initial planning failed: {e}"))?;
    let initial_plan = plan.describe();

    let dynamics = NetworkDynamics::new().link(
        0,
        1,
        ScheduleShape::Step {
            at_ms: cfg.drop_at_ms,
            before_mbps: 1000.0,
            after_mbps: cfg.drop_to_mbps,
        },
    );
    let group = mini_group(
        cfg.batch,
        cfg.seed,
        cfg.max_new_tokens,
        manifest.config.vocab_size,
        manifest.config.prefill_len,
    );
    let engine_cfg = EngineConfig {
        time_scale: cfg.time_scale,
        kv_layout: cfg.kv_layout,
        wire_format: cfg.wire_format,
        prefill_chunk: cfg.prefill_chunk,
        ..EngineConfig::default()
    };

    // 1. adaptive engine under dynamics
    let adaptive_cfg = AdaptiveConfig {
        engine: engine_cfg.clone(),
        dynamics: Some(dynamics.clone()),
        dynamics_tick_real_ms: 4.0,
        max_migrations: 2,
        ..AdaptiveConfig::default()
    };
    let mut adaptive_engine = AdaptiveEngine::new(
        &manifest,
        &weights,
        exec.clone(),
        plan.clone(),
        cluster.clone(),
        traces.clone(),
        adaptive_cfg,
    );
    let (a_results, mut a_stats) = adaptive_engine
        .generate_sequential(std::slice::from_ref(&group))
        .context("adaptive run")?;
    let adaptive = summarize(
        "adaptive",
        a_results,
        a_stats.tokens,
        a_stats.makespan_ms,
        &mut a_stats.iter_latency,
        a_stats.padding_efficiency,
    );

    // 2. static plan under the same dynamics
    let mut s_engine =
        Engine::build(&manifest, &weights, exec.clone(), &plan, &cluster, &engine_cfg)?;
    let links = Arc::new(Mutex::new(s_engine.routed_links()));
    let driver = DynamicsDriver::spawn(
        dynamics.clone(),
        LiveCluster::new(cluster.clone()),
        links,
        cfg.time_scale,
        4.0,
    );
    let (s_results, mut s_stats) = s_engine
        .generate_sequential(std::slice::from_ref(&group))
        .context("static run under dynamics")?;
    driver.stop();
    s_engine.shutdown()?;
    let static_dynamic = summarize(
        "static+drop",
        s_results,
        s_stats.tokens,
        s_stats.makespan_ms,
        &mut s_stats.iter_latency,
        s_stats.padding_efficiency,
    );

    // 3. static plan, dynamics disabled (the control)
    let mut c_engine =
        Engine::build(&manifest, &weights, exec.clone(), &plan, &cluster, &engine_cfg)?;
    let (c_results, mut c_stats) = c_engine
        .generate_sequential(std::slice::from_ref(&group))
        .context("static clean run")?;
    c_engine.shutdown()?;
    let static_clean = summarize(
        "static+clean",
        c_results,
        c_stats.tokens,
        c_stats.makespan_ms,
        &mut c_stats.iter_latency,
        c_stats.padding_efficiency,
    );

    Ok(ScenarioReport {
        initial_plan,
        adaptive,
        static_dynamic,
        static_clean,
        migrations: a_stats.migrations,
        replan_evaluations: a_stats.replan_evaluations,
        final_plan: a_stats.final_plan,
    })
}

/// Knobs of the device-churn experiment (defaults are what the gating
/// e2e test in `tests/device_churn.rs` runs).
#[derive(Debug, Clone)]
pub struct ChurnConfig {
    pub max_new_tokens: usize,
    pub batch: usize,
    /// Which device crashes (must not be the source, device 0 — the
    /// source holds the prompts and the privacy-pinned embedding).
    pub crash_device: usize,
    /// When it crashes, simulated ms after serving starts.
    pub crash_at_ms: f64,
    /// Simulated ms of pipeline silence before failover triggers.
    pub heartbeat_timeout_ms: f64,
    /// Checkpoint cadence (tokens) for the checkpoint-replay run; the
    /// re-prefill run always disables checkpointing.
    pub checkpoint_every: usize,
    pub time_scale: f64,
    pub seed: u64,
    /// Tracer threaded into the adaptive runs (off by default).
    pub trace: crate::obs::Tracer,
    /// Failover flight-dump prefix (see `AdaptiveConfig::flight_prefix`);
    /// suffixed per run (`_ck` / `_reprefill`) so the two adaptive runs
    /// don't overwrite each other's dumps.
    pub flight_prefix: Option<std::path::PathBuf>,
    /// KV layout every engine in the experiment runs under.
    pub kv_layout: crate::coordinator::KvLayout,
    /// Wire format every engine in the experiment runs under.
    pub wire_format: crate::coordinator::WireFormat,
    /// Chunked-prefill size every engine runs under (0 = monolithic).
    /// With chunking on, re-prefill recovery folds the served history
    /// into one extended prefill instead of per-token Step replays.
    pub prefill_chunk: usize,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        // Timing rationale: per-hop latency (3 ms × 3 links) floors every
        // iteration near 10 ms, so 96 tokens keep the run alive well past
        // the 400 ms crash in any build profile, and a 4-token checkpoint
        // cadence guarantees a snapshot exists by then.  The 450 ms
        // heartbeat timeout is ~40× a healthy iteration — slow-but-alive
        // never trips it.
        ChurnConfig {
            max_new_tokens: 96,
            batch: 4,
            crash_device: 1,
            crash_at_ms: 400.0,
            heartbeat_timeout_ms: 450.0,
            checkpoint_every: 4,
            time_scale: 1.0,
            seed: 0,
            trace: crate::obs::Tracer::off(),
            flight_prefix: None,
            kv_layout: crate::coordinator::KvLayout::default(),
            wire_format: crate::coordinator::WireFormat::F32,
            prefill_chunk: 0,
        }
    }
}

/// Everything the device-churn experiment produced.
#[derive(Debug)]
pub struct ChurnReport {
    pub initial_plan: String,
    /// Adaptive run recovering via periodic-checkpoint replay.
    pub checkpointed: RunSummary,
    pub checkpointed_failovers: Vec<FailoverRecord>,
    pub checkpointed_final_plan: String,
    pub checkpoints_taken: u64,
    /// Adaptive run recovering via re-prefill from token history.
    pub reprefilled: RunSummary,
    pub reprefilled_failovers: Vec<FailoverRecord>,
    pub reprefilled_final_plan: String,
    /// The control: a static engine on a clean network (a static engine
    /// under churn would simply never finish).
    pub static_clean: RunSummary,
}

/// The churn scenario's forced 3-stage plan — one stage per device of the
/// mini cluster, so killing device 1 kills a mid-pipeline stage and
/// killing device 2 kills the head stage.
fn three_stage_plan(n_model_layers: usize) -> Plan {
    let a = n_model_layers / 3;
    let b = 2 * n_model_layers / 3;
    Plan {
        objective: PlanObjective::Latency,
        stages: vec![
            Stage { device: 0, start: 0, end: a },
            Stage { device: 1, start: a, end: b },
            Stage { device: 2, start: b, end: n_model_layers },
        ],
        predicted_ms: 0.0,
    }
}

/// Run the mid-generation device-crash experiment; see the module docs.
pub fn device_churn_scenario(cfg: &ChurnConfig) -> Result<ChurnReport> {
    anyhow::ensure!(
        cfg.crash_device != 0,
        "crash_device 0 is the source — there is nothing to fail over to"
    );
    let manifest = Manifest::synthetic(mini_config(), vec![1, cfg.batch]);
    let weights = WeightStore::synthetic(&manifest, cfg.seed);
    let (_svc, exec) = ExecService::start_sim(&manifest)?;

    let workload = Workload {
        prompt_len: manifest.config.prefill_len,
        gen_len: cfg.max_new_tokens,
        batch: cfg.batch,
    };
    let cluster = mini_cluster(&manifest, workload);

    let mut profiler = MeasuredProfiler::new(&manifest, &weights, exec.clone());
    profiler.reps = 2;
    let traces = profiler.profile(&cluster, workload)?;

    let plan = three_stage_plan(manifest.config.n_layers + 2);
    let initial_plan = plan.describe();
    let group = mini_group(
        cfg.batch,
        cfg.seed,
        cfg.max_new_tokens,
        manifest.config.vocab_size,
        manifest.config.prefill_len,
    );
    let engine_cfg = EngineConfig {
        time_scale: cfg.time_scale,
        kv_layout: cfg.kv_layout,
        wire_format: cfg.wire_format,
        prefill_chunk: cfg.prefill_chunk,
        ..EngineConfig::default()
    };
    let dynamics =
        NetworkDynamics::new().device(cfg.crash_device, DeviceShape::CrashAt(cfg.crash_at_ms));

    type ChurnRun = (RunSummary, Vec<FailoverRecord>, String, u64);
    let adaptive_run = |label: &str, checkpoint_every: usize| -> Result<ChurnRun> {
        let adaptive_cfg = AdaptiveConfig {
            engine: engine_cfg.clone(),
            dynamics: Some(dynamics.clone()),
            dynamics_tick_real_ms: 4.0,
            heartbeat_timeout_ms: cfg.heartbeat_timeout_ms,
            checkpoint_every,
            // wide hysteresis: this experiment isolates *failover* — the
            // drift-replan path is exercised by the link-drop scenario
            policy: crate::adaptive::replan::TriggerPolicy {
                degrade_factor: 10.0,
                ..Default::default()
            },
            trace: cfg.trace.clone(),
            flight_prefix: cfg.flight_prefix.as_ref().map(|p| {
                std::path::PathBuf::from(format!(
                    "{}_{}",
                    p.display(),
                    if checkpoint_every > 0 { "ck" } else { "reprefill" }
                ))
            }),
            ..AdaptiveConfig::default()
        };
        let mut engine = AdaptiveEngine::new(
            &manifest,
            &weights,
            exec.clone(),
            plan.clone(),
            cluster.clone(),
            traces.clone(),
            adaptive_cfg,
        );
        let (results, mut stats) = engine
            .generate_sequential(std::slice::from_ref(&group))
            .with_context(|| format!("churn run `{label}`"))?;
        let summary = summarize(
            label,
            results,
            stats.tokens,
            stats.makespan_ms,
            &mut stats.iter_latency,
            stats.padding_efficiency,
        );
        Ok((summary, stats.failovers, stats.final_plan, stats.checkpoints))
    };

    let (checkpointed, checkpointed_failovers, checkpointed_final_plan, checkpoints_taken) =
        adaptive_run("adaptive+crash (checkpoint)", cfg.checkpoint_every)?;
    let (reprefilled, reprefilled_failovers, reprefilled_final_plan, _) =
        adaptive_run("adaptive+crash (re-prefill)", 0)?;

    // the control: static engine, no churn
    let mut c_engine =
        Engine::build(&manifest, &weights, exec.clone(), &plan, &cluster, &engine_cfg)?;
    let (c_results, mut c_stats) = c_engine
        .generate_sequential(std::slice::from_ref(&group))
        .context("static clean run")?;
    c_engine.shutdown()?;
    let static_clean = summarize(
        "static+clean",
        c_results,
        c_stats.tokens,
        c_stats.makespan_ms,
        &mut c_stats.iter_latency,
        c_stats.padding_efficiency,
    );

    Ok(ChurnReport {
        initial_plan,
        checkpointed,
        checkpointed_failovers,
        checkpointed_final_plan,
        checkpoints_taken,
        reprefilled,
        reprefilled_failovers,
        reprefilled_final_plan,
        static_clean,
    })
}

/// Knobs of the **continuous-batching** churn experiment (defaults are
/// what the gating e2e tests in `tests/device_churn.rs` run).
#[derive(Debug, Clone)]
pub struct ContinuousChurnConfig {
    /// Per-request generation lengths.  A ragged mix keeps the slot
    /// scheduler churning — rows admit, retire and recompose throughout
    /// the run — so the checkpoint restore must reconcile a composition
    /// that mutated since the snapshot.
    pub gen_lens: Vec<usize>,
    /// Slot-scheduler pipeline depth (independent runs).
    pub runs: usize,
    pub max_batch: Option<usize>,
    pub initial_batch: Option<usize>,
    /// Which device crashes (never 0 — the source is pinned).
    pub crash_device: usize,
    /// When it crashes, simulated ms after serving starts.
    pub crash_at_ms: f64,
    pub heartbeat_timeout_ms: f64,
    /// Checkpoint cadence (tokens) for the checkpoint-restore run; the
    /// re-prefill run always disables checkpointing.
    pub checkpoint_every: usize,
    pub time_scale: f64,
    pub seed: u64,
    /// Tracer threaded into the adaptive runs (off by default).
    pub trace: crate::obs::Tracer,
    /// Failover flight-dump prefix (see `AdaptiveConfig::flight_prefix`);
    /// suffixed per run (`_ck` / `_reprefill`) so the two adaptive runs
    /// don't overwrite each other's dumps.
    pub flight_prefix: Option<std::path::PathBuf>,
    /// KV layout every engine in the experiment runs under.
    pub kv_layout: crate::coordinator::KvLayout,
    /// Wire format every engine in the experiment runs under.
    pub wire_format: crate::coordinator::WireFormat,
    /// Chunked-prefill size every engine runs under (0 = monolithic).
    /// With chunking on, per-row re-prefill recovery folds each row's
    /// served history into one extended Admit instead of Step replays.
    pub prefill_chunk: usize,
}

impl Default for ContinuousChurnConfig {
    fn default() -> Self {
        // Same timing regime as `ChurnConfig`: per-hop latency floors an
        // iteration near 10 ms, so 192 total tokens over two runs keep
        // the scheduler busy well past the 400 ms crash in any build
        // profile, and the 4-token checkpoint cadence guarantees a
        // committed snapshot by then.  Capacity (2 runs × batch 2) is
        // half the request count, so admissions and retirements straddle
        // whichever checkpoint ends up being the last one.
        ContinuousChurnConfig {
            gen_lens: vec![8, 24, 40, 40, 24, 8, 16, 32],
            runs: 2,
            max_batch: Some(2),
            initial_batch: None,
            crash_device: 1,
            crash_at_ms: 400.0,
            heartbeat_timeout_ms: 450.0,
            checkpoint_every: 4,
            time_scale: 1.0,
            seed: 0,
            trace: crate::obs::Tracer::off(),
            flight_prefix: None,
            kv_layout: crate::coordinator::KvLayout::default(),
            wire_format: crate::coordinator::WireFormat::F32,
            prefill_chunk: 0,
        }
    }
}

/// Everything the continuous-batching churn experiment produced.
#[derive(Debug)]
pub struct ContinuousChurnReport {
    pub initial_plan: String,
    /// Adaptive continuous run recovering via checkpoint restore +
    /// per-row replay.
    pub checkpointed: RunSummary,
    pub checkpointed_failovers: Vec<FailoverRecord>,
    pub checkpointed_final_plan: String,
    pub checkpoints_taken: u64,
    /// Adaptive continuous run recovering via per-row re-prefill.
    pub reprefilled: RunSummary,
    pub reprefilled_failovers: Vec<FailoverRecord>,
    pub reprefilled_final_plan: String,
    /// The control: a static engine serving the same requests
    /// continuously on a clean network.
    pub static_clean: RunSummary,
}

fn continuous_requests(
    cfg: &ContinuousChurnConfig,
    vocab: usize,
    prompt_len: usize,
) -> Vec<GenRequest> {
    cfg.gen_lens
        .iter()
        .enumerate()
        .map(|(r, &gen)| {
            GenRequest::new(
                1 + r as u64,
                (0..prompt_len)
                    .map(|i| ((i * 7 + r * 13 + cfg.seed as usize) % vocab) as i32)
                    .collect(),
                gen,
            )
        })
        .collect()
}

/// Run the continuous-batching device-crash experiment: the slot
/// scheduler serves a ragged mix, a stage host dies mid-run, and the
/// adaptive engine must fail over with per-row recovery — once via
/// checkpoint restore (composition reconciled against the snapshot),
/// once via re-prefill — and still emit per-request token streams
/// byte-identical to an uninterrupted continuous run.
pub fn continuous_churn_scenario(cfg: &ContinuousChurnConfig) -> Result<ContinuousChurnReport> {
    anyhow::ensure!(
        cfg.crash_device != 0,
        "crash_device 0 is the source — there is nothing to fail over to"
    );
    anyhow::ensure!(!cfg.gen_lens.is_empty(), "no requests configured");
    // compiled batches: admissions prefill at 1; 2 and 4 give the slot
    // scheduler real grow/shrink decisions
    let manifest = Manifest::synthetic(mini_config(), vec![1, 2, 4]);
    let weights = WeightStore::synthetic(&manifest, cfg.seed);
    let (_svc, exec) = ExecService::start_sim(&manifest)?;

    let workload = Workload {
        prompt_len: manifest.config.prefill_len,
        gen_len: cfg.gen_lens.iter().copied().max().unwrap_or(1),
        batch: 4,
    };
    let cluster = mini_cluster(&manifest, workload);

    let mut profiler = MeasuredProfiler::new(&manifest, &weights, exec.clone());
    profiler.reps = 2;
    let traces = profiler.profile(&cluster, workload)?;

    let plan = three_stage_plan(manifest.config.n_layers + 2);
    let initial_plan = plan.describe();
    let requests = continuous_requests(
        cfg,
        manifest.config.vocab_size,
        manifest.config.prefill_len,
    );
    let ccfg = ContinuousConfig {
        runs: cfg.runs,
        max_batch: cfg.max_batch,
        initial_batch: cfg.initial_batch,
        ..ContinuousConfig::default()
    };
    let engine_cfg = EngineConfig {
        time_scale: cfg.time_scale,
        kv_layout: cfg.kv_layout,
        wire_format: cfg.wire_format,
        prefill_chunk: cfg.prefill_chunk,
        ..EngineConfig::default()
    };
    let dynamics =
        NetworkDynamics::new().device(cfg.crash_device, DeviceShape::CrashAt(cfg.crash_at_ms));

    type ChurnRun = (RunSummary, Vec<FailoverRecord>, String, u64);
    let adaptive_run = |label: &str, checkpoint_every: usize| -> Result<ChurnRun> {
        let adaptive_cfg = AdaptiveConfig {
            engine: engine_cfg.clone(),
            dynamics: Some(dynamics.clone()),
            dynamics_tick_real_ms: 4.0,
            heartbeat_timeout_ms: cfg.heartbeat_timeout_ms,
            checkpoint_every,
            // wide hysteresis: this experiment isolates failover
            policy: crate::adaptive::replan::TriggerPolicy {
                degrade_factor: 10.0,
                ..Default::default()
            },
            trace: cfg.trace.clone(),
            flight_prefix: cfg.flight_prefix.as_ref().map(|p| {
                std::path::PathBuf::from(format!(
                    "{}_{}",
                    p.display(),
                    if checkpoint_every > 0 { "ck" } else { "reprefill" }
                ))
            }),
            ..AdaptiveConfig::default()
        };
        let mut engine = AdaptiveEngine::new(
            &manifest,
            &weights,
            exec.clone(),
            plan.clone(),
            cluster.clone(),
            traces.clone(),
            adaptive_cfg,
        );
        let (results, mut stats) = engine
            .generate_continuous(&requests, &ccfg)
            .with_context(|| format!("continuous churn run `{label}`"))?;
        let summary = summarize(
            label,
            results,
            stats.tokens,
            stats.makespan_ms,
            &mut stats.iter_latency,
            stats.padding_efficiency,
        );
        Ok((summary, stats.failovers, stats.final_plan, stats.checkpoints))
    };

    let (checkpointed, checkpointed_failovers, checkpointed_final_plan, checkpoints_taken) =
        adaptive_run("continuous+crash (checkpoint)", cfg.checkpoint_every)?;
    let (reprefilled, reprefilled_failovers, reprefilled_final_plan, _) =
        adaptive_run("continuous+crash (re-prefill)", 0)?;

    // the control: static continuous serving, no churn
    let mut c_engine =
        Engine::build(&manifest, &weights, exec.clone(), &plan, &cluster, &engine_cfg)?;
    let (c_results, mut c_stats) = c_engine
        .generate_continuous(&requests, &ccfg)
        .context("static clean continuous run")?;
    c_engine.shutdown()?;
    let static_clean = summarize(
        "static+clean",
        c_results,
        c_stats.tokens,
        c_stats.makespan_ms,
        &mut c_stats.iter_latency,
        c_stats.padding_efficiency,
    );

    Ok(ContinuousChurnReport {
        initial_plan,
        checkpointed,
        checkpointed_failovers,
        checkpointed_final_plan,
        checkpoints_taken,
        reprefilled,
        reprefilled_failovers,
        reprefilled_final_plan,
        static_clean,
    })
}

/// Knobs of the **open-loop** churn experiment: the continuous-batching
/// crash scenario served under Poisson arrivals, so a failover's real
/// cost — queue growth during the stall — lands in client-observed TTFT
/// instead of being invisible to a closed-loop queue.
#[derive(Debug, Clone)]
pub struct OpenLoopChurnConfig {
    pub requests: usize,
    /// Per-burst generation lengths (ragged mix).
    pub gen_lens: Vec<usize>,
    /// Mean Poisson interarrival gap, ms.  Sized so the offered load
    /// stays below capacity — the TTFT inflation must come from the
    /// recovery stall, not from steady-state saturation.
    pub mean_interarrival_ms: f64,
    pub runs: usize,
    pub max_batch: Option<usize>,
    /// Which device crashes (never 0 — the source is pinned).
    pub crash_device: usize,
    pub crash_at_ms: f64,
    pub heartbeat_timeout_ms: f64,
    pub checkpoint_every: usize,
    pub time_scale: f64,
    pub seed: u64,
    /// Tracer threaded into the adaptive run (off by default).
    pub trace: crate::obs::Tracer,
    /// Failover flight-dump prefix (see `AdaptiveConfig::flight_prefix`).
    pub flight_prefix: Option<std::path::PathBuf>,
    /// KV layout every engine in the experiment runs under.
    pub kv_layout: crate::coordinator::KvLayout,
}

impl Default for OpenLoopChurnConfig {
    fn default() -> Self {
        // ~160 requested tokens over a ~640 ms arrival span ≈ 250 tok/s
        // offered, under the ~400 tok/s the 2×2-slot pipeline sustains:
        // pre-crash requests see normal TTFT, requests arriving during
        // the [crash, recovery] window absorb the stall, and the arrival
        // span outlives the crash so both populations exist.
        OpenLoopChurnConfig {
            requests: 16,
            gen_lens: vec![4, 8, 12, 16],
            mean_interarrival_ms: 40.0,
            runs: 2,
            max_batch: Some(2),
            crash_device: 1,
            crash_at_ms: 250.0,
            heartbeat_timeout_ms: 450.0,
            checkpoint_every: 4,
            time_scale: 1.0,
            seed: 0,
            trace: crate::obs::Tracer::off(),
            flight_prefix: None,
            kv_layout: crate::coordinator::KvLayout::default(),
        }
    }
}

/// Everything the open-loop churn experiment produced.
#[derive(Debug)]
pub struct OpenLoopChurnReport {
    pub initial_plan: String,
    /// Adaptive open-loop run under the crash.
    pub churn: RunSummary,
    pub failovers: Vec<FailoverRecord>,
    pub final_plan: String,
    /// The control: a static engine serving the same arrivals on a
    /// clean network.
    pub clean: RunSummary,
    /// The recovery window `[crash, post-recovery]` (drive-clock ms)
    /// requests are classified into by their first-token time.
    pub window_ms: (f64, f64),
    /// p99 TTFT of requests whose first token landed inside the window.
    pub ttft_p99_in_window_ms: f64,
    /// p99 TTFT of everything outside it.
    pub ttft_p99_outside_ms: f64,
    /// `in / outside` — the headline open-loop recovery cost.
    pub ttft_inflation: f64,
    /// Requests inside / outside the window.
    pub in_window: usize,
    pub outside: usize,
    /// Queue-delay p99 of the churn run, ms.
    pub queue_p99_ms: f64,
    pub tokens_identical: bool,
}

/// Slack added past `crash + stall + restore-pause` when bounding the
/// recovery window: covers the replay of served history onto the new
/// pipeline, whose duration the failover record does not carry.
const RECOVERY_WINDOW_SLACK_MS: f64 = 150.0;

/// Run the open-loop churn experiment; see [`OpenLoopChurnConfig`].
pub fn open_loop_churn_scenario(cfg: &OpenLoopChurnConfig) -> Result<OpenLoopChurnReport> {
    anyhow::ensure!(
        cfg.crash_device != 0,
        "crash_device 0 is the source — there is nothing to fail over to"
    );
    let manifest = Manifest::synthetic(mini_config(), vec![1, 2, 4]);
    let weights = WeightStore::synthetic(&manifest, cfg.seed);
    let (_svc, exec) = ExecService::start_sim(&manifest)?;

    let workload = Workload {
        prompt_len: manifest.config.prefill_len,
        gen_len: cfg.gen_lens.iter().copied().max().unwrap_or(1),
        batch: 4,
    };
    let cluster = mini_cluster(&manifest, workload);
    let mut profiler = MeasuredProfiler::new(&manifest, &weights, exec.clone());
    profiler.reps = 2;
    let traces = profiler.profile(&cluster, workload)?;
    let plan = three_stage_plan(manifest.config.n_layers + 2);
    let initial_plan = plan.describe();

    let trace = crate::workload::RaggedTraceGen {
        mean_burst: 2,
        mean_interarrival_ms: cfg.mean_interarrival_ms,
        ..crate::workload::RaggedTraceGen::new(
            manifest.config.prefill_len,
            manifest.config.vocab_size as i32,
            cfg.gen_lens.clone(),
            cfg.seed,
        )
    }
    .generate(cfg.requests);
    let arrival: std::collections::HashMap<u64, f64> =
        trace.iter().map(|r| (r.id, r.arrival_ms)).collect();

    let ccfg = ContinuousConfig {
        runs: cfg.runs,
        max_batch: cfg.max_batch,
        ..ContinuousConfig::default()
    };
    let engine_cfg = EngineConfig {
        time_scale: cfg.time_scale,
        kv_layout: cfg.kv_layout,
        ..EngineConfig::default()
    };
    let dynamics =
        NetworkDynamics::new().device(cfg.crash_device, DeviceShape::CrashAt(cfg.crash_at_ms));

    // 1. adaptive open-loop serving under the crash
    let adaptive_cfg = AdaptiveConfig {
        engine: engine_cfg.clone(),
        dynamics: Some(dynamics),
        dynamics_tick_real_ms: 4.0,
        heartbeat_timeout_ms: cfg.heartbeat_timeout_ms,
        checkpoint_every: cfg.checkpoint_every,
        // wide hysteresis: this experiment isolates failover
        policy: crate::adaptive::replan::TriggerPolicy {
            degrade_factor: 10.0,
            ..Default::default()
        },
        trace: cfg.trace.clone(),
        flight_prefix: cfg.flight_prefix.clone(),
        ..AdaptiveConfig::default()
    };
    let mut engine = AdaptiveEngine::new(
        &manifest,
        &weights,
        exec.clone(),
        plan.clone(),
        cluster.clone(),
        traces.clone(),
        adaptive_cfg,
    );
    let mut queue = crate::coordinator::AdmissionQueue::replay(&trace);
    let (results, mut stats) = engine
        .generate_from_source(&mut queue, &ccfg)
        .context("open-loop churn run")?;
    let queue_p99_ms = stats.queue_delay.percentile(99.0);
    let failovers = std::mem::take(&mut stats.failovers);
    let final_plan = stats.final_plan.clone();
    let churn = summarize(
        "open-loop+crash",
        results,
        stats.tokens,
        stats.makespan_ms,
        &mut stats.iter_latency,
        stats.padding_efficiency,
    );

    // 2. the control: static open-loop serving, clean network, same trace
    let mut c_engine =
        Engine::build(&manifest, &weights, exec.clone(), &plan, &cluster, &engine_cfg)?;
    let mut c_queue = crate::coordinator::AdmissionQueue::replay(&trace);
    let (c_results, mut c_stats) = c_engine
        .generate_from_source(&mut c_queue, &ccfg)
        .context("open-loop clean run")?;
    c_engine.shutdown()?;
    let clean = summarize(
        "open-loop+clean",
        c_results,
        c_stats.tokens,
        c_stats.makespan_ms,
        &mut c_stats.iter_latency,
        c_stats.padding_efficiency,
    );

    // 3. classify by first-token time: the recovery window spans the
    //    crash through detection (stall), restore freight and replay
    let win_hi = cfg.crash_at_ms
        + failovers
            .iter()
            .map(|f| f.stalled_ms + f.pause_ms)
            .fold(0.0, f64::max)
        + RECOVERY_WINDOW_SLACK_MS;
    let window_ms = (cfg.crash_at_ms, win_hi);
    let mut in_hist = crate::metrics::Histogram::new();
    let mut out_hist = crate::metrics::Histogram::new();
    for r in &churn.results {
        let first_tok_at = arrival.get(&r.id).copied().unwrap_or(0.0) + r.ttft_ms;
        if first_tok_at >= window_ms.0 && first_tok_at <= window_ms.1 {
            in_hist.record(r.ttft_ms);
        } else {
            out_hist.record(r.ttft_ms);
        }
    }
    let ttft_p99_in_window_ms = in_hist.percentile(99.0);
    let ttft_p99_outside_ms = out_hist.percentile(99.0);
    let ttft_inflation = if ttft_p99_outside_ms > 0.0 {
        ttft_p99_in_window_ms / ttft_p99_outside_ms
    } else {
        0.0
    };
    let tokens_identical = churn.token_rows() == clean.token_rows();

    Ok(OpenLoopChurnReport {
        initial_plan,
        churn,
        failovers,
        final_plan,
        clean,
        window_ms,
        ttft_p99_in_window_ms,
        ttft_p99_outside_ms,
        ttft_inflation,
        in_window: in_hist.len(),
        outside: out_hist.len(),
        queue_p99_ms,
        tokens_identical,
    })
}

/// Render the open-loop churn report as the markdown `edgeshard repro
/// churn` appends.
pub fn open_loop_churn_markdown(r: &OpenLoopChurnReport) -> String {
    let mut out = String::new();
    out.push_str("# Open-loop failover — recovery-window TTFT inflation\n\n");
    out.push_str(&format!("initial plan: `{}`\n", r.initial_plan));
    out.push_str(&format!("final plan:   `{}`\n\n", r.final_plan));
    let rows: Vec<Vec<String>> = [&r.churn, &r.clean]
        .iter()
        .map(|s| {
            vec![
                s.label.clone(),
                format!("{:.1}", s.tokens_per_s),
                format!("{:.2}", s.p95_iter_ms),
                format!("{:.0}", s.makespan_ms),
            ]
        })
        .collect();
    out.push_str(&markdown_table(
        &["engine", "tokens/s", "p95 inter-token (ms)", "makespan (ms)"],
        &rows,
    ));
    out.push('\n');
    for f in &r.failovers {
        out.push_str(&format!(
            "failover @token {}: d{} declared dead after {:.0} ms silence, `{}` → `{}` \
             ({} runs restored, {} frames replayed, {:.1} ms restore pause)\n",
            f.at_iter,
            f.dead_device,
            f.stalled_ms,
            f.from_plan,
            f.to_plan,
            f.restored_groups,
            f.replayed_iters,
            f.pause_ms,
        ));
    }
    out.push_str(&format!(
        "\nrecovery window [{:.0}, {:.0}] ms: p99 TTFT {:.0} ms over {} in-window requests \
         vs {:.0} ms over {} outside ({:.1}x inflation, confined to the window); \
         queue-delay p99 {:.0} ms; tokens identical vs clean open-loop run: {}\n",
        r.window_ms.0,
        r.window_ms.1,
        r.ttft_p99_in_window_ms,
        r.in_window,
        r.ttft_p99_outside_ms,
        r.outside,
        r.ttft_inflation,
        r.queue_p99_ms,
        r.tokens_identical
    ));
    out
}

/// Render the continuous-batching churn report as the markdown
/// `edgeshard repro churn` appends.
pub fn continuous_churn_markdown(r: &ContinuousChurnReport) -> String {
    let mut out = String::new();
    out.push_str("# Fault tolerance — device crash under continuous batching\n\n");
    out.push_str(&format!("initial plan: `{}`\n", r.initial_plan));
    out.push_str(&format!(
        "final plan (checkpoint run):  `{}`\n",
        r.checkpointed_final_plan
    ));
    out.push_str(&format!(
        "final plan (re-prefill run):  `{}`\n\n",
        r.reprefilled_final_plan
    ));
    let rows: Vec<Vec<String>> = [&r.checkpointed, &r.reprefilled, &r.static_clean]
        .iter()
        .map(|s| {
            vec![
                s.label.clone(),
                format!("{:.1}", s.tokens_per_s),
                format!("{:.2}", s.p95_iter_ms),
                format!("{:.2}", s.padding_efficiency),
                format!("{:.0}", s.makespan_ms),
            ]
        })
        .collect();
    out.push_str(&markdown_table(
        &[
            "engine",
            "tokens/s",
            "p95 inter-token (ms)",
            "padding eff.",
            "makespan (ms)",
        ],
        &rows,
    ));
    out.push('\n');
    for (run, fos) in [
        ("checkpoint", &r.checkpointed_failovers),
        ("re-prefill", &r.reprefilled_failovers),
    ] {
        for f in fos.iter() {
            out.push_str(&format!(
                "failover ({run}) @token {}: d{} declared dead after {:.0} ms silence, \
                 `{}` → `{}` ({} runs restored, {} frames replayed, {} KV bytes, \
                 {:.1} ms restore pause)\n",
                f.at_iter,
                f.dead_device,
                f.stalled_ms,
                f.from_plan,
                f.to_plan,
                f.restored_groups,
                f.replayed_iters,
                f.restore_kv_bytes,
                f.pause_ms,
            ));
        }
    }
    out.push_str(&format!(
        "\ncheckpoints taken: {}; tokens identical across runs: {}\n",
        r.checkpoints_taken,
        r.checkpointed.token_rows() == r.static_clean.token_rows()
            && r.reprefilled.token_rows() == r.static_clean.token_rows()
    ));
    out
}

/// Render the report as the markdown `edgeshard repro churn` emits.
pub fn churn_report_markdown(r: &ChurnReport) -> String {
    let mut out = String::new();
    out.push_str("# Fault tolerance — mid-generation device crash\n\n");
    out.push_str(&format!("initial plan: `{}`\n", r.initial_plan));
    out.push_str(&format!(
        "final plan (checkpoint run):  `{}`\n",
        r.checkpointed_final_plan
    ));
    out.push_str(&format!(
        "final plan (re-prefill run):  `{}`\n\n",
        r.reprefilled_final_plan
    ));
    let rows: Vec<Vec<String>> = [&r.checkpointed, &r.reprefilled, &r.static_clean]
        .iter()
        .map(|s| {
            vec![
                s.label.clone(),
                format!("{:.1}", s.tokens_per_s),
                format!("{:.2}", s.p95_iter_ms),
                format!("{:.0}", s.makespan_ms),
            ]
        })
        .collect();
    out.push_str(&markdown_table(
        &["engine", "tokens/s", "p95 inter-token (ms)", "makespan (ms)"],
        &rows,
    ));
    out.push('\n');
    for (run, fos) in [
        ("checkpoint", &r.checkpointed_failovers),
        ("re-prefill", &r.reprefilled_failovers),
    ] {
        for f in fos.iter() {
            out.push_str(&format!(
                "failover ({run}) @token {}: d{} declared dead after {:.0} ms silence, \
                 `{}` → `{}` ({} groups restored, {} iters replayed, {} KV bytes, \
                 {:.1} ms restore pause)\n",
                f.at_iter,
                f.dead_device,
                f.stalled_ms,
                f.from_plan,
                f.to_plan,
                f.restored_groups,
                f.replayed_iters,
                f.restore_kv_bytes,
                f.pause_ms,
            ));
        }
    }
    out.push_str(&format!(
        "\ncheckpoints taken: {}; tokens identical across runs: {}\n",
        r.checkpoints_taken,
        r.checkpointed.token_rows() == r.static_clean.token_rows()
            && r.reprefilled.token_rows() == r.static_clean.token_rows()
    ));
    out
}

/// Render the report as the markdown `edgeshard repro adaptive` emits.
pub fn report_markdown(r: &ScenarioReport) -> String {
    let mut out = String::new();
    out.push_str("# Adaptive recovery — mid-generation bandwidth drop\n\n");
    out.push_str(&format!("initial plan: `{}`\n", r.initial_plan));
    out.push_str(&format!("final plan:   `{}`\n\n", r.final_plan));
    let rows: Vec<Vec<String>> = [&r.adaptive, &r.static_dynamic, &r.static_clean]
        .iter()
        .map(|s| {
            vec![
                s.label.clone(),
                format!("{:.1}", s.tokens_per_s),
                format!("{:.2}", s.p95_iter_ms),
                format!("{:.2}", s.padding_efficiency),
                format!("{:.0}", s.makespan_ms),
            ]
        })
        .collect();
    out.push_str(&markdown_table(
        &[
            "engine",
            "tokens/s",
            "p95 inter-token (ms)",
            "padding eff.",
            "makespan (ms)",
        ],
        &rows,
    ));
    out.push('\n');
    for m in &r.migrations {
        out.push_str(&format!(
            "migration @token {}: `{}` → `{}` ({} KV bytes, {:.1} ms pause)\n",
            m.at_iter,
            m.from_plan,
            m.to_plan,
            m.kv_bytes,
            m.pause_ms
        ));
    }
    out.push_str(&format!(
        "\nreplan evaluations: {}; tokens identical across engines: {}\n",
        r.replan_evaluations,
        r.adaptive.token_rows() == r.static_dynamic.token_rows()
            && r.adaptive.token_rows() == r.static_clean.token_rows()
    ));
    out
}
