//! Canned adaptive-runtime experiments, shared by the integration tests,
//! the `adaptive_recovery` example and `edgeshard repro adaptive`.
//!
//! The flagship scenario is [`link_drop_scenario`]: a 3-device edge
//! cluster serves batched generation over a fast source↔worker link;
//! mid-generation the link collapses (e.g. 1000 → 0.4 Mbps).  The same
//! trace is served three times:
//!
//! 1. **adaptive** — monitors its own timings, detects the collapse,
//!    re-plans onto the healthy device, migrates KV caches over the
//!    still-fast link, and keeps decoding;
//! 2. **static + dynamics** — the paper's one-shot plan, suffering the
//!    collapsed link for every remaining iteration;
//! 3. **static, clean network** — the control: dynamics disabled must
//!    leave the static engine's numbers (and tokens) untouched.
//!
//! All three must produce byte-identical token streams — migration moves
//! KV tensors, never changes math — which is the scenario's correctness
//! anchor, while tokens/s and p95 inter-token latency are its performance
//! verdict.

use anyhow::{Context, Result};
use std::sync::{Arc, Mutex};

use super::dynamics::{DynamicsDriver, NetworkDynamics, ScheduleShape};
use super::engine::{AdaptiveConfig, AdaptiveEngine, MigrationRecord};
use crate::cluster::{Cluster, Device, DeviceClass, LiveCluster};
use crate::coordinator::api::{GenResult, GroupRequest};
use crate::coordinator::{Engine, EngineConfig};
use crate::planner::latency::algo1;
use crate::planner::Plan;
use crate::profiler::Workload;
use crate::runtime::manifest::ManifestConfig;
use crate::runtime::{ExecService, Manifest, MeasuredProfiler, WeightStore};
use crate::util::markdown_table;

/// Scenario knobs (defaults are what the e2e test runs).
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub max_new_tokens: usize,
    pub batch: usize,
    /// When the bottleneck link collapses, simulated ms after serving
    /// starts.
    pub drop_at_ms: f64,
    pub drop_to_mbps: f64,
    pub time_scale: f64,
    pub seed: u64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            max_new_tokens: 96,
            batch: 8,
            drop_at_ms: 120.0,
            drop_to_mbps: 0.4,
            time_scale: 1.0,
            seed: 0,
        }
    }
}

/// One engine run, summarized.
#[derive(Debug)]
pub struct RunSummary {
    pub label: String,
    pub tokens_per_s: f64,
    pub p95_iter_ms: f64,
    pub makespan_ms: f64,
    /// Real rows / total rows over every frame the engine sent.
    pub padding_efficiency: f64,
    pub results: Vec<GenResult>,
}

impl RunSummary {
    /// Token rows sorted by request id (the cross-run comparison key).
    pub fn token_rows(&self) -> Vec<Vec<i32>> {
        let mut rs: Vec<&GenResult> = self.results.iter().collect();
        rs.sort_by_key(|r| r.id);
        rs.iter().map(|r| r.tokens.clone()).collect()
    }
}

/// Everything the link-drop experiment produced.
#[derive(Debug)]
pub struct ScenarioReport {
    pub initial_plan: String,
    pub adaptive: RunSummary,
    pub static_dynamic: RunSummary,
    pub static_clean: RunSummary,
    pub migrations: Vec<MigrationRecord>,
    pub replan_evaluations: u64,
    pub final_plan: String,
}

/// The tiny-but-fast model config the scenarios run.
fn mini_config() -> ManifestConfig {
    ManifestConfig::mini_sim("tinyllama-mini-sim", 16, 128)
}

/// The scenario's 3-device cluster: the source (d0), the initially
/// preferred worker (d1, fast 1000 Mbps link) and the alternative (d2,
/// 300 Mbps links).  Memory budgets are sized so no single device can
/// host the whole model — partitioning is forced, exactly the regime the
/// paper targets.
fn mini_cluster(manifest: &Manifest, workload: Workload) -> Cluster {
    let model = crate::model::tiny_from_manifest(manifest);
    let total = model.range_memory_bytes(0, model.n_layers(), workload.batch);
    let budget = (total as f64 * 0.6) as u64;
    let devices = vec![
        Device::with_usable_mem(0, DeviceClass::agx_orin(), budget),
        Device::with_usable_mem(1, DeviceClass::agx_orin(), budget),
        Device::with_usable_mem(2, DeviceClass::agx_orin(), budget),
    ];
    let mut c = Cluster::new(devices, 300.0, 3.0);
    c.set_bandwidth(0, 1, 1000.0);
    c
}

fn mini_group(cfg: &ScenarioConfig, vocab: usize, prompt_len: usize) -> GroupRequest {
    let mut tokens = Vec::with_capacity(cfg.batch * prompt_len);
    for r in 0..cfg.batch {
        for i in 0..prompt_len {
            tokens.push(((i * 7 + r * 13 + cfg.seed as usize) % vocab) as i32);
        }
    }
    GroupRequest {
        group_id: 1,
        request_ids: (1..=cfg.batch as u64).collect(),
        tokens,
        batch: cfg.batch,
        prompt_len,
        max_new_tokens: cfg.max_new_tokens,
    }
}

fn summarize(
    label: &str,
    results: Vec<GenResult>,
    tokens: u64,
    makespan_ms: f64,
    iter_latency: &mut crate::metrics::Histogram,
    padding_efficiency: f64,
) -> RunSummary {
    RunSummary {
        label: label.to_string(),
        tokens_per_s: if makespan_ms > 0.0 {
            tokens as f64 / (makespan_ms / 1e3)
        } else {
            0.0
        },
        p95_iter_ms: iter_latency.percentile(95.0),
        makespan_ms,
        padding_efficiency,
        results,
    }
}

/// Run the mid-generation link-drop experiment; see the module docs.
pub fn link_drop_scenario(cfg: &ScenarioConfig) -> Result<ScenarioReport> {
    let manifest = Manifest::synthetic(mini_config(), vec![1, cfg.batch]);
    let weights = WeightStore::synthetic(&manifest, cfg.seed);
    let (_svc, exec) = ExecService::start_sim(&manifest)?;

    let workload = Workload {
        prompt_len: manifest.config.prefill_len,
        gen_len: cfg.max_new_tokens,
        batch: cfg.batch,
    };
    let cluster = mini_cluster(&manifest, workload);

    // offline profiling through the very backend that will serve
    let mut profiler = MeasuredProfiler::new(&manifest, &weights, exec.clone());
    profiler.reps = 2;
    let traces = profiler.profile(&cluster, workload)?;

    let pool: Vec<usize> = (0..cluster.len()).collect();
    let plan: Plan = algo1(&traces, &cluster, &pool, cfg.batch)
        .map_err(|e| anyhow::anyhow!("initial planning failed: {e}"))?;
    let initial_plan = plan.describe();

    let dynamics = NetworkDynamics::new().link(
        0,
        1,
        ScheduleShape::Step {
            at_ms: cfg.drop_at_ms,
            before_mbps: 1000.0,
            after_mbps: cfg.drop_to_mbps,
        },
    );
    let group = mini_group(cfg, manifest.config.vocab_size, manifest.config.prefill_len);
    let engine_cfg = EngineConfig {
        time_scale: cfg.time_scale,
        ..EngineConfig::default()
    };

    // 1. adaptive engine under dynamics
    let adaptive_cfg = AdaptiveConfig {
        engine: engine_cfg.clone(),
        dynamics: Some(dynamics.clone()),
        dynamics_tick_real_ms: 4.0,
        max_migrations: 2,
        ..AdaptiveConfig::default()
    };
    let mut adaptive_engine = AdaptiveEngine::new(
        &manifest,
        &weights,
        exec.clone(),
        plan.clone(),
        cluster.clone(),
        traces.clone(),
        adaptive_cfg,
    );
    let (a_results, mut a_stats) = adaptive_engine
        .generate_sequential(std::slice::from_ref(&group))
        .context("adaptive run")?;
    let adaptive = summarize(
        "adaptive",
        a_results,
        a_stats.tokens,
        a_stats.makespan_ms,
        &mut a_stats.iter_latency,
        a_stats.padding_efficiency,
    );

    // 2. static plan under the same dynamics
    let mut s_engine =
        Engine::build(&manifest, &weights, exec.clone(), &plan, &cluster, &engine_cfg)?;
    let links = Arc::new(Mutex::new(s_engine.routed_links()));
    let driver = DynamicsDriver::spawn(
        dynamics.clone(),
        LiveCluster::new(cluster.clone()),
        links,
        cfg.time_scale,
        4.0,
    );
    let (s_results, mut s_stats) = s_engine
        .generate_sequential(std::slice::from_ref(&group))
        .context("static run under dynamics")?;
    driver.stop();
    s_engine.shutdown()?;
    let static_dynamic = summarize(
        "static+drop",
        s_results,
        s_stats.tokens,
        s_stats.makespan_ms,
        &mut s_stats.iter_latency,
        s_stats.padding_efficiency,
    );

    // 3. static plan, dynamics disabled (the control)
    let mut c_engine =
        Engine::build(&manifest, &weights, exec.clone(), &plan, &cluster, &engine_cfg)?;
    let (c_results, mut c_stats) = c_engine
        .generate_sequential(std::slice::from_ref(&group))
        .context("static clean run")?;
    c_engine.shutdown()?;
    let static_clean = summarize(
        "static+clean",
        c_results,
        c_stats.tokens,
        c_stats.makespan_ms,
        &mut c_stats.iter_latency,
        c_stats.padding_efficiency,
    );

    Ok(ScenarioReport {
        initial_plan,
        adaptive,
        static_dynamic,
        static_clean,
        migrations: a_stats.migrations,
        replan_evaluations: a_stats.replan_evaluations,
        final_plan: a_stats.final_plan,
    })
}

/// Render the report as the markdown `edgeshard repro adaptive` emits.
pub fn report_markdown(r: &ScenarioReport) -> String {
    let mut out = String::new();
    out.push_str("# Adaptive recovery — mid-generation bandwidth drop\n\n");
    out.push_str(&format!("initial plan: `{}`\n", r.initial_plan));
    out.push_str(&format!("final plan:   `{}`\n\n", r.final_plan));
    let rows: Vec<Vec<String>> = [&r.adaptive, &r.static_dynamic, &r.static_clean]
        .iter()
        .map(|s| {
            vec![
                s.label.clone(),
                format!("{:.1}", s.tokens_per_s),
                format!("{:.2}", s.p95_iter_ms),
                format!("{:.2}", s.padding_efficiency),
                format!("{:.0}", s.makespan_ms),
            ]
        })
        .collect();
    out.push_str(&markdown_table(
        &[
            "engine",
            "tokens/s",
            "p95 inter-token (ms)",
            "padding eff.",
            "makespan (ms)",
        ],
        &rows,
    ));
    out.push('\n');
    for m in &r.migrations {
        out.push_str(&format!(
            "migration @token {}: `{}` → `{}` ({} KV bytes, {:.1} ms pause)\n",
            m.at_iter,
            m.from_plan,
            m.to_plan,
            m.kv_bytes,
            m.pause_ms
        ));
    }
    out.push_str(&format!(
        "\nreplan evaluations: {}; tokens identical across engines: {}\n",
        r.replan_evaluations,
        r.adaptive.token_rows() == r.static_dynamic.token_rows()
            && r.adaptive.token_rows() == r.static_clean.token_rows()
    ));
    out
}
