//! Trigger policy + live re-solve: when the observed state has drifted
//! past a hysteresis band, re-run the paper's DP planners on the
//! *observed* cluster/traces and emit a migration diff.
//!
//! Invariants (property-tested in `tests/adaptive_replan.rs`):
//!
//! * every emitted plan passes [`crate::planner::validate_plan`] on the
//!   observed state;
//! * an emitted plan is never predicted-worse than keeping the current
//!   plan on that same observed state (by at least the hysteresis
//!   factor), so the engine cannot be talked into a regression by its own
//!   replanner.

use crate::cluster::Cluster;
use crate::planner::latency::algo1;
use crate::planner::throughput::algo2_classes;
use crate::planner::{
    pipeline_bottleneck_ms, sequential_latency_ms, validate_plan, Plan, PlanObjective,
};
use crate::profiler::ProfiledTraces;
use std::collections::HashMap;

/// When to abandon the current plan.
#[derive(Debug, Clone)]
pub struct TriggerPolicy {
    /// Consider replanning only once the current plan's predicted metric
    /// on the *observed* state exceeds `degrade_factor ×` its adopted
    /// baseline (the band that absorbs measurement noise).
    pub degrade_factor: f64,
    /// Migrate only if the candidate beats the current plan on the
    /// observed state by at least this factor (`cand × improve ≤ cur`) —
    /// the hysteresis that prevents plan flapping.
    pub improve_factor: f64,
    /// Cooldown between migrations, simulated ms.
    pub min_interval_ms: f64,
    /// Migration **cost awareness**: adopt a candidate only if its
    /// predicted total savings over the remaining iterations,
    /// `(cur − cand) × remaining`, exceed `migration_cost_factor ×` the
    /// predicted migration pause (the KV freight's delivery time on the
    /// observed network).  1.0 = break even over the remaining tokens;
    /// higher demands the pause amortize with margin; 0 disables the
    /// gate (the pre-cost-awareness behavior).
    pub migration_cost_factor: f64,
}

impl Default for TriggerPolicy {
    fn default() -> Self {
        TriggerPolicy {
            degrade_factor: 1.4,
            improve_factor: 1.15,
            min_interval_ms: 0.0,
            migration_cost_factor: 1.0,
        }
    }
}

/// A contiguous run of layers changing device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageMove {
    /// Model layers `[layer_lo, layer_hi)` moving.
    pub layer_lo: usize,
    pub layer_hi: usize,
    pub from: usize,
    pub to: usize,
    /// KV bytes that must cross `from → to` for these layers.
    pub kv_bytes: u64,
}

/// The layer-wise diff between two plans, with KV freight.
#[derive(Debug, Clone, Default)]
pub struct MigrationDiff {
    pub moves: Vec<StageMove>,
    pub total_kv_bytes: u64,
}

impl MigrationDiff {
    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }

    /// Predicted stall while KV state crosses the network: per-link
    /// freight is serialized, distinct links transfer in parallel, so the
    /// pause is the slowest link's delivery time on `cluster`.
    pub fn pause_ms(&self, cluster: &Cluster) -> f64 {
        let mut per_link: HashMap<(usize, usize), u64> = HashMap::new();
        for m in &self.moves {
            *per_link.entry((m.from, m.to)).or_insert(0) += m.kv_bytes;
        }
        per_link
            .iter()
            .map(|(&(f, t), &bytes)| cluster.comm_ms(f, t, bytes))
            .fold(0.0, f64::max)
    }
}

/// Layer-wise diff of `old → new`: which layers change device and how
/// many KV bytes ride along (`kv_bytes_per_seq[layer] × batch`; layers
/// without KV — embedding, head — move for free).
pub fn migration_diff(
    old: &Plan,
    new: &Plan,
    kv_bytes_per_seq: &[u64],
    batch: usize,
) -> MigrationDiff {
    let mut moves: Vec<StageMove> = Vec::new();
    let mut total = 0u64;
    for (layer, &kv_per_seq) in kv_bytes_per_seq.iter().enumerate() {
        let (Some(od), Some(nd)) = (old.device_of_layer(layer), new.device_of_layer(layer)) else {
            continue;
        };
        if od == nd {
            continue;
        }
        let kv = kv_per_seq * batch as u64;
        total += kv;
        match moves.last_mut() {
            Some(m) if m.layer_hi == layer && m.from == od && m.to == nd => {
                m.layer_hi = layer + 1;
                m.kv_bytes += kv;
            }
            _ => moves.push(StageMove {
                layer_lo: layer,
                layer_hi: layer + 1,
                from: od,
                to: nd,
                kv_bytes: kv,
            }),
        }
    }
    MigrationDiff {
        moves,
        total_kv_bytes: total,
    }
}

/// What the replanner concluded this round.
#[derive(Debug, Clone)]
pub enum Decision {
    /// Stay on the current plan (its predicted metric on the observed
    /// state is attached for telemetry).
    Keep { current_pred_ms: f64 },
    /// Abandon ship: adopt `plan`, moving the KV freight in `diff`.
    Migrate {
        plan: Plan,
        diff: MigrationDiff,
        current_pred_ms: f64,
        candidate_pred_ms: f64,
    },
}

/// The live re-solver.
pub struct Replanner {
    pub objective: PlanObjective,
    pub policy: TriggerPolicy,
    /// Batch used for memory accounting and KV freight.
    pub batch: usize,
    /// The current plan's predicted metric at adoption time — the
    /// reference the degrade trigger compares against.
    baseline_ms: f64,
    last_migrate_ms: f64,
    evaluations: u64,
    triggers: u64,
}

impl Replanner {
    pub fn new(
        objective: PlanObjective,
        policy: TriggerPolicy,
        batch: usize,
        baseline_ms: f64,
    ) -> Self {
        Replanner {
            objective,
            policy,
            batch: batch.max(1),
            baseline_ms,
            last_migrate_ms: f64::NEG_INFINITY,
            evaluations: 0,
            triggers: 0,
        }
    }

    /// The objective-matched plan evaluator (independent of the DPs).
    pub fn predict_ms(&self, plan: &Plan, traces: &ProfiledTraces, cluster: &Cluster) -> f64 {
        match self.objective {
            PlanObjective::Latency => sequential_latency_ms(plan, traces, cluster),
            PlanObjective::Throughput => pipeline_bottleneck_ms(plan, traces, cluster),
        }
    }

    pub fn baseline_ms(&self) -> f64 {
        self.baseline_ms
    }

    pub fn evaluations(&self) -> u64 {
        self.evaluations
    }

    pub fn triggers(&self) -> u64 {
        self.triggers
    }

    /// Record that a migration to a plan with observed prediction
    /// `new_baseline_ms` actually happened at `now_ms`.
    pub fn adopt(&mut self, new_baseline_ms: f64, now_ms: f64) {
        self.baseline_ms = new_baseline_ms;
        self.last_migrate_ms = now_ms;
    }

    /// One control-loop round over the full device pool with an
    /// unbounded amortization horizon; see [`Replanner::evaluate_pool`].
    pub fn evaluate(
        &mut self,
        current: &Plan,
        traces: &ProfiledTraces,
        cluster: &Cluster,
        now_ms: f64,
    ) -> Decision {
        let pool: Vec<usize> = (0..cluster.len()).collect();
        self.evaluate_pool(current, traces, cluster, now_ms, &pool, u64::MAX)
    }

    /// One control-loop round: compare the current plan's prediction on
    /// the observed state against its baseline, and if it degraded past
    /// the band, try to find a plan — over `pool` only, so devices the
    /// liveness detector has declared dead stay out of candidates — that
    /// is decisively better *on that same observed state* **and** whose
    /// migration pause amortizes over the `remaining_iters` decode
    /// iterations this serve still owes (see
    /// [`TriggerPolicy::migration_cost_factor`]): a cheaper steady state
    /// is not worth adopting if the generation ends before the KV
    /// freight pays for itself.
    pub fn evaluate_pool(
        &mut self,
        current: &Plan,
        traces: &ProfiledTraces,
        cluster: &Cluster,
        now_ms: f64,
        pool: &[usize],
        remaining_iters: u64,
    ) -> Decision {
        self.evaluations += 1;
        let cur = self.predict_ms(current, traces, cluster);
        let keep = Decision::Keep {
            current_pred_ms: cur,
        };
        if now_ms - self.last_migrate_ms < self.policy.min_interval_ms {
            return keep;
        }
        if cur <= self.policy.degrade_factor * self.baseline_ms {
            return keep;
        }
        let cand = match self.objective {
            PlanObjective::Latency => algo1(traces, cluster, pool, self.batch),
            PlanObjective::Throughput => algo2_classes(traces, cluster, pool, self.batch),
        };
        let Ok(cand) = cand else { return keep };
        if cand.stages == current.stages {
            return keep;
        }
        let cand_pred = self.predict_ms(&cand, traces, cluster);
        if cand_pred * self.policy.improve_factor > cur
            || validate_plan(&cand, traces, cluster, self.batch).is_err()
        {
            return keep;
        }
        let diff = migration_diff(current, &cand, &traces.kv_bytes_per_seq, self.batch);
        // cost awareness: the pause is paid once, up front, on the
        // observed network; the per-iteration savings accrue only over
        // what is left to generate
        let savings_ms = (cur - cand_pred) * remaining_iters as f64;
        if savings_ms < self.policy.migration_cost_factor * diff.pause_ms(cluster) {
            return keep;
        }
        self.triggers += 1;
        Decision::Migrate {
            plan: cand,
            diff,
            current_pred_ms: cur,
            candidate_pred_ms: cand_pred,
        }
    }

    /// Failover re-solve: the current plan is *infeasible* (a stage host
    /// is gone), so there is no keep-vs-migrate hysteresis — "keeping"
    /// cannot be predicted-better because keeping does not exist.  Solve
    /// the objective's DP over the surviving `pool` on the observed state
    /// and validate the result; the caller decides what an `Err` (no
    /// feasible plan on the survivors) means.
    pub fn solve_over(
        &self,
        traces: &ProfiledTraces,
        cluster: &Cluster,
        pool: &[usize],
    ) -> Result<Plan, crate::planner::PlanError> {
        let cand = match self.objective {
            PlanObjective::Latency => algo1(traces, cluster, pool, self.batch)?,
            PlanObjective::Throughput => algo2_classes(traces, cluster, pool, self.batch)?,
        };
        validate_plan(&cand, traces, cluster, self.batch)
            .map_err(crate::planner::PlanError::Infeasible)?;
        Ok(cand)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::llama2_7b;
    use crate::planner::{Planner, Stage};
    use crate::profiler::{AnalyticProfiler, Workload};

    fn setup() -> (ProfiledTraces, Cluster, Plan) {
        let cluster = presets::paper_testbed(50.0, 0);
        let traces = AnalyticProfiler::default().profile(
            &llama2_7b(),
            &cluster,
            Workload::paper_default(),
        );
        let plan = crate::planner::LatencyDp::new().plan(&traces, &cluster).unwrap();
        (traces, cluster, plan)
    }

    #[test]
    fn keeps_inside_hysteresis_band() {
        let (traces, cluster, plan) = setup();
        let baseline = sequential_latency_ms(&plan, &traces, &cluster);
        let mut r = Replanner::new(
            PlanObjective::Latency,
            TriggerPolicy::default(),
            1,
            baseline,
        );
        // unchanged observed state → keep, forever
        for _ in 0..5 {
            assert!(matches!(
                r.evaluate(&plan, &traces, &cluster, 0.0),
                Decision::Keep { .. }
            ));
        }
        assert_eq!(r.triggers(), 0);
    }

    #[test]
    fn migrates_after_bottleneck_link_degrades() {
        let (traces, mut cluster, plan) = setup();
        let baseline = sequential_latency_ms(&plan, &traces, &cluster);
        let mut r = Replanner::new(
            PlanObjective::Latency,
            TriggerPolicy::default(),
            1,
            baseline,
        );
        // strangle every link the current plan uses
        let devs = plan.devices();
        for w in devs.windows(2) {
            cluster.set_bandwidth(w[0], w[1], 0.2);
        }
        match r.evaluate(&plan, &traces, &cluster, 0.0) {
            Decision::Migrate {
                plan: cand,
                current_pred_ms,
                candidate_pred_ms,
                ..
            } => {
                validate_plan(&cand, &traces, &cluster, 1).unwrap();
                assert!(candidate_pred_ms < current_pred_ms);
                assert_ne!(cand.stages, plan.stages);
            }
            Decision::Keep { .. } => panic!("expected migration"),
        }
    }

    #[test]
    fn cost_awareness_blocks_unamortizable_migrations() {
        let (traces, mut cluster, plan) = setup();
        let baseline = sequential_latency_ms(&plan, &traces, &cluster);
        let mut r = Replanner::new(
            PlanObjective::Latency,
            TriggerPolicy::default(),
            1,
            baseline,
        );
        let devs = plan.devices();
        for w in devs.windows(2) {
            cluster.set_bandwidth(w[0], w[1], 0.2);
        }
        let pool: Vec<usize> = (0..cluster.len()).collect();
        // with an unbounded horizon the degraded state migrates, and the
        // freight it would move is real (the pause is not free)
        let d = r.evaluate_pool(&plan, &traces, &cluster, 0.0, &pool, u64::MAX);
        let Decision::Migrate { diff, .. } = d else {
            panic!("expected migration with unbounded horizon")
        };
        assert!(diff.pause_ms(&cluster) > 0.0, "test premise: freight is not free");
        // with no runway left, the identical degraded state must keep:
        // the serve ends before the pause pays for itself
        assert!(matches!(
            r.evaluate_pool(&plan, &traces, &cluster, 0.0, &pool, 0),
            Decision::Keep { .. }
        ));
        // a zero cost factor disables the gate entirely
        r.policy.migration_cost_factor = 0.0;
        assert!(matches!(
            r.evaluate_pool(&plan, &traces, &cluster, 0.0, &pool, 0),
            Decision::Migrate { .. }
        ));
    }

    #[test]
    fn cooldown_suppresses_back_to_back_migrations() {
        let (traces, mut cluster, plan) = setup();
        let baseline = sequential_latency_ms(&plan, &traces, &cluster);
        let policy = TriggerPolicy {
            min_interval_ms: 500.0,
            ..TriggerPolicy::default()
        };
        let mut r = Replanner::new(PlanObjective::Latency, policy, 1, baseline);
        let devs = plan.devices();
        for w in devs.windows(2) {
            cluster.set_bandwidth(w[0], w[1], 0.2);
        }
        let d1 = r.evaluate(&plan, &traces, &cluster, 0.0);
        assert!(matches!(d1, Decision::Migrate { .. }));
        r.adopt(1.0, 0.0);
        // still degraded (we did not actually switch plans), but inside
        // the cooldown window nothing fires…
        assert!(matches!(
            r.evaluate(&plan, &traces, &cluster, 100.0),
            Decision::Keep { .. }
        ));
        // …and after the cooldown it may fire again
        assert!(matches!(
            r.evaluate(&plan, &traces, &cluster, 600.0),
            Decision::Migrate { .. }
        ));
    }

    #[test]
    fn solve_over_excludes_dead_devices() {
        let (traces, cluster, plan) = setup();
        let r = Replanner::new(PlanObjective::Latency, TriggerPolicy::default(), 1, 1.0);
        // kill every non-source device the current plan uses; the forced
        // re-solve must produce a valid plan that avoids all of them
        let dead: Vec<usize> = plan
            .devices()
            .into_iter()
            .filter(|&d| d != cluster.source)
            .collect();
        assert!(!dead.is_empty(), "plan uses only the source?");
        let pool: Vec<usize> = (0..cluster.len()).filter(|d| !dead.contains(d)).collect();
        let cand = r.solve_over(&traces, &cluster, &pool).unwrap();
        validate_plan(&cand, &traces, &cluster, 1).unwrap();
        for d in cand.devices() {
            assert!(!dead.contains(&d), "failover plan uses dead device {d}");
        }
        // an unplannable pool errors instead of panicking
        assert!(r.solve_over(&traces, &cluster, &[]).is_err());
    }

    #[test]
    fn diff_merges_contiguous_runs_and_counts_kv() {
        let mk = |stages: Vec<Stage>| Plan {
            objective: PlanObjective::Latency,
            stages,
            predicted_ms: 0.0,
        };
        let old = mk(vec![
            Stage { device: 0, start: 0, end: 3 },
            Stage { device: 1, start: 3, end: 6 },
        ]);
        let new = mk(vec![
            Stage { device: 0, start: 0, end: 3 },
            Stage { device: 2, start: 3, end: 6 },
        ]);
        let kv = vec![0, 10, 10, 10, 10, 0]; // embed/head carry no KV
        let diff = migration_diff(&old, &new, &kv, 4);
        assert_eq!(diff.moves.len(), 1);
        let m = &diff.moves[0];
        assert_eq!((m.layer_lo, m.layer_hi, m.from, m.to), (3, 6, 1, 2));
        // layers 3,4 carry 10×4 bytes each, layer 5 (head) carries none
        assert_eq!(diff.total_kv_bytes, 80);
        assert_eq!(m.kv_bytes, 80);
    }

    #[test]
    fn pause_parallel_links_take_max() {
        let mut cluster = presets::tiny_demo(0);
        cluster.set_bandwidth(0, 1, 8.0);
        cluster.set_bandwidth(1, 2, 8.0);
        cluster.set_latency(0, 1, 0.0);
        cluster.set_latency(1, 2, 0.0);
        let diff = MigrationDiff {
            moves: vec![
                StageMove { layer_lo: 1, layer_hi: 2, from: 0, to: 1, kv_bytes: 1_000_000 },
                StageMove { layer_lo: 3, layer_hi: 4, from: 1, to: 2, kv_bytes: 500_000 },
            ],
            total_kv_bytes: 1_500_000,
        };
        // 1 MB at 8 Mbps = 1000 ms on link 0→1; the 0.5 MB on 1→2 overlaps
        let pause = diff.pause_ms(&cluster);
        assert!((pause - 1000.0).abs() < 1e-6, "pause={pause}");
    }

    #[test]
    fn empty_diff_for_identical_plans() {
        let (traces, _cluster, plan) = setup();
        let diff = migration_diff(&plan, &plan, &traces.kv_bytes_per_seq, 1);
        assert!(diff.is_empty());
        assert_eq!(diff.total_kv_bytes, 0);
    }
}
