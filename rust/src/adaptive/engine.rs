//! The adaptive serving engine: a coordinator pipeline that watches its
//! own timings and re-plans itself while generating.
//!
//! Control loop (every [`AdaptiveConfig::check_every`] tokens):
//!
//! 1. drain the [`Monitor`] and materialize observed cluster + traces;
//! 2. ask the [`Replanner`] whether the current plan degraded past the
//!    hysteresis band *and* a decisively better plan exists;
//! 3. if so, **drain** — stop releasing decode iterations and let
//!    in-flight ones land — then **migrate**: snapshot every stage's
//!    [`GroupCache`] via [`StageMsg::Export`], tear the pipeline down,
//!    charge the real KV transfer time on the current (live) links,
//!    rewire stage actors per the new plan with the caches preloaded,
//!    and release the held iterations.
//!
//! Token numerics are unaffected by migration: the KV tensors move
//! byte-identically, so an adaptive run emits exactly the token stream a
//! static run would — just faster when the network turns hostile
//! (asserted end-to-end in `tests/adaptive_e2e.rs`).

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::dynamics::{DynamicsDriver, NetworkDynamics};
use super::monitor::Monitor;
use super::replan::{Decision, MigrationDiff, Replanner, TriggerPolicy};
use crate::cluster::{Cluster, LiveCluster};
use crate::coordinator::api::{GenResult, GroupRequest};
use crate::coordinator::engine::{wire, EngineConfig, ObsSinks, Wired};
use crate::coordinator::kvcache::{GroupCache, KvPool};
use crate::coordinator::stage::{stage_decoders, KvEntry, Payload, Phase, StageExport, StageMsg};
use crate::metrics::Histogram;
use crate::netsim::RoutedLink;
use crate::planner::{pipeline_bottleneck_ms, sequential_latency_ms, Plan, PlanObjective};
use crate::profiler::ProfiledTraces;
use crate::runtime::manifest::Manifest;
use crate::runtime::{ExecServiceHandle, WeightStore};

/// Hard cap on the real time one migration pause may sleep (safety net
/// against a scenario that schedules a migration over a dead link).
const MAX_MIGRATION_SLEEP_REAL_MS: f64 = 30_000.0;

/// Knobs of the adaptive engine.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    pub engine: EngineConfig,
    /// Which DP re-solves on drift.
    pub objective: PlanObjective,
    pub policy: TriggerPolicy,
    /// EWMA weight of the newest observation.
    pub monitor_alpha: f64,
    /// Run the control loop every this many received token messages.
    pub check_every: usize,
    /// Upper bound on migrations per generate call.
    pub max_migrations: usize,
    /// Ground-truth network weather to replay during generation (the
    /// monitor never reads it — only its effects on timings).
    pub dynamics: Option<NetworkDynamics>,
    /// Dynamics replay granularity, real ms.
    pub dynamics_tick_real_ms: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            engine: EngineConfig::default(),
            objective: PlanObjective::Latency,
            policy: TriggerPolicy::default(),
            monitor_alpha: 0.5,
            check_every: 2,
            max_migrations: 4,
            dynamics: None,
            dynamics_tick_real_ms: 5.0,
        }
    }
}

/// One completed migration.
#[derive(Debug, Clone)]
pub struct MigrationRecord {
    /// Token messages received when the migration committed.
    pub at_iter: u64,
    pub from_plan: String,
    pub to_plan: String,
    /// KV freight that crossed the network.
    pub kv_bytes: u64,
    /// Simulated generation stall while it crossed.
    pub pause_ms: f64,
}

/// Aggregate statistics of one adaptive run.
#[derive(Debug)]
pub struct AdaptiveStats {
    pub makespan_ms: f64,
    pub tokens: u64,
    pub throughput_tps: f64,
    pub ttft: Histogram,
    pub iter_latency: Histogram,
    /// Control-loop rounds that ran.
    pub replan_evaluations: u64,
    pub migrations: Vec<MigrationRecord>,
    pub final_plan: String,
}

/// An engine that owns its plan and may replace it mid-generation.
pub struct AdaptiveEngine<'a> {
    manifest: &'a Manifest,
    weights: &'a WeightStore,
    exec: ExecServiceHandle,
    live: LiveCluster,
    base_traces: ProfiledTraces,
    plan: Plan,
    cfg: AdaptiveConfig,
}

fn sim_now_ms(t0: Instant, time_scale: f64) -> f64 {
    let real = t0.elapsed().as_secs_f64() * 1e3;
    if time_scale > 0.0 {
        real / time_scale
    } else {
        real
    }
}

fn send_prefill(wired: &Wired, g: &GroupRequest) -> Result<()> {
    let msg = StageMsg::Work {
        group: g.group_id,
        iter: 0,
        pos: 0,
        phase: Phase::Prefill,
        batch: g.batch,
        prompt_len: g.prompt_len,
        payload: Payload::Tokens(g.tokens.clone()),
    };
    let bytes = msg.bytes();
    wired.to_first.send(msg, bytes)
}

fn send_decode(wired: &Wired, g: &GroupRequest, iter: usize, tokens: Vec<i32>) -> Result<()> {
    let pos = (g.prompt_len + iter - 1) as i32;
    let msg = StageMsg::Work {
        group: g.group_id,
        iter,
        pos,
        phase: Phase::Decode,
        batch: g.batch,
        prompt_len: g.prompt_len,
        payload: Payload::Tokens(tokens),
    };
    let bytes = msg.bytes();
    wired.to_first.send(msg, bytes)
}

impl<'a> AdaptiveEngine<'a> {
    /// `cluster` is the ground-truth starting state (also the initial
    /// belief); `base_traces` are the offline-profiled traces the initial
    /// `plan` was solved against.
    pub fn new(
        manifest: &'a Manifest,
        weights: &'a WeightStore,
        exec: ExecServiceHandle,
        plan: Plan,
        cluster: Cluster,
        base_traces: ProfiledTraces,
        cfg: AdaptiveConfig,
    ) -> Self {
        AdaptiveEngine {
            manifest,
            weights,
            exec,
            live: LiveCluster::new(cluster),
            base_traces,
            plan,
            cfg,
        }
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// The ground-truth network view (what dynamics mutate).
    pub fn live_cluster(&self) -> LiveCluster {
        self.live.clone()
    }

    /// Serve groups one at a time (sequential inference, window 1).
    pub fn generate_sequential(
        &mut self,
        groups: &[GroupRequest],
    ) -> Result<(Vec<GenResult>, AdaptiveStats)> {
        self.run(groups, 1)
    }

    /// Serve all groups as a no-bubble micro-batched pipeline.
    pub fn generate_pipelined(
        &mut self,
        groups: &[GroupRequest],
    ) -> Result<(Vec<GenResult>, AdaptiveStats)> {
        self.run(groups, groups.len().max(1))
    }

    /// Whether every stage of `plan` could hold the KV caches of groups
    /// with these batch sizes inside the per-stage KV budget — checked
    /// *before* committing to a migration so a replan can never tear down
    /// a working pipeline for a target that cannot admit the freight.
    fn preload_fits(&self, plan: &Plan, batches: &[usize]) -> bool {
        let c = &self.manifest.config;
        let n_model_layers = c.n_layers + 2;
        plan.stages.iter().all(|s| {
            let n_local = stage_decoders(&(s.start..s.end), n_model_layers).len();
            let total: u64 = batches
                .iter()
                .map(|&b| KvPool::group_bytes(n_local, b, c.n_kv_heads, c.max_seq, c.head_dim()))
                .sum();
            total <= self.cfg.engine.kv_budget_bytes
        })
    }

    fn run(
        &mut self,
        groups: &[GroupRequest],
        window: usize,
    ) -> Result<(Vec<GenResult>, AdaptiveStats)> {
        struct Active<'g> {
            req: &'g GroupRequest,
            rows: Vec<Vec<i32>>,
            start: Instant,
            ttft_ms: Option<f64>,
            last_iter_at: Instant,
            done: bool,
            in_flight: bool,
        }
        fn admit(g: &GroupRequest) -> Active<'_> {
            Active {
                req: g,
                rows: vec![Vec::new(); g.batch],
                start: Instant::now(),
                ttft_ms: None,
                last_iter_at: Instant::now(),
                done: false,
                in_flight: true,
            }
        }

        // Same admission contract as the static engine — reject up front
        // rather than letting a stage thread die on a missing variant.
        for g in groups {
            anyhow::ensure!(
                self.manifest.batch_sizes.contains(&g.batch),
                "batch {} not compiled (have {:?})",
                g.batch,
                self.manifest.batch_sizes
            );
            anyhow::ensure!(
                g.prompt_len == self.manifest.config.prefill_len,
                "prompt len {} != compiled {}",
                g.prompt_len,
                self.manifest.config.prefill_len
            );
        }

        let believed = self.live.snapshot();
        let (mut monitor, mon_handle) = Monitor::new(believed.clone(), self.cfg.monitor_alpha);
        let sinks = mon_handle.sinks();
        let mut wired = wire(
            self.manifest,
            self.weights,
            self.exec.clone(),
            &self.plan,
            &believed,
            &self.cfg.engine,
            Some(&sinks),
            Vec::new(),
        )?;
        let shared_links: Arc<Mutex<Vec<RoutedLink>>> = Arc::new(Mutex::new(wired.links.clone()));
        let driver = self.cfg.dynamics.clone().map(|d| {
            DynamicsDriver::spawn(
                d,
                self.live.clone(),
                shared_links.clone(),
                self.cfg.engine.time_scale,
                self.cfg.dynamics_tick_real_ms,
            )
        });

        let batch = groups.iter().map(|g| g.batch).max().unwrap_or(1);
        let baseline = match self.cfg.objective {
            PlanObjective::Latency => {
                sequential_latency_ms(&self.plan, &self.base_traces, &believed)
            }
            PlanObjective::Throughput => {
                pipeline_bottleneck_ms(&self.plan, &self.base_traces, &believed)
            }
        };
        let mut replanner =
            Replanner::new(self.cfg.objective, self.cfg.policy.clone(), batch, baseline);

        let t0 = Instant::now();
        let scale = self.cfg.engine.time_scale;
        let mut ttft = Histogram::new();
        let mut iter_lat = Histogram::new();
        let mut results = Vec::new();
        let mut active: HashMap<u64, Active> = HashMap::new();
        let mut queue = groups.iter();
        let mut in_flight_groups = 0usize;
        let mut received = 0u64;
        let mut real_tokens = 0u64;
        let mut pending: Option<(Plan, MigrationDiff, f64)> = None;
        let mut held: Vec<(u64, usize, Vec<i32>)> = Vec::new();
        let mut migrations: Vec<MigrationRecord> = Vec::new();

        // prime the window
        while in_flight_groups < window {
            let Some(g) = queue.next() else { break };
            send_prefill(&wired, g)?;
            active.insert(g.group_id, admit(g));
            in_flight_groups += 1;
        }

        while in_flight_groups > 0 {
            let tok = wired
                .token_rx
                .recv()
                .map_err(|_| anyhow!("adaptive pipeline closed unexpectedly"))?;
            received += 1;
            let a = active
                .get_mut(&tok.group)
                .with_context(|| format!("unknown group {}", tok.group))?;
            a.in_flight = false;
            let now = Instant::now();
            iter_lat.record(now.duration_since(a.last_iter_at).as_secs_f64() * 1e3);
            a.last_iter_at = now;
            if a.ttft_ms.is_none() {
                let ms = now.duration_since(a.start).as_secs_f64() * 1e3;
                a.ttft_ms = Some(ms);
                ttft.record(ms);
            }
            for (row, &t) in a.rows.iter_mut().zip(&tok.tokens) {
                row.push(t);
            }
            real_tokens += a.req.real() as u64;
            let next_iter = tok.iter + 1;
            if next_iter < a.req.max_new_tokens {
                if pending.is_some() {
                    held.push((tok.group, next_iter, tok.tokens));
                } else {
                    send_decode(&wired, a.req, next_iter, tok.tokens)?;
                    a.in_flight = true;
                }
            } else {
                a.done = true;
                let total = now.duration_since(a.start).as_secs_f64() * 1e3;
                for (i, &rid) in a.req.request_ids.iter().enumerate() {
                    results.push(GenResult {
                        id: rid,
                        tokens: a.rows[i].clone(),
                        ttft_ms: a.ttft_ms.unwrap_or(0.0),
                        total_ms: total,
                    });
                }
                wired.to_first.send(StageMsg::Free { group: tok.group }, 16)?;
                in_flight_groups -= 1;
                if pending.is_none() {
                    if let Some(g) = queue.next() {
                        send_prefill(&wired, g)?;
                        active.insert(g.group_id, admit(g));
                        in_flight_groups += 1;
                    }
                }
            }

            // control loop: consider replanning once everything prefilled
            if pending.is_none()
                && migrations.len() < self.cfg.max_migrations
                && self.cfg.check_every > 0
                && received % self.cfg.check_every as u64 == 0
                && active.values().all(|x| x.done || x.ttft_ms.is_some())
            {
                monitor.drain();
                let obs_cluster = monitor.observed_cluster();
                let obs_traces = monitor.observed_traces(&self.base_traces, &self.plan);
                let decision = replanner.evaluate(
                    &self.plan,
                    &obs_traces,
                    &obs_cluster,
                    sim_now_ms(t0, scale),
                );
                if let Decision::Migrate {
                    plan,
                    diff,
                    candidate_pred_ms,
                    ..
                } = decision
                {
                    let batches: Vec<usize> =
                        active.values().filter(|x| !x.done).map(|x| x.req.batch).collect();
                    if self.preload_fits(&plan, &batches) {
                        pending = Some((plan, diff, candidate_pred_ms));
                    }
                }
            }

            // barrier reached? (every unfinished group drained)
            if pending.is_some() && active.values().all(|x| x.done || !x.in_flight) {
                let (new_plan, diff, cand_pred) = pending.take().unwrap();
                // On a `None` the migration aborted and the old pipeline
                // (or a rewire of it) is still serving the current plan.
                if let Some(record) =
                    self.migrate(&mut wired, &sinks, &shared_links, &new_plan, &diff, received)?
                {
                    replanner.adopt(cand_pred, sim_now_ms(t0, scale));
                    migrations.push(record);
                    self.plan = new_plan;
                }
                for (gid, it, toks) in held.drain(..) {
                    let a = active
                        .get_mut(&gid)
                        .with_context(|| format!("held group {gid} vanished"))?;
                    send_decode(&wired, a.req, it, toks)?;
                    a.in_flight = true;
                }
                while in_flight_groups < window {
                    let Some(g) = queue.next() else { break };
                    send_prefill(&wired, g)?;
                    active.insert(g.group_id, admit(g));
                    in_flight_groups += 1;
                }
            }
        }

        if let Some(d) = driver {
            d.stop();
        }
        let _ = wired.to_first.send(StageMsg::Shutdown, 16);
        for h in wired.handles.drain(..) {
            match h.join() {
                Ok(r) => r?,
                Err(_) => anyhow::bail!("stage thread panicked"),
            }
        }

        let makespan = t0.elapsed().as_secs_f64() * 1e3;
        let stats = AdaptiveStats {
            makespan_ms: makespan,
            tokens: real_tokens,
            throughput_tps: if makespan > 0.0 {
                real_tokens as f64 / (makespan / 1e3)
            } else {
                0.0
            },
            ttft,
            iter_latency: iter_lat,
            replan_evaluations: replanner.evaluations(),
            migrations,
            final_plan: self.plan.describe(),
        };
        Ok((results, stats))
    }

    /// Route a flat KV snapshot onto `plan`'s stages: per-stage preloads
    /// in local layer order, plus the per-link freight that must cross
    /// the network (entries whose device changes).
    #[allow(clippy::type_complexity)]
    fn route_exports(
        &self,
        flat: &[(usize, KvEntry)],
        plan: &Plan,
    ) -> Result<(Vec<Vec<(u64, GroupCache)>>, HashMap<(usize, usize), u64>)> {
        let c = &self.manifest.config;
        let n_model_layers = c.n_layers + 2;
        let ranges: Vec<std::ops::Range<usize>> = plan
            .stages
            .iter()
            .map(|s| stage_decoders(&(s.start..s.end), n_model_layers))
            .collect();
        let mut per_stage: Vec<HashMap<u64, Vec<KvEntry>>> =
            (0..plan.n_stages()).map(|_| HashMap::new()).collect();
        let mut link_bytes: HashMap<(usize, usize), u64> = HashMap::new();
        for (from_dev, e) in flat {
            let si = ranges
                .iter()
                .position(|r| r.contains(&e.layer))
                .with_context(|| format!("decoder layer {} homeless in plan", e.layer))?;
            let new_dev = plan.stages[si].device;
            if new_dev != *from_dev {
                *link_bytes.entry((*from_dev, new_dev)).or_insert(0) += e.k.bytes() + e.v.bytes();
            }
            per_stage[si].entry(e.group).or_default().push(e.clone());
        }
        let mut preloads: Vec<Vec<(u64, GroupCache)>> = Vec::with_capacity(plan.n_stages());
        for (si, groups_map) in per_stage.into_iter().enumerate() {
            let n_local = ranges[si].len();
            let mut v: Vec<(u64, GroupCache)> = Vec::new();
            for (gid, mut entries) in groups_map.into_iter() {
                entries.sort_by_key(|e| e.layer);
                anyhow::ensure!(
                    entries.len() == n_local,
                    "group {gid}: stage {si} expected {n_local} migrated layers, got {}",
                    entries.len()
                );
                let batch = entries.first().map(|e| e.batch).unwrap_or(1);
                let bytes =
                    KvPool::group_bytes(n_local, batch, c.n_kv_heads, c.max_seq, c.head_dim());
                let layers = entries.into_iter().map(|e| (e.k, e.v)).collect();
                v.push((
                    gid,
                    GroupCache {
                        layers,
                        batch,
                        bytes,
                    },
                ));
            }
            preloads.push(v);
        }
        Ok((preloads, link_bytes))
    }

    /// Execute one migration: export KV, tear down, charge transfer time,
    /// rewire with preloaded caches.  Called only at a drained barrier.
    ///
    /// Returns `Ok(None)` when the migration aborted safely — either the
    /// snapshot could not be routed onto the new plan (old pipeline left
    /// untouched) or the new wiring failed (the old plan is re-wired with
    /// the same caches).  A hard `Err` means generation cannot continue.
    fn migrate(
        &self,
        wired: &mut Wired,
        sinks: &ObsSinks,
        shared_links: &Arc<Mutex<Vec<RoutedLink>>>,
        new_plan: &Plan,
        diff: &MigrationDiff,
        at_iter: u64,
    ) -> Result<Option<MigrationRecord>> {
        // 1. snapshot every stage's resident KV caches
        let (reply_tx, reply_rx) = mpsc::channel();
        wired.to_first.send(StageMsg::Export { reply: reply_tx }, 16)?;
        let mut exports: Vec<StageExport> = Vec::new();
        for _ in 0..self.plan.n_stages() {
            exports.push(
                reply_rx
                    .recv()
                    .map_err(|_| anyhow!("stage export lost (pipeline died mid-migration)"))?,
            );
        }
        let mut flat: Vec<(usize, KvEntry)> = Vec::new();
        for ex in exports {
            let dev = ex.device;
            for e in ex.entries {
                flat.push((dev, e));
            }
        }

        // 2. route onto the new plan BEFORE touching the running pipeline
        //    — an unroutable snapshot aborts with everything still serving.
        let Ok((preloads, link_bytes)) = self.route_exports(&flat, new_plan) else {
            return Ok(None);
        };

        // 3. tear down the old pipeline
        wired.to_first.send(StageMsg::Shutdown, 16)?;
        for h in wired.handles.drain(..) {
            match h.join() {
                Ok(r) => r?,
                Err(_) => anyhow::bail!("stage thread panicked during migration"),
            }
        }

        // 4. charge the real KV transfer time on the *current* network:
        //    per-link freight serializes, distinct links overlap.
        let cluster_now = self.live.snapshot();
        let pause_sim_ms = link_bytes
            .iter()
            .map(|(&(f, t), &b)| cluster_now.comm_ms(f, t, b))
            .fold(0.0, f64::max);
        let scale = self.cfg.engine.time_scale;
        if pause_sim_ms > 0.0 && scale > 0.0 {
            let real_ms = (pause_sim_ms * scale).min(MAX_MIGRATION_SLEEP_REAL_MS);
            std::thread::sleep(Duration::from_secs_f64(real_ms / 1e3));
        }

        // 5. rewire on the current ground-truth network; if the new plan
        //    cannot be wired, restore the old one with the same caches.
        match wire(
            self.manifest,
            self.weights,
            self.exec.clone(),
            new_plan,
            &cluster_now,
            &self.cfg.engine,
            Some(sinks),
            preloads,
        ) {
            Ok(w) => {
                *wired = w;
                *shared_links.lock().expect("links lock poisoned") = wired.links.clone();
                Ok(Some(MigrationRecord {
                    at_iter,
                    from_plan: self.plan.describe(),
                    to_plan: new_plan.describe(),
                    kv_bytes: diff.total_kv_bytes,
                    pause_ms: pause_sim_ms,
                }))
            }
            Err(_) => {
                let (old_preloads, _) = self.route_exports(&flat, &self.plan)?;
                *wired = wire(
                    self.manifest,
                    self.weights,
                    self.exec.clone(),
                    &self.plan,
                    &cluster_now,
                    &self.cfg.engine,
                    Some(sinks),
                    old_preloads,
                )
                .context("re-wiring the previous plan after a failed migration")?;
                *shared_links.lock().expect("links lock poisoned") = wired.links.clone();
                Ok(None)
            }
        }
    }
}
